//! Local training driver: wraps the AOT model graphs behind a typed API.
//!
//! Each FL client owns a [`LocalTrainer`] bound to the shared runtime; all
//! compute (forward/backward, sensitivity, evaluation) flows through the
//! PJRT artifacts — no gradient math happens in Rust.

use super::data::{ImageDataset, TokenDataset};
use crate::runtime::executor::{Arg, Runtime};

/// The model workload a trainer runs.
pub enum Workload {
    Image(ImageDataset),
    Token(TokenDataset),
}

/// Typed driver for one model's AOT graphs.
pub struct LocalTrainer<'a> {
    pub rt: &'a Runtime,
    pub model: String,
    pub batch: usize,
    pub param_count: usize,
    /// Per-sample input dims as the artifact expects them (e.g. [1,28,28]
    /// for lenet, [784] for the flat-input mlp).
    input_dims: Vec<i64>,
    cursor: usize,
}

impl<'a> LocalTrainer<'a> {
    pub fn new(rt: &'a Runtime, model: &str) -> anyhow::Result<Self> {
        let meta = rt
            .manifest
            .models
            .get(model)
            .ok_or_else(|| anyhow::anyhow!("model '{model}' has no artifacts"))?;
        Ok(LocalTrainer {
            rt,
            model: model.to_string(),
            batch: rt.manifest.train_batch,
            param_count: meta.param_count,
            input_dims: meta.input_shape.iter().map(|&d| d as i64).collect(),
            cursor: 0,
        })
    }

    /// Input literal dims for a given batch size (images reshape to the
    /// artifact's expectation; a flat [F] spec absorbs C·H·W).
    fn x_dims(&self, batch: usize) -> Vec<i64> {
        let mut dims = vec![batch as i64];
        dims.extend_from_slice(&self.input_dims);
        dims
    }

    /// Run `steps` local SGD steps; returns (new_params, mean loss).
    pub fn train(
        &mut self,
        params: &[f32],
        data: &Workload,
        steps: usize,
        lr: f32,
    ) -> anyhow::Result<(Vec<f32>, f32)> {
        anyhow::ensure!(params.len() == self.param_count, "param length mismatch");
        let graph = format!("{}_train", self.model);
        let mut w = params.to_vec();
        let mut loss_sum = 0.0f32;
        for _ in 0..steps {
            let out = match data {
                Workload::Image(d) => {
                    let (x, y) = d.batch(self.cursor, self.batch);
                    self.cursor = (self.cursor + self.batch) % d.len().max(1);
                    self.rt.execute(
                        &graph,
                        &[
                            Arg::F32(&w, vec![w.len() as i64]),
                            Arg::F32(&x, self.x_dims(self.batch)),
                            Arg::I32(&y, vec![self.batch as i64]),
                            Arg::ScalarF32(lr),
                        ],
                    )?
                }
                Workload::Token(d) => {
                    let (x, y) = d.batch(self.cursor, self.batch);
                    self.cursor = (self.cursor + self.batch) % d.len().max(1);
                    self.rt.execute(
                        &graph,
                        &[
                            Arg::F32(&w, vec![w.len() as i64]),
                            Arg::I32(&x, vec![self.batch as i64, d.seq_len as i64]),
                            Arg::I32(&y, vec![self.batch as i64, d.seq_len as i64]),
                            Arg::ScalarF32(lr),
                        ],
                    )?
                }
            };
            w = out[0].to_vec::<f32>()?;
            loss_sum += out[1].to_vec::<f32>()?[0];
        }
        Ok((w, loss_sum / steps.max(1) as f32))
    }

    /// Evaluate (mean loss, accuracy) over `n_batches` batches.
    pub fn evaluate(
        &mut self,
        params: &[f32],
        data: &Workload,
        n_batches: usize,
    ) -> anyhow::Result<(f32, f32)> {
        let graph = format!("{}_eval", self.model);
        let mut loss_sum = 0.0f32;
        let mut correct = 0.0f32;
        let mut seen = 0.0f32;
        for _ in 0..n_batches {
            let out = match data {
                Workload::Image(d) => {
                    let (x, y) = d.batch(self.cursor, self.batch);
                    self.cursor = (self.cursor + self.batch) % d.len().max(1);
                    self.rt.execute(
                        &graph,
                        &[
                            Arg::F32(params, vec![params.len() as i64]),
                            Arg::F32(&x, self.x_dims(self.batch)),
                            Arg::I32(&y, vec![self.batch as i64]),
                        ],
                    )?
                }
                Workload::Token(d) => {
                    let (x, y) = d.batch(self.cursor, self.batch);
                    self.cursor = (self.cursor + self.batch) % d.len().max(1);
                    self.rt.execute(
                        &graph,
                        &[
                            Arg::F32(params, vec![params.len() as i64]),
                            Arg::I32(&x, vec![self.batch as i64, d.seq_len as i64]),
                            Arg::I32(&y, vec![self.batch as i64, d.seq_len as i64]),
                        ],
                    )?
                }
            };
            // outputs are (loss, correct)
            loss_sum += out[0].to_vec::<f32>()?[0];
            correct += out[1].to_vec::<f32>()?[0];
            seen += match data {
                Workload::Image(_) => self.batch as f32,
                Workload::Token(d) => (self.batch * d.seq_len) as f32,
            };
        }
        Ok((loss_sum / n_batches.max(1) as f32, correct / seen.max(1.0)))
    }

    /// Per-parameter privacy sensitivity over one K-sample batch (§2.4 step 1).
    pub fn sensitivity(&mut self, params: &[f32], data: &Workload) -> anyhow::Result<Vec<f32>> {
        let graph = format!("{}_sens", self.model);
        let k = self.rt.manifest.sens_batch;
        let out = match data {
            Workload::Image(d) => {
                let (x, y) = d.batch(0, k);
                self.rt.execute(
                    &graph,
                    &[
                        Arg::F32(params, vec![params.len() as i64]),
                        Arg::F32(&x, self.x_dims(k)),
                        Arg::I32(&y, vec![k as i64]),
                    ],
                )?
            }
            Workload::Token(d) => {
                let (x, y) = d.batch(0, k);
                self.rt.execute(
                    &graph,
                    &[
                        Arg::F32(params, vec![params.len() as i64]),
                        Arg::I32(&x, vec![k as i64, d.seq_len as i64]),
                        Arg::I32(&y, vec![k as i64, d.seq_len as i64]),
                    ],
                )?
            }
        };
        Ok(out[0].to_vec::<f32>()?)
    }

    /// Flat gradient on one batch (attack target / FedSGD mode).
    pub fn gradient(&mut self, params: &[f32], data: &Workload) -> anyhow::Result<Vec<f32>> {
        let graph = format!("{}_grad", self.model);
        let out = match data {
            Workload::Image(d) => {
                let (x, y) = d.batch(0, self.batch);
                self.rt.execute(
                    &graph,
                    &[
                        Arg::F32(params, vec![params.len() as i64]),
                        Arg::F32(&x, self.x_dims(self.batch)),
                        Arg::I32(&y, vec![self.batch as i64]),
                    ],
                )?
            }
            Workload::Token(d) => {
                let (x, y) = d.batch(0, self.batch);
                self.rt.execute(
                    &graph,
                    &[
                        Arg::F32(params, vec![params.len() as i64]),
                        Arg::I32(&x, vec![self.batch as i64, d.seq_len as i64]),
                        Arg::I32(&y, vec![self.batch as i64, d.seq_len as i64]),
                    ],
                )?
            }
        };
        Ok(out[0].to_vec::<f32>()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::data::synthetic_images;
    use std::path::PathBuf;

    fn runtime() -> Option<Runtime> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return None;
        }
        Some(Runtime::new(dir).unwrap())
    }

    #[test]
    fn mlp_trains_on_synthetic_images() {
        let Some(rt) = runtime() else { return };
        let mut t = LocalTrainer::new(&rt, "mlp").unwrap();
        // mlp takes flat 784 inputs: shape (784, 1, 1) doesn't match the
        // artifact's [B, 784]; use the image dataset flattened
        let d = synthetic_images(0, 64, (1, 28, 28), 10, 0.5, 1);
        // flatten workload: reinterpret as (784,) via custom call below
        let params = rt.manifest.load_init_params("mlp").unwrap();
        // call the graph directly since mlp takes [B, 784]
        let (x, y) = d.batch(0, t.batch);
        let out = rt
            .execute(
                "mlp_train",
                &[
                    Arg::F32(&params, vec![params.len() as i64]),
                    Arg::F32(&x, vec![t.batch as i64, 784]),
                    Arg::I32(&y, vec![t.batch as i64]),
                    Arg::ScalarF32(0.1),
                ],
            )
            .unwrap();
        assert_eq!(out[0].to_vec::<f32>().unwrap().len(), params.len());
        let _ = &mut t;
    }

    #[test]
    fn lenet_full_loop() {
        let Some(rt) = runtime() else { return };
        let mut t = LocalTrainer::new(&rt, "lenet").unwrap();
        let d = Workload::Image(synthetic_images(0, 64, (1, 28, 28), 10, 0.5, 2));
        let params = rt.manifest.load_init_params("lenet").unwrap();
        let (w1, loss1) = t.train(&params, &d, 3, 0.05).unwrap();
        assert_eq!(w1.len(), params.len());
        assert!(loss1.is_finite() && loss1 > 0.0);
        let (w2, loss2) = t.train(&w1, &d, 12, 0.05).unwrap();
        assert!(loss2 < loss1, "loss {loss1} -> {loss2}");
        let (eval_loss, acc) = t.evaluate(&w2, &d, 2).unwrap();
        assert!(eval_loss.is_finite());
        assert!((0.0..=1.0).contains(&acc));
        let s = t.sensitivity(&w2, &d).unwrap();
        assert_eq!(s.len(), params.len());
        assert!(s.iter().all(|&v| v >= 0.0));
        let g = t.gradient(&w2, &d).unwrap();
        assert_eq!(g.len(), params.len());
    }
}
