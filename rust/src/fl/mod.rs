//! Federated-learning substrate: model registry, synthetic datasets, and the
//! local training driver over the AOT artifacts.

pub mod data;
pub mod model_meta;
pub mod synthetic;
pub mod trainer;

pub use model_meta::{ModelInfo, TABLE4_MODELS};
pub use synthetic::{SyntheticClient, SyntheticModel, SYNTHETIC_DEFAULT_DIM, SYNTHETIC_MODEL};
pub use trainer::{LocalTrainer, Workload};
