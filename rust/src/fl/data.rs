//! Synthetic federated datasets.
//!
//! Substitutes for the paper's CIFAR-100 / wikitext samples (DESIGN.md §3):
//! class-conditional structured images (each class is a distinct spatial
//! frequency/orientation pattern plus noise) and Markov-ish token streams.
//! Heterogeneity across clients is induced by Dirichlet-style label skew —
//! the source of the per-client sensitivity-map differences that motivate
//! the secure mask aggregation of §2.4.

use crate::crypto::prng::ChaChaRng;

/// A labeled image dataset in flat NCHW f32 layout.
#[derive(Debug, Clone)]
pub struct ImageDataset {
    pub shape: (usize, usize, usize), // (C, H, W)
    pub images: Vec<f32>,             // n * C*H*W
    pub labels: Vec<i32>,
    pub num_classes: usize,
}

impl ImageDataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }
    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }
    fn image_size(&self) -> usize {
        self.shape.0 * self.shape.1 * self.shape.2
    }

    /// Copy a batch (wrapping) starting at `start` into (x, y) buffers.
    pub fn batch(&self, start: usize, batch: usize) -> (Vec<f32>, Vec<i32>) {
        let isz = self.image_size();
        let mut x = Vec::with_capacity(batch * isz);
        let mut y = Vec::with_capacity(batch);
        for b in 0..batch {
            let i = (start + b) % self.len();
            x.extend_from_slice(&self.images[i * isz..(i + 1) * isz]);
            y.push(self.labels[i]);
        }
        (x, y)
    }
}

/// Deterministic class pattern: oriented sinusoid whose frequency and
/// orientation encode the class.
fn class_pattern(c: usize, ch: usize, h: usize, w: usize, out: &mut [f32]) {
    let freq = 1.0 + (c % 5) as f32;
    let theta = (c as f32) * std::f32::consts::PI / 10.0;
    let (s, co) = theta.sin_cos();
    for z in 0..ch {
        for i in 0..h {
            for j in 0..w {
                let u = i as f32 / h as f32 - 0.5;
                let v = j as f32 / w as f32 - 0.5;
                let phase = 2.0 * std::f32::consts::PI * freq * (u * co + v * s)
                    + z as f32 * 0.7;
                out[(z * h + i) * w + j] = phase.sin();
            }
        }
    }
}

/// Generate a client's local dataset with label skew: the client's "home"
/// classes (determined by `client_id`) dominate with probability `skew`.
pub fn synthetic_images(
    client_id: usize,
    n_samples: usize,
    shape: (usize, usize, usize),
    num_classes: usize,
    skew: f64,
    seed: u64,
) -> ImageDataset {
    let mut rng = ChaChaRng::from_seed(seed, client_id as u64 + 1);
    let (c, h, w) = shape;
    let isz = c * h * w;
    let mut images = vec![0.0f32; n_samples * isz];
    let mut labels = Vec::with_capacity(n_samples);
    let mut pattern = vec![0.0f32; isz];
    let home = client_id % num_classes;
    for s in 0..n_samples {
        let label = if rng.uniform_f64() < skew {
            // home classes: a pair per client
            if rng.uniform_f64() < 0.5 {
                home
            } else {
                (home + 1) % num_classes
            }
        } else {
            rng.uniform_usize(num_classes)
        };
        labels.push(label as i32);
        class_pattern(label, c, h, w, &mut pattern);
        let img = &mut images[s * isz..(s + 1) * isz];
        for (dst, &p) in img.iter_mut().zip(pattern.iter()) {
            *dst = p + 0.3 * (rng.normal_f64() as f32);
        }
    }
    ImageDataset {
        shape,
        images,
        labels,
        num_classes,
    }
}

/// A token dataset for the tinybert workload: order-1 Markov streams whose
/// transition structure differs per client.
#[derive(Debug, Clone)]
pub struct TokenDataset {
    pub seq_len: usize,
    pub vocab: usize,
    /// n * seq_len input tokens.
    pub tokens: Vec<i32>,
    /// n * seq_len next-token targets.
    pub targets: Vec<i32>,
}

impl TokenDataset {
    pub fn len(&self) -> usize {
        self.tokens.len() / self.seq_len
    }

    pub fn batch(&self, start: usize, batch: usize) -> (Vec<i32>, Vec<i32>) {
        let mut x = Vec::with_capacity(batch * self.seq_len);
        let mut y = Vec::with_capacity(batch * self.seq_len);
        for b in 0..batch {
            let i = (start + b) % self.len();
            x.extend_from_slice(&self.tokens[i * self.seq_len..(i + 1) * self.seq_len]);
            y.extend_from_slice(&self.targets[i * self.seq_len..(i + 1) * self.seq_len]);
        }
        (x, y)
    }
}

/// Generate Markov token sequences: token t+1 ≈ a·t + b (mod vocab) with
/// client-dependent (a, b) plus noise — enough structure for the LM loss to
/// fall and for inversion attacks to have something to recover.
pub fn synthetic_tokens(
    client_id: usize,
    n_seqs: usize,
    seq_len: usize,
    vocab: usize,
    seed: u64,
) -> TokenDataset {
    let mut rng = ChaChaRng::from_seed(seed, 1000 + client_id as u64);
    let a = 3 + 2 * (client_id % 5); // odd multiplier
    let b = 7 * (client_id + 1);
    let mut tokens = Vec::with_capacity(n_seqs * seq_len);
    let mut targets = Vec::with_capacity(n_seqs * seq_len);
    for _ in 0..n_seqs {
        let mut t = rng.uniform_usize(vocab);
        for _ in 0..seq_len {
            tokens.push(t as i32);
            let next = if rng.uniform_f64() < 0.9 {
                (a * t + b) % vocab
            } else {
                rng.uniform_usize(vocab)
            };
            targets.push(next as i32);
            t = next;
        }
    }
    TokenDataset {
        seq_len,
        vocab,
        tokens,
        targets,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn images_are_deterministic_and_client_specific() {
        let a = synthetic_images(0, 16, (1, 28, 28), 10, 0.8, 42);
        let b = synthetic_images(0, 16, (1, 28, 28), 10, 0.8, 42);
        let c = synthetic_images(1, 16, (1, 28, 28), 10, 0.8, 42);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
        assert_ne!(a.images, c.images);
        assert_eq!(a.len(), 16);
        assert!(a.labels.iter().all(|&l| (0..10).contains(&l)));
    }

    #[test]
    fn label_skew_concentrates_home_classes() {
        let d = synthetic_images(3, 400, (1, 28, 28), 10, 0.9, 7);
        let home_count = d
            .labels
            .iter()
            .filter(|&&l| l == 3 || l == 4)
            .count();
        // ≥ ~85% in the two home classes under skew 0.9
        assert!(home_count > 300, "home {home_count}");
        let uniform = synthetic_images(3, 400, (1, 28, 28), 10, 0.0, 7);
        let home_uniform = uniform
            .labels
            .iter()
            .filter(|&&l| l == 3 || l == 4)
            .count();
        assert!(home_uniform < 150, "uniform {home_uniform}");
    }

    #[test]
    fn batch_wraps_around() {
        let d = synthetic_images(0, 5, (1, 8, 8), 10, 0.5, 1);
        let (x, y) = d.batch(3, 4);
        assert_eq!(x.len(), 4 * 64);
        assert_eq!(y.len(), 4);
        assert_eq!(y[2], d.labels[0]); // wrapped
    }

    #[test]
    fn tokens_in_range_and_structured() {
        let d = synthetic_tokens(2, 32, 16, 128, 9);
        assert_eq!(d.len(), 32);
        assert!(d.tokens.iter().all(|&t| (0..128).contains(&t)));
        // structure: ≥80% of transitions follow the affine rule
        let a = 3 + 2 * (2 % 5);
        let b = 7 * 3;
        let mut follow = 0;
        let mut total = 0;
        for s in 0..d.len() {
            for j in 0..d.seq_len {
                let t = d.tokens[s * 16 + j] as usize;
                let y = d.targets[s * 16 + j] as usize;
                total += 1;
                if y == (a * t + b) % 128 {
                    follow += 1;
                }
            }
        }
        assert!(follow * 10 > total * 8, "{follow}/{total}");
    }
}
