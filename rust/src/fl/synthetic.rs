//! Artifact-free synthetic workload: a deterministic pure-Rust "trainer"
//! with the same client-facing surface as the AOT-artifact path.
//!
//! The multi-process `serve`/`join` deployment (DESIGN.md §9) and its CI
//! smoke must run — and be *bitwise reproducible* — on machines without the
//! PJRT artifacts. The `synthetic` model provides that: every quantity is a
//! pure function of `(seed, client id, round)`, local training is an exact
//! contraction toward a per-client target (so losses trend down and FedAvg
//! converges), and **no RNG is consumed by training itself** — exactly like
//! the artifact path, where the client's ChaCha stream feeds only
//! encryption and DP noise. Two processes that run the same synthetic
//! client therefore produce byte-identical updates.

use crate::crypto::prng::ChaChaRng;

/// Model name that selects the synthetic workload.
pub const SYNTHETIC_MODEL: &str = "synthetic";

/// Default flat parameter count of the synthetic model.
pub const SYNTHETIC_DEFAULT_DIM: usize = 4096;

/// The synthetic model family: a flat `dim`-parameter vector whose loss
/// landscape for client `c` is `½‖p − t_c‖²` with a seeded target `t_c`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SyntheticModel {
    pub dim: usize,
    pub seed: u64,
}

impl SyntheticModel {
    pub fn new(dim: usize, seed: u64) -> Self {
        assert!(dim >= 1, "synthetic model needs at least one parameter");
        SyntheticModel { dim, seed }
    }

    /// Deterministic initial global parameters (shared by every process of
    /// a run — the out-of-band equivalent of the artifact init file).
    pub fn init_params(&self) -> Vec<f32> {
        let mut rng = ChaChaRng::from_seed(self.seed, 0xB007);
        (0..self.dim)
            .map(|_| (rng.normal_f64() * 0.05) as f32)
            .collect()
    }

    /// Client `id`'s target vector (its "local data distribution").
    pub fn target(&self, id: u64) -> Vec<f32> {
        let mut rng = ChaChaRng::from_seed(self.seed, 0x7A36_0000 ^ id);
        (0..self.dim)
            .map(|_| (rng.normal_f64() * 0.5) as f32)
            .collect()
    }
}

/// One synthetic federated client. Mirrors `FlClient`'s surface (alpha,
/// rng, sensitivity / train / evaluate) without touching the runtime.
pub struct SyntheticClient {
    pub id: u64,
    pub alpha: f64,
    pub model: SyntheticModel,
    pub rng: ChaChaRng,
    target: Vec<f32>,
}

impl SyntheticClient {
    /// Build client `id` of `n_clients`; the rng stream id matches
    /// `FlClient::new` so sim and remote drivers stay interchangeable.
    pub fn new(model: SyntheticModel, id: u64, n_clients: usize) -> Self {
        SyntheticClient {
            id,
            alpha: 1.0 / n_clients.max(1) as f64,
            model,
            rng: ChaChaRng::from_seed(model.seed, 0x1000 + id),
            target: model.target(id),
        }
    }

    /// Rebind this pooled slot to virtual cohort member `vid` for one round
    /// (the synthetic analogue of `FlClient::bind_virtual`).
    pub fn bind_virtual(&mut self, vid: u64, alpha: f64, client_seed: u64, round: u64) {
        self.id = vid;
        self.alpha = alpha;
        self.rng = ChaChaRng::from_seed(client_seed.wrapping_add(round), 0x7000 ^ vid);
        self.target = self.model.target(vid);
    }

    /// Local sensitivity map: |∂loss/∂p| = |p − t| at the global point.
    pub fn sensitivity(&self, global: &[f32]) -> Vec<f32> {
        assert_eq!(global.len(), self.model.dim, "global/model dim mismatch");
        global
            .iter()
            .zip(self.target.iter())
            .map(|(&p, &t)| (p - t).abs())
            .collect()
    }

    /// `steps` exact gradient steps of `½‖p − t‖²`; returns the updated
    /// local model and the pre-training loss (the convention of the
    /// artifact trainer's reported mean loss: it trends down across
    /// rounds as the global approaches the FedAvg fixed point).
    pub fn train(&self, global: &[f32], steps: usize, lr: f32) -> (Vec<f32>, f32) {
        assert_eq!(global.len(), self.model.dim, "global/model dim mismatch");
        let mut p = global.to_vec();
        let loss = self.loss(global);
        let k = 1.0 - (1.0 - lr).powi(steps.max(1) as i32);
        for (v, &t) in p.iter_mut().zip(self.target.iter()) {
            // closed form of `steps` iterations of p ← p − lr·(p − t)
            *v -= k * (*v - t);
        }
        (p, loss)
    }

    /// Mean squared distance to the local target.
    pub fn loss(&self, global: &[f32]) -> f32 {
        let mse: f64 = global
            .iter()
            .zip(self.target.iter())
            .map(|(&p, &t)| ((p - t) as f64).powi(2))
            .sum::<f64>()
            / self.model.dim as f64;
        mse as f32
    }

    /// Evaluation: (loss, pseudo-accuracy in (0, 1]).
    pub fn evaluate(&self, global: &[f32]) -> (f32, f32) {
        let l = self.loss(global);
        (l, 1.0 / (1.0 + l))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn everything_is_deterministic() {
        let m = SyntheticModel::new(128, 9);
        assert_eq!(m.init_params(), m.init_params());
        assert_eq!(m.target(3), m.target(3));
        assert_ne!(m.target(3), m.target(4));
        let c1 = SyntheticClient::new(m, 2, 4);
        let c2 = SyntheticClient::new(m, 2, 4);
        let g = m.init_params();
        assert_eq!(c1.train(&g, 3, 0.1), c2.train(&g, 3, 0.1));
        assert_eq!(c1.sensitivity(&g), c2.sensitivity(&g));
    }

    #[test]
    fn training_contracts_toward_the_target() {
        let m = SyntheticModel::new(256, 4);
        let c = SyntheticClient::new(m, 0, 1);
        let g = m.init_params();
        let (p1, l0) = c.train(&g, 4, 0.2);
        let (_, l1) = c.train(&p1, 4, 0.2);
        assert!(l1 < l0, "loss did not decrease: {l0} -> {l1}");
        // closed form equals literal iteration
        let mut q = g.clone();
        for _ in 0..4 {
            for (v, &t) in q.iter_mut().zip(c.target.iter()) {
                *v -= 0.2 * (*v - t);
            }
        }
        for (a, b) in p1.iter().zip(q.iter()) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn training_consumes_no_rng() {
        let m = SyntheticModel::new(64, 7);
        let mut c = SyntheticClient::new(m, 1, 2);
        let before = c.rng.next_u64();
        let mut c2 = SyntheticClient::new(m, 1, 2);
        let g = m.init_params();
        let _ = c2.train(&g, 8, 0.1);
        let _ = c2.sensitivity(&g);
        let _ = c2.evaluate(&g);
        assert_eq!(c2.rng.next_u64(), before);
        let _ = c.bind_virtual(5, 0.5, 123, 2);
        assert_eq!(c.id, 5);
    }
}
