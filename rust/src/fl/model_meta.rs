//! Model registry: the paper's Table-4 benchmark models (exact parameter
//! counts) plus the locally-trainable models shipped as AOT artifacts.
//!
//! The benchmark models exist only as flat parameter counts — the paper's
//! own HE microbenchmarks flatten models to 1-D vectors before encryption
//! (Table 3 APIs), so overhead reproduction needs nothing else. For
//! layer-granularity mask selection each entry additionally records its
//! weight-tensor count; [`layer_spans`] synthesizes the contiguous per-layer
//! spans of the flat vector from it (the mask cost depends only on the span
//! count and placement, not the exact per-tensor sizes).

/// A model entry in the registry.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelInfo {
    pub name: &'static str,
    /// Flat parameter count (paper's Table 4 column "Model Size").
    pub params: u64,
    /// Whether an AOT train/eval/sens artifact exists for local training.
    pub trainable: bool,
    /// Weight-tensor (layer) count — the run count of a layer-granularity
    /// mask and the length of the per-layer sensitivity score vector.
    pub layers: u32,
}

/// The paper's Table-4 model suite (sizes verbatim from the paper).
pub const TABLE4_MODELS: &[ModelInfo] = &[
    ModelInfo { name: "linear", params: 101, trainable: false, layers: 2 },
    ModelInfo { name: "ts-transformer", params: 5_609, trainable: false, layers: 26 },
    ModelInfo { name: "mlp", params: 79_510, trainable: true, layers: 4 },
    ModelInfo { name: "lenet", params: 88_648, trainable: false, layers: 10 },
    ModelInfo { name: "rnn", params: 822_570, trainable: false, layers: 8 },
    ModelInfo { name: "cnn", params: 1_663_370, trainable: false, layers: 8 },
    ModelInfo { name: "mobilenet", params: 3_315_428, trainable: false, layers: 137 },
    ModelInfo { name: "resnet18", params: 12_556_426, trainable: false, layers: 62 },
    ModelInfo { name: "resnet34", params: 21_797_672, trainable: false, layers: 110 },
    ModelInfo { name: "resnet50", params: 25_557_032, trainable: false, layers: 161 },
    ModelInfo { name: "groupvit", params: 55_726_609, trainable: false, layers: 272 },
    ModelInfo { name: "vit", params: 86_389_248, trainable: false, layers: 152 },
    ModelInfo { name: "bert", params: 109_482_240, trainable: false, layers: 199 },
    ModelInfo { name: "llama2", params: 6_738_000_000, trainable: false, layers: 291 },
];

/// Fallback layer count for models not in the Table-4 registry.
pub const DEFAULT_LAYERS: u32 = 16;

/// Look up a Table-4 model.
pub fn lookup(name: &str) -> Option<ModelInfo> {
    TABLE4_MODELS.iter().copied().find(|m| m.name == name)
}

/// Contiguous per-layer parameter spans of a flat `params`-sized vector:
/// `layers` blocks whose sizes differ by at most one. The registry stores
/// only flat counts, so spans are synthesized — enough structure for
/// layer-granularity masks, whose wire and selection cost is O(layers).
pub fn layer_spans(params: u64, layers: u32) -> Vec<std::ops::Range<usize>> {
    let total = params as usize;
    if total == 0 {
        return Vec::new();
    }
    let n = (layers.max(1) as usize).min(total);
    let base = total / n;
    let rem = total % n;
    let mut spans = Vec::with_capacity(n);
    let mut lo = 0usize;
    for i in 0..n {
        let len = base + usize::from(i < rem);
        spans.push(lo..lo + len);
        lo += len;
    }
    spans
}

/// Layer spans for a named model over an observed flat parameter count: the
/// registry's layer count when known (`DEFAULT_LAYERS` otherwise) over the
/// *actual* total, so the spans always tile the loaded model exactly.
pub fn layer_spans_for(model: &str, total: usize) -> Vec<std::ops::Range<usize>> {
    let layers = lookup(model).map(|m| m.layers).unwrap_or(DEFAULT_LAYERS);
    layer_spans(total as u64, layers)
}

impl ModelInfo {
    /// This model's synthesized per-layer spans.
    pub fn layer_spans(&self) -> Vec<std::ops::Range<usize>> {
        layer_spans(self.params, self.layers)
    }
}

/// Plaintext wire size of a flat f32 model.
pub fn plaintext_bytes(params: u64) -> u64 {
    4 * params
}

/// Ciphertext wire size when fully encrypting `params` values with the
/// given context (ceil-div into packed ciphertexts).
pub fn ciphertext_bytes(params: u64, ctx: &crate::ckks::CkksParams) -> u64 {
    let batch = (ctx.n / 2) as u64;
    params.div_ceil(batch) * ctx.ciphertext_bytes() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_is_sorted_and_complete() {
        assert_eq!(TABLE4_MODELS.len(), 14);
        for w in TABLE4_MODELS.windows(2) {
            assert!(w[0].params < w[1].params, "registry must be size-sorted");
        }
        assert_eq!(lookup("resnet50").unwrap().params, 25_557_032);
        assert!(lookup("nope").is_none());
        // every entry has a plausible layer structure
        for m in TABLE4_MODELS {
            assert!(m.layers >= 1 && (m.layers as u64) <= m.params, "{}", m.name);
        }
    }

    #[test]
    fn layer_spans_tile_the_flat_vector() {
        for m in TABLE4_MODELS.iter().filter(|m| m.params < 10_000_000_000) {
            let spans = m.layer_spans();
            assert_eq!(spans.len(), m.layers as usize, "{}", m.name);
            let mut lo = 0usize;
            for s in &spans {
                assert_eq!(s.start, lo, "{}", m.name);
                assert!(s.end > s.start, "{}", m.name);
                lo = s.end;
            }
            assert_eq!(lo, m.params as usize, "{}", m.name);
        }
        // degenerate inputs
        assert!(layer_spans(0, 5).is_empty());
        assert_eq!(layer_spans(3, 10).len(), 3); // never more spans than params
        // unknown model falls back to DEFAULT_LAYERS over the observed total
        let spans = layer_spans_for("mystery", 1000);
        assert_eq!(spans.len(), DEFAULT_LAYERS as usize);
        assert_eq!(spans.last().unwrap().end, 1000);
    }

    #[test]
    fn comm_expansion_matches_paper_ratio() {
        // Paper Table 4: ResNet-50 → 1.58 GB ciphertext vs 97.79 MB
        // plaintext (ratio 16.58). Our wire format gives the same ~16×.
        let ctx = crate::ckks::CkksParams::new(8192, 4, 52).unwrap();
        let m = lookup("resnet50").unwrap();
        let ct = ciphertext_bytes(m.params, &ctx) as f64;
        let pt = plaintext_bytes(m.params) as f64;
        let ratio = ct / pt;
        assert!((15.0..18.0).contains(&ratio), "ratio {ratio}");
        // absolute size ~1.5–1.7 GB
        assert!((1.4e9..1.8e9).contains(&ct), "ct bytes {ct}");
    }

    #[test]
    fn small_models_pay_full_ciphertext() {
        // Table 4 anomaly reproduced: a 101-parameter model still ships one
        // full ciphertext (240× comm ratio in the paper).
        let ctx = crate::ckks::CkksParams::new(8192, 4, 52).unwrap();
        let m = lookup("linear").unwrap();
        let ratio = ciphertext_bytes(m.params, &ctx) as f64 / plaintext_bytes(m.params) as f64;
        assert!(ratio > 100.0, "ratio {ratio}");
    }
}
