//! Model registry: the paper's Table-4 benchmark models (exact parameter
//! counts) plus the locally-trainable models shipped as AOT artifacts.
//!
//! The benchmark models exist only as flat parameter counts — the paper's
//! own HE microbenchmarks flatten models to 1-D vectors before encryption
//! (Table 3 APIs), so overhead reproduction needs nothing else.

/// A model entry in the registry.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelInfo {
    pub name: &'static str,
    /// Flat parameter count (paper's Table 4 column "Model Size").
    pub params: u64,
    /// Whether an AOT train/eval/sens artifact exists for local training.
    pub trainable: bool,
}

/// The paper's Table-4 model suite (sizes verbatim from the paper).
pub const TABLE4_MODELS: &[ModelInfo] = &[
    ModelInfo { name: "linear", params: 101, trainable: false },
    ModelInfo { name: "ts-transformer", params: 5_609, trainable: false },
    ModelInfo { name: "mlp", params: 79_510, trainable: true },
    ModelInfo { name: "lenet", params: 88_648, trainable: false },
    ModelInfo { name: "rnn", params: 822_570, trainable: false },
    ModelInfo { name: "cnn", params: 1_663_370, trainable: false },
    ModelInfo { name: "mobilenet", params: 3_315_428, trainable: false },
    ModelInfo { name: "resnet18", params: 12_556_426, trainable: false },
    ModelInfo { name: "resnet34", params: 21_797_672, trainable: false },
    ModelInfo { name: "resnet50", params: 25_557_032, trainable: false },
    ModelInfo { name: "groupvit", params: 55_726_609, trainable: false },
    ModelInfo { name: "vit", params: 86_389_248, trainable: false },
    ModelInfo { name: "bert", params: 109_482_240, trainable: false },
    ModelInfo { name: "llama2", params: 6_738_000_000, trainable: false },
];

/// Look up a Table-4 model.
pub fn lookup(name: &str) -> Option<ModelInfo> {
    TABLE4_MODELS.iter().copied().find(|m| m.name == name)
}

/// Plaintext wire size of a flat f32 model.
pub fn plaintext_bytes(params: u64) -> u64 {
    4 * params
}

/// Ciphertext wire size when fully encrypting `params` values with the
/// given context (ceil-div into packed ciphertexts).
pub fn ciphertext_bytes(params: u64, ctx: &crate::ckks::CkksParams) -> u64 {
    let batch = (ctx.n / 2) as u64;
    params.div_ceil(batch) * ctx.ciphertext_bytes() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_is_sorted_and_complete() {
        assert_eq!(TABLE4_MODELS.len(), 14);
        for w in TABLE4_MODELS.windows(2) {
            assert!(w[0].params < w[1].params, "registry must be size-sorted");
        }
        assert_eq!(lookup("resnet50").unwrap().params, 25_557_032);
        assert!(lookup("nope").is_none());
    }

    #[test]
    fn comm_expansion_matches_paper_ratio() {
        // Paper Table 4: ResNet-50 → 1.58 GB ciphertext vs 97.79 MB
        // plaintext (ratio 16.58). Our wire format gives the same ~16×.
        let ctx = crate::ckks::CkksParams::new(8192, 4, 52).unwrap();
        let m = lookup("resnet50").unwrap();
        let ct = ciphertext_bytes(m.params, &ctx) as f64;
        let pt = plaintext_bytes(m.params) as f64;
        let ratio = ct / pt;
        assert!((15.0..18.0).contains(&ratio), "ratio {ratio}");
        // absolute size ~1.5–1.7 GB
        assert!((1.4e9..1.8e9).contains(&ct), "ct bytes {ct}");
    }

    #[test]
    fn small_models_pay_full_ciphertext() {
        // Table 4 anomaly reproduced: a 101-parameter model still ships one
        // full ciphertext (240× comm ratio in the paper).
        let ctx = crate::ckks::CkksParams::new(8192, 4, 52).unwrap();
        let m = lookup("linear").unwrap();
        let ratio = ciphertext_bytes(m.params, &ctx) as f64 / plaintext_bytes(m.params) as f64;
        assert!(ratio > 100.0, "ratio {ratio}");
    }
}
