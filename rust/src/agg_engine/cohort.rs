//! Cohort scheduling over a lazily-materialized client population.
//!
//! Production FL serves populations far larger than any round's participant
//! set (the Fig. 14a client-scaling axis). Storing per-client state for
//! millions of registered clients is unnecessary: everything the coordinator
//! needs about client `id` — its simulated dataset size (the FedAvg weight
//! input) and its RNG/data seed — is derived deterministically from the id
//! on demand. The scheduler therefore keeps O(1) state in the population
//! size and O(K) state per sampled round.

use crate::crypto::prng::ChaChaRng;
use std::collections::HashSet;

/// SplitMix64 finalizer: cheap, well-distributed id → attribute hashing.
fn splitmix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// A registered population of `size` virtual clients. No per-client state
/// is ever allocated — attributes are pure functions of the id.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Population {
    pub size: u64,
    pub seed: u64,
}

impl Population {
    pub fn new(size: u64, seed: u64) -> Self {
        assert!(size >= 1, "population must be non-empty");
        Population { size, seed }
    }

    /// Deterministic simulated local-dataset size for client `id`
    /// (64..=1087 samples) — the FedAvg weighting input.
    pub fn data_size(&self, id: u64) -> u64 {
        64 + splitmix(self.seed ^ id.wrapping_mul(0xD1B5_4A32_D192_ED03)) % 1024
    }

    /// Per-client RNG/data seed (drives a pooled trainer impersonating the
    /// virtual client).
    pub fn client_seed(&self, id: u64) -> u64 {
        splitmix(self.seed.wrapping_add(id))
    }
}

/// One sampled participant.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CohortMember {
    pub id: u64,
    pub data_size: u64,
    /// FedAvg weight, normalized over the cohort (sums to 1).
    pub alpha: f64,
}

/// The K participants selected for one round, sorted by client id.
#[derive(Debug, Clone)]
pub struct Cohort {
    pub round: u64,
    pub members: Vec<CohortMember>,
}

impl Cohort {
    pub fn ids(&self) -> Vec<u64> {
        self.members.iter().map(|m| m.id).collect()
    }
}

/// Cap on a client's straggler penalty (selection probability floor
/// 2^-MAX_PENALTY_SHIFT).
const MAX_PENALTY: u32 = 8;
/// Penalties beyond this shift no further probability halving (floor 1/8).
const MAX_PENALTY_SHIFT: u32 = 3;

/// Samples K distinct participants per round from the population,
/// down-weighting clients the coordinator has observed timing out
/// (straggler-aware resampling): a client with penalty `p` is accepted
/// with probability `2^-min(p, 3)` per draw, so a persistent straggler's
/// selection rate decays toward 1/8 of its fair share and recovers as
/// completed rounds decay the penalty.
///
/// With no recorded penalties the sample stream is byte-identical to the
/// penalty-free scheduler (no extra RNG draws), so existing runs and tests
/// reproduce exactly.
#[derive(Debug, Clone)]
pub struct CohortScheduler {
    pub population: Population,
    pub k: usize,
    /// id → observed-timeout score (incremented per dropped round, decayed
    /// per completed round).
    penalties: std::collections::HashMap<u64, u32>,
}

impl CohortScheduler {
    pub fn new(population: Population, k: usize) -> Self {
        assert!(k >= 1, "cohort must be non-empty");
        assert!(k as u64 <= population.size, "cohort larger than population");
        CohortScheduler {
            population,
            k,
            penalties: std::collections::HashMap::new(),
        }
    }

    /// Record a round in which client `id` was dropped as a straggler.
    pub fn observe_straggler(&mut self, id: u64) {
        let p = self.penalties.entry(id).or_insert(0);
        *p = (*p + 1).min(MAX_PENALTY);
    }

    /// Record a completed (accepted) round for client `id`: one penalty
    /// step decays, so a recovered client earns its share back.
    pub fn observe_completed(&mut self, id: u64) {
        let cleared = match self.penalties.get_mut(&id) {
            Some(p) => {
                *p -= 1;
                *p == 0
            }
            None => false,
        };
        if cleared {
            self.penalties.remove(&id);
        }
    }

    /// Current straggler penalty of client `id`.
    pub fn penalty(&self, id: u64) -> u32 {
        self.penalties.get(&id).copied().unwrap_or(0)
    }

    /// Per-draw acceptance probability of client `id`.
    pub fn selection_prob(&self, id: u64) -> f64 {
        let shift = self.penalty(id).min(MAX_PENALTY_SHIFT);
        1.0 / f64::from(1u32 << shift)
    }

    /// Deterministic per-round sample of K distinct client ids (rejection
    /// sampling: O(K) memory regardless of population size). Penalized ids
    /// survive a draw only with [`Self::selection_prob`]; a bounded
    /// attempt budget guarantees termination even when every id is
    /// penalized (the penalty is a bias, not a ban).
    pub fn sample(&self, round: u64) -> Cohort {
        let mut rng = ChaChaRng::from_seed(self.population.seed, 0xC0_0480 ^ round);
        let mut seen: HashSet<u64> = HashSet::with_capacity(self.k);
        let mut members: Vec<CohortMember> = Vec::with_capacity(self.k);
        let mut attempts_left: u64 = 64 * self.k as u64 + 1024;
        while members.len() < self.k {
            let id = rng.uniform_u64(self.population.size);
            if attempts_left > 0 {
                attempts_left -= 1;
                let prob = self.selection_prob(id);
                // no extra rng draw for unpenalized ids: the base stream
                // stays byte-identical to the penalty-free scheduler
                if prob < 1.0 && rng.uniform_f64() >= prob {
                    continue;
                }
            }
            if seen.insert(id) {
                members.push(CohortMember {
                    id,
                    data_size: self.population.data_size(id),
                    alpha: 0.0,
                });
            }
        }
        members.sort_by_key(|m| m.id);
        let total: f64 = members.iter().map(|m| m.data_size as f64).sum();
        for m in members.iter_mut() {
            m.alpha = m.data_size as f64 / total;
        }
        Cohort { round, members }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn million_population_samples_flat() {
        // The Fig. 14a population-scale point: 1M registered, K=16 per
        // round. Lazy materialization means this must be instant and O(K).
        let sched = CohortScheduler::new(Population::new(1_000_000, 42), 16);
        for round in 0..50 {
            let c = sched.sample(round);
            assert_eq!(c.members.len(), 16);
            let ids = c.ids();
            let distinct: HashSet<u64> = ids.iter().copied().collect();
            assert_eq!(distinct.len(), 16, "round {round}: duplicate ids");
            assert!(ids.iter().all(|&i| i < 1_000_000));
            let mass: f64 = c.members.iter().map(|m| m.alpha).sum();
            assert!((mass - 1.0).abs() < 1e-9, "round {round}: mass {mass}");
        }
    }

    #[test]
    fn population_scales_to_hundreds_of_millions() {
        // Nothing in the scheduler is O(N): a 400M-client registry samples
        // just as fast.
        let sched = CohortScheduler::new(Population::new(400_000_000, 7), 16);
        let c = sched.sample(0);
        assert_eq!(c.members.len(), 16);
        assert!(c.ids().iter().all(|&i| i < 400_000_000));
    }

    #[test]
    fn sampling_is_deterministic_per_round_and_varies_across_rounds() {
        let sched = CohortScheduler::new(Population::new(1_000_000, 9), 16);
        let a = sched.sample(3);
        let b = sched.sample(3);
        assert_eq!(a.ids(), b.ids());
        assert_eq!(
            a.members.iter().map(|m| m.alpha).collect::<Vec<_>>(),
            b.members.iter().map(|m| m.alpha).collect::<Vec<_>>()
        );
        let c = sched.sample(4);
        assert_ne!(a.ids(), c.ids());
    }

    #[test]
    fn attributes_are_pure_functions_of_id() {
        let p = Population::new(1_000_000, 1);
        assert_eq!(p.data_size(12345), p.data_size(12345));
        assert_eq!(p.client_seed(12345), p.client_seed(12345));
        assert!((64..1088).contains(&p.data_size(99))); // bounded sizes
        // different seeds re-randomize the registry
        let q = Population::new(1_000_000, 2);
        assert_ne!(
            (0..64).map(|i| p.data_size(i)).collect::<Vec<_>>(),
            (0..64).map(|i| q.data_size(i)).collect::<Vec<_>>()
        );
    }

    #[test]
    fn persistent_straggler_selection_probability_decays() {
        // One client keeps timing out: feed its drops back into the
        // scheduler and count how often it is sampled, against a
        // penalty-free control. The penalized rate must fall well below
        // the control's (floor 1/8 of fair share).
        let population = Population::new(200, 11);
        let mut penalized = CohortScheduler::new(population, 16);
        let control = CohortScheduler::new(population, 16);
        let victim = control.sample(0).ids()[0];
        let rounds = 300u64;
        let (mut hits_penalized, mut hits_control) = (0u32, 0u32);
        for r in 0..rounds {
            let c = penalized.sample(r);
            if c.ids().contains(&victim) {
                hits_penalized += 1;
                penalized.observe_straggler(victim);
                // everyone else completed fine
                for id in c.ids() {
                    if id != victim {
                        penalized.observe_completed(id);
                    }
                }
            }
            if control.sample(r).ids().contains(&victim) {
                hits_control += 1;
            }
        }
        assert!(hits_control >= 10, "control sampled victim {hits_control}x");
        assert!(
            (hits_penalized as f64) < hits_control as f64 * 0.6,
            "penalty did not bite: {hits_penalized} vs {hits_control}"
        );
        // the probability itself decays monotonically to the floor
        let mut s = CohortScheduler::new(population, 4);
        assert_eq!(s.selection_prob(7), 1.0);
        s.observe_straggler(7);
        assert_eq!(s.selection_prob(7), 0.5);
        s.observe_straggler(7);
        assert_eq!(s.selection_prob(7), 0.25);
        s.observe_straggler(7);
        s.observe_straggler(7);
        assert_eq!(s.selection_prob(7), 0.125, "probability floor");
        // recovery: completions decay the penalty back to fair share
        for _ in 0..MAX_PENALTY {
            s.observe_completed(7);
        }
        assert_eq!(s.penalty(7), 0);
        assert_eq!(s.selection_prob(7), 1.0);
    }

    #[test]
    fn penalty_free_sampling_matches_pristine_scheduler() {
        // Recording and then fully decaying penalties must restore the
        // exact original sample stream (no lingering rng perturbation).
        let population = Population::new(5_000, 3);
        let pristine = CohortScheduler::new(population, 8);
        let mut touched = CohortScheduler::new(population, 8);
        let id = pristine.sample(0).ids()[0];
        touched.observe_straggler(id);
        touched.observe_straggler(id);
        assert_ne!(touched.penalty(id), 0);
        touched.observe_completed(id);
        touched.observe_completed(id);
        for round in 0..20 {
            assert_eq!(pristine.sample(round).ids(), touched.sample(round).ids());
        }
    }

    #[test]
    fn fully_penalized_population_still_terminates() {
        let population = Population::new(4, 0);
        let mut s = CohortScheduler::new(population, 4);
        for id in 0..4 {
            for _ in 0..MAX_PENALTY {
                s.observe_straggler(id);
            }
        }
        // k == population size with everyone at the floor: the attempt
        // budget guarantees the full cohort is still produced
        let c = s.sample(9);
        assert_eq!(c.ids(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn full_population_cohort_allowed() {
        let sched = CohortScheduler::new(Population::new(5, 0), 5);
        let c = sched.sample(0);
        assert_eq!(c.ids(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    #[should_panic(expected = "larger than population")]
    fn oversized_cohort_rejected() {
        CohortScheduler::new(Population::new(4, 0), 5);
    }
}
