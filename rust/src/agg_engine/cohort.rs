//! Cohort scheduling over a lazily-materialized client population.
//!
//! Production FL serves populations far larger than any round's participant
//! set (the Fig. 14a client-scaling axis). Storing per-client state for
//! millions of registered clients is unnecessary: everything the coordinator
//! needs about client `id` — its simulated dataset size (the FedAvg weight
//! input) and its RNG/data seed — is derived deterministically from the id
//! on demand. The scheduler therefore keeps O(1) state in the population
//! size and O(K) state per sampled round.

use crate::crypto::prng::ChaChaRng;
use std::collections::HashSet;

/// SplitMix64 finalizer: cheap, well-distributed id → attribute hashing.
fn splitmix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// A registered population of `size` virtual clients. No per-client state
/// is ever allocated — attributes are pure functions of the id.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Population {
    pub size: u64,
    pub seed: u64,
}

impl Population {
    pub fn new(size: u64, seed: u64) -> Self {
        assert!(size >= 1, "population must be non-empty");
        Population { size, seed }
    }

    /// Deterministic simulated local-dataset size for client `id`
    /// (64..=1087 samples) — the FedAvg weighting input.
    pub fn data_size(&self, id: u64) -> u64 {
        64 + splitmix(self.seed ^ id.wrapping_mul(0xD1B5_4A32_D192_ED03)) % 1024
    }

    /// Per-client RNG/data seed (drives a pooled trainer impersonating the
    /// virtual client).
    pub fn client_seed(&self, id: u64) -> u64 {
        splitmix(self.seed.wrapping_add(id))
    }
}

/// One sampled participant.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CohortMember {
    pub id: u64,
    pub data_size: u64,
    /// FedAvg weight, normalized over the cohort (sums to 1).
    pub alpha: f64,
}

/// The K participants selected for one round, sorted by client id.
#[derive(Debug, Clone)]
pub struct Cohort {
    pub round: u64,
    pub members: Vec<CohortMember>,
}

impl Cohort {
    pub fn ids(&self) -> Vec<u64> {
        self.members.iter().map(|m| m.id).collect()
    }
}

/// Samples K distinct participants per round from the population.
#[derive(Debug, Clone, Copy)]
pub struct CohortScheduler {
    pub population: Population,
    pub k: usize,
}

impl CohortScheduler {
    pub fn new(population: Population, k: usize) -> Self {
        assert!(k >= 1, "cohort must be non-empty");
        assert!(k as u64 <= population.size, "cohort larger than population");
        CohortScheduler { population, k }
    }

    /// Deterministic per-round sample of K distinct client ids (rejection
    /// sampling: O(K) memory regardless of population size).
    pub fn sample(&self, round: u64) -> Cohort {
        let mut rng = ChaChaRng::from_seed(self.population.seed, 0xC0_0480 ^ round);
        let mut seen: HashSet<u64> = HashSet::with_capacity(self.k);
        let mut members: Vec<CohortMember> = Vec::with_capacity(self.k);
        while members.len() < self.k {
            let id = rng.uniform_u64(self.population.size);
            if seen.insert(id) {
                members.push(CohortMember {
                    id,
                    data_size: self.population.data_size(id),
                    alpha: 0.0,
                });
            }
        }
        members.sort_by_key(|m| m.id);
        let total: f64 = members.iter().map(|m| m.data_size as f64).sum();
        for m in members.iter_mut() {
            m.alpha = m.data_size as f64 / total;
        }
        Cohort { round, members }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn million_population_samples_flat() {
        // The Fig. 14a population-scale point: 1M registered, K=16 per
        // round. Lazy materialization means this must be instant and O(K).
        let sched = CohortScheduler::new(Population::new(1_000_000, 42), 16);
        for round in 0..50 {
            let c = sched.sample(round);
            assert_eq!(c.members.len(), 16);
            let ids = c.ids();
            let distinct: HashSet<u64> = ids.iter().copied().collect();
            assert_eq!(distinct.len(), 16, "round {round}: duplicate ids");
            assert!(ids.iter().all(|&i| i < 1_000_000));
            let mass: f64 = c.members.iter().map(|m| m.alpha).sum();
            assert!((mass - 1.0).abs() < 1e-9, "round {round}: mass {mass}");
        }
    }

    #[test]
    fn population_scales_to_hundreds_of_millions() {
        // Nothing in the scheduler is O(N): a 400M-client registry samples
        // just as fast.
        let sched = CohortScheduler::new(Population::new(400_000_000, 7), 16);
        let c = sched.sample(0);
        assert_eq!(c.members.len(), 16);
        assert!(c.ids().iter().all(|&i| i < 400_000_000));
    }

    #[test]
    fn sampling_is_deterministic_per_round_and_varies_across_rounds() {
        let sched = CohortScheduler::new(Population::new(1_000_000, 9), 16);
        let a = sched.sample(3);
        let b = sched.sample(3);
        assert_eq!(a.ids(), b.ids());
        assert_eq!(
            a.members.iter().map(|m| m.alpha).collect::<Vec<_>>(),
            b.members.iter().map(|m| m.alpha).collect::<Vec<_>>()
        );
        let c = sched.sample(4);
        assert_ne!(a.ids(), c.ids());
    }

    #[test]
    fn attributes_are_pure_functions_of_id() {
        let p = Population::new(1_000_000, 1);
        assert_eq!(p.data_size(12345), p.data_size(12345));
        assert_eq!(p.client_seed(12345), p.client_seed(12345));
        assert!((64..1088).contains(&p.data_size(99))); // bounded sizes
        // different seeds re-randomize the registry
        let q = Population::new(1_000_000, 2);
        assert_ne!(
            (0..64).map(|i| p.data_size(i)).collect::<Vec<_>>(),
            (0..64).map(|i| q.data_size(i)).collect::<Vec<_>>()
        );
    }

    #[test]
    fn full_population_cohort_allowed() {
        let sched = CohortScheduler::new(Population::new(5, 0), 5);
        let c = sched.sample(0);
        assert_eq!(c.ids(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    #[should_panic(expected = "larger than population")]
    fn oversized_cohort_rejected() {
        CohortScheduler::new(Population::new(4, 0), 5);
    }
}
