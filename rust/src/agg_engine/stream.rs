//! Streaming intake + sharded aggregation: the pipeline engine.
//!
//! Stages (DESIGN.md §3):
//!
//! 1. **Intake** — arrivals (one per participant, stamped with the
//!    [`crate::netsim`] simulated transfer-completion time, or with the
//!    wall-clock receive time when they come off a real socket via
//!    [`crate::transport`]) are admitted in arrival order through bounded
//!    fan-out channels, so shard workers aggregate update `i` while update
//!    `i+1` is still "on the wire". Batch callers hand one vector to
//!    [`StreamingAggregator::aggregate`]; a transport offers arrivals one at
//!    a time through [`RoundIntake`].
//! 2. **Quorum seal** — the round seals once every non-straggler has
//!    arrived: the first `quorum` arrivals are always accepted, later ones
//!    only within `straggler_timeout_secs` of the quorum point. Dropped
//!    weight mass is reported in [`StreamStats::alpha_mass`] so the caller
//!    renormalizes the decrypted model exactly (HE dropout robustness).
//! 3. **Assembly** — each worker returns its reduced `(ct, limb)` units and
//!    plaintext slice; the main thread scatters them into one
//!    [`EncryptedUpdate`].
//!
//! Exactness: ciphertext limbs are modular sums (commutative, reduced once
//! at seal) — bitwise identical to the sequential kernel for any shard
//! count/arrival order. The plaintext remainder is accumulated in client-id
//! order at seal, f64-for-f64 the same loop as the sequential path — also
//! bitwise identical.

use super::shard::{ShardAccumulator, ShardCtSums, ShardPlan};
use super::EngineConfig;
use crate::ckks::{Ciphertext, CkksParams, RnsPoly};
use crate::he_agg::{EncryptedUpdate, EncryptionMask};
use std::sync::mpsc;
use std::sync::Arc;

/// Depth of each shard's intake channel: enough to keep workers busy while
/// bounding memory to a few in-flight updates per shard.
const INTAKE_DEPTH: usize = 4;

/// One client's update entering the round.
#[derive(Clone)]
pub struct Arrival {
    /// Client id (virtual cohort id or trainer-slot id).
    pub client: u64,
    /// FedAvg weight, normalized over the *selected* cohort.
    pub alpha: f64,
    /// Simulated transfer-completion time (seconds into the round).
    pub arrival_secs: f64,
    pub update: Arc<EncryptedUpdate>,
}

/// What the streaming round did.
#[derive(Debug, Clone, Default)]
pub struct StreamStats {
    pub offered: usize,
    pub accepted: usize,
    pub dropped_stragglers: usize,
    /// Client ids of the accepted participants (the round's comm accounting
    /// charges link time only for these — dropped stragglers count bytes but
    /// never gate the round).
    pub accepted_clients: Vec<u64>,
    /// Σ α over accepted participants. The decrypted model must be divided
    /// by this to renormalize after straggler drops (1.0 when none drop).
    pub alpha_mass: f64,
    /// Simulated time at which the round sealed (last accepted arrival).
    pub sealed_at_secs: f64,
}

/// Per-client work item fanned out to every shard worker.
struct WorkItem {
    client: u64,
    alpha: f64,
    /// Encoded per-limb weight residues for `alpha`.
    weight: Arc<Vec<u64>>,
    update: Arc<EncryptedUpdate>,
}

/// One worker's sealed output.
struct ShardOutput {
    sums: ShardCtSums,
    plain_lo: usize,
    plain: Vec<f32>,
}

/// The sharded streaming aggregation engine.
pub struct StreamingAggregator<'a> {
    pub params: &'a CkksParams,
    pub cfg: EngineConfig,
}

impl<'a> StreamingAggregator<'a> {
    pub fn new(params: &'a CkksParams, cfg: EngineConfig) -> Self {
        StreamingAggregator { params, cfg }
    }

    /// Run one round: admit `arrivals` in simulated-arrival order, apply the
    /// quorum/straggler policy, aggregate across the shard pool, and return
    /// the aggregate plus round statistics. Plaintext-remainder shard
    /// boundaries are an even split; use
    /// [`StreamingAggregator::aggregate_with_mask`] when the round's
    /// encryption mask is known to get run-aligned boundaries.
    pub fn aggregate(
        &self,
        arrivals: Vec<Arrival>,
    ) -> anyhow::Result<(EncryptedUpdate, StreamStats)> {
        self.aggregate_with_mask(arrivals, None)
    }

    /// [`StreamingAggregator::aggregate`] with the round's shared encryption
    /// mask: the plaintext-remainder shard plan is expressed in run space
    /// (cuts snap to nearby mask-complement run boundaries, splitting only
    /// runs longer than a balanced share), so shards own whole runs wherever
    /// alignment is cheap. Bitwise identical to the even-split plan — the
    /// f64 fold is positional either way.
    pub fn aggregate_with_mask(
        &self,
        arrivals: Vec<Arrival>,
        mask: Option<&EncryptionMask>,
    ) -> anyhow::Result<(EncryptedUpdate, StreamStats)> {
        anyhow::ensure!(!arrivals.is_empty(), "streaming round with no arrivals");
        let mut intake = self.begin_round(mask);
        for a in arrivals {
            intake.offer(a)?;
        }
        intake.seal()
    }

    /// Open an incremental round: a real transport offers arrivals one at a
    /// time as their transfers complete (wall-clock stamps), instead of
    /// handing over one pre-built vector. [`RoundIntake::seal`] applies the
    /// same quorum/straggler policy and produces the same aggregate as the
    /// batch entry points.
    pub fn begin_round<'m>(&self, mask: Option<&'m EncryptionMask>) -> RoundIntake<'a, 'm> {
        RoundIntake {
            params: self.params,
            cfg: self.cfg,
            mask,
            arrivals: Vec::new(),
            shape: None,
            quorum_reached_at: None,
        }
    }
}

/// One round's incremental intake (see [`StreamingAggregator::begin_round`]).
///
/// `offer` validates and buffers each arrival; `seal` sorts by arrival stamp,
/// applies the quorum/straggler policy by truncating the straggler tail **in
/// place** (the intake owns its arrivals — admission never deep-copies an
/// update, enforced by an allocation-count gate in `tests/zero_alloc.rs`),
/// and runs the sharded aggregation over the accepted prefix.
pub struct RoundIntake<'p, 'm> {
    params: &'p CkksParams,
    cfg: EngineConfig,
    mask: Option<&'m EncryptionMask>,
    arrivals: Vec<Arrival>,
    /// `(n_cts, n_plain, total, c1_ntt)` of the first offered update. The
    /// final flag pins the c1 domain (NTT for seed-expanded symmetric
    /// uplinks, coefficient for dense) — mixing the two within a round
    /// would silently add incompatible representations.
    shape: Option<(usize, usize, usize, bool)>,
    /// Arrival stamp at which the `quorum`-th offer landed (offer order).
    quorum_reached_at: Option<f64>,
}

impl<'p, 'm> RoundIntake<'p, 'm> {
    /// Admit one arrival. Shape validation covers every offered update —
    /// including ones the seal-time policy later drops — exactly like the
    /// batch path.
    pub fn offer(&mut self, a: Arrival) -> anyhow::Result<()> {
        let c1_ntt = a.update.cts.first().is_some_and(|c| c.c1.ntt_form);
        anyhow::ensure!(
            a.update.cts.iter().all(|c| c.c1.ntt_form == c1_ntt),
            "mixed c1 domains within one update"
        );
        let shape = (
            a.update.cts.len(),
            a.update.plain.len(),
            a.update.total,
            c1_ntt,
        );
        match self.shape {
            None => self.shape = Some(shape),
            Some(s) => anyhow::ensure!(
                s == shape,
                "heterogeneous update shapes in streaming round"
            ),
        }
        self.arrivals.push(a);
        crate::obs::metrics::intake_enqueued();
        if self.quorum_reached_at.is_none() {
            if let Some(q) = self.cfg.quorum {
                if self.arrivals.len() >= q.max(1) {
                    self.quorum_reached_at = Some(self.arrivals.last().unwrap().arrival_secs);
                }
            }
        }
        Ok(())
    }

    /// Offer a batch of arrivals in order — the hand-off point for a
    /// transport outcome (one-shot intake or persistent-session collector).
    pub fn offer_many(
        &mut self,
        arrivals: impl IntoIterator<Item = Arrival>,
    ) -> anyhow::Result<()> {
        for a in arrivals {
            self.offer(a)?;
        }
        Ok(())
    }

    /// Arrivals offered so far.
    pub fn offered(&self) -> usize {
        self.arrivals.len()
    }

    /// Advisory straggler cutoff for the transport: once the quorum-th offer
    /// has landed, waiting past `quorum stamp + straggler_timeout` cannot add
    /// an accepted arrival, so the intake loop may stop accepting. `None`
    /// until quorum is reached (or when no quorum is configured). The
    /// authoritative accept/drop decision is re-derived at [`Self::seal`]
    /// over the sorted arrivals, so a slightly-late stop never skews stats.
    pub fn cutoff_secs(&self) -> Option<f64> {
        self.quorum_reached_at
            .map(|t| t + self.cfg.straggler_timeout_secs)
    }

    /// Seal the round: quorum/straggler filter, sharded aggregation,
    /// assembly. Consumes the intake.
    pub fn seal(mut self) -> anyhow::Result<(EncryptedUpdate, StreamStats)> {
        let _span = crate::obs::span_arg("engine", "seal", self.arrivals.len() as u64);
        crate::obs::metrics::intake_drained(self.arrivals.len() as u64);
        anyhow::ensure!(!self.arrivals.is_empty(), "streaming round with no arrivals");
        self.arrivals.sort_by(|a, b| {
            a.arrival_secs
                .total_cmp(&b.arrival_secs)
                .then(a.client.cmp(&b.client))
        });
        let (n_cts, n_plain, total, c1_ntt) = self.shape.expect("non-empty round has a shape");

        // Quorum/straggler policy over the arrival-ordered list: the first
        // `quorum` arrivals are always accepted, later ones only within the
        // timeout of the quorum point. Sorted by stamp, the accepted set is
        // a prefix — partition in place by truncating the straggler tail
        // (no per-arrival clones).
        let offered = self.arrivals.len();
        let quorum = self.cfg.quorum.unwrap_or(offered).clamp(1, offered);
        let cutoff = self.arrivals[quorum - 1].arrival_secs + self.cfg.straggler_timeout_secs;
        let keep = self
            .arrivals
            .partition_point(|a| a.arrival_secs <= cutoff)
            .max(quorum);
        self.arrivals.truncate(keep);
        crate::obs::metrics::straggler_drops((offered - keep) as u64);
        let accepted = &self.arrivals;
        let stats = StreamStats {
            offered,
            accepted: accepted.len(),
            dropped_stragglers: offered - accepted.len(),
            accepted_clients: accepted.iter().map(|a| a.client).collect(),
            alpha_mass: accepted.iter().map(|a| a.alpha).sum(),
            sealed_at_secs: accepted
                .iter()
                .map(|a| a.arrival_secs)
                .fold(0.0f64, f64::max),
        };

        let mask = self.mask;
        let plan = match mask {
            Some(m) => {
                anyhow::ensure!(m.total() == total, "mask/update total mismatch");
                let plain_layout = m.plaintext_layout();
                anyhow::ensure!(
                    plain_layout.count() == n_plain,
                    "mask complement ({}) does not match plaintext remainder ({n_plain})",
                    plain_layout.count()
                );
                ShardPlan::new_run_aligned(
                    self.cfg.shards.max(1),
                    n_cts,
                    self.params.num_limbs(),
                    plain_layout.runs(),
                )
            }
            None => ShardPlan::new(
                self.cfg.shards.max(1),
                n_cts,
                self.params.num_limbs(),
                n_plain,
            ),
        };
        let params = self.params;
        let outputs: Vec<ShardOutput> = std::thread::scope(|scope| {
            let mut senders = Vec::with_capacity(plan.n_shards);
            let mut handles = Vec::with_capacity(plan.n_shards);
            for shard in 0..plan.n_shards {
                let (tx, rx) = mpsc::sync_channel::<WorkItem>(INTAKE_DEPTH);
                senders.push(tx);
                let worker_plan = plan.clone();
                handles.push(scope.spawn(move || shard_worker(params, worker_plan, shard, rx)));
            }
            // Intake: feed accepted arrivals in arrival order. The bounded
            // channels backpressure the intake, so aggregation of early
            // arrivals overlaps "transfer" of later ones.
            for a in accepted {
                let weight = Arc::new(params.encode_weight(a.alpha));
                for tx in &senders {
                    let item = WorkItem {
                        client: a.client,
                        alpha: a.alpha,
                        weight: weight.clone(),
                        update: a.update.clone(),
                    };
                    tx.send(item).expect("shard worker hung up mid-round");
                }
            }
            // Seal: closing the channels ends every worker's intake loop.
            drop(senders);
            handles
                .into_iter()
                .map(|h| h.join().expect("shard worker panicked"))
                .collect()
        });

        // Assembly: scatter shard outputs into one update.
        let out_scale = accepted[0]
            .update
            .cts
            .first()
            .map(|c| c.scale)
            .unwrap_or(self.params.delta())
            * self.params.delta_w();
        let mut cts: Vec<Ciphertext> = (0..n_cts)
            .map(|c| Ciphertext {
                c0: RnsPoly::zero(self.params),
                c1: RnsPoly::zero(self.params),
                n_values: accepted
                    .iter()
                    .map(|a| a.update.cts[c].n_values)
                    .max()
                    .unwrap(),
                scale: out_scale,
            })
            .collect();
        let mut plain = vec![0.0f32; n_plain];
        for out in outputs {
            for (k, &(ct, limb)) in out.sums.units.iter().enumerate() {
                cts[ct].c0.limb_mut(limb).copy_from_slice(&out.sums.c0[k]);
                cts[ct].c1.limb_mut(limb).copy_from_slice(&out.sums.c1[k]);
            }
            plain[out.plain_lo..out.plain_lo + out.plain.len()].copy_from_slice(&out.plain);
        }
        // Seed-expanded uplinks fold NTT-domain a-parts, so the weighted
        // sums land in NTT domain; normalize the sealed aggregate back to
        // coefficient domain once (INTT is linear mod q, so this commutes
        // exactly with the per-client path — sim stays bitwise equal).
        if c1_ntt {
            for ct in cts.iter_mut() {
                ct.c1.ntt_form = true;
                ct.c1.from_ntt(params);
            }
        }
        Ok((EncryptedUpdate { cts, plain, total }, stats))
    }
}

/// Worker loop: absorb ciphertext limbs as updates arrive; at seal, fold the
/// plaintext slice in client-id order (bitwise-identical to the sequential
/// f64 accumulation) and return the reduced sums.
fn shard_worker(
    params: &CkksParams,
    plan: ShardPlan,
    shard: usize,
    rx: mpsc::Receiver<WorkItem>,
) -> ShardOutput {
    let _span = crate::obs::span_arg("engine", "shard_worker", shard as u64);
    let mut acc = ShardAccumulator::new(&plan, shard, params);
    let mut buffered: Vec<WorkItem> = Vec::new();
    while let Ok(item) = rx.recv() {
        let _s = crate::obs::span_arg("engine", "shard_absorb", item.client);
        acc.absorb(&item.update, &item.weight);
        buffered.push(item);
    }
    let _fold = crate::obs::span_arg("engine", "shard_fold_plain", shard as u64);
    buffered.sort_by_key(|i| i.client);
    let range = plan.plain_range(shard);
    let mut sums = vec![0.0f64; range.len()];
    for item in &buffered {
        let src = &item.update.plain[range.clone()];
        for (d, &v) in sums.iter_mut().zip(src.iter()) {
            *d += item.alpha * v as f64;
        }
    }
    ShardOutput {
        sums: acc.finalize(),
        plain_lo: range.start,
        plain: sums.into_iter().map(|v| v as f32).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agg_engine::Engine;
    use crate::ckks::CkksContext;
    use crate::crypto::prng::ChaChaRng;
    use crate::he_agg::mask::EncryptionMask;
    use crate::he_agg::native;
    use crate::he_agg::selective::SelectiveCodec;

    fn fixture(
        n_clients: usize,
        total: usize,
        ratio: f64,
    ) -> (SelectiveCodec, Vec<EncryptedUpdate>, Vec<f64>, EncryptionMask) {
        let ctx = CkksContext::new(256, 4, 40).unwrap();
        let codec = SelectiveCodec::new(ctx);
        let mut rng = ChaChaRng::from_seed(31, 0);
        let (pk, _sk) = codec.ctx.keygen(&mut rng);
        let sens: Vec<f32> = (0..total).map(|i| ((i * 31) % 101) as f32).collect();
        let mask = EncryptionMask::top_p(&sens, ratio);
        let sizes: Vec<f64> = (0..n_clients).map(|c| (c + 1) as f64).collect();
        let mass: f64 = sizes.iter().sum();
        let alphas: Vec<f64> = sizes.iter().map(|s| s / mass).collect();
        let updates: Vec<EncryptedUpdate> = (0..n_clients)
            .map(|c| {
                let m: Vec<f32> = (0..total)
                    .map(|i| ((i + c * 131) as f32 * 0.003).sin())
                    .collect();
                codec.encrypt_update(&m, &mask, &pk, &mut rng)
            })
            .collect();
        (codec, updates, alphas, mask)
    }

    fn arrivals_of(updates: &[EncryptedUpdate], alphas: &[f64], times: &[f64]) -> Vec<Arrival> {
        updates
            .iter()
            .zip(alphas.iter())
            .zip(times.iter())
            .enumerate()
            .map(|(i, ((u, &alpha), &t))| Arrival {
                client: i as u64,
                alpha,
                arrival_secs: t,
                update: Arc::new(u.clone()),
            })
            .collect()
    }

    #[test]
    fn pipeline_matches_sequential_bitwise_across_shard_counts() {
        let (codec, updates, alphas, _mask) = fixture(5, 900, 0.5);
        let oracle = native::aggregate(&updates, &alphas, &codec.ctx.params);
        // reversed arrival order: last client's bytes land first
        let times: Vec<f64> = (0..5).map(|i| (5 - i) as f64).collect();
        for shards in [1usize, 2, 4, 8] {
            let cfg = EngineConfig {
                engine: Engine::Pipeline,
                shards,
                quorum: None,
                straggler_timeout_secs: 5.0,
            };
            let engine = StreamingAggregator::new(&codec.ctx.params, cfg);
            let (got, stats) = engine
                .aggregate(arrivals_of(&updates, &alphas, &times))
                .unwrap();
            assert_eq!(stats.accepted, 5);
            assert_eq!(stats.dropped_stragglers, 0);
            assert!((stats.alpha_mass - 1.0).abs() < 1e-12);
            assert_eq!(got.cts.len(), oracle.cts.len(), "shards={shards}");
            for (a, b) in got.cts.iter().zip(oracle.cts.iter()) {
                assert_eq!(a.c0, b.c0, "shards={shards}: c0 limbs differ");
                assert_eq!(a.c1, b.c1, "shards={shards}: c1 limbs differ");
                assert_eq!(a.n_values, b.n_values);
                assert!((a.scale - b.scale).abs() < 1e-9);
            }
            // plaintext remainder is bitwise identical
            assert_eq!(got.plain, oracle.plain, "shards={shards}");
        }
    }

    #[test]
    fn run_aligned_plan_is_bitwise_identical_to_even_split() {
        // aggregate_with_mask snaps plaintext cuts to mask-complement run
        // boundaries; the result must stay bitwise equal to both the
        // even-split pipeline and the sequential oracle
        let (codec, updates, alphas, mask) = fixture(5, 1100, 0.35);
        let oracle = native::aggregate(&updates, &alphas, &codec.ctx.params);
        let times: Vec<f64> = (0..5).map(|i| (i * 7 % 5) as f64).collect();
        for shards in [1usize, 3, 4, 8] {
            let cfg = EngineConfig {
                engine: Engine::Pipeline,
                shards,
                quorum: None,
                straggler_timeout_secs: 5.0,
            };
            let engine = StreamingAggregator::new(&codec.ctx.params, cfg);
            let (got, stats) = engine
                .aggregate_with_mask(arrivals_of(&updates, &alphas, &times), Some(&mask))
                .unwrap();
            assert_eq!(stats.accepted, 5);
            for (a, b) in got.cts.iter().zip(oracle.cts.iter()) {
                assert_eq!(a.c0, b.c0, "shards={shards}");
                assert_eq!(a.c1, b.c1, "shards={shards}");
            }
            assert_eq!(got.plain, oracle.plain, "shards={shards}");
        }
        // a mask whose total disagrees with the updates is rejected
        let cfg = EngineConfig {
            engine: Engine::Pipeline,
            shards: 2,
            quorum: None,
            straggler_timeout_secs: 5.0,
        };
        let engine = StreamingAggregator::new(&codec.ctx.params, cfg);
        let bad = EncryptionMask::full(7);
        assert!(engine
            .aggregate_with_mask(arrivals_of(&updates, &alphas, &times), Some(&bad))
            .is_err());
    }

    #[test]
    fn quorum_drops_stragglers_and_reports_mass() {
        let (codec, updates, alphas, _mask) = fixture(6, 600, 0.4);
        // clients 4 and 5 are stragglers: they arrive long after quorum
        let times = [0.1, 0.2, 0.3, 0.4, 100.0, 200.0];
        let cfg = EngineConfig {
            engine: Engine::Pipeline,
            shards: 4,
            quorum: Some(4),
            straggler_timeout_secs: 1.0,
        };
        let engine = StreamingAggregator::new(&codec.ctx.params, cfg);
        let (got, stats) = engine
            .aggregate(arrivals_of(&updates, &alphas, &times))
            .unwrap();
        assert_eq!(stats.offered, 6);
        assert_eq!(stats.accepted, 4);
        assert_eq!(stats.dropped_stragglers, 2);
        let expect_mass: f64 = alphas[..4].iter().sum();
        assert!((stats.alpha_mass - expect_mass).abs() < 1e-12);
        assert!((stats.sealed_at_secs - 0.4).abs() < 1e-12);
        // the aggregate equals the sequential aggregate over the accepted set
        let oracle = native::aggregate(&updates[..4], &alphas[..4], &codec.ctx.params);
        for (a, b) in got.cts.iter().zip(oracle.cts.iter()) {
            assert_eq!(a.c0, b.c0);
            assert_eq!(a.c1, b.c1);
        }
        assert_eq!(got.plain, oracle.plain);
    }

    #[test]
    fn late_arrival_within_timeout_is_accepted() {
        let (codec, updates, alphas, _mask) = fixture(5, 400, 0.3);
        let times = [0.1, 0.2, 0.3, 0.4, 0.9]; // within quorum+timeout
        let cfg = EngineConfig {
            engine: Engine::Pipeline,
            shards: 2,
            quorum: Some(4),
            straggler_timeout_secs: 1.0,
        };
        let engine = StreamingAggregator::new(&codec.ctx.params, cfg);
        let (_, stats) = engine
            .aggregate(arrivals_of(&updates, &alphas, &times))
            .unwrap();
        assert_eq!(stats.accepted, 5);
        assert_eq!(stats.dropped_stragglers, 0);
    }

    #[test]
    fn renormalized_decrypt_matches_fedavg_over_accepted() {
        // End-to-end: drop stragglers, decrypt, renormalize by alpha_mass —
        // the result is the exact FedAvg over the accepted participants.
        let ctx = CkksContext::new(256, 4, 40).unwrap();
        let codec = SelectiveCodec::new(ctx);
        let mut rng = ChaChaRng::from_seed(33, 0);
        let (pk, sk) = codec.ctx.keygen(&mut rng);
        let total = 500;
        let mask = EncryptionMask::full(total);
        let alphas = [0.25, 0.25, 0.25, 0.25];
        let models: Vec<Vec<f32>> = (0..4usize)
            .map(|c| (0..total).map(|i| ((i * (c + 1)) as f32 * 0.002).cos()).collect())
            .collect();
        let updates: Vec<EncryptedUpdate> = models
            .iter()
            .map(|m| codec.encrypt_update(m, &mask, &pk, &mut rng))
            .collect();
        let cfg = EngineConfig {
            engine: Engine::Pipeline,
            shards: 4,
            quorum: Some(3),
            straggler_timeout_secs: 0.5,
        };
        let engine = StreamingAggregator::new(&codec.ctx.params, cfg);
        let times = [0.1, 0.2, 0.3, 99.0]; // client 3 is dropped
        let (agg, stats) = engine
            .aggregate(arrivals_of(&updates, &alphas, &times))
            .unwrap();
        assert_eq!(stats.accepted, 3);
        let mut got = codec.decrypt_update(&agg, &mask, &sk);
        for v in got.iter_mut() {
            *v = (*v as f64 / stats.alpha_mass) as f32;
        }
        let renorm: Vec<f64> = alphas[..3].iter().map(|a| a / stats.alpha_mass).collect();
        let expected = native::plain_fedavg(&models[..3], &renorm);
        for j in 0..total {
            assert!(
                (got[j] - expected[j]).abs() < 1e-4,
                "j={j}: {} vs {}",
                got[j],
                expected[j]
            );
        }
    }

    #[test]
    fn empty_round_is_an_error() {
        let ctx = CkksContext::new(128, 2, 30).unwrap();
        let engine = StreamingAggregator::new(&ctx.params, EngineConfig::default());
        assert!(engine.aggregate(Vec::new()).is_err());
        // the incremental path agrees: sealing an empty intake is an error
        assert!(engine.begin_round(None).seal().is_err());
    }

    #[test]
    fn incremental_intake_matches_batch_bitwise() {
        // Offering arrivals one at a time (out of stamp order, as a real
        // transport might) seals to the same aggregate and stats as the
        // batch entry point.
        let (codec, updates, alphas, mask) = fixture(6, 800, 0.4);
        let times = [0.4, 0.1, 0.9, 0.2, 50.0, 0.3];
        let cfg = EngineConfig {
            engine: Engine::Pipeline,
            shards: 3,
            quorum: Some(4),
            straggler_timeout_secs: 1.0,
        };
        let engine = StreamingAggregator::new(&codec.ctx.params, cfg);
        let arrivals = arrivals_of(&updates, &alphas, &times);
        let (batch_agg, batch_stats) = engine
            .aggregate_with_mask(arrivals.clone(), Some(&mask))
            .unwrap();
        let mut intake = engine.begin_round(Some(&mask));
        for a in arrivals {
            intake.offer(a).unwrap();
        }
        assert_eq!(intake.offered(), 6);
        let (inc_agg, inc_stats) = intake.seal().unwrap();
        assert_eq!(inc_stats.offered, batch_stats.offered);
        assert_eq!(inc_stats.accepted, batch_stats.accepted);
        assert_eq!(inc_stats.dropped_stragglers, batch_stats.dropped_stragglers);
        assert_eq!(inc_stats.accepted_clients, batch_stats.accepted_clients);
        assert!((inc_stats.alpha_mass - batch_stats.alpha_mass).abs() < 1e-15);
        assert_eq!(inc_stats.dropped_stragglers, 1); // client 4 at t=50
        for (a, b) in inc_agg.cts.iter().zip(batch_agg.cts.iter()) {
            assert_eq!(a.c0, b.c0);
            assert_eq!(a.c1, b.c1);
        }
        assert_eq!(inc_agg.plain, batch_agg.plain);
    }

    #[test]
    fn intake_rejects_heterogeneous_shapes() {
        let (codec, updates, alphas, _mask) = fixture(2, 300, 0.5);
        let (_, small_updates, small_alphas, _) = fixture(1, 200, 0.5);
        let cfg = EngineConfig {
            engine: Engine::Pipeline,
            shards: 2,
            quorum: None,
            straggler_timeout_secs: 1.0,
        };
        let engine = StreamingAggregator::new(&codec.ctx.params, cfg);
        let mut intake = engine.begin_round(None);
        for a in arrivals_of(&updates, &alphas, &[0.1, 0.2]) {
            intake.offer(a).unwrap();
        }
        let stray = arrivals_of(&small_updates, &small_alphas, &[0.3]).pop().unwrap();
        assert!(intake.offer(stray).is_err());
    }

    #[test]
    fn intake_cutoff_hint_tracks_quorum() {
        let (codec, updates, alphas, _mask) = fixture(3, 300, 0.5);
        let cfg = EngineConfig {
            engine: Engine::Pipeline,
            shards: 2,
            quorum: Some(2),
            straggler_timeout_secs: 1.5,
        };
        let engine = StreamingAggregator::new(&codec.ctx.params, cfg);
        let mut intake = engine.begin_round(None);
        let mut arrivals = arrivals_of(&updates, &alphas, &[0.2, 0.5, 0.9]);
        intake.offer(arrivals.remove(0)).unwrap();
        assert_eq!(intake.cutoff_secs(), None); // quorum not reached
        intake.offer(arrivals.remove(0)).unwrap();
        let cutoff = intake.cutoff_secs().unwrap();
        assert!((cutoff - 2.0).abs() < 1e-12); // 0.5 + 1.5
        // no quorum configured → never a cutoff hint
        let no_quorum = StreamingAggregator::new(
            &codec.ctx.params,
            EngineConfig {
                engine: Engine::Pipeline,
                shards: 2,
                quorum: None,
                straggler_timeout_secs: 1.5,
            },
        );
        let mut open = no_quorum.begin_round(None);
        for a in arrivals_of(&updates, &alphas, &[0.1, 0.2, 0.3]) {
            open.offer(a).unwrap();
        }
        assert_eq!(open.cutoff_secs(), None);
    }
}
