//! Sharded, streaming ciphertext-aggregation engine with cohort scheduling.
//!
//! The seed coordinator aggregated client updates one at a time on a single
//! thread — the exact hot path the paper drives down to ~10x (ResNet-50) /
//! ~40x (BERT) overhead. This subsystem replaces that loop with a pipeline
//! that overlaps communication and aggregation (the HADES/hybrid-HE
//! observation that scalable secure aggregation must not barrier on the
//! slowest client):
//!
//! * [`shard`] — **limb sharding**: each update's RNS ciphertext limbs are
//!   split into `(ciphertext, limb)` units distributed round-robin over a
//!   worker pool; the modular weighted-sum kernel runs per shard. Modular
//!   addition is commutative and every unit is fully reduced exactly once at
//!   seal time, so the sharded result is **bitwise identical** to the
//!   sequential kernel for any shard count and any arrival order.
//! * [`stream`] — **streaming intake**: updates enter through bounded
//!   channels as their simulated transfers complete ([`crate::netsim`]
//!   arrival ordering), so aggregation overlaps communication. A
//!   quorum/straggler policy (aggregate-at-quorum + configurable timeout)
//!   drops late uploads; the lost FedAvg weight mass is reported so the
//!   decrypted model can be renormalized exactly.
//! * [`cohort`] — **cohort scheduling**: a lazy virtual-client population
//!   (no per-client state; everything derived from the id) from which K
//!   participants are sampled per round, so client-scaling experiments run
//!   at populations of millions with flat memory.
//!
//! See DESIGN.md §3–§4 for the stage diagram, sharding layout and quorum
//! semantics.

pub mod cohort;
pub mod shard;
pub mod stream;

pub use cohort::{Cohort, CohortMember, CohortScheduler, Population};
pub use shard::{ShardAccumulator, ShardCtSums, ShardPlan};
pub use stream::{Arrival, RoundIntake, StreamStats, StreamingAggregator};

/// Which aggregation engine the coordinator runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    /// Seed behavior: barrier on all arrivals, aggregate on one thread.
    Sequential,
    /// Sharded streaming pipeline ([`StreamingAggregator`]).
    Pipeline,
}

impl Engine {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "sequential" | "seq" => Engine::Sequential,
            "pipeline" | "stream" => Engine::Pipeline,
            other => anyhow::bail!("unknown engine '{other}' (expected: sequential | pipeline)"),
        })
    }
}

/// Engine tuning knobs (the CLI surface: `--engine --shards --quorum
/// --straggler-timeout`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EngineConfig {
    pub engine: Engine,
    /// Worker shards for the pipeline engine.
    pub shards: usize,
    /// Minimum arrivals before the straggler cutoff starts; `None` waits for
    /// every participant (no drops).
    pub quorum: Option<usize>,
    /// Simulated seconds after quorum during which late arrivals are still
    /// accepted; anything later is dropped as a straggler.
    pub straggler_timeout_secs: f64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            engine: Engine::Sequential,
            shards: 4,
            quorum: None,
            straggler_timeout_secs: 5.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_parsing() {
        assert_eq!(Engine::parse("sequential").unwrap(), Engine::Sequential);
        assert_eq!(Engine::parse("seq").unwrap(), Engine::Sequential);
        assert_eq!(Engine::parse("pipeline").unwrap(), Engine::Pipeline);
        assert_eq!(Engine::parse("stream").unwrap(), Engine::Pipeline);
        assert!(Engine::parse("gpu").is_err());
    }

    #[test]
    fn default_config_is_seed_compatible() {
        let c = EngineConfig::default();
        assert_eq!(c.engine, Engine::Sequential);
        assert!(c.quorum.is_none());
        assert!(c.shards >= 1);
    }
}
