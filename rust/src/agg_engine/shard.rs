//! Limb sharding: the static work layout and the per-shard streaming
//! accumulator running the modular weighted-sum kernel.
//!
//! The unit of parallelism is one `(ciphertext, limb)` pair — a contiguous
//! `n`-coefficient residue vector. Units are dealt round-robin over shards
//! so the limbs of a single ciphertext spread across workers (a model
//! smaller than the shard count still parallelizes). The kernel is the same
//! lazy-Barrett accumulation as [`crate::ckks::ops::weighted_sum`]: per
//! client one reduced product (`< q < 2^31`) is added into a `u64`
//! accumulator, so up to `2^31` clients fold in before any reduction is
//! needed; the single final reduction makes the result independent of
//! arrival order — bitwise identical to the sequential kernel.
//!
//! The plaintext (selective-encryption remainder) vector is split into one
//! contiguous compacted range per shard. When the round's encryption mask is
//! known, [`ShardPlan::new_run_aligned`] snaps those boundaries to nearby
//! mask-complement run ends (splitting only runs longer than a balanced
//! share), so shards own whole runs wherever alignment is cheap; the f64
//! fold itself is positionally identical either way, keeping the pipeline
//! bitwise equal to the sequential path for any cut placement.

use crate::ckks::modarith::Barrett;
use crate::ckks::CkksParams;
use crate::he_agg::mask::Run;
use crate::he_agg::EncryptedUpdate;

/// Static layout of one aggregation round over `n_shards` workers.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlan {
    pub n_shards: usize,
    /// Ciphertexts per update (all updates in a round have the same shape).
    pub n_cts: usize,
    /// RNS limbs per polynomial.
    pub n_limbs: usize,
    /// Length of the plaintext (selective-encryption remainder) vector.
    pub plain_len: usize,
    /// Shard boundaries in the compacted plaintext space:
    /// `plain_cuts[s]..plain_cuts[s+1]` is shard `s`'s slice. Monotone, with
    /// `plain_cuts[0] == 0` and `plain_cuts[n_shards] == plain_len`.
    plain_cuts: Vec<usize>,
}

impl ShardPlan {
    /// Even split of the plaintext remainder (mask layout unknown).
    pub fn new(n_shards: usize, n_cts: usize, n_limbs: usize, plain_len: usize) -> Self {
        assert!(n_shards >= 1, "at least one shard");
        assert!(n_limbs >= 1, "at least one limb");
        let per = plain_len.div_ceil(n_shards).max(1);
        let plain_cuts = (0..=n_shards).map(|s| (s * per).min(plain_len)).collect();
        ShardPlan {
            n_shards,
            n_cts,
            n_limbs,
            plain_len,
            plain_cuts,
        }
    }

    /// Run-aligned split: `plain_runs` are the mask-complement runs whose
    /// segments the compacted plaintext vector concatenates. Each shard cut
    /// snaps to the run boundary nearest its balanced target when that stays
    /// within one balanced share of it — shards then own whole runs and
    /// their scatter-back is pure segment copies. A run longer than a share
    /// (e.g. the single full-range run of an empty mask) is split at the
    /// balanced target instead: alignment is an optimization, never a reason
    /// to serialize the fold onto one shard.
    pub fn new_run_aligned(
        n_shards: usize,
        n_cts: usize,
        n_limbs: usize,
        plain_runs: &[Run],
    ) -> Self {
        assert!(n_shards >= 1, "at least one shard");
        assert!(n_limbs >= 1, "at least one limb");
        // Cumulative compacted end positions, one per run.
        let mut ends = Vec::with_capacity(plain_runs.len());
        let mut acc = 0usize;
        for r in plain_runs {
            acc += r.len();
            ends.push(acc);
        }
        let plain_len = acc;
        let per = plain_len.div_ceil(n_shards).max(1);
        let mut plain_cuts = vec![0usize; n_shards + 1];
        for s in 1..n_shards {
            let target = plain_len * s / n_shards;
            // nearest run boundaries on either side of the target
            let (before, after) = match ends.binary_search(&target) {
                Ok(i) => (ends[i], ends[i]),
                Err(i) => (
                    if i > 0 { ends[i - 1] } else { 0 },
                    if i < ends.len() { ends[i] } else { plain_len },
                ),
            };
            let snapped = if after - target <= target - before {
                after
            } else {
                before
            };
            let cut = if snapped.abs_diff(target) <= per {
                snapped
            } else {
                target
            };
            plain_cuts[s] = cut.max(plain_cuts[s - 1]);
        }
        plain_cuts[n_shards] = plain_len;
        ShardPlan {
            n_shards,
            n_cts,
            n_limbs,
            plain_len,
            plain_cuts,
        }
    }

    /// Total `(ciphertext, limb)` units in the round.
    pub fn n_units(&self) -> usize {
        self.n_cts * self.n_limbs
    }

    /// The `(ct, limb)` units owned by `shard` (round-robin over the
    /// flattened unit index).
    pub fn units(&self, shard: usize) -> Vec<(usize, usize)> {
        assert!(shard < self.n_shards);
        (0..self.n_units())
            .filter(|u| u % self.n_shards == shard)
            .map(|u| (u / self.n_limbs, u % self.n_limbs))
            .collect()
    }

    /// Contiguous slice of the compacted plaintext remainder owned by
    /// `shard`.
    pub fn plain_range(&self, shard: usize) -> std::ops::Range<usize> {
        assert!(shard < self.n_shards);
        self.plain_cuts[shard]..self.plain_cuts[shard + 1]
    }
}

/// One shard's reduced weighted sums at seal time.
#[derive(Debug, Clone)]
pub struct ShardCtSums {
    /// The `(ct, limb)` units, parallel to `c0`/`c1`.
    pub units: Vec<(usize, usize)>,
    /// Reduced c0 residues per unit (length `n` each).
    pub c0: Vec<Vec<u64>>,
    /// Reduced c1 residues per unit.
    pub c1: Vec<Vec<u64>>,
}

/// Streaming accumulator for one shard: absorbs one client update at a time
/// (in arrival order) and reduces once at seal.
pub struct ShardAccumulator {
    plan: ShardPlan,
    units: Vec<(usize, usize)>,
    reducers: Vec<Barrett>,
    moduli: Vec<u64>,
    acc_c0: Vec<Vec<u64>>,
    acc_c1: Vec<Vec<u64>>,
    /// Pooled expansion buffer for lazily-parsed seeded ciphertexts: one
    /// limb of the a-part is regenerated here from the ciphertext seed
    /// before folding, so warm rounds stay allocation-free.
    a_scratch: Vec<u64>,
    absorbed: usize,
}

impl ShardAccumulator {
    pub fn new(plan: &ShardPlan, shard: usize, params: &CkksParams) -> Self {
        assert_eq!(plan.n_limbs, params.num_limbs(), "plan/params limb mismatch");
        let units = plan.units(shard);
        let n = params.n;
        ShardAccumulator {
            plan: plan.clone(),
            // §Perf: reuse the per-limb reducers cached in `CkksParams`.
            reducers: params.barrett.clone(),
            moduli: params.moduli.clone(),
            acc_c0: vec![vec![0u64; n]; units.len()],
            acc_c1: vec![vec![0u64; n]; units.len()],
            a_scratch: vec![0u64; n],
            units,
            absorbed: 0,
        }
    }

    /// Clients folded in so far.
    pub fn absorbed(&self) -> usize {
        self.absorbed
    }

    /// Fold one client's ciphertext limbs into this shard, weighted by the
    /// client's encoded per-limb FedAvg weight (`CkksParams::encode_weight`).
    /// The per-limb accumulate runs on the runtime-dispatched vector kernel
    /// (§Perf) — bitwise identical to the scalar loop it replaced.
    ///
    /// A lazily-parsed seeded ciphertext (seed present, empty c1) never
    /// materializes its a-part: each owned limb is expanded from the seed
    /// into the pooled scratch and folded straight into the accumulator.
    /// Each `(ct, limb)` unit is owned by exactly one shard, so every limb
    /// is expanded exactly once per client per round.
    pub fn absorb(&mut self, upd: &EncryptedUpdate, weight: &[u64]) {
        assert_eq!(upd.cts.len(), self.plan.n_cts, "update shape drifted mid-round");
        assert_eq!(weight.len(), self.plan.n_limbs, "weight residue count");
        let kernel = crate::ckks::simd::active();
        for (k, &(ct, limb)) in self.units.iter().enumerate() {
            let br = self.reducers[limb];
            let w = weight[limb];
            let src = &upd.cts[ct];
            kernel.weighted_accumulate(&mut self.acc_c0[k], src.c0.limb(limb), w, br);
            match src.a_seed {
                Some(seed) if src.c1.num_limbs() == 0 => {
                    crate::ckks::encrypt::expand_ct_a_limb(
                        &seed,
                        limb,
                        self.moduli[limb],
                        &mut self.a_scratch,
                    );
                    kernel.weighted_accumulate(&mut self.acc_c1[k], &self.a_scratch, w, br);
                }
                _ => kernel.weighted_accumulate(&mut self.acc_c1[k], src.c1.limb(limb), w, br),
            }
        }
        self.absorbed += 1;
        // Lazy-accumulation guard: each term is < 2^31, so fold well before
        // the u64 headroom (2^62 for Barrett::reduce) could run out.
        if self.absorbed % (1 << 30) == 0 {
            self.fold();
        }
    }

    fn fold(&mut self) {
        let kernel = crate::ckks::simd::active();
        for (k, &(_, limb)) in self.units.iter().enumerate() {
            let br = self.reducers[limb];
            kernel.reduce_slice(&mut self.acc_c0[k], br);
            kernel.reduce_slice(&mut self.acc_c1[k], br);
        }
    }

    /// Seal the shard: one final modular reduction per unit.
    pub fn finalize(mut self) -> ShardCtSums {
        let _span = crate::obs::span_arg("engine", "shard_finalize", self.absorbed as u64);
        self.fold();
        ShardCtSums {
            units: self.units,
            c0: self.acc_c0,
            c1: self.acc_c1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckks::{ops, CkksContext};
    use crate::crypto::prng::ChaChaRng;
    use crate::he_agg::mask::EncryptionMask;
    use crate::he_agg::selective::SelectiveCodec;

    #[test]
    fn plan_partitions_all_units_exactly_once() {
        for n_shards in [1usize, 2, 3, 4, 8, 13] {
            let plan = ShardPlan::new(n_shards, 5, 4, 1000);
            let mut seen = vec![0usize; plan.n_units()];
            for s in 0..n_shards {
                for (ct, limb) in plan.units(s) {
                    seen[ct * plan.n_limbs + limb] += 1;
                }
            }
            assert!(seen.iter().all(|&c| c == 1), "shards={n_shards}");
            // plaintext ranges tile [0, plain_len)
            let mut covered = 0usize;
            for s in 0..n_shards {
                let r = plan.plain_range(s);
                assert_eq!(r.start, covered.min(plan.plain_len));
                covered = covered.max(r.end);
            }
            assert_eq!(covered, plan.plain_len);
        }
    }

    #[test]
    fn run_aligned_cuts_snap_or_split_within_bounds() {
        // adversarial complement layouts: singleton runs, one full-range run
        // (empty mask), long blocks, and a mix whose balanced targets fall
        // mid-run
        let layouts: Vec<Vec<Run>> = vec![
            (0..50).map(|i| Run { lo: 2 * i, hi: 2 * i + 1 }).collect(),
            vec![Run { lo: 0, hi: 1000 }],
            vec![
                Run { lo: 0, hi: 7 },
                Run { lo: 100, hi: 530 },
                Run { lo: 600, hi: 601 },
                Run { lo: 700, hi: 950 },
            ],
            Vec::new(),
        ];
        for runs in &layouts {
            let mut ends = Vec::new();
            let mut acc = 0usize;
            for r in runs {
                acc += r.len();
                ends.push(acc);
            }
            for n_shards in [1usize, 2, 3, 4, 8, 13] {
                let plan = ShardPlan::new_run_aligned(n_shards, 3, 4, runs);
                assert_eq!(plan.plain_len, acc);
                let per = acc.div_ceil(n_shards).max(1);
                let mut covered = 0usize;
                let mut prev_cut = 0usize;
                for s in 0..n_shards {
                    let r = plan.plain_range(s);
                    assert_eq!(r.start, covered, "shards={n_shards}");
                    covered = r.end;
                    // balance: no shard hoards the fold (≤ 3 balanced shares)
                    assert!(
                        r.len() <= 3 * per,
                        "shards={n_shards}: shard {s} owns {} of {acc}",
                        r.len()
                    );
                    // every interior cut is a run end, the balanced-target
                    // fallback for an oversized run, or a clamped repeat
                    if s > 0 {
                        let b = r.start;
                        let target = acc * s / n_shards;
                        assert!(
                            b == 0
                                || b == acc
                                || ends.contains(&b)
                                || b == target
                                || b == prev_cut,
                            "shards={n_shards}: cut {b} is neither aligned nor balanced"
                        );
                    }
                    prev_cut = r.start;
                }
                assert_eq!(covered, acc);
            }
            // singleton-run layouts align exactly (snap is always in bound)
        }
        // the empty-mask complement (one full-range run) must still
        // parallelize: the fold is split at balanced targets, not serialized
        let plan = ShardPlan::new_run_aligned(8, 3, 4, &[Run { lo: 0, hi: 1000 }]);
        for s in 0..8 {
            let r = plan.plain_range(s);
            assert!(r.len() <= 250, "shard {s} owns {} of 1000", r.len());
        }
    }

    #[test]
    fn sharded_sums_match_sequential_kernel_bitwise() {
        let ctx = CkksContext::new(256, 4, 40).unwrap();
        let codec = SelectiveCodec::new(ctx);
        let mut rng = ChaChaRng::from_seed(21, 0);
        let (pk, _sk) = codec.ctx.keygen(&mut rng);
        let total = 600; // 5 ciphertexts at batch 128
        let mask = EncryptionMask::full(total);
        let alphas = [0.4, 0.35, 0.25];
        let updates: Vec<EncryptedUpdate> = (0..3usize)
            .map(|c| {
                let m: Vec<f32> = (0..total).map(|i| ((i * (c + 2)) as f32 * 0.01).sin()).collect();
                codec.encrypt_update(&m, &mask, &pk, &mut rng)
            })
            .collect();
        let params = &codec.ctx.params;

        // sequential oracle per ciphertext index
        let oracle: Vec<crate::ckks::Ciphertext> = (0..updates[0].cts.len())
            .map(|c| {
                let slice: Vec<_> = updates.iter().map(|u| u.cts[c].clone()).collect();
                ops::weighted_sum(&slice, &alphas, params)
            })
            .collect();

        for n_shards in [1usize, 2, 4, 8] {
            let plan = ShardPlan::new(n_shards, updates[0].cts.len(), params.num_limbs(), 0);
            let mut accs: Vec<ShardAccumulator> = (0..n_shards)
                .map(|s| ShardAccumulator::new(&plan, s, params))
                .collect();
            // absorb in a scrambled arrival order
            for &i in &[2usize, 0, 1] {
                let w = params.encode_weight(alphas[i]);
                for acc in accs.iter_mut() {
                    acc.absorb(&updates[i], &w);
                }
            }
            for acc in accs {
                assert_eq!(acc.absorbed(), 3);
                let sums = acc.finalize();
                for (k, &(ct, limb)) in sums.units.iter().enumerate() {
                    assert_eq!(sums.c0[k], oracle[ct].c0.limb(limb), "shards={n_shards}");
                    assert_eq!(sums.c1[k], oracle[ct].c1.limb(limb), "shards={n_shards}");
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "shape drifted")]
    fn shape_drift_panics() {
        let ctx = CkksContext::new(128, 2, 30).unwrap();
        let codec = SelectiveCodec::new(ctx);
        let mut rng = ChaChaRng::from_seed(22, 0);
        let (pk, _) = codec.ctx.keygen(&mut rng);
        let u1 = codec.encrypt_update(&vec![1.0; 100], &EncryptionMask::full(100), &pk, &mut rng);
        let u2 = codec.encrypt_update(&vec![1.0; 300], &EncryptionMask::full(300), &pk, &mut rng);
        let params = &codec.ctx.params;
        let plan = ShardPlan::new(2, u1.cts.len(), params.num_limbs(), 0);
        let mut acc = ShardAccumulator::new(&plan, 0, params);
        let w = params.encode_weight(0.5);
        acc.absorb(&u1, &w);
        acc.absorb(&u2, &w);
    }
}
