//! Limb sharding: the static work layout and the per-shard streaming
//! accumulator running the modular weighted-sum kernel.
//!
//! The unit of parallelism is one `(ciphertext, limb)` pair — a contiguous
//! `n`-coefficient residue vector. Units are dealt round-robin over shards
//! so the limbs of a single ciphertext spread across workers (a model
//! smaller than the shard count still parallelizes). The kernel is the same
//! lazy-Barrett accumulation as [`crate::ckks::ops::weighted_sum`]: per
//! client one reduced product (`< q < 2^31`) is added into a `u64`
//! accumulator, so up to `2^31` clients fold in before any reduction is
//! needed; the single final reduction makes the result independent of
//! arrival order — bitwise identical to the sequential kernel.

use crate::ckks::modarith::Barrett;
use crate::ckks::CkksParams;
use crate::he_agg::EncryptedUpdate;

/// Static layout of one aggregation round over `n_shards` workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardPlan {
    pub n_shards: usize,
    /// Ciphertexts per update (all updates in a round have the same shape).
    pub n_cts: usize,
    /// RNS limbs per polynomial.
    pub n_limbs: usize,
    /// Length of the plaintext (selective-encryption remainder) vector.
    pub plain_len: usize,
}

impl ShardPlan {
    pub fn new(n_shards: usize, n_cts: usize, n_limbs: usize, plain_len: usize) -> Self {
        assert!(n_shards >= 1, "at least one shard");
        assert!(n_limbs >= 1, "at least one limb");
        ShardPlan {
            n_shards,
            n_cts,
            n_limbs,
            plain_len,
        }
    }

    /// Total `(ciphertext, limb)` units in the round.
    pub fn n_units(&self) -> usize {
        self.n_cts * self.n_limbs
    }

    /// The `(ct, limb)` units owned by `shard` (round-robin over the
    /// flattened unit index).
    pub fn units(&self, shard: usize) -> Vec<(usize, usize)> {
        assert!(shard < self.n_shards);
        (0..self.n_units())
            .filter(|u| u % self.n_shards == shard)
            .map(|u| (u / self.n_limbs, u % self.n_limbs))
            .collect()
    }

    /// Contiguous slice of the plaintext remainder owned by `shard`.
    pub fn plain_range(&self, shard: usize) -> std::ops::Range<usize> {
        assert!(shard < self.n_shards);
        let per = self.plain_len.div_ceil(self.n_shards).max(1);
        let lo = (shard * per).min(self.plain_len);
        let hi = ((shard + 1) * per).min(self.plain_len);
        lo..hi
    }
}

/// One shard's reduced weighted sums at seal time.
#[derive(Debug, Clone)]
pub struct ShardCtSums {
    /// The `(ct, limb)` units, parallel to `c0`/`c1`.
    pub units: Vec<(usize, usize)>,
    /// Reduced c0 residues per unit (length `n` each).
    pub c0: Vec<Vec<u64>>,
    /// Reduced c1 residues per unit.
    pub c1: Vec<Vec<u64>>,
}

/// Streaming accumulator for one shard: absorbs one client update at a time
/// (in arrival order) and reduces once at seal.
pub struct ShardAccumulator {
    plan: ShardPlan,
    units: Vec<(usize, usize)>,
    reducers: Vec<Barrett>,
    acc_c0: Vec<Vec<u64>>,
    acc_c1: Vec<Vec<u64>>,
    absorbed: usize,
}

impl ShardAccumulator {
    pub fn new(plan: ShardPlan, shard: usize, params: &CkksParams) -> Self {
        assert_eq!(plan.n_limbs, params.num_limbs(), "plan/params limb mismatch");
        let units = plan.units(shard);
        let n = params.n;
        ShardAccumulator {
            plan,
            reducers: params.moduli.iter().map(|&q| Barrett::new(q)).collect(),
            acc_c0: vec![vec![0u64; n]; units.len()],
            acc_c1: vec![vec![0u64; n]; units.len()],
            units,
            absorbed: 0,
        }
    }

    /// Clients folded in so far.
    pub fn absorbed(&self) -> usize {
        self.absorbed
    }

    /// Fold one client's ciphertext limbs into this shard, weighted by the
    /// client's encoded per-limb FedAvg weight (`CkksParams::encode_weight`).
    pub fn absorb(&mut self, upd: &EncryptedUpdate, weight: &[u64]) {
        assert_eq!(upd.cts.len(), self.plan.n_cts, "update shape drifted mid-round");
        assert_eq!(weight.len(), self.plan.n_limbs, "weight residue count");
        for (k, &(ct, limb)) in self.units.iter().enumerate() {
            let br = self.reducers[limb];
            let w = weight[limb];
            let src = &upd.cts[ct];
            for (d, &s) in self.acc_c0[k].iter_mut().zip(src.c0.limbs[limb].iter()) {
                *d += br.mul(s, w);
            }
            for (d, &s) in self.acc_c1[k].iter_mut().zip(src.c1.limbs[limb].iter()) {
                *d += br.mul(s, w);
            }
        }
        self.absorbed += 1;
        // Lazy-accumulation guard: each term is < 2^31, so fold well before
        // the u64 headroom (2^62 for Barrett::reduce) could run out.
        if self.absorbed % (1 << 30) == 0 {
            self.fold();
        }
    }

    fn fold(&mut self) {
        for (k, &(_, limb)) in self.units.iter().enumerate() {
            let br = self.reducers[limb];
            for x in self.acc_c0[k].iter_mut() {
                *x = br.reduce(*x);
            }
            for x in self.acc_c1[k].iter_mut() {
                *x = br.reduce(*x);
            }
        }
    }

    /// Seal the shard: one final modular reduction per unit.
    pub fn finalize(mut self) -> ShardCtSums {
        self.fold();
        ShardCtSums {
            units: self.units,
            c0: self.acc_c0,
            c1: self.acc_c1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckks::{ops, CkksContext};
    use crate::crypto::prng::ChaChaRng;
    use crate::he_agg::mask::EncryptionMask;
    use crate::he_agg::selective::SelectiveCodec;

    #[test]
    fn plan_partitions_all_units_exactly_once() {
        for n_shards in [1usize, 2, 3, 4, 8, 13] {
            let plan = ShardPlan::new(n_shards, 5, 4, 1000);
            let mut seen = vec![0usize; plan.n_units()];
            for s in 0..n_shards {
                for (ct, limb) in plan.units(s) {
                    seen[ct * plan.n_limbs + limb] += 1;
                }
            }
            assert!(seen.iter().all(|&c| c == 1), "shards={n_shards}");
            // plaintext ranges tile [0, plain_len)
            let mut covered = 0usize;
            for s in 0..n_shards {
                let r = plan.plain_range(s);
                assert_eq!(r.start, covered.min(plan.plain_len));
                covered = covered.max(r.end);
            }
            assert_eq!(covered, plan.plain_len);
        }
    }

    #[test]
    fn sharded_sums_match_sequential_kernel_bitwise() {
        let ctx = CkksContext::new(256, 4, 40).unwrap();
        let codec = SelectiveCodec::new(ctx);
        let mut rng = ChaChaRng::from_seed(21, 0);
        let (pk, _sk) = codec.ctx.keygen(&mut rng);
        let total = 600; // 5 ciphertexts at batch 128
        let mask = EncryptionMask::full(total);
        let alphas = [0.4, 0.35, 0.25];
        let updates: Vec<EncryptedUpdate> = (0..3usize)
            .map(|c| {
                let m: Vec<f32> = (0..total).map(|i| ((i * (c + 2)) as f32 * 0.01).sin()).collect();
                codec.encrypt_update(&m, &mask, &pk, &mut rng)
            })
            .collect();
        let params = &codec.ctx.params;

        // sequential oracle per ciphertext index
        let oracle: Vec<crate::ckks::Ciphertext> = (0..updates[0].cts.len())
            .map(|c| {
                let slice: Vec<_> = updates.iter().map(|u| u.cts[c].clone()).collect();
                ops::weighted_sum(&slice, &alphas, params)
            })
            .collect();

        for n_shards in [1usize, 2, 4, 8] {
            let plan = ShardPlan::new(n_shards, updates[0].cts.len(), params.num_limbs(), 0);
            let mut accs: Vec<ShardAccumulator> = (0..n_shards)
                .map(|s| ShardAccumulator::new(plan, s, params))
                .collect();
            // absorb in a scrambled arrival order
            for &i in &[2usize, 0, 1] {
                let w = params.encode_weight(alphas[i]);
                for acc in accs.iter_mut() {
                    acc.absorb(&updates[i], &w);
                }
            }
            for acc in accs {
                assert_eq!(acc.absorbed(), 3);
                let sums = acc.finalize();
                for (k, &(ct, limb)) in sums.units.iter().enumerate() {
                    assert_eq!(sums.c0[k], oracle[ct].c0.limbs[limb], "shards={n_shards}");
                    assert_eq!(sums.c1[k], oracle[ct].c1.limbs[limb], "shards={n_shards}");
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "shape drifted")]
    fn shape_drift_panics() {
        let ctx = CkksContext::new(128, 2, 30).unwrap();
        let codec = SelectiveCodec::new(ctx);
        let mut rng = ChaChaRng::from_seed(22, 0);
        let (pk, _) = codec.ctx.keygen(&mut rng);
        let u1 = codec.encrypt_update(&vec![1.0; 100], &EncryptionMask::full(100), &pk, &mut rng);
        let u2 = codec.encrypt_update(&vec![1.0; 300], &EncryptionMask::full(300), &pk, &mut rng);
        let params = &codec.ctx.params;
        let plan = ShardPlan::new(2, u1.cts.len(), params.num_limbs(), 0);
        let mut acc = ShardAccumulator::new(plan, 0, params);
        let w = params.encode_weight(0.5);
        acc.absorb(&u1, &w);
        acc.absorb(&u2, &w);
    }
}
