//! Network simulation: bandwidth profiles and simulated transfer clocks.
//!
//! The paper's communication costs are `bytes ÷ bandwidth` under three
//! deployment profiles (Appendix D.5) plus the Fig. 8 single-AWS-region
//! setting; this module reproduces exactly that cost model while the byte
//! counts come from the real wire formats.

/// A deployment bandwidth profile.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Bandwidth {
    pub name: &'static str,
    /// Bytes per second.
    pub bytes_per_sec: f64,
}

/// Infiniband: intra-datacenter (paper: 5 GB/s).
pub const INFINIBAND: Bandwidth = Bandwidth { name: "IB", bytes_per_sec: 5.0e9 };
/// Single AWS region (paper: 592 MB/s).
pub const SINGLE_AWS_REGION: Bandwidth = Bandwidth { name: "SAR", bytes_per_sec: 592.0e6 };
/// Multi AWS region (paper: 15.6 MB/s).
pub const MULTI_AWS_REGION: Bandwidth = Bandwidth { name: "MAR", bytes_per_sec: 15.6e6 };
/// Fig. 8 single-region setting (paper: 200 MB/s).
pub const FIG8_REGION: Bandwidth = Bandwidth { name: "AWS-200", bytes_per_sec: 200.0e6 };

/// All profiles of Appendix D.5.
pub const PROFILES: &[Bandwidth] = &[INFINIBAND, SINGLE_AWS_REGION, MULTI_AWS_REGION];

impl Bandwidth {
    /// Simulated seconds to move `bytes` over this link.
    pub fn transfer_secs(&self, bytes: u64) -> f64 {
        bytes as f64 / self.bytes_per_sec
    }
}

/// Accumulates simulated communication time alongside real compute time.
#[derive(Debug, Clone, Copy, Default)]
pub struct SimClock {
    pub comm_secs: f64,
    pub bytes_up: u64,
    pub bytes_down: u64,
}

impl SimClock {
    /// Record a client→server upload.
    pub fn upload(&mut self, bytes: u64, bw: Bandwidth) {
        self.bytes_up += bytes;
        self.comm_secs += bw.transfer_secs(bytes);
    }
    /// Record a server→client download.
    pub fn download(&mut self, bytes: u64, bw: Bandwidth) {
        self.bytes_down += bytes;
        self.comm_secs += bw.transfer_secs(bytes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_times_match_paper_arithmetic() {
        // 1.58 GB ResNet-50 ciphertext over MAR ≈ 101 s; over IB ≈ 0.32 s
        let ct: u64 = 1_580_000_000;
        assert!((MULTI_AWS_REGION.transfer_secs(ct) - 101.28).abs() < 1.0);
        assert!(INFINIBAND.transfer_secs(ct) < 0.35);
    }

    #[test]
    fn clock_accumulates() {
        let mut c = SimClock::default();
        c.upload(1000, Bandwidth { name: "t", bytes_per_sec: 1000.0 });
        c.download(2000, Bandwidth { name: "t", bytes_per_sec: 1000.0 });
        assert_eq!(c.bytes_up, 1000);
        assert_eq!(c.bytes_down, 2000);
        assert!((c.comm_secs - 3.0).abs() < 1e-12);
    }
}
