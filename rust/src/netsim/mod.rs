//! Network simulation: bandwidth profiles and simulated transfer clocks.
//!
//! The paper's communication costs are `bytes ÷ bandwidth` under three
//! deployment profiles (Appendix D.5) plus the Fig. 8 single-AWS-region
//! setting; this module reproduces exactly that cost model while the byte
//! counts come from the real wire formats.

/// A deployment bandwidth profile.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Bandwidth {
    pub name: &'static str,
    /// Bytes per second.
    pub bytes_per_sec: f64,
}

/// Infiniband: intra-datacenter (paper: 5 GB/s).
pub const INFINIBAND: Bandwidth = Bandwidth { name: "IB", bytes_per_sec: 5.0e9 };
/// Single AWS region (paper: 592 MB/s).
pub const SINGLE_AWS_REGION: Bandwidth = Bandwidth { name: "SAR", bytes_per_sec: 592.0e6 };
/// Multi AWS region (paper: 15.6 MB/s).
pub const MULTI_AWS_REGION: Bandwidth = Bandwidth { name: "MAR", bytes_per_sec: 15.6e6 };
/// Fig. 8 single-region setting (paper: 200 MB/s).
pub const FIG8_REGION: Bandwidth = Bandwidth { name: "AWS-200", bytes_per_sec: 200.0e6 };

/// All profiles of Appendix D.5.
pub const PROFILES: &[Bandwidth] = &[INFINIBAND, SINGLE_AWS_REGION, MULTI_AWS_REGION];

impl Bandwidth {
    /// Simulated seconds to move `bytes` over this link.
    pub fn transfer_secs(&self, bytes: u64) -> f64 {
        bytes as f64 / self.bytes_per_sec
    }
}

/// How concurrent client uploads are charged to round time.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum UplinkMode {
    /// Seed behavior: uploads sum serially (models a single-threaded ingest
    /// link; overstates round time when clients upload simultaneously).
    #[default]
    Serial,
    /// Per-round uplink time = max over concurrent transfers (clients push
    /// over independent links; the round waits for the slowest upload).
    Parallel,
}

/// Accumulates simulated communication time alongside real compute time.
#[derive(Debug, Clone, Copy, Default)]
pub struct SimClock {
    pub mode: UplinkMode,
    pub comm_secs: f64,
    pub bytes_up: u64,
    pub bytes_down: u64,
    /// Longest single uplink transfer seen so far (Parallel accounting).
    uplink_max_secs: f64,
}

impl SimClock {
    /// Seed-compatible serial accounting.
    pub fn serial() -> Self {
        SimClock::default()
    }

    /// Parallel-uplink accounting (used for per-round `RoundMetrics`).
    pub fn parallel() -> Self {
        SimClock {
            mode: UplinkMode::Parallel,
            ..SimClock::default()
        }
    }

    /// Record a client→server upload.
    pub fn upload(&mut self, bytes: u64, bw: Bandwidth) {
        self.bytes_up += bytes;
        let t = bw.transfer_secs(bytes);
        match self.mode {
            UplinkMode::Serial => self.comm_secs += t,
            UplinkMode::Parallel => {
                // comm_secs tracks max(uplinks) + Σ downloads exactly.
                if t > self.uplink_max_secs {
                    self.comm_secs += t - self.uplink_max_secs;
                    self.uplink_max_secs = t;
                }
            }
        }
    }
    /// Count upload bytes without charging link time — a transfer the round
    /// never waited for (e.g. a straggler dropped by the quorum policy), or
    /// bytes that already paid real wall-clock time on a live transport.
    pub fn upload_bytes_only(&mut self, bytes: u64) {
        self.bytes_up += bytes;
    }

    /// Mark a round boundary. Parallel accounting maxes each uplink against
    /// the slowest transfer *of the current round only*; without this reset a
    /// clock reused across rounds undercharges every round after the first
    /// (round 2's uploads would be max'd against round 1's slowest). Serial
    /// accounting keeps no per-round state, so the call is always safe.
    pub fn finish_round(&mut self) {
        self.uplink_max_secs = 0.0;
    }

    /// Record a server→client download.
    pub fn download(&mut self, bytes: u64, bw: Bandwidth) {
        self.bytes_down += bytes;
        self.comm_secs += bw.transfer_secs(bytes);
    }

    /// Server→clients broadcast: every recipient receives `bytes`. Serial
    /// accounting sums the transfers; Parallel charges one transfer time
    /// (independent links, all recipients download concurrently).
    pub fn broadcast(&mut self, bytes: u64, recipients: usize, bw: Bandwidth) {
        self.bytes_down += bytes * recipients as u64;
        match self.mode {
            UplinkMode::Serial => self.comm_secs += bw.transfer_secs(bytes) * recipients as f64,
            UplinkMode::Parallel => self.comm_secs += bw.transfer_secs(bytes),
        }
    }
}

/// Completion times for concurrent uploads: client `i` starts at `starts[i]`
/// (e.g. when its local training finishes) and pushes `bytes[i]` over an
/// independent link, arriving at `starts[i] + bytes[i]/bw`. This is the
/// arrival ordering the streaming aggregation engine consumes.
pub fn concurrent_arrivals(bytes: &[u64], starts: &[f64], bw: Bandwidth) -> Vec<f64> {
    assert_eq!(bytes.len(), starts.len());
    bytes
        .iter()
        .zip(starts.iter())
        .map(|(&b, &s)| s + bw.transfer_secs(b))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_times_match_paper_arithmetic() {
        // 1.58 GB ResNet-50 ciphertext over MAR ≈ 101 s; over IB ≈ 0.32 s
        let ct: u64 = 1_580_000_000;
        assert!((MULTI_AWS_REGION.transfer_secs(ct) - 101.28).abs() < 1.0);
        assert!(INFINIBAND.transfer_secs(ct) < 0.35);
    }

    #[test]
    fn clock_accumulates() {
        let mut c = SimClock::default();
        c.upload(1000, Bandwidth { name: "t", bytes_per_sec: 1000.0 });
        c.download(2000, Bandwidth { name: "t", bytes_per_sec: 1000.0 });
        assert_eq!(c.bytes_up, 1000);
        assert_eq!(c.bytes_down, 2000);
        assert!((c.comm_secs - 3.0).abs() < 1e-12);
    }

    #[test]
    fn parallel_uplink_takes_max_not_sum() {
        let bw = Bandwidth { name: "t", bytes_per_sec: 1000.0 };
        let mut serial = SimClock::serial();
        let mut parallel = SimClock::parallel();
        for clock in [&mut serial, &mut parallel] {
            clock.upload(1000, bw); // 1 s
            clock.upload(3000, bw); // 3 s
            clock.upload(2000, bw); // 2 s
            clock.download(500, bw); // 0.5 s
        }
        // serial: 1 + 3 + 2 + 0.5; parallel: max(1, 3, 2) + 0.5
        assert!((serial.comm_secs - 6.5).abs() < 1e-12);
        assert!((parallel.comm_secs - 3.5).abs() < 1e-12);
        // byte counters are accounting-mode independent
        assert_eq!(serial.bytes_up, parallel.bytes_up);
        assert_eq!(serial.bytes_down, parallel.bytes_down);
    }

    #[test]
    fn broadcast_and_bytes_only_accounting() {
        let bw = Bandwidth { name: "t", bytes_per_sec: 1000.0 };
        let mut serial = SimClock::serial();
        let mut parallel = SimClock::parallel();
        for clock in [&mut serial, &mut parallel] {
            clock.broadcast(1000, 4, bw); // 1 s per recipient
            clock.upload_bytes_only(5000); // dropped straggler: bytes, no time
        }
        assert!((serial.comm_secs - 4.0).abs() < 1e-12);
        assert!((parallel.comm_secs - 1.0).abs() < 1e-12);
        assert_eq!(serial.bytes_down, 4000);
        assert_eq!(parallel.bytes_down, 4000);
        assert_eq!(serial.bytes_up, 5000);
    }

    #[test]
    fn parallel_clock_reused_across_rounds_resets_uplink_max() {
        let bw = Bandwidth { name: "t", bytes_per_sec: 1000.0 };
        let mut clock = SimClock::parallel();
        // round 1: slowest uplink 3 s
        clock.upload(3000, bw);
        clock.upload(1000, bw);
        clock.finish_round();
        // round 2: slowest uplink 2 s — must charge a fresh per-round max,
        // not be absorbed by round 1's 3 s
        clock.upload(1000, bw);
        clock.upload(2000, bw);
        clock.finish_round();
        // round 3: a single 1 s uplink
        clock.upload(1000, bw);
        clock.finish_round();
        assert!((clock.comm_secs - 6.0).abs() < 1e-12, "3 + 2 + 1 expected");
        assert_eq!(clock.bytes_up, 8000);

        // regression shape: without the boundary, rounds 2 and 3 ride under
        // round 1's max and the clock undercharges to 3 s total
        let mut stale = SimClock::parallel();
        for b in [3000u64, 1000, 1000, 2000, 1000] {
            stale.upload(b, bw);
        }
        assert!((stale.comm_secs - 3.0).abs() < 1e-12);
    }

    #[test]
    fn concurrent_arrival_ordering() {
        let bw = Bandwidth { name: "t", bytes_per_sec: 100.0 };
        // client 1 starts later but uploads less; client 0 arrives last
        let arr = concurrent_arrivals(&[500, 100], &[0.0, 2.0], bw);
        assert!((arr[0] - 5.0).abs() < 1e-12);
        assert!((arr[1] - 3.0).abs() < 1e-12);
        assert!(arr[1] < arr[0]);
    }
}
