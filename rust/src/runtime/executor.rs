//! Executor: compile-once / run-many wrapper over the PJRT CPU client.
//!
//! Graphs are compiled lazily on first use and cached; every lowered module
//! returns a tuple (aot.py lowers with `return_tuple=True`), which
//! [`Runtime::execute`] decomposes into plain literals.

use super::artifact::Manifest;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Typed argument for a graph call.
pub enum Arg<'a> {
    F32(&'a [f32], Vec<i64>),
    U32(&'a [u32], Vec<i64>),
    I32(&'a [i32], Vec<i64>),
    ScalarF32(f32),
}

impl Arg<'_> {
    fn to_literal(&self) -> anyhow::Result<xla::Literal> {
        Ok(match self {
            Arg::F32(data, dims) => xla::Literal::vec1(data).reshape(dims)?,
            Arg::U32(data, dims) => xla::Literal::vec1(data).reshape(dims)?,
            Arg::I32(data, dims) => xla::Literal::vec1(data).reshape(dims)?,
            Arg::ScalarF32(v) => xla::Literal::scalar(*v),
        })
    }
}

/// The PJRT runtime: client + manifest + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Create a runtime over the artifacts directory.
    pub fn new(artifacts_dir: impl AsRef<std::path::Path>) -> anyhow::Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Number of graphs compiled so far (metrics).
    pub fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Get (compiling if needed) the executable for a graph.
    pub fn executable(&self, graph: &str) -> anyhow::Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(graph) {
            return Ok(exe.clone());
        }
        let path = self.manifest.hlo_path(graph)?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(self.client.compile(&comp)?);
        self.cache
            .lock()
            .unwrap()
            .insert(graph.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute a graph; returns the decomposed output tuple.
    pub fn execute(&self, graph: &str, args: &[Arg<'_>]) -> anyhow::Result<Vec<xla::Literal>> {
        let spec = self
            .manifest
            .graphs
            .get(graph)
            .ok_or_else(|| anyhow::anyhow!("graph '{graph}' not in manifest"))?;
        anyhow::ensure!(
            spec.args.len() == args.len(),
            "graph '{graph}' expects {} args, got {}",
            spec.args.len(),
            args.len()
        );
        let exe = self.executable(graph)?;
        let literals: Vec<xla::Literal> = args
            .iter()
            .map(|a| a.to_literal())
            .collect::<anyhow::Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple()?)
    }

    /// Convenience: execute and extract f32 vectors from every output.
    pub fn execute_f32(&self, graph: &str, args: &[Arg<'_>]) -> anyhow::Result<Vec<Vec<f32>>> {
        self.execute(graph, args)?
            .iter()
            .map(|l| Ok(l.to_vec::<f32>()?))
            .collect()
    }
}

/// Extract an f32 vector from one literal output.
pub fn literal_f32(l: &xla::Literal) -> anyhow::Result<Vec<f32>> {
    Ok(l.to_vec::<f32>()?)
}

/// Extract a u32 vector from one literal output.
pub fn literal_u32(l: &xla::Literal) -> anyhow::Result<Vec<u32>> {
    Ok(l.to_vec::<u32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn runtime() -> Option<Runtime> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(Runtime::new(dir).unwrap())
    }

    #[test]
    fn plain_agg_executes() {
        let Some(rt) = runtime() else { return };
        let n = rt.manifest.agg_clients;
        let b = rt.manifest.plain_block;
        let xs: Vec<f32> = (0..n * b).map(|i| (i % 7) as f32).collect();
        let mut w = vec![0.0f32; n];
        w[0] = 0.5;
        w[1] = 0.5;
        let out = rt
            .execute_f32(
                "plain_agg",
                &[
                    Arg::F32(&xs, vec![n as i64, b as i64]),
                    Arg::F32(&w, vec![n as i64]),
                ],
            )
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].len(), b);
        for j in 0..16 {
            let expected = 0.5 * ((j % 7) as f32) + 0.5 * (((b + j) % 7) as f32);
            assert!((out[0][j] - expected).abs() < 1e-6);
        }
    }

    #[test]
    fn train_step_executes_and_learns() {
        let Some(rt) = runtime() else { return };
        let batch = rt.manifest.train_batch;
        let mut params = rt.manifest.load_init_params("mlp").unwrap();
        // deterministic synthetic batch: class = argmax of 10 pixel groups
        let mut x = vec![0.0f32; batch * 784];
        let mut y = vec![0i32; batch];
        for i in 0..batch {
            let c = (i % 10) as usize;
            y[i] = c as i32;
            for j in 0..78 {
                x[i * 784 + c * 78 + j] = 1.0;
            }
        }
        let mut losses = Vec::new();
        for _ in 0..10 {
            let out = rt
                .execute(
                    "mlp_train",
                    &[
                        Arg::F32(&params, vec![params.len() as i64]),
                        Arg::F32(&x, vec![batch as i64, 784]),
                        Arg::I32(&y, vec![batch as i64]),
                        Arg::ScalarF32(0.5),
                    ],
                )
                .unwrap();
            params = out[0].to_vec::<f32>().unwrap();
            losses.push(out[1].to_vec::<f32>().unwrap()[0]);
        }
        assert!(
            losses.last().unwrap() < &(losses[0] * 0.5),
            "losses {losses:?}"
        );
    }

    #[test]
    fn unknown_graph_is_error() {
        let Some(rt) = runtime() else { return };
        assert!(rt.execute("nope", &[]).is_err());
    }

    #[test]
    fn arg_count_checked() {
        let Some(rt) = runtime() else { return };
        assert!(rt.execute("plain_agg", &[]).is_err());
    }
}
