//! Artifact manifest: the contract emitted by `python/compile/aot.py`.
//!
//! `manifest.json` records, for every lowered graph, the HLO file and the
//! argument shapes/dtypes; plus the crypto context and model metadata. The
//! runtime validates the crypto context against the Rust-side parameters at
//! load time (the cross-language consistency gate).

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One argument of a lowered graph.
#[derive(Debug, Clone, PartialEq)]
pub struct ArgSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One lowered graph.
#[derive(Debug, Clone)]
pub struct GraphSpec {
    pub file: String,
    pub args: Vec<ArgSpec>,
}

/// Model metadata recorded by the AOT pipeline.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub param_count: usize,
    pub input_shape: Vec<usize>,
    pub num_classes: usize,
    pub seq_len: Option<usize>,
    pub vocab: Option<usize>,
}

/// Crypto context as recorded in the manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct CryptoMeta {
    pub n: usize,
    pub num_limbs: usize,
    pub scaling_bits: u32,
    pub weight_bits: u32,
    pub moduli: Vec<u64>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub crypto: CryptoMeta,
    pub agg_clients: usize,
    pub agg_chunk: usize,
    pub plain_block: usize,
    pub train_batch: usize,
    pub sens_batch: usize,
    pub graphs: BTreeMap<String, GraphSpec>,
    pub models: BTreeMap<String, ModelMeta>,
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| anyhow::anyhow!("cannot read manifest in {dir:?}: {e} (run `make artifacts`)"))?;
        let root = Json::parse(&text)?;

        let crypto_j = root
            .get("crypto")
            .ok_or_else(|| anyhow::anyhow!("manifest missing crypto"))?;
        let crypto = CryptoMeta {
            n: field_usize(crypto_j, "n")?,
            num_limbs: field_usize(crypto_j, "num_limbs")?,
            scaling_bits: field_usize(crypto_j, "scaling_bits")? as u32,
            weight_bits: field_usize(crypto_j, "weight_bits")? as u32,
            moduli: crypto_j
                .get("moduli")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow::anyhow!("manifest missing moduli"))?
                .iter()
                .filter_map(Json::as_u64)
                .collect(),
        };

        let mut graphs = BTreeMap::new();
        for (name, g) in root
            .get("graphs")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow::anyhow!("manifest missing graphs"))?
        {
            let args = g
                .get("args")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow::anyhow!("graph {name} missing args"))?
                .iter()
                .map(|a| {
                    Ok(ArgSpec {
                        shape: a
                            .get("shape")
                            .and_then(Json::as_arr)
                            .ok_or_else(|| anyhow::anyhow!("bad arg shape"))?
                            .iter()
                            .filter_map(Json::as_usize)
                            .collect(),
                        dtype: a
                            .get("dtype")
                            .and_then(Json::as_str)
                            .unwrap_or("float32")
                            .to_string(),
                    })
                })
                .collect::<anyhow::Result<Vec<_>>>()?;
            graphs.insert(
                name.clone(),
                GraphSpec {
                    file: g
                        .get("file")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow::anyhow!("graph {name} missing file"))?
                        .to_string(),
                    args,
                },
            );
        }

        let mut models = BTreeMap::new();
        if let Some(ms) = root.get("models").and_then(Json::as_obj) {
            for (name, m) in ms {
                models.insert(
                    name.clone(),
                    ModelMeta {
                        param_count: field_usize(m, "param_count")?,
                        input_shape: m
                            .get("input_shape")
                            .and_then(Json::as_arr)
                            .map(|a| a.iter().filter_map(Json::as_usize).collect())
                            .unwrap_or_default(),
                        num_classes: field_usize(m, "num_classes")?,
                        seq_len: m.get("seq_len").and_then(Json::as_usize),
                        vocab: m.get("vocab").and_then(Json::as_usize),
                    },
                );
            }
        }

        Ok(Manifest {
            dir,
            crypto,
            agg_clients: field_usize(&root, "agg_clients")?,
            agg_chunk: field_usize(&root, "agg_chunk")?,
            plain_block: field_usize(&root, "plain_block")?,
            train_batch: field_usize(&root, "train_batch")?,
            sens_batch: field_usize(&root, "sens_batch")?,
            graphs,
            models,
        })
    }

    /// Check the manifest's crypto context against a Rust parameter set.
    pub fn validate_crypto(&self, params: &crate::ckks::CkksParams) -> anyhow::Result<()> {
        anyhow::ensure!(self.crypto.n == params.n, "ring degree mismatch");
        anyhow::ensure!(
            self.crypto.moduli == params.moduli,
            "RNS moduli mismatch between artifact and Rust substrate"
        );
        anyhow::ensure!(
            self.crypto.weight_bits == crate::ckks::params::WEIGHT_BITS,
            "weight scale mismatch"
        );
        Ok(())
    }

    /// Path of a graph's HLO file.
    pub fn hlo_path(&self, graph: &str) -> anyhow::Result<PathBuf> {
        let g = self
            .graphs
            .get(graph)
            .ok_or_else(|| anyhow::anyhow!("graph '{graph}' not in manifest"))?;
        Ok(self.dir.join(&g.file))
    }

    /// Load the deterministic initial parameters for a model.
    pub fn load_init_params(&self, model: &str) -> anyhow::Result<Vec<f32>> {
        let meta = self
            .models
            .get(model)
            .ok_or_else(|| anyhow::anyhow!("model '{model}' not in manifest"))?;
        let path = self.dir.join("init").join(format!("{model}.f32"));
        let bytes = std::fs::read(&path)?;
        anyhow::ensure!(bytes.len() == 4 * meta.param_count, "bad init file size");
        Ok(bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
            .collect())
    }
}

fn field_usize(j: &Json, key: &str) -> anyhow::Result<usize> {
    j.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow::anyhow!("manifest missing field '{key}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_real_manifest() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.crypto.n, 8192);
        assert_eq!(m.crypto.moduli.len(), 4);
        assert!(m.graphs.contains_key("he_agg"));
        assert!(m.graphs.contains_key("lenet_train"));
        // moduli agree with the Rust scan
        let params = crate::ckks::CkksParams::new(8192, 4, 52).unwrap();
        m.validate_crypto(&params).unwrap();
        // init params load
        let init = m.load_init_params("mlp").unwrap();
        assert_eq!(init.len(), 79510);
    }

    #[test]
    fn missing_manifest_is_an_error() {
        let err = Manifest::load("/nonexistent-dir").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
