//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! This is the only boundary between the Rust coordinator and the
//! JAX/Pallas-authored compute graphs; Python never runs here.

pub mod artifact;
pub mod executor;

pub use artifact::Manifest;
pub use executor::Runtime;
