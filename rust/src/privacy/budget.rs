//! Privacy-budget analysis of §3.3: the differential-privacy cost of the
//! three protection schemes.
//!
//! With per-parameter sensitivities Δf_i and a Laplace scale b, releasing an
//! unencrypted parameter i costs ε_i = Δf_i / b (Lemma 3.8); encrypted
//! parameters cost 0 (Theorem 3.9). Sequential composition (Lemma 3.10)
//! sums the costs:
//!
//! * all-DP (no encryption):      J           = Σ_i Δf_i / b   (Remark 3.12)
//! * random p-fraction encrypted: (1 − p)·J   in expectation   (Remark 3.13)
//! * top-p sensitive encrypted:   ≈ (1 − p)²·J under Δf ~ U(0,1) (Remark 3.14)

use crate::he_agg::EncryptionMask;

/// Total budget J = Σ Δf_i / b (Remark 3.12).
pub fn budget_full_dp(sensitivities: &[f32], b: f64) -> f64 {
    assert!(b > 0.0);
    sensitivities.iter().map(|&s| s as f64 / b).sum()
}

/// Empirical budget of an arbitrary mask: Σ over *unencrypted* i of Δf_i/b
/// (Theorem 3.11). Sums over the mask-complement runs — no dense view.
pub fn budget_with_mask(sensitivities: &[f32], mask: &EncryptionMask, b: f64) -> f64 {
    assert!(b > 0.0);
    assert_eq!(sensitivities.len(), mask.total());
    mask.plaintext_layout()
        .runs()
        .iter()
        .flat_map(|r| sensitivities[r.lo..r.hi].iter())
        .map(|&s| s as f64 / b)
        .sum()
}

/// Analytic expectations under Δf ~ U(0,1) (the Remarks' closed forms).
pub fn expected_budgets(n: usize, p: f64, b: f64) -> (f64, f64, f64) {
    let j = n as f64 * 0.5 / b;
    (j, (1.0 - p) * j, (1.0 - p) * (1.0 - p) * j)
}

/// The headline observation: selective encryption needs (1−p)× less budget
/// than random selection at the same ratio.
pub fn selective_advantage(sensitivities: &[f32], p: f64, b: f64) -> f64 {
    let selective = budget_with_mask(
        sensitivities,
        &EncryptionMask::top_p(sensitivities, p),
        b,
    );
    let j = budget_full_dp(sensitivities, b);
    let random_expected = (1.0 - p) * j;
    if selective == 0.0 {
        f64::INFINITY
    } else {
        random_expected / selective
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::prng::ChaChaRng;

    fn uniform_sens(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = ChaChaRng::from_seed(seed, 0);
        (0..n).map(|_| rng.uniform_f64() as f32).collect()
    }

    #[test]
    fn remark_3_12_full_dp() {
        let s = uniform_sens(100_000, 1);
        let j = budget_full_dp(&s, 1.0);
        // E[J] = n/2 under U(0,1)
        assert!((j - 50_000.0).abs() < 500.0, "J = {j}");
    }

    #[test]
    fn remark_3_13_random_selection() {
        let s = uniform_sens(100_000, 2);
        let j = budget_full_dp(&s, 1.0);
        let mut rng = ChaChaRng::from_seed(7, 0);
        for p in [0.1, 0.3, 0.7] {
            let m = EncryptionMask::random(s.len(), p, &mut rng);
            let eps = budget_with_mask(&s, &m, 1.0);
            let expected = (1.0 - p) * j;
            assert!(
                (eps - expected).abs() / expected < 0.02,
                "p={p}: {eps} vs {expected}"
            );
        }
    }

    #[test]
    fn remark_3_14_selective_selection() {
        let s = uniform_sens(100_000, 3);
        let j = budget_full_dp(&s, 1.0);
        for p in [0.1, 0.3, 0.7] {
            let m = EncryptionMask::top_p(&s, p);
            let eps = budget_with_mask(&s, &m, 1.0);
            // remaining parameters are the (1-p) least sensitive: under
            // U(0,1) their mean is (1-p)/2 ⇒ ε = (1-p)^2 · J
            let expected = (1.0 - p) * (1.0 - p) * j;
            assert!(
                (eps - expected).abs() / expected.max(1.0) < 0.03,
                "p={p}: {eps} vs {expected}"
            );
        }
    }

    #[test]
    fn analytic_matches_empirical() {
        let n = 200_000;
        let s = uniform_sens(n, 4);
        let (j, rand, sel) = expected_budgets(n, 0.3, 2.0);
        assert!((budget_full_dp(&s, 2.0) - j).abs() / j < 0.01);
        let m = EncryptionMask::top_p(&s, 0.3);
        assert!((budget_with_mask(&s, &m, 2.0) - sel).abs() / sel < 0.03);
        assert!(rand > sel);
    }

    #[test]
    fn advantage_is_one_over_one_minus_p() {
        let s = uniform_sens(100_000, 5);
        for p in [0.1, 0.5] {
            let adv = selective_advantage(&s, p, 1.0);
            let expected = 1.0 / (1.0 - p);
            assert!((adv - expected).abs() / expected < 0.05, "p={p}: {adv}");
        }
    }

    #[test]
    fn full_encryption_costs_zero() {
        let s = uniform_sens(1000, 6);
        let eps = budget_with_mask(&s, &EncryptionMask::full(1000), 1.0);
        assert_eq!(eps, 0.0);
    }
}
