//! Privacy accounting (§3 of the paper).

pub mod budget;
