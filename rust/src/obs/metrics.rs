//! Global metrics registry: a fixed set of counters, gauges and histograms
//! over static atomics.
//!
//! Everything here is wait-free and allocation-free on the record side —
//! one `Relaxed` `fetch_add`/`fetch_max` per event — so the transport frame
//! loop, the streaming-intake admission path and the CKKS kernels can
//! record unconditionally without violating the `tests/zero_alloc.rs`
//! steady-state gates or perturbing the deterministic RNG streams.
//! Snapshotting ([`snapshot`]) allocates (it builds a [`Json`] tree) and is
//! only called from exporters, the stats ticker and the STATS frame
//! handler.
//!
//! Counter totals are exact: recording uses `fetch_add`, so concurrent
//! recorders never lose increments (gated by the serial-oracle test in
//! `tests/obs.rs`). A snapshot taken while recorders are live is a
//! near-point-in-time view — individual counters are exact totals at their
//! read instant, but the set is not read atomically as a group.

use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};

/// Wire frame-kind ids this registry tracks (index 0 is "unknown"; ids
/// mirror `transport::FrameKind as u32`). Kept in lockstep with the
/// transport enum by a consistency test — `obs` itself stays
/// transport-free.
pub const N_FRAME_KINDS: usize = 15;

/// Human names for the tracked frame kinds, indexed by wire id.
pub const FRAME_KIND_NAMES: [&str; N_FRAME_KINDS] = [
    "unknown",
    "begin",
    "ct_chunk",
    "plain",
    "end",
    "ack",
    "hello",
    "welcome",
    "mask",
    "down_begin",
    "down_end",
    "stats",
    "stats_reply",
    "challenge",
    "challenge_resp",
];

/// Log₂-bucketed latency histogram (nanoseconds): bucket `i` counts samples
/// in `[2^i, 2^{i+1})` ns, so 40 buckets span 1 ns to ~18 minutes.
pub const HIST_BUCKETS: usize = 40;

/// Most intake shards the reactor hub will run (and the fixed width of the
/// per-shard session counters exported as `hub_shard_sessions`).
pub const MAX_HUB_SHARDS: usize = 16;

#[allow(clippy::declare_interior_mutable_const)]
const ZERO: AtomicU64 = AtomicU64::new(0);

/// Frames/bytes in one direction, indexed by wire kind id.
struct FrameDir {
    frames: [AtomicU64; N_FRAME_KINDS],
    bytes: [AtomicU64; N_FRAME_KINDS],
}

impl FrameDir {
    const fn new() -> Self {
        FrameDir { frames: [ZERO; N_FRAME_KINDS], bytes: [ZERO; N_FRAME_KINDS] }
    }

    fn record(&self, kind_id: u32, wire_bytes: u64) {
        let idx = (kind_id as usize).min(N_FRAME_KINDS - 1);
        let idx = if kind_id as usize >= N_FRAME_KINDS { 0 } else { idx };
        self.frames[idx].fetch_add(1, Ordering::Relaxed);
        self.bytes[idx].fetch_add(wire_bytes, Ordering::Relaxed);
    }

    fn to_json(&self) -> (Json, Json) {
        let frames = Json::Obj(
            FRAME_KIND_NAMES
                .iter()
                .enumerate()
                .map(|(i, name)| {
                    (name.to_string(), self.frames[i].load(Ordering::Relaxed).into())
                })
                .collect(),
        );
        let bytes = Json::Obj(
            FRAME_KIND_NAMES
                .iter()
                .enumerate()
                .map(|(i, name)| {
                    (name.to_string(), self.bytes[i].load(Ordering::Relaxed).into())
                })
                .collect(),
        );
        (frames, bytes)
    }

    fn reset(&self) {
        for c in self.frames.iter().chain(self.bytes.iter()) {
            c.store(0, Ordering::Relaxed);
        }
    }

    fn total_frames(&self) -> u64 {
        self.frames.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    fn total_bytes(&self) -> u64 {
        self.bytes.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }
}

/// A gauge with a high-water mark (used for the intake queue depth).
struct Gauge {
    value: AtomicU64,
    peak: AtomicU64,
}

impl Gauge {
    const fn new() -> Self {
        Gauge { value: AtomicU64::new(0), peak: AtomicU64::new(0) }
    }

    fn add(&self, n: u64) {
        let v = self.value.fetch_add(n, Ordering::Relaxed) + n;
        self.peak.fetch_max(v, Ordering::Relaxed);
    }

    fn sub(&self, n: u64) {
        // saturating: a missed add (process restart mid-round) must not wrap
        let _ = self
            .value
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                Some(v.saturating_sub(n))
            });
    }
}

/// Fixed-bucket log₂ histogram over nanosecond samples.
struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
}

impl Histogram {
    const fn new() -> Self {
        Histogram {
            buckets: [ZERO; HIST_BUCKETS],
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }

    fn record_ns(&self, ns: u64) {
        let idx = (63 - ns.max(1).leading_zeros() as usize).min(HIST_BUCKETS - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    fn to_json(&self) -> Json {
        let count = self.count.load(Ordering::Relaxed);
        let sum_ns = self.sum_ns.load(Ordering::Relaxed);
        let buckets: Vec<Json> = self
            .buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed).into())
            .collect();
        Json::obj(vec![
            ("count", count.into()),
            ("sum_secs", (sum_ns as f64 * 1e-9).into()),
            ("max_secs", (self.max_ns.load(Ordering::Relaxed) as f64 * 1e-9).into()),
            (
                "mean_secs",
                (if count == 0 { 0.0 } else { sum_ns as f64 * 1e-9 / count as f64 }).into(),
            ),
            ("log2_ns_buckets", Json::Arr(buckets)),
        ])
    }

    fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum_ns.store(0, Ordering::Relaxed);
        self.max_ns.store(0, Ordering::Relaxed);
    }
}

struct Registry {
    sent: FrameDir,
    received: FrameDir,
    crc_rejects: AtomicU64,
    frame_rejects: AtomicU64,
    auth_rejects: AtomicU64,
    replay_rejects: AtomicU64,
    chaos_injected: AtomicU64,
    straggler_drops: AtomicU64,
    rejoins: AtomicU64,
    scratch_pool_hits: AtomicU64,
    scratch_pool_misses: AtomicU64,
    ntt_forward: AtomicU64,
    ntt_inverse: AtomicU64,
    ntt_kernel_avx2: AtomicU64,
    ntt_kernel_scalar: AtomicU64,
    pack_slots_used: AtomicU64,
    pack_slots_total: AtomicU64,
    ct_seed_expansions: AtomicU64,
    uplink_bytes_saved: AtomicU64,
    intake_offered: AtomicU64,
    intake_queue: Gauge,
    session_rtt: Histogram,
    hub_wakeups: AtomicU64,
    hub_partial_reads: AtomicU64,
    hub_sessions: Gauge,
    hub_shard_sessions: [AtomicU64; MAX_HUB_SHARDS],
    hub_write_queue: Gauge,
}

static REGISTRY: Registry = Registry {
    sent: FrameDir::new(),
    received: FrameDir::new(),
    crc_rejects: AtomicU64::new(0),
    frame_rejects: AtomicU64::new(0),
    auth_rejects: AtomicU64::new(0),
    replay_rejects: AtomicU64::new(0),
    chaos_injected: AtomicU64::new(0),
    straggler_drops: AtomicU64::new(0),
    rejoins: AtomicU64::new(0),
    scratch_pool_hits: AtomicU64::new(0),
    scratch_pool_misses: AtomicU64::new(0),
    ntt_forward: AtomicU64::new(0),
    ntt_inverse: AtomicU64::new(0),
    ntt_kernel_avx2: AtomicU64::new(0),
    ntt_kernel_scalar: AtomicU64::new(0),
    pack_slots_used: AtomicU64::new(0),
    pack_slots_total: AtomicU64::new(0),
    ct_seed_expansions: AtomicU64::new(0),
    uplink_bytes_saved: AtomicU64::new(0),
    intake_offered: AtomicU64::new(0),
    intake_queue: Gauge::new(),
    session_rtt: Histogram::new(),
    hub_wakeups: AtomicU64::new(0),
    hub_partial_reads: AtomicU64::new(0),
    hub_sessions: Gauge::new(),
    hub_shard_sessions: [ZERO; MAX_HUB_SHARDS],
    hub_write_queue: Gauge::new(),
};

/// One frame put on the wire (`kind_id` = `FrameKind as u32`).
#[inline]
pub fn frame_sent(kind_id: u32, wire_bytes: u64) {
    REGISTRY.sent.record(kind_id, wire_bytes);
}

/// One validated frame read off the wire.
#[inline]
pub fn frame_received(kind_id: u32, wire_bytes: u64) {
    REGISTRY.received.record(kind_id, wire_bytes);
}

/// A frame rejected by the payload CRC check.
#[inline]
pub fn crc_reject() {
    REGISTRY.crc_rejects.fetch_add(1, Ordering::Relaxed);
    REGISTRY.frame_rejects.fetch_add(1, Ordering::Relaxed);
}

/// A frame rejected before the CRC (bad magic/version/round/kind/length).
#[inline]
pub fn frame_reject() {
    REGISTRY.frame_rejects.fetch_add(1, Ordering::Relaxed);
}

/// An authenticated frame whose MAC tag (or handshake proof) failed to
/// verify — a forgery, corruption, or key/direction confusion.
#[inline]
pub fn auth_reject() {
    REGISTRY.auth_rejects.fetch_add(1, Ordering::Relaxed);
    REGISTRY.frame_rejects.fetch_add(1, Ordering::Relaxed);
}

/// An authenticated frame whose tag verified but whose auth sequence was
/// not strictly monotone — a replayed (or duplicated) frame, discarded.
#[inline]
pub fn replay_reject() {
    REGISTRY.replay_rejects.fetch_add(1, Ordering::Relaxed);
    REGISTRY.frame_rejects.fetch_add(1, Ordering::Relaxed);
}

/// One fault (drop/corrupt/delay/duplicate/disconnect) injected by the
/// deterministic chaos layer (`transport::chaos`).
#[inline]
pub fn chaos_injected() {
    REGISTRY.chaos_injected.fetch_add(1, Ordering::Relaxed);
}

/// Current auth-reject total (test support: assertions use deltas because
/// the registry is process-global and tests run in parallel).
pub fn snapshot_auth_rejects() -> u64 {
    REGISTRY.auth_rejects.load(Ordering::Relaxed)
}

/// Current replay-reject total (test support, delta-based like
/// [`snapshot_auth_rejects`]).
pub fn snapshot_replay_rejects() -> u64 {
    REGISTRY.replay_rejects.load(Ordering::Relaxed)
}

/// Current chaos-injection total (test support, delta-based like
/// [`snapshot_auth_rejects`]).
pub fn snapshot_chaos_injected() -> u64 {
    REGISTRY.chaos_injected.load(Ordering::Relaxed)
}

/// `n` uploads dropped by the quorum/straggler cutoff.
#[inline]
pub fn straggler_drops(n: u64) {
    REGISTRY.straggler_drops.fetch_add(n, Ordering::Relaxed);
}

/// A HELLO that replaced a registered session (disconnect → rejoin).
#[inline]
pub fn rejoin() {
    REGISTRY.rejoins.fetch_add(1, Ordering::Relaxed);
}

/// One pooled-scratch kernel call; `hit` = every staging buffer was already
/// at capacity (the steady state `tests/zero_alloc.rs` gates).
#[inline]
pub fn scratch_pool(hit: bool) {
    if hit {
        REGISTRY.scratch_pool_hits.fetch_add(1, Ordering::Relaxed);
    } else {
        REGISTRY.scratch_pool_misses.fetch_add(1, Ordering::Relaxed);
    }
}

/// One forward NTT over a limb.
#[inline]
pub fn ntt_forward() {
    REGISTRY.ntt_forward.fetch_add(1, Ordering::Relaxed);
}

/// One inverse NTT over a limb.
#[inline]
pub fn ntt_inverse() {
    REGISTRY.ntt_inverse.fetch_add(1, Ordering::Relaxed);
}

/// One NTT dispatch through the active butterfly kernel; `simd` = a
/// vectorized kernel (AVX2) was selected, else the portable scalar path
/// (see `ckks::simd::active` and the `FEDML_HE_NTT_KERNEL` override).
#[inline]
pub fn ntt_kernel(simd: bool) {
    if simd {
        REGISTRY.ntt_kernel_avx2.fetch_add(1, Ordering::Relaxed);
    } else {
        REGISTRY.ntt_kernel_scalar.fetch_add(1, Ordering::Relaxed);
    }
}

/// One packing plan cut by the selective codec: `used` slots carry masked
/// values out of `total` allocated CKKS slots (`n_cts · batch`). The
/// snapshot derives the run-aware slot-utilization gauge from the running
/// totals.
#[inline]
pub fn pack_slots(used: u64, total: u64) {
    REGISTRY.pack_slots_used.fetch_add(used, Ordering::Relaxed);
    REGISTRY.pack_slots_total.fetch_add(total, Ordering::Relaxed);
}

/// One limb of a seeded ciphertext's a-part expanded from its 32-byte seed
/// (client-side at encrypt, or lazily inside an aggregation shard).
#[inline]
pub fn ct_seed_expansion() {
    REGISTRY.ct_seed_expansions.fetch_add(1, Ordering::Relaxed);
}

/// Encrypted uplink bytes the seed-expanded ct wire saved versus shipping
/// the same ciphertext dense (counted where compressed shards are built).
#[inline]
pub fn uplink_bytes_saved(n: u64) {
    REGISTRY.uplink_bytes_saved.fetch_add(n, Ordering::Relaxed);
}

/// An arrival admitted to the streaming intake (queue depth +1).
#[inline]
pub fn intake_enqueued() {
    REGISTRY.intake_offered.fetch_add(1, Ordering::Relaxed);
    REGISTRY.intake_queue.add(1);
}

/// `n` queued arrivals drained by a round seal (queue depth −n).
#[inline]
pub fn intake_drained(n: u64) {
    REGISTRY.intake_queue.sub(n);
}

/// A parked reactor loop woken through its eventfd (command enqueued,
/// upload settled, shutdown) rather than by socket readiness.
#[inline]
pub fn hub_wakeup() {
    REGISTRY.hub_wakeups.fetch_add(1, Ordering::Relaxed);
}

/// A session frame decoder suspended mid-frame by a short read and resumed
/// on a later readiness event (the partial-read boundary the reactor's
/// state machines must survive; chaos leans on this path hard).
#[inline]
pub fn hub_partial_read() {
    REGISTRY.hub_partial_reads.fetch_add(1, Ordering::Relaxed);
}

/// A connection adopted by reactor shard `shard` (active sessions +1).
#[inline]
pub fn hub_session_opened(shard: usize) {
    REGISTRY.hub_sessions.add(1);
    REGISTRY.hub_shard_sessions[shard.min(MAX_HUB_SHARDS - 1)]
        .fetch_add(1, Ordering::Relaxed);
}

/// A connection closed/evicted on reactor shard `shard` (sessions −1).
#[inline]
pub fn hub_session_closed(shard: usize) {
    REGISTRY.hub_sessions.sub(1);
    let slot = &REGISTRY.hub_shard_sessions[shard.min(MAX_HUB_SHARDS - 1)];
    let _ = slot.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
        Some(v.saturating_sub(1))
    });
}

/// `n` bytes queued onto a shard's downlink write queue.
#[inline]
pub fn hub_write_enqueued(n: u64) {
    REGISTRY.hub_write_queue.add(n);
}

/// `n` queued downlink bytes flushed to (or abandoned with) a socket.
#[inline]
pub fn hub_write_flushed(n: u64) {
    REGISTRY.hub_write_queue.sub(n);
}

/// One measured session round trip (client END→ACK).
#[inline]
pub fn session_rtt_secs(secs: f64) {
    if secs.is_finite() && secs >= 0.0 {
        REGISTRY.session_rtt.record_ns((secs * 1e9) as u64);
    }
}

/// Run-aware packing slot utilization over every plan cut so far
/// (`used / total`; 0.0 before the first plan).
fn pack_slot_utilization() -> f64 {
    let used = REGISTRY.pack_slots_used.load(Ordering::Relaxed);
    let total = REGISTRY.pack_slots_total.load(Ordering::Relaxed);
    if total == 0 {
        0.0
    } else {
        used as f64 / total as f64
    }
}

/// Point-in-time JSON view of every metric (stable key set — the
/// `--report-json` schema and the STATS frame payload).
pub fn snapshot() -> Json {
    let (sent_frames, sent_bytes) = REGISTRY.sent.to_json();
    let (recv_frames, recv_bytes) = REGISTRY.received.to_json();
    let (spans_recorded, spans_dropped) = super::trace::stats();
    Json::obj(vec![
        ("frames_sent", sent_frames),
        ("bytes_sent", sent_bytes),
        ("frames_received", recv_frames),
        ("bytes_received", recv_bytes),
        ("crc_rejects", REGISTRY.crc_rejects.load(Ordering::Relaxed).into()),
        ("frame_rejects", REGISTRY.frame_rejects.load(Ordering::Relaxed).into()),
        ("auth_rejects", REGISTRY.auth_rejects.load(Ordering::Relaxed).into()),
        (
            "replay_rejects",
            REGISTRY.replay_rejects.load(Ordering::Relaxed).into(),
        ),
        (
            "chaos_injected",
            REGISTRY.chaos_injected.load(Ordering::Relaxed).into(),
        ),
        (
            "straggler_drops",
            REGISTRY.straggler_drops.load(Ordering::Relaxed).into(),
        ),
        ("rejoins", REGISTRY.rejoins.load(Ordering::Relaxed).into()),
        (
            "scratch_pool_hits",
            REGISTRY.scratch_pool_hits.load(Ordering::Relaxed).into(),
        ),
        (
            "scratch_pool_misses",
            REGISTRY.scratch_pool_misses.load(Ordering::Relaxed).into(),
        ),
        ("ntt_forward", REGISTRY.ntt_forward.load(Ordering::Relaxed).into()),
        ("ntt_inverse", REGISTRY.ntt_inverse.load(Ordering::Relaxed).into()),
        (
            "ntt_kernel_avx2",
            REGISTRY.ntt_kernel_avx2.load(Ordering::Relaxed).into(),
        ),
        (
            "ntt_kernel_scalar",
            REGISTRY.ntt_kernel_scalar.load(Ordering::Relaxed).into(),
        ),
        (
            "pack_slots_used",
            REGISTRY.pack_slots_used.load(Ordering::Relaxed).into(),
        ),
        (
            "pack_slots_total",
            REGISTRY.pack_slots_total.load(Ordering::Relaxed).into(),
        ),
        ("pack_slot_utilization", pack_slot_utilization().into()),
        (
            "ct_seed_expansions",
            REGISTRY.ct_seed_expansions.load(Ordering::Relaxed).into(),
        ),
        (
            "uplink_bytes_saved",
            REGISTRY.uplink_bytes_saved.load(Ordering::Relaxed).into(),
        ),
        (
            "intake_offered",
            REGISTRY.intake_offered.load(Ordering::Relaxed).into(),
        ),
        (
            "intake_queue_depth",
            REGISTRY.intake_queue.value.load(Ordering::Relaxed).into(),
        ),
        (
            "intake_queue_peak",
            REGISTRY.intake_queue.peak.load(Ordering::Relaxed).into(),
        ),
        ("session_rtt", REGISTRY.session_rtt.to_json()),
        ("hub_wakeups", REGISTRY.hub_wakeups.load(Ordering::Relaxed).into()),
        (
            "hub_partial_reads",
            REGISTRY.hub_partial_reads.load(Ordering::Relaxed).into(),
        ),
        (
            "hub_active_sessions",
            REGISTRY.hub_sessions.value.load(Ordering::Relaxed).into(),
        ),
        (
            "hub_sessions_peak",
            REGISTRY.hub_sessions.peak.load(Ordering::Relaxed).into(),
        ),
        (
            "hub_shard_sessions",
            Json::Arr(
                REGISTRY
                    .hub_shard_sessions
                    .iter()
                    .map(|c| c.load(Ordering::Relaxed).into())
                    .collect(),
            ),
        ),
        (
            "hub_write_queue_depth",
            REGISTRY.hub_write_queue.value.load(Ordering::Relaxed).into(),
        ),
        (
            "hub_write_queue_peak",
            REGISTRY.hub_write_queue.peak.load(Ordering::Relaxed).into(),
        ),
        ("spans_recorded", spans_recorded.into()),
        ("spans_dropped", spans_dropped.into()),
    ])
}

/// One-line human summary (the periodic `serve` stderr ticker).
pub fn summary_line() -> String {
    format!(
        "rx {} frames / {} · tx {} frames / {} · rejects {} (crc {} auth {} replay {}) · \
         stragglers {} · rejoins {} · ntt {} · intake q {} (peak {}) · rtt n={}",
        REGISTRY.received.total_frames(),
        crate::util::human_bytes(REGISTRY.received.total_bytes()),
        REGISTRY.sent.total_frames(),
        crate::util::human_bytes(REGISTRY.sent.total_bytes()),
        REGISTRY.frame_rejects.load(Ordering::Relaxed),
        REGISTRY.crc_rejects.load(Ordering::Relaxed),
        REGISTRY.auth_rejects.load(Ordering::Relaxed),
        REGISTRY.replay_rejects.load(Ordering::Relaxed),
        REGISTRY.straggler_drops.load(Ordering::Relaxed),
        REGISTRY.rejoins.load(Ordering::Relaxed),
        REGISTRY.ntt_forward.load(Ordering::Relaxed)
            + REGISTRY.ntt_inverse.load(Ordering::Relaxed),
        REGISTRY.intake_queue.value.load(Ordering::Relaxed),
        REGISTRY.intake_queue.peak.load(Ordering::Relaxed),
        REGISTRY.session_rtt.count.load(Ordering::Relaxed),
    )
}

/// Zero every metric (test isolation; production never resets).
pub fn reset() {
    REGISTRY.sent.reset();
    REGISTRY.received.reset();
    for c in [
        &REGISTRY.crc_rejects,
        &REGISTRY.frame_rejects,
        &REGISTRY.auth_rejects,
        &REGISTRY.replay_rejects,
        &REGISTRY.chaos_injected,
        &REGISTRY.straggler_drops,
        &REGISTRY.rejoins,
        &REGISTRY.scratch_pool_hits,
        &REGISTRY.scratch_pool_misses,
        &REGISTRY.ntt_forward,
        &REGISTRY.ntt_inverse,
        &REGISTRY.ntt_kernel_avx2,
        &REGISTRY.ntt_kernel_scalar,
        &REGISTRY.pack_slots_used,
        &REGISTRY.pack_slots_total,
        &REGISTRY.ct_seed_expansions,
        &REGISTRY.uplink_bytes_saved,
        &REGISTRY.intake_offered,
        &REGISTRY.intake_queue.value,
        &REGISTRY.intake_queue.peak,
        &REGISTRY.hub_wakeups,
        &REGISTRY.hub_partial_reads,
        &REGISTRY.hub_sessions.value,
        &REGISTRY.hub_sessions.peak,
        &REGISTRY.hub_write_queue.value,
        &REGISTRY.hub_write_queue.peak,
    ] {
        c.store(0, Ordering::Relaxed);
    }
    for c in &REGISTRY.hub_shard_sessions {
        c.store(0, Ordering::Relaxed);
    }
    REGISTRY.session_rtt.reset();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gauge_tracks_peak_and_saturates() {
        let g = Gauge::new();
        g.add(3);
        g.add(2);
        g.sub(4);
        assert_eq!(g.value.load(Ordering::Relaxed), 1);
        assert_eq!(g.peak.load(Ordering::Relaxed), 5);
        g.sub(10); // saturating, never wraps
        assert_eq!(g.value.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn histogram_buckets_by_log2() {
        let h = Histogram::new();
        h.record_ns(1);
        h.record_ns(1024);
        h.record_ns(1025);
        h.record_ns(u64::MAX); // clamps into the last bucket
        assert_eq!(h.buckets[0].load(Ordering::Relaxed), 1);
        assert_eq!(h.buckets[10].load(Ordering::Relaxed), 2);
        assert_eq!(h.buckets[HIST_BUCKETS - 1].load(Ordering::Relaxed), 1);
        assert_eq!(h.count.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn unknown_frame_kind_lands_in_slot_zero() {
        let d = FrameDir::new();
        d.record(999, 64);
        assert_eq!(d.frames[0].load(Ordering::Relaxed), 1);
        assert_eq!(d.bytes[0].load(Ordering::Relaxed), 64);
    }
}
