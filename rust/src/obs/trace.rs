//! Span tracer over per-thread lock-free ring buffers.
//!
//! Design constraints (DESIGN.md §10):
//!
//! * **Disabled is free.** Tracing is off unless `--trace-out` was passed.
//!   A disabled [`span`] call is one relaxed atomic load and returns an
//!   inert guard — no clock read, no thread-local touch, no allocation.
//! * **Enabled never reallocates in steady state.** Each thread lazily
//!   registers one pre-allocated ring of [`RING_CAPACITY`] fixed-size
//!   [`SpanRecord`]s on its first span. Recording a finished span writes
//!   one slot and bumps an atomic head; on overflow the oldest records are
//!   overwritten (and counted as dropped), the ring never grows. The first
//!   span on a thread allocates the ring — hot loops that must satisfy the
//!   `tests/zero_alloc.rs` gates pay that once during warm-up, like every
//!   other pooled buffer.
//! * **Single-writer rings.** Only the owning thread writes its ring;
//!   [`drain`] is called after workers quiesce (end of run / test), so the
//!   Release store on `head` paired with the Acquire load in the reader is
//!   enough — no per-slot locks.
//!
//! Span identity is two `&'static str`s (category + name) plus one `u64`
//! argument (chunk index, round number, frame seq…): everything `Copy`, so
//! a record is a plain memcpy into the ring.

use std::cell::{Cell, UnsafeCell};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Spans kept per thread; older spans are overwritten once a thread has
/// recorded more than this many. 16384 records × 64 B ≈ 1 MiB per thread —
/// comfortably holds a multi-round loopback run.
pub const RING_CAPACITY: usize = 16384;

/// One finished span, fixed-size and `Copy`.
#[derive(Debug, Clone, Copy)]
pub struct SpanRecord {
    /// Category: `"coordinator"`, `"codec"`, `"engine"`, `"transport"`.
    pub cat: &'static str,
    /// Span name within the category, e.g. `"phase_collect"`.
    pub name: &'static str,
    /// Start offset from the process trace epoch, nanoseconds.
    pub start_ns: u64,
    /// Span duration, nanoseconds.
    pub dur_ns: u64,
    /// Tracer-assigned thread id (dense, stable per thread).
    pub tid: u64,
    /// Free-form numeric argument (round, chunk index, frame seq…).
    pub arg: u64,
    /// Whether `arg` was set (distinguishes "0" from "none").
    pub has_arg: bool,
    /// Nesting depth on the recording thread at span open (0 = top level).
    pub depth: u32,
}

const EMPTY: SpanRecord = SpanRecord {
    cat: "",
    name: "",
    start_ns: 0,
    dur_ns: 0,
    tid: 0,
    arg: 0,
    has_arg: false,
    depth: 0,
};

/// Per-thread pre-allocated span storage. `head` counts records ever
/// written; slot `head % RING_CAPACITY` is the next write target.
struct Ring {
    tid: u64,
    slots: Box<[UnsafeCell<SpanRecord>]>,
    head: AtomicU64,
}

// SAFETY: slots are written only by the owning thread (via the `RING`
// thread-local); other threads only read, and only via `drain`/`stats`
// after observing `head` with Acquire ordering. A concurrent reader may see
// a torn in-progress slot, but `drain` is documented to run after writer
// threads quiesce, and `stats` reads only the atomic head.
unsafe impl Sync for Ring {}

impl Ring {
    fn new(tid: u64) -> Self {
        Ring {
            tid,
            slots: (0..RING_CAPACITY)
                .map(|_| UnsafeCell::new(EMPTY))
                .collect(),
            head: AtomicU64::new(0),
        }
    }

    /// Owner-thread-only append.
    fn push(&self, mut rec: SpanRecord) {
        rec.tid = self.tid;
        let head = self.head.load(Ordering::Relaxed);
        let slot = self.slots[(head % RING_CAPACITY as u64) as usize].get();
        // SAFETY: single writer (owner thread); readers wait for quiesce.
        unsafe { *slot = rec };
        self.head.store(head + 1, Ordering::Release);
    }
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_TID: AtomicU64 = AtomicU64::new(0);
static EPOCH: OnceLock<Instant> = OnceLock::new();

fn registry() -> &'static Mutex<Vec<Arc<Ring>>> {
    static REGISTRY: OnceLock<Mutex<Vec<Arc<Ring>>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(Vec::new()))
}

thread_local! {
    static RING: Arc<Ring> = {
        let ring = Arc::new(Ring::new(NEXT_TID.fetch_add(1, Ordering::Relaxed)));
        registry().lock().unwrap().push(ring.clone());
        ring
    };
    static DEPTH: Cell<u32> = const { Cell::new(0) };
}

/// Turn the tracer on or off (off by default; `--trace-out` turns it on
/// before the run starts).
pub fn set_enabled(on: bool) {
    if on {
        // Pin the epoch before any span so start offsets are non-negative.
        EPOCH.get_or_init(Instant::now);
    }
    ENABLED.store(on, Ordering::Relaxed);
}

/// Whether spans are currently being recorded.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

fn now_ns() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// RAII span guard: records one [`SpanRecord`] on drop. Inert (all-`None`)
/// when the tracer is disabled at open time.
pub struct Span {
    live: Option<LiveSpan>,
}

struct LiveSpan {
    cat: &'static str,
    name: &'static str,
    start_ns: u64,
    arg: Option<u64>,
    depth: u32,
}

/// Open a span; it closes (and records) when the returned guard drops.
#[inline]
pub fn span(cat: &'static str, name: &'static str) -> Span {
    open(cat, name, None)
}

/// [`span`] with a numeric argument (round, chunk index, frame seq…).
#[inline]
pub fn span_arg(cat: &'static str, name: &'static str, arg: u64) -> Span {
    open(cat, name, Some(arg))
}

#[inline]
fn open(cat: &'static str, name: &'static str, arg: Option<u64>) -> Span {
    if !enabled() {
        return Span { live: None };
    }
    let depth = DEPTH.with(|d| {
        let v = d.get();
        d.set(v + 1);
        v
    });
    Span {
        live: Some(LiveSpan {
            cat,
            name,
            start_ns: now_ns(),
            arg,
            depth,
        }),
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(live) = self.live.take() else { return };
        let end_ns = now_ns();
        DEPTH.with(|d| d.set(d.get().saturating_sub(1)));
        RING.with(|ring| {
            ring.push(SpanRecord {
                cat: live.cat,
                name: live.name,
                start_ns: live.start_ns,
                dur_ns: end_ns.saturating_sub(live.start_ns),
                tid: 0, // assigned by Ring::push
                arg: live.arg.unwrap_or(0),
                has_arg: live.arg.is_some(),
                depth: live.depth,
            })
        });
    }
}

/// Collect every recorded span, oldest-first per thread. Call after worker
/// threads quiesce (end of run); records overwritten by ring overflow are
/// gone (see [`stats`] for the drop count).
pub fn drain() -> Vec<SpanRecord> {
    let rings = registry().lock().unwrap();
    let mut out = Vec::new();
    for ring in rings.iter() {
        let head = ring.head.load(Ordering::Acquire);
        let len = head.min(RING_CAPACITY as u64);
        let start = head - len;
        for i in start..head {
            let slot = ring.slots[(i % RING_CAPACITY as u64) as usize].get();
            // SAFETY: writers have quiesced (drain contract) and `head` was
            // read with Acquire, so every slot below it is fully written.
            out.push(unsafe { *slot });
        }
    }
    out.sort_by_key(|r| r.start_ns);
    out
}

/// `(recorded, dropped)` span totals across all threads. `recorded` is the
/// number of spans still resident in rings; `dropped` were overwritten by
/// ring overflow.
pub fn stats() -> (u64, u64) {
    let rings = registry().lock().unwrap();
    let mut recorded = 0u64;
    let mut dropped = 0u64;
    for ring in rings.iter() {
        let head = ring.head.load(Ordering::Acquire);
        let resident = head.min(RING_CAPACITY as u64);
        recorded += resident;
        dropped += head - resident;
    }
    (recorded, dropped)
}

/// Reset every ring (test isolation). Rings stay registered and allocated;
/// only their heads rewind.
pub fn clear() {
    let rings = registry().lock().unwrap();
    for ring in rings.iter() {
        ring.head.store(0, Ordering::Release);
    }
}
