//! Exporters for the tracing/metrics substrate: chrome://tracing JSON
//! (`--trace-out`), the versioned run report (`--report-json`), and the
//! periodic stderr stats ticker for long `serve` runs.

use super::{metrics, trace};
use crate::util::json::Json;
use std::path::Path;
use std::sync::mpsc;
use std::time::Duration;

/// Schema version stamped into the `--report-json` envelope. Bump on any
/// breaking change to the envelope layout (CI diffs the committed
/// `BENCH_perf.json` / report schemas against freshly generated ones).
/// (v2: NTT kernel-dispatch counters and run-aware packing slot gauges
/// joined the metrics snapshot. v3: wire-auth and chaos counters —
/// `auth_rejects`, `replay_rejects`, `chaos_injected` — joined the
/// snapshot alongside the challenge/challenge_resp frame kinds. v4:
/// reactor-backend hub gauges — `hub_wakeups`, `hub_partial_reads`,
/// `hub_active_sessions`, `hub_sessions_peak`, `hub_shard_sessions`,
/// `hub_write_queue_depth`, `hub_write_queue_peak` — joined the
/// snapshot. v5: seed-expanded ciphertext-wire counters —
/// `ct_seed_expansions`, `uplink_bytes_saved` — joined the snapshot.)
pub const REPORT_SCHEMA_VERSION: u64 = 5;

/// Identifier stamped into the `--report-json` envelope.
pub const REPORT_SCHEMA_NAME: &str = "fedml-he/run-report";

/// Render every drained span as a chrome://tracing "complete" (`ph:"X"`)
/// event. Load the file via `chrome://tracing` or <https://ui.perfetto.dev>.
pub fn chrome_trace_json() -> Json {
    let events: Vec<Json> = trace::drain()
        .into_iter()
        .map(|r| {
            let mut args = vec![("depth", Json::from(u64::from(r.depth)))];
            if r.has_arg {
                args.push(("arg", Json::from(r.arg)));
            }
            Json::obj(vec![
                ("name", r.name.into()),
                ("cat", r.cat.into()),
                ("ph", "X".into()),
                ("ts", (r.start_ns as f64 / 1e3).into()),
                ("dur", (r.dur_ns as f64 / 1e3).into()),
                ("pid", 1u64.into()),
                ("tid", r.tid.into()),
                ("args", Json::obj(args)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", "ms".into()),
    ])
}

/// Drain the tracer and write the chrome-trace file (atomic replace).
pub fn write_chrome_trace(path: &Path) -> anyhow::Result<()> {
    let json = chrome_trace_json();
    let n_events = json
        .get("traceEvents")
        .and_then(Json::as_arr)
        .map_or(0, <[Json]>::len);
    crate::util::write_file_atomic(path, json.to_string().as_bytes())?;
    crate::log_info!("obs", "wrote {} trace events to {}", n_events, path.display());
    Ok(())
}

/// Wrap a run's report (`FlReport::to_json()` or bench output) in the
/// versioned envelope together with the metrics snapshot.
pub fn run_report(report: Json) -> Json {
    let (spans_recorded, spans_dropped) = trace::stats();
    Json::obj(vec![
        ("schema", REPORT_SCHEMA_NAME.into()),
        ("version", REPORT_SCHEMA_VERSION.into()),
        ("report", report),
        ("metrics", metrics::snapshot()),
        (
            "trace",
            Json::obj(vec![
                ("spans_recorded", spans_recorded.into()),
                ("spans_dropped", spans_dropped.into()),
            ]),
        ),
    ])
}

/// Write the enveloped run report (atomic replace).
pub fn write_run_report(path: &Path, report: Json) -> anyhow::Result<()> {
    crate::util::write_file_atomic(path, run_report(report).to_string().as_bytes())?;
    crate::log_info!("obs", "wrote run report to {}", path.display());
    Ok(())
}

/// Periodic one-line stderr stats summary for long `serve` runs. Emits
/// [`metrics::summary_line`] every `period` until dropped.
pub struct StatsTicker {
    stop: mpsc::Sender<()>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl StatsTicker {
    /// Start the ticker thread.
    pub fn start(period: Duration) -> StatsTicker {
        let (stop, rx) = mpsc::channel::<()>();
        let handle = std::thread::Builder::new()
            .name("stats-ticker".into())
            .spawn(move || loop {
                match rx.recv_timeout(period) {
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        crate::log_info!("stats", "{}", metrics::summary_line());
                    }
                    _ => return,
                }
            })
            .expect("spawn stats ticker");
        StatsTicker {
            stop,
            handle: Some(handle),
        }
    }
}

impl Drop for StatsTicker {
    fn drop(&mut self) {
        let _ = self.stop.send(());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
