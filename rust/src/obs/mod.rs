//! Observability substrate: structured tracing + global metrics (DESIGN.md
//! §10).
//!
//! Three pieces, all dependency-free (no crates.io in the offline image) and
//! safe to leave compiled into the hot paths:
//!
//! * [`metrics`] — a fixed global registry of counters/gauges/histograms
//!   (frames and bytes by kind, CRC rejects, straggler drops, rejoins,
//!   scratch-pool hits, NTT invocations, intake queue depth, per-session
//!   RTT). Recording is one relaxed atomic op — no locks, no allocation —
//!   so the `tests/zero_alloc.rs` gates stay green with instrumentation
//!   enabled.
//! * [`trace`] — a span tracer over per-thread lock-free ring buffers.
//!   Disabled (the default) a span costs one atomic load; enabled it writes
//!   one fixed-size record into a pre-allocated per-thread ring (oldest
//!   spans overwritten on overflow, never a reallocation). Spans are
//!   hierarchical: coordinator phases wrap codec chunks wrap frame I/O.
//! * [`export`] — exporters: chrome://tracing JSON (`--trace-out`), the
//!   versioned machine-readable run report (`--report-json`), and the
//!   periodic one-line stderr stats summary for long `serve` runs.
//!
//! The live-query path (STATS frame + `stats` CLI subcommand) lives in
//! [`crate::transport`] — it serializes [`metrics::snapshot`] over the
//! session protocol; this module stays transport-free.

pub mod export;
pub mod metrics;
pub mod trace;

pub use export::{run_report, write_chrome_trace, write_run_report, StatsTicker};
pub use trace::{span, span_arg, Span};
