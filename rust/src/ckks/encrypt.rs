//! RLWE encryption / decryption.
//!
//! `Enc(pk, m)`: sample ephemeral ternary `u` and errors `e0, e1`;
//! `ct = (c0, c1) = (b·u + e0 + m, a·u + e1)`.
//! `Dec(sk, ct)`: `m ≈ c0 + c1·s` (error ≈ e·u + e0 + e1·s, a few bits —
//! negligible against Δ·Δ_w).
//!
//! Ciphertext polynomials are kept in **coefficient domain**: the
//! aggregation pipeline only adds and scalar-multiplies, which are
//! domain-agnostic, and the serialization/kernels operate on raw limbs.

use super::keys::{PublicKey, SecretKey};
use super::params::CkksParams;
use super::poly::RnsPoly;
use crate::crypto::prng::ChaChaRng;

/// A CKKS ciphertext (pair of RNS polynomials, coefficient domain) plus the
/// metadata needed to decode: number of meaningful slots and current scale.
#[derive(Debug, Clone, PartialEq)]
pub struct Ciphertext {
    pub c0: RnsPoly,
    pub c1: RnsPoly,
    /// Number of packed values (≤ n/2).
    pub n_values: usize,
    /// Aggregate scale (Δ fresh; Δ·Δ_w after weighting).
    pub scale: f64,
}

/// Encrypt a coefficient-domain plaintext polynomial.
pub fn encrypt(
    params: &CkksParams,
    pk: &PublicKey,
    pt: &RnsPoly,
    n_values: usize,
    rng: &mut ChaChaRng,
) -> Ciphertext {
    assert!(!pt.ntt_form, "plaintext must be in coefficient domain");
    let mut u = RnsPoly::sample_ternary(params, rng);
    u.to_ntt(params);

    // c0 = b·u (NTT) → coeff + e0 + m
    let mut c0 = pk.b_ntt.mul_ntt(&u, params);
    c0.from_ntt(params);
    let e0 = RnsPoly::sample_error(params, rng);
    c0.add_assign(&e0, params);
    c0.add_assign(pt, params);

    // c1 = a·u (NTT) → coeff + e1
    let mut c1 = pk.a_ntt.mul_ntt(&u, params);
    c1.from_ntt(params);
    let e1 = RnsPoly::sample_error(params, rng);
    c1.add_assign(&e1, params);

    Ciphertext {
        c0,
        c1,
        n_values,
        scale: params.delta(),
    }
}

/// Decrypt to a coefficient-domain plaintext polynomial.
pub fn decrypt(params: &CkksParams, sk: &SecretKey, ct: &Ciphertext) -> RnsPoly {
    let mut c1 = ct.c1.clone();
    c1.to_ntt(params);
    let mut m = c1.mul_ntt(&sk.s_ntt, params);
    m.from_ntt(params);
    m.add_assign(&ct.c0, params);
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckks::encoding::Encoder;
    use crate::ckks::keys::keygen;
    use std::sync::Arc;

    fn setup(n: usize, bits: u32) -> (Arc<CkksParams>, Encoder, PublicKey, SecretKey) {
        let params = Arc::new(CkksParams::new(n, 4, bits).unwrap());
        let encoder = Encoder::new(params.clone());
        let mut rng = ChaChaRng::from_seed(42, 0);
        let (pk, sk) = keygen(&params, &mut rng);
        (params, encoder, pk, sk)
    }

    #[test]
    fn encrypt_decrypt_roundtrip() {
        let (params, encoder, pk, sk) = setup(1024, 40);
        let mut rng = ChaChaRng::from_seed(1, 1);
        let values: Vec<f64> = (0..512).map(|i| (i as f64) * 0.01 - 2.5).collect();
        let pt = encoder.encode(&values);
        let ct = encrypt(&params, &pk, &pt, values.len(), &mut rng);
        let dec_pt = decrypt(&params, &sk, &ct);
        let dec = encoder.decode(&dec_pt, ct.n_values, ct.scale);
        for (a, b) in values.iter().zip(dec.iter()) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn ciphertext_is_not_plaintext() {
        // The ciphertext limbs must look nothing like the encoded message.
        let (params, encoder, pk, _sk) = setup(256, 30);
        let mut rng = ChaChaRng::from_seed(2, 2);
        let values = vec![1.0; 128];
        let pt = encoder.encode(&values);
        let ct = encrypt(&params, &pk, &pt, 128, &mut rng);
        // A fresh encode of the same values differs wildly from c0.
        let diff_count = pt.limbs[0]
            .iter()
            .zip(ct.c0.limbs[0].iter())
            .filter(|(a, b)| a != b)
            .count();
        assert!(diff_count > 250, "c0 leaks plaintext structure");
    }

    #[test]
    fn decrypt_with_wrong_key_fails() {
        let (params, encoder, pk, _sk) = setup(256, 30);
        let mut rng = ChaChaRng::from_seed(3, 3);
        let values = vec![0.5; 128];
        let pt = encoder.encode(&values);
        let ct = encrypt(&params, &pk, &pt, 128, &mut rng);
        let (_pk2, sk2) = keygen(&params, &mut rng);
        let dec_pt = decrypt(&params, &sk2, &ct);
        let dec = encoder.decode(&dec_pt, 128, ct.scale);
        let max_err = values
            .iter()
            .zip(dec.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        assert!(max_err > 1.0, "wrong key should not decrypt (err {max_err})");
    }

    #[test]
    fn homomorphic_addition() {
        let (params, encoder, pk, sk) = setup(512, 40);
        let mut rng = ChaChaRng::from_seed(4, 4);
        let a: Vec<f64> = (0..256).map(|i| i as f64 * 0.01).collect();
        let b: Vec<f64> = (0..256).map(|i| 3.0 - i as f64 * 0.02).collect();
        let mut ca = encrypt(&params, &pk, &encoder.encode(&a), 256, &mut rng);
        let cb = encrypt(&params, &pk, &encoder.encode(&b), 256, &mut rng);
        ca.c0.add_assign(&cb.c0, &params);
        ca.c1.add_assign(&cb.c1, &params);
        let dec = encoder.decode(&decrypt(&params, &sk, &ca), 256, ca.scale);
        for i in 0..256 {
            assert!((dec[i] - (a[i] + b[i])).abs() < 1e-6);
        }
    }

    #[test]
    fn homomorphic_scalar_weighting() {
        // The exact operation of Algorithm 1: ct ← α ⊙ ct.
        let (params, encoder, pk, sk) = setup(512, 40);
        let mut rng = ChaChaRng::from_seed(5, 5);
        let a: Vec<f64> = (0..256).map(|i| (i as f64 - 128.0) * 0.05).collect();
        let mut ct = encrypt(&params, &pk, &encoder.encode(&a), 256, &mut rng);
        let alpha = 1.0 / 3.0;
        let w = params.encode_weight(alpha);
        ct.c0.mul_scalar(&w, &params);
        ct.c1.mul_scalar(&w, &params);
        ct.scale *= params.delta_w();
        let dec = encoder.decode(&decrypt(&params, &sk, &ct), 256, ct.scale);
        for i in 0..256 {
            assert!(
                (dec[i] - alpha * a[i]).abs() < 1e-5,
                "{} vs {}",
                dec[i],
                alpha * a[i]
            );
        }
    }

    #[test]
    fn noise_stays_small_after_weighted_sum() {
        // 16-client weighted aggregate at the paper's default scale.
        let (params, encoder, pk, sk) = setup(1024, 52);
        let mut rng = ChaChaRng::from_seed(6, 6);
        let n_clients = 16;
        let alpha = 1.0 / n_clients as f64;
        let w = params.encode_weight(alpha);
        let values: Vec<f64> = (0..512).map(|i| (i as f64) * 0.003 - 0.7).collect();
        let mut agg: Option<Ciphertext> = None;
        for _ in 0..n_clients {
            let mut ct = encrypt(&params, &pk, &encoder.encode(&values), 512, &mut rng);
            ct.c0.mul_scalar(&w, &params);
            ct.c1.mul_scalar(&w, &params);
            ct.scale *= params.delta_w();
            match &mut agg {
                None => agg = Some(ct),
                Some(acc) => {
                    acc.c0.add_assign(&ct.c0, &params);
                    acc.c1.add_assign(&ct.c1, &params);
                }
            }
        }
        let agg = agg.unwrap();
        let dec = encoder.decode(&decrypt(&params, &sk, &agg), 512, agg.scale);
        for i in 0..512 {
            assert!(
                (dec[i] - values[i]).abs() < 1e-6,
                "{} vs {}",
                dec[i],
                values[i]
            );
        }
    }
}
