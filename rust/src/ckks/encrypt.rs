//! RLWE encryption / decryption — the public-key path and the seed-expanded
//! symmetric path.
//!
//! **Public-key** (`Enc(pk, m)`, [`encrypt_into`]): sample ephemeral ternary
//! `u` and errors `e0, e1`; `ct = (c0, c1) = (b·u + e0 + m, a·u + e1)`.
//!
//! **Symmetric seeded** (`Enc(sk, m)`, [`encrypt_sym_seeded_into`]): draw a
//! fresh 32-byte seed, expand the uniform a-part from it **directly in flat
//! limb-major NTT domain** (`a = expand(seed)`, one ChaCha sub-stream per
//! limb — no NTT on the client), and set `ct = (m + e − a·s, a)` with
//! `c1 = a` carried in NTT form and `a_seed` recording the seed. Decryption
//! is the same `m ≈ c0 + c1·s` for both forms — the decryptor (including the
//! threshold share-escrow path) only needs `c1` in NTT form at the
//! key-product step, which a seeded ciphertext already is. The wire form of
//! a seeded ciphertext is `seed ‖ c0_limbs` (DESIGN.md §14): half the dense
//! size, because the receiver re-expands `a` from the seed on demand
//! ([`Ciphertext::expand_a`], lazily per limb in the aggregation shards).
//!
//! Ciphertext polynomials otherwise live in **coefficient domain**: the
//! aggregation pipeline only adds and scalar-multiplies, which are
//! domain-agnostic, and the serialization/kernels operate on raw limbs.
//! `RnsPoly::ntt_form` tracks the one deliberate exception — the NTT-domain
//! c1 of seeded ciphertexts, converted back exactly once when an aggregate
//! is sealed.
//!
//! §Perf: the hot entry points are the `_into` variants — they write into a
//! caller-owned ciphertext/plaintext and stage everything in a pooled
//! [`CkksScratch`], so the steady state performs **zero heap allocations**
//! (proved by `tests/zero_alloc.rs`). The seed path materialized ~7
//! temporary polynomials per ciphertext; here `b·u + e0 + m` is accumulated
//! in place (pointwise product into the output limb, inverse NTT in place,
//! then one fused error+message sweep) and the error samples never exist as
//! a separate polynomial — they are drawn once into a single pooled limb and
//! re-lifted per modulus on the fly. The symmetric path is cheaper still:
//! no ephemeral `u`, no forward NTTs, a single error polynomial.

use super::keys::{PublicKey, SecretKey};
use super::modarith::{add_mod, center, lift_signed, sub_mod};
use super::params::CkksParams;
use super::poly::{sample_cbd_limb0, sample_ternary_into, CkksScratch, RnsPoly};
use crate::crypto::prng::ChaChaRng;

/// A CKKS ciphertext (pair of RNS polynomials, coefficient domain) plus the
/// metadata needed to decode: number of meaningful slots and current scale.
#[derive(Debug, Clone, PartialEq)]
pub struct Ciphertext {
    pub c0: RnsPoly,
    pub c1: RnsPoly,
    /// Number of packed values (≤ n/2).
    pub n_values: usize,
    /// Aggregate scale (Δ fresh; Δ·Δ_w after weighting).
    pub scale: f64,
    /// For symmetric seeded ciphertexts: the 32-byte seed that the
    /// NTT-domain a-part (`c1`) expands from. A lazily-parsed compressed
    /// ciphertext may carry the seed with an *empty* (0-limb) `c1`; the
    /// aggregation shards expand limbs on demand, or
    /// [`Ciphertext::expand_a`] materializes all of them.
    pub a_seed: Option<[u8; 32]>,
}

impl Ciphertext {
    /// An all-zero ciphertext skeleton of the parameter set's shape — the
    /// reusable target of [`encrypt_into`] and the `_into` aggregation
    /// kernels.
    pub fn zero(params: &CkksParams) -> Self {
        Ciphertext {
            c0: RnsPoly::zero(params),
            c1: RnsPoly::zero(params),
            n_values: 0,
            scale: 0.0,
            a_seed: None,
        }
    }

    /// Materialize the a-part of a lazily-parsed seeded ciphertext: if
    /// `c1` is the empty 0-limb placeholder and a seed is present, expand
    /// every limb from the seed (NTT domain). No-op when `c1` already has
    /// its limbs (fresh client-side seeded cts, or dense cts).
    pub fn expand_a(&mut self, params: &CkksParams) {
        let Some(seed) = self.a_seed else { return };
        if self.c1.num_limbs() != 0 {
            return;
        }
        let n = params.n;
        let num_limbs = params.num_limbs();
        let mut data = vec![0u64; num_limbs * n];
        for (l, limb) in data.chunks_exact_mut(n).enumerate() {
            expand_ct_a_limb(&seed, l, params.moduli[l], limb);
        }
        self.c1 = RnsPoly::from_flat(n, num_limbs, data, true);
    }
}

/// Key material for one encrypt call: the public-key path (dense ct wire)
/// or the seed-expanded symmetric path (`CtWire::Seed`; requires every
/// client to hold the single secret key).
#[derive(Clone, Copy)]
pub enum EncKey<'a> {
    Public(&'a PublicKey),
    SymSeeded(&'a SecretKey),
}

impl EncKey<'_> {
    /// Dispatch to [`encrypt_into`] or [`encrypt_sym_seeded_into`].
    pub fn encrypt_into(
        &self,
        params: &CkksParams,
        pt: &RnsPoly,
        n_values: usize,
        rng: &mut ChaChaRng,
        scratch: &mut CkksScratch,
        out: &mut Ciphertext,
    ) {
        match self {
            EncKey::Public(pk) => encrypt_into(params, pk, pt, n_values, rng, scratch, out),
            EncKey::SymSeeded(sk) => {
                encrypt_sym_seeded_into(params, sk, pt, n_values, rng, scratch, out)
            }
        }
    }
}

/// Expand limb `l` of a seeded ciphertext's a-part: a fresh ChaCha stream
/// keyed by the ciphertext seed with the limb index as nonce, sampled
/// uniformly below `q` straight into NTT domain. Per-limb sub-streams (not
/// one long stream) are required for lazy random access: rejection
/// sampling makes stream positions data-dependent, so limb `l` must not
/// depend on how many words limbs `0..l` consumed.
pub fn expand_ct_a_limb(seed: &[u8; 32], limb: usize, q: u64, out: &mut [u64]) {
    let mut nonce = [0u8; 12];
    nonce[..8].copy_from_slice(&(limb as u64).to_le_bytes());
    let mut rng = ChaChaRng::new(seed, &nonce);
    for o in out.iter_mut() {
        *o = rng.uniform_u64(q);
    }
    crate::obs::metrics::ct_seed_expansion();
}

/// Encrypt a coefficient-domain plaintext polynomial (allocating
/// convenience wrapper over [`encrypt_into`]).
pub fn encrypt(
    params: &CkksParams,
    pk: &PublicKey,
    pt: &RnsPoly,
    n_values: usize,
    rng: &mut ChaChaRng,
) -> Ciphertext {
    let mut scratch = CkksScratch::new(params);
    let mut out = Ciphertext::zero(params);
    encrypt_into(params, pk, pt, n_values, rng, &mut scratch, &mut out);
    out
}

/// Encrypt into a caller-owned ciphertext using pooled scratch buffers —
/// allocation-free after warm-up. RNG consumption (u, then e0, then e1) is
/// identical to the seed path, so ciphertexts are bitwise-stable.
pub fn encrypt_into(
    params: &CkksParams,
    pk: &PublicKey,
    pt: &RnsPoly,
    n_values: usize,
    rng: &mut ChaChaRng,
    scratch: &mut CkksScratch,
    out: &mut Ciphertext,
) {
    assert!(!pt.ntt_form, "plaintext must be in coefficient domain");
    let n = params.n;
    let num_limbs = params.num_limbs();
    debug_assert_eq!(out.c0.n, n, "output ciphertext shape mismatch");
    debug_assert_eq!(out.c0.num_limbs(), num_limbs);
    let q0 = params.moduli[0];

    // Ephemeral ternary u, sampled straight into the pooled buffer and
    // NTT'd per limb in place. (resize is a no-op after warm-up; the
    // scratch-pool metric counts whether this call reallocated.)
    crate::obs::metrics::scratch_pool(
        scratch.u.capacity() >= num_limbs * n && scratch.e.capacity() >= n,
    );
    scratch.u.resize(num_limbs * n, 0);
    scratch.e.resize(n, 0);
    sample_ternary_into(params, rng, &mut scratch.u);
    for (l, limb) in scratch.u.chunks_exact_mut(n).enumerate() {
        params.ntt[l].forward(limb);
    }

    // c0 = INTT(b ∘ u) + e0 + m, fused per limb with no temporaries.
    sample_cbd_limb0(params, super::params::CBD_K, rng, &mut scratch.e);
    for l in 0..num_limbs {
        let q = params.moduli[l];
        let br = params.barrett[l];
        let u_l = &scratch.u[l * n..(l + 1) * n];
        let dst = out.c0.limb_mut(l);
        for ((d, &b), &u) in dst.iter_mut().zip(pk.b_ntt.limb(l)).zip(u_l.iter()) {
            *d = br.mul(b, u);
        }
        params.ntt[l].inverse(dst);
        for ((d, &e0), &m) in dst.iter_mut().zip(scratch.e.iter()).zip(pt.limb(l)) {
            let e = if l == 0 { e0 } else { lift_signed(center(e0, q0), q) };
            *d = add_mod(add_mod(*d, e, q), m, q);
        }
    }
    out.c0.ntt_form = false;

    // c1 = INTT(a ∘ u) + e1, same fused pattern.
    sample_cbd_limb0(params, super::params::CBD_K, rng, &mut scratch.e);
    for l in 0..num_limbs {
        let q = params.moduli[l];
        let br = params.barrett[l];
        let u_l = &scratch.u[l * n..(l + 1) * n];
        let dst = out.c1.limb_mut(l);
        for ((d, &a), &u) in dst.iter_mut().zip(pk.a_ntt.limb(l)).zip(u_l.iter()) {
            *d = br.mul(a, u);
        }
        params.ntt[l].inverse(dst);
        for (d, &e1) in dst.iter_mut().zip(scratch.e.iter()) {
            let e = if l == 0 { e1 } else { lift_signed(center(e1, q0), q) };
            *d = add_mod(*d, e, q);
        }
    }
    out.c1.ntt_form = false;

    out.n_values = n_values;
    out.scale = params.delta();
    out.a_seed = None; // recycled buffers may carry a stale seed
}

/// Symmetric seeded encrypt (allocating convenience wrapper over
/// [`encrypt_sym_seeded_into`]).
pub fn encrypt_sym_seeded(
    params: &CkksParams,
    sk: &SecretKey,
    pt: &RnsPoly,
    n_values: usize,
    rng: &mut ChaChaRng,
) -> Ciphertext {
    let mut scratch = CkksScratch::new(params);
    let mut out = Ciphertext::zero(params);
    encrypt_sym_seeded_into(params, sk, pt, n_values, rng, &mut scratch, &mut out);
    out
}

/// Symmetric seeded encrypt into a caller-owned ciphertext —
/// allocation-free after warm-up, and cheaper than the public-key path (no
/// ephemeral `u`, no forward NTTs, one error polynomial).
///
/// Draws a fresh 32-byte seed from `rng`, expands the uniform a-part from
/// it per limb directly in NTT domain ([`expand_ct_a_limb`]), and sets
/// `c0 = m + e − a·s` (coefficient domain), `c1 = a` (NTT domain),
/// `a_seed = Some(seed)`. Decrypts with the same `m ≈ c0 + c1·s` as the
/// public-key form: `c0 + a·s = m + e`. RNG consumption is pinned (seed,
/// then e) so ciphertexts are bitwise-stable across buffer reuse and
/// parallel codec chunking.
pub fn encrypt_sym_seeded_into(
    params: &CkksParams,
    sk: &SecretKey,
    pt: &RnsPoly,
    n_values: usize,
    rng: &mut ChaChaRng,
    scratch: &mut CkksScratch,
    out: &mut Ciphertext,
) {
    assert!(!pt.ntt_form, "plaintext must be in coefficient domain");
    let n = params.n;
    let num_limbs = params.num_limbs();
    debug_assert_eq!(out.c0.n, n, "output ciphertext shape mismatch");
    debug_assert_eq!(out.c0.num_limbs(), num_limbs);
    let q0 = params.moduli[0];

    let mut seed = [0u8; 32];
    rng.fill_bytes(&mut seed);

    crate::obs::metrics::scratch_pool(scratch.e.capacity() >= n);
    scratch.e.resize(n, 0);
    sample_cbd_limb0(params, super::params::CBD_K, rng, &mut scratch.e);

    // Per limb: c1 = expand(seed) in NTT domain; c0 = m + e − INTT(c1 ∘ s).
    if out.c1.num_limbs() == 0 {
        // Reused lazily-parsed skeletons may carry the empty placeholder.
        out.c1 = RnsPoly::from_flat(n, num_limbs, vec![0u64; num_limbs * n], true);
    }
    for l in 0..num_limbs {
        let q = params.moduli[l];
        let br = params.barrett[l];
        expand_ct_a_limb(&seed, l, q, out.c1.limb_mut(l));
        let a_l = out.c1.limb(l);
        let dst = out.c0.limb_mut(l);
        for ((d, &a), &s) in dst.iter_mut().zip(a_l.iter()).zip(sk.s_ntt.limb(l)) {
            *d = br.mul(a, s);
        }
        params.ntt[l].inverse(dst);
        for ((d, &e0), &m) in dst.iter_mut().zip(scratch.e.iter()).zip(pt.limb(l)) {
            let e = if l == 0 { e0 } else { lift_signed(center(e0, q0), q) };
            *d = sub_mod(add_mod(m, e, q), *d, q);
        }
    }
    out.c0.ntt_form = false;
    out.c1.ntt_form = true;
    out.n_values = n_values;
    out.scale = params.delta();
    out.a_seed = Some(seed);
}

/// Decrypt to a coefficient-domain plaintext polynomial (allocating
/// convenience wrapper over [`decrypt_into`]).
pub fn decrypt(params: &CkksParams, sk: &SecretKey, ct: &Ciphertext) -> RnsPoly {
    let mut scratch = CkksScratch::new(params);
    let mut out = RnsPoly::zero(params);
    decrypt_into(params, sk, ct, &mut scratch, &mut out);
    out
}

/// Decrypt into a caller-owned polynomial using pooled scratch buffers —
/// allocation-free after warm-up.
pub fn decrypt_into(
    params: &CkksParams,
    sk: &SecretKey,
    ct: &Ciphertext,
    scratch: &mut CkksScratch,
    out: &mut RnsPoly,
) {
    assert!(!ct.c0.ntt_form, "ciphertext c0 must be in coefficient domain");
    let n = params.n;
    debug_assert_eq!(out.n, n, "output plaintext shape mismatch");
    crate::obs::metrics::scratch_pool(scratch.t.capacity() >= params.num_limbs() * n);
    scratch.t.resize(params.num_limbs() * n, 0);
    scratch.t.copy_from_slice(ct.c1.flat());
    for l in 0..params.num_limbs() {
        let q = params.moduli[l];
        let br = params.barrett[l];
        let t_l = &mut scratch.t[l * n..(l + 1) * n];
        // A seeded ciphertext's c1 is already NTT-domain — skip the lift.
        if !ct.c1.ntt_form {
            params.ntt[l].forward(t_l);
        }
        let dst = out.limb_mut(l);
        for ((d, &t), &s) in dst.iter_mut().zip(t_l.iter()).zip(sk.s_ntt.limb(l)) {
            *d = br.mul(t, s);
        }
        params.ntt[l].inverse(dst);
        for (d, &c0) in dst.iter_mut().zip(ct.c0.limb(l)) {
            *d = add_mod(*d, c0, q);
        }
    }
    out.ntt_form = false;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckks::encoding::Encoder;
    use crate::ckks::keys::keygen;
    use std::sync::Arc;

    fn setup(n: usize, bits: u32) -> (Arc<CkksParams>, Encoder, PublicKey, SecretKey) {
        let params = Arc::new(CkksParams::new(n, 4, bits).unwrap());
        let encoder = Encoder::new(params.clone());
        let mut rng = ChaChaRng::from_seed(42, 0);
        let (pk, sk) = keygen(&params, &mut rng);
        (params, encoder, pk, sk)
    }

    #[test]
    fn encrypt_decrypt_roundtrip() {
        let (params, encoder, pk, sk) = setup(1024, 40);
        let mut rng = ChaChaRng::from_seed(1, 1);
        let values: Vec<f64> = (0..512).map(|i| (i as f64) * 0.01 - 2.5).collect();
        let pt = encoder.encode(&values);
        let ct = encrypt(&params, &pk, &pt, values.len(), &mut rng);
        let dec_pt = decrypt(&params, &sk, &ct);
        let dec = encoder.decode(&dec_pt, ct.n_values, ct.scale);
        for (a, b) in values.iter().zip(dec.iter()) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn into_variants_match_allocating_wrappers() {
        // Same RNG state ⇒ bitwise-identical ciphertexts, with or without
        // caller-owned buffers, and across buffer reuse.
        let (params, encoder, pk, sk) = setup(256, 30);
        let values = vec![0.25; 64];
        let pt = encoder.encode(&values);
        let mut r1 = ChaChaRng::from_seed(8, 8);
        let mut r2 = ChaChaRng::from_seed(8, 8);
        let ct = encrypt(&params, &pk, &pt, 64, &mut r1);
        let mut scratch = CkksScratch::new(&params);
        let mut ct2 = Ciphertext::zero(&params);
        // dirty the reused buffer first to prove every word is rewritten
        let mut dirty_rng = ChaChaRng::from_seed(9, 9);
        encrypt_into(&params, &pk, &pt, 64, &mut dirty_rng, &mut scratch, &mut ct2);
        encrypt_into(&params, &pk, &pt, 64, &mut r2, &mut scratch, &mut ct2);
        assert_eq!(ct, ct2);
        let dec1 = decrypt(&params, &sk, &ct);
        let mut dec2 = RnsPoly::zero(&params);
        decrypt_into(&params, &sk, &ct2, &mut scratch, &mut dec2);
        assert_eq!(dec1, dec2);
    }

    #[test]
    fn sym_seeded_encrypt_decrypt_roundtrip() {
        let (params, encoder, _pk, sk) = setup(1024, 40);
        let mut rng = ChaChaRng::from_seed(11, 1);
        let values: Vec<f64> = (0..512).map(|i| (i as f64) * 0.01 - 2.5).collect();
        let pt = encoder.encode(&values);
        let ct = encrypt_sym_seeded(&params, &sk, &pt, values.len(), &mut rng);
        assert!(ct.a_seed.is_some());
        assert!(ct.c1.ntt_form && !ct.c0.ntt_form);
        let dec = encoder.decode(&decrypt(&params, &sk, &ct), ct.n_values, ct.scale);
        for (a, b) in values.iter().zip(dec.iter()) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn sym_into_variant_matches_allocating_wrapper() {
        // Same RNG state ⇒ bitwise-identical seeded ciphertexts across
        // dirty buffer reuse.
        let (params, encoder, _pk, sk) = setup(256, 30);
        let pt = encoder.encode(&vec![0.25; 64]);
        let mut r1 = ChaChaRng::from_seed(18, 8);
        let mut r2 = ChaChaRng::from_seed(18, 8);
        let ct = encrypt_sym_seeded(&params, &sk, &pt, 64, &mut r1);
        let mut scratch = CkksScratch::new(&params);
        let mut ct2 = Ciphertext::zero(&params);
        let mut dirty_rng = ChaChaRng::from_seed(19, 9);
        encrypt_sym_seeded_into(&params, &sk, &pt, 64, &mut dirty_rng, &mut scratch, &mut ct2);
        encrypt_sym_seeded_into(&params, &sk, &pt, 64, &mut r2, &mut scratch, &mut ct2);
        assert_eq!(ct, ct2);
    }

    #[test]
    fn expand_a_rebuilds_identical_a_part() {
        // Strip a seeded ciphertext down to its lazy wire shape (seed +
        // empty c1) and re-expand: the a-part must come back bitwise.
        let (params, encoder, _pk, sk) = setup(512, 40);
        let mut rng = ChaChaRng::from_seed(21, 2);
        let pt = encoder.encode(&vec![1.5; 256]);
        let ct = encrypt_sym_seeded(&params, &sk, &pt, 256, &mut rng);
        let mut lazy = ct.clone();
        lazy.c1 = RnsPoly::from_flat(params.n, 0, vec![], true);
        lazy.expand_a(&params);
        assert_eq!(lazy, ct);
        // And expand_a on an already-materialized ct is a no-op.
        let mut again = lazy.clone();
        again.expand_a(&params);
        assert_eq!(again, lazy);
    }

    #[test]
    fn sym_ciphertext_is_not_plaintext_and_wrong_key_fails() {
        let (params, encoder, _pk, sk) = setup(256, 30);
        let mut rng = ChaChaRng::from_seed(12, 2);
        let values = vec![1.0; 128];
        let pt = encoder.encode(&values);
        let ct = encrypt_sym_seeded(&params, &sk, &pt, 128, &mut rng);
        let diff_count = pt
            .limb(0)
            .iter()
            .zip(ct.c0.limb(0).iter())
            .filter(|(a, b)| a != b)
            .count();
        assert!(diff_count > 250, "c0 leaks plaintext structure");
        let (_pk2, sk2) = keygen(&params, &mut rng);
        let dec = encoder.decode(&decrypt(&params, &sk2, &ct), 128, ct.scale);
        let max_err = values
            .iter()
            .zip(dec.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        assert!(max_err > 1.0, "wrong key should not decrypt (err {max_err})");
    }

    #[test]
    fn sym_weighted_sum_decrypts_without_materializing_coeff_c1() {
        // 16-client aggregate over seeded ciphertexts: c1 stays NTT-domain
        // end to end and decrypt handles it directly.
        let (params, encoder, _pk, sk) = setup(1024, 52);
        let mut rng = ChaChaRng::from_seed(16, 6);
        let n_clients = 16;
        let w = params.encode_weight(1.0 / n_clients as f64);
        let values: Vec<f64> = (0..512).map(|i| (i as f64) * 0.003 - 0.7).collect();
        let mut agg: Option<Ciphertext> = None;
        for _ in 0..n_clients {
            let mut ct = encrypt_sym_seeded(&params, &sk, &encoder.encode(&values), 512, &mut rng);
            ct.c0.mul_scalar(&w, &params);
            ct.c1.mul_scalar(&w, &params);
            ct.scale *= params.delta_w();
            match &mut agg {
                None => agg = Some(ct),
                Some(acc) => {
                    acc.c0.add_assign(&ct.c0, &params);
                    acc.c1.add_assign(&ct.c1, &params);
                }
            }
        }
        let agg = agg.unwrap();
        assert!(agg.c1.ntt_form);
        let dec = encoder.decode(&decrypt(&params, &sk, &agg), 512, agg.scale);
        for i in 0..512 {
            assert!((dec[i] - values[i]).abs() < 1e-6, "{} vs {}", dec[i], values[i]);
        }
    }

    #[test]
    fn ciphertext_is_not_plaintext() {
        // The ciphertext limbs must look nothing like the encoded message.
        let (params, encoder, pk, _sk) = setup(256, 30);
        let mut rng = ChaChaRng::from_seed(2, 2);
        let values = vec![1.0; 128];
        let pt = encoder.encode(&values);
        let ct = encrypt(&params, &pk, &pt, 128, &mut rng);
        // A fresh encode of the same values differs wildly from c0.
        let diff_count = pt
            .limb(0)
            .iter()
            .zip(ct.c0.limb(0).iter())
            .filter(|(a, b)| a != b)
            .count();
        assert!(diff_count > 250, "c0 leaks plaintext structure");
    }

    #[test]
    fn decrypt_with_wrong_key_fails() {
        let (params, encoder, pk, _sk) = setup(256, 30);
        let mut rng = ChaChaRng::from_seed(3, 3);
        let values = vec![0.5; 128];
        let pt = encoder.encode(&values);
        let ct = encrypt(&params, &pk, &pt, 128, &mut rng);
        let (_pk2, sk2) = keygen(&params, &mut rng);
        let dec_pt = decrypt(&params, &sk2, &ct);
        let dec = encoder.decode(&dec_pt, 128, ct.scale);
        let max_err = values
            .iter()
            .zip(dec.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        assert!(max_err > 1.0, "wrong key should not decrypt (err {max_err})");
    }

    #[test]
    fn homomorphic_addition() {
        let (params, encoder, pk, sk) = setup(512, 40);
        let mut rng = ChaChaRng::from_seed(4, 4);
        let a: Vec<f64> = (0..256).map(|i| i as f64 * 0.01).collect();
        let b: Vec<f64> = (0..256).map(|i| 3.0 - i as f64 * 0.02).collect();
        let mut ca = encrypt(&params, &pk, &encoder.encode(&a), 256, &mut rng);
        let cb = encrypt(&params, &pk, &encoder.encode(&b), 256, &mut rng);
        ca.c0.add_assign(&cb.c0, &params);
        ca.c1.add_assign(&cb.c1, &params);
        let dec = encoder.decode(&decrypt(&params, &sk, &ca), 256, ca.scale);
        for i in 0..256 {
            assert!((dec[i] - (a[i] + b[i])).abs() < 1e-6);
        }
    }

    #[test]
    fn homomorphic_scalar_weighting() {
        // The exact operation of Algorithm 1: ct ← α ⊙ ct.
        let (params, encoder, pk, sk) = setup(512, 40);
        let mut rng = ChaChaRng::from_seed(5, 5);
        let a: Vec<f64> = (0..256).map(|i| (i as f64 - 128.0) * 0.05).collect();
        let mut ct = encrypt(&params, &pk, &encoder.encode(&a), 256, &mut rng);
        let alpha = 1.0 / 3.0;
        let w = params.encode_weight(alpha);
        ct.c0.mul_scalar(&w, &params);
        ct.c1.mul_scalar(&w, &params);
        ct.scale *= params.delta_w();
        let dec = encoder.decode(&decrypt(&params, &sk, &ct), 256, ct.scale);
        for i in 0..256 {
            assert!(
                (dec[i] - alpha * a[i]).abs() < 1e-5,
                "{} vs {}",
                dec[i],
                alpha * a[i]
            );
        }
    }

    #[test]
    fn noise_stays_small_after_weighted_sum() {
        // 16-client weighted aggregate at the paper's default scale.
        let (params, encoder, pk, sk) = setup(1024, 52);
        let mut rng = ChaChaRng::from_seed(6, 6);
        let n_clients = 16;
        let alpha = 1.0 / n_clients as f64;
        let w = params.encode_weight(alpha);
        let values: Vec<f64> = (0..512).map(|i| (i as f64) * 0.003 - 0.7).collect();
        let mut agg: Option<Ciphertext> = None;
        for _ in 0..n_clients {
            let mut ct = encrypt(&params, &pk, &encoder.encode(&values), 512, &mut rng);
            ct.c0.mul_scalar(&w, &params);
            ct.c1.mul_scalar(&w, &params);
            ct.scale *= params.delta_w();
            match &mut agg {
                None => agg = Some(ct),
                Some(acc) => {
                    acc.c0.add_assign(&ct.c0, &params);
                    acc.c1.add_assign(&ct.c1, &params);
                }
            }
        }
        let agg = agg.unwrap();
        let dec = encoder.decode(&decrypt(&params, &sk, &agg), 512, agg.scale);
        for i in 0..512 {
            assert!(
                (dec[i] - values[i]).abs() < 1e-6,
                "{} vs {}",
                dec[i],
                values[i]
            );
        }
    }
}
