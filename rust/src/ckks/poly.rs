//! RNS polynomials in Z_Q[X]/(X^n + 1): the working data type of the scheme.
//!
//! Coefficients are stored limb-major (`limbs[l][j]` = coefficient j mod
//! q_l) so the per-limb NTT and the limb-wise aggregation kernel stream
//! contiguous memory.

use super::modarith::{add_mod, lift_signed, neg_mod, sub_mod};
use super::params::CkksParams;
use crate::crypto::prng::ChaChaRng;

/// An RNS polynomial; `ntt_form` tracks which domain the limbs are in.
#[derive(Debug, Clone, PartialEq)]
pub struct RnsPoly {
    pub n: usize,
    /// One residue vector per modulus, each of length n.
    pub limbs: Vec<Vec<u64>>,
    pub ntt_form: bool,
}

impl RnsPoly {
    /// The zero polynomial.
    pub fn zero(params: &CkksParams) -> Self {
        RnsPoly {
            n: params.n,
            limbs: vec![vec![0u64; params.n]; params.num_limbs()],
            ntt_form: false,
        }
    }

    /// Lift signed coefficients (e.g. an encoded message or error sample)
    /// into every RNS limb.
    pub fn from_signed(params: &CkksParams, coeffs: &[i64]) -> Self {
        assert_eq!(coeffs.len(), params.n);
        let limbs = params
            .moduli
            .iter()
            .map(|&q| coeffs.iter().map(|&c| lift_signed(c, q)).collect())
            .collect();
        RnsPoly {
            n: params.n,
            limbs,
            ntt_form: false,
        }
    }

    /// Lift signed i128 coefficients (for wide encodings at high scale) via
    /// per-limb reduction.
    ///
    /// §Perf: splits |c| = hi·2^64 + lo and reduces with one u64 division
    /// plus a Barrett multiply instead of an i128 modulo (a libcall); valid
    /// for |c| < 2^90 (hi < 2^26 < q so hi needs no reduction), which covers
    /// every encoding scale the scheme admits.
    pub fn from_signed_wide(params: &CkksParams, coeffs: &[i128]) -> Self {
        assert_eq!(coeffs.len(), params.n);
        let limbs = params
            .moduli
            .iter()
            .map(|&q| {
                let br = super::modarith::Barrett::new(q);
                let two64 = ((1u128 << 64) % q as u128) as u64;
                coeffs
                    .iter()
                    .map(|&c| {
                        let abs = c.unsigned_abs();
                        debug_assert!(abs < 1u128 << 90, "encoding overflow");
                        let hi = (abs >> 64) as u64; // < 2^26 < q
                        let lo = (abs as u64) % q;
                        let r = super::modarith::add_mod(br.mul(hi, two64), lo, q);
                        if c < 0 {
                            super::modarith::neg_mod(r, q)
                        } else {
                            r
                        }
                    })
                    .collect()
            })
            .collect();
        RnsPoly {
            n: params.n,
            limbs,
            ntt_form: false,
        }
    }

    /// Uniform random polynomial over R_Q (public `a` of the key pair).
    pub fn sample_uniform(params: &CkksParams, rng: &mut ChaChaRng) -> Self {
        let limbs = params
            .moduli
            .iter()
            .map(|&q| (0..params.n).map(|_| rng.uniform_u64(q)).collect())
            .collect();
        RnsPoly {
            n: params.n,
            limbs,
            ntt_form: false,
        }
    }

    /// Ternary polynomial (secret / ephemeral key distribution).
    pub fn sample_ternary(params: &CkksParams, rng: &mut ChaChaRng) -> Self {
        let coeffs: Vec<i64> = (0..params.n).map(|_| rng.ternary()).collect();
        Self::from_signed(params, &coeffs)
    }

    /// Centered-binomial error polynomial.
    pub fn sample_error(params: &CkksParams, rng: &mut ChaChaRng) -> Self {
        let coeffs: Vec<i64> = (0..params.n)
            .map(|_| rng.cbd(super::params::CBD_K))
            .collect();
        Self::from_signed(params, &coeffs)
    }

    /// Forward NTT on every limb (idempotence guarded by `ntt_form`).
    pub fn to_ntt(&mut self, params: &CkksParams) {
        assert!(!self.ntt_form, "already in NTT form");
        for (l, limb) in self.limbs.iter_mut().enumerate() {
            params.ntt[l].forward(limb);
        }
        self.ntt_form = true;
    }

    /// Inverse NTT on every limb.
    pub fn from_ntt(&mut self, params: &CkksParams) {
        assert!(self.ntt_form, "not in NTT form");
        for (l, limb) in self.limbs.iter_mut().enumerate() {
            params.ntt[l].inverse(limb);
        }
        self.ntt_form = false;
    }

    /// `self += other` (domains must match).
    pub fn add_assign(&mut self, other: &RnsPoly, params: &CkksParams) {
        assert_eq!(self.ntt_form, other.ntt_form, "domain mismatch");
        for l in 0..self.limbs.len() {
            let q = params.moduli[l];
            for j in 0..self.n {
                self.limbs[l][j] = add_mod(self.limbs[l][j], other.limbs[l][j], q);
            }
        }
    }

    /// `self -= other`.
    pub fn sub_assign(&mut self, other: &RnsPoly, params: &CkksParams) {
        assert_eq!(self.ntt_form, other.ntt_form, "domain mismatch");
        for l in 0..self.limbs.len() {
            let q = params.moduli[l];
            for j in 0..self.n {
                self.limbs[l][j] = sub_mod(self.limbs[l][j], other.limbs[l][j], q);
            }
        }
    }

    /// Negate in place.
    pub fn negate(&mut self, params: &CkksParams) {
        for l in 0..self.limbs.len() {
            let q = params.moduli[l];
            for x in self.limbs[l].iter_mut() {
                *x = neg_mod(*x, q);
            }
        }
    }

    /// Pointwise product (both operands must be in NTT form).
    pub fn mul_ntt(&self, other: &RnsPoly, params: &CkksParams) -> RnsPoly {
        assert!(self.ntt_form && other.ntt_form, "mul requires NTT form");
        let limbs = (0..self.limbs.len())
            .map(|l| {
                let br = super::modarith::Barrett::new(params.moduli[l]);
                self.limbs[l]
                    .iter()
                    .zip(other.limbs[l].iter())
                    .map(|(&a, &b)| br.mul(a, b))
                    .collect()
            })
            .collect();
        RnsPoly {
            n: self.n,
            limbs,
            ntt_form: true,
        }
    }

    /// Multiply by a scalar given as per-limb residues (the encoded
    /// aggregation weight). Domain-agnostic: scalar multiplication commutes
    /// with the NTT.
    pub fn mul_scalar(&mut self, scalar: &[u64], params: &CkksParams) {
        assert_eq!(scalar.len(), self.limbs.len());
        for l in 0..self.limbs.len() {
            let br = super::modarith::Barrett::new(params.moduli[l]);
            let s = scalar[l];
            for x in self.limbs[l].iter_mut() {
                *x = br.mul(*x, s);
            }
        }
    }

    /// Full negacyclic product: handles NTT conversion, returns coefficient
    /// domain. (Convenience for tests; hot paths manage domains explicitly.)
    pub fn mul_full(&self, other: &RnsPoly, params: &CkksParams) -> RnsPoly {
        let mut a = self.clone();
        let mut b = other.clone();
        if !a.ntt_form {
            a.to_ntt(params);
        }
        if !b.ntt_form {
            b.to_ntt(params);
        }
        let mut c = a.mul_ntt(&b, params);
        c.from_ntt(params);
        c
    }

    /// CRT-reconstruct all coefficients to centered i128.
    pub fn to_centered_coeffs(&self, params: &CkksParams) -> Vec<i128> {
        assert!(!self.ntt_form, "reconstruct from coefficient domain");
        let mut out = Vec::with_capacity(self.n);
        let mut residues = vec![0u64; self.limbs.len()];
        for j in 0..self.n {
            for l in 0..self.limbs.len() {
                residues[l] = self.limbs[l][j];
            }
            out.push(params.crt_reconstruct_centered(&residues));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> CkksParams {
        CkksParams::new(64, 3, 30).unwrap()
    }

    #[test]
    fn signed_lift_reconstruct_roundtrip() {
        let p = params();
        let mut rng = ChaChaRng::from_seed(1, 0);
        let coeffs: Vec<i64> = (0..p.n)
            .map(|_| rng.uniform_u64(1 << 40) as i64 - (1 << 39))
            .collect();
        let poly = RnsPoly::from_signed(&p, &coeffs);
        let rec = poly.to_centered_coeffs(&p);
        for (a, b) in coeffs.iter().zip(rec.iter()) {
            assert_eq!(*a as i128, *b);
        }
    }

    #[test]
    fn add_sub_neg() {
        let p = params();
        let mut rng = ChaChaRng::from_seed(2, 0);
        let a = RnsPoly::sample_uniform(&p, &mut rng);
        let b = RnsPoly::sample_uniform(&p, &mut rng);
        let mut s = a.clone();
        s.add_assign(&b, &p);
        s.sub_assign(&b, &p);
        assert_eq!(s, a);
        let mut n = a.clone();
        n.negate(&p);
        n.add_assign(&a, &p);
        assert_eq!(n, RnsPoly::zero(&p));
    }

    #[test]
    fn scalar_mul_commutes_with_ntt() {
        let p = params();
        let mut rng = ChaChaRng::from_seed(3, 0);
        let a = RnsPoly::sample_uniform(&p, &mut rng);
        let scalar: Vec<u64> = p.moduli.iter().map(|&q| 12345 % q).collect();

        // scalar-mult then NTT
        let mut x = a.clone();
        x.mul_scalar(&scalar, &p);
        x.to_ntt(&p);

        // NTT then scalar-mult
        let mut y = a.clone();
        y.to_ntt(&p);
        y.mul_scalar(&scalar, &p);

        assert_eq!(x, y);
    }

    #[test]
    fn mul_matches_schoolbook_via_identity() {
        // (a * 1) == a
        let p = params();
        let mut rng = ChaChaRng::from_seed(4, 0);
        let a = RnsPoly::sample_uniform(&p, &mut rng);
        let mut one_coeffs = vec![0i64; p.n];
        one_coeffs[0] = 1;
        let one = RnsPoly::from_signed(&p, &one_coeffs);
        let prod = a.mul_full(&one, &p);
        assert_eq!(prod, a);
    }

    #[test]
    fn ternary_and_error_are_small() {
        let p = params();
        let mut rng = ChaChaRng::from_seed(5, 0);
        let t = RnsPoly::sample_ternary(&p, &mut rng).to_centered_coeffs(&p);
        assert!(t.iter().all(|&c| c.abs() <= 1));
        let e = RnsPoly::sample_error(&p, &mut rng).to_centered_coeffs(&p);
        assert!(e.iter().all(|&c| c.abs() <= 21));
    }

    #[test]
    #[should_panic(expected = "domain mismatch")]
    fn domain_mismatch_panics() {
        let p = params();
        let mut rng = ChaChaRng::from_seed(6, 0);
        let mut a = RnsPoly::sample_uniform(&p, &mut rng);
        let b = RnsPoly::sample_uniform(&p, &mut rng);
        a.to_ntt(&p);
        a.add_assign(&b, &p);
    }
}
