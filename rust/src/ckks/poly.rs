//! RNS polynomials in Z_Q[X]/(X^n + 1): the working data type of the scheme.
//!
//! §Perf: coefficients live in **one contiguous limb-major allocation**
//! (`data[l*n + j]` = coefficient j mod q_l) instead of the seed's
//! `Vec<Vec<u64>>`. One allocation per polynomial keeps the allocator out of
//! the hot paths, the per-limb NTT and the limb-wise aggregation kernel still
//! stream contiguous memory through [`RnsPoly::limb`]/[`RnsPoly::limb_mut`]
//! slice views, and whole-poly copies are a single `memcpy` of the flat
//! buffer. [`CkksScratch`] pools the staging buffers so the encrypt/decrypt/
//! weighted-sum steady state performs no heap allocation at all (see
//! DESIGN.md §7).

use super::modarith::{add_mod, center, lift_signed, neg_mod, sub_mod};
use super::params::CkksParams;
use crate::crypto::prng::ChaChaRng;

/// An RNS polynomial; `ntt_form` tracks which domain the limbs are in.
#[derive(Debug, Clone, PartialEq)]
pub struct RnsPoly {
    pub n: usize,
    num_limbs: usize,
    /// Contiguous limb-major storage, length `num_limbs * n`.
    data: Vec<u64>,
    pub ntt_form: bool,
}

impl RnsPoly {
    /// The zero polynomial.
    pub fn zero(params: &CkksParams) -> Self {
        RnsPoly {
            n: params.n,
            num_limbs: params.num_limbs(),
            data: vec![0u64; params.num_limbs() * params.n],
            ntt_form: false,
        }
    }

    /// Wrap an existing flat limb-major buffer (deserialization, kernel
    /// output). `data.len()` must be `num_limbs * n`.
    pub fn from_flat(n: usize, num_limbs: usize, data: Vec<u64>, ntt_form: bool) -> Self {
        assert_eq!(data.len(), num_limbs * n, "flat buffer shape mismatch");
        RnsPoly {
            n,
            num_limbs,
            data,
            ntt_form,
        }
    }

    /// Number of RNS limbs.
    #[inline]
    pub fn num_limbs(&self) -> usize {
        self.num_limbs
    }

    /// Residue vector of limb `l` (length n, contiguous).
    #[inline]
    pub fn limb(&self, l: usize) -> &[u64] {
        &self.data[l * self.n..(l + 1) * self.n]
    }

    /// Mutable residue vector of limb `l`.
    #[inline]
    pub fn limb_mut(&mut self, l: usize) -> &mut [u64] {
        let n = self.n;
        &mut self.data[l * n..(l + 1) * n]
    }

    /// Iterate limb slices in order.
    #[inline]
    pub fn limbs(&self) -> std::slice::ChunksExact<'_, u64> {
        self.data.chunks_exact(self.n)
    }

    /// Iterate mutable limb slices in order.
    #[inline]
    pub fn limbs_mut(&mut self) -> std::slice::ChunksExactMut<'_, u64> {
        let n = self.n;
        self.data.chunks_exact_mut(n)
    }

    /// The whole flat limb-major buffer.
    #[inline]
    pub fn flat(&self) -> &[u64] {
        &self.data
    }

    /// Mutable flat buffer.
    #[inline]
    pub fn flat_mut(&mut self) -> &mut [u64] {
        &mut self.data
    }

    /// Lift signed coefficients (e.g. an encoded message or error sample)
    /// into every RNS limb.
    pub fn from_signed(params: &CkksParams, coeffs: &[i64]) -> Self {
        assert_eq!(coeffs.len(), params.n);
        let mut data = Vec::with_capacity(params.num_limbs() * params.n);
        for &q in &params.moduli {
            data.extend(coeffs.iter().map(|&c| lift_signed(c, q)));
        }
        RnsPoly {
            n: params.n,
            num_limbs: params.num_limbs(),
            data,
            ntt_form: false,
        }
    }

    /// Lift signed i128 coefficients (for wide encodings at high scale) via
    /// per-limb reduction.
    ///
    /// §Perf: splits |c| = hi·2^64 + lo and reduces with one u64 division
    /// plus a Barrett multiply instead of an i128 modulo (a libcall); valid
    /// for |c| < 2^90 (hi < 2^26 < q so hi needs no reduction), which covers
    /// every encoding scale the scheme admits.
    pub fn from_signed_wide(params: &CkksParams, coeffs: &[i128]) -> Self {
        let mut p = RnsPoly::zero(params);
        p.assign_signed_wide(params, coeffs);
        p
    }

    /// In-place body of [`Self::from_signed_wide`]: overwrite this
    /// polynomial with the per-limb reduction of `coeffs` — allocation-free,
    /// the encoder's pooled-arena path (§Perf). The receiver must already
    /// have this parameter set's shape.
    pub fn assign_signed_wide(&mut self, params: &CkksParams, coeffs: &[i128]) {
        assert_eq!(coeffs.len(), params.n);
        assert_eq!(self.n, params.n, "output polynomial shape mismatch");
        assert_eq!(self.num_limbs, params.num_limbs(), "output limb mismatch");
        for (l, limb) in self.data.chunks_exact_mut(self.n).enumerate() {
            let q = params.moduli[l];
            let br = params.barrett[l];
            let two64 = ((1u128 << 64) % q as u128) as u64;
            for (d, &c) in limb.iter_mut().zip(coeffs.iter()) {
                let abs = c.unsigned_abs();
                debug_assert!(abs < 1u128 << 90, "encoding overflow");
                let hi = (abs >> 64) as u64; // < 2^26 < q
                let lo = (abs as u64) % q;
                let r = add_mod(br.mul(hi, two64), lo, q);
                *d = if c < 0 { neg_mod(r, q) } else { r };
            }
        }
        self.ntt_form = false;
    }

    /// Uniform random polynomial over R_Q (public `a` of the key pair).
    pub fn sample_uniform(params: &CkksParams, rng: &mut ChaChaRng) -> Self {
        let mut data = Vec::with_capacity(params.num_limbs() * params.n);
        for &q in &params.moduli {
            for _ in 0..params.n {
                data.push(rng.uniform_u64(q));
            }
        }
        RnsPoly {
            n: params.n,
            num_limbs: params.num_limbs(),
            data,
            ntt_form: false,
        }
    }

    /// Ternary polynomial (secret / ephemeral key distribution).
    pub fn sample_ternary(params: &CkksParams, rng: &mut ChaChaRng) -> Self {
        let mut p = RnsPoly::zero(params);
        sample_ternary_into(params, rng, &mut p.data);
        p
    }

    /// Centered-binomial error polynomial.
    pub fn sample_error(params: &CkksParams, rng: &mut ChaChaRng) -> Self {
        let mut p = RnsPoly::zero(params);
        let n = params.n;
        sample_cbd_limb0(params, super::params::CBD_K, rng, &mut p.data[..n]);
        broadcast_limb0(params, &mut p.data);
        p
    }

    /// Forward NTT on every limb (idempotence guarded by `ntt_form`).
    pub fn to_ntt(&mut self, params: &CkksParams) {
        assert!(!self.ntt_form, "already in NTT form");
        for (l, limb) in self.data.chunks_exact_mut(self.n).enumerate() {
            params.ntt[l].forward(limb);
        }
        self.ntt_form = true;
    }

    /// Inverse NTT on every limb.
    pub fn from_ntt(&mut self, params: &CkksParams) {
        assert!(self.ntt_form, "not in NTT form");
        for (l, limb) in self.data.chunks_exact_mut(self.n).enumerate() {
            params.ntt[l].inverse(limb);
        }
        self.ntt_form = false;
    }

    /// `self += other` (domains must match).
    pub fn add_assign(&mut self, other: &RnsPoly, params: &CkksParams) {
        assert_eq!(self.ntt_form, other.ntt_form, "domain mismatch");
        let n = self.n;
        for (l, (dst, src)) in self
            .data
            .chunks_exact_mut(n)
            .zip(other.data.chunks_exact(n))
            .enumerate()
        {
            let q = params.moduli[l];
            for (d, &s) in dst.iter_mut().zip(src.iter()) {
                *d = add_mod(*d, s, q);
            }
        }
    }

    /// `self -= other`.
    pub fn sub_assign(&mut self, other: &RnsPoly, params: &CkksParams) {
        assert_eq!(self.ntt_form, other.ntt_form, "domain mismatch");
        let n = self.n;
        for (l, (dst, src)) in self
            .data
            .chunks_exact_mut(n)
            .zip(other.data.chunks_exact(n))
            .enumerate()
        {
            let q = params.moduli[l];
            for (d, &s) in dst.iter_mut().zip(src.iter()) {
                *d = sub_mod(*d, s, q);
            }
        }
    }

    /// Negate in place.
    pub fn negate(&mut self, params: &CkksParams) {
        let n = self.n;
        for (l, limb) in self.data.chunks_exact_mut(n).enumerate() {
            let q = params.moduli[l];
            for x in limb.iter_mut() {
                *x = neg_mod(*x, q);
            }
        }
    }

    /// Pointwise product (both operands must be in NTT form).
    ///
    /// §Perf: uses the per-limb Barrett reducers cached in [`CkksParams`]
    /// instead of rebuilding one per limb per call.
    pub fn mul_ntt(&self, other: &RnsPoly, params: &CkksParams) -> RnsPoly {
        assert!(self.ntt_form && other.ntt_form, "mul requires NTT form");
        let n = self.n;
        let mut data = Vec::with_capacity(self.num_limbs * n);
        for (l, (a, b)) in self
            .data
            .chunks_exact(n)
            .zip(other.data.chunks_exact(n))
            .enumerate()
        {
            let br = params.barrett[l];
            data.extend(a.iter().zip(b.iter()).map(|(&x, &y)| br.mul(x, y)));
        }
        RnsPoly {
            n,
            num_limbs: self.num_limbs,
            data,
            ntt_form: true,
        }
    }

    /// Multiply by a scalar given as per-limb residues (the encoded
    /// aggregation weight). Domain-agnostic: scalar multiplication commutes
    /// with the NTT.
    pub fn mul_scalar(&mut self, scalar: &[u64], params: &CkksParams) {
        assert_eq!(scalar.len(), self.num_limbs);
        let n = self.n;
        for (l, limb) in self.data.chunks_exact_mut(n).enumerate() {
            let br = params.barrett[l];
            let s = scalar[l];
            for x in limb.iter_mut() {
                *x = br.mul(*x, s);
            }
        }
    }

    /// Full negacyclic product: handles NTT conversion, returns coefficient
    /// domain. (Convenience for tests; hot paths manage domains explicitly.)
    pub fn mul_full(&self, other: &RnsPoly, params: &CkksParams) -> RnsPoly {
        let mut a = self.clone();
        let mut b = other.clone();
        if !a.ntt_form {
            a.to_ntt(params);
        }
        if !b.ntt_form {
            b.to_ntt(params);
        }
        let mut c = a.mul_ntt(&b, params);
        c.from_ntt(params);
        c
    }

    /// CRT-reconstruct all coefficients to centered i128.
    pub fn to_centered_coeffs(&self, params: &CkksParams) -> Vec<i128> {
        assert!(!self.ntt_form, "reconstruct from coefficient domain");
        let n = self.n;
        let mut out = Vec::with_capacity(n);
        let mut residues = vec![0u64; self.num_limbs];
        for j in 0..n {
            for l in 0..self.num_limbs {
                residues[l] = self.data[l * n + j];
            }
            out.push(params.crt_reconstruct_centered(&residues));
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Pooled scratch buffers + allocation-free sampling (§Perf).

/// Reusable staging buffers for the encrypt/decrypt/weighted-sum hot paths:
/// every buffer starts empty and is sized by the first kernel that needs it
/// (a scratch used only for aggregation never allocates poly staging, one
/// used only for decryption never allocates the ephemeral-`u` pool). After
/// one warm-up call per shape, `encrypt_into`, `decrypt_into` and
/// `weighted_sum_refs_into` perform **zero heap allocations** (proved by
/// `tests/zero_alloc.rs`). Each worker thread owns one scratch.
#[derive(Default)]
pub struct CkksScratch {
    /// Full flat poly staging (ephemeral `u` in NTT form), `num_limbs * n`.
    pub(crate) u: Vec<u64>,
    /// Single-limb sample staging (`n` values lifted mod q_0): error samples
    /// are drawn once here and re-lifted per limb on the fly.
    pub(crate) e: Vec<u64>,
    /// Full flat poly temp (decrypt's NTT copy of c1), `num_limbs * n`.
    pub(crate) t: Vec<u64>,
    /// Amortized per-round weight residues (`clients * num_limbs`).
    pub(crate) weights: Vec<u64>,
}

impl CkksScratch {
    pub fn new(_params: &CkksParams) -> Self {
        CkksScratch::default()
    }
}

/// Sample a ternary polynomial straight into a flat limb-major buffer: limb 0
/// is drawn from the RNG (same draw order as the seed path), the remaining
/// limbs are re-lifted from limb 0 — no intermediate signed vector.
pub(crate) fn sample_ternary_into(params: &CkksParams, rng: &mut ChaChaRng, out: &mut [u64]) {
    let n = params.n;
    debug_assert_eq!(out.len(), params.num_limbs() * n);
    let q0 = params.moduli[0];
    let (first, rest) = out.split_at_mut(n);
    for x in first.iter_mut() {
        *x = lift_signed(rng.ternary(), q0);
    }
    broadcast_from_limb0(params, first, rest);
}

/// Sample `n` centered-binomial values lifted into limb 0's modulus.
pub(crate) fn sample_cbd_limb0(
    params: &CkksParams,
    k: u32,
    rng: &mut ChaChaRng,
    out: &mut [u64],
) {
    debug_assert_eq!(out.len(), params.n);
    let q0 = params.moduli[0];
    for x in out.iter_mut() {
        *x = lift_signed(rng.cbd(k), q0);
    }
}

/// Re-lift limb 0 of a flat buffer into every other limb (small centered
/// values only: the limb-0 residue uniquely determines the signed sample).
pub(crate) fn broadcast_limb0(params: &CkksParams, data: &mut [u64]) {
    let n = params.n;
    let (first, rest) = data.split_at_mut(n);
    broadcast_from_limb0(params, first, rest);
}

fn broadcast_from_limb0(params: &CkksParams, first: &[u64], rest: &mut [u64]) {
    let n = params.n;
    let q0 = params.moduli[0];
    for (l, limb) in rest.chunks_exact_mut(n).enumerate() {
        let q = params.moduli[l + 1];
        for (d, &s) in limb.iter_mut().zip(first.iter()) {
            *d = lift_signed(center(s, q0), q);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> CkksParams {
        CkksParams::new(64, 3, 30).unwrap()
    }

    #[test]
    fn signed_lift_reconstruct_roundtrip() {
        let p = params();
        let mut rng = ChaChaRng::from_seed(1, 0);
        let coeffs: Vec<i64> = (0..p.n)
            .map(|_| rng.uniform_u64(1 << 40) as i64 - (1 << 39))
            .collect();
        let poly = RnsPoly::from_signed(&p, &coeffs);
        let rec = poly.to_centered_coeffs(&p);
        for (a, b) in coeffs.iter().zip(rec.iter()) {
            assert_eq!(*a as i128, *b);
        }
    }

    #[test]
    fn add_sub_neg() {
        let p = params();
        let mut rng = ChaChaRng::from_seed(2, 0);
        let a = RnsPoly::sample_uniform(&p, &mut rng);
        let b = RnsPoly::sample_uniform(&p, &mut rng);
        let mut s = a.clone();
        s.add_assign(&b, &p);
        s.sub_assign(&b, &p);
        assert_eq!(s, a);
        let mut n = a.clone();
        n.negate(&p);
        n.add_assign(&a, &p);
        assert_eq!(n, RnsPoly::zero(&p));
    }

    #[test]
    fn scalar_mul_commutes_with_ntt() {
        let p = params();
        let mut rng = ChaChaRng::from_seed(3, 0);
        let a = RnsPoly::sample_uniform(&p, &mut rng);
        let scalar: Vec<u64> = p.moduli.iter().map(|&q| 12345 % q).collect();

        // scalar-mult then NTT
        let mut x = a.clone();
        x.mul_scalar(&scalar, &p);
        x.to_ntt(&p);

        // NTT then scalar-mult
        let mut y = a.clone();
        y.to_ntt(&p);
        y.mul_scalar(&scalar, &p);

        assert_eq!(x, y);
    }

    #[test]
    fn mul_matches_schoolbook_via_identity() {
        // (a * 1) == a
        let p = params();
        let mut rng = ChaChaRng::from_seed(4, 0);
        let a = RnsPoly::sample_uniform(&p, &mut rng);
        let mut one_coeffs = vec![0i64; p.n];
        one_coeffs[0] = 1;
        let one = RnsPoly::from_signed(&p, &one_coeffs);
        let prod = a.mul_full(&one, &p);
        assert_eq!(prod, a);
    }

    #[test]
    fn ternary_and_error_are_small() {
        let p = params();
        let mut rng = ChaChaRng::from_seed(5, 0);
        let t = RnsPoly::sample_ternary(&p, &mut rng).to_centered_coeffs(&p);
        assert!(t.iter().all(|&c| c.abs() <= 1));
        let e = RnsPoly::sample_error(&p, &mut rng).to_centered_coeffs(&p);
        assert!(e.iter().all(|&c| c.abs() <= 21));
    }

    #[test]
    fn flat_views_are_consistent() {
        let p = params();
        let mut rng = ChaChaRng::from_seed(7, 0);
        let a = RnsPoly::sample_uniform(&p, &mut rng);
        assert_eq!(a.num_limbs(), p.num_limbs());
        assert_eq!(a.flat().len(), p.num_limbs() * p.n);
        for (l, limb) in a.limbs().enumerate() {
            assert_eq!(limb, a.limb(l));
            assert_eq!(limb, &a.flat()[l * p.n..(l + 1) * p.n]);
        }
        let rebuilt = RnsPoly::from_flat(a.n, a.num_limbs(), a.flat().to_vec(), a.ntt_form);
        assert_eq!(rebuilt, a);
    }

    #[test]
    fn sampling_into_matches_allocating_samplers() {
        // The scratch-buffer samplers must consume the RNG identically to
        // the allocating ones (bitwise-stable ciphertexts).
        let p = params();
        let mut r1 = ChaChaRng::from_seed(9, 0);
        let mut r2 = ChaChaRng::from_seed(9, 0);
        let t1 = RnsPoly::sample_ternary(&p, &mut r1);
        let mut buf = vec![0u64; p.num_limbs() * p.n];
        sample_ternary_into(&p, &mut r2, &mut buf);
        assert_eq!(t1.flat(), &buf[..]);
        assert_eq!(r1.next_u64(), r2.next_u64());
    }

    #[test]
    #[should_panic(expected = "domain mismatch")]
    fn domain_mismatch_panics() {
        let p = params();
        let mut rng = ChaChaRng::from_seed(6, 0);
        let mut a = RnsPoly::sample_uniform(&p, &mut rng);
        let b = RnsPoly::sample_uniform(&p, &mut rng);
        a.to_ntt(&p);
        a.add_assign(&b, &p);
    }
}
