//! Negacyclic number-theoretic transform over Z_q[X]/(X^n + 1).
//!
//! Iterative Cooley–Tukey (decimation-in-time) forward and Gentleman–Sande
//! (decimation-in-frequency) inverse with ψ-twisting folded into the
//! butterflies (Longa–Naehrig layout): `intt(ntt(a) ∘ ntt(b))` is the
//! negacyclic product `a·b mod (X^n + 1, q)`.
//!
//! Multiplications use Shoup's precomputed-quotient trick: for a fixed
//! twiddle `w`, `w' = ⌊w·2^64/q⌋` lets `a·w mod q` be computed with two
//! multiplies and no division.
//!
//! §Perf (Harvey-style lazy reduction): the hot butterflies keep values
//! **unreduced in [0, 4q)** — the Shoup product skips its conditional
//! subtract (result in [0, 2q)), the add/sub wings skip theirs — so the
//! per-butterfly branches of the seed implementation disappear from the
//! inner loops. q < 2^31 gives plenty of u64 headroom (4q < 2^33, and the
//! Shoup product a·w < 2^33·2^31 < 2^64). The forward pass finishes with one
//! full-reduction sweep; the inverse folds the final Gentleman–Sande stage,
//! the n^{-1} scaling and the full reduction into a single fused pass. Both
//! transforms return **fully reduced** (< q) outputs, bitwise identical to
//! the reference butterflies kept below ([`NttTables::forward_reference`] /
//! [`NttTables::inverse_reference`], the seed implementation retained as the
//! differential-test oracle and bench baseline).

use super::modarith::{bit_reverse, inv_mod, mul_mod};
use super::params::primitive_root_2n;
use super::simd::NttKernel;

/// Precomputed tables for one (q, n) pair.
pub struct NttTables {
    pub q: u64,
    pub n: usize,
    /// ψ^bitrev(i) — forward twiddles in bit-reversed order.
    psi_rev: Vec<u64>,
    /// Shoup companions ⌊psi_rev·2^64/q⌋.
    psi_rev_shoup: Vec<u64>,
    /// ψ^{-bitrev(i)} — inverse twiddles in bit-reversed order.
    inv_psi_rev: Vec<u64>,
    inv_psi_rev_shoup: Vec<u64>,
    /// n^{-1} mod q.
    n_inv: u64,
    n_inv_shoup: u64,
    /// ψ^{-bitrev(1)}·n^{-1} — the final inverse stage's twiddle with the
    /// n^{-1} scaling folded in (§Perf: fused final pass).
    inv_psi_last: u64,
    inv_psi_last_shoup: u64,
}

#[inline(always)]
fn shoup_precompute(w: u64, q: u64) -> u64 {
    (((w as u128) << 64) / q as u128) as u64
}

/// Shoup modular multiplication: `a·w mod q` given `w_shoup = ⌊w·2^64/q⌋`.
/// Result is in [0, q).
#[inline(always)]
pub(crate) fn mul_mod_shoup(a: u64, w: u64, w_shoup: u64, q: u64) -> u64 {
    let r = mul_mod_shoup_lazy(a, w, w_shoup, q);
    if r >= q {
        r - q
    } else {
        r
    }
}

/// Lazy Shoup multiplication: result in [0, 2q) — the deferred conditional
/// subtract of the Harvey butterflies. Valid whenever `a·w < 2^64` (here
/// a < 4q < 2^33 and w < q < 2^31).
#[inline(always)]
pub(crate) fn mul_mod_shoup_lazy(a: u64, w: u64, w_shoup: u64, q: u64) -> u64 {
    let hi = ((a as u128 * w_shoup as u128) >> 64) as u64;
    let r = a.wrapping_mul(w).wrapping_sub(hi.wrapping_mul(q));
    debug_assert!(r < 2 * q);
    r
}

impl NttTables {
    pub fn new(q: u64, n: usize) -> Self {
        assert!(n.is_power_of_two());
        let bits = n.trailing_zeros();
        let psi = primitive_root_2n(q, n);
        let psi_inv = inv_mod(psi, q);
        let mut psi_pows = vec![1u64; n];
        let mut inv_psi_pows = vec![1u64; n];
        for i in 1..n {
            psi_pows[i] = mul_mod(psi_pows[i - 1], psi, q);
            inv_psi_pows[i] = mul_mod(inv_psi_pows[i - 1], psi_inv, q);
        }
        let mut psi_rev = vec![0u64; n];
        let mut inv_psi_rev = vec![0u64; n];
        for i in 0..n {
            psi_rev[i] = psi_pows[bit_reverse(i, bits)];
            inv_psi_rev[i] = inv_psi_pows[bit_reverse(i, bits)];
        }
        let psi_rev_shoup = psi_rev.iter().map(|&w| shoup_precompute(w, q)).collect();
        let inv_psi_rev_shoup = inv_psi_rev.iter().map(|&w| shoup_precompute(w, q)).collect();
        let n_inv = inv_mod(n as u64, q);
        let inv_psi_last = mul_mod(inv_psi_rev[1], n_inv, q);
        NttTables {
            q,
            n,
            psi_rev,
            psi_rev_shoup,
            inv_psi_rev,
            inv_psi_rev_shoup,
            n_inv,
            n_inv_shoup: shoup_precompute(n_inv, q),
            inv_psi_last,
            inv_psi_last_shoup: shoup_precompute(inv_psi_last, q),
        }
    }

    /// In-place forward negacyclic NTT (natural order in, natural order out
    /// with respect to the paired inverse below). Input must be reduced;
    /// output is fully reduced.
    ///
    /// §Perf: Harvey lazy butterflies — values ride in [0, 4q), the only
    /// reduction inside the loop is one conditional subtract of 2q on the
    /// even wing; a single sweep at the end reduces to [0, q). The butterfly
    /// stages run on the process-wide dispatched kernel
    /// ([`crate::ckks::simd::active`]): AVX2 lanes where the host supports
    /// them, the portable scalar loops otherwise — bitwise identical either
    /// way.
    pub fn forward(&self, a: &mut [u64]) {
        let k = crate::ckks::simd::active();
        crate::obs::metrics::ntt_forward();
        crate::obs::metrics::ntt_kernel(k.is_simd());
        self.forward_with(k, a);
    }

    /// [`Self::forward`] on an explicit kernel (differential tests and the
    /// bench drive both dispatch paths through this).
    pub fn forward_with(&self, k: &dyn NttKernel, a: &mut [u64]) {
        assert_eq!(a.len(), self.n);
        let q = self.q;
        let n = self.n;
        let mut t = n;
        let mut m = 1;
        while m < n {
            t >>= 1;
            k.forward_stage(
                a,
                m,
                t,
                &self.psi_rev[m..2 * m],
                &self.psi_rev_shoup[m..2 * m],
                q,
            );
            m <<= 1;
        }
        k.forward_finish(a, q);
    }

    /// In-place inverse negacyclic NTT (inverse of [`Self::forward`]).
    /// Input must be reduced; output is fully reduced.
    ///
    /// §Perf: lazy butterflies keep values in [0, 2q); the final
    /// Gentleman–Sande stage, the n^{-1} scaling and the full reduction are
    /// fused into one pass using the precomputed `ψ^{-bitrev(1)}·n^{-1}`
    /// twiddle — no separate scaling sweep over the array. Stages dispatch
    /// to the same runtime-selected kernel as [`Self::forward`].
    pub fn inverse(&self, a: &mut [u64]) {
        let k = crate::ckks::simd::active();
        crate::obs::metrics::ntt_inverse();
        crate::obs::metrics::ntt_kernel(k.is_simd());
        self.inverse_with(k, a);
    }

    /// [`Self::inverse`] on an explicit kernel.
    pub fn inverse_with(&self, k: &dyn NttKernel, a: &mut [u64]) {
        assert_eq!(a.len(), self.n);
        let q = self.q;
        let n = self.n;
        let mut t = 1;
        let mut m = n;
        while m > 2 {
            let h = m >> 1;
            k.inverse_stage(
                a,
                h,
                t,
                &self.inv_psi_rev[h..2 * h],
                &self.inv_psi_rev_shoup[h..2 * h],
                q,
            );
            t <<= 1;
            m = h;
        }
        // Fused final stage (m = 2): one butterfly pass over the two halves
        // with n^{-1} folded into both wings, fully reducing on the way out.
        let (lo, hi) = a.split_at_mut(n / 2);
        k.inverse_finish(
            lo,
            hi,
            self.n_inv,
            self.n_inv_shoup,
            self.inv_psi_last,
            self.inv_psi_last_shoup,
            q,
        );
    }

    /// The seed (pre-lazy) forward butterflies: fully reduced after every
    /// butterfly. Kept as the differential-test oracle for the lazy rewrite
    /// and as the `perf_hotpath` baseline.
    pub fn forward_reference(&self, a: &mut [u64]) {
        assert_eq!(a.len(), self.n);
        let q = self.q;
        let n = self.n;
        let mut t = n;
        let mut m = 1;
        while m < n {
            t >>= 1;
            for i in 0..m {
                let j1 = 2 * i * t;
                let s = self.psi_rev[m + i];
                let s_shoup = self.psi_rev_shoup[m + i];
                let (lo, hi) = a[j1..j1 + 2 * t].split_at_mut(t);
                for (x, y) in lo.iter_mut().zip(hi.iter_mut()) {
                    let u = *x;
                    let v = mul_mod_shoup(*y, s, s_shoup, q);
                    let sum = u + v;
                    *x = if sum >= q { sum - q } else { sum };
                    *y = if u >= v { u - v } else { u + q - v };
                }
            }
            m <<= 1;
        }
    }

    /// The seed (pre-lazy) inverse butterflies with the separate n^{-1}
    /// sweep. Oracle/baseline companion of [`Self::forward_reference`].
    pub fn inverse_reference(&self, a: &mut [u64]) {
        assert_eq!(a.len(), self.n);
        let q = self.q;
        let n = self.n;
        let mut t = 1;
        let mut m = n;
        while m > 1 {
            let h = m >> 1;
            let mut j1 = 0;
            for i in 0..h {
                let s = self.inv_psi_rev[h + i];
                let s_shoup = self.inv_psi_rev_shoup[h + i];
                let (lo, hi) = a[j1..j1 + 2 * t].split_at_mut(t);
                for (x, y) in lo.iter_mut().zip(hi.iter_mut()) {
                    let u = *x;
                    let v = *y;
                    let sum = u + v;
                    *x = if sum >= q { sum - q } else { sum };
                    let diff = if u >= v { u - v } else { u + q - v };
                    *y = mul_mod_shoup(diff, s, s_shoup, q);
                }
                j1 += 2 * t;
            }
            t <<= 1;
            m = h;
        }
        for x in a.iter_mut() {
            *x = mul_mod_shoup(*x, self.n_inv, self.n_inv_shoup, q);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckks::params::generate_ntt_primes;
    use crate::crypto::prng::ChaChaRng;

    fn naive_negacyclic(a: &[u64], b: &[u64], q: u64) -> Vec<u64> {
        let n = a.len();
        let mut c = vec![0i128; n];
        for i in 0..n {
            for j in 0..n {
                let prod = (a[i] as i128) * (b[j] as i128) % q as i128;
                if i + j < n {
                    c[i + j] = (c[i + j] + prod) % q as i128;
                } else {
                    c[i + j - n] = (c[i + j - n] - prod).rem_euclid(q as i128);
                }
            }
        }
        c.into_iter().map(|x| x.rem_euclid(q as i128) as u64).collect()
    }

    #[test]
    fn forward_inverse_roundtrip() {
        let q = generate_ntt_primes(1)[0];
        for n in [16usize, 256, 1024, 8192] {
            let t = NttTables::new(q, n);
            let mut rng = ChaChaRng::from_seed(n as u64, 0);
            let orig: Vec<u64> = (0..n).map(|_| rng.uniform_u64(q)).collect();
            let mut a = orig.clone();
            t.forward(&mut a);
            assert_ne!(a, orig);
            t.inverse(&mut a);
            assert_eq!(a, orig);
        }
    }

    /// §Perf property test: the lazy-reduction butterflies must produce
    /// fully reduced outputs bitwise equal to the seed (reference)
    /// implementation for every generated prime and the full ring-degree
    /// range, in both directions.
    #[test]
    fn lazy_matches_reference_and_is_fully_reduced() {
        for &q in &generate_ntt_primes(4) {
            for n in [16usize, 64, 256, 1024, 4096, 8192] {
                let t = NttTables::new(q, n);
                let mut rng = ChaChaRng::from_seed(q ^ n as u64, 1);
                let orig: Vec<u64> = (0..n).map(|_| rng.uniform_u64(q)).collect();

                let mut lazy = orig.clone();
                let mut reference = orig.clone();
                t.forward(&mut lazy);
                t.forward_reference(&mut reference);
                assert_eq!(lazy, reference, "forward mismatch q={q} n={n}");
                assert!(
                    lazy.iter().all(|&x| x < q),
                    "forward output not reduced q={q} n={n}"
                );

                t.inverse(&mut lazy);
                t.inverse_reference(&mut reference);
                assert_eq!(lazy, reference, "inverse mismatch q={q} n={n}");
                assert!(
                    lazy.iter().all(|&x| x < q),
                    "inverse output not reduced q={q} n={n}"
                );
                assert_eq!(lazy, orig, "roundtrip mismatch q={q} n={n}");
            }
        }
    }

    /// Boundary stress: all-(q-1) and single-spike inputs exercise the
    /// maximal intermediate values of the lazy bounds analysis.
    #[test]
    fn lazy_extremal_inputs() {
        let q = generate_ntt_primes(1)[0];
        for n in [16usize, 512] {
            let t = NttTables::new(q, n);
            let mut patterns: Vec<Vec<u64>> = vec![vec![q - 1; n], vec![0; n]];
            let mut spike = vec![0u64; n];
            spike[n - 1] = q - 1;
            patterns.push(spike);
            for orig in patterns.drain(..) {
                let mut lazy = orig.clone();
                let mut reference = orig.clone();
                t.forward(&mut lazy);
                t.forward_reference(&mut reference);
                assert_eq!(lazy, reference);
                assert!(lazy.iter().all(|&x| x < q));
                t.inverse(&mut lazy);
                assert_eq!(lazy, orig);
            }
        }
    }

    #[test]
    fn matches_naive_negacyclic_convolution() {
        let q = generate_ntt_primes(2)[1];
        let n = 64;
        let t = NttTables::new(q, n);
        let mut rng = ChaChaRng::from_seed(7, 1);
        let a: Vec<u64> = (0..n).map(|_| rng.uniform_u64(q)).collect();
        let b: Vec<u64> = (0..n).map(|_| rng.uniform_u64(q)).collect();
        let expected = naive_negacyclic(&a, &b, q);
        let mut fa = a.clone();
        let mut fb = b.clone();
        t.forward(&mut fa);
        t.forward(&mut fb);
        let mut fc: Vec<u64> = fa
            .iter()
            .zip(fb.iter())
            .map(|(&x, &y)| mul_mod(x, y, q))
            .collect();
        t.inverse(&mut fc);
        assert_eq!(fc, expected);
    }

    #[test]
    fn negacyclic_wraparound_sign() {
        // X^{n-1} * X = X^n = -1 mod (X^n + 1)
        let q = generate_ntt_primes(1)[0];
        let n = 32;
        let t = NttTables::new(q, n);
        let mut a = vec![0u64; n];
        let mut b = vec![0u64; n];
        a[n - 1] = 1;
        b[1] = 1;
        t.forward(&mut a);
        t.forward(&mut b);
        let mut c: Vec<u64> = a
            .iter()
            .zip(b.iter())
            .map(|(&x, &y)| mul_mod(x, y, q))
            .collect();
        t.inverse(&mut c);
        assert_eq!(c[0], q - 1); // -1
        assert!(c[1..].iter().all(|&x| x == 0));
    }

    #[test]
    fn shoup_mul_matches_plain() {
        let q = generate_ntt_primes(1)[0];
        let mut rng = ChaChaRng::from_seed(3, 3);
        for _ in 0..1000 {
            let a = rng.uniform_u64(q);
            let w = rng.uniform_u64(q);
            let ws = shoup_precompute(w, q);
            assert_eq!(mul_mod_shoup(a, w, ws, q), mul_mod(a, w, q));
            // the lazy variant is reduced-equal
            let lazy = mul_mod_shoup_lazy(a, w, ws, q);
            assert!(lazy < 2 * q);
            assert_eq!(lazy % q, mul_mod(a, w, q));
        }
    }

    #[test]
    fn linearity() {
        let q = generate_ntt_primes(1)[0];
        let n = 128;
        let t = NttTables::new(q, n);
        let mut rng = ChaChaRng::from_seed(9, 0);
        let a: Vec<u64> = (0..n).map(|_| rng.uniform_u64(q)).collect();
        let b: Vec<u64> = (0..n).map(|_| rng.uniform_u64(q)).collect();
        let sum: Vec<u64> = a
            .iter()
            .zip(b.iter())
            .map(|(&x, &y)| super::super::modarith::add_mod(x, y, q))
            .collect();
        let (mut fa, mut fb, mut fs) = (a, b, sum);
        t.forward(&mut fa);
        t.forward(&mut fb);
        t.forward(&mut fs);
        for i in 0..n {
            assert_eq!(fs[i], super::super::modarith::add_mod(fa[i], fb[i], q));
        }
    }
}
