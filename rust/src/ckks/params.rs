//! CKKS parameter set: ring degree, RNS moduli, scales and NTT tables.
//!
//! Moduli are generated deterministically (descending scan from 2^31 for
//! primes ≡ 1 mod 2^14) so the Python AOT path (`python/compile/crypto.py`)
//! derives the *same* moduli without any cross-language data file; a pytest
//! asserts the two lists match via `artifacts/crypto_params.json`.

use super::modarith::{is_prime, pow_mod, Barrett};
use super::ntt::NttTables;

/// Largest ring degree supported by the 2^14 root-of-unity order of the
/// generated primes (q ≡ 1 mod 2^14 ⇒ a primitive 2n-th root exists for all
/// n ≤ 8192).
pub const MAX_N: usize = 8192;

/// The exponent of the aggregation-weight scale Δ_w (α_i is encoded as
/// round(α_i · 2^WEIGHT_BITS); the paper needs exactly one multiplicative
/// depth for this weighting).
pub const WEIGHT_BITS: u32 = 20;

/// Centered-binomial parameter for the error distribution (variance k/2;
/// k = 21 ⇒ σ ≈ 3.24, matching the σ = 3.2 convention of CKKS stacks).
pub const CBD_K: u32 = 21;

/// Generate the first `count` NTT-friendly primes below 2^31 with
/// q ≡ 1 (mod 2^14), scanning downward (deterministic).
pub fn generate_ntt_primes(count: usize) -> Vec<u64> {
    let step = 1u64 << 14;
    let mut primes = Vec::with_capacity(count);
    // Largest candidate ≡ 1 mod 2^14 below 2^31.
    let mut cand = ((1u64 << 31) / step) * step + 1;
    while cand >= (1 << 31) {
        cand -= step;
    }
    while primes.len() < count {
        if is_prime(cand) {
            primes.push(cand);
        }
        cand -= step;
        assert!(cand > 1 << 30, "ran out of 31-bit NTT primes");
    }
    primes
}

/// Find a primitive 2n-th root of unity mod q (q ≡ 1 mod 2n required).
pub fn primitive_root_2n(q: u64, n: usize) -> u64 {
    let order = 2 * n as u64;
    assert_eq!((q - 1) % order, 0, "q-1 must be divisible by 2n");
    let exp = (q - 1) / order;
    // Deterministic scan over candidate bases.
    for base in 2u64.. {
        let psi = pow_mod(base, exp, q);
        // psi has order dividing 2n; it is primitive iff psi^n = -1 mod q.
        if pow_mod(psi, n as u64, q) == q - 1 {
            return psi;
        }
        assert!(base < 1000, "no primitive root found (q not prime?)");
    }
    unreachable!()
}

/// Full CKKS parameter set.
pub struct CkksParams {
    /// Ring degree (power of two). Batch = n/2 packed values.
    pub n: usize,
    /// RNS moduli q_l (31-bit NTT primes).
    pub moduli: Vec<u64>,
    /// Message scale exponent: Δ = 2^scaling_bits.
    pub scaling_bits: u32,
    /// Per-limb NTT tables.
    pub ntt: Vec<NttTables>,
    /// Per-limb Barrett reducers, precomputed once (§Perf: the hot kernels
    /// — `mul_ntt`, `mul_scalar`, the weighted-sum loops — index this table
    /// instead of rebuilding a reducer per limb per call).
    pub barrett: Vec<Barrett>,
    /// CRT reconstruction precomputation: Q, Q_l = Q/q_l, and
    /// inv_l = (Q_l)^{-1} mod q_l.
    pub q_full: u128,
    pub crt_q_div: Vec<u128>,
    pub crt_inv: Vec<u64>,
}

impl std::fmt::Debug for CkksParams {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CkksParams")
            .field("n", &self.n)
            .field("moduli", &self.moduli)
            .field("scaling_bits", &self.scaling_bits)
            .field("log2_q", &self.log2_q())
            .finish()
    }
}

impl CkksParams {
    pub fn new(n: usize, num_limbs: usize, scaling_bits: u32) -> anyhow::Result<Self> {
        anyhow::ensure!(n.is_power_of_two(), "ring degree must be a power of two");
        anyhow::ensure!(n >= 8 && n <= MAX_N, "ring degree out of range");
        anyhow::ensure!(num_limbs >= 1 && num_limbs <= 8, "1..=8 RNS limbs");
        anyhow::ensure!(
            scaling_bits >= 10 && scaling_bits <= 56,
            "scaling bits out of range"
        );
        let moduli = generate_ntt_primes(num_limbs);
        let ntt = moduli.iter().map(|&q| NttTables::new(q, n)).collect();
        let barrett = moduli.iter().map(|&q| Barrett::new(q)).collect();
        let q_full: u128 = moduli.iter().map(|&q| q as u128).product();
        let crt_q_div: Vec<u128> = moduli.iter().map(|&q| q_full / q as u128).collect();
        let crt_inv: Vec<u64> = moduli
            .iter()
            .zip(crt_q_div.iter())
            .map(|(&q, &qd)| super::modarith::inv_mod((qd % q as u128) as u64, q))
            .collect();
        Ok(CkksParams {
            n,
            moduli,
            scaling_bits,
            ntt,
            barrett,
            q_full,
            crt_q_div,
            crt_inv,
        })
    }

    /// Number of RNS limbs.
    pub fn num_limbs(&self) -> usize {
        self.moduli.len()
    }

    /// Message scale Δ.
    pub fn delta(&self) -> f64 {
        (2f64).powi(self.scaling_bits as i32)
    }

    /// Weight scale Δ_w.
    pub fn delta_w(&self) -> f64 {
        (2f64).powi(WEIGHT_BITS as i32)
    }

    /// log2 of the full modulus Q.
    pub fn log2_q(&self) -> f64 {
        self.moduli.iter().map(|&q| (q as f64).log2()).sum()
    }

    /// Serialized bytes per ciphertext: 2 polys × limbs × n coefficients × 4B
    /// (limbs are < 2^31 and stored as u32) + a small header.
    pub fn ciphertext_bytes(&self) -> usize {
        2 * self.num_limbs() * self.n * 4 + serialize_header_bytes()
    }

    /// CRT-reconstruct a coefficient from its per-limb residues, centered
    /// into (-Q/2, Q/2].
    ///
    /// §Perf: each CRT term is < Q, so the accumulator stays < 2Q after an
    /// add and a conditional subtraction keeps it reduced — no u128 modulo
    /// (a slow libcall) in the loop.
    pub fn crt_reconstruct_centered(&self, residues: &[u64]) -> i128 {
        debug_assert_eq!(residues.len(), self.num_limbs());
        let mut acc: u128 = 0;
        for l in 0..self.num_limbs() {
            let t = super::modarith::mul_mod(residues[l], self.crt_inv[l], self.moduli[l]);
            // t < q_l ⇒ t·Q_l < Q; reduce with one comparison.
            acc += t as u128 * self.crt_q_div[l];
            if acc >= self.q_full {
                acc -= self.q_full;
            }
        }
        if acc > self.q_full / 2 {
            acc as i128 - self.q_full as i128
        } else {
            acc as i128
        }
    }

    /// Encode a non-negative scalar weight at Δ_w into per-limb residues
    /// (the aggregation weight α_i of Algorithm 1).
    pub fn encode_weight(&self, alpha: f64) -> Vec<u64> {
        let mut out = Vec::with_capacity(self.num_limbs());
        self.encode_weight_into(alpha, &mut out);
        out
    }

    /// Append the per-limb residues of an encoded weight to `out` — the
    /// allocation-free variant for pooled weight buffers.
    pub fn encode_weight_into(&self, alpha: f64, out: &mut Vec<u64>) {
        assert!(alpha >= 0.0, "aggregation weights are non-negative");
        let w = (alpha * self.delta_w()).round() as u64;
        out.extend(self.moduli.iter().map(|&q| w % q));
    }
}

/// Header bytes used by `serialize.rs` (kept here so the size accounting in
/// `ciphertext_bytes` matches the real wire format): magic(4) version(4)
/// n(4) limbs(4) n_values(4) scale(8) reserved(8).
pub const fn serialize_header_bytes() -> usize {
    36
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primes_are_ntt_friendly() {
        let primes = generate_ntt_primes(8);
        assert_eq!(primes.len(), 8);
        for &q in &primes {
            assert!(q < 1 << 31);
            assert!(q > 1 << 30);
            assert!(is_prime(q));
            assert_eq!((q - 1) % (1 << 14), 0);
        }
        // deterministic + descending + distinct
        let again = generate_ntt_primes(8);
        assert_eq!(primes, again);
        for w in primes.windows(2) {
            assert!(w[0] > w[1]);
        }
    }

    #[test]
    fn primitive_roots() {
        for &q in &generate_ntt_primes(3) {
            for n in [1024usize, 8192] {
                let psi = primitive_root_2n(q, n);
                assert_eq!(pow_mod(psi, n as u64, q), q - 1);
                assert_eq!(pow_mod(psi, 2 * n as u64, q), 1);
            }
        }
    }

    #[test]
    fn params_construct() {
        let p = CkksParams::new(8192, 4, 52).unwrap();
        assert_eq!(p.n, 8192);
        assert_eq!(p.num_limbs(), 4);
        assert!(p.log2_q() > 120.0 && p.log2_q() < 125.0);
        // ciphertext ~256 KiB for the default config
        assert_eq!(p.ciphertext_bytes(), 2 * 4 * 8192 * 4 + 36);
    }

    #[test]
    fn params_validation() {
        assert!(CkksParams::new(1000, 4, 52).is_err()); // not power of two
        assert!(CkksParams::new(16384, 4, 52).is_err()); // too large
        assert!(CkksParams::new(1024, 0, 52).is_err());
        assert!(CkksParams::new(1024, 4, 60).is_err());
    }

    #[test]
    fn crt_reconstruct_small_values() {
        let p = CkksParams::new(1024, 4, 40).unwrap();
        for v in [-12345i128, -1, 0, 1, 99999, 1i128 << 80, -(1i128 << 80)] {
            let residues: Vec<u64> = p
                .moduli
                .iter()
                .map(|&q| {
                    let r = v.rem_euclid(q as i128);
                    r as u64
                })
                .collect();
            assert_eq!(p.crt_reconstruct_centered(&residues), v);
        }
    }

    #[test]
    fn weight_encoding() {
        let p = CkksParams::new(1024, 2, 40).unwrap();
        let w = p.encode_weight(0.5);
        let expect = (0.5 * p.delta_w()).round() as u64;
        for (l, &q) in p.moduli.iter().enumerate() {
            assert_eq!(w[l], expect % q);
        }
    }
}
