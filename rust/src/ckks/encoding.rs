//! CKKS canonical-embedding encoder/decoder.
//!
//! A real vector `v ∈ R^{n/2}` is packed into the slots of a plaintext
//! polynomial by evaluating at the primitive 2n-th roots of unity
//! ζ^{2j+1}, ζ = e^{iπ/n}. Using `E_j = Σ_k c_k ζ^{(2j+1)k} = FFT_n(c_k ζ^k)_j`
//! the map reduces to a twisted complex FFT; conjugate symmetry
//! `E_{n-1-j} = conj(E_j)` keeps coefficients real.
//!
//! Homomorphism: slot values are evaluations, so ciphertext addition adds
//! slots and scalar multiplication scales slots — exactly the two operations
//! Algorithm 1 needs.

use super::params::CkksParams;
use super::poly::RnsPoly;
use std::sync::Arc;

/// Minimal complex number (no num-complex offline).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct C64 {
    pub re: f64,
    pub im: f64,
}

impl C64 {
    #[inline]
    pub fn new(re: f64, im: f64) -> Self {
        C64 { re, im }
    }
    #[inline]
    pub fn conj(self) -> Self {
        C64::new(self.re, -self.im)
    }
    #[inline]
    pub fn mul(self, o: C64) -> Self {
        C64::new(
            self.re * o.re - self.im * o.im,
            self.re * o.im + self.im * o.re,
        )
    }
    #[inline]
    pub fn add(self, o: C64) -> Self {
        C64::new(self.re + o.re, self.im + o.im)
    }
    #[inline]
    pub fn sub(self, o: C64) -> Self {
        C64::new(self.re - o.re, self.im - o.im)
    }
}

/// Iterative radix-2 complex FFT with precomputed twiddles.
pub struct Fft {
    n: usize,
    /// Twiddles ω^k, ω = e^{2πi/n}, k < n/2.
    twiddles: Vec<C64>,
}

impl Fft {
    pub fn new(n: usize) -> Self {
        assert!(n.is_power_of_two());
        let twiddles = (0..n / 2)
            .map(|k| {
                let t = 2.0 * std::f64::consts::PI * k as f64 / n as f64;
                C64::new(t.cos(), t.sin())
            })
            .collect();
        Fft { n, twiddles }
    }

    fn bit_reverse_permute(&self, a: &mut [C64]) {
        let bits = self.n.trailing_zeros();
        for i in 0..self.n {
            let j = super::modarith::bit_reverse(i, bits);
            if i < j {
                a.swap(i, j);
            }
        }
    }

    /// Forward FFT: `A_j = Σ_k a_k ω^{jk}` (ω = e^{2πi/n}).
    pub fn forward(&self, a: &mut [C64]) {
        assert_eq!(a.len(), self.n);
        self.bit_reverse_permute(a);
        let mut len = 2;
        while len <= self.n {
            let step = self.n / len;
            for start in (0..self.n).step_by(len) {
                for k in 0..len / 2 {
                    let w = self.twiddles[k * step];
                    let u = a[start + k];
                    let v = a[start + k + len / 2].mul(w);
                    a[start + k] = u.add(v);
                    a[start + k + len / 2] = u.sub(v);
                }
            }
            len <<= 1;
        }
    }

    /// Inverse FFT: `a_k = (1/n) Σ_j A_j ω^{-jk}`.
    pub fn inverse(&self, a: &mut [C64]) {
        // conj → forward → conj, then scale.
        for x in a.iter_mut() {
            *x = x.conj();
        }
        self.forward(a);
        let inv_n = 1.0 / self.n as f64;
        for x in a.iter_mut() {
            *x = C64::new(x.re * inv_n, -x.im * inv_n);
        }
    }
}

/// The CKKS encoder for a fixed parameter set.
pub struct Encoder {
    params: Arc<CkksParams>,
    fft: Fft,
    /// Twist factors ζ^k (ζ = e^{iπ/n}), k < n.
    zeta: Vec<C64>,
    /// Inverse twist ζ^{-k}.
    zeta_inv: Vec<C64>,
}

/// Pooled staging buffers for [`Encoder::encode_into`]: the FFT evaluation
/// vector and the wide signed coefficients, reused across chunks so the
/// per-round encode fan-out allocates nothing after warm-up.
#[derive(Default)]
pub struct EncodeScratch {
    e: Vec<C64>,
    coeffs: Vec<i128>,
}

impl Encoder {
    pub fn new(params: Arc<CkksParams>) -> Self {
        let n = params.n;
        let fft = Fft::new(n);
        let zeta: Vec<C64> = (0..n)
            .map(|k| {
                let t = std::f64::consts::PI * k as f64 / n as f64;
                C64::new(t.cos(), t.sin())
            })
            .collect();
        let zeta_inv = zeta.iter().map(|z| z.conj()).collect();
        Encoder {
            params,
            fft,
            zeta,
            zeta_inv,
        }
    }

    /// Slots per plaintext.
    pub fn batch(&self) -> usize {
        self.params.n / 2
    }

    /// Encode up to `batch()` real values at scale Δ into an RNS plaintext.
    pub fn encode(&self, values: &[f64]) -> RnsPoly {
        let mut scratch = EncodeScratch::default();
        let mut out = RnsPoly::zero(&self.params);
        self.encode_into(values, &mut scratch, &mut out);
        out
    }

    /// [`Self::encode`] into a caller-owned plaintext, staging the FFT
    /// evaluation vector and wide coefficients in pooled scratch —
    /// allocation-free after warm-up (§Perf: the codec's per-chunk encrypt
    /// fan-out goes through here so steady-state rounds stop allocating).
    /// Bitwise identical to [`Self::encode`].
    pub fn encode_into(&self, values: &[f64], scratch: &mut EncodeScratch, out: &mut RnsPoly) {
        let n = self.params.n;
        let half = n / 2;
        assert!(values.len() <= half, "too many values for one plaintext");
        let EncodeScratch { e, coeffs } = scratch;
        // Conjugate-symmetric evaluation vector.
        e.clear();
        e.resize(n, C64::default());
        for (j, &v) in values.iter().enumerate() {
            e[j] = C64::new(v, 0.0);
            e[n - 1 - j] = C64::new(v, 0.0); // conj of a real value
        }
        self.fft.inverse(e);
        let delta = self.params.delta();
        coeffs.clear();
        coeffs.extend((0..n).map(|k| {
            let u = e[k].mul(self.zeta_inv[k]);
            // u is real up to fp error by conjugate symmetry.
            (u.re * delta).round() as i128
        }));
        out.assign_signed_wide(&self.params, coeffs);
    }

    /// Decode `n_values` slots from a coefficient-domain plaintext at the
    /// given aggregate scale (Δ for fresh, Δ·Δ_w after weighting).
    pub fn decode(&self, pt: &RnsPoly, n_values: usize, scale: f64) -> Vec<f64> {
        let n = self.params.n;
        assert!(n_values <= n / 2);
        let centered = pt.to_centered_coeffs(&self.params);
        let mut u: Vec<C64> = (0..n)
            .map(|k| C64::new(centered[k] as f64, 0.0).mul(self.zeta[k]))
            .collect();
        self.fft.forward(&mut u);
        (0..n_values).map(|j| u[j].re / scale).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::prng::ChaChaRng;

    fn encoder(n: usize, bits: u32) -> Encoder {
        Encoder::new(Arc::new(CkksParams::new(n, 4, bits).unwrap()))
    }

    #[test]
    fn fft_roundtrip() {
        let fft = Fft::new(256);
        let mut rng = ChaChaRng::from_seed(1, 0);
        let orig: Vec<C64> = (0..256)
            .map(|_| C64::new(rng.uniform_f64() - 0.5, rng.uniform_f64() - 0.5))
            .collect();
        let mut a = orig.clone();
        fft.forward(&mut a);
        fft.inverse(&mut a);
        for (x, y) in a.iter().zip(orig.iter()) {
            assert!((x.re - y.re).abs() < 1e-12 && (x.im - y.im).abs() < 1e-12);
        }
    }

    #[test]
    fn fft_matches_dft_definition() {
        let n = 16;
        let fft = Fft::new(n);
        let mut rng = ChaChaRng::from_seed(2, 0);
        let a: Vec<C64> = (0..n)
            .map(|_| C64::new(rng.uniform_f64(), rng.uniform_f64()))
            .collect();
        let mut fast = a.clone();
        fft.forward(&mut fast);
        for j in 0..n {
            let mut acc = C64::default();
            for (k, &x) in a.iter().enumerate() {
                let t = 2.0 * std::f64::consts::PI * (j * k) as f64 / n as f64;
                acc = acc.add(x.mul(C64::new(t.cos(), t.sin())));
            }
            assert!((acc.re - fast[j].re).abs() < 1e-9);
            assert!((acc.im - fast[j].im).abs() < 1e-9);
        }
    }

    #[test]
    fn encode_decode_roundtrip() {
        let enc = encoder(1024, 40);
        let mut rng = ChaChaRng::from_seed(3, 0);
        let values: Vec<f64> = (0..enc.batch()).map(|_| rng.uniform_f64() * 8.0 - 4.0).collect();
        let pt = enc.encode(&values);
        let dec = enc.decode(&pt, values.len(), enc.params.delta());
        for (a, b) in values.iter().zip(dec.iter()) {
            assert!((a - b).abs() < 1e-8, "{a} vs {b}");
        }
    }

    #[test]
    fn roundtrip_error_shrinks_with_scale() {
        // The Table-6 accuracy mechanism: fewer scaling bits ⇒ larger
        // quantization error.
        let err_at = |bits: u32| {
            let enc = encoder(512, bits);
            let values: Vec<f64> = (0..enc.batch()).map(|i| (i as f64) * 1e-3).collect();
            let pt = enc.encode(&values);
            let dec = enc.decode(&pt, values.len(), enc.params.delta());
            values
                .iter()
                .zip(dec.iter())
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f64, f64::max)
        };
        let coarse = err_at(14);
        let fine = err_at(40);
        assert!(coarse > 100.0 * fine, "coarse {coarse} fine {fine}");
    }

    #[test]
    fn encoding_is_additive() {
        let enc = encoder(256, 40);
        let a: Vec<f64> = (0..enc.batch()).map(|i| i as f64 * 0.01).collect();
        let b: Vec<f64> = (0..enc.batch()).map(|i| 1.0 - i as f64 * 0.02).collect();
        let mut pa = enc.encode(&a);
        let pb = enc.encode(&b);
        pa.add_assign(&pb, &enc.params);
        let dec = enc.decode(&pa, enc.batch(), enc.params.delta());
        for i in 0..enc.batch() {
            assert!((dec[i] - (a[i] + b[i])).abs() < 1e-8);
        }
    }

    #[test]
    fn scalar_multiplication_scales_slots() {
        let enc = encoder(256, 40);
        let a: Vec<f64> = (0..enc.batch()).map(|i| (i as f64 - 64.0) * 0.05).collect();
        let mut pa = enc.encode(&a);
        let alpha = 0.375;
        let w = enc.params.encode_weight(alpha);
        pa.mul_scalar(&w, &enc.params);
        let scale = enc.params.delta() * enc.params.delta_w();
        let dec = enc.decode(&pa, enc.batch(), scale);
        for i in 0..enc.batch() {
            assert!(
                (dec[i] - alpha * a[i]).abs() < 1e-6,
                "{} vs {}",
                dec[i],
                alpha * a[i]
            );
        }
    }

    #[test]
    fn partial_fill_decodes_cleanly() {
        let enc = encoder(256, 40);
        let values = vec![1.5, -2.25, 3.0];
        let pt = enc.encode(&values);
        let dec = enc.decode(&pt, 3, enc.params.delta());
        for (a, b) in values.iter().zip(dec.iter()) {
            assert!((a - b).abs() < 1e-8);
        }
    }
}
