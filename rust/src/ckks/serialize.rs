//! Ciphertext (de)serialization: the wire format whose byte counts feed every
//! communication-overhead table in the paper.
//!
//! Layout (little-endian):
//! ```text
//! magic    u32   = 0x434B4B53 ("CKKS")
//! version  u32   = 1
//! n        u32   ring degree
//! limbs    u32   number of RNS limbs
//! n_values u32   packed value count
//! scale    f64   aggregate scale
//! reserved u32 ×2 (pad to the 32-byte header of params::serialize_header_bytes)
//! body: c0 then c1, limb-major, each coefficient as u32 (moduli < 2^31)
//! ```
//!
//! Besides the dense full format there are two uplink views (DESIGN.md §14):
//! limb-range **shards** ("CKSH") carrying a slice of both polynomials, and
//! the **seed-expanded compressed** form ("CKSS") for symmetric seeded
//! ciphertexts — the same 32-byte header followed by the 32-byte a-seed and
//! only the c0 limbs, ≈half the dense size.

use super::encrypt::Ciphertext;
use super::params::{serialize_header_bytes, CkksParams};
use super::poly::RnsPoly;

const MAGIC: u32 = 0x434B_4B53;
const VERSION: u32 = 1;

/// How uplink ciphertexts travel: dense `(c0, c1)` limbs, or the
/// seed-expanded compressed form `seed ‖ c0` for symmetric seeded
/// ciphertexts. Negotiated in the HELLO/WELCOME handshake; both sides of a
/// session must agree or the connection fails loudly.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CtWire {
    /// Both polynomials on the wire (public-key encryption; the default).
    Dense,
    /// `seed ‖ c0_limbs` — the receiver re-expands the a-part
    /// ([`super::encrypt::expand_ct_a_limb`]). Requires single-key
    /// symmetric encryption.
    Seed,
}

impl CtWire {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "dense" => Some(CtWire::Dense),
            "seed" => Some(CtWire::Seed),
            _ => None,
        }
    }

    /// Default mode, overridable via `FEDML_HE_CT_WIRE` (CI reruns the
    /// whole suite with `FEDML_HE_CT_WIRE=seed`).
    pub fn env_default() -> Self {
        match std::env::var("FEDML_HE_CT_WIRE") {
            Ok(v) => CtWire::parse(&v).unwrap_or(CtWire::Dense),
            Err(_) => CtWire::Dense,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            CtWire::Dense => "dense",
            CtWire::Seed => "seed",
        }
    }

    /// Stable u32 code carried in the HELLO/WELCOME payloads.
    pub fn wire_code(self) -> u32 {
        match self {
            CtWire::Dense => 0,
            CtWire::Seed => 1,
        }
    }

    pub fn from_wire_code(code: u32) -> Option<Self> {
        match code {
            0 => Some(CtWire::Dense),
            1 => Some(CtWire::Seed),
            _ => None,
        }
    }
}

/// Serialize a ciphertext.
pub fn ciphertext_to_bytes(ct: &Ciphertext) -> Vec<u8> {
    assert!(!ct.c0.ntt_form && !ct.c1.ntt_form);
    let n = ct.c0.n;
    let limbs = ct.c0.num_limbs();
    let mut out = Vec::with_capacity(serialize_header_bytes() + 2 * limbs * n * 4);
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&(n as u32).to_le_bytes());
    out.extend_from_slice(&(limbs as u32).to_le_bytes());
    out.extend_from_slice(&(ct.n_values as u32).to_le_bytes());
    out.extend_from_slice(&ct.scale.to_le_bytes());
    out.extend_from_slice(&0u32.to_le_bytes());
    out.extend_from_slice(&0u32.to_le_bytes());
    debug_assert_eq!(out.len(), serialize_header_bytes());
    for poly in [&ct.c0, &ct.c1] {
        for &c in poly.flat() {
            debug_assert!(c < 1 << 31);
            out.extend_from_slice(&(c as u32).to_le_bytes());
        }
    }
    out
}

fn read_u32(bytes: &[u8], off: &mut usize) -> anyhow::Result<u32> {
    anyhow::ensure!(bytes.len() >= *off + 4, "truncated buffer");
    let v = u32::from_le_bytes(bytes[*off..*off + 4].try_into().unwrap());
    *off += 4;
    Ok(v)
}

/// Deserialize a ciphertext; validates header against `params`.
pub fn ciphertext_from_bytes(bytes: &[u8], params: &CkksParams) -> anyhow::Result<Ciphertext> {
    let mut off = 0usize;
    anyhow::ensure!(read_u32(bytes, &mut off)? == MAGIC, "bad magic");
    anyhow::ensure!(read_u32(bytes, &mut off)? == VERSION, "bad version");
    let n = read_u32(bytes, &mut off)? as usize;
    let limbs = read_u32(bytes, &mut off)? as usize;
    let n_values = read_u32(bytes, &mut off)? as usize;
    anyhow::ensure!(bytes.len() >= off + 8, "truncated header");
    let scale = f64::from_le_bytes(bytes[off..off + 8].try_into().unwrap());
    off += 8;
    off += 8; // reserved
    anyhow::ensure!(n == params.n, "ring degree mismatch");
    anyhow::ensure!(limbs == params.num_limbs(), "limb count mismatch");
    anyhow::ensure!(n_values <= n / 2, "n_values out of range");
    let body = 2 * limbs * n * 4;
    anyhow::ensure!(bytes.len() == off + body, "bad body length");

    let mut polys = Vec::with_capacity(2);
    for _ in 0..2 {
        let mut data = Vec::with_capacity(limbs * n);
        for l in 0..limbs {
            let q = params.moduli[l];
            for _ in 0..n {
                let c = read_u32(bytes, &mut off)? as u64;
                anyhow::ensure!(c < q, "coefficient out of range");
                data.push(c);
            }
        }
        polys.push(RnsPoly::from_flat(n, limbs, data, false));
    }
    let c1 = polys.pop().unwrap();
    let c0 = polys.pop().unwrap();
    Ok(Ciphertext {
        c0,
        c1,
        n_values,
        scale,
        a_seed: None,
    })
}

// ---------------------------------------------------------------------------
// Per-shard limb views (the agg_engine wire format): a shard transfers only
// the limb range it aggregates, so sharded intake moves exactly the full
// ciphertext body split across links with a small per-shard header.

const SHARD_MAGIC: u32 = 0x434B_5348; // "CKSH"

/// A deserialized limb-range view of one ciphertext.
#[derive(Debug, Clone, PartialEq)]
pub struct CiphertextShard {
    /// Limb range [lo, hi) carried by this shard.
    pub lo: usize,
    pub hi: usize,
    pub n_values: usize,
    pub scale: f64,
    /// c0 residue vectors for limbs lo..hi (each length n).
    pub c0_limbs: Vec<Vec<u64>>,
    /// c1 residue vectors for limbs lo..hi.
    pub c1_limbs: Vec<Vec<u64>>,
}

impl CiphertextShard {
    /// Scatter this shard's limbs into a full ciphertext skeleton.
    pub fn scatter_into(&self, ct: &mut Ciphertext) {
        for (k, l) in (self.lo..self.hi).enumerate() {
            ct.c0.limb_mut(l).copy_from_slice(&self.c0_limbs[k]);
            ct.c1.limb_mut(l).copy_from_slice(&self.c1_limbs[k]);
        }
        ct.n_values = self.n_values;
        ct.scale = self.scale;
    }
}

/// Header bytes of the shard wire format: magic(4) version(4) n(4) lo(4)
/// hi(4) n_values(4) scale(8).
pub const fn shard_header_bytes() -> usize {
    32
}

/// Serialized size of a limb-range shard.
pub fn shard_wire_bytes(params: &CkksParams, lo: usize, hi: usize) -> usize {
    shard_header_bytes() + 2 * (hi - lo) * params.n * 4
}

/// Serialize limbs [lo, hi) of a ciphertext.
pub fn ciphertext_shard_to_bytes(ct: &Ciphertext, lo: usize, hi: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(shard_header_bytes() + 2 * (hi - lo) * ct.c0.n * 4);
    ciphertext_shard_append(ct, lo, hi, &mut out);
    out
}

/// Append the shard wire format for limbs [lo, hi) to `out`. The transport
/// frame writer serializes straight into its (reused) frame buffer — no
/// intermediate per-frame vector.
pub fn ciphertext_shard_append(ct: &Ciphertext, lo: usize, hi: usize, out: &mut Vec<u8>) {
    assert!(!ct.c0.ntt_form && !ct.c1.ntt_form);
    assert!(lo < hi && hi <= ct.c0.num_limbs(), "bad limb range");
    let n = ct.c0.n;
    out.reserve(shard_header_bytes() + 2 * (hi - lo) * n * 4);
    let start = out.len();
    out.extend_from_slice(&SHARD_MAGIC.to_le_bytes());
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&(n as u32).to_le_bytes());
    out.extend_from_slice(&(lo as u32).to_le_bytes());
    out.extend_from_slice(&(hi as u32).to_le_bytes());
    out.extend_from_slice(&(ct.n_values as u32).to_le_bytes());
    out.extend_from_slice(&ct.scale.to_le_bytes());
    debug_assert_eq!(out.len() - start, shard_header_bytes());
    for poly in [&ct.c0, &ct.c1] {
        for l in lo..hi {
            for &c in poly.limb(l) {
                debug_assert!(c < 1 << 31);
                out.extend_from_slice(&(c as u32).to_le_bytes());
            }
        }
    }
}

/// Deserialize a limb-range shard; validates header against `params`.
pub fn ciphertext_shard_from_bytes(
    bytes: &[u8],
    params: &CkksParams,
) -> anyhow::Result<CiphertextShard> {
    let mut off = 0usize;
    anyhow::ensure!(read_u32(bytes, &mut off)? == SHARD_MAGIC, "bad shard magic");
    anyhow::ensure!(read_u32(bytes, &mut off)? == VERSION, "bad version");
    let n = read_u32(bytes, &mut off)? as usize;
    let lo = read_u32(bytes, &mut off)? as usize;
    let hi = read_u32(bytes, &mut off)? as usize;
    let n_values = read_u32(bytes, &mut off)? as usize;
    anyhow::ensure!(bytes.len() >= off + 8, "truncated shard header");
    let scale = f64::from_le_bytes(bytes[off..off + 8].try_into().unwrap());
    off += 8;
    anyhow::ensure!(n == params.n, "ring degree mismatch");
    anyhow::ensure!(lo < hi && hi <= params.num_limbs(), "limb range out of bounds");
    anyhow::ensure!(n_values <= n / 2, "n_values out of range");
    anyhow::ensure!(
        bytes.len() == off + 2 * (hi - lo) * n * 4,
        "bad shard body length"
    );

    let mut polys: Vec<Vec<Vec<u64>>> = Vec::with_capacity(2);
    for _ in 0..2 {
        let mut limb_vecs = Vec::with_capacity(hi - lo);
        for l in lo..hi {
            let q = params.moduli[l];
            let mut v = Vec::with_capacity(n);
            for _ in 0..n {
                let c = read_u32(bytes, &mut off)? as u64;
                anyhow::ensure!(c < q, "coefficient out of range");
                v.push(c);
            }
            limb_vecs.push(v);
        }
        polys.push(limb_vecs);
    }
    let c1_limbs = polys.pop().unwrap();
    let c0_limbs = polys.pop().unwrap();
    Ok(CiphertextShard {
        lo,
        hi,
        n_values,
        scale,
        c0_limbs,
        c1_limbs,
    })
}

// ---------------------------------------------------------------------------
// Seed-expanded compressed ciphertexts (the `--ct-wire seed` uplink format):
// the 32-byte shard-style header (limb range pinned to the full ciphertext),
// the 32-byte a-seed, then only the c0 limbs. The receiver re-expands the
// uniform a-part from the seed — lazily, limb by limb, inside the
// aggregation shards — so the wire carries half the dense payload.

const SEEDED_MAGIC: u32 = 0x434B_5353; // "CKSS"

/// Header bytes of the compressed format: the 32-byte shard header plus the
/// 32-byte ciphertext seed.
pub const fn seeded_header_bytes() -> usize {
    shard_header_bytes() + 32
}

/// Serialized size of a seed-expanded compressed ciphertext.
pub fn seeded_wire_bytes(params: &CkksParams) -> usize {
    seeded_header_bytes() + params.num_limbs() * params.n * 4
}

/// Append the compressed wire form of a symmetric seeded ciphertext
/// (`seed ‖ c0_limbs`). Panics if the ciphertext carries no seed. Counts
/// the bytes saved versus the dense full-range shard form.
pub fn ciphertext_seeded_append(ct: &Ciphertext, out: &mut Vec<u8>) {
    let seed = ct
        .a_seed
        .expect("seeded wire form requires a symmetric seeded ciphertext");
    assert!(!ct.c0.ntt_form, "c0 must be in coefficient domain");
    let n = ct.c0.n;
    let limbs = ct.c0.num_limbs();
    out.reserve(seeded_header_bytes() + limbs * n * 4);
    let start = out.len();
    out.extend_from_slice(&SEEDED_MAGIC.to_le_bytes());
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&(n as u32).to_le_bytes());
    out.extend_from_slice(&0u32.to_le_bytes()); // lo: always the full range
    out.extend_from_slice(&(limbs as u32).to_le_bytes()); // hi
    out.extend_from_slice(&(ct.n_values as u32).to_le_bytes());
    out.extend_from_slice(&ct.scale.to_le_bytes());
    out.extend_from_slice(&seed);
    debug_assert_eq!(out.len() - start, seeded_header_bytes());
    for l in 0..limbs {
        for &c in ct.c0.limb(l) {
            debug_assert!(c < 1 << 31);
            out.extend_from_slice(&(c as u32).to_le_bytes());
        }
    }
    let dense = shard_header_bytes() as u64 + 2 * (limbs * n * 4) as u64;
    crate::obs::metrics::uplink_bytes_saved(dense - (out.len() - start) as u64);
}

/// Allocating wrapper over [`ciphertext_seeded_append`].
pub fn ciphertext_seeded_to_bytes(ct: &Ciphertext) -> Vec<u8> {
    let mut out = Vec::with_capacity(seeded_wire_bytes_for(ct));
    ciphertext_seeded_append(ct, &mut out);
    out
}

fn seeded_wire_bytes_for(ct: &Ciphertext) -> usize {
    seeded_header_bytes() + ct.c0.num_limbs() * ct.c0.n * 4
}

/// Deserialize a compressed seeded ciphertext; validates the header against
/// `params` (strict full limb range — oversized or partial limb counts are
/// rejected), every c0 coefficient against its modulus, and the exact body
/// length (a truncated seed fails here too). Returns the **lazy** form: c0
/// populated, `a_seed` set, and `c1` the empty 0-limb NTT-domain
/// placeholder that [`Ciphertext::expand_a`] or the aggregation shards
/// materialize on demand.
pub fn ciphertext_seeded_from_bytes(
    bytes: &[u8],
    params: &CkksParams,
) -> anyhow::Result<Ciphertext> {
    let mut off = 0usize;
    anyhow::ensure!(
        read_u32(bytes, &mut off)? == SEEDED_MAGIC,
        "bad seeded ct magic"
    );
    anyhow::ensure!(read_u32(bytes, &mut off)? == VERSION, "bad version");
    let n = read_u32(bytes, &mut off)? as usize;
    let lo = read_u32(bytes, &mut off)? as usize;
    let hi = read_u32(bytes, &mut off)? as usize;
    let n_values = read_u32(bytes, &mut off)? as usize;
    anyhow::ensure!(bytes.len() >= off + 8, "truncated seeded ct header");
    let scale = f64::from_le_bytes(bytes[off..off + 8].try_into().unwrap());
    off += 8;
    anyhow::ensure!(n == params.n, "ring degree mismatch");
    anyhow::ensure!(
        lo == 0 && hi == params.num_limbs(),
        "seeded ct must cover the full limb range"
    );
    anyhow::ensure!(n_values <= n / 2, "n_values out of range");
    anyhow::ensure!(bytes.len() >= off + 32, "truncated ciphertext seed");
    let mut seed = [0u8; 32];
    seed.copy_from_slice(&bytes[off..off + 32]);
    off += 32;
    anyhow::ensure!(
        bytes.len() == off + hi * n * 4,
        "bad seeded ct body length"
    );
    let mut data = Vec::with_capacity(hi * n);
    for l in 0..hi {
        let q = params.moduli[l];
        for _ in 0..n {
            let c = read_u32(bytes, &mut off)? as u64;
            anyhow::ensure!(c < q, "coefficient out of range");
            data.push(c);
        }
    }
    Ok(Ciphertext {
        c0: RnsPoly::from_flat(n, hi, data, false),
        c1: RnsPoly::from_flat(n, 0, Vec::new(), true),
        n_values,
        scale,
        a_seed: Some(seed),
    })
}

// ---------------------------------------------------------------------------
// Key material (the out-of-band distribution file of the serve/join flow):
// raw RNS polynomials with their domain flag, coefficients as u32 (< 2^31).

const POLY_MAGIC: u32 = 0x434B_504C; // "CKPL"

/// Append one RNS polynomial: magic(4) version(4) n(4) limbs(4) ntt(1)
/// pad(3) body (limb-major u32 coefficients).
pub fn rns_poly_append(p: &RnsPoly, out: &mut Vec<u8>) {
    let limbs = p.num_limbs();
    out.reserve(20 + limbs * p.n * 4);
    out.extend_from_slice(&POLY_MAGIC.to_le_bytes());
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&(p.n as u32).to_le_bytes());
    out.extend_from_slice(&(limbs as u32).to_le_bytes());
    out.push(u8::from(p.ntt_form));
    out.extend_from_slice(&[0u8; 3]);
    for &c in p.flat() {
        debug_assert!(c < 1 << 31);
        out.extend_from_slice(&(c as u32).to_le_bytes());
    }
}

/// Read one RNS polynomial written by [`rns_poly_append`], advancing `off`.
/// Validates the shape against `params` and every coefficient against its
/// limb modulus.
pub fn rns_poly_read(
    bytes: &[u8],
    off: &mut usize,
    params: &CkksParams,
) -> anyhow::Result<RnsPoly> {
    anyhow::ensure!(read_u32(bytes, off)? == POLY_MAGIC, "bad poly magic");
    anyhow::ensure!(read_u32(bytes, off)? == VERSION, "bad poly version");
    let n = read_u32(bytes, off)? as usize;
    let limbs = read_u32(bytes, off)? as usize;
    anyhow::ensure!(bytes.len() >= *off + 4, "truncated poly header");
    let ntt = bytes[*off];
    anyhow::ensure!(ntt <= 1, "bad poly domain flag {ntt}");
    anyhow::ensure!(
        bytes[*off + 1..*off + 4] == [0u8; 3],
        "bad poly header padding"
    );
    *off += 4;
    anyhow::ensure!(n == params.n, "ring degree mismatch");
    anyhow::ensure!(limbs == params.num_limbs(), "limb count mismatch");
    let mut data = Vec::with_capacity(limbs * n);
    for l in 0..limbs {
        let q = params.moduli[l];
        for _ in 0..n {
            let c = read_u32(bytes, off)? as u64;
            anyhow::ensure!(c < q, "poly coefficient out of range");
            data.push(c);
        }
    }
    Ok(RnsPoly::from_flat(n, limbs, data, ntt == 1))
}

/// Append a public key (`b` then `a`, both NTT form).
pub fn public_key_append(pk: &super::keys::PublicKey, out: &mut Vec<u8>) {
    rns_poly_append(&pk.b_ntt, out);
    rns_poly_append(&pk.a_ntt, out);
}

/// Read a public key written by [`public_key_append`], advancing `off`.
pub fn public_key_read(
    bytes: &[u8],
    off: &mut usize,
    params: &CkksParams,
) -> anyhow::Result<super::keys::PublicKey> {
    let b_ntt = rns_poly_read(bytes, off, params)?;
    let a_ntt = rns_poly_read(bytes, off, params)?;
    anyhow::ensure!(
        b_ntt.ntt_form && a_ntt.ntt_form,
        "public key halves must be in NTT form"
    );
    Ok(super::keys::PublicKey { b_ntt, a_ntt })
}

/// Append a secret key (`s`, NTT form).
pub fn secret_key_append(sk: &super::keys::SecretKey, out: &mut Vec<u8>) {
    rns_poly_append(&sk.s_ntt, out);
}

/// Read a secret key written by [`secret_key_append`], advancing `off`.
pub fn secret_key_read(
    bytes: &[u8],
    off: &mut usize,
    params: &CkksParams,
) -> anyhow::Result<super::keys::SecretKey> {
    let s_ntt = rns_poly_read(bytes, off, params)?;
    anyhow::ensure!(s_ntt.ntt_form, "secret key must be in NTT form");
    Ok(super::keys::SecretKey { s_ntt })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckks::encoding::Encoder;
    use crate::ckks::encrypt::encrypt;
    use crate::ckks::keys::keygen;
    use crate::crypto::prng::ChaChaRng;
    use std::sync::Arc;

    #[test]
    fn roundtrip_and_size() {
        let params = Arc::new(CkksParams::new(256, 4, 40).unwrap());
        let encoder = Encoder::new(params.clone());
        let mut rng = ChaChaRng::from_seed(1, 0);
        let (pk, _) = keygen(&params, &mut rng);
        let m: Vec<f64> = (0..128).map(|i| i as f64 * 0.01).collect();
        let ct = encrypt(&params, &pk, &encoder.encode(&m), 128, &mut rng);
        let bytes = ciphertext_to_bytes(&ct);
        assert_eq!(bytes.len(), params.ciphertext_bytes());
        let back = ciphertext_from_bytes(&bytes, &params).unwrap();
        assert_eq!(back, ct);
    }

    #[test]
    fn corruption_detected() {
        let params = Arc::new(CkksParams::new(128, 2, 30).unwrap());
        let encoder = Encoder::new(params.clone());
        let mut rng = ChaChaRng::from_seed(2, 0);
        let (pk, _) = keygen(&params, &mut rng);
        let ct = encrypt(&params, &pk, &encoder.encode(&[1.0]), 1, &mut rng);
        let bytes = ciphertext_to_bytes(&ct);
        // bad magic
        let mut b = bytes.clone();
        b[0] ^= 0xFF;
        assert!(ciphertext_from_bytes(&b, &params).is_err());
        // truncation
        assert!(ciphertext_from_bytes(&bytes[..bytes.len() - 1], &params).is_err());
        // out-of-range coefficient
        let mut b = bytes.clone();
        let hdr = crate::ckks::params::serialize_header_bytes();
        b[hdr..hdr + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(ciphertext_from_bytes(&b, &params).is_err());
    }

    #[test]
    fn shard_views_tile_the_ciphertext() {
        let params = Arc::new(CkksParams::new(256, 4, 40).unwrap());
        let encoder = Encoder::new(params.clone());
        let mut rng = ChaChaRng::from_seed(4, 0);
        let (pk, _) = keygen(&params, &mut rng);
        let m: Vec<f64> = (0..128).map(|i| i as f64 * 0.02 - 1.0).collect();
        let ct = encrypt(&params, &pk, &encoder.encode(&m), 128, &mut rng);

        // split limbs into two shards: [0,2) and [2,4)
        let a = ciphertext_shard_to_bytes(&ct, 0, 2);
        let b = ciphertext_shard_to_bytes(&ct, 2, 4);
        assert_eq!(a.len(), shard_wire_bytes(&params, 0, 2));
        // shard bodies sum to the full-ciphertext body
        let full_body = params.ciphertext_bytes() - crate::ckks::params::serialize_header_bytes();
        assert_eq!(
            (a.len() - shard_header_bytes()) + (b.len() - shard_header_bytes()),
            full_body
        );

        // reassemble into a skeleton and compare bitwise
        let sa = ciphertext_shard_from_bytes(&a, &params).unwrap();
        let sb = ciphertext_shard_from_bytes(&b, &params).unwrap();
        let mut rebuilt = Ciphertext {
            c0: RnsPoly::zero(&params),
            c1: RnsPoly::zero(&params),
            n_values: 0,
            scale: 0.0,
            a_seed: None,
        };
        sa.scatter_into(&mut rebuilt);
        sb.scatter_into(&mut rebuilt);
        assert_eq!(rebuilt, ct);
    }

    #[test]
    fn shard_corruption_detected() {
        let params = Arc::new(CkksParams::new(128, 3, 30).unwrap());
        let encoder = Encoder::new(params.clone());
        let mut rng = ChaChaRng::from_seed(5, 0);
        let (pk, _) = keygen(&params, &mut rng);
        let ct = encrypt(&params, &pk, &encoder.encode(&[1.0]), 1, &mut rng);
        let bytes = ciphertext_shard_to_bytes(&ct, 1, 3);
        let mut bad = bytes.clone();
        bad[0] ^= 0xFF;
        assert!(ciphertext_shard_from_bytes(&bad, &params).is_err());
        assert!(ciphertext_shard_from_bytes(&bytes[..bytes.len() - 2], &params).is_err());
        // out-of-range coefficient in the body
        let mut bad = bytes.clone();
        let hdr = shard_header_bytes();
        bad[hdr..hdr + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(ciphertext_shard_from_bytes(&bad, &params).is_err());
        // full-format bytes are not a shard
        let full = ciphertext_to_bytes(&ct);
        assert!(ciphertext_shard_from_bytes(&full, &params).is_err());
    }

    #[test]
    fn shard_append_writes_after_existing_prefix() {
        let params = Arc::new(CkksParams::new(128, 2, 30).unwrap());
        let encoder = Encoder::new(params.clone());
        let mut rng = ChaChaRng::from_seed(6, 0);
        let (pk, _) = keygen(&params, &mut rng);
        let ct = encrypt(&params, &pk, &encoder.encode(&[0.5]), 1, &mut rng);
        let direct = ciphertext_shard_to_bytes(&ct, 0, 2);
        let mut buf = vec![0xAAu8; 7];
        ciphertext_shard_append(&ct, 0, 2, &mut buf);
        assert_eq!(&buf[..7], &[0xAA; 7]);
        assert_eq!(&buf[7..], &direct[..]);
    }

    #[test]
    fn ct_wire_parse_codes_roundtrip() {
        for mode in [CtWire::Dense, CtWire::Seed] {
            assert_eq!(CtWire::parse(mode.as_str()), Some(mode));
            assert_eq!(CtWire::from_wire_code(mode.wire_code()), Some(mode));
        }
        assert_eq!(CtWire::parse("gzip"), None);
        assert_eq!(CtWire::from_wire_code(7), None);
    }

    #[test]
    fn seeded_expand_oracle_matches_dense_twin_bitwise() {
        // The core gate: serialize a symmetric seeded ct compressed, parse
        // it lazily, expand the a-part from the seed — the result must be
        // bitwise-identical to the dense twin built with the same seeded a,
        // including on the dense wire.
        let params = Arc::new(CkksParams::new(256, 4, 40).unwrap());
        let encoder = Encoder::new(params.clone());
        let mut rng = ChaChaRng::from_seed(31, 0);
        let (_pk, sk) = keygen(&params, &mut rng);
        let m: Vec<f64> = (0..128).map(|i| i as f64 * 0.01 - 0.4).collect();
        let ct = crate::ckks::encrypt::encrypt_sym_seeded(
            &params,
            &sk,
            &encoder.encode(&m),
            128,
            &mut rng,
        );

        let bytes = ciphertext_seeded_to_bytes(&ct);
        assert_eq!(bytes.len(), seeded_wire_bytes(&params));
        let mut lazy = ciphertext_seeded_from_bytes(&bytes, &params).unwrap();
        assert_eq!(lazy.c1.num_limbs(), 0);
        lazy.expand_a(&params);
        assert_eq!(lazy, ct);

        // An independent limb expansion agrees with the client-side c1.
        let mut limb = vec![0u64; params.n];
        for l in 0..params.num_limbs() {
            expand_ct_a_limb(&ct.a_seed.unwrap(), l, params.moduli[l], &mut limb);
            assert_eq!(&limb[..], ct.c1.limb(l));
        }

        // And the dense shard wire of the expanded ct matches the twin's.
        let limbs = params.num_limbs();
        let mut d1 = lazy.clone();
        let mut d2 = ct.clone();
        d1.c1.from_ntt(&params);
        d2.c1.from_ntt(&params);
        assert_eq!(
            ciphertext_shard_to_bytes(&d1, 0, limbs),
            ciphertext_shard_to_bytes(&d2, 0, limbs)
        );
    }

    #[test]
    fn seeded_wire_is_about_half_the_dense_shard() {
        let params = Arc::new(CkksParams::new(1024, 6, 40).unwrap());
        let dense = shard_wire_bytes(&params, 0, params.num_limbs());
        let seeded = seeded_wire_bytes(&params);
        assert!(
            (seeded as f64) < 0.55 * dense as f64,
            "seeded {seeded} vs dense {dense}"
        );
    }

    #[test]
    fn seeded_corruption_and_malformed_inputs_rejected() {
        let params = Arc::new(CkksParams::new(128, 3, 30).unwrap());
        let encoder = Encoder::new(params.clone());
        let mut rng = ChaChaRng::from_seed(32, 0);
        let (_pk, sk) = keygen(&params, &mut rng);
        let ct = crate::ckks::encrypt::encrypt_sym_seeded(
            &params,
            &sk,
            &encoder.encode(&[1.0]),
            1,
            &mut rng,
        );
        let bytes = ciphertext_seeded_to_bytes(&ct);
        assert!(ciphertext_seeded_from_bytes(&bytes, &params).is_ok());

        // bad magic
        let mut bad = bytes.clone();
        bad[0] ^= 0xFF;
        assert!(ciphertext_seeded_from_bytes(&bad, &params).is_err());
        // truncated inside the seed
        assert!(
            ciphertext_seeded_from_bytes(&bytes[..shard_header_bytes() + 16], &params).is_err()
        );
        // truncated body / trailing garbage
        assert!(ciphertext_seeded_from_bytes(&bytes[..bytes.len() - 1], &params).is_err());
        let mut long = bytes.clone();
        long.push(0);
        assert!(ciphertext_seeded_from_bytes(&long, &params).is_err());
        // oversized limb count (hi beyond the parameter set)
        let mut bad = bytes.clone();
        bad[16..20].copy_from_slice(&(params.num_limbs() as u32 + 1).to_le_bytes());
        assert!(ciphertext_seeded_from_bytes(&bad, &params).is_err());
        // partial limb range is not a valid compressed ct
        let mut bad = bytes.clone();
        bad[12..16].copy_from_slice(&1u32.to_le_bytes());
        assert!(ciphertext_seeded_from_bytes(&bad, &params).is_err());
        // out-of-range coefficient
        let mut bad = bytes.clone();
        let hdr = seeded_header_bytes();
        bad[hdr..hdr + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(ciphertext_seeded_from_bytes(&bad, &params).is_err());
        // cross-format confusion: dense shard bytes are not a seeded ct
        // and vice versa
        let mut dense_ct = ct.clone();
        dense_ct.c1.from_ntt(&params);
        let shard = ciphertext_shard_to_bytes(&dense_ct, 0, params.num_limbs());
        assert!(ciphertext_seeded_from_bytes(&shard, &params).is_err());
        assert!(ciphertext_shard_from_bytes(&bytes, &params).is_err());
        // single-byte corruption sweep over the header + seed region
        for i in 0..seeded_header_bytes() {
            let mut b = bytes.clone();
            b[i] ^= 0x01;
            // Either rejected, or (for n_values/scale/seed bytes) parses to
            // a different ciphertext — never silently equal.
            if let Ok(mut parsed) = ciphertext_seeded_from_bytes(&b, &params) {
                parsed.expand_a(&params);
                let mut orig = ciphertext_seeded_from_bytes(&bytes, &params).unwrap();
                orig.expand_a(&params);
                assert_ne!(parsed, orig, "flip at byte {i} was silently absorbed");
            }
        }
    }

    #[test]
    fn key_material_roundtrips_and_validates() {
        let params = Arc::new(CkksParams::new(256, 3, 30).unwrap());
        let mut rng = ChaChaRng::from_seed(9, 0);
        let (pk, sk) = keygen(&params, &mut rng);
        let mut bytes = Vec::new();
        public_key_append(&pk, &mut bytes);
        secret_key_append(&sk, &mut bytes);
        let mut off = 0usize;
        let pk2 = public_key_read(&bytes, &mut off, &params).unwrap();
        let sk2 = secret_key_read(&bytes, &mut off, &params).unwrap();
        assert_eq!(off, bytes.len());
        assert_eq!(pk2.b_ntt, pk.b_ntt);
        assert_eq!(pk2.a_ntt, pk.a_ntt);
        assert_eq!(sk2.s_ntt, sk.s_ntt);

        // the recovered key pair actually decrypts
        let encoder = Encoder::new(params.clone());
        let ct = encrypt(&params, &pk2, &encoder.encode(&[0.625]), 1, &mut rng);
        let dec = crate::ckks::decrypt(&params, &sk2, &ct);
        let vals = encoder.decode(&dec, 1, ct.scale);
        assert!((vals[0] - 0.625).abs() < 1e-4);

        // truncation / bad magic / coefficient out of range are rejected
        let mut off = 0usize;
        assert!(public_key_read(&bytes[..bytes.len() - 1], &mut off, &params).is_ok());
        let mut off = 0usize;
        assert!(secret_key_read(&bytes[..10], &mut off, &params).is_err());
        let mut bad = bytes.clone();
        bad[0] ^= 0xFF;
        let mut off = 0usize;
        assert!(public_key_read(&bad, &mut off, &params).is_err());
        let mut bad = bytes.clone();
        bad[20..24].copy_from_slice(&u32::MAX.to_le_bytes());
        let mut off = 0usize;
        assert!(public_key_read(&bad, &mut off, &params).is_err());
        // a coefficient-domain poly is rejected as key material
        let mut coeff = sk.s_ntt.clone();
        coeff.from_ntt(&params);
        let mut b = Vec::new();
        rns_poly_append(&coeff, &mut b);
        let mut off = 0usize;
        assert!(secret_key_read(&b, &mut off, &params).is_err());
        // wrong params
        let other = CkksParams::new(512, 3, 30).unwrap();
        let mut off = 0usize;
        assert!(public_key_read(&bytes, &mut off, &other).is_err());
    }

    #[test]
    fn wrong_params_rejected() {
        let p1 = Arc::new(CkksParams::new(128, 2, 30).unwrap());
        let p2 = Arc::new(CkksParams::new(256, 2, 30).unwrap());
        let encoder = Encoder::new(p1.clone());
        let mut rng = ChaChaRng::from_seed(3, 0);
        let (pk, _) = keygen(&p1, &mut rng);
        let ct = encrypt(&p1, &pk, &encoder.encode(&[1.0]), 1, &mut rng);
        let bytes = ciphertext_to_bytes(&ct);
        assert!(ciphertext_from_bytes(&bytes, &p2).is_err());
    }
}
