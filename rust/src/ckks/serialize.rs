//! Ciphertext (de)serialization: the wire format whose byte counts feed every
//! communication-overhead table in the paper.
//!
//! Layout (little-endian):
//! ```text
//! magic    u32   = 0x434B4B53 ("CKKS")
//! version  u32   = 1
//! n        u32   ring degree
//! limbs    u32   number of RNS limbs
//! n_values u32   packed value count
//! scale    f64   aggregate scale
//! reserved u32 ×2 (pad to the 32-byte header of params::serialize_header_bytes)
//! body: c0 then c1, limb-major, each coefficient as u32 (moduli < 2^31)
//! ```

use super::encrypt::Ciphertext;
use super::params::{serialize_header_bytes, CkksParams};
use super::poly::RnsPoly;

const MAGIC: u32 = 0x434B_4B53;
const VERSION: u32 = 1;

/// Serialize a ciphertext.
pub fn ciphertext_to_bytes(ct: &Ciphertext) -> Vec<u8> {
    assert!(!ct.c0.ntt_form && !ct.c1.ntt_form);
    let n = ct.c0.n;
    let limbs = ct.c0.limbs.len();
    let mut out = Vec::with_capacity(serialize_header_bytes() + 2 * limbs * n * 4);
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&(n as u32).to_le_bytes());
    out.extend_from_slice(&(limbs as u32).to_le_bytes());
    out.extend_from_slice(&(ct.n_values as u32).to_le_bytes());
    out.extend_from_slice(&ct.scale.to_le_bytes());
    out.extend_from_slice(&0u32.to_le_bytes());
    out.extend_from_slice(&0u32.to_le_bytes());
    debug_assert_eq!(out.len(), serialize_header_bytes());
    for poly in [&ct.c0, &ct.c1] {
        for limb in &poly.limbs {
            for &c in limb {
                debug_assert!(c < 1 << 31);
                out.extend_from_slice(&(c as u32).to_le_bytes());
            }
        }
    }
    out
}

fn read_u32(bytes: &[u8], off: &mut usize) -> anyhow::Result<u32> {
    anyhow::ensure!(bytes.len() >= *off + 4, "truncated buffer");
    let v = u32::from_le_bytes(bytes[*off..*off + 4].try_into().unwrap());
    *off += 4;
    Ok(v)
}

/// Deserialize a ciphertext; validates header against `params`.
pub fn ciphertext_from_bytes(bytes: &[u8], params: &CkksParams) -> anyhow::Result<Ciphertext> {
    let mut off = 0usize;
    anyhow::ensure!(read_u32(bytes, &mut off)? == MAGIC, "bad magic");
    anyhow::ensure!(read_u32(bytes, &mut off)? == VERSION, "bad version");
    let n = read_u32(bytes, &mut off)? as usize;
    let limbs = read_u32(bytes, &mut off)? as usize;
    let n_values = read_u32(bytes, &mut off)? as usize;
    anyhow::ensure!(bytes.len() >= off + 8, "truncated header");
    let scale = f64::from_le_bytes(bytes[off..off + 8].try_into().unwrap());
    off += 8;
    off += 8; // reserved
    anyhow::ensure!(n == params.n, "ring degree mismatch");
    anyhow::ensure!(limbs == params.num_limbs(), "limb count mismatch");
    anyhow::ensure!(n_values <= n / 2, "n_values out of range");
    let body = 2 * limbs * n * 4;
    anyhow::ensure!(bytes.len() == off + body, "bad body length");

    let mut polys = Vec::with_capacity(2);
    for _ in 0..2 {
        let mut limb_vecs = Vec::with_capacity(limbs);
        for l in 0..limbs {
            let q = params.moduli[l];
            let mut v = Vec::with_capacity(n);
            for _ in 0..n {
                let c = read_u32(bytes, &mut off)? as u64;
                anyhow::ensure!(c < q, "coefficient out of range");
                v.push(c);
            }
            limb_vecs.push(v);
        }
        polys.push(RnsPoly {
            n,
            limbs: limb_vecs,
            ntt_form: false,
        });
    }
    let c1 = polys.pop().unwrap();
    let c0 = polys.pop().unwrap();
    Ok(Ciphertext {
        c0,
        c1,
        n_values,
        scale,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckks::encoding::Encoder;
    use crate::ckks::encrypt::encrypt;
    use crate::ckks::keys::keygen;
    use crate::crypto::prng::ChaChaRng;
    use std::sync::Arc;

    #[test]
    fn roundtrip_and_size() {
        let params = Arc::new(CkksParams::new(256, 4, 40).unwrap());
        let encoder = Encoder::new(params.clone());
        let mut rng = ChaChaRng::from_seed(1, 0);
        let (pk, _) = keygen(&params, &mut rng);
        let m: Vec<f64> = (0..128).map(|i| i as f64 * 0.01).collect();
        let ct = encrypt(&params, &pk, &encoder.encode(&m), 128, &mut rng);
        let bytes = ciphertext_to_bytes(&ct);
        assert_eq!(bytes.len(), params.ciphertext_bytes());
        let back = ciphertext_from_bytes(&bytes, &params).unwrap();
        assert_eq!(back, ct);
    }

    #[test]
    fn corruption_detected() {
        let params = Arc::new(CkksParams::new(128, 2, 30).unwrap());
        let encoder = Encoder::new(params.clone());
        let mut rng = ChaChaRng::from_seed(2, 0);
        let (pk, _) = keygen(&params, &mut rng);
        let ct = encrypt(&params, &pk, &encoder.encode(&[1.0]), 1, &mut rng);
        let bytes = ciphertext_to_bytes(&ct);
        // bad magic
        let mut b = bytes.clone();
        b[0] ^= 0xFF;
        assert!(ciphertext_from_bytes(&b, &params).is_err());
        // truncation
        assert!(ciphertext_from_bytes(&bytes[..bytes.len() - 1], &params).is_err());
        // out-of-range coefficient
        let mut b = bytes.clone();
        let hdr = crate::ckks::params::serialize_header_bytes();
        b[hdr..hdr + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(ciphertext_from_bytes(&b, &params).is_err());
    }

    #[test]
    fn wrong_params_rejected() {
        let p1 = Arc::new(CkksParams::new(128, 2, 30).unwrap());
        let p2 = Arc::new(CkksParams::new(256, 2, 30).unwrap());
        let encoder = Encoder::new(p1.clone());
        let mut rng = ChaChaRng::from_seed(3, 0);
        let (pk, _) = keygen(&p1, &mut rng);
        let ct = encrypt(&p1, &pk, &encoder.encode(&[1.0]), 1, &mut rng);
        let bytes = ciphertext_to_bytes(&ct);
        assert!(ciphertext_from_bytes(&bytes, &p2).is_err());
    }
}
