//! Threshold (multiparty) CKKS — Appendix B of the paper.
//!
//! n-of-n additive variant: each party holds a ternary share `s_k`; the
//! joint secret is `s = Σ s_k`. Key agreement and decryption are interactive:
//!
//! 1. **Key agreement**: a common reference polynomial `a` is derived from a
//!    public seed (CRS); each party publishes `b_k = -(a·s_k) + e_k`; the
//!    joint public key is `(Σ b_k, a)`.
//! 2. **Distributed decryption**: for `ct = (c0, c1)` each party publishes a
//!    partial decryption `d_k = c1·s_k + e_smudge` (the smudging noise hides
//!    `s_k` from the combiner); the plaintext is `c0 + Σ d_k`.
//!
//! Optional t-of-n escrow: each party's share can additionally be
//! Shamir-split ([`crate::crypto::shamir`]) so a quorum can reconstruct a
//! dropped party's share (dropout robustness for long-running FL tasks).

use super::encrypt::Ciphertext;
use super::keys::PublicKey;
use super::params::CkksParams;
use super::poly::RnsPoly;
use crate::crypto::prng::ChaChaRng;

/// Smudging-noise CBD parameter (variance 8× the base error).
const SMUDGE_K: u32 = 8 * super::params::CBD_K;

/// One party's state in the threshold protocol.
pub struct ThresholdParty {
    pub id: usize,
    /// Secret share s_k (NTT form).
    pub s_ntt: RnsPoly,
    /// Published key-agreement share b_k (NTT form).
    pub b_share_ntt: RnsPoly,
}

/// Derive the common reference polynomial `a` from a public seed.
pub fn common_reference(params: &CkksParams, crs_seed: u64) -> RnsPoly {
    let mut rng = ChaChaRng::from_seed(crs_seed, 0xC0DE);
    let mut a = RnsPoly::sample_uniform(params, &mut rng);
    a.to_ntt(params);
    a
}

/// Round 1 of key agreement: create a party and its public share.
pub fn party_keygen(
    params: &CkksParams,
    id: usize,
    a_ntt: &RnsPoly,
    rng: &mut ChaChaRng,
) -> ThresholdParty {
    let mut s = RnsPoly::sample_ternary(params, rng);
    s.to_ntt(params);
    let mut e = RnsPoly::sample_error(params, rng);
    e.to_ntt(params);
    let mut b = a_ntt.mul_ntt(&s, params);
    b.negate(params);
    b.add_assign(&e, params);
    ThresholdParty {
        id,
        s_ntt: s,
        b_share_ntt: b,
    }
}

/// Round 2: combine the published shares into the joint public key.
pub fn combine_public_key(
    params: &CkksParams,
    a_ntt: &RnsPoly,
    shares: &[&RnsPoly],
) -> PublicKey {
    assert!(!shares.is_empty());
    let mut b = shares[0].clone();
    for s in &shares[1..] {
        b.add_assign(s, params);
    }
    PublicKey {
        b_ntt: b,
        a_ntt: a_ntt.clone(),
    }
}

/// Serialize a secret share (NTT-form limbs, coefficients < 2^31 as u32 LE,
/// limb-major) for Shamir escrow: the key authority splits these bytes
/// t-of-n across the other parties so a quorum can resurrect a dropped
/// party's share ([`crate::crypto::shamir::split_bytes`]).
pub fn share_to_bytes(share: &RnsPoly) -> Vec<u8> {
    assert!(share.ntt_form, "secret shares are held in NTT form");
    let mut out = Vec::with_capacity(share.num_limbs() * share.n * 4);
    for &c in share.flat() {
        debug_assert!(c < 1 << 31);
        out.extend_from_slice(&(c as u32).to_le_bytes());
    }
    out
}

/// Rebuild an escrowed secret share from its serialized bytes.
pub fn share_from_bytes(params: &CkksParams, bytes: &[u8]) -> anyhow::Result<RnsPoly> {
    let l = params.num_limbs();
    anyhow::ensure!(
        bytes.len() == l * params.n * 4,
        "escrowed share has wrong length ({} bytes for n={} limbs={})",
        bytes.len(),
        params.n,
        l
    );
    let mut data = Vec::with_capacity(l * params.n);
    let mut off = 0usize;
    for limb_idx in 0..l {
        let q = params.moduli[limb_idx];
        for _ in 0..params.n {
            let c = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()) as u64;
            anyhow::ensure!(c < q, "escrowed coefficient out of range");
            data.push(c);
            off += 4;
        }
    }
    Ok(RnsPoly::from_flat(params.n, l, data, true))
}

/// A party's partial decryption of a ciphertext (coefficient domain).
pub fn partial_decrypt(
    params: &CkksParams,
    party: &ThresholdParty,
    ct: &Ciphertext,
    rng: &mut ChaChaRng,
) -> RnsPoly {
    let mut c1 = ct.c1.clone();
    // Symmetric seeded ciphertexts already carry c1 in NTT form (the
    // expanded `a` is sampled directly in the NTT domain); only forward
    // coefficient-domain inputs.
    if !c1.ntt_form {
        c1.to_ntt(params);
    }
    let mut d = c1.mul_ntt(&party.s_ntt, params);
    d.from_ntt(params);
    // Smudging noise: hides s_k from whoever combines the partials.
    let smudge: Vec<i64> = (0..params.n).map(|_| rng.cbd(SMUDGE_K)).collect();
    let e = RnsPoly::from_signed(params, &smudge);
    d.add_assign(&e, params);
    d
}

/// Combine `c0` with all partial decryptions into the plaintext polynomial.
pub fn combine_partials(
    params: &CkksParams,
    ct: &Ciphertext,
    partials: &[RnsPoly],
) -> RnsPoly {
    let mut m = ct.c0.clone();
    for d in partials {
        m.add_assign(d, params);
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckks::encoding::Encoder;
    use crate::ckks::encrypt::encrypt;
    use crate::ckks::ops::weighted_sum;
    use std::sync::Arc;

    fn run_threshold(n_parties: usize) {
        let params = Arc::new(CkksParams::new(512, 4, 45).unwrap());
        let encoder = Encoder::new(params.clone());
        let a = common_reference(&params, 99);
        let mut rng = ChaChaRng::from_seed(13, 0);
        let parties: Vec<ThresholdParty> = (0..n_parties)
            .map(|k| party_keygen(&params, k, &a, &mut rng))
            .collect();
        let shares: Vec<&RnsPoly> = parties.iter().map(|p| &p.b_share_ntt).collect();
        let pk = combine_public_key(&params, &a, &shares);

        // Encrypt under the joint key, weighted-aggregate, then decrypt
        // collaboratively — the Fig. 12 workload.
        let models: Vec<Vec<f64>> = (0..3)
            .map(|c| (0..256).map(|i| ((i * (c + 1)) as f64 * 0.01).cos()).collect())
            .collect();
        let alphas = [0.2, 0.3, 0.5];
        let cts: Vec<Ciphertext> = models
            .iter()
            .map(|m| encrypt(&params, &pk, &encoder.encode(m), m.len(), &mut rng))
            .collect();
        let agg = weighted_sum(&cts, &alphas, &params);

        let partials: Vec<RnsPoly> = parties
            .iter()
            .map(|p| partial_decrypt(&params, p, &agg, &mut rng))
            .collect();
        let m = combine_partials(&params, &agg, &partials);
        let dec = encoder.decode(&m, 256, agg.scale);
        for j in 0..256 {
            let expected: f64 = (0..3).map(|c| alphas[c] * models[c][j]).sum();
            assert!(
                (dec[j] - expected).abs() < 1e-4,
                "slot {j}: {} vs {expected}",
                dec[j]
            );
        }
    }

    #[test]
    fn two_party_threshold_decrypts() {
        run_threshold(2);
    }

    #[test]
    fn five_party_threshold_decrypts() {
        run_threshold(5);
    }

    #[test]
    fn missing_partial_fails() {
        let params = Arc::new(CkksParams::new(256, 3, 40).unwrap());
        let encoder = Encoder::new(params.clone());
        let a = common_reference(&params, 7);
        let mut rng = ChaChaRng::from_seed(14, 0);
        let parties: Vec<ThresholdParty> = (0..3)
            .map(|k| party_keygen(&params, k, &a, &mut rng))
            .collect();
        let shares: Vec<&RnsPoly> = parties.iter().map(|p| &p.b_share_ntt).collect();
        let pk = combine_public_key(&params, &a, &shares);
        let values = vec![1.0; 128];
        let ct = encrypt(&params, &pk, &encoder.encode(&values), 128, &mut rng);
        // only 2 of 3 partials
        let partials: Vec<RnsPoly> = parties[..2]
            .iter()
            .map(|p| partial_decrypt(&params, p, &ct, &mut rng))
            .collect();
        let m = combine_partials(&params, &ct, &partials);
        let dec = encoder.decode(&m, 128, ct.scale);
        let max_err = values
            .iter()
            .zip(dec.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        assert!(max_err > 1.0, "partial set should not decrypt");
    }

    #[test]
    fn seeded_symmetric_ct_threshold_roundtrip() {
        // Property (satellite): threshold share-escrow decryption round-trips
        // symmetric seeded ciphertexts — the NTT-form c1 produced by
        // `encrypt_sym_seeded` (and by lazy wire expansion) feeds straight
        // into `partial_decrypt` without a redundant forward NTT.
        use crate::ckks::encrypt::encrypt_sym_seeded;
        use crate::ckks::keys::SecretKey;
        use crate::ckks::serialize::{ciphertext_seeded_from_bytes, ciphertext_seeded_to_bytes};

        let params = Arc::new(CkksParams::new(512, 4, 45).unwrap());
        let encoder = Encoder::new(params.clone());
        let a = common_reference(&params, 31);
        let mut rng = ChaChaRng::from_seed(41, 0);
        let parties: Vec<ThresholdParty> = (0..3)
            .map(|k| party_keygen(&params, k, &a, &mut rng))
            .collect();
        // The joint secret is the sum of the party shares; a client holding
        // it can use the symmetric seeded encryption path directly.
        let mut s = parties[0].s_ntt.clone();
        for p in &parties[1..] {
            s.add_assign(&p.s_ntt, &params);
        }
        let joint_sk = SecretKey { s_ntt: s };

        let values: Vec<f64> = (0..200).map(|i| (i as f64 * 0.017).sin()).collect();
        let ct = encrypt_sym_seeded(
            &params,
            &joint_sk,
            &encoder.encode(&values),
            values.len(),
            &mut rng,
        );
        assert!(ct.c1.ntt_form && ct.a_seed.is_some());

        // Direct threshold decryption of the fresh seeded ciphertext.
        let mut d_rng = ChaChaRng::from_seed(42, 0);
        let partials: Vec<RnsPoly> = parties
            .iter()
            .map(|p| partial_decrypt(&params, p, &ct, &mut d_rng))
            .collect();
        let m = combine_partials(&params, &ct, &partials);
        let dec = encoder.decode(&m, values.len(), ct.scale);
        for (j, (&v, &d)) in values.iter().zip(dec.iter()).enumerate() {
            assert!((v - d).abs() < 1e-4, "slot {j}: {v} vs {d}");
        }

        // And through the compressed wire: serialize, re-expand, decrypt.
        let bytes = ciphertext_seeded_to_bytes(&ct);
        let mut wire_ct = ciphertext_seeded_from_bytes(&bytes, &params).unwrap();
        wire_ct.expand_a(&params);
        let mut d_rng = ChaChaRng::from_seed(42, 0);
        let partials: Vec<RnsPoly> = parties
            .iter()
            .map(|p| partial_decrypt(&params, p, &wire_ct, &mut d_rng))
            .collect();
        let m2 = combine_partials(&params, &wire_ct, &partials);
        assert_eq!(m, m2, "wire round-trip must be bitwise identical");
    }

    #[test]
    fn crs_is_deterministic() {
        let params = Arc::new(CkksParams::new(128, 2, 30).unwrap());
        assert_eq!(common_reference(&params, 5), common_reference(&params, 5));
        assert_ne!(common_reference(&params, 5), common_reference(&params, 6));
    }

    #[test]
    fn share_escrow_roundtrip() {
        // Shamir-escrow a party's serialized secret share and recover it.
        use crate::crypto::shamir;
        let params = Arc::new(CkksParams::new(64, 2, 30).unwrap());
        let a = common_reference(&params, 1);
        let mut rng = ChaChaRng::from_seed(15, 0);
        let party = party_keygen(&params, 0, &a, &mut rng);
        // serialize the share's first limb as bytes
        let bytes: Vec<u8> = party.s_ntt.limb(0)
            .iter()
            .flat_map(|&c| (c as u32).to_le_bytes())
            .collect();
        let shares = shamir::split_bytes(&bytes, 2, 3, &mut rng);
        let rec = shamir::reconstruct_bytes(&[&shares[0], &shares[2]], bytes.len());
        assert_eq!(rec, bytes);
    }
}
