//! Key generation: ternary secret, RLWE public key.
//!
//! The public key is kept in NTT form (both halves) because encryption
//! multiplies it by the ephemeral ternary `u` — the hot loop of client-side
//! encryption.

use super::params::CkksParams;
use super::poly::RnsPoly;
use crate::crypto::prng::ChaChaRng;

/// Secret key `s` (ternary), stored in NTT form for decryption products.
#[derive(Debug, Clone)]
pub struct SecretKey {
    pub s_ntt: RnsPoly,
}

/// Public key `(b, a) = (-(a·s) + e, a)`, both halves in NTT form.
#[derive(Debug, Clone)]
pub struct PublicKey {
    pub b_ntt: RnsPoly,
    pub a_ntt: RnsPoly,
}

/// Generate a single-key pair.
pub fn keygen(params: &CkksParams, rng: &mut ChaChaRng) -> (PublicKey, SecretKey) {
    let mut s = RnsPoly::sample_ternary(params, rng);
    s.to_ntt(params);

    let mut a = RnsPoly::sample_uniform(params, rng);
    a.to_ntt(params);

    let mut e = RnsPoly::sample_error(params, rng);
    e.to_ntt(params);

    // b = -(a·s) + e
    let mut b = a.mul_ntt(&s, params);
    b.negate(params);
    b.add_assign(&e, params);

    (
        PublicKey {
            b_ntt: b,
            a_ntt: a,
        },
        SecretKey { s_ntt: s },
    )
}

/// Generate a public key for a *given* secret and common reference `a`
/// (used by the threshold protocol where all parties share `a`).
pub fn keygen_with(
    params: &CkksParams,
    s_ntt: &RnsPoly,
    a_ntt: &RnsPoly,
    rng: &mut ChaChaRng,
) -> PublicKey {
    let mut e = RnsPoly::sample_error(params, rng);
    e.to_ntt(params);
    let mut b = a_ntt.mul_ntt(s_ntt, params);
    b.negate(params);
    b.add_assign(&e, params);
    PublicKey {
        b_ntt: b,
        a_ntt: a_ntt.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keygen_relation_holds() {
        // b + a·s = e must be small.
        let params = CkksParams::new(256, 3, 30).unwrap();
        let mut rng = ChaChaRng::from_seed(1, 0);
        let (pk, sk) = keygen(&params, &mut rng);
        let mut lhs = pk.a_ntt.mul_ntt(&sk.s_ntt, &params);
        lhs.add_assign(&pk.b_ntt, &params);
        lhs.from_ntt(&params);
        let coeffs = lhs.to_centered_coeffs(&params);
        assert!(coeffs.iter().all(|&c| c.abs() <= 21), "error not small");
        assert!(coeffs.iter().any(|&c| c != 0), "error must be nonzero");
    }

    #[test]
    fn distinct_keys_from_distinct_randomness() {
        let params = CkksParams::new(64, 2, 30).unwrap();
        let mut r1 = ChaChaRng::from_seed(1, 0);
        let mut r2 = ChaChaRng::from_seed(2, 0);
        let (pk1, sk1) = keygen(&params, &mut r1);
        let (pk2, sk2) = keygen(&params, &mut r2);
        assert_ne!(sk1.s_ntt, sk2.s_ntt);
        assert_ne!(pk1.a_ntt, pk2.a_ntt);
    }

    #[test]
    fn keygen_with_shared_a() {
        let params = CkksParams::new(64, 2, 30).unwrap();
        let mut rng = ChaChaRng::from_seed(3, 0);
        let mut a = RnsPoly::sample_uniform(&params, &mut rng);
        a.to_ntt(&params);
        let mut s = RnsPoly::sample_ternary(&params, &mut rng);
        s.to_ntt(&params);
        let pk = keygen_with(&params, &s, &a, &mut rng);
        assert_eq!(pk.a_ntt, a);
        let mut lhs = pk.a_ntt.mul_ntt(&s, &params);
        lhs.add_assign(&pk.b_ntt, &params);
        lhs.from_ntt(&params);
        assert!(lhs
            .to_centered_coeffs(&params)
            .iter()
            .all(|&c| c.abs() <= 21));
    }
}
