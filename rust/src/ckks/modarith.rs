//! Modular arithmetic over word-sized primes (q < 2^31), plus deterministic
//! Miller–Rabin primality testing used by NTT-prime generation.
//!
//! The 31-bit limb bound is a deliberate cross-layer contract: products fit
//! in u64 (`a·b < 2^62`), which is exactly what the L1 Pallas kernel can do
//! in `uint64`, so the Rust aggregator and the XLA artifact compute
//! bit-identical results.

/// `a + b mod q` (inputs reduced).
#[inline(always)]
pub fn add_mod(a: u64, b: u64, q: u64) -> u64 {
    let s = a + b;
    if s >= q {
        s - q
    } else {
        s
    }
}

/// `a - b mod q` (inputs reduced).
#[inline(always)]
pub fn sub_mod(a: u64, b: u64, q: u64) -> u64 {
    if a >= b {
        a - b
    } else {
        a + q - b
    }
}

/// `a * b mod q`, multiplying in u64 — **contract: q < 2^31** so the product
/// of reduced inputs stays below 2^62 and cannot overflow. Moduli at or
/// above 2^32 would wrap silently; callers with wider moduli (the primality
/// test) must use the u128-widened `mul_mod_wide` below instead.
#[inline(always)]
pub fn mul_mod(a: u64, b: u64, q: u64) -> u64 {
    debug_assert!(q < 1 << 31, "mul_mod contract: q < 2^31 (got {q})");
    debug_assert!(a < q && b < q);
    (a * b) % q
}

/// Barrett reducer for a fixed modulus q < 2^31: replaces the hardware
/// division in `a·b mod q` (20–40 cycles) with two multiplies (§Perf).
#[derive(Debug, Clone, Copy)]
pub struct Barrett {
    pub q: u64,
    /// ⌊2^62 / q⌋ (< 2^32 for q > 2^30).
    m: u64,
}

impl Barrett {
    pub fn new(q: u64) -> Self {
        debug_assert!(q > 1 && q < 1 << 31);
        Barrett {
            q,
            m: ((1u128 << 62) / q as u128) as u64,
        }
    }

    /// `a · b mod q` for reduced inputs (product < 2^62).
    #[inline(always)]
    pub fn mul(&self, a: u64, b: u64) -> u64 {
        let t = a * b; // < 2^62
        // quotient estimate ⌊t·m / 2^62⌋ ∈ {⌊t/q⌋, ⌊t/q⌋ − 1}
        let quot = ((t as u128 * self.m as u128) >> 62) as u64;
        let r = t - quot * self.q;
        if r >= self.q {
            r - self.q
        } else {
            r
        }
    }

    /// The precomputed ⌊2^62/q⌋ magic — the SIMD kernels splat it into
    /// vector lanes (crate-internal; < 2^32 whenever q > 2^30).
    #[inline(always)]
    pub(crate) fn magic(&self) -> u64 {
        self.m
    }

    /// Reduce a value < 2^62.
    #[inline(always)]
    pub fn reduce(&self, t: u64) -> u64 {
        let quot = ((t as u128 * self.m as u128) >> 62) as u64;
        let r = t - quot * self.q;
        if r >= self.q {
            r - self.q
        } else {
            r
        }
    }
}

/// `base^exp mod q`.
pub fn pow_mod(mut base: u64, mut exp: u64, q: u64) -> u64 {
    base %= q;
    let mut acc = 1u64;
    while exp > 0 {
        if exp & 1 == 1 {
            acc = mul_mod(acc, base, q);
        }
        base = mul_mod(base, base, q);
        exp >>= 1;
    }
    acc
}

/// Modular inverse for prime q (Fermat).
pub fn inv_mod(a: u64, q: u64) -> u64 {
    pow_mod(a, q - 2, q)
}

/// Negate mod q.
#[inline(always)]
pub fn neg_mod(a: u64, q: u64) -> u64 {
    if a == 0 {
        0
    } else {
        q - a
    }
}

/// Lift a signed value into [0, q).
#[inline(always)]
pub fn lift_signed(v: i64, q: u64) -> u64 {
    let r = v % q as i64;
    if r < 0 {
        (r + q as i64) as u64
    } else {
        r as u64
    }
}

/// Center a reduced value into (-q/2, q/2].
#[inline(always)]
pub fn center(v: u64, q: u64) -> i64 {
    if v > q / 2 {
        v as i64 - q as i64
    } else {
        v as i64
    }
}

/// Deterministic Miller–Rabin for u64 (the listed witness set is proven
/// complete below 3.3 * 10^24).
pub fn is_prime(n: u64) -> bool {
    if n < 2 {
        return false;
    }
    for p in [2u64, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37] {
        if n == p {
            return true;
        }
        if n % p == 0 {
            return false;
        }
    }
    let mut d = n - 1;
    let mut r = 0;
    while d % 2 == 0 {
        d /= 2;
        r += 1;
    }
    'witness: for a in [2u64, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37] {
        let mut x = pow_mod_wide(a, d, n);
        if x == 1 || x == n - 1 {
            continue;
        }
        for _ in 0..r - 1 {
            x = mul_mod_wide(x, x, n);
            if x == n - 1 {
                continue 'witness;
            }
        }
        return false;
    }
    true
}

/// u128-widened helpers for the primality test (moduli may exceed 2^32 there).
#[inline]
fn mul_mod_wide(a: u64, b: u64, q: u64) -> u64 {
    ((a as u128 * b as u128) % q as u128) as u64
}

fn pow_mod_wide(mut base: u64, mut exp: u64, q: u64) -> u64 {
    base %= q;
    let mut acc = 1u64;
    while exp > 0 {
        if exp & 1 == 1 {
            acc = mul_mod_wide(acc, base, q);
        }
        base = mul_mod_wide(base, base, q);
        exp >>= 1;
    }
    acc
}

/// Bit-reverse the low `bits` bits of `x`.
#[inline]
pub fn bit_reverse(x: usize, bits: u32) -> usize {
    x.reverse_bits() >> (usize::BITS - bits)
}

#[cfg(test)]
mod tests {
    use super::*;

    const Q: u64 = 2147377153; // a 31-bit NTT prime (≡ 1 mod 2^14)

    #[test]
    fn add_sub_roundtrip() {
        for (a, b) in [(0u64, 0u64), (1, Q - 1), (Q / 2, Q / 2 + 1), (Q - 1, Q - 1)] {
            let s = add_mod(a, b, Q);
            assert!(s < Q);
            assert_eq!(sub_mod(s, b, Q), a);
        }
    }

    #[test]
    fn inverse_works() {
        for a in [1u64, 2, 12345, Q - 1, Q / 3] {
            assert_eq!(mul_mod(a, inv_mod(a, Q), Q), 1);
        }
    }

    #[test]
    fn pow_matches_naive() {
        let mut acc = 1u64;
        for e in 0..20u64 {
            assert_eq!(pow_mod(3, e, Q), acc);
            acc = mul_mod(acc, 3, Q);
        }
    }

    #[test]
    fn signed_lift_center_roundtrip() {
        for v in [-5i64, -1, 0, 1, 5, 1 << 20, -(1 << 20)] {
            let lifted = lift_signed(v, Q);
            assert!(lifted < Q);
            assert_eq!(center(lifted, Q), v);
        }
    }

    #[test]
    fn primality_known_values() {
        assert!(is_prime(2));
        assert!(is_prime(3));
        assert!(is_prime(Q));
        assert!(is_prime((1u64 << 61) - 1)); // Mersenne
        assert!(!is_prime(1));
        assert!(!is_prime(2147377151)); // Q-2, even
        assert!(!is_prime(2147483647u64 * 3));
        // strong pseudoprime traps
        assert!(!is_prime(3215031751));
        assert!(is_prime(4294967291)); // largest prime < 2^32
    }

    #[test]
    fn barrett_matches_plain_mul_mod() {
        use crate::crypto::prng::ChaChaRng;
        let mut rng = ChaChaRng::from_seed(77, 0);
        for &q in &crate::ckks::params::generate_ntt_primes(4) {
            let br = Barrett::new(q);
            for _ in 0..2000 {
                let a = rng.uniform_u64(q);
                let b = rng.uniform_u64(q);
                assert_eq!(br.mul(a, b), mul_mod(a, b, q));
            }
            // boundary values
            assert_eq!(br.mul(q - 1, q - 1), mul_mod(q - 1, q - 1, q));
            assert_eq!(br.mul(0, q - 1), 0);
            assert_eq!(br.reduce((q - 1) * (q - 1)), mul_mod(q - 1, q - 1, q));
        }
    }

    #[test]
    fn bit_reverse_involution() {
        for bits in [3u32, 8, 13] {
            for x in 0..(1usize << bits) {
                assert_eq!(bit_reverse(bit_reverse(x, bits), bits), x);
            }
        }
    }
}
