//! Ciphertext-level operations: addition, scalar weighting, and the native
//! (pure-Rust) weighted aggregation used as the oracle/fallback for the
//! XLA-kernel hot path.

use super::encrypt::Ciphertext;
use super::params::CkksParams;
use super::poly::CkksScratch;

/// `acc += ct` (scales must match).
pub fn add_assign(acc: &mut Ciphertext, ct: &Ciphertext, params: &CkksParams) {
    assert!(
        (acc.scale - ct.scale).abs() < 1e-9,
        "scale mismatch in ciphertext addition"
    );
    acc.c0.add_assign(&ct.c0, params);
    acc.c1.add_assign(&ct.c1, params);
    acc.n_values = acc.n_values.max(ct.n_values);
}

/// `ct ← α ⊙ ct`: multiply by the encoded scalar weight, bumping the scale
/// by Δ_w (the single multiplicative depth of Algorithm 1).
pub fn mul_weight(ct: &mut Ciphertext, alpha: f64, params: &CkksParams) {
    let w = params.encode_weight(alpha);
    ct.c0.mul_scalar(&w, params);
    ct.c1.mul_scalar(&w, params);
    ct.scale *= params.delta_w();
}

/// Native weighted sum `Σ_i α_i · ct_i` — the server aggregation of
/// Algorithm 1 in pure Rust. Used to cross-check the XLA artifact and as the
/// fallback for non-artifact shapes.
pub fn weighted_sum(cts: &[Ciphertext], alphas: &[f64], params: &CkksParams) -> Ciphertext {
    let refs: Vec<&Ciphertext> = cts.iter().collect();
    weighted_sum_refs(&refs, alphas, params)
}

/// Borrowed-input variant of [`weighted_sum`] (allocating wrapper over
/// [`weighted_sum_refs_into`]).
pub fn weighted_sum_refs(cts: &[&Ciphertext], alphas: &[f64], params: &CkksParams) -> Ciphertext {
    let mut scratch = CkksScratch::new(params);
    let mut out = Ciphertext::zero(params);
    weighted_sum_refs_into(cts, alphas, params, &mut scratch, &mut out);
    out
}

/// The aggregation hot path (`he_agg::native`, the `agg_engine` oracle):
/// weighted-sum borrowed ciphertexts into a caller-owned output, staging the
/// encoded weights in the pooled scratch — allocation-free after warm-up.
///
/// The inner loop is the measured L3 hot path: per (limb, coefficient) it is
/// one Barrett product and one add per client. The §Perf pass keeps the
/// product reduction lazy (each reduced term is < 2^31 so up to 2^31 terms
/// accumulate in u64 before a fold) and indexes the per-limb Barrett
/// reducers cached in [`CkksParams`] instead of rebuilding one per call.
/// The per-limb init/accumulate/fold passes run on the runtime-dispatched
/// vector kernel ([`crate::ckks::simd::active`]) — four Barrett lanes per
/// iteration on AVX2 hosts, bitwise identical to the scalar loops.
pub fn weighted_sum_refs_into(
    cts: &[&Ciphertext],
    alphas: &[f64],
    params: &CkksParams,
    scratch: &mut CkksScratch,
    out: &mut Ciphertext,
) {
    assert_eq!(cts.len(), alphas.len());
    assert!(!cts.is_empty());
    let num_limbs = params.num_limbs();
    debug_assert!(
        cts.len() < (1usize << 31),
        "lazy accumulation bound exceeded"
    );
    scratch.weights.clear();
    for &a in alphas {
        params.encode_weight_into(a, &mut scratch.weights);
    }
    out.scale = cts[0].scale * params.delta_w();
    out.n_values = cts.iter().map(|c| c.n_values).max().unwrap();
    out.a_seed = None; // an aggregate has no single expansion seed
    // Domain-agnostic kernel: the output lives in whatever domain the inputs
    // do (the seed path inherited this via `out = cts[0].clone()`).
    out.c0.ntt_form = cts[0].c0.ntt_form;
    out.c1.ntt_form = cts[0].c1.ntt_form;
    let kernel = crate::ckks::simd::active();
    for poly_idx in 0..2 {
        for l in 0..num_limbs {
            let br = params.barrett[l];
            let dst = if poly_idx == 0 {
                out.c0.limb_mut(l)
            } else {
                out.c1.limb_mut(l)
            };
            // Initialize with the first client's weighted limb, then
            // accumulate the rest lazily (each reduced product < 2^31).
            let w0 = scratch.weights[l];
            let src0 = if poly_idx == 0 {
                cts[0].c0.limb(l)
            } else {
                cts[0].c1.limb(l)
            };
            kernel.weighted_init(dst, src0, w0, br);
            for (i, ct) in cts.iter().enumerate().skip(1) {
                let w = scratch.weights[i * num_limbs + l];
                let src = if poly_idx == 0 {
                    ct.c0.limb(l)
                } else {
                    ct.c1.limb(l)
                };
                kernel.weighted_accumulate(dst, src, w, br);
                // Fold the accumulator periodically to stay < 2^63.
                if i % (1 << 30) == 0 {
                    kernel.reduce_slice(dst, br);
                }
            }
            kernel.reduce_slice(dst, br);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckks::encoding::Encoder;
    use crate::ckks::encrypt::{decrypt, encrypt};
    use crate::ckks::keys::keygen;
    use crate::crypto::prng::ChaChaRng;
    use std::sync::Arc;

    #[test]
    fn weighted_sum_matches_plain_fedavg() {
        let params = Arc::new(CkksParams::new(512, 4, 45).unwrap());
        let encoder = Encoder::new(params.clone());
        let mut rng = ChaChaRng::from_seed(7, 0);
        let (pk, sk) = keygen(&params, &mut rng);

        let n_clients = 5;
        let alphas = [0.1, 0.25, 0.3, 0.15, 0.2];
        let models: Vec<Vec<f64>> = (0..n_clients)
            .map(|c| {
                (0..256)
                    .map(|i| ((i + c * 31) as f64 * 0.013).sin())
                    .collect()
            })
            .collect();
        let cts: Vec<Ciphertext> = models
            .iter()
            .map(|m| encrypt(&params, &pk, &encoder.encode(m), m.len(), &mut rng))
            .collect();
        let agg = weighted_sum(&cts, &alphas, &params);
        let dec = encoder.decode(&decrypt(&params, &sk, &agg), 256, agg.scale);

        for j in 0..256 {
            let expected: f64 = (0..n_clients).map(|c| alphas[c] * models[c][j]).sum();
            assert!(
                (dec[j] - expected).abs() < 1e-5,
                "slot {j}: {} vs {}",
                dec[j],
                expected
            );
        }
    }

    #[test]
    fn weighted_sum_equals_sequential_ops() {
        let params = Arc::new(CkksParams::new(128, 3, 35).unwrap());
        let encoder = Encoder::new(params.clone());
        let mut rng = ChaChaRng::from_seed(8, 0);
        let (pk, _sk) = keygen(&params, &mut rng);
        let alphas = [0.5, 0.5];
        let cts: Vec<Ciphertext> = (0..2)
            .map(|c| {
                let m: Vec<f64> = (0..64).map(|i| (i * (c + 1)) as f64 * 0.01).collect();
                encrypt(&params, &pk, &encoder.encode(&m), 64, &mut rng)
            })
            .collect();

        let fast = weighted_sum(&cts, &alphas, &params);

        let mut slow = cts[0].clone();
        mul_weight(&mut slow, alphas[0], &params);
        let mut t = cts[1].clone();
        mul_weight(&mut t, alphas[1], &params);
        add_assign(&mut slow, &t, &params);

        assert_eq!(fast.c0, slow.c0);
        assert_eq!(fast.c1, slow.c1);
        assert!((fast.scale - slow.scale).abs() < 1e-9);
    }

    #[test]
    fn into_variant_reuses_buffers_bitwise() {
        let params = Arc::new(CkksParams::new(128, 3, 35).unwrap());
        let encoder = Encoder::new(params.clone());
        let mut rng = ChaChaRng::from_seed(18, 0);
        let (pk, _sk) = keygen(&params, &mut rng);
        let alphas = [0.25, 0.75];
        let cts: Vec<Ciphertext> = (0..2)
            .map(|c| {
                let m: Vec<f64> = (0..64).map(|i| (i + c) as f64 * 0.02).collect();
                encrypt(&params, &pk, &encoder.encode(&m), 64, &mut rng)
            })
            .collect();
        let refs: Vec<&Ciphertext> = cts.iter().collect();
        let oracle = weighted_sum_refs(&refs, &alphas, &params);
        let mut scratch = CkksScratch::new(&params);
        let mut out = Ciphertext::zero(&params);
        for _ in 0..3 {
            // repeated reuse of the same output/scratch stays bitwise equal
            weighted_sum_refs_into(&refs, &alphas, &params, &mut scratch, &mut out);
            assert_eq!(out, oracle);
        }
    }

    #[test]
    fn weighted_sum_preserves_input_domain() {
        // The kernel is domain-agnostic: the output must carry the inputs'
        // domain flag (regression for the flat-limb rewrite, which no longer
        // clone-inherits it).
        let params = Arc::new(CkksParams::new(128, 2, 30).unwrap());
        let encoder = Encoder::new(params.clone());
        let mut rng = ChaChaRng::from_seed(21, 0);
        let (pk, _sk) = keygen(&params, &mut rng);
        let m = vec![0.5; 32];
        let mut a = encrypt(&params, &pk, &encoder.encode(&m), 32, &mut rng);
        let mut b = encrypt(&params, &pk, &encoder.encode(&m), 32, &mut rng);
        let agg = weighted_sum(&[a.clone(), b.clone()], &[0.5, 0.5], &params);
        assert!(!agg.c0.ntt_form && !agg.c1.ntt_form);
        // NTT-domain inputs: output flags follow, and the result is the NTT
        // of the coefficient-domain aggregate (the kernel commutes).
        a.c0.to_ntt(&params);
        a.c1.to_ntt(&params);
        b.c0.to_ntt(&params);
        b.c1.to_ntt(&params);
        let mut agg_ntt = weighted_sum(&[a, b], &[0.5, 0.5], &params);
        assert!(agg_ntt.c0.ntt_form && agg_ntt.c1.ntt_form);
        agg_ntt.c0.from_ntt(&params);
        agg_ntt.c1.from_ntt(&params);
        assert_eq!(agg_ntt.c0, agg.c0);
        assert_eq!(agg_ntt.c1, agg.c1);
    }

    #[test]
    fn single_client_weight_one_is_identityish() {
        let params = Arc::new(CkksParams::new(128, 3, 35).unwrap());
        let encoder = Encoder::new(params.clone());
        let mut rng = ChaChaRng::from_seed(9, 0);
        let (pk, sk) = keygen(&params, &mut rng);
        let m: Vec<f64> = (0..64).map(|i| i as f64 * 0.1 - 3.0).collect();
        let ct = encrypt(&params, &pk, &encoder.encode(&m), 64, &mut rng);
        let agg = weighted_sum(std::slice::from_ref(&ct), &[1.0], &params);
        let dec = encoder.decode(&decrypt(&params, &sk, &agg), 64, agg.scale);
        for j in 0..64 {
            assert!((dec[j] - m[j]).abs() < 1e-6);
        }
    }

    #[test]
    #[should_panic(expected = "scale mismatch")]
    fn scale_mismatch_rejected() {
        let params = Arc::new(CkksParams::new(128, 2, 30).unwrap());
        let encoder = Encoder::new(params.clone());
        let mut rng = ChaChaRng::from_seed(10, 0);
        let (pk, _sk) = keygen(&params, &mut rng);
        let m = vec![1.0; 32];
        let mut a = encrypt(&params, &pk, &encoder.encode(&m), 32, &mut rng);
        let mut b = a.clone();
        mul_weight(&mut b, 0.5, &params);
        add_assign(&mut a, &b, &params);
    }
}
