//! Runtime-dispatched vector kernels for the CKKS hot core (§Perf).
//!
//! The NTT butterflies and the per-limb Barrett weighted-sum loops are the
//! two inner loops every aggregation round flows through. This module puts
//! them behind a small [`NttKernel`] trait with two implementations:
//!
//! - [`ScalarKernel`] — the portable loops, verbatim the arithmetic that
//!   lived inline in `ntt.rs` / `ops.rs` / `agg_engine/shard.rs` before this
//!   module existed. Always available; also the fallback for vector tails.
//! - `Avx2Kernel` — AVX2 butterflies and Barrett reductions, four lanes per
//!   iteration, built from `pmuludq` (32×32→64) partial products. Selected
//!   only after `is_x86_feature_detected!("avx2")`, so its safe trait
//!   methods are sound on any host that can obtain a handle to it.
//!
//! **Bitwise contract:** every kernel must produce outputs bitwise identical
//! to [`ScalarKernel`] (and therefore to the seed reference butterflies kept
//! in `ntt.rs` as the differential oracle). The AVX2 paths achieve this by
//! computing the *exact* same integers — the partial-product decompositions
//! below are exact under the crate-wide bounds q < 2^31 (so lazy values are
//! < 4q < 2^33 and Barrett magics fit 32 bits), never approximations. The
//! `tests/simd_ntt.rs` sweep pins this across every generated prime and
//! ring degree on both dispatch paths.
//!
//! - `NeonKernel` — the aarch64 twin: two lanes per iteration from
//!   `vmull_u32` (32×32→64) partial products, selected after
//!   `is_aarch64_feature_detected!("neon")`. Same bitwise contract, same
//!   exactness argument (NEON has native 64-bit add/sub/compare but no
//!   64×64 multiply, so the decompositions mirror the AVX2 ones).
//!
//! Dispatch is process-global ([`active`]) with an environment override:
//! setting `FEDML_HE_NTT_KERNEL=scalar` forces the portable kernel even on
//! hosts with AVX2/NEON (CI runs the whole tier-1 suite both ways).

use std::sync::OnceLock;

use super::modarith::Barrett;
use super::ntt::{mul_mod_shoup, mul_mod_shoup_lazy};

/// Environment variable consulted once per process by [`active`]:
/// `scalar` forces [`ScalarKernel`]; any other value (or unset) auto-detects.
pub const KERNEL_ENV: &str = "FEDML_HE_NTT_KERNEL";

/// One vectorizable inner-loop backend for the CKKS hot core.
///
/// Stage methods receive the twiddle slices for that stage (the tables stay
/// private to `NttTables`); weighted methods receive the limb's Barrett
/// reducer. Implementations must be bitwise identical to [`ScalarKernel`].
pub trait NttKernel: Sync {
    /// Display name ("scalar", "avx2", ...).
    fn name(&self) -> &'static str;

    /// True for vectorized implementations (drives the obs kernel counters).
    fn is_simd(&self) -> bool;

    /// One forward Cooley–Tukey stage: `m` butterfly groups of width `t`
    /// over `a` (len 2·m·t), group `i` twiddled by `psi[i]`. Values ride in
    /// [0, 4q) (Harvey lazy reduction).
    fn forward_stage(
        &self,
        a: &mut [u64],
        m: usize,
        t: usize,
        psi: &[u64],
        psi_shoup: &[u64],
        q: u64,
    );

    /// Final forward sweep: reduce every element from [0, 4q) to [0, q).
    fn forward_finish(&self, a: &mut [u64], q: u64);

    /// One inverse Gentleman–Sande stage: `h` butterfly groups of width `t`,
    /// group `i` twiddled by `psi[i]`. Values ride in [0, 2q).
    fn inverse_stage(
        &self,
        a: &mut [u64],
        h: usize,
        t: usize,
        psi: &[u64],
        psi_shoup: &[u64],
        q: u64,
    );

    /// Fused final inverse stage over the two half-arrays with n^{-1} folded
    /// into both wings, fully reducing on the way out.
    fn inverse_finish(
        &self,
        lo: &mut [u64],
        hi: &mut [u64],
        n_inv: u64,
        n_inv_shoup: u64,
        psi_last: u64,
        psi_last_shoup: u64,
        q: u64,
    );

    /// `dst[i] = src[i]·w mod q` for reduced `src` and `w` (the weighted-sum
    /// init pass of `ops.rs` / `agg_engine/shard.rs`).
    fn weighted_init(&self, dst: &mut [u64], src: &[u64], w: u64, br: Barrett);

    /// `dst[i] += src[i]·w mod q` — plain u64 accumulation of Barrett
    /// products; callers fold (reduce) before 2^62 can overflow.
    fn weighted_accumulate(&self, dst: &mut [u64], src: &[u64], w: u64, br: Barrett);

    /// Barrett-reduce every accumulator (each < 2^62) to [0, q).
    fn reduce_slice(&self, dst: &mut [u64], br: Barrett);
}

/// Portable reference kernel: the exact scalar loops the vector kernels are
/// measured against.
pub struct ScalarKernel;

impl NttKernel for ScalarKernel {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn is_simd(&self) -> bool {
        false
    }

    fn forward_stage(
        &self,
        a: &mut [u64],
        m: usize,
        t: usize,
        psi: &[u64],
        psi_shoup: &[u64],
        q: u64,
    ) {
        let two_q = 2 * q;
        for i in 0..m {
            let j1 = 2 * i * t;
            let s = psi[i];
            let s_shoup = psi_shoup[i];
            let (lo, hi) = a[j1..j1 + 2 * t].split_at_mut(t);
            for (x, y) in lo.iter_mut().zip(hi.iter_mut()) {
                let mut u = *x; // < 4q
                if u >= two_q {
                    u -= two_q;
                }
                let v = mul_mod_shoup_lazy(*y, s, s_shoup, q); // < 2q
                *x = u + v; // < 4q
                *y = u + two_q - v; // < 4q
            }
        }
    }

    fn forward_finish(&self, a: &mut [u64], q: u64) {
        let two_q = 2 * q;
        for x in a.iter_mut() {
            let mut v = *x;
            if v >= two_q {
                v -= two_q;
            }
            if v >= q {
                v -= q;
            }
            *x = v;
        }
    }

    fn inverse_stage(
        &self,
        a: &mut [u64],
        h: usize,
        t: usize,
        psi: &[u64],
        psi_shoup: &[u64],
        q: u64,
    ) {
        let two_q = 2 * q;
        let mut j1 = 0;
        for i in 0..h {
            let s = psi[i];
            let s_shoup = psi_shoup[i];
            let (lo, hi) = a[j1..j1 + 2 * t].split_at_mut(t);
            for (x, y) in lo.iter_mut().zip(hi.iter_mut()) {
                let u = *x; // < 2q
                let v = *y; // < 2q
                let mut sum = u + v; // < 4q
                if sum >= two_q {
                    sum -= two_q;
                }
                *x = sum; // < 2q
                *y = mul_mod_shoup_lazy(u + two_q - v, s, s_shoup, q); // < 2q
            }
            j1 += 2 * t;
        }
    }

    fn inverse_finish(
        &self,
        lo: &mut [u64],
        hi: &mut [u64],
        n_inv: u64,
        n_inv_shoup: u64,
        psi_last: u64,
        psi_last_shoup: u64,
        q: u64,
    ) {
        let two_q = 2 * q;
        for (x, y) in lo.iter_mut().zip(hi.iter_mut()) {
            let u = *x; // < 2q
            let v = *y; // < 2q
            *x = mul_mod_shoup(u + v, n_inv, n_inv_shoup, q);
            *y = mul_mod_shoup(u + two_q - v, psi_last, psi_last_shoup, q);
        }
    }

    fn weighted_init(&self, dst: &mut [u64], src: &[u64], w: u64, br: Barrett) {
        for (d, &s) in dst.iter_mut().zip(src.iter()) {
            *d = br.mul(s, w);
        }
    }

    fn weighted_accumulate(&self, dst: &mut [u64], src: &[u64], w: u64, br: Barrett) {
        for (d, &s) in dst.iter_mut().zip(src.iter()) {
            *d += br.mul(s, w);
        }
    }

    fn reduce_slice(&self, dst: &mut [u64], br: Barrett) {
        for d in dst.iter_mut() {
            *d = br.reduce(*d);
        }
    }
}

static SCALAR: ScalarKernel = ScalarKernel;

/// The portable kernel (always available).
pub fn scalar() -> &'static dyn NttKernel {
    &SCALAR
}

/// The best vector kernel the host supports, if any.
pub fn detected_simd() -> Option<&'static dyn NttKernel> {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return Some(&avx2::AVX2);
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return Some(&neon::NEON);
        }
    }
    None
}

/// Kernel selection as a pure function of the override value — the logic
/// behind [`active`], exposed for tests: `Some("scalar")` forces the
/// portable kernel, anything else auto-detects.
pub fn kernel_for(env_override: Option<&str>) -> &'static dyn NttKernel {
    match env_override {
        Some("scalar") => scalar(),
        _ => detected_simd().unwrap_or_else(scalar),
    }
}

static ACTIVE: OnceLock<&'static dyn NttKernel> = OnceLock::new();

/// The process-wide dispatched kernel: [`KERNEL_ENV`] override, else the
/// best detected vector kernel, else scalar. Resolved once per process.
pub fn active() -> &'static dyn NttKernel {
    *ACTIVE.get_or_init(|| kernel_for(std::env::var(KERNEL_ENV).ok().as_deref()))
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    //! AVX2 lane math. There is no 64×64→128 multiply and no unsigned
    //! 64-bit compare in AVX2, so everything is built from `pmuludq`
    //! (32×32→64 on the low halves of each lane) and signed compares —
    //! both exact under the crate's bounds:
    //!
    //! - Shoup operands are < 4q < 2^33, so their high 32-bit half is 0 or
    //!   1 and the 4-product mulhi decomposition cannot overflow its
    //!   carry-save accumulator (max 2^64 − 1).
    //! - Twiddles / weights / moduli are < 2^31, so low-64 products need
    //!   only two `pmuludq`.
    //! - Barrett magics ⌊2^62/q⌋ fit 32 bits for q > 2^30 (every generated
    //!   prime); the wrappers below verify that at runtime and fall back to
    //!   scalar otherwise.
    //! - Every compared value is < 2^62, so signed `cmpgt` orders them
    //!   correctly.

    use super::{Barrett, NttKernel, ScalarKernel};
    use std::arch::x86_64::{
        __m256i, _mm256_add_epi64, _mm256_andnot_si256, _mm256_cmpgt_epi64, _mm256_loadu_si256,
        _mm256_mul_epu32, _mm256_or_si256, _mm256_set1_epi64x, _mm256_slli_epi64,
        _mm256_srli_epi64, _mm256_storeu_si256, _mm256_sub_epi64,
    };

    pub(super) struct Avx2Kernel {
        _private: (),
    }

    /// Sole instance; only reachable through `detected_simd()`, which gates
    /// on runtime AVX2 detection — the soundness condition for the safe
    /// trait methods below.
    pub(super) static AVX2: Avx2Kernel = Avx2Kernel { _private: () };

    const LANES: usize = 4;

    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn splat(x: u64) -> __m256i {
        _mm256_set1_epi64x(x as i64)
    }

    /// Low 64 bits of `a·b` per lane, exact when `b < 2^32` and `a·b < 2^64`.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn mul_lo_small(a: __m256i, b: __m256i) -> __m256i {
        let lo = _mm256_mul_epu32(a, b);
        let hi = _mm256_mul_epu32(_mm256_srli_epi64(a, 32), b);
        _mm256_add_epi64(lo, _mm256_slli_epi64(hi, 32))
    }

    /// High 64 bits of `a·b` per lane, exact for `a < 2^33` (so `a >> 32`
    /// is 0 or 1 and the carry-save middle term stays below 2^64).
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn mul_hi_narrow(a: __m256i, b: __m256i) -> __m256i {
        let a_hi = _mm256_srli_epi64(a, 32);
        let b_hi = _mm256_srli_epi64(b, 32);
        let p00 = _mm256_mul_epu32(a, b);
        let p01 = _mm256_mul_epu32(a, b_hi);
        let p10 = _mm256_mul_epu32(a_hi, b);
        let p11 = _mm256_mul_epu32(a_hi, b_hi);
        let mid = _mm256_add_epi64(_mm256_add_epi64(p01, p10), _mm256_srli_epi64(p00, 32));
        _mm256_add_epi64(p11, _mm256_srli_epi64(mid, 32))
    }

    /// `x − b` where `x ≥ b`, else `x` (signed compare is exact: both
    /// operands < 2^62).
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn csub(x: __m256i, b: __m256i) -> __m256i {
        let lt = _mm256_cmpgt_epi64(b, x);
        _mm256_sub_epi64(x, _mm256_andnot_si256(lt, b))
    }

    /// Lazy Shoup product per lane: `a·w − ⌊a·w_shoup/2^64⌋·q ∈ [0, 2q)`
    /// for `a < 4q < 2^33`, `w < q < 2^31` — the vector twin of
    /// `ntt::mul_mod_shoup_lazy`, bit for bit.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn shoup_lazy(a: __m256i, w: __m256i, w_shoup: __m256i, q: __m256i) -> __m256i {
        let hi = mul_hi_narrow(a, w_shoup);
        let aw = mul_lo_small(a, w);
        let hq = mul_lo_small(hi, q);
        _mm256_sub_epi64(aw, hq)
    }

    /// Fully reduced Shoup product: lazy then one conditional subtract.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn shoup_full(a: __m256i, w: __m256i, w_shoup: __m256i, q: __m256i) -> __m256i {
        csub(shoup_lazy(a, w, w_shoup, q), q)
    }

    /// Barrett reduction per lane: `t − ⌊t·m/2^62⌋·q` then a conditional
    /// subtract, exact for `t < 2^62` and `m < 2^32` — the vector twin of
    /// `Barrett::reduce`/`Barrett::mul`'s reduction half.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn barrett_reduce(t: __m256i, m: __m256i, q: __m256i) -> __m256i {
        let t_hi = _mm256_srli_epi64(t, 32);
        let p00 = _mm256_mul_epu32(t, m);
        let p10 = _mm256_mul_epu32(t_hi, m);
        // t·m as hi64/lo64 via carry-save: full = p10·2^32 + p00.
        let hi64 = _mm256_srli_epi64(_mm256_add_epi64(p10, _mm256_srli_epi64(p00, 32)), 32);
        let lo64 = _mm256_add_epi64(_mm256_slli_epi64(p10, 32), p00);
        // ⌊t·m/2^62⌋ = hi64·4 | lo64»62 (< 2^32, so the low-product below
        // is exact).
        let quot = _mm256_or_si256(_mm256_slli_epi64(hi64, 2), _mm256_srli_epi64(lo64, 62));
        let r = _mm256_sub_epi64(t, mul_lo_small(quot, q));
        csub(r, q)
    }

    #[target_feature(enable = "avx2")]
    unsafe fn forward_stage_avx2(
        a: &mut [u64],
        m: usize,
        t: usize,
        psi: &[u64],
        psi_shoup: &[u64],
        q: u64,
    ) {
        let qv = splat(q);
        let two_qv = splat(2 * q);
        for i in 0..m {
            let j1 = 2 * i * t;
            let s = splat(psi[i]);
            let s_sh = splat(psi_shoup[i]);
            let (lo, hi) = a[j1..j1 + 2 * t].split_at_mut(t);
            let mut j = 0;
            // t is a power of two ≥ 4 here: no tail.
            while j < t {
                let xp = lo.as_mut_ptr().add(j).cast::<__m256i>();
                let yp = hi.as_mut_ptr().add(j).cast::<__m256i>();
                let x = _mm256_loadu_si256(xp);
                let y = _mm256_loadu_si256(yp);
                let u = csub(x, two_qv);
                let v = shoup_lazy(y, s, s_sh, qv);
                _mm256_storeu_si256(xp, _mm256_add_epi64(u, v));
                _mm256_storeu_si256(yp, _mm256_add_epi64(u, _mm256_sub_epi64(two_qv, v)));
                j += LANES;
            }
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn forward_finish_avx2(a: &mut [u64], q: u64) {
        let qv = splat(q);
        let two_qv = splat(2 * q);
        let mut chunks = a.chunks_exact_mut(LANES);
        for c in chunks.by_ref() {
            let p = c.as_mut_ptr().cast::<__m256i>();
            let x = _mm256_loadu_si256(p);
            _mm256_storeu_si256(p, csub(csub(x, two_qv), qv));
        }
        ScalarKernel.forward_finish(chunks.into_remainder(), q);
    }

    #[target_feature(enable = "avx2")]
    unsafe fn inverse_stage_avx2(
        a: &mut [u64],
        h: usize,
        t: usize,
        psi: &[u64],
        psi_shoup: &[u64],
        q: u64,
    ) {
        let qv = splat(q);
        let two_qv = splat(2 * q);
        let mut j1 = 0;
        for i in 0..h {
            let s = splat(psi[i]);
            let s_sh = splat(psi_shoup[i]);
            let (lo, hi) = a[j1..j1 + 2 * t].split_at_mut(t);
            let mut j = 0;
            while j < t {
                let xp = lo.as_mut_ptr().add(j).cast::<__m256i>();
                let yp = hi.as_mut_ptr().add(j).cast::<__m256i>();
                let u = _mm256_loadu_si256(xp);
                let v = _mm256_loadu_si256(yp);
                let sum = csub(_mm256_add_epi64(u, v), two_qv);
                let diff = _mm256_add_epi64(u, _mm256_sub_epi64(two_qv, v));
                _mm256_storeu_si256(xp, sum);
                _mm256_storeu_si256(yp, shoup_lazy(diff, s, s_sh, qv));
                j += LANES;
            }
            j1 += 2 * t;
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn inverse_finish_avx2(
        lo: &mut [u64],
        hi: &mut [u64],
        n_inv: u64,
        n_inv_shoup: u64,
        psi_last: u64,
        psi_last_shoup: u64,
        q: u64,
    ) {
        let qv = splat(q);
        let two_qv = splat(2 * q);
        let ni = splat(n_inv);
        let ni_sh = splat(n_inv_shoup);
        let pl = splat(psi_last);
        let pl_sh = splat(psi_last_shoup);
        let half = lo.len();
        let vec_end = half - half % LANES;
        let mut j = 0;
        while j < vec_end {
            let xp = lo.as_mut_ptr().add(j).cast::<__m256i>();
            let yp = hi.as_mut_ptr().add(j).cast::<__m256i>();
            let u = _mm256_loadu_si256(xp);
            let v = _mm256_loadu_si256(yp);
            let sum = _mm256_add_epi64(u, v);
            let diff = _mm256_add_epi64(u, _mm256_sub_epi64(two_qv, v));
            _mm256_storeu_si256(xp, shoup_full(sum, ni, ni_sh, qv));
            _mm256_storeu_si256(yp, shoup_full(diff, pl, pl_sh, qv));
            j += LANES;
        }
        ScalarKernel.inverse_finish(
            &mut lo[vec_end..],
            &mut hi[vec_end..],
            n_inv,
            n_inv_shoup,
            psi_last,
            psi_last_shoup,
            q,
        );
    }

    #[target_feature(enable = "avx2")]
    unsafe fn weighted_init_avx2(dst: &mut [u64], src: &[u64], w: u64, br: Barrett) {
        let qv = splat(br.q);
        let mv = splat(br.magic());
        let wv = splat(w);
        let n = dst.len();
        let vec_end = n - n % LANES;
        let mut j = 0;
        while j < vec_end {
            let sp = src.as_ptr().add(j).cast::<__m256i>();
            let dp = dst.as_mut_ptr().add(j).cast::<__m256i>();
            // src and w are both < q < 2^31: one pmuludq is the exact product.
            let t = _mm256_mul_epu32(_mm256_loadu_si256(sp), wv);
            _mm256_storeu_si256(dp, barrett_reduce(t, mv, qv));
            j += LANES;
        }
        ScalarKernel.weighted_init(&mut dst[vec_end..], &src[vec_end..], w, br);
    }

    #[target_feature(enable = "avx2")]
    unsafe fn weighted_accumulate_avx2(dst: &mut [u64], src: &[u64], w: u64, br: Barrett) {
        let qv = splat(br.q);
        let mv = splat(br.magic());
        let wv = splat(w);
        let n = dst.len();
        let vec_end = n - n % LANES;
        let mut j = 0;
        while j < vec_end {
            let sp = src.as_ptr().add(j).cast::<__m256i>();
            let dp = dst.as_mut_ptr().add(j).cast::<__m256i>();
            let t = _mm256_mul_epu32(_mm256_loadu_si256(sp), wv);
            let prod = barrett_reduce(t, mv, qv);
            let acc = _mm256_add_epi64(_mm256_loadu_si256(dp), prod);
            _mm256_storeu_si256(dp, acc);
            j += LANES;
        }
        ScalarKernel.weighted_accumulate(&mut dst[vec_end..], &src[vec_end..], w, br);
    }

    #[target_feature(enable = "avx2")]
    unsafe fn reduce_slice_avx2(dst: &mut [u64], br: Barrett) {
        let qv = splat(br.q);
        let mv = splat(br.magic());
        let n = dst.len();
        let vec_end = n - n % LANES;
        let mut j = 0;
        while j < vec_end {
            let dp = dst.as_mut_ptr().add(j).cast::<__m256i>();
            let t = _mm256_loadu_si256(dp);
            _mm256_storeu_si256(dp, barrett_reduce(t, mv, qv));
            j += LANES;
        }
        ScalarKernel.reduce_slice(&mut dst[vec_end..], br);
    }

    impl NttKernel for Avx2Kernel {
        fn name(&self) -> &'static str {
            "avx2"
        }

        fn is_simd(&self) -> bool {
            true
        }

        fn forward_stage(
            &self,
            a: &mut [u64],
            m: usize,
            t: usize,
            psi: &[u64],
            psi_shoup: &[u64],
            q: u64,
        ) {
            if t >= LANES {
                // Sound: AVX2 presence was verified before this handle
                // could be obtained.
                unsafe { forward_stage_avx2(a, m, t, psi, psi_shoup, q) }
            } else {
                // The last two stages (t ∈ {1, 2}) interleave wings too
                // tightly for 4-lane loads; they are O(n) scalar work.
                ScalarKernel.forward_stage(a, m, t, psi, psi_shoup, q);
            }
        }

        fn forward_finish(&self, a: &mut [u64], q: u64) {
            unsafe { forward_finish_avx2(a, q) }
        }

        fn inverse_stage(
            &self,
            a: &mut [u64],
            h: usize,
            t: usize,
            psi: &[u64],
            psi_shoup: &[u64],
            q: u64,
        ) {
            if t >= LANES {
                unsafe { inverse_stage_avx2(a, h, t, psi, psi_shoup, q) }
            } else {
                ScalarKernel.inverse_stage(a, h, t, psi, psi_shoup, q);
            }
        }

        fn inverse_finish(
            &self,
            lo: &mut [u64],
            hi: &mut [u64],
            n_inv: u64,
            n_inv_shoup: u64,
            psi_last: u64,
            psi_last_shoup: u64,
            q: u64,
        ) {
            unsafe { inverse_finish_avx2(lo, hi, n_inv, n_inv_shoup, psi_last, psi_last_shoup, q) }
        }

        fn weighted_init(&self, dst: &mut [u64], src: &[u64], w: u64, br: Barrett) {
            if br.magic() >> 32 != 0 {
                ScalarKernel.weighted_init(dst, src, w, br);
            } else {
                unsafe { weighted_init_avx2(dst, src, w, br) }
            }
        }

        fn weighted_accumulate(&self, dst: &mut [u64], src: &[u64], w: u64, br: Barrett) {
            if br.magic() >> 32 != 0 {
                ScalarKernel.weighted_accumulate(dst, src, w, br);
            } else {
                unsafe { weighted_accumulate_avx2(dst, src, w, br) }
            }
        }

        fn reduce_slice(&self, dst: &mut [u64], br: Barrett) {
            if br.magic() >> 32 != 0 {
                ScalarKernel.reduce_slice(dst, br);
            } else {
                unsafe { reduce_slice_avx2(dst, br) }
            }
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    //! NEON lane math. Unlike AVX2, A64 NEON has native unsigned 64-bit
    //! add/sub and compare (`cmhi` → `vcgtq_u64`), but still no 64×64→128
    //! multiply — products are built from `vmull_u32` (32×32→64) partial
    //! products, exact under the same crate-wide bounds as the AVX2 module:
    //!
    //! - Shoup operands are < 4q < 2^33, so their high 32-bit half is 0 or
    //!   1 and the mulhi carry-save accumulator cannot overflow.
    //! - Twiddles / weights / moduli are < 2^31, so low-64 products need
    //!   only two `vmull_u32`.
    //! - Barrett magics ⌊2^62/q⌋ fit 32 bits for q > 2^30; the wrappers
    //!   verify that at runtime and fall back to scalar otherwise.

    use super::{Barrett, NttKernel, ScalarKernel};
    use std::arch::aarch64::{
        uint32x2_t, uint64x2_t, vaddq_u64, vbicq_u64, vcgtq_u64, vdupq_n_u64, vld1q_u64,
        vmovn_u64, vmull_u32, vorrq_u64, vshlq_n_u64, vshrq_n_u64, vst1q_u64, vsubq_u64,
    };

    pub(super) struct NeonKernel {
        _private: (),
    }

    /// Sole instance; only reachable through `detected_simd()`, which gates
    /// on runtime NEON detection — the soundness condition for the safe
    /// trait methods below.
    pub(super) static NEON: NeonKernel = NeonKernel { _private: () };

    const LANES: usize = 2;

    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn splat(x: u64) -> uint64x2_t {
        vdupq_n_u64(x)
    }

    /// Low 32 bits of each lane as a narrowed `u32x2`.
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn lo32(a: uint64x2_t) -> uint32x2_t {
        vmovn_u64(a)
    }

    /// Low 64 bits of `a·b` per lane, exact when `b < 2^32` and `a·b < 2^64`.
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn mul_lo_small(a: uint64x2_t, b: uint64x2_t) -> uint64x2_t {
        let lo = vmull_u32(lo32(a), lo32(b));
        let hi = vmull_u32(lo32(vshrq_n_u64::<32>(a)), lo32(b));
        vaddq_u64(lo, vshlq_n_u64::<32>(hi))
    }

    /// High 64 bits of `a·b` per lane, exact for `a < 2^33` (so `a >> 32`
    /// is 0 or 1 and the carry-save middle term stays below 2^64).
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn mul_hi_narrow(a: uint64x2_t, b: uint64x2_t) -> uint64x2_t {
        let a_lo = lo32(a);
        let a_hi = lo32(vshrq_n_u64::<32>(a));
        let b_lo = lo32(b);
        let b_hi = lo32(vshrq_n_u64::<32>(b));
        let p00 = vmull_u32(a_lo, b_lo);
        let p01 = vmull_u32(a_lo, b_hi);
        let p10 = vmull_u32(a_hi, b_lo);
        let p11 = vmull_u32(a_hi, b_hi);
        let mid = vaddq_u64(vaddq_u64(p01, p10), vshrq_n_u64::<32>(p00));
        vaddq_u64(p11, vshrq_n_u64::<32>(mid))
    }

    /// `x − b` where `x ≥ b`, else `x` (`vcgtq_u64` is a true unsigned
    /// 64-bit compare — no signed-range caveat here).
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn csub(x: uint64x2_t, b: uint64x2_t) -> uint64x2_t {
        let lt = vcgtq_u64(b, x);
        vsubq_u64(x, vbicq_u64(b, lt))
    }

    /// Lazy Shoup product per lane: `a·w − ⌊a·w_shoup/2^64⌋·q ∈ [0, 2q)`
    /// for `a < 4q < 2^33`, `w < q < 2^31` — the vector twin of
    /// `ntt::mul_mod_shoup_lazy`, bit for bit.
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn shoup_lazy(
        a: uint64x2_t,
        w: uint64x2_t,
        w_shoup: uint64x2_t,
        q: uint64x2_t,
    ) -> uint64x2_t {
        let hi = mul_hi_narrow(a, w_shoup);
        let aw = mul_lo_small(a, w);
        let hq = mul_lo_small(hi, q);
        vsubq_u64(aw, hq)
    }

    /// Fully reduced Shoup product: lazy then one conditional subtract.
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn shoup_full(
        a: uint64x2_t,
        w: uint64x2_t,
        w_shoup: uint64x2_t,
        q: uint64x2_t,
    ) -> uint64x2_t {
        csub(shoup_lazy(a, w, w_shoup, q), q)
    }

    /// Barrett reduction per lane: `t − ⌊t·m/2^62⌋·q` then a conditional
    /// subtract, exact for `t < 2^62` and `m < 2^32` — the vector twin of
    /// `Barrett::reduce`/`Barrett::mul`'s reduction half.
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn barrett_reduce(t: uint64x2_t, m: uint64x2_t, q: uint64x2_t) -> uint64x2_t {
        let t_hi = vshrq_n_u64::<32>(t);
        let p00 = vmull_u32(lo32(t), lo32(m));
        let p10 = vmull_u32(lo32(t_hi), lo32(m));
        // t·m as hi64/lo64 via carry-save: full = p10·2^32 + p00.
        let hi64 = vshrq_n_u64::<32>(vaddq_u64(p10, vshrq_n_u64::<32>(p00)));
        let lo64 = vaddq_u64(vshlq_n_u64::<32>(p10), p00);
        // ⌊t·m/2^62⌋ = hi64·4 | lo64»62 (< 2^32, so the low-product below
        // is exact).
        let quot = vorrq_u64(vshlq_n_u64::<2>(hi64), vshrq_n_u64::<62>(lo64));
        let r = vsubq_u64(t, mul_lo_small(quot, q));
        csub(r, q)
    }

    #[target_feature(enable = "neon")]
    unsafe fn forward_stage_neon(
        a: &mut [u64],
        m: usize,
        t: usize,
        psi: &[u64],
        psi_shoup: &[u64],
        q: u64,
    ) {
        let qv = splat(q);
        let two_qv = splat(2 * q);
        for i in 0..m {
            let j1 = 2 * i * t;
            let s = splat(psi[i]);
            let s_sh = splat(psi_shoup[i]);
            let (lo, hi) = a[j1..j1 + 2 * t].split_at_mut(t);
            let mut j = 0;
            // t is a power of two ≥ 2 here: no tail.
            while j < t {
                let xp = lo.as_mut_ptr().add(j);
                let yp = hi.as_mut_ptr().add(j);
                let x = vld1q_u64(xp);
                let y = vld1q_u64(yp);
                let u = csub(x, two_qv);
                let v = shoup_lazy(y, s, s_sh, qv);
                vst1q_u64(xp, vaddq_u64(u, v));
                vst1q_u64(yp, vaddq_u64(u, vsubq_u64(two_qv, v)));
                j += LANES;
            }
        }
    }

    #[target_feature(enable = "neon")]
    unsafe fn forward_finish_neon(a: &mut [u64], q: u64) {
        let qv = splat(q);
        let two_qv = splat(2 * q);
        let mut chunks = a.chunks_exact_mut(LANES);
        for c in chunks.by_ref() {
            let p = c.as_mut_ptr();
            let x = vld1q_u64(p);
            vst1q_u64(p, csub(csub(x, two_qv), qv));
        }
        ScalarKernel.forward_finish(chunks.into_remainder(), q);
    }

    #[target_feature(enable = "neon")]
    unsafe fn inverse_stage_neon(
        a: &mut [u64],
        h: usize,
        t: usize,
        psi: &[u64],
        psi_shoup: &[u64],
        q: u64,
    ) {
        let qv = splat(q);
        let two_qv = splat(2 * q);
        let mut j1 = 0;
        for i in 0..h {
            let s = splat(psi[i]);
            let s_sh = splat(psi_shoup[i]);
            let (lo, hi) = a[j1..j1 + 2 * t].split_at_mut(t);
            let mut j = 0;
            while j < t {
                let xp = lo.as_mut_ptr().add(j);
                let yp = hi.as_mut_ptr().add(j);
                let u = vld1q_u64(xp);
                let v = vld1q_u64(yp);
                let sum = csub(vaddq_u64(u, v), two_qv);
                let diff = vaddq_u64(u, vsubq_u64(two_qv, v));
                vst1q_u64(xp, sum);
                vst1q_u64(yp, shoup_lazy(diff, s, s_sh, qv));
                j += LANES;
            }
            j1 += 2 * t;
        }
    }

    #[target_feature(enable = "neon")]
    unsafe fn inverse_finish_neon(
        lo: &mut [u64],
        hi: &mut [u64],
        n_inv: u64,
        n_inv_shoup: u64,
        psi_last: u64,
        psi_last_shoup: u64,
        q: u64,
    ) {
        let qv = splat(q);
        let two_qv = splat(2 * q);
        let ni = splat(n_inv);
        let ni_sh = splat(n_inv_shoup);
        let pl = splat(psi_last);
        let pl_sh = splat(psi_last_shoup);
        let half = lo.len();
        let vec_end = half - half % LANES;
        let mut j = 0;
        while j < vec_end {
            let xp = lo.as_mut_ptr().add(j);
            let yp = hi.as_mut_ptr().add(j);
            let u = vld1q_u64(xp);
            let v = vld1q_u64(yp);
            let sum = vaddq_u64(u, v);
            let diff = vaddq_u64(u, vsubq_u64(two_qv, v));
            vst1q_u64(xp, shoup_full(sum, ni, ni_sh, qv));
            vst1q_u64(yp, shoup_full(diff, pl, pl_sh, qv));
            j += LANES;
        }
        ScalarKernel.inverse_finish(
            &mut lo[vec_end..],
            &mut hi[vec_end..],
            n_inv,
            n_inv_shoup,
            psi_last,
            psi_last_shoup,
            q,
        );
    }

    #[target_feature(enable = "neon")]
    unsafe fn weighted_init_neon(dst: &mut [u64], src: &[u64], w: u64, br: Barrett) {
        let qv = splat(br.q);
        let mv = splat(br.magic());
        let wv = splat(w);
        let n = dst.len();
        let vec_end = n - n % LANES;
        let mut j = 0;
        while j < vec_end {
            let sp = src.as_ptr().add(j);
            let dp = dst.as_mut_ptr().add(j);
            // src and w are both < q < 2^31: one vmull_u32 is the exact
            // product.
            let t = vmull_u32(lo32(vld1q_u64(sp)), lo32(wv));
            vst1q_u64(dp, barrett_reduce(t, mv, qv));
            j += LANES;
        }
        ScalarKernel.weighted_init(&mut dst[vec_end..], &src[vec_end..], w, br);
    }

    #[target_feature(enable = "neon")]
    unsafe fn weighted_accumulate_neon(dst: &mut [u64], src: &[u64], w: u64, br: Barrett) {
        let qv = splat(br.q);
        let mv = splat(br.magic());
        let wv = splat(w);
        let n = dst.len();
        let vec_end = n - n % LANES;
        let mut j = 0;
        while j < vec_end {
            let sp = src.as_ptr().add(j);
            let dp = dst.as_mut_ptr().add(j);
            let t = vmull_u32(lo32(vld1q_u64(sp)), lo32(wv));
            let prod = barrett_reduce(t, mv, qv);
            let acc = vaddq_u64(vld1q_u64(dp), prod);
            vst1q_u64(dp, acc);
            j += LANES;
        }
        ScalarKernel.weighted_accumulate(&mut dst[vec_end..], &src[vec_end..], w, br);
    }

    #[target_feature(enable = "neon")]
    unsafe fn reduce_slice_neon(dst: &mut [u64], br: Barrett) {
        let qv = splat(br.q);
        let mv = splat(br.magic());
        let n = dst.len();
        let vec_end = n - n % LANES;
        let mut j = 0;
        while j < vec_end {
            let dp = dst.as_mut_ptr().add(j);
            let t = vld1q_u64(dp);
            vst1q_u64(dp, barrett_reduce(t, mv, qv));
            j += LANES;
        }
        ScalarKernel.reduce_slice(&mut dst[vec_end..], br);
    }

    impl NttKernel for NeonKernel {
        fn name(&self) -> &'static str {
            "neon"
        }

        fn is_simd(&self) -> bool {
            true
        }

        fn forward_stage(
            &self,
            a: &mut [u64],
            m: usize,
            t: usize,
            psi: &[u64],
            psi_shoup: &[u64],
            q: u64,
        ) {
            if t >= LANES {
                // Sound: NEON presence was verified before this handle
                // could be obtained.
                unsafe { forward_stage_neon(a, m, t, psi, psi_shoup, q) }
            } else {
                // The last stage (t = 1) interleaves wings too tightly for
                // 2-lane loads; it is O(n) scalar work.
                ScalarKernel.forward_stage(a, m, t, psi, psi_shoup, q);
            }
        }

        fn forward_finish(&self, a: &mut [u64], q: u64) {
            unsafe { forward_finish_neon(a, q) }
        }

        fn inverse_stage(
            &self,
            a: &mut [u64],
            h: usize,
            t: usize,
            psi: &[u64],
            psi_shoup: &[u64],
            q: u64,
        ) {
            if t >= LANES {
                unsafe { inverse_stage_neon(a, h, t, psi, psi_shoup, q) }
            } else {
                ScalarKernel.inverse_stage(a, h, t, psi, psi_shoup, q);
            }
        }

        fn inverse_finish(
            &self,
            lo: &mut [u64],
            hi: &mut [u64],
            n_inv: u64,
            n_inv_shoup: u64,
            psi_last: u64,
            psi_last_shoup: u64,
            q: u64,
        ) {
            unsafe { inverse_finish_neon(lo, hi, n_inv, n_inv_shoup, psi_last, psi_last_shoup, q) }
        }

        fn weighted_init(&self, dst: &mut [u64], src: &[u64], w: u64, br: Barrett) {
            if br.magic() >> 32 != 0 {
                ScalarKernel.weighted_init(dst, src, w, br);
            } else {
                unsafe { weighted_init_neon(dst, src, w, br) }
            }
        }

        fn weighted_accumulate(&self, dst: &mut [u64], src: &[u64], w: u64, br: Barrett) {
            if br.magic() >> 32 != 0 {
                ScalarKernel.weighted_accumulate(dst, src, w, br);
            } else {
                unsafe { weighted_accumulate_neon(dst, src, w, br) }
            }
        }

        fn reduce_slice(&self, dst: &mut [u64], br: Barrett) {
            if br.magic() >> 32 != 0 {
                ScalarKernel.reduce_slice(dst, br);
            } else {
                unsafe { reduce_slice_neon(dst, br) }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn env_override_forces_scalar() {
        assert_eq!(kernel_for(Some("scalar")).name(), "scalar");
        assert!(!kernel_for(Some("scalar")).is_simd());
    }

    #[test]
    fn unknown_override_auto_detects() {
        let auto = kernel_for(None).name();
        assert_eq!(kernel_for(Some("definitely-not-a-kernel")).name(), auto);
        assert_eq!(kernel_for(Some("avx2")).name(), auto);
    }

    #[test]
    fn active_is_a_known_kernel() {
        let k = active();
        assert!(k.name() == "scalar" || k.name() == "avx2" || k.name() == "neon");
    }
}
