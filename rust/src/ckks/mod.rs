//! From-scratch RNS-CKKS homomorphic encryption (the paper's "Crypto
//! Foundation" layer, which FedML-HE outsourced to PALISADE/TenSEAL).
//!
//! Scope is exactly the paper's usage envelope: approximate-number encoding,
//! encryption/decryption, ciphertext addition, and ciphertext × plaintext
//! *scalar* multiplication (multiplicative depth 1 — the aggregation-weight
//! multiply of Algorithm 1). No relinearization, rescaling or bootstrapping
//! is needed at this depth.
//!
//! Design choices (see DESIGN.md §2):
//! * power-of-two ring `Z_Q[X]/(X^n + 1)`, default `n = 8192`;
//! * RNS limbs `q_l < 2^31`, `q_l ≡ 1 (mod 2n)` so that the L1 Pallas kernel
//!   can mirror the modular arithmetic exactly in uint64;
//! * canonical-embedding slot encoding (`n/2` packed values per ciphertext =
//!   the paper's default "HE packing batch size 4096");
//! * ternary secrets, centered-binomial errors (σ ≈ 3.2);
//! * n-of-n additive threshold keys + Shamir escrow (Appendix B).

pub mod encoding;
pub mod encrypt;
pub mod keys;
pub mod modarith;
pub mod ntt;
pub mod ops;
pub mod params;
pub mod poly;
pub mod serialize;
pub mod simd;
pub mod threshold;

pub use encoding::{EncodeScratch, Encoder};
pub use encrypt::{
    decrypt, decrypt_into, encrypt, encrypt_into, encrypt_sym_seeded, encrypt_sym_seeded_into,
    expand_ct_a_limb, Ciphertext, EncKey,
};
pub use keys::{keygen, PublicKey, SecretKey};
pub use params::CkksParams;
pub use poly::{CkksScratch, RnsPoly};
pub use serialize::CtWire;

use crate::crypto::prng::ChaChaRng;
use std::sync::Arc;

/// A convenience bundle of parameters + encoder: the "crypto context" that
/// the key authority distributes in Algorithm 1.
#[derive(Clone)]
pub struct CkksContext {
    pub params: Arc<CkksParams>,
    pub encoder: Arc<Encoder>,
}

impl CkksContext {
    /// Build a context; `n` the ring degree (power of two), `scaling_bits`
    /// the CKKS scale exponent (paper default 52), `num_limbs` RNS limbs.
    pub fn new(n: usize, num_limbs: usize, scaling_bits: u32) -> anyhow::Result<Self> {
        let params = Arc::new(CkksParams::new(n, num_limbs, scaling_bits)?);
        let encoder = Arc::new(Encoder::new(params.clone()));
        Ok(CkksContext { params, encoder })
    }

    /// The paper's default configuration: multiplicative depth 1, scaling
    /// factor 52 bits, packing batch 4096 (n = 8192), 128-bit security.
    pub fn default_paper() -> anyhow::Result<Self> {
        Self::new(8192, 4, 52)
    }

    /// Values packed per ciphertext (the paper's "HE packing batch size").
    pub fn batch(&self) -> usize {
        self.params.n / 2
    }

    /// Generate a fresh key pair using this context.
    pub fn keygen(&self, rng: &mut ChaChaRng) -> (PublicKey, SecretKey) {
        keys::keygen(&self.params, rng)
    }

    /// Encrypt a slice of at most `batch()` f64 values.
    pub fn encrypt_values(
        &self,
        values: &[f64],
        pk: &PublicKey,
        rng: &mut ChaChaRng,
    ) -> Ciphertext {
        self.encrypt_values_keyed(values, EncKey::Public(pk), rng)
    }

    /// [`Self::encrypt_values`] under either ct-wire key mode.
    pub fn encrypt_values_keyed(
        &self,
        values: &[f64],
        key: EncKey<'_>,
        rng: &mut ChaChaRng,
    ) -> Ciphertext {
        let pt = self.encoder.encode(values);
        let mut scratch = CkksScratch::new(&self.params);
        let mut out = Ciphertext::zero(&self.params);
        key.encrypt_into(&self.params, &pt, values.len(), rng, &mut scratch, &mut out);
        out
    }

    /// Decrypt to `ct.n_values` f64 values, undoing the aggregate scale
    /// `Δ · Δ_w^depth` tracked by the ciphertext.
    pub fn decrypt_values(&self, ct: &Ciphertext, sk: &SecretKey) -> Vec<f64> {
        let pt = encrypt::decrypt(&self.params, sk, ct);
        self.encoder.decode(&pt, ct.n_values, ct.scale)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn context_roundtrip_small() {
        let ctx = CkksContext::new(1024, 3, 40).unwrap();
        let mut rng = ChaChaRng::from_seed(1, 0);
        let (pk, sk) = ctx.keygen(&mut rng);
        let values: Vec<f64> = (0..ctx.batch()).map(|i| (i as f64) / 100.0 - 2.0).collect();
        let ct = ctx.encrypt_values(&values, &pk, &mut rng);
        let dec = ctx.decrypt_values(&ct, &sk);
        for (a, b) in values.iter().zip(dec.iter()) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn default_paper_params() {
        let ctx = CkksContext::default_paper().unwrap();
        assert_eq!(ctx.batch(), 4096);
        assert_eq!(ctx.params.moduli.len(), 4);
        assert!(ctx.params.log2_q() > 100.0);
    }
}
