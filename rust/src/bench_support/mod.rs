//! Benchmark support: the measured HE pipeline used by every table/figure
//! harness (criterion is unavailable offline; each bench target under
//! `rust/benches/` is a `harness = false` binary built on this module).
//!
//! Methodology: HE cost is measured per-ciphertext on a sample of chunks and
//! scaled linearly to the full model — the O(n) linearity is itself verified
//! by `linearity_holds` below, and matches the paper's own observation
//! (§1, Fig. 2: "overheads grow linearly with the input size").

use crate::ckks::{encrypt, ops, threshold, Ciphertext, CkksContext};
use crate::crypto::prng::ChaChaRng;
use std::time::Instant;

/// Per-stage measured seconds for an HE FedAvg pipeline on one model.
#[derive(Debug, Clone, Copy, Default)]
pub struct HePipelineCost {
    pub params: u64,
    pub n_clients: usize,
    /// Per-client encryption of the full update.
    pub encrypt_secs: f64,
    /// Server-side homomorphic weighted aggregation.
    pub aggregate_secs: f64,
    /// Key-holder decryption of the aggregate.
    pub decrypt_secs: f64,
    /// Plain (non-HE) aggregation of the same model.
    pub plain_secs: f64,
    /// Ciphertext bytes per client upload.
    pub ct_bytes: u64,
    /// Plaintext bytes per client upload.
    pub pt_bytes: u64,
    /// Fraction of ciphertexts actually measured (1.0 = full).
    pub sample_fraction: f64,
}

impl HePipelineCost {
    /// Total HE-side seconds (the Table-4 "HE Time" column: encrypt all
    /// clients + aggregate + decrypt).
    pub fn he_secs(&self) -> f64 {
        self.encrypt_secs * self.n_clients as f64 + self.aggregate_secs + self.decrypt_secs
    }
    /// Computation overhead ratio vs plaintext (Table 4 "Comp Ratio").
    pub fn comp_ratio(&self) -> f64 {
        self.he_secs() / self.plain_secs.max(1e-9)
    }
    /// Communication overhead ratio (Table 4 "Comm Ratio").
    pub fn comm_ratio(&self) -> f64 {
        self.ct_bytes as f64 / self.pt_bytes.max(1) as f64
    }
}

/// Measure the full-encryption FedAvg pipeline for a model of `n_params`
/// parameters and `n_clients` clients, measuring at most `max_cts`
/// ciphertext chunks and extrapolating linearly.
pub fn measure_pipeline(
    ctx: &CkksContext,
    n_clients: usize,
    n_params: u64,
    max_cts: usize,
    rng: &mut ChaChaRng,
) -> HePipelineCost {
    let batch = ctx.batch() as u64;
    let total_cts = n_params.div_ceil(batch).max(1);
    let measured_cts = (total_cts as usize).min(max_cts).max(1);
    let scale = total_cts as f64 / measured_cts as f64;

    let (pk, sk) = ctx.keygen(rng);
    let alphas: Vec<f64> = vec![1.0 / n_clients as f64; n_clients];
    let values: Vec<f64> = (0..ctx.batch())
        .map(|i| ((i * 13) as f64 * 1e-4).sin())
        .collect();

    let mut enc = 0.0;
    let mut agg = 0.0;
    let mut dec = 0.0;
    for _ in 0..measured_cts {
        // measure one client's encode+encrypt as the per-client figure
        let mut cts: Vec<Ciphertext> = Vec::with_capacity(n_clients);
        for c in 0..n_clients {
            let t = Instant::now();
            let pt = ctx.encoder.encode(&values);
            let ct = encrypt::encrypt(&ctx.params, &pk, &pt, values.len(), rng);
            if c == 0 {
                enc += t.elapsed().as_secs_f64();
            }
            cts.push(ct);
        }
        let t = Instant::now();
        let out = ops::weighted_sum(&cts, &alphas, &ctx.params);
        agg += t.elapsed().as_secs_f64();
        let t = Instant::now();
        let _ = ctx.decrypt_values(&out, &sk);
        dec += t.elapsed().as_secs_f64();
    }

    // plaintext aggregation over the same parameter count (sampled)
    let plain_chunk: usize = 1 << 20;
    let plain_measured = (n_params as usize).min(plain_chunk).max(1);
    let models: Vec<Vec<f32>> = (0..n_clients)
        .map(|c| (0..plain_measured).map(|i| ((i + c) as f32) * 1e-6).collect())
        .collect();
    let t = Instant::now();
    let _ = crate::he_agg::native::plain_fedavg(&models, &alphas);
    let plain_secs = t.elapsed().as_secs_f64() * (n_params as f64 / plain_measured as f64);

    HePipelineCost {
        params: n_params,
        n_clients,
        encrypt_secs: enc * scale,
        aggregate_secs: agg * scale,
        decrypt_secs: dec * scale,
        plain_secs,
        ct_bytes: total_cts * ctx.params.ciphertext_bytes() as u64,
        pt_bytes: 4 * n_params,
        sample_fraction: measured_cts as f64 / total_cts as f64,
    }
}

/// Selective-encryption variant: encrypt `ratio` of the parameters, leave
/// the rest plaintext (Fig. 7 / Table 7 workload).
pub fn measure_selective(
    ctx: &CkksContext,
    n_clients: usize,
    n_params: u64,
    ratio: f64,
    max_cts: usize,
    rng: &mut ChaChaRng,
) -> HePipelineCost {
    let enc_params = (n_params as f64 * ratio).round() as u64;
    let plain_params = n_params - enc_params;
    let mut cost = if enc_params > 0 {
        measure_pipeline(ctx, n_clients, enc_params, max_cts, rng)
    } else {
        HePipelineCost {
            n_clients,
            sample_fraction: 1.0,
            ..Default::default()
        }
    };
    // the plaintext remainder adds plain aggregation time + bytes
    if plain_params > 0 {
        let alphas: Vec<f64> = vec![1.0 / n_clients as f64; n_clients];
        let chunk = (plain_params as usize).min(1 << 20);
        let models: Vec<Vec<f32>> = (0..n_clients)
            .map(|c| (0..chunk).map(|i| ((i + c) as f32) * 1e-6).collect())
            .collect();
        let t = Instant::now();
        let _ = crate::he_agg::native::plain_fedavg(&models, &alphas);
        cost.plain_secs += t.elapsed().as_secs_f64() * (plain_params as f64 / chunk as f64);
        cost.ct_bytes += 4 * plain_params;
    }
    cost.params = n_params;
    cost.pt_bytes = 4 * n_params;
    cost
}

/// Threshold-HE pipeline cost (Fig. 12): interactive keygen + encrypt +
/// aggregate + distributed decryption for `n_parties`.
#[derive(Debug, Clone, Copy, Default)]
pub struct ThresholdCost {
    pub keygen_secs: f64,
    pub encrypt_secs: f64,
    pub aggregate_secs: f64,
    pub decrypt_secs: f64,
}

pub fn measure_threshold(
    ctx: &CkksContext,
    n_parties: usize,
    n_cts: usize,
    rng: &mut ChaChaRng,
) -> ThresholdCost {
    let t = Instant::now();
    let a = threshold::common_reference(&ctx.params, 1);
    let parties: Vec<threshold::ThresholdParty> = (0..n_parties)
        .map(|k| threshold::party_keygen(&ctx.params, k, &a, rng))
        .collect();
    let shares: Vec<&crate::ckks::RnsPoly> = parties.iter().map(|p| &p.b_share_ntt).collect();
    let pk = threshold::combine_public_key(&ctx.params, &a, &shares);
    let keygen_secs = t.elapsed().as_secs_f64();

    let values: Vec<f64> = (0..ctx.batch()).map(|i| (i as f64) * 1e-4).collect();
    let alphas: Vec<f64> = vec![1.0 / n_parties as f64; n_parties];
    let mut encrypt_secs = 0.0;
    let mut aggregate_secs = 0.0;
    let mut decrypt_secs = 0.0;
    for _ in 0..n_cts {
        let mut cts = Vec::with_capacity(n_parties);
        for _ in 0..n_parties {
            let t = Instant::now();
            let pt = ctx.encoder.encode(&values);
            cts.push(encrypt::encrypt(&ctx.params, &pk, &pt, values.len(), rng));
            encrypt_secs += t.elapsed().as_secs_f64();
        }
        let t = Instant::now();
        let agg = ops::weighted_sum(&cts, &alphas, &ctx.params);
        aggregate_secs += t.elapsed().as_secs_f64();
        let t = Instant::now();
        let partials: Vec<crate::ckks::RnsPoly> = parties
            .iter()
            .map(|p| threshold::partial_decrypt(&ctx.params, p, &agg, rng))
            .collect();
        let m = threshold::combine_partials(&ctx.params, &agg, &partials);
        let _ = ctx.encoder.decode(&m, agg.n_values, agg.scale);
        decrypt_secs += t.elapsed().as_secs_f64();
    }
    ThresholdCost {
        keygen_secs,
        encrypt_secs,
        aggregate_secs,
        decrypt_secs,
    }
}

/// Wall-clock a closure `iters` times, returning per-iteration seconds.
pub fn time_iters<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    let t = Instant::now();
    for _ in 0..iters {
        f();
    }
    t.elapsed().as_secs_f64() / iters.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pipeline_measures_something() {
        let ctx = CkksContext::new(1024, 4, 40).unwrap();
        let mut rng = ChaChaRng::from_seed(1, 0);
        let cost = measure_pipeline(&ctx, 3, 5_000, 8, &mut rng);
        assert!(cost.he_secs() > 0.0);
        assert!(cost.plain_secs > 0.0);
        assert!(cost.comp_ratio() > 1.0, "HE must cost more than plaintext");
        assert!(cost.comm_ratio() > 1.0);
        assert!((cost.sample_fraction - 0.8).abs() < 1e-9); // 8 of 10 chunks
    }

    #[test]
    fn linearity_holds() {
        // The extrapolation premise: cost per ciphertext is constant.
        let ctx = CkksContext::new(1024, 4, 40).unwrap();
        let mut rng = ChaChaRng::from_seed(2, 0);
        let small = measure_pipeline(&ctx, 2, 512 * 4, 4, &mut rng);
        let large = measure_pipeline(&ctx, 2, 512 * 16, 16, &mut rng);
        let ratio = large.he_secs() / small.he_secs();
        assert!((2.0..8.0).contains(&ratio), "ratio {ratio} not ~4");
    }

    #[test]
    fn selective_cheaper_than_full() {
        let ctx = CkksContext::new(1024, 4, 40).unwrap();
        let mut rng = ChaChaRng::from_seed(3, 0);
        let full = measure_selective(&ctx, 3, 50_000, 1.0, 8, &mut rng);
        let tenth = measure_selective(&ctx, 3, 50_000, 0.1, 8, &mut rng);
        let none = measure_selective(&ctx, 3, 50_000, 0.0, 8, &mut rng);
        assert!(tenth.he_secs() < full.he_secs());
        assert!(tenth.ct_bytes < full.ct_bytes);
        assert_eq!(none.he_secs(), 0.0);
        assert_eq!(none.ct_bytes, 4 * 50_000);
    }

    #[test]
    fn threshold_cost_positive() {
        let ctx = CkksContext::new(512, 4, 40).unwrap();
        let mut rng = ChaChaRng::from_seed(4, 0);
        let c = measure_threshold(&ctx, 2, 2, &mut rng);
        assert!(c.keygen_secs > 0.0 && c.decrypt_secs > 0.0);
    }
}
