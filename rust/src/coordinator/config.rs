//! FL task configuration (the "server package" of the deployment platform).

use crate::agg_engine::{Engine, EngineConfig};
use crate::ckks::CtWire;
use crate::util::cli::Args;

/// Which parameters get encrypted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Selection {
    /// Full encryption (vanilla HE baseline).
    Full,
    /// Paper's Selective Parameter Encryption: top-p by sensitivity.
    TopP,
    /// Random-p baseline (Fig. 9 comparison).
    Random,
    /// No encryption (plaintext FedAvg baseline).
    None,
}

impl Selection {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "full" => Selection::Full,
            "topp" | "top-p" | "sensitivity" => Selection::TopP,
            "random" => Selection::Random,
            "none" | "plaintext" => Selection::None,
            other => anyhow::bail!("unknown selection strategy '{other}'"),
        })
    }
}

/// Granularity of the selective-encryption mask.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MaskGranularity {
    /// Per-parameter top-p selection (the paper's headline mechanism). The
    /// mask-agreement stage aggregates an O(params) sensitivity map.
    Param,
    /// Whole-layer selection: clients aggregate sensitivity per layer, the
    /// server picks whole layers by mean score. The practical deployment
    /// mode — the agreement message and the mask both shrink to O(layers).
    Layer,
}

impl MaskGranularity {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "param" | "parameter" => MaskGranularity::Param,
            "layer" => MaskGranularity::Layer,
            other => anyhow::bail!(
                "unknown mask granularity '{other}' (expected: param | layer)"
            ),
        })
    }
}

/// How client updates reach the server's aggregation intake.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Transport {
    /// In-process simulator: arrivals are stamped with `netsim` transfer
    /// times derived from the configured bandwidth profile.
    Sim,
    /// Real TCP: each participant uploads its serialized update over a
    /// socket ([`crate::transport`]); arrivals are stamped with wall-clock
    /// receive times and a mid-upload disconnect becomes a dropped
    /// straggler. TCP rounds always aggregate through the streaming intake
    /// (bitwise-identical to the sequential kernel), so `--engine` only
    /// selects the aggregation loop of the simulator path.
    Tcp,
}

impl Transport {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "sim" | "simulated" => Transport::Sim,
            "tcp" => Transport::Tcp,
            other => anyhow::bail!("unknown transport '{other}' (expected: sim | tcp)"),
        })
    }
}

/// Session wire-authentication mode (`--wire-auth {none,mac}`, DESIGN.md
/// §12). The default comes from the `FEDML_HE_WIRE_AUTH` environment
/// variable when set (mirroring `FEDML_HE_NTT_KERNEL`), so CI can run the
/// whole tier-1 suite once per mode without touching every invocation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireAuth {
    /// Legacy plaintext control plane: CRC only, unauthenticated HELLO.
    None,
    /// Challenge/response handshake + per-frame SipHash-2-4 tags with a
    /// session-monotone replay window.
    Mac,
}

impl WireAuth {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "none" => WireAuth::None,
            "mac" => WireAuth::Mac,
            other => anyhow::bail!("unknown wire-auth mode '{other}' (expected: none | mac)"),
        })
    }

    /// Process-wide default: `FEDML_HE_WIRE_AUTH` when set and valid,
    /// else [`WireAuth::None`].
    pub fn env_default() -> Self {
        match std::env::var("FEDML_HE_WIRE_AUTH") {
            Ok(v) => WireAuth::parse(v.trim()).unwrap_or(WireAuth::None),
            Err(_) => WireAuth::None,
        }
    }
}

/// Which server-side session driver carries `--transport tcp` traffic
/// (`--transport-backend {threads,hub}`, DESIGN.md §13). The default comes
/// from the `FEDML_HE_TRANSPORT_BACKEND` environment variable when set
/// (mirroring `FEDML_HE_WIRE_AUTH`), so CI can rerun the whole tier-1
/// suite on the reactor hub without touching every invocation. Both
/// backends speak the identical wire protocol and produce bitwise-identical
/// final models; clients never see the difference.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportBackend {
    /// Blocking sockets, one OS thread per live session (the legacy
    /// `transport::session::SessionHub`).
    Threads,
    /// Sharded epoll reactor: nonblocking sockets multiplexed across a few
    /// shard threads (`transport::hub::ReactorHub`), sized for thousands of
    /// concurrent sessions.
    Hub,
}

impl TransportBackend {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "threads" | "thread" | "blocking" => TransportBackend::Threads,
            "hub" | "reactor" | "epoll" => TransportBackend::Hub,
            other => anyhow::bail!(
                "unknown transport backend '{other}' (expected: threads | hub)"
            ),
        })
    }

    /// Process-wide default: `FEDML_HE_TRANSPORT_BACKEND` when set and
    /// valid, else [`TransportBackend::Threads`].
    pub fn env_default() -> Self {
        match std::env::var("FEDML_HE_TRANSPORT_BACKEND") {
            Ok(v) => TransportBackend::parse(v.trim()).unwrap_or(TransportBackend::Threads),
            Err(_) => TransportBackend::Threads,
        }
    }
}

/// Aggregation backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// AOT Pallas kernel via PJRT (the three-layer hot path).
    Xla,
    /// Pure-Rust aggregation.
    Native,
}

/// Key management mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KeyMode {
    /// Trusted key authority distributes one key pair (paper default).
    SingleKey,
    /// n-of-n threshold key agreement (Appendix B).
    Threshold,
}

/// Full FL task configuration.
#[derive(Debug, Clone)]
pub struct FlConfig {
    pub model: String,
    pub clients: usize,
    pub rounds: usize,
    pub local_steps: usize,
    pub lr: f32,
    /// Selective-encryption ratio p ∈ [0, 1].
    pub ratio: f64,
    pub selection: Selection,
    /// Mask granularity for top-p selection (`--mask-granularity
    /// {param,layer}`).
    pub mask_granularity: MaskGranularity,
    pub backend: Backend,
    pub key_mode: KeyMode,
    /// Per-round client dropout probability.
    pub dropout: f64,
    /// Optional local-DP Laplace scale on the plaintext part (Algorithm 1's
    /// optional noise).
    pub dp_scale: Option<f64>,
    /// Samples per client.
    pub samples_per_client: usize,
    /// Label-skew level in [0, 1].
    pub skew: f64,
    pub seed: u64,
    pub bandwidth: crate::netsim::Bandwidth,
    /// Evaluate every k rounds (0 = never).
    pub eval_every: usize,
    /// Override the crypto context as (n, num_limbs, scaling_bits) — used by
    /// the Table-6 crypto-parameter sweep. Only valid with the native
    /// backend (the XLA artifacts are compiled for the default context).
    pub crypto_override: Option<(usize, usize, u32)>,
    /// Aggregation engine: the seed's sequential loop or the sharded
    /// streaming pipeline (`agg_engine`).
    pub engine: Engine,
    /// Worker shards for the pipeline engine.
    pub shards: usize,
    /// Aggregate-at-quorum: minimum arrivals before the straggler cutoff
    /// applies (`None` = wait for every participant).
    pub quorum: Option<usize>,
    /// Simulated seconds after quorum during which stragglers still make it.
    pub straggler_timeout: f64,
    /// Registered virtual-client population; when set, each round's
    /// participants are a cohort of `clients` sampled from this population
    /// (lazily materialized — see `agg_engine::cohort`).
    pub population: Option<u64>,
    /// Update delivery: in-process simulator or real TCP sockets.
    pub transport: Transport,
    /// Bind address for the TCP intake (`--listen`; port 0 = ephemeral).
    pub listen: String,
    /// Address uploaders dial (`--connect`; defaults to the bound listen
    /// address, which is the loopback single-process case).
    pub connect: Option<String>,
    /// Hard wall-clock bound in seconds on one TCP intake round
    /// (`--intake-max-wait`; default 30 s + the straggler timeout). Raise
    /// it for slow links where honest uploads take longer.
    pub intake_max_wait: Option<f64>,
    /// Flat parameter count of the artifact-free `synthetic` model
    /// (`--synthetic-params`; ignored for artifact models).
    pub synthetic_dim: usize,
    /// Seconds the server waits for all clients' session handshakes
    /// (`--join-wait`) — the barrier before the mask-agreement stage under
    /// `--transport tcp` and `serve`.
    pub join_wait: f64,
    /// Seconds a client session waits for the next downlink
    /// (`--round-wait`) — covers server aggregation plus the other
    /// clients' training between rounds.
    pub round_wait: f64,
    /// Session wire-authentication mode (`--wire-auth`).
    pub wire_auth: WireAuth,
    /// Uplink ciphertext wire format (`--ct-wire {dense,seed}`, env
    /// `FEDML_HE_CT_WIRE`). `seed` switches clients to symmetric seeded
    /// encryption whose a-part travels as a 32-byte seed — roughly halving
    /// encrypted upload bytes — and the server to lazy a-expansion.
    /// Task-level: the HELLO/WELCOME handshake refuses mismatched peers.
    pub ct_wire: CtWire,
    /// Server session driver under `--transport tcp`
    /// (`--transport-backend`): blocking thread-per-session or the sharded
    /// epoll reactor hub.
    pub transport_backend: TransportBackend,
    /// Connect/rejoin attempts before a client session gives up
    /// (`--connect-retries`; 0 = fail fast on the first refusal).
    pub connect_retries: u32,
    /// Base delay in milliseconds for the capped exponential connect
    /// backoff (`--retry-base-ms`; jittered, doubling per attempt).
    pub retry_base_ms: u64,
}

impl Default for FlConfig {
    fn default() -> Self {
        FlConfig {
            model: "lenet".to_string(),
            clients: 8,
            rounds: 20,
            local_steps: 4,
            lr: 0.05,
            ratio: 0.1,
            selection: Selection::TopP,
            mask_granularity: MaskGranularity::Param,
            backend: Backend::Xla,
            key_mode: KeyMode::SingleKey,
            dropout: 0.0,
            dp_scale: None,
            samples_per_client: 128,
            skew: 0.5,
            seed: 42,
            bandwidth: crate::netsim::SINGLE_AWS_REGION,
            eval_every: 5,
            crypto_override: None,
            engine: Engine::Sequential,
            shards: 4,
            quorum: None,
            straggler_timeout: 5.0,
            population: None,
            transport: Transport::Sim,
            listen: "127.0.0.1:0".to_string(),
            connect: None,
            intake_max_wait: None,
            synthetic_dim: crate::fl::SYNTHETIC_DEFAULT_DIM,
            join_wait: 120.0,
            round_wait: 300.0,
            wire_auth: WireAuth::env_default(),
            ct_wire: CtWire::env_default(),
            transport_backend: TransportBackend::env_default(),
            connect_retries: 5,
            retry_base_ms: 50,
        }
    }
}

impl FlConfig {
    /// Parse from CLI options (unset options keep defaults).
    pub fn from_args(args: &Args) -> anyhow::Result<Self> {
        let d = FlConfig::default();
        let bandwidth = match args.get_or("bandwidth", "sar").as_str() {
            "ib" => crate::netsim::INFINIBAND,
            "sar" => crate::netsim::SINGLE_AWS_REGION,
            "mar" => crate::netsim::MULTI_AWS_REGION,
            "aws200" => crate::netsim::FIG8_REGION,
            other => anyhow::bail!("unknown bandwidth profile '{other}'"),
        };
        Ok(FlConfig {
            model: args.get_or("model", &d.model),
            clients: args.get_parsed_or("clients", d.clients),
            rounds: args.get_parsed_or("rounds", d.rounds),
            local_steps: args.get_parsed_or("local-steps", d.local_steps),
            lr: args.get_parsed_or("lr", d.lr),
            ratio: args.get_parsed_or("ratio", d.ratio),
            selection: Selection::parse(&args.get_or("selection", "topp"))?,
            mask_granularity: MaskGranularity::parse(
                &args.get_or("mask-granularity", "param"),
            )?,
            backend: match args.get_or("backend", "xla").as_str() {
                "xla" => Backend::Xla,
                "native" => Backend::Native,
                other => anyhow::bail!("unknown backend '{other}'"),
            },
            key_mode: match args.get_or("keys", "single").as_str() {
                "single" => KeyMode::SingleKey,
                "threshold" => KeyMode::Threshold,
                other => anyhow::bail!("unknown key mode '{other}'"),
            },
            dropout: args.get_parsed_or("dropout", d.dropout),
            dp_scale: args.get("dp-scale").and_then(|v| v.parse().ok()),
            samples_per_client: args.get_parsed_or("samples", d.samples_per_client),
            skew: args.get_parsed_or("skew", d.skew),
            seed: args.get_parsed_or("seed", d.seed),
            bandwidth,
            eval_every: args.get_parsed_or("eval-every", d.eval_every),
            crypto_override: None,
            engine: Engine::parse(&args.get_or("engine", "sequential"))?,
            shards: args.parsed("shards")?.unwrap_or(d.shards),
            quorum: args.parsed("quorum")?,
            straggler_timeout: args
                .parsed("straggler-timeout")?
                .unwrap_or(d.straggler_timeout),
            population: args.parsed("population")?,
            transport: Transport::parse(&args.get_or("transport", "sim"))?,
            listen: args.get_or("listen", &d.listen),
            connect: args.get("connect").map(String::from),
            intake_max_wait: args.parsed("intake-max-wait")?,
            synthetic_dim: args.get_parsed_or("synthetic-params", d.synthetic_dim),
            join_wait: args.get_parsed_or("join-wait", d.join_wait),
            round_wait: args.get_parsed_or("round-wait", d.round_wait),
            wire_auth: match args.get("wire-auth") {
                Some(v) => WireAuth::parse(&v)?,
                None => d.wire_auth,
            },
            ct_wire: match args.get("ct-wire") {
                Some(v) => CtWire::parse(v.trim()).ok_or_else(|| {
                    anyhow::anyhow!("unknown ct-wire mode '{v}' (expected: dense | seed)")
                })?,
                None => d.ct_wire,
            },
            transport_backend: match args.get("transport-backend") {
                Some(v) => TransportBackend::parse(&v)?,
                None => d.transport_backend,
            },
            connect_retries: args.get_parsed_or("connect-retries", d.connect_retries),
            retry_base_ms: args.get_parsed_or("retry-base-ms", d.retry_base_ms),
        })
    }

    /// The engine knobs in `agg_engine` form.
    pub fn engine_config(&self) -> EngineConfig {
        EngineConfig {
            engine: self.engine,
            shards: self.shards.max(1),
            quorum: self.quorum,
            straggler_timeout_secs: self.straggler_timeout,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_and_overrides() {
        let args = Args::parse_from(
            "run --model mlp --clients 12 --ratio 0.3 --selection random --backend native \
             --keys threshold --bandwidth mar --dropout 0.2"
                .split_whitespace()
                .map(String::from),
        );
        let c = FlConfig::from_args(&args).unwrap();
        assert_eq!(c.model, "mlp");
        assert_eq!(c.clients, 12);
        assert_eq!(c.ratio, 0.3);
        assert_eq!(c.selection, Selection::Random);
        assert_eq!(c.backend, Backend::Native);
        assert_eq!(c.key_mode, KeyMode::Threshold);
        assert_eq!(c.bandwidth.name, "MAR");
        assert_eq!(c.dropout, 0.2);
        // untouched defaults
        assert_eq!(c.rounds, 20);
        assert_eq!(c.engine, Engine::Sequential);
        assert_eq!(c.quorum, None);
        assert_eq!(c.population, None);
        assert_eq!(c.mask_granularity, MaskGranularity::Param);
        assert_eq!(c.transport, Transport::Sim);
        assert_eq!(c.listen, "127.0.0.1:0");
        assert_eq!(c.connect, None);
    }

    #[test]
    fn transport_options_parse() {
        let args = Args::parse_from(
            "run --transport tcp --listen 127.0.0.1:7070 --connect 10.0.0.5:7070 \
             --intake-max-wait 120 --synthetic-params 2048 --join-wait 45 \
             --round-wait 90"
                .split_whitespace()
                .map(String::from),
        );
        let c = FlConfig::from_args(&args).unwrap();
        assert_eq!(c.transport, Transport::Tcp);
        assert_eq!(c.listen, "127.0.0.1:7070");
        assert_eq!(c.connect.as_deref(), Some("10.0.0.5:7070"));
        assert_eq!(c.intake_max_wait, Some(120.0));
        assert_eq!(c.synthetic_dim, 2048);
        assert_eq!(c.join_wait, 45.0);
        assert_eq!(c.round_wait, 90.0);
        assert_eq!(Transport::parse("sim").unwrap(), Transport::Sim);
        assert_eq!(Transport::parse("simulated").unwrap(), Transport::Sim);
        assert!(Transport::parse("udp").is_err());
        // defaults
        let d = FlConfig::default();
        assert_eq!(d.synthetic_dim, crate::fl::SYNTHETIC_DEFAULT_DIM);
        assert!(d.join_wait > 0.0 && d.round_wait > 0.0);
    }

    #[test]
    fn wire_auth_parses() {
        let args = Args::parse_from(
            "run --wire-auth mac --connect-retries 9 --retry-base-ms 10"
                .split_whitespace()
                .map(String::from),
        );
        let c = FlConfig::from_args(&args).unwrap();
        assert_eq!(c.wire_auth, WireAuth::Mac);
        assert_eq!(c.connect_retries, 9);
        assert_eq!(c.retry_base_ms, 10);
        assert_eq!(WireAuth::parse("none").unwrap(), WireAuth::None);
        assert!(WireAuth::parse("tls").is_err());
    }

    #[test]
    fn ct_wire_parses() {
        let args = Args::parse_from(
            "run --ct-wire seed".split_whitespace().map(String::from),
        );
        let c = FlConfig::from_args(&args).unwrap();
        assert_eq!(c.ct_wire, CtWire::Seed);
        let none = Args::parse_from(["run".to_string()]);
        // no env override in tests: the default wire stays dense
        if std::env::var("FEDML_HE_CT_WIRE").is_err() {
            assert_eq!(FlConfig::from_args(&none).unwrap().ct_wire, CtWire::Dense);
        }
        assert_eq!(CtWire::parse("dense").unwrap(), CtWire::Dense);
        assert!(CtWire::parse("sparse").is_none());
    }

    #[test]
    fn transport_backend_parses() {
        let args = Args::parse_from(
            "run --transport tcp --transport-backend hub"
                .split_whitespace()
                .map(String::from),
        );
        let c = FlConfig::from_args(&args).unwrap();
        assert_eq!(c.transport_backend, TransportBackend::Hub);
        assert_eq!(
            TransportBackend::parse("threads").unwrap(),
            TransportBackend::Threads
        );
        assert_eq!(
            TransportBackend::parse("reactor").unwrap(),
            TransportBackend::Hub
        );
        assert!(TransportBackend::parse("iocp").is_err());
    }

    #[test]
    fn mask_granularity_parses() {
        let args = Args::parse_from(
            "run --mask-granularity layer"
                .split_whitespace()
                .map(String::from),
        );
        let c = FlConfig::from_args(&args).unwrap();
        assert_eq!(c.mask_granularity, MaskGranularity::Layer);
        assert_eq!(MaskGranularity::parse("param").unwrap(), MaskGranularity::Param);
        assert_eq!(MaskGranularity::parse("parameter").unwrap(), MaskGranularity::Param);
        assert!(MaskGranularity::parse("tensor").is_err());
    }

    #[test]
    fn engine_options_parse() {
        let args = Args::parse_from(
            "run --engine pipeline --shards 8 --quorum 12 --straggler-timeout 2.5 \
             --population 1000000"
                .split_whitespace()
                .map(String::from),
        );
        let c = FlConfig::from_args(&args).unwrap();
        assert_eq!(c.engine, Engine::Pipeline);
        assert_eq!(c.shards, 8);
        assert_eq!(c.quorum, Some(12));
        assert_eq!(c.straggler_timeout, 2.5);
        assert_eq!(c.population, Some(1_000_000));
        let ec = c.engine_config();
        assert_eq!(ec.engine, Engine::Pipeline);
        assert_eq!(ec.shards, 8);
        assert_eq!(ec.quorum, Some(12));
        assert_eq!(ec.straggler_timeout_secs, 2.5);
    }

    #[test]
    fn bad_values_rejected() {
        for bad in [
            "run --selection nope",
            "run --backend gpu",
            "run --keys paillier",
            "run --bandwidth lan",
            "run --engine gpu",
            "run --quorum many",
            "run --population everyone",
            "run --shards 1O",
            "run --straggler-timeout soon",
            "run --mask-granularity tensor",
            "run --transport udp",
            "run --intake-max-wait soon",
            "run --wire-auth hmac",
            "run --ct-wire sparse",
            "run --transport-backend fancy",
            "run --connect-retries lots",
            "run --retry-base-ms soon",
        ] {
            let args = Args::parse_from(bad.split_whitespace().map(String::from));
            assert!(FlConfig::from_args(&args).is_err(), "{bad}");
        }
    }
}
