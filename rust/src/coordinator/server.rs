//! FL server: Fig. 3's three stages as an explicit round-phase state
//! machine. The phases themselves — KeyAgreement → MaskAgreement → per
//! round {Broadcast, LocalTrain+Encrypt/Intake, Aggregate, Decrypt+Apply} →
//! Eval → Finale — live in [`super::phases`]; this module owns the
//! configuration surface, the aggregation/decryption primitives, the
//! per-stage overhead report (the data source for Figs. 8/14 and the
//! deployment-platform monitoring of Appendix C), and the three run modes:
//!
//! * [`FlServer::run`] with `--transport sim` — in-process simulator
//!   participants, simulated comm accounting.
//! * [`FlServer::run`] with `--transport tcp` — the same phase machine
//!   driving persistent duplex sessions over loopback: the coordinator
//!   spawns one client-session thread per client running the exact `join`
//!   loop, and every mask/global downlink and update uplink is real frames
//!   with measured bytes/times.
//! * [`FlServer::serve`] — the multi-process deployment: clients are
//!   separate `join` OS processes, keys distributed out-of-band via a task
//!   key file (DESIGN.md §9). Same phases, same bytes, bitwise-identical
//!   final model for the same seed.

use super::config::{Backend, FlConfig, KeyMode, Transport, TransportBackend, WireAuth};
use super::key_authority::KeyMaterial;
use super::phases::{self, Participant, RemoteParticipant, SimParticipant, Uplink};
use super::taskkey::{TaskKey, TaskSpec};
use crate::ckks::{CkksContext, CtWire};
use crate::coordinator::client::{ClientCore, FlClient};
use crate::crypto::prng::ChaChaRng;
use crate::fl::{SyntheticClient, SyntheticModel, SYNTHETIC_MODEL};
use crate::he_agg::xla::XlaAggregator;
use crate::he_agg::{native, selective, EncryptedUpdate, EncryptionMask, SelectiveCodec};
use crate::runtime::Runtime;
use crate::transport::{ReactorHub, SessionHub, SessionOpts, TransportHub};
use crate::util::json::Json;
use std::time::Duration;

/// Bind the selected server-side session backend (`--transport-backend`):
/// both serve the identical wire protocol, so everything downstream of
/// this call is backend-agnostic.
fn bind_transport_hub(
    backend: TransportBackend,
    addr: &str,
    params: std::sync::Arc<crate::ckks::CkksParams>,
    max_sessions: usize,
    auth_root: Option<[u8; 32]>,
    ct_wire: CtWire,
) -> anyhow::Result<TransportHub> {
    Ok(match backend {
        TransportBackend::Threads => TransportHub::Threads(SessionHub::bind_full(
            addr,
            params,
            max_sessions,
            auth_root,
            ct_wire,
        )?),
        TransportBackend::Hub => TransportHub::Reactor(ReactorHub::bind_full(
            addr,
            params,
            max_sessions,
            auth_root,
            ct_wire,
        )?),
    })
}

/// Crypto context used by the artifact-free `synthetic` model when no
/// `--n/--limbs` override is given: modest (fast CI smoke) but real RNS.
pub const SYNTHETIC_CRYPTO: (usize, usize, u32) = (1024, 4, 40);

/// `timing_source` label: stage/comm times are simulated from the
/// configured bandwidth profile.
pub const TIMING_SIMULATED: &str = "simulated";
/// `timing_source` label: comm times and byte counts are measured off real
/// sockets (persistent duplex sessions).
pub const TIMING_MEASURED: &str = "measured";

/// Per-round overhead breakdown (the paper's "training cycle" dissection).
/// `comm_secs` uses parallel-uplink accounting (round comm = max over the
/// concurrent uploads + broadcast time) under `--transport sim`; under tcp
/// every comm number is measured wall clock — uplink intake time plus the
/// real downlink push — and `timing_source` says which convention a row
/// uses, so sim and tcp reports are never silently conflated.
#[derive(Debug, Clone, Default)]
pub struct RoundMetrics {
    pub round: usize,
    pub participants: usize,
    /// Late uploads dropped by the pipeline engine's quorum policy.
    pub stragglers_dropped: usize,
    pub train_secs: f64,
    pub encrypt_secs: f64,
    pub aggregate_secs: f64,
    pub decrypt_secs: f64,
    /// Simulated network time (sim) or measured wall-clock comm (tcp).
    pub comm_secs: f64,
    /// Measured downlink wall time under tcp (0 under sim: the broadcast
    /// is folded into `comm_secs` by the clock).
    pub downlink_secs: f64,
    pub upload_bytes: u64,
    pub download_bytes: u64,
    pub train_loss: f32,
    /// [`TIMING_SIMULATED`] or [`TIMING_MEASURED`].
    pub timing_source: &'static str,
}

/// An evaluation point.
#[derive(Debug, Clone, Copy)]
pub struct EvalPoint {
    pub round: usize,
    pub loss: f32,
    pub accuracy: f32,
}

/// Full run report.
#[derive(Debug, Clone, Default)]
pub struct FlReport {
    pub model: String,
    pub clients: usize,
    pub mask_ratio: f64,
    pub encrypted_params: usize,
    pub total_params: usize,
    /// Interval-run count of the agreed mask (its O(·) wire/memory factor).
    pub mask_runs: usize,
    /// Serialized size of the Algorithm-1 round-1 mask-distribution message
    /// (run-delta format).
    pub mask_bytes: u64,
    /// Client→server bytes of the mask-agreement stage (encrypted
    /// sensitivity maps; O(layers) ciphertexts under layer granularity).
    pub mask_upload_bytes: u64,
    /// Measured server→client bytes of the mask broadcast under tcp (0
    /// under sim — the simulated clock folds it into `mask_comm_secs`).
    pub mask_downlink_bytes: u64,
    /// Comm time of the mask-agreement stage (sensitivity-map uplinks +
    /// mask broadcast), included in `mask_agreement_secs`. Simulated or
    /// measured per `timing_source`.
    pub mask_comm_secs: f64,
    pub keygen_secs: f64,
    pub mask_agreement_secs: f64,
    /// Final-downlink cost (the FIN broadcast carrying the last aggregate).
    pub fin_downlink_bytes: u64,
    pub fin_downlink_secs: f64,
    /// [`TIMING_SIMULATED`] or [`TIMING_MEASURED`] — which convention every
    /// comm/time figure in this report uses.
    pub timing_source: &'static str,
    pub rounds: Vec<RoundMetrics>,
    pub evals: Vec<EvalPoint>,
}

impl FlReport {
    pub fn total_secs(&self) -> f64 {
        self.keygen_secs
            + self.mask_agreement_secs
            + self.fin_downlink_secs
            + self
                .rounds
                .iter()
                .map(|r| {
                    r.train_secs + r.encrypt_secs + r.aggregate_secs + r.decrypt_secs + r.comm_secs
                })
                .sum::<f64>()
    }

    pub fn total_upload_bytes(&self) -> u64 {
        self.rounds.iter().map(|r| r.upload_bytes).sum()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", self.model.clone().into()),
            ("clients", self.clients.into()),
            ("mask_ratio", self.mask_ratio.into()),
            ("encrypted_params", self.encrypted_params.into()),
            ("total_params", self.total_params.into()),
            ("mask_runs", self.mask_runs.into()),
            ("mask_bytes", self.mask_bytes.into()),
            ("mask_upload_bytes", self.mask_upload_bytes.into()),
            ("mask_downlink_bytes", self.mask_downlink_bytes.into()),
            ("mask_comm_secs", self.mask_comm_secs.into()),
            ("keygen_secs", self.keygen_secs.into()),
            ("mask_agreement_secs", self.mask_agreement_secs.into()),
            ("fin_downlink_bytes", self.fin_downlink_bytes.into()),
            ("fin_downlink_secs", self.fin_downlink_secs.into()),
            ("timing_source", self.timing_source.to_string().into()),
            (
                "rounds",
                Json::Arr(
                    self.rounds
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("round", r.round.into()),
                                ("participants", r.participants.into()),
                                ("stragglers_dropped", r.stragglers_dropped.into()),
                                ("train_secs", r.train_secs.into()),
                                ("encrypt_secs", r.encrypt_secs.into()),
                                ("aggregate_secs", r.aggregate_secs.into()),
                                ("decrypt_secs", r.decrypt_secs.into()),
                                ("comm_secs", r.comm_secs.into()),
                                ("downlink_secs", r.downlink_secs.into()),
                                ("upload_bytes", r.upload_bytes.into()),
                                ("download_bytes", r.download_bytes.into()),
                                ("train_loss", (r.train_loss as f64).into()),
                                (
                                    "timing_source",
                                    r.timing_source.to_string().into(),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "evals",
                Json::Arr(
                    self.evals
                        .iter()
                        .map(|e| {
                            Json::obj(vec![
                                ("round", e.round.into()),
                                ("loss", (e.loss as f64).into()),
                                ("accuracy", (e.accuracy as f64).into()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Options for [`FlServer::serve`] (the multi-process deployment entry).
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Where to write the out-of-band task key (spec + pk + sk) **before**
    /// listening — the side channel `join` processes read.
    pub task_key: std::path::PathBuf,
    /// Optional file to write the bound listen address to (lets `join`
    /// processes discover an ephemeral `--listen 127.0.0.1:0` port).
    pub addr_file: Option<std::path::PathBuf>,
}

/// The FL server/orchestrator.
pub struct FlServer<'a> {
    /// PJRT runtime for artifact models (`None` for the standalone
    /// synthetic model).
    pub rt: Option<&'a Runtime>,
    pub cfg: FlConfig,
    pub codec: SelectiveCodec,
}

impl<'a> FlServer<'a> {
    /// Build a server over the AOT runtime (any model, including
    /// `synthetic`, which ignores the runtime).
    pub fn new(rt: &'a Runtime, cfg: FlConfig) -> anyhow::Result<Self> {
        Self::with_runtime(Some(rt), cfg)
    }

    /// Build a runtime-free server — only the `synthetic` model qualifies
    /// (everything else needs the AOT artifacts).
    pub fn standalone(cfg: FlConfig) -> anyhow::Result<FlServer<'static>> {
        FlServer::<'static>::with_runtime(None, cfg)
    }

    fn with_runtime(rt: Option<&'a Runtime>, mut cfg: FlConfig) -> anyhow::Result<Self> {
        anyhow::ensure!(
            cfg.ct_wire == CtWire::Dense || cfg.key_mode == KeyMode::SingleKey,
            "--ct-wire seed requires --keys single: seeded ciphertexts are \
             symmetric (secret-key) encryptions, which the threshold share \
             holders cannot produce individually"
        );
        let ctx = if cfg.model == SYNTHETIC_MODEL {
            // artifact-free: force the native backend (the XLA aggregation
            // path needs a runtime and buys nothing at synthetic scale)
            cfg.backend = Backend::Native;
            let (n, limbs, bits) = cfg.crypto_override.unwrap_or(SYNTHETIC_CRYPTO);
            CkksContext::new(n, limbs, bits)?
        } else {
            let rt = rt.ok_or_else(|| {
                anyhow::anyhow!(
                    "model '{}' needs the AOT artifacts; only '{SYNTHETIC_MODEL}' runs \
                     standalone",
                    cfg.model
                )
            })?;
            match cfg.crypto_override {
                Some((n, limbs, bits)) => {
                    anyhow::ensure!(
                        cfg.backend == Backend::Native,
                        "crypto overrides require the native backend (XLA artifacts \
                         are compiled for the default context)"
                    );
                    CkksContext::new(n, limbs, bits)?
                }
                None => {
                    let c = &rt.manifest.crypto;
                    let ctx = CkksContext::new(c.n, c.num_limbs, c.scaling_bits)?;
                    rt.manifest.validate_crypto(&ctx.params)?;
                    ctx
                }
            }
        };
        Ok(FlServer {
            rt,
            cfg,
            codec: SelectiveCodec::new(ctx),
        })
    }

    pub(crate) fn aggregate(
        &self,
        updates: &[EncryptedUpdate],
        alphas: &[f64],
    ) -> anyhow::Result<EncryptedUpdate> {
        match self.cfg.backend {
            Backend::Xla => {
                let rt = self
                    .rt
                    .ok_or_else(|| anyhow::anyhow!("the XLA backend needs a runtime"))?;
                let agg = XlaAggregator::new(rt, self.codec.ctx.params.clone())?;
                agg.aggregate(updates, alphas)
            }
            Backend::Native => Ok(native::aggregate(updates, alphas, &self.codec.ctx.params)),
        }
    }

    /// Decrypt an aggregated update into a flat global model (done by a
    /// client / the key holder in the real deployment; the server never has
    /// the key — this method takes the key material explicitly).
    pub(crate) fn decrypt_global(
        &self,
        update: &EncryptedUpdate,
        mask: &EncryptionMask,
        keys: &KeyMaterial,
        rng: &mut ChaChaRng,
    ) -> Vec<f32> {
        match keys {
            KeyMaterial::SingleKey { sk, .. } => self.codec.decrypt_update(update, mask, sk),
            KeyMaterial::Threshold { parties, .. } => {
                let refs: Vec<&crate::ckks::threshold::ThresholdParty> = parties.iter().collect();
                self.codec.decrypt_update_threshold(update, mask, &refs, rng)
            }
        }
    }

    pub(crate) fn decrypt_vec(
        &self,
        cts: &[crate::ckks::Ciphertext],
        keys: &KeyMaterial,
        total: usize,
        rng: &mut ChaChaRng,
    ) -> Vec<f32> {
        match keys {
            KeyMaterial::SingleKey { sk, .. } => {
                selective::decrypt_vector(&self.codec.ctx, cts, sk, total)
            }
            KeyMaterial::Threshold { parties, .. } => {
                let mut out = Vec::with_capacity(total);
                for ct in cts {
                    let partials: Vec<_> = parties
                        .iter()
                        .map(|p| {
                            crate::ckks::threshold::partial_decrypt(
                                &self.codec.ctx.params,
                                p,
                                ct,
                                rng,
                            )
                        })
                        .collect();
                    let m = crate::ckks::threshold::combine_partials(
                        &self.codec.ctx.params,
                        ct,
                        &partials,
                    );
                    out.extend(
                        self.codec
                            .ctx
                            .encoder
                            .decode(&m, ct.n_values, ct.scale)
                            .into_iter()
                            .map(|v| v as f32),
                    );
                }
                out.truncate(total);
                out
            }
        }
    }

    /// The initial global model (artifact init file, or the synthetic
    /// model's seeded init — the same one every `join` process derives).
    pub(crate) fn init_global(&self) -> anyhow::Result<Vec<f32>> {
        if self.cfg.model == SYNTHETIC_MODEL {
            Ok(SyntheticModel::new(self.cfg.synthetic_dim.max(1), self.cfg.seed).init_params())
        } else {
            let rt = self.rt.expect("artifact model has a runtime (checked at construction)");
            rt.manifest.load_init_params(&self.cfg.model)
        }
    }

    /// Build client `id`'s compute core (artifact trainer or synthetic).
    pub(crate) fn make_core(&self, id: usize) -> anyhow::Result<ClientCore<'a>> {
        let cfg = &self.cfg;
        if cfg.model == SYNTHETIC_MODEL {
            let m = SyntheticModel::new(cfg.synthetic_dim.max(1), cfg.seed);
            Ok(ClientCore::Synthetic(SyntheticClient::new(
                m,
                id as u64,
                cfg.clients,
            )))
        } else {
            let rt = self.rt.expect("artifact model has a runtime (checked at construction)");
            Ok(ClientCore::Artifact(FlClient::new(
                rt,
                &cfg.model,
                id,
                cfg.clients,
                cfg.samples_per_client,
                cfg.skew,
                cfg.seed,
            )?))
        }
    }

    fn session_opts(&self) -> SessionOpts {
        SessionOpts {
            round_wait: Duration::from_secs_f64(self.cfg.round_wait.max(1.0)),
            connect_retry: Duration::from_secs_f64(self.cfg.join_wait.max(1.0)),
            connect_retries: self.cfg.connect_retries,
            retry_base: Duration::from_millis(self.cfg.retry_base_ms.max(1)),
            ct_wire: self.cfg.ct_wire,
            ..SessionOpts::default()
        }
    }

    /// The task's MAC root under `--wire-auth mac`: fresh OS entropy per
    /// run — never derived from `cfg.seed`, which is public and pins the
    /// (deterministic) model trajectory, not secrets.
    fn draw_mac_root(&self) -> anyhow::Result<Option<[u8; 32]>> {
        if self.cfg.wire_auth != WireAuth::Mac {
            return Ok(None);
        }
        let mut root = [0u8; 32];
        ChaChaRng::from_os_entropy()
            .map_err(|e| anyhow::anyhow!("cannot draw the task mac root: {e}"))?
            .fill_bytes(&mut root);
        Ok(Some(root))
    }

    /// Run the full federated task. Returns the report and the final
    /// model. Pure phase dispatch: the transport decides who the
    /// participants are, the phases are the same either way.
    pub fn run(&self) -> anyhow::Result<(FlReport, Vec<f32>)> {
        match self.cfg.transport {
            Transport::Sim => self.run_sim(),
            Transport::Tcp => self.run_tcp(),
        }
    }

    fn run_sim(&self) -> anyhow::Result<(FlReport, Vec<f32>)> {
        let mut st = phases::init_state(self)?;
        let mut participants: Vec<Box<dyn Participant + 'a>> =
            Vec::with_capacity(self.cfg.clients);
        for id in 0..self.cfg.clients {
            participants.push(Box::new(SimParticipant::new(self.make_core(id)?)));
        }
        phases::drive(self, &mut st, &mut participants, &Uplink::Sim)?;
        Ok((st.report, st.global))
    }

    /// Single-process tcp: the coordinator spawns one client-session
    /// thread per client running the exact `join` loop over loopback, so
    /// every downlink/uplink is real frames through the persistent hub.
    fn run_tcp(&self) -> anyhow::Result<(FlReport, Vec<f32>)> {
        let cfg = &self.cfg;
        anyhow::ensure!(
            cfg.key_mode == KeyMode::SingleKey,
            "--transport tcp requires --keys single: session clients decrypt \
             the broadcast aggregate locally with the distributed secret key"
        );
        let mut st = phases::init_state(self)?;
        let KeyMaterial::SingleKey { pk, sk } = &st.keys else {
            anyhow::bail!("tcp transport requires single-key material");
        };
        let pk = pk.clone();
        let sk = sk.clone();
        let mac_root = self.draw_mac_root()?;
        let mut hub = bind_transport_hub(
            cfg.transport_backend,
            &cfg.listen,
            self.codec.ctx.params.clone(),
            cfg.clients * 2 + 8,
            mac_root,
            cfg.ct_wire,
        )?;
        crate::log_debug!("server", "transport backend: {}", hub.backend_name());
        let addr = match &cfg.connect {
            Some(a) => a.clone(),
            None => hub.local_addr()?.to_string(),
        };
        let init_global = st.global.clone();
        // build cores up-front so artifact errors surface before threads
        let mut cores = Vec::with_capacity(cfg.clients);
        for id in 0..cfg.clients {
            cores.push(self.make_core(id)?);
        }
        let drive_result = std::thread::scope(|s| {
            let mut handles = Vec::with_capacity(cfg.clients);
            for (id, core) in cores.into_iter().enumerate() {
                let mut opts = self.session_opts();
                if let Some(root) = &mac_root {
                    opts.auth = Some(crate::crypto::mac::derive_client_key(root, id as u64));
                }
                let lcfg = phases::ClientLoopCfg {
                    addr: addr.clone(),
                    client: id as u64,
                    model: cfg.model.clone(),
                    clients: cfg.clients,
                    selection: cfg.selection,
                    mask_granularity: cfg.mask_granularity,
                    local_steps: cfg.local_steps,
                    lr: cfg.lr,
                    dp_scale: cfg.dp_scale,
                    opts,
                };
                let codec = &self.codec;
                let pk = pk.clone();
                let sk = sk.clone();
                let ig = init_global.clone();
                handles.push(s.spawn(move || {
                    let mut core = core;
                    phases::client_session_loop(&lcfg, codec, &pk, &sk, ig, &mut core)
                }));
            }
            let r = (|| -> anyhow::Result<()> {
                let ids = hub.wait_for_clients(
                    cfg.clients,
                    Duration::from_secs_f64(cfg.join_wait.max(1.0)),
                )?;
                anyhow::ensure!(
                    ids == (0..cfg.clients as u64).collect::<Vec<u64>>(),
                    "session client ids must be exactly 0..{} (got {ids:?})",
                    cfg.clients
                );
                let mut participants: Vec<Box<dyn Participant + '_>> = ids
                    .iter()
                    .map(|&id| {
                        Box::new(RemoteParticipant::new(&hub, id, 1.0 / cfg.clients as f64))
                            as Box<dyn Participant + '_>
                    })
                    .collect();
                phases::drive(self, &mut st, &mut participants, &Uplink::Hub(&hub))
            })();
            // closing the hub unblocks any client thread still in a read,
            // success or failure — the scope must always join
            hub.shutdown();
            for h in handles {
                match h.join() {
                    Ok(Ok(_final_model)) => {}
                    Ok(Err(e)) => {
                        crate::log_debug!("server", "client session thread exited: {e}")
                    }
                    Err(_) => crate::log_debug!("server", "client session thread panicked"),
                }
            }
            r
        });
        drive_result?;
        Ok((st.report, st.global))
    }

    /// Multi-process deployment entry: write the out-of-band task key,
    /// listen, wait for `clients` independent `join` processes, and drive
    /// the same phase machine over their persistent sessions. The final
    /// model is bitwise-identical to a same-seed `--transport sim` run.
    pub fn serve(&self, opts: &ServeOptions) -> anyhow::Result<(FlReport, Vec<f32>)> {
        let cfg = &self.cfg;
        anyhow::ensure!(
            cfg.transport == Transport::Tcp,
            "serve is a tcp-transport mode"
        );
        anyhow::ensure!(
            cfg.key_mode == KeyMode::SingleKey,
            "serve distributes a single key pair out-of-band (--keys single)"
        );
        anyhow::ensure!(
            cfg.population.is_none(),
            "--population requires --transport sim"
        );
        let mut st = phases::init_state(self)?;
        let KeyMaterial::SingleKey { pk, sk } = &st.keys else {
            anyhow::bail!("serve requires single-key material");
        };
        // the mac root rides the task key (the same trusted side channel
        // as the secret key), so join processes derive their per-client
        // keys without any on-wire key exchange
        let mac_root = self.draw_mac_root()?;
        let task_key = TaskKey {
            spec: TaskSpec::from_config(cfg, &self.codec.ctx.params),
            pk: pk.clone(),
            sk: sk.clone(),
            mac_root: mac_root.unwrap_or([0u8; 32]),
        };
        // key file first, then listen: a join process that sees the file
        // can immediately dial (with connect retry) without racing the bind
        task_key.save(&opts.task_key)?;
        let mut hub = bind_transport_hub(
            cfg.transport_backend,
            &cfg.listen,
            self.codec.ctx.params.clone(),
            cfg.clients * 2 + 8,
            mac_root,
            cfg.ct_wire,
        )?;
        let addr = hub.local_addr()?;
        if let Some(p) = &opts.addr_file {
            // atomic: a join process polling for the file must never read
            // a created-but-empty address
            crate::util::write_file_atomic(p, addr.to_string().as_bytes())
                .map_err(|e| anyhow::anyhow!("cannot write addr file {}: {e}", p.display()))?;
        }
        eprintln!(
            "serve: listening on {addr} for {} join processes (task key: {})",
            cfg.clients,
            opts.task_key.display()
        );
        let r = (|| -> anyhow::Result<()> {
            let ids = hub
                .wait_for_clients(cfg.clients, Duration::from_secs_f64(cfg.join_wait.max(1.0)))?;
            anyhow::ensure!(
                ids == (0..cfg.clients as u64).collect::<Vec<u64>>(),
                "join processes must use --client-id 0..{} (got {ids:?})",
                cfg.clients
            );
            let mut participants: Vec<Box<dyn Participant + '_>> = ids
                .iter()
                .map(|&id| {
                    Box::new(RemoteParticipant::new(&hub, id, 1.0 / cfg.clients as f64))
                        as Box<dyn Participant + '_>
                })
                .collect();
            phases::drive(self, &mut st, &mut participants, &Uplink::Hub(&hub))
        })();
        hub.shutdown();
        r?;
        Ok((st.report, st.global))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::{KeyMode, MaskGranularity, Selection};
    use std::path::PathBuf;

    fn runtime() -> Option<Runtime> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return None;
        }
        Some(Runtime::new(dir).unwrap())
    }

    fn quick_cfg() -> FlConfig {
        FlConfig {
            model: "mlp".into(),
            clients: 3,
            rounds: 3,
            local_steps: 2,
            lr: 0.1,
            ratio: 0.1,
            samples_per_client: 64,
            eval_every: 3,
            ..Default::default()
        }
    }

    #[test]
    fn full_pipeline_selective_xla() {
        let Some(rt) = runtime() else { return };
        let server = FlServer::new(&rt, quick_cfg()).unwrap();
        let (report, global) = server.run().unwrap();
        assert_eq!(report.rounds.len(), 3);
        assert_eq!(global.len(), 79510);
        assert!((report.mask_ratio - 0.1).abs() < 0.01);
        assert!(!report.evals.is_empty());
        assert_eq!(report.timing_source, TIMING_SIMULATED);
        // losses should trend down across rounds
        let first = report.rounds.first().unwrap().train_loss;
        let last = report.rounds.last().unwrap().train_loss;
        assert!(last < first, "loss {first} -> {last}");
        // selective encryption cuts upload bytes well below full encryption
        let plain_bytes = 4 * 79510u64 * 3;
        assert!(report.rounds[0].upload_bytes < 4 * plain_bytes);
    }

    #[test]
    fn plaintext_and_full_encryption_agree() {
        let Some(rt) = runtime() else { return };
        // same seed, plaintext vs fully-encrypted: final models must agree
        // to CKKS precision (the "exact aggregation" claim of Table 1).
        let mut cfg_a = quick_cfg();
        cfg_a.selection = Selection::None;
        cfg_a.dropout = 0.0;
        let mut cfg_b = quick_cfg();
        cfg_b.selection = Selection::Full;
        cfg_b.dropout = 0.0;
        let (_, ga) = FlServer::new(&rt, cfg_a).unwrap().run().unwrap();
        let (_, gb) = FlServer::new(&rt, cfg_b).unwrap().run().unwrap();
        assert_eq!(ga.len(), gb.len());
        let max_err = ga
            .iter()
            .zip(gb.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 1e-3, "max err {max_err}");
    }

    #[test]
    fn threshold_mode_runs() {
        let Some(rt) = runtime() else { return };
        let mut cfg = quick_cfg();
        cfg.key_mode = KeyMode::Threshold;
        // threshold share holders can't produce symmetric seeded
        // ciphertexts, so this test pins the dense wire (robust against the
        // CI-wide FEDML_HE_CT_WIRE=seed rerun)
        cfg.ct_wire = crate::ckks::CtWire::Dense;
        cfg.rounds = 2;
        cfg.backend = Backend::Native;
        let (report, _) = FlServer::new(&rt, cfg).unwrap().run().unwrap();
        assert_eq!(report.rounds.len(), 2);
    }

    #[test]
    fn pipeline_engine_matches_sequential_exactly() {
        let Some(rt) = runtime() else { return };
        // Identical seeds, no dropout/stragglers: the pipeline engine must
        // produce the same global model as the sequential loop (the
        // ciphertext limbs are bitwise identical pre-decryption, so the
        // decrypted models match bit-for-bit).
        let mut seq = quick_cfg();
        seq.backend = Backend::Native;
        seq.dropout = 0.0;
        let mut pipe = seq.clone();
        pipe.engine = crate::agg_engine::Engine::Pipeline;
        pipe.shards = 4;
        let (_, ga) = FlServer::new(&rt, seq).unwrap().run().unwrap();
        let (_, gb) = FlServer::new(&rt, pipe).unwrap().run().unwrap();
        // the aggregation itself is bitwise identical (gated by
        // tests/agg_engine_equiv.rs); across two full runs we only allow
        // for benign nondeterminism in the XLA training path
        let max_err = ga
            .iter()
            .zip(gb.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 1e-6, "pipeline diverged from sequential: {max_err}");
    }

    #[test]
    fn tcp_transport_round_matches_sim_transport() {
        let Some(rt) = runtime() else { return };
        // Same seeds: delivering the whole task over persistent loopback
        // sessions (client threads running the join loop, real downlink
        // frames, client-side decryption) must not change the trained
        // model. Tolerance only covers benign XLA training nondeterminism
        // between the two runs — aggregation and decryption are
        // bitwise-stable.
        let mut sim = quick_cfg();
        sim.backend = Backend::Native;
        sim.dropout = 0.0;
        sim.rounds = 2;
        let mut tcp = sim.clone();
        tcp.transport = Transport::Tcp;
        tcp.engine = crate::agg_engine::Engine::Pipeline;
        tcp.shards = 2;
        let (ra, ga) = FlServer::new(&rt, sim).unwrap().run().unwrap();
        let (rb, gb) = FlServer::new(&rt, tcp).unwrap().run().unwrap();
        assert_eq!(rb.rounds.len(), 2);
        assert!(rb.rounds.iter().all(|r| r.stragglers_dropped == 0));
        assert!(rb.rounds.iter().all(|r| r.upload_bytes > 0));
        // downlink is measured under tcp, simulated under sim
        assert_eq!(ra.timing_source, TIMING_SIMULATED);
        assert_eq!(rb.timing_source, TIMING_MEASURED);
        assert!(rb.rounds[1].download_bytes > 0);
        let max_err = ga
            .iter()
            .zip(gb.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 1e-6, "tcp transport diverged from sim: {max_err}");
    }

    #[test]
    fn layer_granularity_mode_runs() {
        let Some(rt) = runtime() else { return };
        let mut cfg = quick_cfg();
        cfg.backend = Backend::Native;
        cfg.mask_granularity = MaskGranularity::Layer;
        cfg.rounds = 2;
        let (report, global) = FlServer::new(&rt, cfg).unwrap().run().unwrap();
        assert_eq!(report.rounds.len(), 2);
        assert!(global.iter().all(|v| v.is_finite()));
        // whole-layer mask: O(layers) runs and a tiny distribution message
        let layers = crate::fl::model_meta::lookup("mlp").unwrap().layers as usize;
        assert!(report.mask_runs <= layers, "runs {}", report.mask_runs);
        assert!(report.mask_bytes < 1024, "mask bytes {}", report.mask_bytes);
        // whole layers are selected until the ratio target is covered
        assert!(report.mask_ratio >= 0.1);
        // the layer-granularity agreement message is O(layers) ciphertexts,
        // far below the O(params) per-parameter map
        assert!(report.mask_upload_bytes > 0);
    }

    #[test]
    fn population_cohort_round_runs() {
        let Some(rt) = runtime() else { return };
        let mut cfg = quick_cfg();
        cfg.backend = Backend::Native;
        cfg.engine = crate::agg_engine::Engine::Pipeline;
        cfg.population = Some(1_000_000);
        cfg.rounds = 2;
        let (report, global) = FlServer::new(&rt, cfg).unwrap().run().unwrap();
        assert_eq!(report.rounds.len(), 2);
        assert!(global.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn dropout_reduces_participants() {
        let Some(rt) = runtime() else { return };
        let mut cfg = quick_cfg();
        cfg.clients = 6;
        cfg.dropout = 0.5;
        cfg.rounds = 4;
        cfg.selection = Selection::Random;
        let (report, _) = FlServer::new(&rt, cfg).unwrap().run().unwrap();
        assert!(report.rounds.iter().any(|r| r.participants < 6));
        // run completes despite dropout — the HE robustness claim of Table 1
        assert_eq!(report.rounds.len(), 4);
    }
}
