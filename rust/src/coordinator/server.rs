//! FL server: orchestrates the three stages of Fig. 3 —
//! key agreement → encryption-mask calculation → encrypted federated
//! learning — and records per-stage overhead metrics (the data source for
//! Figs. 8/14 and the deployment-platform monitoring of Appendix C).

use super::client::FlClient;
use super::config::{Backend, FlConfig, MaskGranularity, Selection, Transport};
use super::key_authority::{self, KeyMaterial};
use crate::agg_engine::{Arrival, CohortScheduler, Engine, Population, StreamingAggregator};
use crate::ckks::CkksContext;
use crate::crypto::prng::ChaChaRng;
use crate::he_agg::xla::XlaAggregator;
use crate::he_agg::{native, selective, EncryptedUpdate, EncryptionMask, SelectiveCodec};
use crate::netsim::{concurrent_arrivals, SimClock};
use crate::runtime::Runtime;
use crate::transport::{
    IntakeConfig, TcpIntake, UpdateShape, UploadConfig, UNIDENTIFIED_CLIENT,
};
use crate::util::json::Json;
use std::sync::Arc;
use std::time::Instant;

/// Per-round overhead breakdown (the paper's "training cycle" dissection).
/// `comm_secs` uses parallel-uplink accounting (round comm = max over the
/// concurrent uploads + broadcast time), not the serial sum. Under
/// `--transport tcp` the uplink part is the measured wall-clock intake time
/// instead of a simulated transfer time; the downlink broadcast stays
/// simulated (DESIGN.md §8).
#[derive(Debug, Clone, Default)]
pub struct RoundMetrics {
    pub round: usize,
    pub participants: usize,
    /// Late uploads dropped by the pipeline engine's quorum policy.
    pub stragglers_dropped: usize,
    pub train_secs: f64,
    pub encrypt_secs: f64,
    pub aggregate_secs: f64,
    pub decrypt_secs: f64,
    /// Simulated network time at the configured bandwidth.
    pub comm_secs: f64,
    pub upload_bytes: u64,
    pub download_bytes: u64,
    pub train_loss: f32,
}

/// An evaluation point.
#[derive(Debug, Clone, Copy)]
pub struct EvalPoint {
    pub round: usize,
    pub loss: f32,
    pub accuracy: f32,
}

/// Full run report.
#[derive(Debug, Clone, Default)]
pub struct FlReport {
    pub model: String,
    pub clients: usize,
    pub mask_ratio: f64,
    pub encrypted_params: usize,
    pub total_params: usize,
    /// Interval-run count of the agreed mask (its O(·) wire/memory factor).
    pub mask_runs: usize,
    /// Serialized size of the Algorithm-1 round-1 mask-distribution message
    /// (run-delta format).
    pub mask_bytes: u64,
    /// Client→server bytes of the mask-agreement stage (encrypted
    /// sensitivity maps; O(layers) ciphertexts under layer granularity).
    pub mask_upload_bytes: u64,
    /// Simulated comm time of the mask-agreement stage (sensitivity-map
    /// uplinks + mask broadcast), included in `mask_agreement_secs`.
    pub mask_comm_secs: f64,
    pub keygen_secs: f64,
    pub mask_agreement_secs: f64,
    pub rounds: Vec<RoundMetrics>,
    pub evals: Vec<EvalPoint>,
}

impl FlReport {
    pub fn total_secs(&self) -> f64 {
        self.keygen_secs
            + self.mask_agreement_secs
            + self
                .rounds
                .iter()
                .map(|r| {
                    r.train_secs + r.encrypt_secs + r.aggregate_secs + r.decrypt_secs + r.comm_secs
                })
                .sum::<f64>()
    }

    pub fn total_upload_bytes(&self) -> u64 {
        self.rounds.iter().map(|r| r.upload_bytes).sum()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", self.model.clone().into()),
            ("clients", self.clients.into()),
            ("mask_ratio", self.mask_ratio.into()),
            ("encrypted_params", self.encrypted_params.into()),
            ("total_params", self.total_params.into()),
            ("mask_runs", self.mask_runs.into()),
            ("mask_bytes", self.mask_bytes.into()),
            ("mask_upload_bytes", self.mask_upload_bytes.into()),
            ("mask_comm_secs", self.mask_comm_secs.into()),
            ("keygen_secs", self.keygen_secs.into()),
            ("mask_agreement_secs", self.mask_agreement_secs.into()),
            (
                "rounds",
                Json::Arr(
                    self.rounds
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("round", r.round.into()),
                                ("participants", r.participants.into()),
                                ("stragglers_dropped", r.stragglers_dropped.into()),
                                ("train_secs", r.train_secs.into()),
                                ("encrypt_secs", r.encrypt_secs.into()),
                                ("aggregate_secs", r.aggregate_secs.into()),
                                ("decrypt_secs", r.decrypt_secs.into()),
                                ("comm_secs", r.comm_secs.into()),
                                ("upload_bytes", r.upload_bytes.into()),
                                ("download_bytes", r.download_bytes.into()),
                                ("train_loss", (r.train_loss as f64).into()),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "evals",
                Json::Arr(
                    self.evals
                        .iter()
                        .map(|e| {
                            Json::obj(vec![
                                ("round", e.round.into()),
                                ("loss", (e.loss as f64).into()),
                                ("accuracy", (e.accuracy as f64).into()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// The FL server/orchestrator.
pub struct FlServer<'a> {
    pub rt: &'a Runtime,
    pub cfg: FlConfig,
    pub codec: SelectiveCodec,
}

impl<'a> FlServer<'a> {
    pub fn new(rt: &'a Runtime, cfg: FlConfig) -> anyhow::Result<Self> {
        let ctx = match cfg.crypto_override {
            Some((n, limbs, bits)) => {
                anyhow::ensure!(
                    cfg.backend == Backend::Native,
                    "crypto overrides require the native backend (XLA artifacts \
                     are compiled for the default context)"
                );
                CkksContext::new(n, limbs, bits)?
            }
            None => {
                let c = &rt.manifest.crypto;
                let ctx = CkksContext::new(c.n, c.num_limbs, c.scaling_bits)?;
                rt.manifest.validate_crypto(&ctx.params)?;
                ctx
            }
        };
        Ok(FlServer {
            rt,
            cfg,
            codec: SelectiveCodec::new(ctx),
        })
    }

    fn aggregate(
        &self,
        updates: &[EncryptedUpdate],
        alphas: &[f64],
    ) -> anyhow::Result<EncryptedUpdate> {
        match self.cfg.backend {
            Backend::Xla => {
                let agg = XlaAggregator::new(self.rt, self.codec.ctx.params.clone())?;
                agg.aggregate(updates, alphas)
            }
            Backend::Native => Ok(native::aggregate(updates, alphas, &self.codec.ctx.params)),
        }
    }

    /// Decrypt an aggregated update into a flat global model (done by a
    /// client / the key holder in the real deployment; the server never has
    /// the key — this method takes the key material explicitly).
    fn decrypt_global(
        &self,
        update: &EncryptedUpdate,
        mask: &EncryptionMask,
        keys: &KeyMaterial,
        rng: &mut ChaChaRng,
    ) -> Vec<f32> {
        match keys {
            KeyMaterial::SingleKey { sk, .. } => self.codec.decrypt_update(update, mask, sk),
            KeyMaterial::Threshold { parties, .. } => {
                let refs: Vec<&crate::ckks::threshold::ThresholdParty> = parties.iter().collect();
                self.codec.decrypt_update_threshold(update, mask, &refs, rng)
            }
        }
    }

    fn decrypt_vec(
        &self,
        cts: &[crate::ckks::Ciphertext],
        keys: &KeyMaterial,
        total: usize,
        rng: &mut ChaChaRng,
    ) -> Vec<f32> {
        match keys {
            KeyMaterial::SingleKey { sk, .. } => {
                selective::decrypt_vector(&self.codec.ctx, cts, sk, total)
            }
            KeyMaterial::Threshold { parties, .. } => {
                let mut out = Vec::with_capacity(total);
                for ct in cts {
                    let partials: Vec<_> = parties
                        .iter()
                        .map(|p| {
                            crate::ckks::threshold::partial_decrypt(
                                &self.codec.ctx.params,
                                p,
                                ct,
                                rng,
                            )
                        })
                        .collect();
                    let m = crate::ckks::threshold::combine_partials(
                        &self.codec.ctx.params,
                        ct,
                        &partials,
                    );
                    out.extend(
                        self.codec
                            .ctx
                            .encoder
                            .decode(&m, ct.n_values, ct.scale)
                            .into_iter()
                            .map(|v| v as f32),
                    );
                }
                out.truncate(total);
                out
            }
        }
    }

    /// Run the full federated task. Returns the report and the final model.
    pub fn run(&self) -> anyhow::Result<(FlReport, Vec<f32>)> {
        let cfg = &self.cfg;
        let mut report = FlReport {
            model: cfg.model.clone(),
            clients: cfg.clients,
            ..Default::default()
        };
        let mut server_rng = ChaChaRng::from_seed(cfg.seed, 0x5E17);

        // ------------------------------------------------------------------
        // Stage 1 — Encryption key agreement (Fig. 3).
        let t = Instant::now();
        let keys = key_authority::setup(
            &self.codec.ctx,
            cfg.key_mode,
            cfg.clients,
            &mut server_rng,
        );
        report.keygen_secs = t.elapsed().as_secs_f64();
        let pk = keys.public_key().clone();

        // Build clients with their local datasets.
        let mut clients: Vec<FlClient<'_>> = (0..cfg.clients)
            .map(|id| {
                FlClient::new(
                    self.rt,
                    &cfg.model,
                    id,
                    cfg.clients,
                    cfg.samples_per_client,
                    cfg.skew,
                    cfg.seed,
                )
            })
            .collect::<anyhow::Result<_>>()?;
        let mut global = self.rt.manifest.load_init_params(&cfg.model)?;
        let total_params = global.len();
        report.total_params = total_params;

        // ------------------------------------------------------------------
        // Stage 2 — Encryption mask calculation (§2.4): clients compute local
        // sensitivity maps (per parameter, or pre-aggregated per layer under
        // `--mask-granularity layer`), encrypt them, the server aggregates
        // them homomorphically, the key holder decrypts the *aggregate* only,
        // and the agreed mask becomes shared configuration. The stage's wire
        // traffic — encrypted map uplinks plus the run-delta mask broadcast
        // of Algorithm 1 round 1 — is charged to `mask_agreement_secs`.
        let t = Instant::now();
        let mut mask_clock = SimClock::parallel();
        let mask = match cfg.selection {
            Selection::Full => EncryptionMask::full(total_params),
            Selection::None => EncryptionMask::empty(total_params),
            Selection::Random => {
                EncryptionMask::random(total_params, cfg.ratio, &mut server_rng)
            }
            Selection::TopP => {
                let alphas: Vec<f64> = clients.iter().map(|c| c.alpha).collect();
                let spans = crate::fl::model_meta::layer_spans_for(&cfg.model, total_params);
                let map_len = match cfg.mask_granularity {
                    MaskGranularity::Param => total_params,
                    MaskGranularity::Layer => spans.len(),
                };
                let mut enc_maps: Vec<EncryptedUpdate> = Vec::with_capacity(cfg.clients);
                for c in clients.iter_mut() {
                    let s = match cfg.mask_granularity {
                        MaskGranularity::Param => c.sensitivity(&global)?,
                        MaskGranularity::Layer => c.layer_sensitivity(&global, &spans)?,
                    };
                    let cts = selective::encrypt_vector(&self.codec.ctx, &s, &pk, &mut c.rng);
                    enc_maps.push(EncryptedUpdate {
                        cts,
                        plain: Vec::new(),
                        total: map_len,
                    });
                }
                for u in &enc_maps {
                    mask_clock.upload(u.wire_bytes(&self.codec.ctx) as u64, cfg.bandwidth);
                }
                let agg_map = self.aggregate(&enc_maps, &alphas)?;
                let global_map =
                    self.decrypt_vec(&agg_map.cts, &keys, map_len, &mut server_rng);
                match cfg.mask_granularity {
                    MaskGranularity::Param => EncryptionMask::top_p(&global_map, cfg.ratio),
                    MaskGranularity::Layer => EncryptionMask::from_layer_scores(
                        total_params,
                        &global_map,
                        &spans,
                        cfg.ratio,
                    ),
                }
            }
        };
        // Algorithm 1 round 1: broadcast the agreed mask to every client.
        let mask_bytes = mask.to_bytes().len() as u64;
        mask_clock.broadcast(mask_bytes, cfg.clients, cfg.bandwidth);
        report.mask_upload_bytes = mask_clock.bytes_up;
        report.mask_bytes = mask_bytes;
        report.mask_comm_secs = mask_clock.comm_secs;
        report.mask_agreement_secs = t.elapsed().as_secs_f64() + mask_clock.comm_secs;
        report.mask_ratio = mask.ratio();
        report.encrypted_params = mask.encrypted_count();
        report.mask_runs = mask.encrypted.n_runs();

        // ------------------------------------------------------------------
        // Stage 3 — Encrypted federated learning rounds (Algorithm 1).
        // With `--population N`, each round's participants are a cohort of
        // `clients` virtual ids sampled from the registered population; the
        // instantiated trainers form a pool backing the sampled members.
        if let Some(n) = cfg.population {
            anyhow::ensure!(
                n >= cfg.clients as u64,
                "--population ({n}) must be at least --clients ({})",
                cfg.clients
            );
        }
        let scheduler = cfg
            .population
            .map(|n| CohortScheduler::new(Population::new(n, cfg.seed), cfg.clients));
        // TCP transport: bind the intake once for the whole task — rebinding
        // a fixed `--listen` port every round would hit TIME_WAIT
        // (EADDRINUSE) from the previous round's closed connections. The
        // round id in every frame keeps rounds from bleeding into each
        // other on the shared listener.
        let tcp_intake = match cfg.transport {
            Transport::Tcp => {
                let shape = UpdateShape::for_round(&self.codec.ctx, &mask);
                Some(TcpIntake::bind(
                    &cfg.listen,
                    self.codec.ctx.params.clone(),
                    shape,
                )?)
            }
            Transport::Sim => None,
        };
        let tcp_dial = match (&tcp_intake, &cfg.connect) {
            (Some(_), Some(a)) => Some(a.clone()),
            (Some(intake), None) => Some(intake.local_addr()?.to_string()),
            (None, _) => None,
        };
        // One Parallel clock spans every round; per-round metrics are deltas
        // and `finish_round` resets the per-round uplink max at each
        // boundary (a reused clock without the reset would max round-2
        // uploads against round 1's slowest transfer).
        let mut clock = SimClock::parallel();
        for round in 0..cfg.rounds {
            let mut rm = RoundMetrics {
                round,
                ..Default::default()
            };
            let comm0 = clock.comm_secs;
            let up0 = clock.bytes_up;
            let down0 = clock.bytes_down;

            let cohort = scheduler.as_ref().map(|s| s.sample(round as u64));
            if let (Some(c), Some(s)) = (&cohort, &scheduler) {
                for (slot, m) in c.members.iter().enumerate() {
                    clients[slot].bind_virtual(
                        m.id,
                        m.alpha,
                        s.population.client_seed(m.id),
                        round as u64,
                    );
                }
            }

            // dropout injection (HE is dropout-robust: we just renormalize)
            let active: Vec<usize> = (0..cfg.clients)
                .filter(|_| server_rng.uniform_f64() >= cfg.dropout)
                .collect();
            let active = if active.is_empty() { vec![0] } else { active };
            rm.participants = active.len();
            let alpha_sum: f64 = active.iter().map(|&i| clients[i].alpha).sum();

            // local training + encryption per participant
            let mut updates: Vec<EncryptedUpdate> = Vec::with_capacity(active.len());
            let mut alphas: Vec<f64> = Vec::with_capacity(active.len());
            let mut client_ids: Vec<u64> = Vec::with_capacity(active.len());
            let mut train_starts: Vec<f64> = Vec::with_capacity(active.len());
            let mut upload_bytes: Vec<u64> = Vec::with_capacity(active.len());
            let mut loss_sum = 0.0f32;
            for &i in &active {
                let c = &mut clients[i];
                let t = Instant::now();
                let (mut local, loss) = c.train(&global, cfg.local_steps, cfg.lr)?;
                let train_t = t.elapsed().as_secs_f64();
                rm.train_secs += train_t;
                loss_sum += loss;

                let t = Instant::now();
                let upd = c.encrypt(&self.codec, &mut local, &mask, &pk, cfg.dp_scale);
                rm.encrypt_secs += t.elapsed().as_secs_f64();
                // a client's upload starts when its (concurrent) local
                // training finishes — the arrival ordering of the pipeline
                train_starts.push(train_t);
                upload_bytes.push(upd.wire_bytes(&self.codec.ctx) as u64);
                client_ids.push(
                    cohort
                        .as_ref()
                        .map(|co| co.members[i].id)
                        .unwrap_or(i as u64),
                );
                alphas.push(c.alpha / alpha_sum);
                updates.push(upd);
            }

            // server-side homomorphic aggregation; uplink time is charged
            // only for uploads the round actually waited for
            let t = Instant::now();
            let mut wire_secs = 0.0f64;
            let (agg, alpha_mass) = if cfg.transport == Transport::Tcp {
                // Real loopback/LAN delivery: one uploader thread per
                // participant streams its (staged) update over a socket; the
                // intake stamps completions with wall-clock times, the
                // streaming engine applies the quorum policy to those
                // stamps, and a client failing mid-upload is folded into
                // the straggler count.
                let intake = tcp_intake.as_ref().expect("bound at task setup");
                let dial = tcp_dial.as_deref().expect("resolved at task setup");
                let icfg = IntakeConfig {
                    round_id: round as u64,
                    expected_uploads: active.len(),
                    quorum: cfg.quorum,
                    straggler_timeout: std::time::Duration::from_secs_f64(
                        cfg.straggler_timeout.max(0.0),
                    ),
                    // hard intake bound: explicit --intake-max-wait, or base
                    // slack plus the configured straggler window so a wide
                    // --straggler-timeout is never silently truncated; also
                    // what bounds a fully-failed round (e.g. a misconfigured
                    // --connect where no upload ever lands)
                    max_wait: std::time::Duration::from_secs_f64(
                        cfg.intake_max_wait
                            .unwrap_or(30.0 + cfg.straggler_timeout.max(0.0))
                            .max(1.0),
                    ),
                    ..IntakeConfig::default()
                };
                let outcome = std::thread::scope(|s| {
                    for (k, upd) in updates.drain(..).enumerate() {
                        let ucfg = UploadConfig {
                            round_id: round as u64,
                            client: client_ids[k],
                            alpha: alphas[k],
                            ..UploadConfig::default()
                        };
                        s.spawn(move || {
                            if let Err(e) = crate::transport::upload_update(dial, &ucfg, &upd)
                            {
                                crate::log_debug!(
                                    "transport",
                                    "client {} upload failed: {e}",
                                    ucfg.client
                                );
                            }
                        });
                    }
                    intake.collect_round(&icfg)
                })?;
                wire_secs = outcome.elapsed_secs;
                clock.upload_bytes_only(outcome.bytes_received);
                let engine =
                    StreamingAggregator::new(&self.codec.ctx.params, cfg.engine_config());
                let mut round_intake = engine.begin_round(Some(&mask));
                for a in outcome.arrivals {
                    round_intake.offer(a)?;
                }
                let (agg, mut stats) = round_intake.seal()?;
                // Only identified participants whose upload failed count as
                // dropped stragglers — anonymous probes and retries of an
                // already-accepted client would otherwise skew the round's
                // reported drop rate.
                let accepted_ids: std::collections::HashSet<u64> =
                    stats.accepted_clients.iter().copied().collect();
                let failed_participants = outcome
                    .failed
                    .iter()
                    .filter(|&&id| id != UNIDENTIFIED_CLIENT && !accepted_ids.contains(&id))
                    .collect::<std::collections::HashSet<_>>()
                    .len();
                stats.offered += failed_participants;
                stats.dropped_stragglers += failed_participants;
                rm.participants = stats.accepted;
                rm.stragglers_dropped = stats.dropped_stragglers;
                (agg, stats.alpha_mass)
            } else {
                match cfg.engine {
                    Engine::Sequential => {
                        for &b in &upload_bytes {
                            clock.upload(b, cfg.bandwidth);
                        }
                        (self.aggregate(&updates, &alphas)?, 1.0)
                    }
                    Engine::Pipeline => {
                        let arrival_secs =
                            concurrent_arrivals(&upload_bytes, &train_starts, cfg.bandwidth);
                        let arrivals: Vec<Arrival> = updates
                            .drain(..)
                            .zip(alphas.iter())
                            .zip(arrival_secs.iter())
                            .enumerate()
                            .map(|(k, ((upd, &alpha), &at))| Arrival {
                                client: client_ids[k],
                                alpha,
                                arrival_secs: at,
                                update: Arc::new(upd),
                            })
                            .collect();
                        let engine =
                            StreamingAggregator::new(&self.codec.ctx.params, cfg.engine_config());
                        // run-aligned plaintext shard plan from the shared mask
                        let (agg, stats) = engine.aggregate_with_mask(arrivals, Some(&mask))?;
                        let accepted: std::collections::HashSet<u64> =
                            stats.accepted_clients.iter().copied().collect();
                        for (cid, &b) in client_ids.iter().zip(upload_bytes.iter()) {
                            if accepted.contains(cid) {
                                clock.upload(b, cfg.bandwidth);
                            } else {
                                // dropped straggler: bytes were sent but the
                                // round never waited for them
                                clock.upload_bytes_only(b);
                            }
                        }
                        rm.participants = stats.accepted;
                        rm.stragglers_dropped = stats.dropped_stragglers;
                        (agg, stats.alpha_mass)
                    }
                }
            };
            rm.aggregate_secs = t.elapsed().as_secs_f64();

            // broadcast the partially-encrypted global model to every active
            // client — dropped stragglers still receive the next global —
            // over concurrent downlinks (one transfer time under parallel
            // accounting)
            let down = agg.wire_bytes(&self.codec.ctx) as u64;
            clock.broadcast(down, active.len(), cfg.bandwidth);

            // key-holder decryption + merge (renormalized by the accepted
            // FedAvg weight mass when the quorum policy dropped stragglers)
            let t = Instant::now();
            global = self.decrypt_global(&agg, &mask, &keys, &mut server_rng);
            if (alpha_mass - 1.0).abs() > 1e-12 {
                for v in global.iter_mut() {
                    *v = (*v as f64 / alpha_mass) as f32;
                }
            }
            rm.decrypt_secs = t.elapsed().as_secs_f64();

            rm.comm_secs = clock.comm_secs - comm0 + wire_secs;
            rm.upload_bytes = clock.bytes_up - up0;
            rm.download_bytes = clock.bytes_down - down0;
            rm.train_loss = loss_sum / active.len() as f32;
            crate::log_debug!(
                "server",
                "round {round}: loss {:.4} train {:.2}s enc {:.2}s agg {:.2}s",
                rm.train_loss,
                rm.train_secs,
                rm.encrypt_secs,
                rm.aggregate_secs
            );
            report.rounds.push(rm);
            clock.finish_round();

            // periodic evaluation
            if cfg.eval_every > 0 && (round + 1) % cfg.eval_every == 0 {
                let mut l = 0.0f32;
                let mut a = 0.0f32;
                for c in clients.iter_mut() {
                    let (cl, ca) = c.evaluate(&global, 1)?;
                    l += cl;
                    a += ca;
                }
                report.evals.push(EvalPoint {
                    round: round + 1,
                    loss: l / cfg.clients as f32,
                    accuracy: a / cfg.clients as f32,
                });
            }
        }
        Ok((report, global))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::KeyMode;
    use std::path::PathBuf;

    fn runtime() -> Option<Runtime> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return None;
        }
        Some(Runtime::new(dir).unwrap())
    }

    fn quick_cfg() -> FlConfig {
        FlConfig {
            model: "mlp".into(),
            clients: 3,
            rounds: 3,
            local_steps: 2,
            lr: 0.1,
            ratio: 0.1,
            samples_per_client: 64,
            eval_every: 3,
            ..Default::default()
        }
    }

    #[test]
    fn full_pipeline_selective_xla() {
        let Some(rt) = runtime() else { return };
        let server = FlServer::new(&rt, quick_cfg()).unwrap();
        let (report, global) = server.run().unwrap();
        assert_eq!(report.rounds.len(), 3);
        assert_eq!(global.len(), 79510);
        assert!((report.mask_ratio - 0.1).abs() < 0.01);
        assert!(!report.evals.is_empty());
        // losses should trend down across rounds
        let first = report.rounds.first().unwrap().train_loss;
        let last = report.rounds.last().unwrap().train_loss;
        assert!(last < first, "loss {first} -> {last}");
        // selective encryption cuts upload bytes well below full encryption
        let plain_bytes = 4 * 79510u64 * 3;
        assert!(report.rounds[0].upload_bytes < 4 * plain_bytes);
    }

    #[test]
    fn plaintext_and_full_encryption_agree() {
        let Some(rt) = runtime() else { return };
        // same seed, plaintext vs fully-encrypted: final models must agree
        // to CKKS precision (the "exact aggregation" claim of Table 1).
        let mut cfg_a = quick_cfg();
        cfg_a.selection = Selection::None;
        cfg_a.dropout = 0.0;
        let mut cfg_b = quick_cfg();
        cfg_b.selection = Selection::Full;
        cfg_b.dropout = 0.0;
        let (_, ga) = FlServer::new(&rt, cfg_a).unwrap().run().unwrap();
        let (_, gb) = FlServer::new(&rt, cfg_b).unwrap().run().unwrap();
        assert_eq!(ga.len(), gb.len());
        let max_err = ga
            .iter()
            .zip(gb.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 1e-3, "max err {max_err}");
    }

    #[test]
    fn threshold_mode_runs() {
        let Some(rt) = runtime() else { return };
        let mut cfg = quick_cfg();
        cfg.key_mode = KeyMode::Threshold;
        cfg.rounds = 2;
        cfg.backend = Backend::Native;
        let (report, _) = FlServer::new(&rt, cfg).unwrap().run().unwrap();
        assert_eq!(report.rounds.len(), 2);
    }

    #[test]
    fn pipeline_engine_matches_sequential_exactly() {
        let Some(rt) = runtime() else { return };
        // Identical seeds, no dropout/stragglers: the pipeline engine must
        // produce the same global model as the sequential loop (the
        // ciphertext limbs are bitwise identical pre-decryption, so the
        // decrypted models match bit-for-bit).
        let mut seq = quick_cfg();
        seq.backend = Backend::Native;
        seq.dropout = 0.0;
        let mut pipe = seq.clone();
        pipe.engine = crate::agg_engine::Engine::Pipeline;
        pipe.shards = 4;
        let (_, ga) = FlServer::new(&rt, seq).unwrap().run().unwrap();
        let (_, gb) = FlServer::new(&rt, pipe).unwrap().run().unwrap();
        // the aggregation itself is bitwise identical (gated by
        // tests/agg_engine_equiv.rs); across two full runs we only allow
        // for benign nondeterminism in the XLA training path
        let max_err = ga
            .iter()
            .zip(gb.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 1e-6, "pipeline diverged from sequential: {max_err}");
    }

    #[test]
    fn tcp_transport_round_matches_sim_transport() {
        let Some(rt) = runtime() else { return };
        // Same seeds, same staged encryption: delivering the updates over
        // real loopback sockets instead of the in-process vector must not
        // change the trained model (no stragglers at loopback speed, quorum
        // unset). Tolerance only covers benign XLA training nondeterminism
        // between the two runs — the aggregation itself is bitwise-stable.
        let mut sim = quick_cfg();
        sim.backend = Backend::Native;
        sim.dropout = 0.0;
        sim.rounds = 2;
        let mut tcp = sim.clone();
        tcp.transport = Transport::Tcp;
        tcp.engine = crate::agg_engine::Engine::Pipeline;
        tcp.shards = 2;
        let (_, ga) = FlServer::new(&rt, sim).unwrap().run().unwrap();
        let (rb, gb) = FlServer::new(&rt, tcp).unwrap().run().unwrap();
        assert_eq!(rb.rounds.len(), 2);
        assert!(rb.rounds.iter().all(|r| r.stragglers_dropped == 0));
        assert!(rb.rounds.iter().all(|r| r.upload_bytes > 0));
        let max_err = ga
            .iter()
            .zip(gb.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 1e-6, "tcp transport diverged from sim: {max_err}");
    }

    #[test]
    fn layer_granularity_mode_runs() {
        let Some(rt) = runtime() else { return };
        let mut cfg = quick_cfg();
        cfg.backend = Backend::Native;
        cfg.mask_granularity = MaskGranularity::Layer;
        cfg.rounds = 2;
        let (report, global) = FlServer::new(&rt, cfg).unwrap().run().unwrap();
        assert_eq!(report.rounds.len(), 2);
        assert!(global.iter().all(|v| v.is_finite()));
        // whole-layer mask: O(layers) runs and a tiny distribution message
        let layers = crate::fl::model_meta::lookup("mlp").unwrap().layers as usize;
        assert!(report.mask_runs <= layers, "runs {}", report.mask_runs);
        assert!(report.mask_bytes < 1024, "mask bytes {}", report.mask_bytes);
        // whole layers are selected until the ratio target is covered
        assert!(report.mask_ratio >= 0.1);
        // the layer-granularity agreement message is O(layers) ciphertexts,
        // far below the O(params) per-parameter map
        assert!(report.mask_upload_bytes > 0);
    }

    #[test]
    fn population_cohort_round_runs() {
        let Some(rt) = runtime() else { return };
        let mut cfg = quick_cfg();
        cfg.backend = Backend::Native;
        cfg.engine = crate::agg_engine::Engine::Pipeline;
        cfg.population = Some(1_000_000);
        cfg.rounds = 2;
        let (report, global) = FlServer::new(&rt, cfg).unwrap().run().unwrap();
        assert_eq!(report.rounds.len(), 2);
        assert!(global.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn dropout_reduces_participants() {
        let Some(rt) = runtime() else { return };
        let mut cfg = quick_cfg();
        cfg.clients = 6;
        cfg.dropout = 0.5;
        cfg.rounds = 4;
        cfg.selection = Selection::Random;
        let (report, _) = FlServer::new(&rt, cfg).unwrap().run().unwrap();
        assert!(report.rounds.iter().any(|r| r.participants < 6));
        // run completes despite dropout — the HE robustness claim of Table 1
        assert_eq!(report.rounds.len(), 4);
    }
}
