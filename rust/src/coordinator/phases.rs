//! The round-phase state machine (DESIGN.md §9).
//!
//! `FlServer::run` used to inline every stage of Fig. 3 into one 400-line
//! loop that only knew in-process clients. Here the task is an explicit
//! phase sequence over a shared [`RoundState`]:
//!
//! ```text
//! KeyAgreement → MaskAgreement → per round r {
//!     Broadcast(r)      downlink: previous aggregate + round roles
//!     LocalTrain+Encrypt / Intake(r)
//!     Aggregate(r)      streaming engine, quorum/straggler policy
//!     Decrypt+Apply(r)  key-holder decrypt + α-mass renormalization
//!     Eval(r)
//! } → Finale            last aggregate + FIN downlink
//! ```
//!
//! Each phase is a function over `RoundState` and a slice of
//! [`Participant`]s. The trait is the deployment boundary: the same phase
//! code drives in-process simulator clients ([`SimParticipant`], arrivals
//! stamped with `netsim` transfer times) and remote TCP peers
//! ([`RemoteParticipant`], persistent duplex sessions with measured
//! wall-clock downlink/uplink). `--transport sim`, `--transport tcp`
//! (in-process client session threads over loopback) and multi-process
//! `serve`/`join` all execute this file — which is what makes their final
//! models bitwise-identical for the same seed: every RNG stream (server
//! and per-client) is consumed by the same code in the same order, and the
//! aggregation/decryption kernels are order-independent.
//!
//! [`client_session_loop`] is the other half of the symmetry: the client
//! main loop shared verbatim by `join` processes and the client threads a
//! single-process tcp run spawns.

use super::client::ClientCore;
use super::config::{MaskGranularity, Selection, Transport};
use super::key_authority::{self, KeyMaterial};
use super::server::{
    EvalPoint, FlReport, FlServer, RoundMetrics, TIMING_MEASURED, TIMING_SIMULATED,
};
use super::taskkey::TaskKey;
use crate::agg_engine::{Arrival, CohortScheduler, Engine, Population, StreamingAggregator};
use crate::ckks::{CkksContext, CtWire, EncKey, PublicKey, SecretKey};
use crate::crypto::prng::ChaChaRng;
use crate::fl::model_meta::layer_spans_for;
use crate::fl::{SyntheticClient, SyntheticModel, SYNTHETIC_MODEL};
use crate::he_agg::{selective, EncryptedUpdate, EncryptionMask, SelectiveCodec};
use crate::netsim::{concurrent_arrivals, SimClock};
use crate::runtime::Runtime;
use crate::transport::{
    ClientSession, DownBegin, IntakeConfig, RoundDownlink, SessionOpts, TransportHub, UpdateShape,
    MASK_ROUND, UNIDENTIFIED_CLIENT,
};
use std::collections::{HashMap, HashSet};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Shared mutable state threaded through the phase machine.
pub struct RoundState {
    pub keys: KeyMaterial,
    pub pk: PublicKey,
    pub global: Vec<f32>,
    pub total_params: usize,
    /// Agreed encryption mask (set by the MaskAgreement phase).
    pub mask: Option<EncryptionMask>,
    /// Round upload/downlink shape derived from the mask.
    pub shape: Option<UpdateShape>,
    pub report: FlReport,
    pub server_rng: ChaChaRng,
    pub clock: SimClock,
    /// Previous round's aggregate + its accepted α mass — the payload of
    /// the next Broadcast phase.
    pub last_agg: Option<(EncryptedUpdate, f64)>,
    /// Cohort scheduler (population mode, sim transport only) — carries
    /// the straggler-penalty state across rounds.
    pub scheduler: Option<CohortScheduler>,
}

/// How round uploads reach the aggregation intake.
pub enum Uplink<'h> {
    /// In-process: arrivals come straight out of [`Participant::
    /// launch_round`], stamped with simulated transfer times.
    Sim,
    /// Persistent TCP sessions: arrivals come off the hub's per-session
    /// readers, stamped with measured wall-clock times.
    Hub(&'h TransportHub),
}

/// Context for the mask-agreement phase.
pub struct MaskStage<'s> {
    pub granularity: MaskGranularity,
    pub spans: &'s [std::ops::Range<usize>],
    /// Sensitivity-map length (params, or layer count under layer
    /// granularity).
    pub map_len: usize,
    pub global: &'s [f32],
    pub pk: &'s PublicKey,
    /// `Some` under `--ct-wire seed`: sim participants then encrypt their
    /// sensitivity maps symmetrically (seed-expanded wire), matching what a
    /// remote client of the same task would put on the socket.
    pub enc_sk: Option<&'s SecretKey>,
    pub codec: &'s SelectiveCodec,
}

/// One round's launch order for a participant.
pub struct RoundLaunch<'s> {
    pub round: usize,
    pub global: &'s [f32],
    pub mask: &'s EncryptionMask,
    pub pk: &'s PublicKey,
    /// `Some` under `--ct-wire seed` (see [`MaskStage::enc_sk`]).
    pub enc_sk: Option<&'s SecretKey>,
    pub codec: &'s SelectiveCodec,
    /// This participant's FedAvg weight normalized over the round's active
    /// set.
    pub alpha_norm: f64,
    pub local_steps: usize,
    pub lr: f32,
    pub dp_scale: Option<f64>,
}

/// The secret key in-process participants encrypt with under `--ct-wire
/// seed` (`None` in dense mode: they use the public key). Seed mode with
/// threshold keys is rejected at server construction, so the `Threshold`
/// arm is unreachable when `ct_wire == Seed`.
fn seed_wire_sk(ct_wire: CtWire, keys: &KeyMaterial) -> Option<&SecretKey> {
    match (ct_wire, keys) {
        (CtWire::Seed, KeyMaterial::SingleKey { sk, .. }) => Some(sk),
        _ => None,
    }
}

/// Uplink key + wire format for one sim encrypt site: symmetric seeded
/// when the task runs `--ct-wire seed` (sk present), else public-key
/// dense. The wire tag feeds the simulated byte accounting.
fn uplink_key<'k>(pk: &'k PublicKey, enc_sk: Option<&'k SecretKey>) -> (EncKey<'k>, CtWire) {
    match enc_sk {
        Some(sk) => (EncKey::SymSeeded(sk), CtWire::Seed),
        None => (EncKey::Public(pk), CtWire::Dense),
    }
}

/// What an in-process participant produced for a round (remote peers
/// return `None` — their upload arrives over the session instead).
pub struct SimRoundOutput {
    pub client: u64,
    pub alpha: f64,
    pub update: EncryptedUpdate,
    pub train_secs: f64,
    pub encrypt_secs: f64,
    pub upload_bytes: u64,
    pub loss: f32,
}

/// A task participant as the phase machine sees it: the same phase code
/// drives in-process simulator clients and remote TCP peers through this
/// trait (the issue's deployment symmetry).
pub trait Participant {
    /// Wire client id (virtual cohort id in population mode).
    fn id(&self) -> u64;
    /// Base FedAvg weight (before per-round normalization).
    fn base_alpha(&self) -> f64;
    /// Rebind this pooled slot to a virtual cohort member (sim-only).
    fn bind_virtual(&mut self, _vid: u64, _alpha: f64, _client_seed: u64, _round: u64) {}
    /// MaskAgreement: produce the encrypted sensitivity map inline (sim),
    /// or `None` when it arrives over the session (remote). The `u64` is
    /// the upload's wire size.
    fn solicit_sensitivity(
        &mut self,
        stage: &MaskStage,
    ) -> anyhow::Result<Option<(EncryptedUpdate, u64)>>;
    /// Downlink the agreed mask (`wire` is its serialized form). Returns
    /// measured wire bytes (0 when the downlink is simulated).
    fn deliver_mask(&mut self, mask: &EncryptionMask, wire: &[u8]) -> anyhow::Result<u64>;
    /// Downlink one round's preamble + optional carried aggregate.
    fn deliver_round(
        &mut self,
        round: u64,
        down: &DownBegin,
        agg: Option<&EncryptedUpdate>,
    ) -> anyhow::Result<u64>;
    /// Kick off round-`r` local train + encrypt + upload. Sim participants
    /// do the work inline and return the result; remote peers return
    /// `None` (their session loop reacts to the Broadcast downlink).
    fn launch_round(&mut self, launch: &RoundLaunch) -> anyhow::Result<Option<SimRoundOutput>>;
    /// Evaluate the global on local data (`None` when the participant
    /// cannot evaluate server-side, i.e. remote peers).
    fn evaluate(&mut self, global: &[f32]) -> anyhow::Result<Option<(f32, f32)>>;
}

/// In-process participant: wraps a [`ClientCore`] (artifact or synthetic).
pub struct SimParticipant<'a> {
    core: ClientCore<'a>,
    /// Wire id — the virtual cohort id after `bind_virtual`, else the
    /// trainer-slot id.
    wire_id: u64,
}

impl<'a> SimParticipant<'a> {
    pub fn new(core: ClientCore<'a>) -> Self {
        let wire_id = core.id();
        SimParticipant { core, wire_id }
    }
}

impl Participant for SimParticipant<'_> {
    fn id(&self) -> u64 {
        self.wire_id
    }

    fn base_alpha(&self) -> f64 {
        self.core.alpha()
    }

    fn bind_virtual(&mut self, vid: u64, alpha: f64, client_seed: u64, round: u64) {
        self.core.bind_virtual(vid, alpha, client_seed, round);
        self.wire_id = vid;
    }

    fn solicit_sensitivity(
        &mut self,
        stage: &MaskStage,
    ) -> anyhow::Result<Option<(EncryptedUpdate, u64)>> {
        let s = match stage.granularity {
            MaskGranularity::Param => self.core.sensitivity(stage.global)?,
            MaskGranularity::Layer => self.core.layer_sensitivity(stage.global, stage.spans)?,
        };
        let (key, wire) = uplink_key(stage.pk, stage.enc_sk);
        let cts = selective::encrypt_vector_keyed(&stage.codec.ctx, &s, key, self.core.rng_mut());
        let upd = EncryptedUpdate {
            cts,
            plain: Vec::new(),
            total: stage.map_len,
        };
        let bytes = upd.wire_bytes_for(&stage.codec.ctx, wire) as u64;
        Ok(Some((upd, bytes)))
    }

    fn deliver_mask(&mut self, _mask: &EncryptionMask, _wire: &[u8]) -> anyhow::Result<u64> {
        Ok(0) // shared-memory delivery; the sim clock charges the broadcast
    }

    fn deliver_round(
        &mut self,
        _round: u64,
        _down: &DownBegin,
        _agg: Option<&EncryptedUpdate>,
    ) -> anyhow::Result<u64> {
        Ok(0) // ditto: the decrypted global is applied by Decrypt+Apply
    }

    fn launch_round(&mut self, l: &RoundLaunch) -> anyhow::Result<Option<SimRoundOutput>> {
        let t = Instant::now();
        let (mut local, loss) = self.core.train(l.global, l.local_steps, l.lr)?;
        let train_secs = t.elapsed().as_secs_f64();
        let t = Instant::now();
        let (key, wire) = uplink_key(l.pk, l.enc_sk);
        let update = self.core.encrypt_keyed(l.codec, &mut local, l.mask, key, l.dp_scale);
        let encrypt_secs = t.elapsed().as_secs_f64();
        let upload_bytes = update.wire_bytes_for(&l.codec.ctx, wire) as u64;
        Ok(Some(SimRoundOutput {
            client: self.wire_id,
            alpha: l.alpha_norm,
            update,
            train_secs,
            encrypt_secs,
            upload_bytes,
            loss,
        }))
    }

    fn evaluate(&mut self, global: &[f32]) -> anyhow::Result<Option<(f32, f32)>> {
        self.core.evaluate(global, 1).map(Some)
    }
}

/// Remote participant: a persistent-session peer. Downlinks are real
/// frames pushed through the hub; uploads arrive via the hub's collector.
pub struct RemoteParticipant<'h> {
    hub: &'h TransportHub,
    id: u64,
    alpha: f64,
}

impl<'h> RemoteParticipant<'h> {
    pub fn new(hub: &'h TransportHub, id: u64, alpha: f64) -> Self {
        RemoteParticipant { hub, id, alpha }
    }
}

impl Participant for RemoteParticipant<'_> {
    fn id(&self) -> u64 {
        self.id
    }

    fn base_alpha(&self) -> f64 {
        self.alpha
    }

    fn solicit_sensitivity(
        &mut self,
        _stage: &MaskStage,
    ) -> anyhow::Result<Option<(EncryptedUpdate, u64)>> {
        Ok(None) // the join side computes + uploads over its session
    }

    fn deliver_mask(&mut self, _mask: &EncryptionMask, wire: &[u8]) -> anyhow::Result<u64> {
        let out = self.hub.broadcast_mask(&[self.id], wire);
        anyhow::ensure!(
            out.failed.is_empty(),
            "mask downlink to client {} failed",
            self.id
        );
        Ok(out.bytes_sent)
    }

    /// Per-client round push. NOTE: the per-round Broadcast and Finale
    /// phases batch the whole cohort through `TransportHub::broadcast_round`
    /// instead (the shared aggregate is serialized once); this per-client
    /// entry exists for targeted pushes — e.g. a future mid-round downlink
    /// replay to a rejoined client.
    fn deliver_round(
        &mut self,
        round: u64,
        down: &DownBegin,
        agg: Option<&EncryptedUpdate>,
    ) -> anyhow::Result<u64> {
        let out = self.hub.broadcast_round(round, &[(self.id, *down)], agg);
        anyhow::ensure!(
            out.failed.is_empty(),
            "round {round} downlink to client {} failed",
            self.id
        );
        Ok(out.bytes_sent)
    }

    fn launch_round(&mut self, _launch: &RoundLaunch) -> anyhow::Result<Option<SimRoundOutput>> {
        Ok(None) // the Broadcast downlink already carries the launch order
    }

    fn evaluate(&mut self, _global: &[f32]) -> anyhow::Result<Option<(f32, f32)>> {
        Ok(None) // remote local data is not reachable server-side
    }
}

// ---------------------------------------------------------------------------
// Phases.

/// Phase 0 — KeyAgreement (Fig. 3 stage 1) + state construction.
pub(crate) fn init_state(srv: &FlServer) -> anyhow::Result<RoundState> {
    let cfg = &srv.cfg;
    if let Some(n) = cfg.population {
        anyhow::ensure!(
            n >= cfg.clients as u64,
            "--population ({n}) must be at least --clients ({})",
            cfg.clients
        );
        anyhow::ensure!(
            cfg.transport == Transport::Sim,
            "--population requires --transport sim (virtual cohort members \
             have no remote processes)"
        );
    }
    let mut server_rng = ChaChaRng::from_seed(cfg.seed, 0x5E17);
    let t = Instant::now();
    let keys = key_authority::setup(&srv.codec.ctx, cfg.key_mode, cfg.clients, &mut server_rng);
    let keygen_secs = t.elapsed().as_secs_f64();
    let pk = keys.public_key().clone();
    let global = srv.init_global()?;
    let total_params = global.len();
    let timing_source = match cfg.transport {
        Transport::Sim => TIMING_SIMULATED,
        Transport::Tcp => TIMING_MEASURED,
    };
    let report = FlReport {
        model: cfg.model.clone(),
        clients: cfg.clients,
        total_params,
        keygen_secs,
        timing_source,
        ..Default::default()
    };
    let scheduler = cfg
        .population
        .map(|n| CohortScheduler::new(Population::new(n, cfg.seed), cfg.clients));
    Ok(RoundState {
        keys,
        pk,
        global,
        total_params,
        mask: None,
        shape: None,
        report,
        server_rng,
        clock: SimClock::parallel(),
        last_agg: None,
        scheduler,
    })
}

/// Phase 1 — MaskAgreement (§2.4): compute/collect encrypted sensitivity
/// maps (TopP), aggregate + decrypt the aggregate only, derive the mask,
/// and broadcast it to every participant (simulated clock or real MASK
/// frames).
pub(crate) fn phase_mask_agreement(
    srv: &FlServer,
    st: &mut RoundState,
    participants: &mut [Box<dyn Participant + '_>],
    uplink: &Uplink,
) -> anyhow::Result<()> {
    let _span = crate::obs::span("coordinator", "phase_mask_agreement");
    let cfg = &srv.cfg;
    let t = Instant::now();
    let mut mask_clock = SimClock::parallel();
    let mut measured_up = 0u64;
    let mut measured_secs = 0.0f64;
    let mask = match cfg.selection {
        Selection::Full => EncryptionMask::full(st.total_params),
        Selection::None => EncryptionMask::empty(st.total_params),
        Selection::Random => {
            EncryptionMask::random(st.total_params, cfg.ratio, &mut st.server_rng)
        }
        Selection::TopP => {
            let spans = layer_spans_for(&cfg.model, st.total_params);
            let map_len = match cfg.mask_granularity {
                MaskGranularity::Param => st.total_params,
                MaskGranularity::Layer => spans.len(),
            };
            let stage = MaskStage {
                granularity: cfg.mask_granularity,
                spans: &spans,
                map_len,
                global: &st.global,
                pk: &st.pk,
                enc_sk: seed_wire_sk(cfg.ct_wire, &st.keys),
                codec: &srv.codec,
            };
            let mut maps: Vec<(u64, f64, EncryptedUpdate)> = Vec::new();
            let mut base_alpha: HashMap<u64, f64> = HashMap::new();
            for p in participants.iter_mut() {
                base_alpha.insert(p.id(), p.base_alpha());
                if let Some((upd, bytes)) = p.solicit_sensitivity(&stage)? {
                    mask_clock.upload(bytes, cfg.bandwidth);
                    maps.push((p.id(), p.base_alpha(), upd));
                }
            }
            if let Uplink::Hub(hub) = uplink {
                let shape = UpdateShape {
                    n_cts: srv.codec.ct_count(map_len),
                    n_plain: 0,
                    total: map_len,
                    ct_wire: cfg.ct_wire,
                };
                let expected: Vec<(u64, Option<f64>)> = base_alpha
                    .iter()
                    .map(|(&id, &alpha)| (id, Some(alpha)))
                    .collect();
                let stage_wait = Duration::from_secs_f64(
                    cfg.intake_max_wait.unwrap_or(cfg.round_wait).max(1.0),
                );
                let icfg = IntakeConfig {
                    round_id: MASK_ROUND,
                    expected_uploads: expected.len(),
                    quorum: None,
                    max_wait: stage_wait,
                    // a client may compute its sensitivity map for a while
                    // before its BEGIN lands; the per-read timeout must not
                    // undercut that (the deadline clamp still bounds it)
                    io_timeout: stage_wait,
                    ..IntakeConfig::default()
                };
                let outcome = hub.collect_round(&expected, shape, &icfg);
                anyhow::ensure!(
                    outcome.failed.is_empty() && outcome.arrivals.len() == expected.len(),
                    "mask agreement requires every client's sensitivity map \
                     ({} of {} arrived, failed: {:?})",
                    outcome.arrivals.len(),
                    expected.len(),
                    outcome.failed
                );
                measured_up = outcome.bytes_received;
                measured_secs += outcome.elapsed_secs;
                for a in outcome.arrivals {
                    // server-authoritative weights: the agreed base alpha,
                    // not whatever the wire declared
                    let alpha = base_alpha[&a.client];
                    let upd = Arc::try_unwrap(a.update)
                        .unwrap_or_else(|arc| (*arc).clone());
                    maps.push((a.client, alpha, upd));
                }
            }
            maps.sort_by_key(|(id, _, _)| *id);
            let alphas: Vec<f64> = maps.iter().map(|m| m.1).collect();
            let updates: Vec<EncryptedUpdate> = maps.into_iter().map(|m| m.2).collect();
            let agg_map = srv.aggregate(&updates, &alphas)?;
            let global_map =
                srv.decrypt_vec(&agg_map.cts, &st.keys, map_len, &mut st.server_rng);
            match cfg.mask_granularity {
                MaskGranularity::Param => EncryptionMask::top_p(&global_map, cfg.ratio),
                MaskGranularity::Layer => EncryptionMask::from_layer_scores(
                    st.total_params,
                    &global_map,
                    &spans,
                    cfg.ratio,
                ),
            }
        }
    };

    // Algorithm 1 round 1: broadcast the agreed mask to every client.
    let wire = mask.to_bytes();
    let mask_bytes = wire.len() as u64;
    let t_down = Instant::now();
    let mut measured_down = 0u64;
    for p in participants.iter_mut() {
        measured_down += p.deliver_mask(&mask, &wire)?;
    }
    match uplink {
        Uplink::Sim => {
            mask_clock.broadcast(mask_bytes, cfg.clients, cfg.bandwidth);
            st.report.mask_upload_bytes = mask_clock.bytes_up;
            st.report.mask_comm_secs = mask_clock.comm_secs;
            st.report.mask_agreement_secs = t.elapsed().as_secs_f64() + mask_clock.comm_secs;
        }
        Uplink::Hub(_) => {
            measured_secs += t_down.elapsed().as_secs_f64();
            st.report.mask_upload_bytes = measured_up;
            st.report.mask_downlink_bytes = measured_down;
            st.report.mask_comm_secs = measured_secs;
            // wall time already contains the measured network time
            st.report.mask_agreement_secs = t.elapsed().as_secs_f64();
        }
    }
    st.report.mask_bytes = mask_bytes;
    st.report.mask_ratio = mask.ratio();
    st.report.encrypted_params = mask.encrypted_count();
    st.report.mask_runs = mask.encrypted.n_runs();
    st.shape = Some(UpdateShape::for_round_wire(&srv.codec.ctx, &mask, cfg.ct_wire));
    st.mask = Some(mask);
    Ok(())
}

/// One Broadcast phase's outcome: the active set and the measured downlink
/// cost.
pub(crate) struct BroadcastPlan {
    /// Participant indexes active this round.
    pub active: Vec<usize>,
    /// Their wire client ids (aligned with `active`).
    pub active_ids: Vec<u64>,
    /// Their FedAvg weights normalized over the active set.
    pub alphas: Vec<f64>,
    /// Measured downlink frame bytes (0 under sim — the clock carries it).
    pub down_bytes: u64,
    /// Measured downlink wall time (0-ish under sim).
    pub down_secs: f64,
}

/// Phase 2 — Broadcast(r): sample the cohort (population mode), draw
/// dropout, and push the start-of-round downlink — the previous round's
/// partially-encrypted aggregate plus each participant's role — to every
/// connected participant (dropped clients still receive the next global).
pub(crate) fn phase_broadcast(
    srv: &FlServer,
    st: &mut RoundState,
    participants: &mut [Box<dyn Participant + '_>],
    round: usize,
    uplink: &Uplink,
) -> anyhow::Result<BroadcastPlan> {
    let _span = crate::obs::span_arg("coordinator", "phase_broadcast", round as u64);
    let cfg = &srv.cfg;
    if let Uplink::Hub(hub) = uplink {
        hub.set_next_round(round as u64);
    }
    let cohort = st.scheduler.as_ref().map(|s| s.sample(round as u64));
    if let (Some(c), Some(s)) = (&cohort, &st.scheduler) {
        for (slot, m) in c.members.iter().enumerate() {
            participants[slot].bind_virtual(
                m.id,
                m.alpha,
                s.population.client_seed(m.id),
                round as u64,
            );
        }
    }

    // dropout injection (HE is dropout-robust: we just renormalize);
    // rng consumption order matches the seed coordinator exactly
    let active: Vec<usize> = (0..cfg.clients)
        .filter(|_| st.server_rng.uniform_f64() >= cfg.dropout)
        .collect();
    let active = if active.is_empty() { vec![0] } else { active };
    let alpha_sum: f64 = active.iter().map(|&i| participants[i].base_alpha()).sum();
    let alphas: Vec<f64> = active
        .iter()
        .map(|&i| participants[i].base_alpha() / alpha_sum)
        .collect();
    let active_ids: Vec<u64> = active.iter().map(|&i| participants[i].id()).collect();

    let (agg, alpha_mass) = match &st.last_agg {
        Some((a, m)) => (Some(a), *m),
        None => (None, 0.0),
    };
    let shape = st.shape.expect("mask agreed before rounds");
    let plans: Vec<(u64, DownBegin)> = participants
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let k = active.iter().position(|&a| a == i);
            let down = DownBegin {
                alpha: k.map(|k| alphas[k]).unwrap_or(0.0),
                alpha_mass,
                n_cts: if agg.is_some() { shape.n_cts } else { 0 },
                n_plain: if agg.is_some() { shape.n_plain } else { 0 },
                total: if agg.is_some() { shape.total } else { 0 },
                participate: k.is_some(),
                has_agg: agg.is_some(),
                fin: false,
            };
            (p.id(), down)
        })
        .collect();
    match uplink {
        Uplink::Sim => {
            // symmetry hook: sim participants receive the same per-round
            // role delivery (a no-op — the sim clock charges the broadcast)
            for (p, (_, down)) in participants.iter_mut().zip(plans.iter()) {
                p.deliver_round(round as u64, down, agg)?;
            }
            if let Some(a) = agg {
                st.clock.broadcast(
                    a.wire_bytes(&srv.codec.ctx) as u64,
                    participants.len(),
                    cfg.bandwidth,
                );
            }
            Ok(BroadcastPlan {
                active,
                active_ids,
                alphas,
                down_bytes: 0,
                down_secs: 0.0,
            })
        }
        Uplink::Hub(hub) => {
            // one batched push: the shared aggregate is serialized once and
            // fanned out to every connected session
            let out = hub.broadcast_round(round as u64, &plans, agg);
            for client in &out.failed {
                // dead session: its absence surfaces as a failed upload in
                // the Intake phase (straggler accounting); slot can rejoin
                crate::log_debug!(
                    "phases",
                    "round {round} downlink to client {client} failed"
                );
            }
            Ok(BroadcastPlan {
                active,
                active_ids,
                alphas,
                down_bytes: out.bytes_sent,
                down_secs: out.elapsed_secs,
            })
        }
    }
}

/// Phase 3a — LocalTrain+Encrypt then Aggregate, in-process: launch each
/// active participant inline, stamp arrivals with simulated transfer
/// times, and run the configured engine (sequential barrier or streaming
/// pipeline).
fn phase_collect_sim(
    srv: &FlServer,
    st: &mut RoundState,
    participants: &mut [Box<dyn Participant + '_>],
    round: usize,
    plan: &BroadcastPlan,
    rm: &mut RoundMetrics,
) -> anyhow::Result<(EncryptedUpdate, f64)> {
    let _span = crate::obs::span_arg("coordinator", "phase_collect", round as u64);
    let cfg = &srv.cfg;
    let mask = st.mask.as_ref().expect("mask agreed");
    let mut outs: Vec<SimRoundOutput> = Vec::with_capacity(plan.active.len());
    let mut loss_sum = 0.0f32;
    for (k, &i) in plan.active.iter().enumerate() {
        let launch = RoundLaunch {
            round,
            global: &st.global,
            mask,
            pk: &st.pk,
            enc_sk: seed_wire_sk(cfg.ct_wire, &st.keys),
            codec: &srv.codec,
            alpha_norm: plan.alphas[k],
            local_steps: cfg.local_steps,
            lr: cfg.lr,
            dp_scale: cfg.dp_scale,
        };
        let out = participants[i]
            .launch_round(&launch)?
            .expect("sim participants produce their round output inline");
        rm.train_secs += out.train_secs;
        rm.encrypt_secs += out.encrypt_secs;
        loss_sum += out.loss;
        outs.push(out);
    }
    rm.train_loss = loss_sum / plan.active.len() as f32;

    let t = Instant::now();
    let result = match cfg.engine {
        Engine::Sequential => {
            for o in &outs {
                st.clock.upload(o.upload_bytes, cfg.bandwidth);
            }
            let alphas: Vec<f64> = outs.iter().map(|o| o.alpha).collect();
            let updates: Vec<EncryptedUpdate> = outs.into_iter().map(|o| o.update).collect();
            (srv.aggregate(&updates, &alphas)?, 1.0)
        }
        Engine::Pipeline => {
            let client_ids: Vec<u64> = outs.iter().map(|o| o.client).collect();
            let bytes: Vec<u64> = outs.iter().map(|o| o.upload_bytes).collect();
            // a client's upload starts when its (concurrent) local
            // training finishes — the arrival ordering of the pipeline
            let starts: Vec<f64> = outs.iter().map(|o| o.train_secs).collect();
            let arrival_secs = concurrent_arrivals(&bytes, &starts, cfg.bandwidth);
            let arrivals: Vec<Arrival> = outs
                .into_iter()
                .zip(arrival_secs)
                .map(|(o, at)| Arrival {
                    client: o.client,
                    alpha: o.alpha,
                    arrival_secs: at,
                    update: Arc::new(o.update),
                })
                .collect();
            let engine = StreamingAggregator::new(&srv.codec.ctx.params, cfg.engine_config());
            // run-aligned plaintext shard plan from the shared mask
            let (agg, stats) = engine.aggregate_with_mask(arrivals, Some(mask))?;
            let accepted: HashSet<u64> = stats.accepted_clients.iter().copied().collect();
            for (cid, &b) in client_ids.iter().zip(bytes.iter()) {
                if accepted.contains(cid) {
                    st.clock.upload(b, cfg.bandwidth);
                } else {
                    // dropped straggler: bytes were sent but the round
                    // never waited for them
                    st.clock.upload_bytes_only(b);
                }
            }
            // straggler-aware resampling: feed observed outcomes back into
            // the cohort scheduler (population mode)
            if let Some(s) = st.scheduler.as_mut() {
                for cid in &client_ids {
                    if accepted.contains(cid) {
                        s.observe_completed(*cid);
                    } else {
                        s.observe_straggler(*cid);
                    }
                }
            }
            rm.participants = stats.accepted;
            rm.stragglers_dropped = stats.dropped_stragglers;
            (agg, stats.alpha_mass)
        }
    };
    rm.aggregate_secs = t.elapsed().as_secs_f64();
    Ok(result)
}

/// Phase 3b — Intake then Aggregate, persistent sessions: collect the
/// round's uploads off the hub (wall-clock stamps, quorum early-stop,
/// client-reported local metrics), feed the streaming engine, and fold
/// failed sessions into the straggler accounting.
fn phase_collect_hub(
    srv: &FlServer,
    st: &mut RoundState,
    hub: &TransportHub,
    round: usize,
    plan: &BroadcastPlan,
    rm: &mut RoundMetrics,
) -> anyhow::Result<(EncryptedUpdate, f64)> {
    let _span = crate::obs::span_arg("coordinator", "phase_collect", round as u64);
    let cfg = &srv.cfg;
    let mask = st.mask.as_ref().expect("mask agreed");
    let shape = st.shape.expect("mask agreed");
    let t = Instant::now();
    // hard intake bound: explicit --intake-max-wait, or base slack plus
    // the straggler window so a wide timeout is never silently truncated;
    // also what bounds a fully-failed round
    let max_wait = Duration::from_secs_f64(
        cfg.intake_max_wait
            .unwrap_or(30.0 + cfg.straggler_timeout.max(0.0))
            .max(1.0),
    );
    let icfg = IntakeConfig {
        round_id: round as u64,
        expected_uploads: plan.active_ids.len(),
        quorum: cfg.quorum,
        straggler_timeout: Duration::from_secs_f64(cfg.straggler_timeout.max(0.0)),
        max_wait,
        // clients train before their BEGIN lands — the per-read timeout
        // must cover that; the (cutoff-aware) deadline clamp still bounds
        // every read, so straggler responsiveness is unaffected
        io_timeout: max_wait,
        ..IntakeConfig::default()
    };
    // server-authoritative weights: the collector pins each session's
    // declared FedAvg weight to the one this round's downlink assigned, so
    // a skewed upload fails its session before touching arrivals or the
    // round's metric sums
    let expected: Vec<(u64, Option<f64>)> = plan
        .active_ids
        .iter()
        .copied()
        .zip(plan.alphas.iter().map(|&a| Some(a)))
        .collect();
    let outcome = hub.collect_round(&expected, shape, &icfg);
    let wire_secs = outcome.elapsed_secs;
    st.clock.upload_bytes_only(outcome.bytes_received);
    rm.train_secs = outcome.train_secs;
    rm.encrypt_secs = outcome.encrypt_secs;
    let completed = outcome.arrivals.len();
    if completed > 0 {
        rm.train_loss = (outcome.loss_sum / completed as f64) as f32;
    }
    let failed = outcome.failed;

    let engine = StreamingAggregator::new(&srv.codec.ctx.params, cfg.engine_config());
    let mut intake = engine.begin_round(Some(mask));
    intake.offer_many(outcome.arrivals)?;
    let (agg, mut stats) = intake.seal()?;
    // Only identified participants whose upload failed count as dropped
    // stragglers — retries of an already-accepted client would otherwise
    // skew the round's reported drop rate.
    let accepted_ids: HashSet<u64> = stats.accepted_clients.iter().copied().collect();
    let failed_participants = failed
        .iter()
        .filter(|&&id| id != UNIDENTIFIED_CLIENT && !accepted_ids.contains(&id))
        .collect::<HashSet<_>>()
        .len();
    stats.offered += failed_participants;
    stats.dropped_stragglers += failed_participants;
    rm.participants = stats.accepted;
    rm.stragglers_dropped = stats.dropped_stragglers;
    rm.comm_secs += wire_secs;
    rm.aggregate_secs = (t.elapsed().as_secs_f64() - wire_secs).max(0.0);
    Ok((agg, stats.alpha_mass))
}

/// Phase 4 — Decrypt+Apply: key-holder decryption of the aggregate,
/// renormalized by the accepted FedAvg weight mass; the result becomes the
/// next global and the aggregate is retained for the next Broadcast.
pub(crate) fn phase_decrypt_apply(
    srv: &FlServer,
    st: &mut RoundState,
    agg: EncryptedUpdate,
    alpha_mass: f64,
) -> anyhow::Result<f64> {
    let _span = crate::obs::span("coordinator", "phase_decrypt_apply");
    let t = Instant::now();
    let mut global = srv.decrypt_global(
        &agg,
        st.mask.as_ref().expect("mask agreed"),
        &st.keys,
        &mut st.server_rng,
    );
    if (alpha_mass - 1.0).abs() > 1e-12 {
        for v in global.iter_mut() {
            *v = (*v as f64 / alpha_mass) as f32;
        }
    }
    st.global = global;
    st.last_agg = Some((agg, alpha_mass));
    Ok(t.elapsed().as_secs_f64())
}

/// Phase 5 — Eval: periodic evaluation on participants' local data; under
/// remote participants the synthetic model evaluates server-side (pure
/// function of the seed), artifact models skip.
pub(crate) fn phase_eval(
    srv: &FlServer,
    st: &mut RoundState,
    participants: &mut [Box<dyn Participant + '_>],
    round: usize,
) -> anyhow::Result<()> {
    let cfg = &srv.cfg;
    if cfg.eval_every == 0 || (round + 1) % cfg.eval_every != 0 {
        return Ok(());
    }
    let _span = crate::obs::span_arg("coordinator", "phase_eval", round as u64);
    let mut l = 0.0f32;
    let mut a = 0.0f32;
    let mut n = 0usize;
    for p in participants.iter_mut() {
        if let Some((cl, ca)) = p.evaluate(&st.global)? {
            l += cl;
            a += ca;
            n += 1;
        }
    }
    if n == 0 && cfg.model == SYNTHETIC_MODEL {
        let m = SyntheticModel::new(cfg.synthetic_dim.max(1), cfg.seed);
        for id in 0..cfg.clients {
            let (cl, ca) = SyntheticClient::new(m, id as u64, cfg.clients).evaluate(&st.global);
            l += cl;
            a += ca;
            n += 1;
        }
    }
    if n > 0 {
        st.report.evals.push(EvalPoint {
            round: round + 1,
            loss: l / n as f32,
            accuracy: a / n as f32,
        });
    }
    Ok(())
}

/// Phase 6 — Finale: deliver the last aggregate with the FIN flag so every
/// client applies the final global and exits its session loop (real frames
/// under tcp; one simulated broadcast under sim for accounting symmetry).
pub(crate) fn phase_finale(
    srv: &FlServer,
    st: &mut RoundState,
    participants: &mut [Box<dyn Participant + '_>],
    uplink: &Uplink,
) -> anyhow::Result<()> {
    let _span = crate::obs::span("coordinator", "phase_finale");
    let cfg = &srv.cfg;
    let (agg, alpha_mass) = match &st.last_agg {
        Some((a, m)) => (Some(a), *m),
        None => (None, 0.0),
    };
    if let Uplink::Hub(hub) = uplink {
        hub.set_next_round(cfg.rounds as u64);
    }
    let shape = st.shape.expect("mask agreed");
    let down0 = st.clock.bytes_down;
    let comm0 = st.clock.comm_secs;
    let fin = DownBegin {
        alpha: 0.0,
        alpha_mass,
        n_cts: if agg.is_some() { shape.n_cts } else { 0 },
        n_plain: if agg.is_some() { shape.n_plain } else { 0 },
        total: if agg.is_some() { shape.total } else { 0 },
        participate: false,
        has_agg: agg.is_some(),
        fin: true,
    };
    match uplink {
        Uplink::Sim => {
            for p in participants.iter_mut() {
                p.deliver_round(cfg.rounds as u64, &fin, agg)?;
            }
            if let Some(a) = agg {
                st.clock.broadcast(
                    a.wire_bytes(&srv.codec.ctx) as u64,
                    participants.len(),
                    cfg.bandwidth,
                );
            }
            st.report.fin_downlink_bytes = st.clock.bytes_down - down0;
            st.report.fin_downlink_secs = st.clock.comm_secs - comm0;
        }
        Uplink::Hub(hub) => {
            let plans: Vec<(u64, DownBegin)> =
                participants.iter().map(|p| (p.id(), fin)).collect();
            let out = hub.broadcast_round(cfg.rounds as u64, &plans, agg);
            for client in &out.failed {
                crate::log_debug!("phases", "fin downlink to client {client} failed");
            }
            st.report.fin_downlink_bytes = out.bytes_sent;
            st.report.fin_downlink_secs = out.elapsed_secs;
        }
    }
    Ok(())
}

/// The driver: MaskAgreement, then per-round phase dispatch, then Finale.
/// `FlServer::run` and `FlServer::serve` both reduce to this.
pub(crate) fn drive(
    srv: &FlServer,
    st: &mut RoundState,
    participants: &mut [Box<dyn Participant + '_>],
    uplink: &Uplink,
) -> anyhow::Result<()> {
    phase_mask_agreement(srv, st, participants, uplink)?;
    for round in 0..srv.cfg.rounds {
        let _round_span = crate::obs::span_arg("coordinator", "round", round as u64);
        let comm0 = st.clock.comm_secs;
        let up0 = st.clock.bytes_up;
        let down0 = st.clock.bytes_down;
        let mut rm = RoundMetrics {
            round,
            timing_source: st.report.timing_source,
            ..Default::default()
        };
        let plan = phase_broadcast(srv, st, participants, round, uplink)?;
        rm.participants = plan.active.len();
        let (agg, alpha_mass) = match uplink {
            Uplink::Sim => phase_collect_sim(srv, st, participants, round, &plan, &mut rm)?,
            Uplink::Hub(hub) => phase_collect_hub(srv, st, *hub, round, &plan, &mut rm)?,
        };
        rm.decrypt_secs = phase_decrypt_apply(srv, st, agg, alpha_mass)?;
        rm.upload_bytes = st.clock.bytes_up - up0;
        rm.comm_secs += st.clock.comm_secs - comm0;
        match uplink {
            Uplink::Sim => rm.download_bytes = st.clock.bytes_down - down0,
            Uplink::Hub(_) => {
                rm.comm_secs += plan.down_secs;
                rm.downlink_secs = plan.down_secs;
                rm.download_bytes = plan.down_bytes;
            }
        }
        crate::log_debug!(
            "server",
            "round {round}: loss {:.4} train {:.2}s enc {:.2}s agg {:.2}s",
            rm.train_loss,
            rm.train_secs,
            rm.encrypt_secs,
            rm.aggregate_secs
        );
        st.report.rounds.push(rm);
        st.clock.finish_round();
        phase_eval(srv, st, participants, round)?;
    }
    phase_finale(srv, st, participants, uplink)
}

// ---------------------------------------------------------------------------
// The client side of the deployment symmetry.

/// Everything a client session loop needs to know about the task (a subset
/// of [`super::taskkey::TaskSpec`], resolved for one client).
#[derive(Debug, Clone)]
pub struct ClientLoopCfg {
    pub addr: String,
    pub client: u64,
    pub model: String,
    pub clients: usize,
    pub selection: Selection,
    pub mask_granularity: MaskGranularity,
    pub local_steps: usize,
    pub lr: f32,
    pub dp_scale: Option<f64>,
    pub opts: SessionOpts,
}

/// Burn one unit of the rejoin budget and reconnect; errors with the
/// original failure once the budget is exhausted. Each attempt runs the
/// full [`ClientSession::connect`] (backoff dial + handshake), and the
/// server-side handshake replays the in-flight stage's downlink.
fn rejoin_session(
    cfg: &ClientLoopCfg,
    codec: &SelectiveCodec,
    rejoins_left: &mut u32,
    err: anyhow::Error,
) -> anyhow::Result<ClientSession> {
    let mut last = err;
    while *rejoins_left > 0 {
        *rejoins_left -= 1;
        crate::log_debug!(
            "client",
            "client {}: session lost ({last}); rejoining ({} attempts left)",
            cfg.client,
            rejoins_left
        );
        match ClientSession::connect(
            &cfg.addr,
            cfg.client,
            codec.ctx.params.clone(),
            cfg.opts.clone(),
        ) {
            Ok((sess, _next)) => return Ok(sess),
            Err(e) => last = e,
        }
    }
    Err(last.context("session lost and the rejoin budget is exhausted"))
}

/// The client main loop, shared verbatim by `join` processes and the
/// in-process client threads of `--transport tcp`: connect + HELLO, upload
/// the encrypted sensitivity map (TopP), receive the mask, then per round
/// receive the downlink (decrypt + renormalize the carried aggregate with
/// the secret key — the client-side half of Algorithm 1), train, encrypt,
/// upload. Exits on the FIN downlink; returns the final global model.
///
/// Wire faults do not kill the task while the rejoin budget
/// (`opts.connect_retries`) lasts: a failed receive or upload reconnects,
/// the server's handshake replays the current stage's downlink, and the
/// loop's round counter skips downlinks it already processed (wire round
/// below its own) or fast-forwards to a later round the task moved on to
/// while the client was gone.
pub fn client_session_loop(
    cfg: &ClientLoopCfg,
    codec: &SelectiveCodec,
    pk: &PublicKey,
    sk: &SecretKey,
    init_global: Vec<f32>,
    core: &mut ClientCore,
) -> anyhow::Result<Vec<f32>> {
    let (mut sess, _next) = ClientSession::connect(
        &cfg.addr,
        cfg.client,
        codec.ctx.params.clone(),
        cfg.opts.clone(),
    )?;
    let mut global = init_global;
    let total = global.len();
    // Uplink encryption key for the task's ct-wire mode. The HELLO/WELCOME
    // handshake already pinned the mode task-wide, so a seed-mode client
    // encrypts symmetrically — same rng stream, same order as the sim
    // participant it is bitwise-equivalent to.
    let enc = match cfg.opts.ct_wire {
        CtWire::Dense => EncKey::Public(pk),
        CtWire::Seed => EncKey::SymSeeded(sk),
    };
    // rejoin budget for the whole task
    let mut rejoins_left = cfg.opts.connect_retries;

    // Mask-agreement stage (TopP only): encrypted sensitivity uplink.
    if cfg.selection == Selection::TopP {
        let spans = layer_spans_for(&cfg.model, total);
        let s = match cfg.mask_granularity {
            MaskGranularity::Param => core.sensitivity(&global)?,
            MaskGranularity::Layer => core.layer_sensitivity(&global, &spans)?,
        };
        let map_len = s.len();
        let cts = selective::encrypt_vector_keyed(&codec.ctx, &s, enc, core.rng_mut());
        let upd = EncryptedUpdate {
            cts,
            plain: Vec::new(),
            total: map_len,
        };
        loop {
            match sess.upload(MASK_ROUND, core.alpha(), &upd, None) {
                Ok(_) => break,
                Err(e) => sess = rejoin_session(cfg, codec, &mut rejoins_left, e)?,
            }
        }
    }
    let mask = loop {
        match sess.recv_mask(total) {
            Ok(m) => break m,
            Err(e) => sess = rejoin_session(cfg, codec, &mut rejoins_left, e)?,
        }
    };
    anyhow::ensure!(
        mask.total() == total,
        "agreed mask covers {} params, local model has {total}",
        mask.total()
    );
    let shape = UpdateShape::for_round(&codec.ctx, &mask);

    let mut round: u64 = 0;
    // A downlink drained during an upload retry that turned out to belong
    // to a *later* round (the server closed this client's upload window
    // and moved on) — processed by the next loop iteration.
    let mut carry: Option<(u64, RoundDownlink)> = None;
    loop {
        let (wire_round, dl) = match carry.take() {
            Some(x) => x,
            None => match sess.recv_round_any(Some(shape), total) {
                Ok(x) => x,
                Err(e) => {
                    sess = rejoin_session(cfg, codec, &mut rejoins_left, e)?;
                    continue;
                }
            },
        };
        if wire_round < round {
            // a rejoin replay of a downlink this client already processed
            continue;
        }
        round = wire_round;
        if let Some(agg) = &dl.agg {
            let mut g = codec.decrypt_update(agg, &mask, sk);
            // identical renormalization (and skip-condition) to the
            // server's Decrypt+Apply phase — bit-for-bit the same global
            if (dl.down.alpha_mass - 1.0).abs() > 1e-12 {
                for v in g.iter_mut() {
                    *v = (*v as f64 / dl.down.alpha_mass) as f32;
                }
            }
            global = g;
        }
        if dl.down.fin {
            break;
        }
        if dl.down.participate {
            let t = Instant::now();
            let (mut local, loss) = core.train(&global, cfg.local_steps, cfg.lr)?;
            let train_secs = t.elapsed().as_secs_f64();
            let t = Instant::now();
            let upd = core.encrypt_keyed(codec, &mut local, &mask, enc, cfg.dp_scale);
            let encrypt_secs = t.elapsed().as_secs_f64();
            loop {
                match sess.upload(
                    round,
                    dl.down.alpha,
                    &upd,
                    Some((train_secs, encrypt_secs, loss)),
                ) {
                    Ok(_) => break,
                    Err(e) => {
                        sess = rejoin_session(cfg, codec, &mut rejoins_left, e)?;
                        // The rejoin handshake replays the in-flight
                        // stage's downlink; drain it so the retry's ACK is
                        // the next frame on the read path. A replay of the
                        // current round means the server is still
                        // collecting — retry the upload; a later round
                        // means this client's window closed — carry it.
                        match sess.recv_round_any(Some(shape), total) {
                            Ok((r, d)) if r > round => {
                                carry = Some((r, d));
                                break;
                            }
                            Ok(_) => {}
                            Err(e) => {
                                sess = rejoin_session(cfg, codec, &mut rejoins_left, e)?;
                            }
                        }
                    }
                }
            }
        }
        round += 1;
    }
    Ok(global)
}

/// Run one `join` process: load the out-of-band task key, build the client
/// core (synthetic, or artifact-backed via `rt`), and drive
/// [`client_session_loop`] against the serve process at `addr`. Returns
/// the client's final global model.
pub fn join_task(
    addr: &str,
    client_id: u64,
    key: &TaskKey,
    rt: Option<&Runtime>,
    mut opts: SessionOpts,
) -> anyhow::Result<Vec<f32>> {
    let spec = &key.spec;
    anyhow::ensure!(
        client_id < spec.clients as u64,
        "--client-id {client_id} out of range (task has {} clients, ids 0..{})",
        spec.clients,
        spec.clients - 1
    );
    // the wire-auth mode travels in the task key, so `join` auto-selects
    // it — a client can never be silently downgraded by the socket peer
    if spec.wire_auth == crate::coordinator::config::WireAuth::Mac {
        opts.auth = Some(crate::crypto::mac::derive_client_key(
            &key.mac_root,
            client_id,
        ));
    }
    // ditto the ct-wire mode: every join announces the task's mode at
    // HELLO, so a seed-mode task can't be silently downgraded to dense
    opts.ct_wire = spec.ct_wire;
    let params = spec.params()?;
    let ctx = CkksContext {
        encoder: Arc::new(crate::ckks::Encoder::new(params.clone())),
        params,
    };
    let codec = SelectiveCodec::new(ctx);
    let (mut core, init_global) = if spec.model == SYNTHETIC_MODEL {
        let m = SyntheticModel::new(spec.synthetic_dim.max(1), spec.seed);
        (
            ClientCore::Synthetic(SyntheticClient::new(m, client_id, spec.clients)),
            m.init_params(),
        )
    } else {
        let rt = rt.ok_or_else(|| {
            anyhow::anyhow!(
                "model '{}' needs the AOT artifacts (--artifacts); only the \
                 synthetic model joins artifact-free",
                spec.model
            )
        })?;
        let client = super::client::FlClient::new(
            rt,
            &spec.model,
            client_id as usize,
            spec.clients,
            spec.samples_per_client,
            spec.skew,
            spec.seed,
        )?;
        let init = rt.manifest.load_init_params(&spec.model)?;
        (ClientCore::Artifact(client), init)
    };
    let lcfg = ClientLoopCfg {
        addr: addr.to_string(),
        client: client_id,
        model: spec.model.clone(),
        clients: spec.clients,
        selection: spec.selection,
        mask_granularity: spec.mask_granularity,
        local_steps: spec.local_steps,
        lr: spec.lr,
        dp_scale: spec.dp_scale,
        opts,
    };
    client_session_loop(&lcfg, &codec, &key.pk, &key.sk, init_global, &mut core)
}
