//! Out-of-band task/key distribution for multi-process `serve`/`join`
//! (DESIGN.md §9).
//!
//! `serve` writes one binary **task-key file** before opening its listen
//! socket; each `join` process reads it to recover (a) the task spec every
//! participant must agree on for the run to be bitwise-reproducible (model,
//! crypto context, seed, FL hyper-parameters) and (b) the key material: the
//! public key every client encrypts under and the secret key the key-holder
//! role uses to decrypt the broadcast aggregate locally.
//!
//! **Trust model.** The file is the paper's "key agreement" stage collapsed
//! to a file handed out over a trusted side channel: whoever can read it
//! can decrypt aggregates, so it must never travel over the session socket.
//! Since v2 the file also carries the 32-byte `mac_root` from which every
//! client derives its per-client MAC key (`crypto::mac::derive_client_key`)
//! — under `--wire-auth mac` the HELLO/WELCOME handshake is a server-nonce
//! challenge/response and every post-handshake frame carries a keyed tag,
//! so client ids can no longer be forged by any peer that merely knows the
//! listen address (DESIGN.md §12). The transport itself remains plaintext
//! TCP: the MAC layer gives integrity and identity, not confidentiality —
//! which the HE layer already provides for everything that matters.

use super::config::{FlConfig, MaskGranularity, Selection, WireAuth};
use crate::ckks::keys::{PublicKey, SecretKey};
use crate::ckks::serialize::{
    public_key_append, public_key_read, secret_key_append, secret_key_read,
};
use crate::ckks::{CkksParams, CtWire};
use crate::transport::frame::crc32;
use std::sync::Arc;

const MAGIC: u32 = 0x4648_544B; // "FHTK"
const VERSION: u32 = 3; // v2: wire-auth tag + mac_root; v3: ct-wire tag

/// The task parameters every process of a multi-process run must share.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskSpec {
    pub model: String,
    /// Parameter count of the `synthetic` model (0 for artifact models).
    pub synthetic_dim: usize,
    pub clients: usize,
    pub rounds: usize,
    pub local_steps: usize,
    pub lr: f32,
    pub ratio: f64,
    pub selection: Selection,
    pub mask_granularity: MaskGranularity,
    pub dp_scale: Option<f64>,
    pub samples_per_client: usize,
    pub skew: f64,
    pub seed: u64,
    /// Crypto context as `(n, num_limbs, scaling_bits)`.
    pub crypto: (usize, usize, u32),
    /// Wire-authentication mode every participant must run in lockstep
    /// (`join` auto-selects it from here; a mode mismatch fails loudly at
    /// the handshake).
    pub wire_auth: WireAuth,
    /// Uplink ciphertext wire format (`--ct-wire`), pinned task-wide the
    /// same way: `join` announces it at HELLO and the server refuses a
    /// mismatch, so no client can be silently downgraded to the dense wire.
    pub ct_wire: CtWire,
}

impl TaskSpec {
    /// Extract the shared spec from a server config + its crypto context.
    pub fn from_config(cfg: &FlConfig, params: &CkksParams) -> Self {
        TaskSpec {
            model: cfg.model.clone(),
            synthetic_dim: cfg.synthetic_dim,
            clients: cfg.clients,
            rounds: cfg.rounds,
            local_steps: cfg.local_steps,
            lr: cfg.lr,
            ratio: cfg.ratio,
            selection: cfg.selection,
            mask_granularity: cfg.mask_granularity,
            dp_scale: cfg.dp_scale,
            samples_per_client: cfg.samples_per_client,
            skew: cfg.skew,
            seed: cfg.seed,
            crypto: (params.n, params.num_limbs(), params.scaling_bits),
            wire_auth: cfg.wire_auth,
            ct_wire: cfg.ct_wire,
        }
    }

    /// Rebuild the crypto parameters this spec pins.
    pub fn params(&self) -> anyhow::Result<Arc<CkksParams>> {
        let (n, limbs, bits) = self.crypto;
        Ok(Arc::new(CkksParams::new(n, limbs, bits)?))
    }
}

fn selection_to_u8(s: Selection) -> u8 {
    match s {
        Selection::Full => 0,
        Selection::TopP => 1,
        Selection::Random => 2,
        Selection::None => 3,
    }
}

fn selection_from_u8(v: u8) -> anyhow::Result<Selection> {
    Ok(match v {
        0 => Selection::Full,
        1 => Selection::TopP,
        2 => Selection::Random,
        3 => Selection::None,
        other => anyhow::bail!("unknown selection tag {other}"),
    })
}

fn granularity_to_u8(g: MaskGranularity) -> u8 {
    match g {
        MaskGranularity::Param => 0,
        MaskGranularity::Layer => 1,
    }
}

fn granularity_from_u8(v: u8) -> anyhow::Result<MaskGranularity> {
    Ok(match v {
        0 => MaskGranularity::Param,
        1 => MaskGranularity::Layer,
        other => anyhow::bail!("unknown mask-granularity tag {other}"),
    })
}

fn wire_auth_to_u8(w: WireAuth) -> u8 {
    match w {
        WireAuth::None => 0,
        WireAuth::Mac => 1,
    }
}

fn wire_auth_from_u8(v: u8) -> anyhow::Result<WireAuth> {
    Ok(match v {
        0 => WireAuth::None,
        1 => WireAuth::Mac,
        other => anyhow::bail!("unknown wire-auth tag {other}"),
    })
}

fn ct_wire_from_u8(v: u8) -> anyhow::Result<CtWire> {
    CtWire::from_wire_code(v as u32).ok_or_else(|| anyhow::anyhow!("unknown ct-wire tag {v}"))
}

/// The complete out-of-band distribution artifact: spec + key material.
pub struct TaskKey {
    pub spec: TaskSpec,
    pub pk: PublicKey,
    pub sk: SecretKey,
    /// Root of the per-client MAC key hierarchy (DESIGN.md §12). Drawn
    /// from OS entropy at `serve` time — never from `cfg.seed`, which is
    /// public and pins the (deterministic) model trajectory, not secrets.
    /// All-zeros when `wire_auth` is [`WireAuth::None`].
    pub mac_root: [u8; 32],
}

fn read_u32(bytes: &[u8], off: &mut usize) -> anyhow::Result<u32> {
    anyhow::ensure!(bytes.len() >= *off + 4, "truncated task key");
    let v = u32::from_le_bytes(bytes[*off..*off + 4].try_into().unwrap());
    *off += 4;
    Ok(v)
}

fn read_u64(bytes: &[u8], off: &mut usize) -> anyhow::Result<u64> {
    anyhow::ensure!(bytes.len() >= *off + 8, "truncated task key");
    let v = u64::from_le_bytes(bytes[*off..*off + 8].try_into().unwrap());
    *off += 8;
    Ok(v)
}

fn read_f64(bytes: &[u8], off: &mut usize) -> anyhow::Result<f64> {
    Ok(f64::from_bits(read_u64(bytes, off)?))
}

impl TaskKey {
    /// Serialize: fixed header, spec fields, model name, pk, sk, CRC-32.
    pub fn to_bytes(&self) -> Vec<u8> {
        let s = &self.spec;
        let mut out = Vec::new();
        out.extend_from_slice(&MAGIC.to_le_bytes());
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(s.crypto.0 as u32).to_le_bytes());
        out.extend_from_slice(&(s.crypto.1 as u32).to_le_bytes());
        out.extend_from_slice(&s.crypto.2.to_le_bytes());
        out.extend_from_slice(&s.seed.to_le_bytes());
        out.extend_from_slice(&(s.clients as u32).to_le_bytes());
        out.extend_from_slice(&(s.rounds as u32).to_le_bytes());
        out.extend_from_slice(&(s.local_steps as u32).to_le_bytes());
        out.extend_from_slice(&s.lr.to_le_bytes());
        out.extend_from_slice(&s.ratio.to_le_bytes());
        out.push(selection_to_u8(s.selection));
        out.push(granularity_to_u8(s.mask_granularity));
        out.push(u8::from(s.dp_scale.is_some()));
        out.push(wire_auth_to_u8(s.wire_auth));
        out.push(s.ct_wire.wire_code() as u8);
        out.extend_from_slice(&s.dp_scale.unwrap_or(0.0).to_le_bytes());
        out.extend_from_slice(&(s.samples_per_client as u32).to_le_bytes());
        out.extend_from_slice(&s.skew.to_le_bytes());
        out.extend_from_slice(&(s.synthetic_dim as u64).to_le_bytes());
        let name = s.model.as_bytes();
        assert!(name.len() <= u16::MAX as usize, "model name too long");
        out.extend_from_slice(&(name.len() as u16).to_le_bytes());
        out.extend_from_slice(name);
        public_key_append(&self.pk, &mut out);
        secret_key_append(&self.sk, &mut out);
        out.extend_from_slice(&self.mac_root);
        let crc = crc32(&out);
        out.extend_from_slice(&crc.to_le_bytes());
        out
    }

    /// Parse + validate a task-key file; returns the key and its rebuilt
    /// crypto parameters.
    pub fn from_bytes(bytes: &[u8]) -> anyhow::Result<(TaskKey, Arc<CkksParams>)> {
        anyhow::ensure!(bytes.len() > 4, "truncated task key");
        let (body, crc_bytes) = bytes.split_at(bytes.len() - 4);
        anyhow::ensure!(
            u32::from_le_bytes(crc_bytes.try_into().unwrap()) == crc32(body),
            "task key crc mismatch"
        );
        let mut off = 0usize;
        anyhow::ensure!(read_u32(body, &mut off)? == MAGIC, "bad task-key magic");
        anyhow::ensure!(read_u32(body, &mut off)? == VERSION, "bad task-key version");
        let n = read_u32(body, &mut off)? as usize;
        let limbs = read_u32(body, &mut off)? as usize;
        let scaling_bits = read_u32(body, &mut off)?;
        let seed = read_u64(body, &mut off)?;
        let clients = read_u32(body, &mut off)? as usize;
        let rounds = read_u32(body, &mut off)? as usize;
        let local_steps = read_u32(body, &mut off)? as usize;
        let lr = f32::from_bits(read_u32(body, &mut off)?);
        let ratio = read_f64(body, &mut off)?;
        anyhow::ensure!(body.len() >= off + 5, "truncated task key");
        let selection = selection_from_u8(body[off])?;
        let mask_granularity = granularity_from_u8(body[off + 1])?;
        let has_dp = body[off + 2];
        anyhow::ensure!(has_dp <= 1, "bad dp flag");
        let wire_auth = wire_auth_from_u8(body[off + 3])?;
        let ct_wire = ct_wire_from_u8(body[off + 4])?;
        off += 5;
        let dp_raw = read_f64(body, &mut off)?;
        let dp_scale = (has_dp == 1).then_some(dp_raw);
        let samples_per_client = read_u32(body, &mut off)? as usize;
        let skew = read_f64(body, &mut off)?;
        let synthetic_dim = read_u64(body, &mut off)? as usize;
        anyhow::ensure!(body.len() >= off + 2, "truncated task key");
        let name_len = u16::from_le_bytes(body[off..off + 2].try_into().unwrap()) as usize;
        off += 2;
        anyhow::ensure!(body.len() >= off + name_len, "truncated model name");
        let model = std::str::from_utf8(&body[off..off + name_len])
            .map_err(|_| anyhow::anyhow!("model name is not utf-8"))?
            .to_string();
        off += name_len;
        anyhow::ensure!(clients >= 1, "task key declares no clients");
        anyhow::ensure!(lr.is_finite(), "non-finite learning rate");
        anyhow::ensure!(ratio.is_finite() && (0.0..=1.0).contains(&ratio), "bad ratio");
        anyhow::ensure!(skew.is_finite(), "non-finite skew");
        let params = Arc::new(CkksParams::new(n, limbs, scaling_bits)?);
        let pk = public_key_read(body, &mut off, &params)?;
        let sk = secret_key_read(body, &mut off, &params)?;
        anyhow::ensure!(body.len() >= off + 32, "truncated mac root");
        let mut mac_root = [0u8; 32];
        mac_root.copy_from_slice(&body[off..off + 32]);
        off += 32;
        anyhow::ensure!(off == body.len(), "trailing bytes in task key");
        let spec = TaskSpec {
            model,
            synthetic_dim,
            clients,
            rounds,
            local_steps,
            lr,
            ratio,
            selection,
            mask_granularity,
            dp_scale,
            samples_per_client,
            skew,
            seed,
            crypto: (n, limbs, scaling_bits),
            wire_auth,
            ct_wire,
        };
        Ok((TaskKey { spec, pk, sk, mac_root }, params))
    }

    /// Write the file atomically — temp file + rename, so a `join` process
    /// polling for the path's existence can never observe a partial key
    /// (0600-equivalent permissions are the operator's responsibility —
    /// the file contains the secret key).
    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<()> {
        crate::util::write_file_atomic(path, &self.to_bytes())
            .map_err(|e| anyhow::anyhow!("cannot write task key {}: {e}", path.display()))
    }

    /// Read + parse a task-key file.
    pub fn load(path: &std::path::Path) -> anyhow::Result<(TaskKey, Arc<CkksParams>)> {
        let bytes = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("cannot read task key {}: {e}", path.display()))?;
        Self::from_bytes(&bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::prng::ChaChaRng;

    fn fixture() -> TaskKey {
        let params = CkksParams::new(256, 3, 30).unwrap();
        let mut rng = ChaChaRng::from_seed(5, 0);
        let (pk, sk) = crate::ckks::keys::keygen(&params, &mut rng);
        let cfg = FlConfig {
            model: "synthetic".into(),
            clients: 3,
            rounds: 4,
            seed: 77,
            dp_scale: Some(0.25),
            wire_auth: WireAuth::Mac,
            ct_wire: CtWire::Seed,
            ..Default::default()
        };
        let mut mac_root = [0u8; 32];
        for (i, b) in mac_root.iter_mut().enumerate() {
            *b = (i as u8).wrapping_mul(41);
        }
        TaskKey {
            spec: TaskSpec::from_config(&cfg, &params),
            pk,
            sk,
            mac_root,
        }
    }

    #[test]
    fn roundtrip_preserves_spec_and_keys() {
        let tk = fixture();
        let bytes = tk.to_bytes();
        let (back, params) = TaskKey::from_bytes(&bytes).unwrap();
        assert_eq!(back.spec, tk.spec);
        assert_eq!(back.spec.wire_auth, WireAuth::Mac);
        assert_eq!(back.spec.ct_wire, CtWire::Seed);
        assert_eq!(back.mac_root, tk.mac_root);
        assert_eq!(params.n, 256);
        assert_eq!(back.pk.b_ntt, tk.pk.b_ntt);
        assert_eq!(back.pk.a_ntt, tk.pk.a_ntt);
        assert_eq!(back.sk.s_ntt, tk.sk.s_ntt);
    }

    #[test]
    fn corruption_and_truncation_rejected() {
        let bytes = fixture().to_bytes();
        // flip every 97th byte: crc (or a field validator) must catch it
        for i in (0..bytes.len()).step_by(97) {
            let mut b = bytes.clone();
            b[i] ^= 0x40;
            assert!(TaskKey::from_bytes(&b).is_err(), "flip at {i} accepted");
        }
        for cut in [0, 3, 16, bytes.len() / 2, bytes.len() - 1] {
            assert!(TaskKey::from_bytes(&bytes[..cut]).is_err(), "cut {cut}");
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let tk = fixture();
        let path = std::env::temp_dir().join(format!(
            "fedml_he_taskkey_test_{}.key",
            std::process::id()
        ));
        tk.save(&path).unwrap();
        let (back, _) = TaskKey::load(&path).unwrap();
        assert_eq!(back.spec, tk.spec);
        std::fs::remove_file(&path).ok();
    }
}
