//! Key management (paper Fig. 3 "Encryption Key Agreement" stage).
//!
//! Two modes:
//! * **Single key** — a trusted key authority generates `(pk, sk)` and
//!   distributes both to clients; the aggregation server receives only the
//!   public crypto context (it must never decrypt).
//! * **Threshold** — no trusted dealer: every client contributes a key share
//!   over a CRS-derived common polynomial (Appendix B); decryption requires
//!   all parties' partials.
//!
//! Either way the authority can Shamir-escrow key material so a quorum of
//! clients survives catastrophic dropout ([`escrow_secret`]).

use crate::ckks::threshold::{self, ThresholdParty};
use crate::ckks::{CkksContext, PublicKey, SecretKey};
use crate::crypto::prng::ChaChaRng;
use crate::crypto::shamir;

/// Key material held by the *clients* (the server only ever sees `public`).
pub enum KeyMaterial {
    SingleKey {
        pk: PublicKey,
        sk: SecretKey,
    },
    Threshold {
        pk: PublicKey,
        parties: Vec<ThresholdParty>,
    },
}

impl KeyMaterial {
    pub fn public_key(&self) -> &PublicKey {
        match self {
            KeyMaterial::SingleKey { pk, .. } => pk,
            KeyMaterial::Threshold { pk, .. } => pk,
        }
    }
}

/// Run the key-agreement stage.
pub fn setup(
    ctx: &CkksContext,
    mode: crate::coordinator::config::KeyMode,
    n_clients: usize,
    rng: &mut ChaChaRng,
) -> KeyMaterial {
    match mode {
        crate::coordinator::config::KeyMode::SingleKey => {
            let (pk, sk) = ctx.keygen(rng);
            KeyMaterial::SingleKey { pk, sk }
        }
        crate::coordinator::config::KeyMode::Threshold => {
            // Round 0: CRS; Round 1: every client publishes a share;
            // Round 2: combine.
            let a = threshold::common_reference(&ctx.params, 0xFED5_EED);
            let parties: Vec<ThresholdParty> = (0..n_clients)
                .map(|k| threshold::party_keygen(&ctx.params, k, &a, rng))
                .collect();
            let shares: Vec<&crate::ckks::RnsPoly> =
                parties.iter().map(|p| &p.b_share_ntt).collect();
            let pk = threshold::combine_public_key(&ctx.params, &a, &shares);
            KeyMaterial::Threshold { pk, parties }
        }
    }
}

/// Shamir-escrow an opaque secret (e.g. a serialized secret key) as t-of-n
/// shares.
pub fn escrow_secret(
    secret: &[u8],
    t: usize,
    n: usize,
    rng: &mut ChaChaRng,
) -> Vec<Vec<shamir::Share>> {
    shamir::split_bytes(secret, t, n, rng)
}

/// Recover an escrowed secret from a quorum.
pub fn recover_secret(parties: &[&[shamir::Share]], len: usize) -> Vec<u8> {
    shamir::reconstruct_bytes(parties, len)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::KeyMode;

    #[test]
    fn single_key_mode_roundtrips() {
        let ctx = CkksContext::new(256, 3, 40).unwrap();
        let mut rng = ChaChaRng::from_seed(1, 0);
        let km = setup(&ctx, KeyMode::SingleKey, 4, &mut rng);
        let values = vec![1.25, -0.5, 3.0];
        let ct = ctx.encrypt_values(&values, km.public_key(), &mut rng);
        let KeyMaterial::SingleKey { sk, .. } = &km else {
            panic!()
        };
        let dec = ctx.decrypt_values(&ct, sk);
        for (a, b) in values.iter().zip(dec.iter()) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn threshold_mode_needs_all_parties() {
        let ctx = CkksContext::new(256, 3, 40).unwrap();
        let mut rng = ChaChaRng::from_seed(2, 0);
        let km = setup(&ctx, KeyMode::Threshold, 3, &mut rng);
        let KeyMaterial::Threshold { pk, parties } = &km else {
            panic!()
        };
        let values = vec![0.75; 64];
        let ct = ctx.encrypt_values(&values, pk, &mut rng);
        let partials: Vec<_> = parties
            .iter()
            .map(|p| threshold::partial_decrypt(&ctx.params, p, &ct, &mut rng))
            .collect();
        let m = threshold::combine_partials(&ctx.params, &ct, &partials);
        let dec = ctx.encoder.decode(&m, ct.n_values, ct.scale);
        assert!((dec[0] - 0.75).abs() < 1e-4);
    }

    #[test]
    fn escrow_recovers_after_dropout() {
        let mut rng = ChaChaRng::from_seed(3, 0);
        let secret = b"serialized-secret-key-material".to_vec();
        let shares = escrow_secret(&secret, 2, 5, &mut rng);
        // parties 0, 3 survive
        let rec = recover_secret(&[&shares[0], &shares[3]], secret.len());
        assert_eq!(rec, secret);
    }
}
