//! Client-side executor: local training, sensitivity analysis, encryption.
//!
//! A client never ships plaintext parameters for masked coordinates; all its
//! heavy math (train/sensitivity) runs through the AOT artifacts.

use crate::crypto::prng::ChaChaRng;
use crate::fl::data::synthetic_images;
use crate::fl::{LocalTrainer, Workload};
use crate::he_agg::{EncryptedUpdate, EncryptionMask, SelectiveCodec};
use crate::runtime::Runtime;

/// One federated client.
pub struct FlClient<'a> {
    pub id: usize,
    pub alpha: f64,
    pub trainer: LocalTrainer<'a>,
    pub data: Workload,
    pub rng: ChaChaRng,
}

impl<'a> FlClient<'a> {
    /// Build a client with its local synthetic dataset.
    pub fn new(
        rt: &'a Runtime,
        model: &str,
        id: usize,
        n_clients: usize,
        samples: usize,
        skew: f64,
        seed: u64,
    ) -> anyhow::Result<Self> {
        let trainer = LocalTrainer::new(rt, model)?;
        let meta = &rt.manifest.models[model];
        let data = if model == "tinybert" {
            Workload::Token(crate::fl::data::synthetic_tokens(
                id,
                samples,
                meta.seq_len.unwrap_or(16),
                meta.vocab.unwrap_or(128),
                seed,
            ))
        } else {
            let shape = match meta.input_shape.as_slice() {
                [c, h, w] => (*c, *h, *w),
                [f] => (1, 1, *f), // flat inputs (mlp): dataset synthesizes 1×1×F
                _ => anyhow::bail!("unsupported input shape"),
            };
            // mlp trains on flattened 28×28 images
            let gen_shape = if model == "mlp" { (1, 28, 28) } else { shape };
            Workload::Image(synthetic_images(
                id,
                samples,
                gen_shape,
                meta.num_classes,
                skew,
                seed,
            ))
        };
        Ok(FlClient {
            id,
            alpha: 1.0 / n_clients as f64,
            trainer,
            data,
            rng: ChaChaRng::from_seed(seed, 0x1000 + id as u64),
        })
    }

    /// Rebind this (pooled) trainer slot to impersonate virtual cohort
    /// member `vid` for one round: the population's per-client weight and a
    /// per-(virtual-client, round) RNG stream. The round is folded into the
    /// seed so a client re-sampled in a later round never replays encryption
    /// or DP randomness (LWE randomness reuse would leak plaintext
    /// differences). Trainer pools back the lazily materialized population
    /// of `agg_engine::cohort` — only the K sampled participants per round
    /// ever hold real state.
    pub fn bind_virtual(&mut self, vid: u64, alpha: f64, client_seed: u64, round: u64) {
        self.alpha = alpha;
        self.rng = ChaChaRng::from_seed(client_seed.wrapping_add(round), 0x7000 ^ vid);
    }

    /// Local sensitivity map (mask-agreement stage input).
    pub fn sensitivity(&mut self, params: &[f32]) -> anyhow::Result<Vec<f32>> {
        let LocalTrainer { .. } = &self.trainer;
        self.trainer.sensitivity(params, &self.data)
    }

    /// Per-layer sensitivity scores (mean |Δf| over each span) for
    /// layer-granularity mask agreement: the client pre-aggregates locally so
    /// the encrypted agreement message is O(layers), not O(params).
    pub fn layer_sensitivity(
        &mut self,
        params: &[f32],
        spans: &[std::ops::Range<usize>],
    ) -> anyhow::Result<Vec<f32>> {
        let s = self.sensitivity(params)?;
        Ok(crate::he_agg::mask::layer_mean_scores(&s, spans))
    }

    /// Local training: `steps` SGD steps starting from the global model.
    pub fn train(&mut self, global: &[f32], steps: usize, lr: f32) -> anyhow::Result<(Vec<f32>, f32)> {
        self.trainer.train(global, &self.data, steps, lr)
    }

    /// Encrypt the local model per Algorithm 1 (optionally with local DP on
    /// the plaintext coordinates).
    pub fn encrypt(
        &mut self,
        codec: &SelectiveCodec,
        params: &mut Vec<f32>,
        mask: &EncryptionMask,
        pk: &crate::ckks::PublicKey,
        dp_scale: Option<f64>,
    ) -> EncryptedUpdate {
        self.encrypt_keyed(codec, params, mask, crate::ckks::EncKey::Public(pk), dp_scale)
    }

    /// [`Self::encrypt`] under either ct-wire key mode: public-key (dense
    /// wire) or symmetric seeded (seed wire, `--ct-wire seed`).
    pub fn encrypt_keyed(
        &mut self,
        codec: &SelectiveCodec,
        params: &mut Vec<f32>,
        mask: &EncryptionMask,
        key: crate::ckks::EncKey<'_>,
        dp_scale: Option<f64>,
    ) -> EncryptedUpdate {
        let mut update = codec.encrypt_update_keyed(params, mask, key, &mut self.rng);
        if let Some(b) = dp_scale {
            // Laplace noise on the *plaintext* part only — encrypted
            // coordinates need no noise (Theorem 3.9: ε = 0).
            crate::crypto::dp::add_noise(&mut self.rng, &mut update.plain, b);
        }
        update
    }

    /// Evaluate the global model on local data.
    pub fn evaluate(&mut self, params: &[f32], batches: usize) -> anyhow::Result<(f32, f32)> {
        self.trainer.evaluate(params, &self.data, batches)
    }
}

/// mlp-shaped workloads feed [B, 784]; image graphs feed [B, C, H, W]. The
/// trainer handles image graphs; this helper flattens for mlp.
pub fn is_flat_input(model: &str) -> bool {
    model == "mlp"
}

/// The client-side compute core behind a coordinator
/// [`Participant`](crate::coordinator::phases::Participant): either the
/// AOT-artifact trainer or the artifact-free synthetic workload. One enum so the phase machine, the in-process tcp
/// client threads, and standalone `join` processes all drive exactly the
/// same per-client logic (same rng streams, same encrypt path) — the
/// bitwise-equivalence guarantee between `--transport sim`, `--transport
/// tcp` and multi-process `serve`/`join` rests on this.
pub enum ClientCore<'a> {
    Artifact(FlClient<'a>),
    Synthetic(crate::fl::SyntheticClient),
}

impl ClientCore<'_> {
    pub fn id(&self) -> u64 {
        match self {
            ClientCore::Artifact(c) => c.id as u64,
            ClientCore::Synthetic(c) => c.id,
        }
    }

    /// Base FedAvg weight (before per-round normalization over the active
    /// set).
    pub fn alpha(&self) -> f64 {
        match self {
            ClientCore::Artifact(c) => c.alpha,
            ClientCore::Synthetic(c) => c.alpha,
        }
    }

    /// The client's encryption/DP randomness stream.
    pub fn rng_mut(&mut self) -> &mut ChaChaRng {
        match self {
            ClientCore::Artifact(c) => &mut c.rng,
            ClientCore::Synthetic(c) => &mut c.rng,
        }
    }

    /// Rebind this pooled slot to a virtual cohort member for one round.
    pub fn bind_virtual(&mut self, vid: u64, alpha: f64, client_seed: u64, round: u64) {
        match self {
            ClientCore::Artifact(c) => c.bind_virtual(vid, alpha, client_seed, round),
            ClientCore::Synthetic(c) => c.bind_virtual(vid, alpha, client_seed, round),
        }
    }

    /// Local sensitivity map (mask-agreement stage input).
    pub fn sensitivity(&mut self, global: &[f32]) -> anyhow::Result<Vec<f32>> {
        match self {
            ClientCore::Artifact(c) => c.sensitivity(global),
            ClientCore::Synthetic(c) => Ok(c.sensitivity(global)),
        }
    }

    /// Per-layer sensitivity scores (layer-granularity mask agreement).
    pub fn layer_sensitivity(
        &mut self,
        global: &[f32],
        spans: &[std::ops::Range<usize>],
    ) -> anyhow::Result<Vec<f32>> {
        match self {
            ClientCore::Artifact(c) => c.layer_sensitivity(global, spans),
            ClientCore::Synthetic(c) => {
                let s = c.sensitivity(global);
                Ok(crate::he_agg::mask::layer_mean_scores(&s, spans))
            }
        }
    }

    /// Local training from the global model.
    pub fn train(
        &mut self,
        global: &[f32],
        steps: usize,
        lr: f32,
    ) -> anyhow::Result<(Vec<f32>, f32)> {
        match self {
            ClientCore::Artifact(c) => c.train(global, steps, lr),
            ClientCore::Synthetic(c) => Ok(c.train(global, steps, lr)),
        }
    }

    /// Algorithm-1 client-side encryption (+ optional DP noise on the
    /// plaintext remainder), driven by this client's rng stream.
    pub fn encrypt(
        &mut self,
        codec: &SelectiveCodec,
        params: &mut Vec<f32>,
        mask: &EncryptionMask,
        pk: &crate::ckks::PublicKey,
        dp_scale: Option<f64>,
    ) -> EncryptedUpdate {
        self.encrypt_keyed(codec, params, mask, crate::ckks::EncKey::Public(pk), dp_scale)
    }

    /// [`Self::encrypt`] under either ct-wire key mode — the seed wire
    /// encrypts symmetrically with the distributed secret key, consuming
    /// the same per-client rng stream in the same order on every
    /// transport (the bitwise sim/tcp/serve equivalence rests on this).
    pub fn encrypt_keyed(
        &mut self,
        codec: &SelectiveCodec,
        params: &mut Vec<f32>,
        mask: &EncryptionMask,
        key: crate::ckks::EncKey<'_>,
        dp_scale: Option<f64>,
    ) -> EncryptedUpdate {
        match self {
            ClientCore::Artifact(c) => c.encrypt_keyed(codec, params, mask, key, dp_scale),
            ClientCore::Synthetic(c) => {
                let mut update = codec.encrypt_update_keyed(params, mask, key, &mut c.rng);
                if let Some(b) = dp_scale {
                    crate::crypto::dp::add_noise(&mut c.rng, &mut update.plain, b);
                }
                update
            }
        }
    }

    /// Evaluate the global model on local data.
    pub fn evaluate(&mut self, global: &[f32], batches: usize) -> anyhow::Result<(f32, f32)> {
        match self {
            ClientCore::Artifact(c) => c.evaluate(global, batches),
            ClientCore::Synthetic(c) => Ok(c.evaluate(global)),
        }
    }
}
