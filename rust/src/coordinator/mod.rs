//! L3 coordinator: the paper's FL Orchestration layer.
//!
//! * [`config`] — task configuration (the deployment "server package").
//! * [`key_authority`] — key agreement: trusted dealer or threshold protocol.
//! * [`client`] — client-side executor (local train, sensitivity, encrypt).
//! * [`server`] — the round orchestrator implementing Fig. 3's three stages
//!   and Algorithm 1, with per-stage overhead metrics.

pub mod client;
pub mod config;
pub mod key_authority;
pub mod server;

pub use config::{Backend, FlConfig, KeyMode, MaskGranularity, Selection, Transport};
pub use server::{FlReport, FlServer, RoundMetrics};
