//! L3 coordinator: the paper's FL Orchestration layer.
//!
//! * [`config`] — task configuration (the deployment "server package").
//! * [`key_authority`] — key agreement: trusted dealer or threshold protocol.
//! * [`client`] — client-side executor (local train, sensitivity, encrypt)
//!   behind the [`client::ClientCore`] artifact/synthetic split.
//! * [`phases`] — the round-phase state machine (KeyAgreement →
//!   MaskAgreement → per-round Broadcast/Intake/Aggregate/Decrypt → Eval →
//!   Finale) over the [`phases::Participant`] trait, plus the client
//!   session loop shared by `join` processes and in-process tcp clients.
//! * [`taskkey`] — the out-of-band task/key distribution file for
//!   multi-process `serve`/`join`.
//! * [`server`] — the orchestrator: configuration, report, and the
//!   run/serve entry points dispatching into the phase machine.

pub mod client;
pub mod config;
pub mod key_authority;
pub mod phases;
pub mod server;
pub mod taskkey;

pub use config::{
    Backend, FlConfig, KeyMode, MaskGranularity, Selection, Transport, TransportBackend,
};
pub use phases::{client_session_loop, join_task, Participant, RemoteParticipant, SimParticipant};
pub use server::{FlReport, FlServer, RoundMetrics, ServeOptions};
pub use taskkey::{TaskKey, TaskSpec};
