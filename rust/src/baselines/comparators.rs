//! Calibrated cost models of the comparator frameworks (Table 8 / Fig. 2).
//!
//! NVIDIA FLARE and IBMFL are closed, heavyweight stacks we cannot run in
//! this testbed; following DESIGN.md §3 we emulate them as *cost models
//! calibrated to the paper's own Table 8 measurements*, expressed as factors
//! relative to our measured PALISADE-class pipeline:
//!
//! |            | comp factor | comm factor | basis (paper Table 8, CNN, 3 clients) |
//! |------------|-------------|-------------|----------------------------------------|
//! | ours       | 1.000       | 1.000       | 2.456 s, 105.72 MB                      |
//! | FLARE      | 1.151       | 1.227       | 2.826 s, 129.75 MB (TenSEAL)            |
//! | ours-TenSEAL | 1.624     | 1.227       | 3.989 s, 129.75 MB                      |
//! | IBMFL      | 1.610       | 0.819       | 3.955 s,  86.58 MB (HELayers)           |
//!
//! FLARE is *faster* than a naive TenSEAL port because it weights updates on
//! the client (skipping the server-side ciphertext multiply) at the price of
//! revealing the weighting to clients — reproduced by `server_multiplies`.

/// One emulated framework.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Framework {
    pub name: &'static str,
    pub he_core: &'static str,
    /// Computation-time factor vs our measured pipeline.
    pub comp_factor: f64,
    /// Ciphertext-size factor vs our wire format.
    pub comm_factor: f64,
    /// Whether aggregation weights are applied on the server (ciphertext
    /// multiply) or pre-scaled on clients (FLARE's shortcut).
    pub server_multiplies: bool,
    /// Key-management support (Table 8 column).
    pub key_management: bool,
}

pub const OURS: Framework = Framework {
    name: "FedML-HE (PALISADE-class)",
    he_core: "own RNS-CKKS",
    comp_factor: 1.0,
    comm_factor: 1.0,
    server_multiplies: true,
    key_management: true,
};

pub const OURS_TENSEAL: Framework = Framework {
    name: "FedML-HE (TenSEAL-class)",
    he_core: "SEAL (TenSEAL)",
    comp_factor: 3.989 / 2.456,
    comm_factor: 129.75 / 105.72,
    server_multiplies: true,
    key_management: true,
};

pub const FLARE: Framework = Framework {
    name: "Nvidia FLARE (9a1b226)",
    he_core: "SEAL (TenSEAL)",
    comp_factor: 2.826 / 2.456,
    comm_factor: 129.75 / 105.72,
    server_multiplies: false,
    key_management: true,
};

pub const IBMFL: Framework = Framework {
    name: "IBMFL (8c8ab11)",
    he_core: "SEAL (HELayers)",
    comp_factor: 3.955 / 2.456,
    comm_factor: 86.58 / 105.72,
    server_multiplies: true,
    key_management: false,
};

pub const ALL: &[Framework] = &[OURS, OURS_TENSEAL, FLARE, IBMFL];

impl Framework {
    /// Emulated computation time given our measured seconds.
    pub fn comp_secs(&self, ours_measured_secs: f64) -> f64 {
        ours_measured_secs * self.comp_factor
    }

    /// Emulated ciphertext bytes given our measured bytes.
    pub fn comm_bytes(&self, ours_measured_bytes: u64) -> u64 {
        (ours_measured_bytes as f64 * self.comm_factor) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_reproduces_table8_ratios() {
        // If our pipeline measured exactly the paper's 2.456 s / 105.72 MB,
        // the emulators must reproduce the paper's comparator numbers.
        let ours_s = 2.456;
        let ours_b = (105.72 * 1024.0 * 1024.0) as u64;
        assert!((FLARE.comp_secs(ours_s) - 2.826).abs() < 1e-9);
        assert!((OURS_TENSEAL.comp_secs(ours_s) - 3.989).abs() < 1e-9);
        assert!((IBMFL.comp_secs(ours_s) - 3.955).abs() < 1e-9);
        let flare_mb = FLARE.comm_bytes(ours_b) as f64 / (1024.0 * 1024.0);
        assert!((flare_mb - 129.75).abs() < 0.1);
        let ibm_mb = IBMFL.comm_bytes(ours_b) as f64 / (1024.0 * 1024.0);
        assert!((ibm_mb - 86.58).abs() < 0.1);
    }

    #[test]
    fn ordering_matches_paper() {
        // comp: ours < FLARE < IBMFL ≈ ours-TenSEAL; comm: IBMFL < ours < FLARE
        assert!(OURS.comp_factor < FLARE.comp_factor);
        assert!(FLARE.comp_factor < IBMFL.comp_factor);
        assert!(IBMFL.comm_factor < OURS.comm_factor);
        assert!(OURS.comm_factor < FLARE.comm_factor);
        assert!(!FLARE.server_multiplies); // the client-side weighting shortcut
    }
}
