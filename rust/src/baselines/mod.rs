//! Baselines the paper compares against: pairwise-mask secure aggregation
//! (Bonawitz et al.), calibrated cost models of the other HE-FL frameworks
//! (Table 8 / Fig. 2), and parameter-efficiency compressors (Table 5).

pub mod comparators;
pub mod param_efficiency;
pub mod secagg;
