//! Pairwise-mask secure aggregation (Bonawitz et al. 2017) — the non-HE
//! baseline of Table 1.
//!
//! Every client pair (i, j) derives a shared mask stream from a common seed;
//! client i adds it, client j subtracts it, so the server's sum telescopes
//! to the true aggregate while individual updates stay hidden. The protocol
//! needs an interactive seed-agreement round and breaks under dropout unless
//! survivors run a seed-recovery round — exactly the operational weaknesses
//! (Table 1 "Interactive Sync" / "Client Dropout") that motivate HE.

use crate::crypto::prng::ChaChaRng;

/// Shared pairwise seeds (the output of the interactive agreement round —
/// here derived from a session seed; in production, Diffie–Hellman).
pub struct SecAggSession {
    pub n_clients: usize,
    session_seed: u64,
}

impl SecAggSession {
    pub fn new(n_clients: usize, session_seed: u64) -> Self {
        SecAggSession {
            n_clients,
            session_seed,
        }
    }

    fn pair_stream(&self, i: usize, j: usize, len: usize) -> Vec<f32> {
        let (lo, hi) = if i < j { (i, j) } else { (j, i) };
        let mut rng = ChaChaRng::from_seed(
            self.session_seed ^ ((lo as u64) << 32 | hi as u64),
            0xA5A5,
        );
        (0..len).map(|_| (rng.uniform_f64() as f32 - 0.5) * 2.0).collect()
    }

    /// Client i's masked update: x + Σ_{j>i} m_ij − Σ_{j<i} m_ji.
    pub fn mask(&self, client: usize, update: &[f32]) -> Vec<f32> {
        let mut out = update.to_vec();
        for j in 0..self.n_clients {
            if j == client {
                continue;
            }
            let stream = self.pair_stream(client, j, update.len());
            let sign = if client < j { 1.0 } else { -1.0 };
            for (o, m) in out.iter_mut().zip(stream.iter()) {
                *o += sign * m;
            }
        }
        out
    }

    /// Server aggregation: a plain sum of the masked updates. Correct only
    /// if every registered client submitted (dropout breaks it).
    pub fn aggregate(&self, masked: &[Vec<f32>]) -> Vec<f32> {
        let len = masked[0].len();
        let mut out = vec![0.0f32; len];
        for m in masked {
            for (o, &v) in out.iter_mut().zip(m.iter()) {
                *o += v;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masks_cancel_with_full_participation() {
        let n = 5;
        let s = SecAggSession::new(n, 99);
        let updates: Vec<Vec<f32>> = (0..n).map(|c| vec![c as f32 + 1.0; 64]).collect();
        let masked: Vec<Vec<f32>> = updates
            .iter()
            .enumerate()
            .map(|(i, u)| s.mask(i, u))
            .collect();
        let agg = s.aggregate(&masked);
        let expected: f32 = (1..=n).map(|v| v as f32).sum();
        for &v in &agg {
            assert!((v - expected).abs() < 1e-3, "{v} vs {expected}");
        }
        // individual masked updates are far from the raw updates
        for (i, m) in masked.iter().enumerate() {
            let dist: f32 = m
                .iter()
                .zip(updates[i].iter())
                .map(|(a, b)| (a - b).abs())
                .sum();
            assert!(dist > 1.0, "client {i} insufficiently masked");
        }
    }

    #[test]
    fn dropout_corrupts_aggregate() {
        // The Table-1 fragility: drop one client and the sum is garbage.
        let n = 4;
        let s = SecAggSession::new(n, 7);
        let updates: Vec<Vec<f32>> = (0..n).map(|_| vec![1.0f32; 32]).collect();
        let mut masked: Vec<Vec<f32>> = updates
            .iter()
            .enumerate()
            .map(|(i, u)| s.mask(i, u))
            .collect();
        masked.pop(); // client 3 drops
        let agg = s.aggregate(&masked);
        let err: f32 = agg.iter().map(|&v| (v - 3.0).abs()).sum::<f32>() / 32.0;
        assert!(err > 0.5, "dropout should corrupt the sum (err {err})");
    }
}
