//! Parameter-efficiency techniques applied before HE (Table 5).
//!
//! * DoubleSqueeze-style top-k sparsification (Tang et al. 2019): ship only
//!   the k largest-magnitude update coordinates (index + value), with local
//!   error feedback;
//! * LoRA-style low-rank factors (Hu et al. 2021): for fine-tuning, only
//!   rank-r adapter weights are shared — modeled by its update-size factor.

/// Top-k sparsified update: coordinate indices + values.
#[derive(Debug, Clone)]
pub struct TopKUpdate {
    pub total: usize,
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

impl TopKUpdate {
    /// Wire size: 4 B index + 4 B value per kept coordinate.
    pub fn wire_bytes(&self) -> u64 {
        8 * self.indices.len() as u64
    }

    /// Densify back to a full vector (zeros elsewhere).
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.total];
        for (&i, &v) in self.indices.iter().zip(self.values.iter()) {
            out[i as usize] = v;
        }
        out
    }
}

/// Compress to the k largest-magnitude coordinates; returns the update and
/// the residual (error feedback for the next round, as in DoubleSqueeze).
pub fn top_k(update: &[f32], k: usize) -> (TopKUpdate, Vec<f32>) {
    let k = k.min(update.len());
    let mut idx: Vec<u32> = (0..update.len() as u32).collect();
    idx.select_nth_unstable_by(k.saturating_sub(1).min(update.len() - 1), |&a, &b| {
        update[b as usize]
            .abs()
            .partial_cmp(&update[a as usize].abs())
            .unwrap()
    });
    let mut kept: Vec<u32> = idx[..k].to_vec();
    kept.sort_unstable();
    let values: Vec<f32> = kept.iter().map(|&i| update[i as usize]).collect();
    let mut residual = update.to_vec();
    for &i in &kept {
        residual[i as usize] = 0.0;
    }
    (
        TopKUpdate {
            total: update.len(),
            indices: kept,
            values,
        },
        residual,
    )
}

/// LoRA update-size model: parameters shipped for rank-r adapters on a
/// transformer with `d_model`, `n_layers` and `n_matrices` adapted matrices
/// per layer (each d×d → 2·d·r).
pub fn lora_params(d_model: u64, n_layers: u64, n_matrices: u64, rank: u64) -> u64 {
    n_layers * n_matrices * 2 * d_model * rank
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top_k_keeps_largest() {
        let u = vec![0.1f32, -5.0, 0.2, 3.0, -0.05, 1.0];
        let (t, residual) = top_k(&u, 2);
        assert_eq!(t.indices, vec![1, 3]);
        assert_eq!(t.values, vec![-5.0, 3.0]);
        assert_eq!(t.wire_bytes(), 16);
        let dense = t.to_dense();
        assert_eq!(dense[1], -5.0);
        assert_eq!(dense[0], 0.0);
        // residual holds the dropped mass
        assert_eq!(residual[1], 0.0);
        assert_eq!(residual[0], 0.1);
    }

    #[test]
    fn error_feedback_conserves_signal() {
        let u: Vec<f32> = (0..100).map(|i| (i as f32 * 0.7).sin()).collect();
        let (t, residual) = top_k(&u, 30);
        let dense = t.to_dense();
        for i in 0..100 {
            assert!((dense[i] + residual[i] - u[i]).abs() < 1e-7);
        }
    }

    #[test]
    fn table5_resnet18_reduction() {
        // Paper Table 5: ResNet-18 (12 M) with k = 1,000,000 → 19.03 MB
        // ciphertext after optimization. Our k=1M ciphertext size:
        let ctx = crate::ckks::CkksParams::new(8192, 4, 52).unwrap();
        let k = 1_000_000u64;
        let cts = k.div_ceil((ctx.n / 2) as u64);
        let bytes = cts * ctx.ciphertext_bytes() as u64;
        let mb = bytes as f64 / (1024.0 * 1024.0);
        // same order as the paper's 19.03 MB (they serialize slightly
        // differently); must be far below the 796 MB unoptimized ciphertext
        assert!((40.0..80.0).contains(&mb), "{mb} MB");
        assert!(mb < 796.70 / 8.0);
    }

    #[test]
    fn lora_sizes() {
        // BERT-base-ish: d=768, 12 layers, 2 adapted matrices, r=8
        let p = lora_params(768, 12, 2, 8);
        assert_eq!(p, 294_912);
        // ~0.3% of the 110 M full model
        assert!((p as f64) < 0.005 * 110e6);
    }
}
