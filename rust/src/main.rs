//! `fedml-he` — CLI launcher for the FedML-HE reproduction.
//!
//! Subcommands are registered as they are implemented; run with no arguments
//! for usage.

use fedml_he::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    fedml_he::dispatch(args)
}
