//! Real TCP transport behind the [`crate::agg_engine`] `Arrival` intake.
//!
//! The paper frames its overhead numbers as wire-transfer costs over real
//! links (Appendix D.5, Fig. 8); until this module, the aggregation intake
//! was only ever fed from an in-process vector built by the simulator. Here
//! the client/server boundary is a real socket:
//!
//! * [`frame`] — the length-framed binary protocol: magic + version +
//!   round id + CRC'd frames, with strict malformed-input validation
//!   (truncation, oversized declared lengths, version skew, garbage CRC all
//!   return `Err`, and no attacker-controlled length drives an allocation).
//! * [`client`] — the upload driver: streams ciphertext chunks through a
//!   bounded write buffer, either from an already-encrypted update or
//!   **while later chunks are still being encrypted** by the parallel
//!   [`crate::he_agg::SelectiveCodec`] worker pool.
//! * [`intake`] — the multi-client server: concurrent per-connection worker
//!   threads reassemble updates and stamp them with wall-clock receive
//!   times; completed uploads become true [`crate::agg_engine::Arrival`]s
//!   driving the existing quorum/straggler policy, and a mid-upload
//!   disconnect is absorbed as a dropped straggler — never a panic or a
//!   poisoned round.
//! * [`session`] — persistent duplex sessions (DESIGN.md §9): one
//!   long-lived connection per client for the whole task, with a real
//!   downlink broadcast (mask + per-round partially-encrypted aggregate as
//!   frames), HELLO/WELCOME slot handshakes with rejoin, and per-round
//!   upload collection feeding the same streaming-engine intake. This is
//!   the transport behind `--transport tcp` and the multi-process
//!   `serve`/`join` subcommands. Under `--wire-auth mac` (DESIGN.md §12)
//!   the handshake runs a keyed challenge/response and every session
//!   frame carries a truncated keyed-hash tag + monotone sequence number
//!   (replay rejection).
//! * [`hub`] — the sharded epoll reactor backend (DESIGN.md §13): the same
//!   session protocol as [`session`], served readiness-driven from a fixed
//!   thread pool ([`machine`] holds the per-session nonblocking state
//!   machines, [`reactor`] the epoll/eventfd syscall surface). Selected
//!   with `--transport-backend hub`; thousands of concurrent sessions cost
//!   buffers, not threads.
//! * [`chaos`] — deterministic fault injection between the frame codec and
//!   the socket (seeded drop/corrupt/delay/duplicate/disconnect schedules)
//!   for the adversarial transport harness in `crate::attacks`.
//!
//! Ciphertext frame payloads reuse the per-shard wire views of
//! [`crate::ckks::serialize`] (a CT frame is a full-limb-range shard view,
//! serialized straight into the frame buffer), so a loopback round is
//! byte-identical to the simulator's accounting and bitwise-identical in its
//! aggregate. The coordinator selects the path with `--transport {sim,tcp}`
//! (`--listen`/`--connect` pick the socket addresses); see DESIGN.md §8 for
//! the frame diagram, arrival-stamp semantics and failure matrix.

pub mod chaos;
pub mod client;
pub mod frame;
pub mod hub;
pub mod intake;
pub(crate) mod machine;
pub(crate) mod reactor;
pub(crate) mod reassembly;
pub mod session;

pub use chaos::{ChaosConfig, ChaosWriter};
pub use hub::{ReactorHub, TransportHub};
pub use client::{
    connect_with_backoff, upload_encrypt_streaming, upload_partial_then_disconnect,
    upload_update, UploadConfig, UploadReceipt,
};
pub use frame::{
    crc32, frame_payload_cap, mask_payload_cap, read_frame, read_frame_into, write_frame,
    DownBegin, Frame, FrameKind, CONTROL_ROUND, MASK_ROUND,
};
pub use intake::{
    IntakeConfig, IntakeOutcome, TcpIntake, UpdateShape, UNIDENTIFIED_CLIENT,
};
pub use session::{
    query_stats, ClientSession, DownlinkOutcome, PeerSession, RoundDownlink, SessionHub,
    SessionOpts, STATS_REPLY_MAX_BYTES,
};
