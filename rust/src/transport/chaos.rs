//! Deterministic fault injection between the frame codec and the socket
//! (DESIGN.md §12).
//!
//! [`ChaosWriter`] wraps any byte sink a [`super::client::FrameSink`] (or a
//! raw test writer) flushes into, reassembles the byte stream into whole
//! wire frames using only the public frame layout (length field at a fixed
//! header offset), and applies a seeded schedule of faults per frame:
//! **drop**, **corrupt** (single byte flip), **delay**, **duplicate**, and
//! a one-shot mid-frame **disconnect**. Every decision comes from a
//! [`ChaChaRng`] keyed by the schedule seed, so a failing adversarial run
//! replays exactly from its seed.
//!
//! Two deliberate properties keep injected faults *semantically* visible
//! instead of degenerating into stream desync:
//!
//! * corruption never touches the header length field, so the receiver
//!   still parses frame boundaries and the damage surfaces as a CRC or
//!   MAC reject (counted) rather than a garbled stream;
//! * duplication re-sends the exact wire bytes — under `--wire-auth mac`
//!   that is precisely a replayed frame, which the receiver's monotone
//!   auth-sequence check must discard.

use crate::crypto::prng::ChaChaRng;
use crate::obs::metrics;
use std::io::Write;

use super::frame::{AUTH_TRAILER_BYTES, FRAME_HEADER_BYTES, FRAME_TRAILER_BYTES};

/// Byte offset of the little-endian payload-length field in the header.
const LEN_OFFSET: usize = 24;
/// Byte offset of the round id in the header (for `only_round` targeting).
const ROUND_OFFSET: usize = 8;

/// A seeded per-frame fault schedule. Rates are per-mille (0..=1000) and
/// evaluated in a fixed order (drop, corrupt, duplicate, delay) with at
/// most one fault per frame; `disconnect_at_frame` takes precedence over
/// everything when its eligible-frame index comes up.
#[derive(Debug, Clone)]
pub struct ChaosConfig {
    /// Schedule seed: same seed + same frame stream = same faults.
    pub seed: u64,
    /// Probability (‰) an eligible frame is silently not written.
    pub drop_per_mille: u16,
    /// Probability (‰) one byte of an eligible frame is flipped.
    pub corrupt_per_mille: u16,
    /// Probability (‰) an eligible frame's exact bytes are written twice.
    pub duplicate_per_mille: u16,
    /// Probability (‰) an eligible frame is delayed by [`Self::delay_ms`].
    pub delay_per_mille: u16,
    /// Delay applied by a delay fault, in milliseconds.
    pub delay_ms: u64,
    /// After writing half of the Nth *eligible* frame, sever the
    /// connection: invoke the disconnect hook and fail the write.
    pub disconnect_at_frame: Option<u64>,
    /// Number of leading frames exempt from all faults (lets handshake
    /// and mask-stage traffic through untouched).
    pub immune_prefix: u64,
    /// When set, only frames stamped with this round id are eligible —
    /// robust targeting of e.g. "round 0 uploads" regardless of how many
    /// handshake/mask frames precede them.
    pub only_round: Option<u64>,
    /// Whether frames on this stream carry the 12-byte auth trailer
    /// (`--wire-auth mac`) — needed to compute frame boundaries.
    pub authed: bool,
}

impl ChaosConfig {
    /// A schedule that injects nothing (passthrough).
    pub fn passthrough(seed: u64) -> Self {
        ChaosConfig {
            seed,
            drop_per_mille: 0,
            corrupt_per_mille: 0,
            duplicate_per_mille: 0,
            delay_per_mille: 0,
            delay_ms: 0,
            disconnect_at_frame: None,
            immune_prefix: 0,
            only_round: None,
            authed: false,
        }
    }
}

enum Fault {
    Pass,
    Drop,
    Corrupt,
    Duplicate,
    Delay,
}

/// The interposed sink. Buffers bytes until a whole frame is available,
/// rolls the schedule, then forwards (or drops/mauls/replays) the frame.
pub struct ChaosWriter<W: Write> {
    inner: W,
    cfg: ChaosConfig,
    rng: ChaChaRng,
    buf: Vec<u8>,
    /// Total frames seen (for `immune_prefix`).
    frames_seen: u64,
    /// Eligible frames seen (for `disconnect_at_frame`).
    eligible_seen: u64,
    /// Invoked when the disconnect fault fires — typically shuts down the
    /// underlying `TcpStream` both ways so the reader side dies too.
    on_disconnect: Option<Box<dyn FnMut() + Send>>,
    /// Set after the disconnect fault: every later write fails.
    severed: bool,
}

impl<W: Write> ChaosWriter<W> {
    pub fn new(inner: W, cfg: ChaosConfig) -> Self {
        let rng = ChaChaRng::from_seed(cfg.seed, u64::from_le_bytes(*b"chaoswr\0"));
        ChaosWriter {
            inner,
            cfg,
            rng,
            buf: Vec::new(),
            frames_seen: 0,
            eligible_seen: 0,
            on_disconnect: None,
            severed: false,
        }
    }

    /// Register the hook the disconnect fault fires (e.g. a
    /// `TcpStream::shutdown` on a clone of the socket).
    pub fn on_disconnect(mut self, hook: Box<dyn FnMut() + Send>) -> Self {
        self.on_disconnect = Some(hook);
        self
    }

    /// Wire length of the frame starting at `buf[0]`, once the header is
    /// complete; `None` until enough bytes have arrived.
    fn frame_len(&self) -> Option<usize> {
        if self.buf.len() < FRAME_HEADER_BYTES {
            return None;
        }
        let len = u32::from_le_bytes(self.buf[LEN_OFFSET..LEN_OFFSET + 4].try_into().unwrap())
            as usize;
        let trailer = if self.cfg.authed { AUTH_TRAILER_BYTES } else { 0 };
        Some(FRAME_HEADER_BYTES + len + FRAME_TRAILER_BYTES + trailer)
    }

    fn roll(&mut self, per_mille: u16) -> bool {
        per_mille > 0 && self.rng.next_u64() % 1000 < u64::from(per_mille)
    }

    /// Apply the schedule to one complete frame held in `frame`.
    fn emit(&mut self, frame: &[u8]) -> std::io::Result<()> {
        let idx = self.frames_seen;
        self.frames_seen += 1;
        let round =
            u64::from_le_bytes(frame[ROUND_OFFSET..ROUND_OFFSET + 8].try_into().unwrap());
        let round_ok = match self.cfg.only_round {
            Some(r) => r == round,
            None => true,
        };
        let eligible = idx >= self.cfg.immune_prefix && round_ok;
        if !eligible {
            return self.inner.write_all(frame);
        }
        let eidx = self.eligible_seen;
        self.eligible_seen += 1;
        if self.cfg.disconnect_at_frame == Some(eidx) {
            metrics::chaos_injected();
            self.inner.write_all(&frame[..frame.len() / 2])?;
            self.inner.flush().ok();
            if let Some(hook) = self.on_disconnect.as_mut() {
                hook();
            }
            self.severed = true;
            return Err(std::io::Error::new(
                std::io::ErrorKind::ConnectionReset,
                "chaos: injected mid-frame disconnect",
            ));
        }
        let fault = if self.roll(self.cfg.drop_per_mille) {
            Fault::Drop
        } else if self.roll(self.cfg.corrupt_per_mille) {
            Fault::Corrupt
        } else if self.roll(self.cfg.duplicate_per_mille) {
            Fault::Duplicate
        } else if self.roll(self.cfg.delay_per_mille) {
            Fault::Delay
        } else {
            Fault::Pass
        };
        match fault {
            Fault::Pass => self.inner.write_all(frame),
            Fault::Drop => {
                metrics::chaos_injected();
                Ok(())
            }
            Fault::Corrupt => {
                metrics::chaos_injected();
                // flip one byte anywhere except the length field, so the
                // receiver keeps frame sync and rejects via MAC/CRC
                let eligible_bytes = frame.len() - 4;
                let mut pos = (self.rng.next_u64() % eligible_bytes as u64) as usize;
                if pos >= LEN_OFFSET {
                    pos += 4;
                }
                let mut mauled = frame.to_vec();
                mauled[pos] ^= 1 << (self.rng.next_u64() % 8);
                self.inner.write_all(&mauled)
            }
            Fault::Duplicate => {
                metrics::chaos_injected();
                self.inner.write_all(frame)?;
                self.inner.write_all(frame)
            }
            Fault::Delay => {
                metrics::chaos_injected();
                std::thread::sleep(std::time::Duration::from_millis(self.cfg.delay_ms));
                self.inner.write_all(frame)
            }
        }
    }
}

impl<W: Write> Write for ChaosWriter<W> {
    fn write(&mut self, bytes: &[u8]) -> std::io::Result<usize> {
        if self.severed {
            return Err(std::io::Error::new(
                std::io::ErrorKind::ConnectionReset,
                "chaos: connection severed",
            ));
        }
        self.buf.extend_from_slice(bytes);
        while let Some(total) = self.frame_len() {
            if self.buf.len() < total {
                break;
            }
            let frame: Vec<u8> = self.buf.drain(..total).collect();
            self.emit(&frame)?;
        }
        Ok(bytes.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        if self.severed {
            return Err(std::io::Error::new(
                std::io::ErrorKind::ConnectionReset,
                "chaos: connection severed",
            ));
        }
        self.inner.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::frame::{write_frame, FrameKind};

    fn frames(n: usize, round: u64) -> Vec<u8> {
        let mut out = Vec::new();
        for i in 0..n {
            let payload = vec![i as u8; 24];
            write_frame(&mut out, round, FrameKind::Plain, i as u32, &payload).unwrap();
        }
        out
    }

    fn drive(cfg: ChaosConfig, wire: &[u8]) -> std::io::Result<Vec<u8>> {
        let mut out = Vec::new();
        let mut w = ChaosWriter::new(&mut out, cfg);
        // feed in awkward chunk sizes to exercise reassembly
        for chunk in wire.chunks(13) {
            w.write_all(chunk)?;
        }
        w.flush()?;
        drop(w);
        Ok(out)
    }

    #[test]
    fn passthrough_is_byte_identical_in_any_chunking() {
        let wire = frames(5, 3);
        let out = drive(ChaosConfig::passthrough(9), &wire).unwrap();
        assert_eq!(out, wire);
    }

    #[test]
    fn drop_removes_eligible_frames_only() {
        let wire = frames(4, 0);
        let one = frames(1, 0);
        let cfg = ChaosConfig {
            drop_per_mille: 1000,
            immune_prefix: 1,
            ..ChaosConfig::passthrough(1)
        };
        let out = drive(cfg, &wire).unwrap();
        assert_eq!(out, one, "only the immune first frame survives");
    }

    #[test]
    fn only_round_filter_protects_other_rounds() {
        let mut wire = frames(2, 0);
        wire.extend_from_slice(&frames(2, 1));
        let cfg = ChaosConfig {
            drop_per_mille: 1000,
            only_round: Some(1),
            ..ChaosConfig::passthrough(2)
        };
        let out = drive(cfg, &wire).unwrap();
        assert_eq!(out, frames(2, 0), "round-0 frames untouched, round-1 dropped");
    }

    #[test]
    fn corruption_preserves_frame_boundaries() {
        let wire = frames(6, 0);
        let cfg = ChaosConfig {
            corrupt_per_mille: 1000,
            ..ChaosConfig::passthrough(3)
        };
        let out = drive(cfg, &wire).unwrap();
        assert_eq!(out.len(), wire.len());
        assert_ne!(out, wire, "every frame took a byte flip");
        // every length field intact → receiver keeps frame sync
        let mut off = 0;
        while off < out.len() {
            assert_eq!(out[off + 24..off + 28], wire[off + 24..off + 28]);
            let len =
                u32::from_le_bytes(out[off + 24..off + 28].try_into().unwrap()) as usize;
            off += 28 + len + 4;
        }
        assert_eq!(off, out.len());
    }

    #[test]
    fn duplicate_replays_exact_wire_bytes() {
        let wire = frames(2, 0);
        let cfg = ChaosConfig {
            duplicate_per_mille: 1000,
            ..ChaosConfig::passthrough(4)
        };
        let out = drive(cfg, &wire).unwrap();
        assert_eq!(out.len(), wire.len() * 2);
        let one = frames(1, 0);
        assert_eq!(&out[..one.len()], &out[one.len()..2 * one.len()]);
    }

    #[test]
    fn disconnect_fires_hook_and_severs_the_stream() {
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;
        let wire = frames(3, 0);
        let fired = Arc::new(AtomicBool::new(false));
        let f2 = fired.clone();
        let cfg = ChaosConfig {
            disconnect_at_frame: Some(1),
            ..ChaosConfig::passthrough(5)
        };
        let mut out = Vec::new();
        let mut w = ChaosWriter::new(&mut out, cfg)
            .on_disconnect(Box::new(move || f2.store(true, Ordering::SeqCst)));
        let err = w.write_all(&wire).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::ConnectionReset);
        assert!(fired.load(Ordering::SeqCst));
        assert!(w.write_all(&[0u8; 4]).is_err(), "stream stays severed");
        let one = frames(1, 0);
        // frame 0 intact, frame 1 cut mid-frame
        assert!(out.len() > one.len() && out.len() < 2 * one.len());
    }

    #[test]
    fn same_seed_same_faults() {
        let wire = frames(16, 0);
        let cfg = ChaosConfig {
            drop_per_mille: 300,
            corrupt_per_mille: 300,
            duplicate_per_mille: 300,
            ..ChaosConfig::passthrough(77)
        };
        let a = drive(cfg.clone(), &wire).unwrap();
        let b = drive(cfg, &wire).unwrap();
        assert_eq!(a, b);
    }
}
