//! Chunked-update reassembly shared by the uplink (`intake::read_upload`)
//! and downlink (`ClientSession::recv_round`) paths.
//!
//! Both directions stream one [`crate::he_agg::EncryptedUpdate`] as
//! CT_CHUNK frames (full-limb shard views, any order, no duplicates) plus
//! in-order PLAIN frames (f32 LE), terminated by an END/DOWN_END frame.
//! The validation rules are identical, so both loops feed this assembler —
//! one instrumented, fuzz-hardened implementation instead of two
//! hand-kept copies (ROADMAP item 1 follow-up).
//!
//! [`UploadAssembly`] layers the *uplink protocol* on top: the
//! BEGIN-preamble identity/weight/shape checks and the per-frame-kind
//! dispatch that both upload collectors — the blocking
//! `intake::read_upload` and the nonblocking `machine::SessionMachine` —
//! previously hand-kept as twin loops (DESIGN.md §13). Both backends now
//! validate uploads through this one implementation, so they accept and
//! reject byte-for-byte the same streams.

use super::frame::{decode_begin, decode_end_timing, FrameKind, BEGIN_PAYLOAD_BYTES};
use super::intake::{UpdateShape, UploadFrames, UNIDENTIFIED_CLIENT};
use crate::ckks::serialize::{ciphertext_seeded_from_bytes, ciphertext_shard_from_bytes};
use crate::ckks::{Ciphertext, CkksParams, CtWire};
use crate::he_agg::EncryptedUpdate;

/// Incremental reassembly of one chunked update against a declared shape.
pub(crate) struct ChunkAssembler {
    n_plain: usize,
    total: usize,
    /// Wire format every CT_CHUNK must arrive in — pinned by the round
    /// (handshake negotiation), never inferred from the payload: a
    /// seed-compressed chunk on a dense round (or vice versa) is malformed.
    ct_wire: CtWire,
    cts: Vec<Option<Ciphertext>>,
    plain: Vec<f32>,
    next_plain_seq: u32,
}

impl ChunkAssembler {
    /// Start reassembly toward a declared `(n_cts, n_plain, total)` shape
    /// (the BEGIN/DOWN_BEGIN preamble, already validated by the caller),
    /// expecting dense full-limb shard chunks.
    pub fn new(n_cts: usize, n_plain: usize, total: usize) -> Self {
        Self::new_with_wire(n_cts, n_plain, total, CtWire::Dense)
    }

    /// [`ChunkAssembler::new`] with the round's negotiated ciphertext wire
    /// format.
    pub fn new_with_wire(n_cts: usize, n_plain: usize, total: usize, ct_wire: CtWire) -> Self {
        ChunkAssembler {
            n_plain,
            total,
            ct_wire,
            cts: (0..n_cts).map(|_| None).collect(),
            plain: Vec::with_capacity(n_plain),
            next_plain_seq: 0,
        }
    }

    /// Accept one CT_CHUNK payload: in-range seq, no duplicates, and the
    /// payload must parse in the round's pinned wire format (dense shards
    /// covering the full limb range, or seed-compressed ciphertexts —
    /// kept lazy, their `a`-part expands inside the aggregation shards).
    pub fn accept_ct(
        &mut self,
        params: &CkksParams,
        seq: u32,
        payload: &[u8],
    ) -> anyhow::Result<()> {
        let _s = crate::obs::span_arg("transport", "assemble_ct", u64::from(seq));
        let seq = seq as usize;
        anyhow::ensure!(seq < self.cts.len(), "ciphertext chunk {seq} out of range");
        anyhow::ensure!(self.cts[seq].is_none(), "duplicate ciphertext chunk {seq}");
        match self.ct_wire {
            CtWire::Dense => {
                let shard = ciphertext_shard_from_bytes(payload, params)?;
                anyhow::ensure!(
                    shard.lo == 0 && shard.hi == params.num_limbs(),
                    "ciphertext chunk must carry the full limb range, got [{}, {})",
                    shard.lo,
                    shard.hi
                );
                let mut ct = Ciphertext::zero(params);
                shard.scatter_into(&mut ct);
                self.cts[seq] = Some(ct);
            }
            CtWire::Seed => {
                self.cts[seq] = Some(ciphertext_seeded_from_bytes(payload, params)?);
            }
        }
        Ok(())
    }

    /// Accept one PLAIN payload: in-order seq, f32-aligned, within the
    /// declared value count.
    pub fn accept_plain(&mut self, seq: u32, payload: &[u8]) -> anyhow::Result<()> {
        let _s = crate::obs::span_arg("transport", "assemble_plain", u64::from(seq));
        anyhow::ensure!(
            seq == self.next_plain_seq,
            "plaintext chunk {seq} out of order (expected {})",
            self.next_plain_seq
        );
        self.next_plain_seq += 1;
        anyhow::ensure!(payload.len() % 4 == 0, "plaintext payload not f32-aligned");
        let k = payload.len() / 4;
        anyhow::ensure!(
            self.plain.len() + k <= self.n_plain,
            "plaintext remainder overflows the declared {} values",
            self.n_plain
        );
        for c in payload.chunks_exact(4) {
            self.plain.push(f32::from_le_bytes(c.try_into().unwrap()));
        }
        Ok(())
    }

    /// Seal the update (the END/DOWN_END frame arrived): every declared
    /// chunk must be present.
    pub fn finish(self) -> anyhow::Result<EncryptedUpdate> {
        anyhow::ensure!(
            self.cts.iter().all(|c| c.is_some()),
            "update sealed with missing ciphertext chunks"
        );
        anyhow::ensure!(
            self.plain.len() == self.n_plain,
            "update sealed with {} of {} plaintext values",
            self.plain.len(),
            self.n_plain
        );
        Ok(EncryptedUpdate {
            cts: self.cts.into_iter().map(|c| c.unwrap()).collect(),
            plain: self.plain,
            total: self.total,
        })
    }
}

/// End-to-end validation of one client upload: BEGIN preamble checks plus
/// the chunk/END dispatch, over a [`ChunkAssembler`]. The protocol rules —
/// reserved-id rejection, session identity pinning, assigned-weight
/// pinning, exact shape match, duplicate-BEGIN and unexpected-kind
/// rejection — live here once, shared by the blocking and reactor
/// backends.
pub(crate) struct UploadAssembly {
    client: u64,
    alpha: f64,
    asm: ChunkAssembler,
}

impl UploadAssembly {
    /// Validate a BEGIN payload and open the assembly. `expect_client`
    /// pins the identity (persistent sessions know whose socket this is),
    /// `expect_alpha` pins the server-assigned FedAvg weight, and the
    /// declared shape must match the round's server-derived shape exactly
    /// — a client can never size a server-side buffer. `seen_client` is
    /// stamped as soon as the identity validates (before the shape check),
    /// so a shape-rejected upload still settles its participant slot.
    pub fn begin(
        payload: &[u8],
        shape: UpdateShape,
        expect_client: Option<u64>,
        expect_alpha: Option<f64>,
        seen_client: &mut Option<u64>,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(
            payload.len() == BEGIN_PAYLOAD_BYTES,
            "BEGIN payload length {}",
            payload.len()
        );
        let (client, alpha, n_cts, n_plain, total) = decode_begin(payload)?;
        // rejected before the connection counts as "identified": the
        // sentinel would corrupt slot settling and straggler accounting
        anyhow::ensure!(
            client != UNIDENTIFIED_CLIENT,
            "client id {client} is reserved"
        );
        if let Some(expected) = expect_client {
            anyhow::ensure!(
                client == expected,
                "session for client {expected} sent BEGIN for client {client}"
            );
        }
        if let Some(expected) = expect_alpha {
            anyhow::ensure!(
                (alpha - expected).abs() <= 1e-9,
                "client {client} declared FedAvg weight {alpha}, round assigned {expected}"
            );
        }
        *seen_client = Some(client);
        anyhow::ensure!(
            n_cts == shape.n_cts && n_plain == shape.n_plain && total == shape.total,
            "upload shape ({n_cts} cts, {n_plain} plain, {total} total) does not match \
             the round shape ({} cts, {} plain, {} total)",
            shape.n_cts,
            shape.n_plain,
            shape.total
        );
        Ok(UploadAssembly {
            client,
            alpha,
            asm: ChunkAssembler::new_with_wire(n_cts, n_plain, total, shape.ct_wire),
        })
    }

    /// The validated identity from the BEGIN preamble.
    pub fn client(&self) -> u64 {
        self.client
    }

    /// Feed one post-BEGIN frame. Returns `Some(train, encrypt, loss)`
    /// when the END frame arrived (the upload is complete — call
    /// [`UploadAssembly::finish`]), `None` for an accepted chunk.
    pub fn accept(
        &mut self,
        params: &CkksParams,
        kind: FrameKind,
        seq: u32,
        payload: &[u8],
    ) -> anyhow::Result<Option<(f64, f64, f32)>> {
        match kind {
            FrameKind::CtChunk => {
                self.asm.accept_ct(params, seq, payload)?;
                Ok(None)
            }
            FrameKind::Plain => {
                self.asm.accept_plain(seq, payload)?;
                Ok(None)
            }
            FrameKind::End => Ok(Some(decode_end_timing(payload)?)),
            FrameKind::Begin => anyhow::bail!("duplicate BEGIN frame"),
            other => anyhow::bail!("unexpected {other:?} frame in an upload"),
        }
    }

    /// Seal the upload with the END frame's timing payload.
    pub fn finish(self, timing: (f64, f64, f32)) -> anyhow::Result<UploadFrames> {
        let update = self.asm.finish()?;
        Ok(UploadFrames {
            client: self.client,
            alpha: self.alpha,
            train_secs: timing.0,
            encrypt_secs: timing.1,
            loss: timing.2,
            update,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckks::serialize::ciphertext_shard_to_bytes;

    fn params() -> CkksParams {
        CkksParams::new(256, 3, 30).unwrap()
    }

    fn ct_bytes(p: &CkksParams) -> Vec<u8> {
        ciphertext_shard_to_bytes(&Ciphertext::zero(p), 0, p.num_limbs())
    }

    #[test]
    fn reassembles_out_of_order_cts_and_in_order_plain() {
        let p = params();
        let mut a = ChunkAssembler::new(2, 3, 100);
        a.accept_ct(&p, 1, &ct_bytes(&p)).unwrap();
        a.accept_ct(&p, 0, &ct_bytes(&p)).unwrap();
        a.accept_plain(0, &1.0f32.to_le_bytes()).unwrap();
        let mut two = Vec::new();
        two.extend_from_slice(&2.0f32.to_le_bytes());
        two.extend_from_slice(&3.0f32.to_le_bytes());
        a.accept_plain(1, &two).unwrap();
        let u = a.finish().unwrap();
        assert_eq!(u.cts.len(), 2);
        assert_eq!(u.plain, vec![1.0, 2.0, 3.0]);
        assert_eq!(u.total, 100);
    }

    #[test]
    fn rejects_malformed_sequences() {
        let p = params();
        // duplicate ct
        let mut a = ChunkAssembler::new(1, 0, 1);
        a.accept_ct(&p, 0, &ct_bytes(&p)).unwrap();
        assert!(a.accept_ct(&p, 0, &ct_bytes(&p)).is_err());
        // out-of-range ct
        let mut a = ChunkAssembler::new(1, 0, 1);
        assert!(a.accept_ct(&p, 1, &ct_bytes(&p)).is_err());
        // out-of-order plain
        let mut a = ChunkAssembler::new(0, 2, 2);
        assert!(a.accept_plain(1, &0.0f32.to_le_bytes()).is_err());
        // unaligned plain
        let mut a = ChunkAssembler::new(0, 2, 2);
        assert!(a.accept_plain(0, &[0u8; 3]).is_err());
        // plain overflow
        let mut a = ChunkAssembler::new(0, 1, 1);
        assert!(a.accept_plain(0, &[0u8; 8]).is_err());
        // incomplete at seal: missing ct, then missing plain
        let a = ChunkAssembler::new(1, 0, 1);
        assert!(a.finish().is_err());
        let a = ChunkAssembler::new(0, 1, 1);
        assert!(a.finish().is_err());
    }

    #[test]
    fn upload_assembly_runs_the_full_protocol() {
        use crate::transport::frame::{encode_begin, encode_end_timing};
        let p = params();
        let shape = UpdateShape {
            n_cts: 1,
            n_plain: 2,
            total: 10,
            ct_wire: CtWire::Dense,
        };
        let begin = encode_begin(5, 0.5, 1, 2, 10);
        let mut seen = None;
        let mut a =
            UploadAssembly::begin(&begin, shape, Some(5), Some(0.5), &mut seen).unwrap();
        assert_eq!(seen, Some(5));
        assert_eq!(a.client(), 5);
        assert!(a.accept(&p, FrameKind::CtChunk, 0, &ct_bytes(&p)).unwrap().is_none());
        let mut two = Vec::new();
        two.extend_from_slice(&1.0f32.to_le_bytes());
        two.extend_from_slice(&2.0f32.to_le_bytes());
        assert!(a.accept(&p, FrameKind::Plain, 0, &two).unwrap().is_none());
        let timing = a
            .accept(&p, FrameKind::End, 0, &encode_end_timing(1.0, 0.5, 0.25))
            .unwrap()
            .unwrap();
        assert_eq!(timing, (1.0, 0.5, 0.25));
        let frames = a.finish(timing).unwrap();
        assert_eq!(frames.client, 5);
        assert_eq!(frames.alpha, 0.5);
        assert_eq!(frames.update.plain, vec![1.0, 2.0]);
    }

    #[test]
    fn upload_assembly_rejects_protocol_violations() {
        use crate::transport::frame::encode_begin;
        let p = params();
        let shape = UpdateShape {
            n_cts: 1,
            n_plain: 2,
            total: 10,
            ct_wire: CtWire::Dense,
        };

        // reserved sentinel id never identifies a session
        let mut seen = None;
        let bad = encode_begin(UNIDENTIFIED_CLIENT, 0.5, 1, 2, 10);
        assert!(UploadAssembly::begin(&bad, shape, None, None, &mut seen).is_err());
        assert_eq!(seen, None);

        // identity pinned to the session's handshake
        let mut seen = None;
        let begin = encode_begin(5, 0.5, 1, 2, 10);
        assert!(UploadAssembly::begin(&begin, shape, Some(6), None, &mut seen).is_err());
        assert_eq!(seen, None, "identity mismatch must not identify the slot");

        // skewed declared weight rejected against the assigned one
        let mut seen = None;
        assert!(UploadAssembly::begin(&begin, shape, Some(5), Some(0.25), &mut seen).is_err());

        // shape mismatch settles the slot (seen is stamped) but fails
        let mut seen = None;
        let wrong = encode_begin(5, 0.5, 2, 2, 10);
        assert!(UploadAssembly::begin(&wrong, shape, Some(5), Some(0.5), &mut seen).is_err());
        assert_eq!(seen, Some(5), "shape rejects happen after identification");

        // duplicate BEGIN and out-of-protocol kinds are fatal
        let mut seen = None;
        let mut a =
            UploadAssembly::begin(&begin, shape, None, None, &mut seen).unwrap();
        assert!(a.accept(&p, FrameKind::Begin, 0, &begin).is_err());
        let mut a = UploadAssembly::begin(&begin, shape, None, None, &mut seen).unwrap();
        assert!(a.accept(&p, FrameKind::Hello, 0, &[]).is_err());
    }

    #[test]
    fn seed_wire_chunks_parse_and_modes_do_not_mix() {
        use crate::ckks::encoding::Encoder;
        use crate::ckks::encrypt::encrypt_sym_seeded;
        use crate::ckks::keys::keygen;
        use crate::ckks::serialize::ciphertext_seeded_to_bytes;
        use crate::crypto::prng::ChaChaRng;
        let p = std::sync::Arc::new(params());
        let encoder = Encoder::new(p.clone());
        let mut rng = ChaChaRng::from_seed(33, 0);
        let (_pk, sk) = keygen(&p, &mut rng);
        let m: Vec<f64> = (0..32).map(|i| (i as f64 * 0.01).sin()).collect();
        let ct = encrypt_sym_seeded(&p, &sk, &encoder.encode(&m), m.len(), &mut rng);
        let seeded = ciphertext_seeded_to_bytes(&ct);

        // a seed-compressed chunk parses on the seed wire and stays lazy
        let mut a = ChunkAssembler::new_with_wire(1, 0, 1, CtWire::Seed);
        a.accept_ct(&p, 0, &seeded).unwrap();
        let u = a.finish().unwrap();
        assert!(u.cts[0].a_seed.is_some(), "seed wire keeps the ct lazy");

        // the wire mode is pinned by the round: a seed-compressed chunk on
        // a dense round is malformed, and a dense shard on a seed round is
        // malformed — the payload never chooses its own format
        let mut dense_round = ChunkAssembler::new(1, 0, 1);
        assert!(dense_round.accept_ct(&p, 0, &seeded).is_err());
        let mut seed_round = ChunkAssembler::new_with_wire(1, 0, 1, CtWire::Seed);
        assert!(seed_round.accept_ct(&p, 0, &ct_bytes(&p)).is_err());

        // a truncated seed-compressed chunk is rejected
        let mut short = seeded.clone();
        short.truncate(seeded.len() - 1);
        let mut a = ChunkAssembler::new_with_wire(1, 0, 1, CtWire::Seed);
        assert!(a.accept_ct(&p, 0, &short).is_err());
    }
}
