//! Chunked-update reassembly shared by the uplink (`intake::read_upload`)
//! and downlink (`ClientSession::recv_round`) paths.
//!
//! Both directions stream one [`crate::he_agg::EncryptedUpdate`] as
//! CT_CHUNK frames (full-limb shard views, any order, no duplicates) plus
//! in-order PLAIN frames (f32 LE), terminated by an END/DOWN_END frame.
//! The validation rules are identical, so both loops feed this assembler —
//! one instrumented, fuzz-hardened implementation instead of two
//! hand-kept copies (ROADMAP item 1 follow-up).

use crate::ckks::serialize::ciphertext_shard_from_bytes;
use crate::ckks::{Ciphertext, CkksParams};
use crate::he_agg::EncryptedUpdate;

/// Incremental reassembly of one chunked update against a declared shape.
pub(crate) struct ChunkAssembler {
    n_plain: usize,
    total: usize,
    cts: Vec<Option<Ciphertext>>,
    plain: Vec<f32>,
    next_plain_seq: u32,
}

impl ChunkAssembler {
    /// Start reassembly toward a declared `(n_cts, n_plain, total)` shape
    /// (the BEGIN/DOWN_BEGIN preamble, already validated by the caller).
    pub fn new(n_cts: usize, n_plain: usize, total: usize) -> Self {
        ChunkAssembler {
            n_plain,
            total,
            cts: (0..n_cts).map(|_| None).collect(),
            plain: Vec::with_capacity(n_plain),
            next_plain_seq: 0,
        }
    }

    /// Accept one CT_CHUNK payload: in-range seq, no duplicates, and the
    /// shard must cover the full limb range.
    pub fn accept_ct(
        &mut self,
        params: &CkksParams,
        seq: u32,
        payload: &[u8],
    ) -> anyhow::Result<()> {
        let _s = crate::obs::span_arg("transport", "assemble_ct", u64::from(seq));
        let seq = seq as usize;
        anyhow::ensure!(seq < self.cts.len(), "ciphertext chunk {seq} out of range");
        anyhow::ensure!(self.cts[seq].is_none(), "duplicate ciphertext chunk {seq}");
        let shard = ciphertext_shard_from_bytes(payload, params)?;
        anyhow::ensure!(
            shard.lo == 0 && shard.hi == params.num_limbs(),
            "ciphertext chunk must carry the full limb range, got [{}, {})",
            shard.lo,
            shard.hi
        );
        let mut ct = Ciphertext::zero(params);
        shard.scatter_into(&mut ct);
        self.cts[seq] = Some(ct);
        Ok(())
    }

    /// Accept one PLAIN payload: in-order seq, f32-aligned, within the
    /// declared value count.
    pub fn accept_plain(&mut self, seq: u32, payload: &[u8]) -> anyhow::Result<()> {
        let _s = crate::obs::span_arg("transport", "assemble_plain", u64::from(seq));
        anyhow::ensure!(
            seq == self.next_plain_seq,
            "plaintext chunk {seq} out of order (expected {})",
            self.next_plain_seq
        );
        self.next_plain_seq += 1;
        anyhow::ensure!(payload.len() % 4 == 0, "plaintext payload not f32-aligned");
        let k = payload.len() / 4;
        anyhow::ensure!(
            self.plain.len() + k <= self.n_plain,
            "plaintext remainder overflows the declared {} values",
            self.n_plain
        );
        for c in payload.chunks_exact(4) {
            self.plain.push(f32::from_le_bytes(c.try_into().unwrap()));
        }
        Ok(())
    }

    /// Seal the update (the END/DOWN_END frame arrived): every declared
    /// chunk must be present.
    pub fn finish(self) -> anyhow::Result<EncryptedUpdate> {
        anyhow::ensure!(
            self.cts.iter().all(|c| c.is_some()),
            "update sealed with missing ciphertext chunks"
        );
        anyhow::ensure!(
            self.plain.len() == self.n_plain,
            "update sealed with {} of {} plaintext values",
            self.plain.len(),
            self.n_plain
        );
        Ok(EncryptedUpdate {
            cts: self.cts.into_iter().map(|c| c.unwrap()).collect(),
            plain: self.plain,
            total: self.total,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckks::serialize::ciphertext_shard_to_bytes;

    fn params() -> CkksParams {
        CkksParams::new(256, 3, 30).unwrap()
    }

    fn ct_bytes(p: &CkksParams) -> Vec<u8> {
        ciphertext_shard_to_bytes(&Ciphertext::zero(p), 0, p.num_limbs())
    }

    #[test]
    fn reassembles_out_of_order_cts_and_in_order_plain() {
        let p = params();
        let mut a = ChunkAssembler::new(2, 3, 100);
        a.accept_ct(&p, 1, &ct_bytes(&p)).unwrap();
        a.accept_ct(&p, 0, &ct_bytes(&p)).unwrap();
        a.accept_plain(0, &1.0f32.to_le_bytes()).unwrap();
        let mut two = Vec::new();
        two.extend_from_slice(&2.0f32.to_le_bytes());
        two.extend_from_slice(&3.0f32.to_le_bytes());
        a.accept_plain(1, &two).unwrap();
        let u = a.finish().unwrap();
        assert_eq!(u.cts.len(), 2);
        assert_eq!(u.plain, vec![1.0, 2.0, 3.0]);
        assert_eq!(u.total, 100);
    }

    #[test]
    fn rejects_malformed_sequences() {
        let p = params();
        // duplicate ct
        let mut a = ChunkAssembler::new(1, 0, 1);
        a.accept_ct(&p, 0, &ct_bytes(&p)).unwrap();
        assert!(a.accept_ct(&p, 0, &ct_bytes(&p)).is_err());
        // out-of-range ct
        let mut a = ChunkAssembler::new(1, 0, 1);
        assert!(a.accept_ct(&p, 1, &ct_bytes(&p)).is_err());
        // out-of-order plain
        let mut a = ChunkAssembler::new(0, 2, 2);
        assert!(a.accept_plain(1, &0.0f32.to_le_bytes()).is_err());
        // unaligned plain
        let mut a = ChunkAssembler::new(0, 2, 2);
        assert!(a.accept_plain(0, &[0u8; 3]).is_err());
        // plain overflow
        let mut a = ChunkAssembler::new(0, 1, 1);
        assert!(a.accept_plain(0, &[0u8; 8]).is_err());
        // incomplete at seal: missing ct, then missing plain
        let a = ChunkAssembler::new(1, 0, 1);
        assert!(a.finish().is_err());
        let a = ChunkAssembler::new(0, 1, 1);
        assert!(a.finish().is_err());
    }
}
