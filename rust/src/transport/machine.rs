//! Pure protocol state machines for the reactor session hub (DESIGN.md
//! §13): bytes in, protocol steps out — no sockets, threads, or timers.
//!
//! The blocking backends drive the wire with `Read`/`Write` calls that park
//! a thread per connection. The reactor backend cannot park, so the
//! protocol logic is split out here into buffer-in/buffer-out machines the
//! shard loops drive from readiness events:
//!
//! * [`FrameDecoder`] — reassembles complete wire frames from arbitrary
//!   read boundaries (partial reads land mid-header or mid-payload under
//!   chaos) and validates each one through
//!   [`super::frame::validate_wire_frame`], preserving the blocking
//!   reader's semantics exactly: MAC before trust, counted soft rejects
//!   for forged/replayed frames with the stream kept aligned, hard errors
//!   for malformed framing.
//! * [`SessionMachine`] — the server side of one session: HELLO/WELCOME
//!   registration, the `--wire-auth mac` CHALLENGE/CHALLENGE_RESP proof,
//!   STATS probes, and round upload reassembly via
//!   [`super::reassembly::UploadAssembly`]. Each [`Step`] tells the
//!   driving shard what to enqueue (challenge, welcome, ACK) or deliver
//!   (a completed upload); everything stateful about *when* bytes arrive
//!   stays in the driver.

use super::frame::{
    decode_challenge_resp, decode_hello, frame_declared_len, validate_wire_frame, FrameKind,
    RxAuth, TxAuth, WireVerdict, AUTH_DIR_DOWN, AUTH_DIR_UP, AUTH_TRAILER_BYTES, CONTROL_ROUND,
    FRAME_HEADER_BYTES, FRAME_TRAILER_BYTES, MAX_CONSECUTIVE_AUTH_REJECTS,
};
use super::intake::{UpdateShape, UploadFrames, UNIDENTIFIED_CLIENT};
use super::reassembly::UploadAssembly;
use crate::ckks::{CkksParams, CtWire};
use crate::crypto::mac::{self, MacKey};
use std::ops::Range;

/// Incremental frame reassembly over arbitrary read boundaries. Bytes are
/// [`FrameDecoder::push`]ed as they arrive; [`FrameDecoder::next_frame`]
/// yields one validated frame at a time. The declared payload length is
/// the only header field read before validation, and it is capped before
/// the frame is ever buffered whole — a hostile length can never force an
/// unbounded allocation, mirroring the blocking reader.
pub(crate) struct FrameDecoder {
    cap: usize,
    buf: Vec<u8>,
    /// Consumed prefix of `buf` (compacted on the next push).
    start: usize,
    /// Consecutive auth/replay soft rejects (bounded like the blocking
    /// reader, across `next_frame` calls).
    rejected: usize,
    /// A parse attempt stalled mid-frame — the next push is a partial-read
    /// resumption.
    mid_frame: bool,
}

impl FrameDecoder {
    pub fn new(cap: usize) -> Self {
        FrameDecoder {
            cap,
            buf: Vec::new(),
            start: 0,
            rejected: 0,
            mid_frame: false,
        }
    }

    /// Unparsed byte count currently buffered.
    pub fn pending(&self) -> usize {
        self.buf.len() - self.start
    }

    /// Append freshly-read bytes (any boundary — mid-header is fine).
    pub fn push(&mut self, bytes: &[u8]) {
        if self.mid_frame {
            crate::obs::metrics::hub_partial_read();
            self.mid_frame = false;
        }
        if self.start > 0 {
            self.buf.drain(..self.start);
            self.start = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Decode the next complete frame: `Some((round, kind, seq, payload))`
    /// on accept (`payload` indexes this decoder via
    /// [`FrameDecoder::bytes`], valid until the next push), `None` when
    /// more bytes are needed. Auth/replay soft rejects are discarded
    /// internally — bounded by [`MAX_CONSECUTIVE_AUTH_REJECTS`] — and
    /// malformed framing is a hard error that kills the connection.
    pub fn next_frame(
        &mut self,
        rx: &mut Option<RxAuth>,
    ) -> anyhow::Result<Option<(u64, FrameKind, u32, Range<usize>)>> {
        loop {
            let pending = &self.buf[self.start..];
            if pending.len() < FRAME_HEADER_BYTES {
                self.mid_frame = !pending.is_empty();
                return Ok(None);
            }
            let len = frame_declared_len(pending);
            if len > self.cap {
                crate::obs::metrics::frame_reject();
                anyhow::bail!("declared payload length {len} exceeds cap {}", self.cap);
            }
            let auth_extra = if rx.is_some() { AUTH_TRAILER_BYTES } else { 0 };
            let total = FRAME_HEADER_BYTES + len + FRAME_TRAILER_BYTES + auth_extra;
            if pending.len() < total {
                self.mid_frame = true;
                return Ok(None);
            }
            let frame_start = self.start;
            self.start += total;
            match validate_wire_frame(&self.buf[frame_start..frame_start + total], rx)? {
                WireVerdict::Accept { round, kind, seq } => {
                    self.rejected = 0;
                    let payload = frame_start + FRAME_HEADER_BYTES
                        ..frame_start + FRAME_HEADER_BYTES + len;
                    return Ok(Some((round, kind, seq, payload)));
                }
                WireVerdict::AuthReject | WireVerdict::ReplayReject => {
                    self.rejected += 1;
                    anyhow::ensure!(
                        self.rejected <= MAX_CONSECUTIVE_AUTH_REJECTS,
                        "too many consecutive auth-rejected frames ({})",
                        self.rejected
                    );
                }
            }
        }
    }

    /// Resolve a payload range from [`FrameDecoder::next_frame`].
    pub fn bytes(&self, r: Range<usize>) -> &[u8] {
        &self.buf[r]
    }
}

/// What the round collector expects of uploads, threaded into
/// [`SessionMachine::poll`] while a round is armed.
pub(crate) struct RoundCtx<'a> {
    pub round_id: u64,
    pub shape: UpdateShape,
    /// Server-assigned FedAvg weight to pin the BEGIN declaration to.
    pub expect_alpha: Option<f64>,
    pub params: &'a CkksParams,
}

/// One actionable protocol step out of [`SessionMachine::poll`]. The
/// driving shard performs the I/O the step names; the machine has already
/// advanced past it.
pub(crate) enum Step {
    /// A STATS probe in place of HELLO: reply with a metrics snapshot and
    /// close after the flush — no session slot is claimed.
    Stats,
    /// `--wire-auth mac`: send CHALLENGE carrying this session nonce.
    Challenge { nonce: [u8; 16] },
    /// Handshake complete: register `client` and enqueue WELCOME (plus any
    /// mid-round downlink replay), authenticating the downlink with `tx`
    /// when armed.
    Register { client: u64, tx: Option<TxAuth> },
    /// A complete validated upload for the armed round: hand it to the
    /// collector and enqueue the ACK.
    Upload { frames: Box<UploadFrames> },
}

#[derive(Clone, Copy)]
enum MachineState {
    /// Fresh connection: first frame must be HELLO (or a STATS probe).
    AwaitHello,
    /// CHALLENGE sent; the proof tag must verify before any registration.
    AwaitChallengeResp { client: u64 },
    /// Registered. Uploads parse only while the driver arms a round.
    Ready { client: u64 },
}

/// The server side of one hub session as a pure state machine — the
/// nonblocking twin of `session::handshake` + `intake::read_upload`,
/// accepting and rejecting byte-for-byte the same streams.
pub(crate) struct SessionMachine {
    decoder: FrameDecoder,
    /// Uplink authenticator, armed when the handshake proof verifies.
    rx: Option<RxAuth>,
    state: MachineState,
    auth_root: Option<[u8; 32]>,
    /// The task's ciphertext wire format: every HELLO must announce the
    /// same mode or the handshake is a hard error (mirrors the blocking
    /// hub).
    ct_wire: CtWire,
    /// Session challenge nonce, drawn by the driver at accept time (the
    /// machine itself touches no entropy source).
    nonce: [u8; 16],
    upload: Option<UploadAssembly>,
    /// Wire bytes consumed by round frames since the last take (handshake
    /// traffic is not counted, matching the blocking collectors).
    wire_bytes: u64,
}

impl SessionMachine {
    /// `cap` bounds any declared payload ([`super::frame::frame_payload_cap`]);
    /// `auth_root` is the task MAC root (`None` = legacy wire); `ct_wire`
    /// is the task's ciphertext wire format HELLOs must announce; `nonce`
    /// is this connection's fresh challenge nonce.
    pub fn new(
        cap: usize,
        auth_root: Option<[u8; 32]>,
        ct_wire: CtWire,
        nonce: [u8; 16],
    ) -> Self {
        SessionMachine {
            decoder: FrameDecoder::new(cap),
            rx: None,
            state: MachineState::AwaitHello,
            auth_root,
            ct_wire,
            nonce,
            upload: None,
            wire_bytes: 0,
        }
    }

    /// Feed freshly-read bytes.
    pub fn push(&mut self, bytes: &[u8]) {
        self.decoder.push(bytes);
    }

    /// The session identity, known once a valid HELLO parsed.
    pub fn client(&self) -> Option<u64> {
        match self.state {
            MachineState::AwaitHello => None,
            MachineState::AwaitChallengeResp { client }
            | MachineState::Ready { client } => Some(client),
        }
    }

    /// Drain the wire-byte count of round frames consumed so far (folded
    /// into the round ledger on completion or failure).
    pub fn take_wire_bytes(&mut self) -> u64 {
        std::mem::take(&mut self.wire_bytes)
    }

    /// Unparsed bytes buffered in the frame decoder — the shard read loop's
    /// per-connection memory bound (it stops reading past a cap and lets
    /// level-triggered readiness re-deliver the socket later).
    pub fn buffered(&self) -> usize {
        self.decoder.pending()
    }

    /// An upload is mid-reassembly: the stream is desynchronized if the
    /// round ends here, so the driver must kill the connection rather than
    /// carry the half-built state into the next round.
    pub fn mid_upload(&self) -> bool {
        self.upload.is_some()
    }

    /// Advance as far as the buffered bytes allow. Returns the next
    /// actionable [`Step`], or `None` when more bytes are needed — or when
    /// the machine is registered and `round` is `None`: buffered upload
    /// frames stay unparsed until the driver arms a round, which is what
    /// carries the blocking backend's TCP backpressure semantics (an
    /// unprompted upload fills kernel buffers, not server memory) across
    /// the refactor. Any `Err` desynchronizes the connection: kill it.
    pub fn poll(&mut self, round: Option<&RoundCtx<'_>>) -> anyhow::Result<Option<Step>> {
        loop {
            match self.state {
                MachineState::AwaitHello => {
                    let Some((rnd, kind, _seq, pr)) = self.decoder.next_frame(&mut self.rx)?
                    else {
                        return Ok(None);
                    };
                    anyhow::ensure!(
                        rnd == CONTROL_ROUND,
                        "frame for round {rnd}, expected {CONTROL_ROUND}"
                    );
                    if kind == FrameKind::Stats {
                        return Ok(Some(Step::Stats));
                    }
                    anyhow::ensure!(kind == FrameKind::Hello, "expected HELLO, got {kind:?}");
                    let (client, announced) = decode_hello(self.decoder.bytes(pr))?;
                    anyhow::ensure!(
                        client != UNIDENTIFIED_CLIENT,
                        "client id {client} is reserved"
                    );
                    anyhow::ensure!(
                        announced == self.ct_wire,
                        "client {client} announced ciphertext wire mode {}, task runs {}",
                        announced.as_str(),
                        self.ct_wire.as_str()
                    );
                    if self.auth_root.is_some() {
                        self.state = MachineState::AwaitChallengeResp { client };
                        return Ok(Some(Step::Challenge { nonce: self.nonce }));
                    }
                    self.state = MachineState::Ready { client };
                    return Ok(Some(Step::Register { client, tx: None }));
                }
                MachineState::AwaitChallengeResp { client } => {
                    let Some((rnd, kind, _seq, pr)) = self.decoder.next_frame(&mut self.rx)?
                    else {
                        return Ok(None);
                    };
                    anyhow::ensure!(
                        rnd == CONTROL_ROUND,
                        "frame for round {rnd}, expected {CONTROL_ROUND}"
                    );
                    anyhow::ensure!(
                        kind == FrameKind::ChallengeResp,
                        "expected CHALLENGE_RESP, got {kind:?} (client not in --wire-auth mac?)"
                    );
                    let (echoed, tag) = decode_challenge_resp(self.decoder.bytes(pr))?;
                    let Some(root) = self.auth_root else {
                        anyhow::bail!("challenge state without an auth root");
                    };
                    let skey =
                        mac::derive_session_key(&mac::derive_client_key(&root, client), &self.nonce);
                    if echoed != client || tag != mac::handshake_tag(&skey, &self.nonce, client) {
                        crate::obs::metrics::auth_reject();
                        anyhow::bail!("client {client} failed the handshake challenge");
                    }
                    self.rx = Some(RxAuth::new(MacKey(skey.0), AUTH_DIR_UP));
                    self.state = MachineState::Ready { client };
                    return Ok(Some(Step::Register {
                        client,
                        tx: Some(TxAuth::new(skey, AUTH_DIR_DOWN)),
                    }));
                }
                MachineState::Ready { client } => {
                    let Some(ctx) = round else {
                        return Ok(None);
                    };
                    let auth_extra = if self.rx.is_some() { AUTH_TRAILER_BYTES } else { 0 };
                    let Some((rnd, kind, seq, pr)) = self.decoder.next_frame(&mut self.rx)?
                    else {
                        return Ok(None);
                    };
                    anyhow::ensure!(
                        rnd == ctx.round_id,
                        "frame for round {rnd}, expected {}",
                        ctx.round_id
                    );
                    self.wire_bytes +=
                        (FRAME_HEADER_BYTES + pr.len() + FRAME_TRAILER_BYTES + auth_extra) as u64;
                    let payload = self.decoder.bytes(pr);
                    match self.upload.as_mut() {
                        None => {
                            anyhow::ensure!(
                                kind == FrameKind::Begin,
                                "upload must start with BEGIN, got {kind:?}"
                            );
                            let mut seen = None;
                            self.upload = Some(UploadAssembly::begin(
                                payload,
                                ctx.shape,
                                Some(client),
                                ctx.expect_alpha,
                                &mut seen,
                            )?);
                        }
                        Some(asm) => {
                            if let Some(timing) = asm.accept(ctx.params, kind, seq, payload)? {
                                let Some(asm) = self.upload.take() else {
                                    anyhow::bail!("upload assembly vanished at END");
                                };
                                let frames = asm.finish(timing)?;
                                return Ok(Some(Step::Upload {
                                    frames: Box::new(frames),
                                }));
                            }
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckks::serialize::ciphertext_shard_to_bytes;
    use crate::ckks::Ciphertext;
    use crate::transport::frame::{
        encode_begin, encode_challenge_resp, encode_end_timing, encode_hello,
        frame_payload_cap, write_frame, write_frame_with,
    };

    fn params() -> CkksParams {
        CkksParams::new(256, 3, 30).unwrap()
    }

    fn frame_bytes(round: u64, kind: FrameKind, seq: u32, payload: &[u8]) -> Vec<u8> {
        let mut b = Vec::new();
        write_frame(&mut b, round, kind, seq, payload).unwrap();
        b
    }

    fn shape() -> UpdateShape {
        UpdateShape {
            n_cts: 1,
            n_plain: 1,
            total: 4,
            ct_wire: CtWire::Dense,
        }
    }

    /// A full valid upload for `shape()`: BEGIN, one ct chunk, one plain
    /// value, END — authenticated when `tx` is armed.
    fn upload_stream(client: u64, round: u64, tx: &mut Option<TxAuth>, p: &CkksParams) -> Vec<u8> {
        let mut b = Vec::new();
        let begin = encode_begin(client, 0.5, 1, 1, 4);
        write_frame_with(&mut b, round, FrameKind::Begin, 0, &begin, tx).unwrap();
        let ct = ciphertext_shard_to_bytes(&Ciphertext::zero(p), 0, p.num_limbs());
        write_frame_with(&mut b, round, FrameKind::CtChunk, 0, &ct, tx).unwrap();
        write_frame_with(&mut b, round, FrameKind::Plain, 0, &7.0f32.to_le_bytes(), tx).unwrap();
        let end = encode_end_timing(1.0, 2.0, 0.5);
        write_frame_with(&mut b, round, FrameKind::End, 0, &end, tx).unwrap();
        b
    }

    #[test]
    fn plain_handshake_and_upload_survive_byte_at_a_time_reads() {
        let p = params();
        let mut m = SessionMachine::new(frame_payload_cap(&p), None, CtWire::Dense, [0u8; 16]);
        let hello = encode_hello(9, CtWire::Dense);
        let mut wire = frame_bytes(CONTROL_ROUND, FrameKind::Hello, 0, &hello);
        let upload = upload_stream(9, 3, &mut None, &p);
        let upload_len = upload.len() as u64;
        wire.extend_from_slice(&upload);
        let ctx = RoundCtx { round_id: 3, shape: shape(), expect_alpha: Some(0.5), params: &p };
        let mut registered = None;
        let mut uploaded = None;
        for &byte in &wire {
            m.push(&[byte]);
            while let Some(step) = m.poll(Some(&ctx)).unwrap() {
                match step {
                    Step::Register { client, tx } => {
                        assert!(tx.is_none(), "legacy wire must not arm a downlink MAC");
                        registered = Some(client);
                    }
                    Step::Upload { frames } => uploaded = Some(frames),
                    _ => panic!("unexpected step"),
                }
            }
        }
        assert_eq!(registered, Some(9));
        assert_eq!(m.client(), Some(9));
        let frames = uploaded.expect("upload must complete");
        assert_eq!(frames.client, 9);
        assert_eq!(frames.alpha, 0.5);
        assert_eq!(frames.update.plain, vec![7.0]);
        assert_eq!(frames.update.total, 4);
        assert_eq!(frames.train_secs, 1.0);
        assert_eq!(m.take_wire_bytes(), upload_len);
    }

    #[test]
    fn uploads_stay_buffered_until_a_round_is_armed() {
        let p = params();
        let mut m = SessionMachine::new(frame_payload_cap(&p), None, CtWire::Dense, [0u8; 16]);
        m.push(&frame_bytes(CONTROL_ROUND, FrameKind::Hello, 0, &encode_hello(2, CtWire::Dense)));
        assert!(matches!(m.poll(None).unwrap(), Some(Step::Register { client: 2, .. })));
        // the whole upload arrives before the server arms the round
        m.push(&upload_stream(2, 0, &mut None, &p));
        assert!(m.poll(None).unwrap().is_none());
        assert!(m.poll(None).unwrap().is_none(), "no round armed: frames stay put");
        let ctx = RoundCtx { round_id: 0, shape: shape(), expect_alpha: None, params: &p };
        match m.poll(Some(&ctx)).unwrap() {
            Some(Step::Upload { frames }) => assert_eq!(frames.client, 2),
            _ => panic!("armed round must drain the buffered upload"),
        }
    }

    #[test]
    fn stats_probe_short_circuits_registration() {
        let p = params();
        let mut m = SessionMachine::new(frame_payload_cap(&p), None, CtWire::Dense, [0u8; 16]);
        m.push(&frame_bytes(CONTROL_ROUND, FrameKind::Stats, 0, &[]));
        assert!(matches!(m.poll(None).unwrap(), Some(Step::Stats)));
        assert_eq!(m.client(), None);
    }

    #[test]
    fn hello_with_mismatched_ct_wire_is_fatal() {
        let p = params();
        // seed announcement on a dense task: hard error before registration
        let mut m = SessionMachine::new(frame_payload_cap(&p), None, CtWire::Dense, [0u8; 16]);
        m.push(&frame_bytes(CONTROL_ROUND, FrameKind::Hello, 0, &encode_hello(6, CtWire::Seed)));
        assert!(m.poll(None).is_err());
        assert_eq!(m.client(), None, "mismatch must not identify the session");
        // dense announcement on a seed task: same, other direction
        let mut m = SessionMachine::new(frame_payload_cap(&p), None, CtWire::Seed, [0u8; 16]);
        m.push(&frame_bytes(CONTROL_ROUND, FrameKind::Hello, 0, &encode_hello(6, CtWire::Dense)));
        assert!(m.poll(None).is_err());
        // a matching seed announcement registers
        let mut m = SessionMachine::new(frame_payload_cap(&p), None, CtWire::Seed, [0u8; 16]);
        m.push(&frame_bytes(CONTROL_ROUND, FrameKind::Hello, 0, &encode_hello(6, CtWire::Seed)));
        assert!(matches!(m.poll(None).unwrap(), Some(Step::Register { client: 6, .. })));
    }

    #[test]
    fn mac_handshake_verifies_the_proof_and_soft_rejects_forgeries() {
        let p = params();
        let root = [7u8; 32];
        let mut m =
            SessionMachine::new(frame_payload_cap(&p), Some(root), CtWire::Dense, [3u8; 16]);
        m.push(&frame_bytes(CONTROL_ROUND, FrameKind::Hello, 0, &encode_hello(4, CtWire::Dense)));
        let nonce = match m.poll(None).unwrap() {
            Some(Step::Challenge { nonce }) => nonce,
            _ => panic!("mac mode must challenge before registering"),
        };
        assert_eq!(nonce, [3u8; 16]);
        assert!(m.poll(None).unwrap().is_none());
        let skey = mac::derive_session_key(&mac::derive_client_key(&root, 4), &nonce);
        let resp = encode_challenge_resp(4, mac::handshake_tag(&skey, &nonce, 4));
        m.push(&frame_bytes(CONTROL_ROUND, FrameKind::ChallengeResp, 0, &resp));
        let tx = match m.poll(None).unwrap() {
            Some(Step::Register { client, tx }) => {
                assert_eq!(client, 4);
                tx
            }
            _ => panic!("valid proof must register"),
        };
        assert!(tx.is_some(), "mac mode must arm the downlink authenticator");

        // a forged (untagged) frame injected ahead of the real upload is a
        // counted soft reject; the authenticated stream stays aligned
        let rejects_before = crate::obs::metrics::snapshot_auth_rejects();
        let mut forged = frame_bytes(1, FrameKind::Plain, 9, &0.0f32.to_le_bytes());
        forged.extend_from_slice(&[0u8; AUTH_TRAILER_BYTES]);
        m.push(&forged);
        let mut tx_up = Some(TxAuth::new(MacKey(skey.0), AUTH_DIR_UP));
        m.push(&upload_stream(4, 1, &mut tx_up, &p));
        let ctx = RoundCtx { round_id: 1, shape: shape(), expect_alpha: Some(0.5), params: &p };
        match m.poll(Some(&ctx)).unwrap() {
            Some(Step::Upload { frames }) => assert_eq!(frames.client, 4),
            _ => panic!("upload must survive an interleaved forgery"),
        }
        assert!(
            crate::obs::metrics::snapshot_auth_rejects() > rejects_before,
            "the forgery must be counted"
        );
    }

    #[test]
    fn bad_handshake_proof_is_fatal_and_counted() {
        let p = params();
        let root = [7u8; 32];
        let mut m =
            SessionMachine::new(frame_payload_cap(&p), Some(root), CtWire::Dense, [3u8; 16]);
        m.push(&frame_bytes(CONTROL_ROUND, FrameKind::Hello, 0, &encode_hello(4, CtWire::Dense)));
        assert!(matches!(m.poll(None).unwrap(), Some(Step::Challenge { .. })));
        let rejects_before = crate::obs::metrics::snapshot_auth_rejects();
        let resp = encode_challenge_resp(4, 0xdead_beef);
        m.push(&frame_bytes(CONTROL_ROUND, FrameKind::ChallengeResp, 0, &resp));
        assert!(m.poll(None).is_err());
        assert!(crate::obs::metrics::snapshot_auth_rejects() > rejects_before);
    }

    #[test]
    fn protocol_violations_are_hard_errors() {
        let p = params();
        // first frame must be HELLO (or STATS)
        let mut m = SessionMachine::new(frame_payload_cap(&p), None, CtWire::Dense, [0u8; 16]);
        m.push(&frame_bytes(CONTROL_ROUND, FrameKind::Begin, 0, &[0u8; 32]));
        assert!(m.poll(None).is_err());
        // reserved sentinel id
        let mut m = SessionMachine::new(frame_payload_cap(&p), None, CtWire::Dense, [0u8; 16]);
        m.push(&frame_bytes(
            CONTROL_ROUND,
            FrameKind::Hello,
            0,
            &encode_hello(UNIDENTIFIED_CLIENT, CtWire::Dense),
        ));
        assert!(m.poll(None).is_err());
        // a registered session's upload frames must carry the armed round
        let mut m = SessionMachine::new(frame_payload_cap(&p), None, CtWire::Dense, [0u8; 16]);
        m.push(&frame_bytes(CONTROL_ROUND, FrameKind::Hello, 0, &encode_hello(5, CtWire::Dense)));
        assert!(matches!(m.poll(None).unwrap(), Some(Step::Register { .. })));
        m.push(&upload_stream(5, 8, &mut None, &p));
        let ctx = RoundCtx { round_id: 3, shape: shape(), expect_alpha: None, params: &p };
        assert!(m.poll(Some(&ctx)).is_err());
        // an upload must open with BEGIN
        let mut m = SessionMachine::new(frame_payload_cap(&p), None, CtWire::Dense, [0u8; 16]);
        m.push(&frame_bytes(CONTROL_ROUND, FrameKind::Hello, 0, &encode_hello(5, CtWire::Dense)));
        assert!(matches!(m.poll(None).unwrap(), Some(Step::Register { .. })));
        m.push(&frame_bytes(3, FrameKind::Plain, 0, &0.0f32.to_le_bytes()));
        assert!(m.poll(Some(&ctx)).is_err());
    }

    #[test]
    fn oversized_declared_length_is_rejected_from_the_header_alone() {
        let mut d = FrameDecoder::new(1024);
        let mut frame = frame_bytes(0, FrameKind::Plain, 0, &[0u8; 8]);
        frame[24..28].copy_from_slice(&u32::MAX.to_le_bytes());
        // only the header needs to arrive for the cap check to fire
        d.push(&frame[..FRAME_HEADER_BYTES]);
        assert!(d.next_frame(&mut None).is_err());
    }

    #[test]
    fn decoder_resumes_across_partial_reads() {
        let p = params();
        let mut d = FrameDecoder::new(frame_payload_cap(&p));
        let frame = frame_bytes(0, FrameKind::Plain, 0, &1.0f32.to_le_bytes());
        d.push(&frame[..5]);
        assert!(d.next_frame(&mut None).unwrap().is_none());
        d.push(&frame[5..]);
        let (round, kind, _seq, pr) = d.next_frame(&mut None).unwrap().unwrap();
        assert_eq!((round, kind), (0, FrameKind::Plain));
        assert_eq!(d.bytes(pr), &1.0f32.to_le_bytes()[..]);
        assert_eq!(d.pending(), 0);
    }
}
