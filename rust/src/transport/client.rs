//! Client-side upload driver: stream an [`EncryptedUpdate`] to the server's
//! TCP intake, frame by frame.
//!
//! Two one-shot entry points (one connection per upload, the PR-4 uplink
//! path kept for tests, demos and anonymous uploads):
//!
//! * [`upload_update`] — ship an already-encrypted update. Tests and demos
//!   use it to *re-upload* a prepared update over a fresh connection — a
//!   benign, intentional re-send, not to be confused with a replayed
//!   *frame*: under `--wire-auth mac` any byte-identical frame repeated
//!   into a live session fails the monotone auth-sequence check and is
//!   discarded with `replay_rejects` incremented (DESIGN.md §12).
//! * [`upload_encrypt_streaming`] — encrypt-and-upload: ciphertext chunks go
//!   onto the socket **while later chunks are still being encrypted** by the
//!   parallel [`SelectiveCodec`] worker pool
//!   ([`SelectiveCodec::encrypt_update_streamed`]). The socket writer is a
//!   bounded `BufWriter`, so a slow link backpressures the encrypt workers
//!   through their bounded hand-off channels instead of buffering the whole
//!   ciphertext body in memory.
//!
//! Both produce byte-identical uploads for the same update/rng.
//!
//! The persistent-session path ([`super::session::ClientSession`]) reuses
//! the same [`FrameSink`] over one long-lived connection: `send_begin`
//! opens a fresh per-upload receipt window, so a sink can carry many
//! uploads (one per round) without reconnecting.

use super::frame::{
    encode_begin, encode_end_timing, read_frame_into_with, write_frame_with, FrameKind,
    RxAuth, TxAuth, BEGIN_PAYLOAD_BYTES, PLAIN_CHUNK_VALUES,
};
use crate::ckks::serialize::{ciphertext_seeded_append, ciphertext_shard_append};
use crate::ckks::{Ciphertext, CtWire, PublicKey};
use crate::crypto::prng::ChaChaRng;
use crate::he_agg::{CtArena, EncryptedUpdate, EncryptionMask, SelectiveCodec};
use std::io::{BufWriter, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

/// Per-upload knobs.
#[derive(Debug, Clone)]
pub struct UploadConfig {
    pub round_id: u64,
    /// Client id carried in the BEGIN frame.
    pub client: u64,
    /// FedAvg weight carried in the BEGIN frame (must be in (0, 1]).
    pub alpha: f64,
    /// Socket write-buffer capacity in bytes: the bound on how far the
    /// uploader runs ahead of the link.
    pub write_buffer: usize,
    /// Socket read/write timeout.
    pub io_timeout: Duration,
}

impl Default for UploadConfig {
    fn default() -> Self {
        UploadConfig {
            round_id: 0,
            client: 0,
            alpha: 1.0,
            write_buffer: 256 * 1024,
            io_timeout: Duration::from_secs(10),
        }
    }
}

/// What an upload put on the wire.
#[derive(Debug, Clone, Default)]
pub struct UploadReceipt {
    pub bytes_sent: u64,
    pub ct_frames: usize,
    /// Whether the server acknowledged the END frame.
    pub acked: bool,
}

/// Frame writer over one (possibly long-lived) connection. Per-upload
/// accounting restarts at each `send_begin`; `bytes_sent` is cumulative
/// over the sink's lifetime.
pub(crate) struct FrameSink {
    writer: BufWriter<Box<dyn Write + Send>>,
    round: u64,
    /// Outbound frame authenticator (`--wire-auth mac`); `None` = legacy.
    auth: Option<TxAuth>,
    /// Ciphertext wire format for CT_CHUNK frames (`--ct-wire`): dense
    /// full-limb shards, or the seed-compressed symmetric form.
    ct_wire: CtWire,
    /// Reused payload staging buffer for ciphertext frames.
    buf: Vec<u8>,
    /// Cumulative frame bytes written over the sink's lifetime.
    bytes_sent: u64,
    /// `bytes_sent` at the most recent BEGIN (receipt window start).
    upload_base: u64,
    /// Ciphertext frames of the current upload.
    ct_frames: usize,
}

impl FrameSink {
    /// Wrap an already-connected stream (the persistent-session path).
    pub(crate) fn over(stream: TcpStream, round: u64, write_buffer: usize) -> Self {
        Self::over_writer(Box::new(stream), round, write_buffer)
    }

    /// Wrap an arbitrary byte sink — the chaos layer interposes here.
    pub(crate) fn over_writer(
        writer: Box<dyn Write + Send>,
        round: u64,
        write_buffer: usize,
    ) -> Self {
        FrameSink {
            writer: BufWriter::with_capacity(write_buffer.max(1024), writer),
            round,
            auth: None,
            ct_wire: CtWire::Dense,
            buf: Vec::new(),
            bytes_sent: 0,
            upload_base: 0,
            ct_frames: 0,
        }
    }

    /// Install (or clear) the outbound frame authenticator.
    pub(crate) fn set_auth(&mut self, auth: Option<TxAuth>) {
        self.auth = auth;
    }

    /// Select the ciphertext wire format for subsequent CT_CHUNK frames
    /// (the session sets this to the handshake-negotiated mode).
    pub(crate) fn set_ct_wire(&mut self, ct_wire: CtWire) {
        self.ct_wire = ct_wire;
    }

    /// Dial + wrap (the one-shot path). Returns the sink and a cloned read
    /// half for the ACK.
    fn connect(addr: &str, cfg: &UploadConfig) -> anyhow::Result<(Self, TcpStream)> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(cfg.io_timeout))?;
        stream.set_write_timeout(Some(cfg.io_timeout))?;
        let reader = stream.try_clone()?;
        Ok((Self::over(stream, cfg.round_id, cfg.write_buffer), reader))
    }

    /// Switch the round id stamped on subsequent frames (persistent
    /// sessions write mask-stage and per-round frames over one socket).
    pub(crate) fn set_round(&mut self, round: u64) {
        self.round = round;
    }

    /// Cumulative frame bytes written over the sink's lifetime.
    pub(crate) fn total_bytes(&self) -> u64 {
        self.bytes_sent
    }

    pub(crate) fn send(
        &mut self,
        kind: FrameKind,
        seq: u32,
        payload: &[u8],
    ) -> std::io::Result<()> {
        self.bytes_sent +=
            write_frame_with(&mut self.writer, self.round, kind, seq, payload, &mut self.auth)?;
        Ok(())
    }

    pub(crate) fn send_begin(
        &mut self,
        client: u64,
        alpha: f64,
        n_cts: usize,
        n_plain: usize,
        total: usize,
    ) -> std::io::Result<()> {
        self.upload_base = self.bytes_sent;
        self.ct_frames = 0;
        let p = encode_begin(client, alpha, n_cts, n_plain, total);
        self.send(FrameKind::Begin, 0, &p)
    }

    pub(crate) fn send_ct(&mut self, seq: usize, ct: &Ciphertext) -> std::io::Result<()> {
        let limbs = ct.c0.num_limbs();
        self.buf.clear();
        match self.ct_wire {
            CtWire::Dense => ciphertext_shard_append(ct, 0, limbs, &mut self.buf),
            CtWire::Seed => ciphertext_seeded_append(ct, &mut self.buf),
        }
        let payload = std::mem::take(&mut self.buf);
        let r = self.send(FrameKind::CtChunk, seq as u32, &payload);
        self.buf = payload;
        if r.is_ok() {
            self.ct_frames += 1;
        }
        r
    }

    pub(crate) fn send_plain(&mut self, plain: &[f32]) -> std::io::Result<()> {
        for (seq, chunk) in plain.chunks(PLAIN_CHUNK_VALUES).enumerate() {
            self.buf.clear();
            self.buf.reserve(chunk.len() * 4);
            for &v in chunk {
                self.buf.extend_from_slice(&v.to_le_bytes());
            }
            let payload = std::mem::take(&mut self.buf);
            let r = self.send(FrameKind::Plain, seq as u32, &payload);
            self.buf = payload;
            r?;
        }
        Ok(())
    }

    pub(crate) fn flush(&mut self) -> std::io::Result<()> {
        self.writer.flush()
    }

    /// END (optionally carrying measured local metrics) + flush, then wait
    /// for the server's ACK on `reader`. Non-consuming: a persistent
    /// session calls this once per round over the same sink.
    pub(crate) fn end_and_ack<R: Read>(
        &mut self,
        reader: &mut R,
        read_buf: &mut Vec<u8>,
        metrics: Option<(f64, f64, f32)>,
        rx: &mut Option<RxAuth>,
    ) -> anyhow::Result<UploadReceipt> {
        let _span = crate::obs::span("transport", "end_and_ack");
        match metrics {
            Some((train, encrypt, loss)) => {
                self.send(FrameKind::End, 0, &encode_end_timing(train, encrypt, loss))?
            }
            None => self.send(FrameKind::End, 0, &[])?,
        }
        self.writer.flush()?;
        // END→ACK round trip: the server's receipt stamps the far end, so
        // this is the wire+reassembly latency the RTT histogram tracks
        let t0 = std::time::Instant::now();
        let (kind, _) =
            read_frame_into_with(reader, self.round, BEGIN_PAYLOAD_BYTES, read_buf, rx)?;
        crate::obs::metrics::session_rtt_secs(t0.elapsed().as_secs_f64());
        anyhow::ensure!(kind == FrameKind::Ack, "expected ACK, got {kind:?}");
        Ok(UploadReceipt {
            bytes_sent: self.bytes_sent - self.upload_base,
            ct_frames: self.ct_frames,
            acked: true,
        })
    }
}

/// Upload an already-encrypted update over a fresh connection. Frames
/// stream through the bounded write buffer; returns once the server
/// acknowledges the END frame.
pub fn upload_update(
    addr: &str,
    cfg: &UploadConfig,
    update: &EncryptedUpdate,
) -> anyhow::Result<UploadReceipt> {
    let (mut sink, mut reader) = FrameSink::connect(addr, cfg)?;
    sink.send_begin(cfg.client, cfg.alpha, update.cts.len(), update.plain.len(), update.total)?;
    for (seq, ct) in update.cts.iter().enumerate() {
        sink.send_ct(seq, ct)?;
    }
    sink.send_plain(&update.plain)?;
    let mut ack_buf = Vec::new();
    sink.end_and_ack(&mut reader, &mut ack_buf, None, &mut None)
}

/// Encrypt-and-upload: chunk `c` is framed onto the socket while chunks
/// `> c` are still encrypting on the codec's worker pool. The resulting
/// upload is byte-identical to encrypting with
/// [`SelectiveCodec::encrypt_update`] and calling [`upload_update`] with the
/// same rng state.
pub fn upload_encrypt_streaming(
    addr: &str,
    cfg: &UploadConfig,
    codec: &SelectiveCodec,
    model: &[f32],
    mask: &EncryptionMask,
    pk: &PublicKey,
    rng: &mut ChaChaRng,
) -> anyhow::Result<UploadReceipt> {
    let (mut sink, mut reader) = FrameSink::connect(addr, cfg)?;
    let n_cts = codec.ct_count(mask.encrypted_count());
    let n_plain = mask.total() - mask.encrypted_count();
    sink.send_begin(cfg.client, cfg.alpha, n_cts, n_plain, mask.total())?;
    // Stream ciphertext chunks as the worker pool finishes them. Encryption
    // keeps running after a socket error; the first error is kept and
    // reported once the (deterministic) rng stream has fully advanced.
    // Each serialized chunk's buffer is recycled into the arena, so the
    // upload keeps O(workers) ciphertext buffers live regardless of model
    // size.
    let arena = CtArena::new();
    let mut io_err: Option<std::io::Error> = None;
    let (plain, ct_frames) =
        codec.encrypt_update_streamed_with_arena(model, mask, pk, rng, &arena, |seq, ct| {
            if io_err.is_none() {
                if let Err(e) = sink.send_ct(seq, &ct) {
                    io_err = Some(e);
                }
            }
            arena.recycle(ct);
        });
    if let Some(e) = io_err {
        return Err(e.into());
    }
    anyhow::ensure!(
        ct_frames == n_cts && plain.len() == n_plain,
        "codec produced {ct_frames} chunks / {} plain values, declared {n_cts} / {n_plain}",
        plain.len()
    );
    sink.send_plain(&plain)?;
    let mut ack_buf = Vec::new();
    sink.end_and_ack(&mut reader, &mut ack_buf, None, &mut None)
}

/// Failure injection for tests and demos: send BEGIN plus the first
/// `ct_frames` ciphertext chunks, then drop the connection without END — a
/// mid-upload disconnect the server must absorb as a dropped straggler.
pub fn upload_partial_then_disconnect(
    addr: &str,
    cfg: &UploadConfig,
    update: &EncryptedUpdate,
    ct_frames: usize,
) -> anyhow::Result<u64> {
    let (mut sink, _reader) = FrameSink::connect(addr, cfg)?;
    sink.send_begin(cfg.client, cfg.alpha, update.cts.len(), update.plain.len(), update.total)?;
    for (seq, ct) in update.cts.iter().take(ct_frames).enumerate() {
        sink.send_ct(seq, ct)?;
    }
    sink.flush()?;
    let sent = sink.total_bytes();
    drop(sink); // closes the socket with the upload incomplete
    Ok(sent)
}

/// Dial with capped exponential backoff + jitter: attempt 0 is immediate,
/// then up to `retries` more attempts sleep `base · 2^k` each (capped at
/// 5 s), jittered ±50% from a seeded [`ChaChaRng`] so a cohort of clients
/// restarting together doesn't reconnect in lockstep. `retries == 0`
/// restores the legacy fail-fast connect.
pub fn connect_with_backoff(
    addr: &str,
    retries: u32,
    base: Duration,
    seed: u64,
) -> anyhow::Result<TcpStream> {
    const CAP: Duration = Duration::from_secs(5);
    let mut jitter = ChaChaRng::from_seed(seed, u64::from_le_bytes(*b"backoff\0"));
    let mut last_err = None;
    for attempt in 0..=retries {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => last_err = Some(e),
        }
        if attempt == retries {
            break;
        }
        let exp = base.saturating_mul(1u32 << attempt.min(16)).min(CAP);
        // ±50%: scale by a factor in [0.5, 1.5)
        let factor = 0.5 + (jitter.next_u64() >> 11) as f64 / (1u64 << 53) as f64;
        std::thread::sleep(exp.mul_f64(factor));
    }
    Err(anyhow::anyhow!(
        "connect to {addr} failed after {} attempt(s): {}",
        retries as u64 + 1,
        last_err.expect("at least one attempt")
    ))
}
