//! Length-framed binary upload protocol (DESIGN.md §8).
//!
//! Every message on the wire is one frame:
//!
//! ```text
//! magic    u32   = 0x46485450 ("FHTP")
//! version  u32   = 1
//! round    u64   round id (both sides reject skew)
//! kind     u32   frame kind (BEGIN/CT_CHUNK/PLAIN/END/ACK)
//! seq      u32   chunk sequence (ciphertext index / plaintext chunk index)
//! len      u32   payload byte length
//! payload  len bytes
//! crc      u32   CRC-32 (IEEE) of the payload
//! ```
//!
//! The reader validates magic, version, round, kind and `len` **before**
//! allocating the payload buffer: `len` is capped by a params-derived bound
//! ([`frame_payload_cap`]), so an attacker-controlled length prefix can never
//! drive an allocation beyond one legitimate frame. Truncation (EOF anywhere
//! inside a frame), CRC mismatch, version skew and unknown kinds all return
//! `Err` — the connection's upload is then discarded as a dropped straggler,
//! never a panic or a poisoned round.

use crate::ckks::serialize::shard_wire_bytes;
use crate::ckks::CkksParams;
use std::io::{Read, Write};

/// Frame magic: "FHTP" (FedML-HE transport protocol).
pub const FRAME_MAGIC: u32 = 0x4648_5450;
/// Wire protocol version; bumped on any layout change.
pub const PROTOCOL_VERSION: u32 = 1;
/// Fixed frame header size: magic(4) version(4) round(8) kind(4) seq(4) len(4).
pub const FRAME_HEADER_BYTES: usize = 28;
/// Fixed frame trailer size: payload CRC-32.
pub const FRAME_TRAILER_BYTES: usize = 4;
/// BEGIN payload: client(8) alpha(8) n_cts(4) n_plain(4) total(8).
pub const BEGIN_PAYLOAD_BYTES: usize = 32;
/// f32 values per PLAIN frame (256 KiB of payload).
pub const PLAIN_CHUNK_VALUES: usize = 65_536;

/// What a frame carries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameKind {
    /// Upload preamble: client identity, FedAvg weight, declared shape.
    Begin = 1,
    /// One ciphertext chunk: a full-limb-range shard view
    /// (`ckks::serialize::ciphertext_shard_to_bytes(ct, 0, limbs)`).
    CtChunk = 2,
    /// A slice of the compacted plaintext remainder (f32 LE, in order).
    Plain = 3,
    /// Upload complete (empty payload); the server stamps the arrival here.
    End = 4,
    /// Server receipt (u32 LE status, 0 = received).
    Ack = 5,
}

impl FrameKind {
    fn from_u32(v: u32) -> anyhow::Result<Self> {
        Ok(match v {
            1 => FrameKind::Begin,
            2 => FrameKind::CtChunk,
            3 => FrameKind::Plain,
            4 => FrameKind::End,
            5 => FrameKind::Ack,
            other => anyhow::bail!("unknown frame kind {other}"),
        })
    }
}

/// One parsed frame.
#[derive(Debug, Clone)]
pub struct Frame {
    pub kind: FrameKind,
    pub seq: u32,
    pub payload: Vec<u8>,
}

impl Frame {
    /// Total bytes this frame occupied on the wire.
    pub fn wire_bytes(&self) -> u64 {
        (FRAME_HEADER_BYTES + self.payload.len() + FRAME_TRAILER_BYTES) as u64
    }
}

/// Largest payload any legitimate frame of a round can carry: the full-limb
/// ciphertext shard view, a PLAIN chunk, or the BEGIN preamble — whichever
/// is biggest. The reader rejects declared lengths above this bound before
/// allocating.
pub fn frame_payload_cap(params: &CkksParams) -> usize {
    shard_wire_bytes(params, 0, params.num_limbs())
        .max(PLAIN_CHUNK_VALUES * 4)
        .max(BEGIN_PAYLOAD_BYTES)
}

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc_table();

/// CRC-32 (IEEE 802.3, reflected).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Write one frame; returns the bytes put on the wire.
pub fn write_frame<W: Write>(
    w: &mut W,
    round: u64,
    kind: FrameKind,
    seq: u32,
    payload: &[u8],
) -> std::io::Result<u64> {
    let mut hdr = [0u8; FRAME_HEADER_BYTES];
    hdr[0..4].copy_from_slice(&FRAME_MAGIC.to_le_bytes());
    hdr[4..8].copy_from_slice(&PROTOCOL_VERSION.to_le_bytes());
    hdr[8..16].copy_from_slice(&round.to_le_bytes());
    hdr[16..20].copy_from_slice(&(kind as u32).to_le_bytes());
    hdr[20..24].copy_from_slice(&seq.to_le_bytes());
    hdr[24..28].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    w.write_all(&hdr)?;
    w.write_all(payload)?;
    w.write_all(&crc32(payload).to_le_bytes())?;
    Ok((FRAME_HEADER_BYTES + payload.len() + FRAME_TRAILER_BYTES) as u64)
}

fn read_exact_or(r: &mut impl Read, buf: &mut [u8], what: &str) -> anyhow::Result<()> {
    r.read_exact(buf)
        .map_err(|e| anyhow::anyhow!("truncated {what}: {e}"))
}

/// Read and validate one frame. `max_payload` bounds the allocation made for
/// the declared payload length ([`frame_payload_cap`] on the server side).
pub fn read_frame<R: Read>(
    r: &mut R,
    expect_round: u64,
    max_payload: usize,
) -> anyhow::Result<Frame> {
    let mut hdr = [0u8; FRAME_HEADER_BYTES];
    read_exact_or(r, &mut hdr, "frame header")?;
    let magic = u32::from_le_bytes(hdr[0..4].try_into().unwrap());
    anyhow::ensure!(magic == FRAME_MAGIC, "bad frame magic {magic:#010x}");
    let version = u32::from_le_bytes(hdr[4..8].try_into().unwrap());
    anyhow::ensure!(
        version == PROTOCOL_VERSION,
        "protocol version skew: got {version}, expected {PROTOCOL_VERSION}"
    );
    let round = u64::from_le_bytes(hdr[8..16].try_into().unwrap());
    anyhow::ensure!(
        round == expect_round,
        "frame for round {round}, expected {expect_round}"
    );
    let kind = FrameKind::from_u32(u32::from_le_bytes(hdr[16..20].try_into().unwrap()))?;
    let seq = u32::from_le_bytes(hdr[20..24].try_into().unwrap());
    let len = u32::from_le_bytes(hdr[24..28].try_into().unwrap()) as usize;
    anyhow::ensure!(
        len <= max_payload,
        "declared payload length {len} exceeds cap {max_payload}"
    );
    let mut payload = vec![0u8; len];
    read_exact_or(r, &mut payload, "frame payload")?;
    let mut crc = [0u8; FRAME_TRAILER_BYTES];
    read_exact_or(r, &mut crc, "frame crc")?;
    anyhow::ensure!(
        u32::from_le_bytes(crc) == crc32(&payload),
        "frame crc mismatch"
    );
    Ok(Frame { kind, seq, payload })
}

/// Encode a BEGIN payload.
pub fn encode_begin(
    client: u64,
    alpha: f64,
    n_cts: usize,
    n_plain: usize,
    total: usize,
) -> [u8; BEGIN_PAYLOAD_BYTES] {
    let mut p = [0u8; BEGIN_PAYLOAD_BYTES];
    p[0..8].copy_from_slice(&client.to_le_bytes());
    p[8..16].copy_from_slice(&alpha.to_le_bytes());
    p[16..20].copy_from_slice(&(n_cts as u32).to_le_bytes());
    p[20..24].copy_from_slice(&(n_plain as u32).to_le_bytes());
    p[24..32].copy_from_slice(&(total as u64).to_le_bytes());
    p
}

/// Decode a BEGIN payload: `(client, alpha, n_cts, n_plain, total)`.
pub fn decode_begin(p: &[u8]) -> anyhow::Result<(u64, f64, usize, usize, usize)> {
    anyhow::ensure!(
        p.len() == BEGIN_PAYLOAD_BYTES,
        "BEGIN payload must be {BEGIN_PAYLOAD_BYTES} bytes, got {}",
        p.len()
    );
    let client = u64::from_le_bytes(p[0..8].try_into().unwrap());
    let alpha = f64::from_le_bytes(p[8..16].try_into().unwrap());
    let n_cts = u32::from_le_bytes(p[16..20].try_into().unwrap()) as usize;
    let n_plain = u32::from_le_bytes(p[20..24].try_into().unwrap()) as usize;
    let total = u64::from_le_bytes(p[24..32].try_into().unwrap()) as usize;
    anyhow::ensure!(
        alpha.is_finite() && alpha > 0.0 && alpha <= 1.0,
        "FedAvg weight out of range: {alpha}"
    );
    Ok((client, alpha, n_cts, n_plain, total))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn crc32_matches_ieee_check_value() {
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn frame_roundtrip() {
        let payload: Vec<u8> = (0..200u16).map(|v| (v % 251) as u8).collect();
        let mut wire = Vec::new();
        let n = write_frame(&mut wire, 7, FrameKind::CtChunk, 3, &payload).unwrap();
        assert_eq!(n as usize, wire.len());
        let f = read_frame(&mut Cursor::new(&wire), 7, 4096).unwrap();
        assert_eq!(f.kind, FrameKind::CtChunk);
        assert_eq!(f.seq, 3);
        assert_eq!(f.payload, payload);
        assert_eq!(f.wire_bytes(), n);
    }

    #[test]
    fn begin_payload_roundtrip_and_validation() {
        let p = encode_begin(42, 0.25, 8, 1000, 9000);
        let (client, alpha, n_cts, n_plain, total) = decode_begin(&p).unwrap();
        assert_eq!(
            (client, alpha, n_cts, n_plain, total),
            (42, 0.25, 8, 1000, 9000)
        );
        // malformed weights are rejected
        for bad in [f64::NAN, f64::INFINITY, -0.5, 0.0, 1.5] {
            let p = encode_begin(1, bad, 1, 1, 1);
            assert!(decode_begin(&p).is_err(), "alpha {bad} accepted");
        }
        assert!(decode_begin(&p[..31]).is_err());
    }

    #[test]
    fn malformed_frames_rejected_not_panicking() {
        let payload = vec![9u8; 64];
        let mut wire = Vec::new();
        write_frame(&mut wire, 5, FrameKind::Plain, 0, &payload).unwrap();

        // truncation at every boundary: header, payload, crc
        for cut in [1, FRAME_HEADER_BYTES - 1, FRAME_HEADER_BYTES + 10, wire.len() - 1] {
            assert!(
                read_frame(&mut Cursor::new(&wire[..cut]), 5, 4096).is_err(),
                "cut at {cut} accepted"
            );
        }
        // bad magic
        let mut b = wire.clone();
        b[0] ^= 0xFF;
        assert!(read_frame(&mut Cursor::new(&b), 5, 4096).is_err());
        // version skew
        let mut b = wire.clone();
        b[4..8].copy_from_slice(&2u32.to_le_bytes());
        assert!(read_frame(&mut Cursor::new(&b), 5, 4096).is_err());
        // wrong round
        assert!(read_frame(&mut Cursor::new(&wire), 6, 4096).is_err());
        // unknown kind
        let mut b = wire.clone();
        b[16..20].copy_from_slice(&99u32.to_le_bytes());
        assert!(read_frame(&mut Cursor::new(&b), 5, 4096).is_err());
        // garbage crc
        let mut b = wire.clone();
        let last = b.len() - 1;
        b[last] ^= 0x55;
        assert!(read_frame(&mut Cursor::new(&b), 5, 4096).is_err());
        // corrupted payload byte → crc mismatch
        let mut b = wire.clone();
        b[FRAME_HEADER_BYTES + 3] ^= 0x01;
        assert!(read_frame(&mut Cursor::new(&b), 5, 4096).is_err());
    }

    #[test]
    fn every_single_byte_corruption_parses_or_errors_never_panics() {
        let payload = vec![7u8; 96];
        let mut wire = Vec::new();
        write_frame(&mut wire, 11, FrameKind::CtChunk, 2, &payload).unwrap();
        for i in 0..wire.len() {
            let mut b = wire.clone();
            b[i] ^= 0x80;
            let _ = read_frame(&mut Cursor::new(&b), 11, 4096);
        }
    }

    #[test]
    fn oversized_declared_length_rejected_before_allocating() {
        // a frame header declaring a u32::MAX payload must be rejected by
        // the cap check, not by attempting a 4 GiB allocation
        let mut hdr = [0u8; FRAME_HEADER_BYTES];
        hdr[0..4].copy_from_slice(&FRAME_MAGIC.to_le_bytes());
        hdr[4..8].copy_from_slice(&PROTOCOL_VERSION.to_le_bytes());
        hdr[8..16].copy_from_slice(&3u64.to_le_bytes());
        hdr[16..20].copy_from_slice(&(FrameKind::CtChunk as u32).to_le_bytes());
        hdr[24..28].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = read_frame(&mut Cursor::new(&hdr[..]), 3, 1 << 20).unwrap_err();
        assert!(err.to_string().contains("exceeds cap"), "{err}");
    }

    #[test]
    fn payload_cap_covers_ct_and_plain_frames() {
        let params = CkksParams::new(256, 3, 30).unwrap();
        let cap = frame_payload_cap(&params);
        assert!(cap >= shard_wire_bytes(&params, 0, params.num_limbs()));
        assert!(cap >= PLAIN_CHUNK_VALUES * 4);
        assert!(cap >= BEGIN_PAYLOAD_BYTES);
    }
}
