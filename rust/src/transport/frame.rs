//! Length-framed binary session protocol (DESIGN.md §8–§9).
//!
//! Every message on the wire is one frame:
//!
//! ```text
//! magic    u32   = 0x46485450 ("FHTP")
//! version  u32   = 1
//! round    u64   round id (both sides reject skew)
//! kind     u32   frame kind (see [`FrameKind`])
//! seq      u32   chunk sequence (ciphertext index / plaintext chunk index)
//! len      u32   payload byte length
//! payload  len bytes
//! crc      u32   CRC-32 (IEEE) of the payload
//! ```
//!
//! Uplink kinds (client → server): BEGIN/CT_CHUNK/PLAIN/END plus the
//! session handshake HELLO. Downlink kinds (server → client): ACK, WELCOME,
//! MASK and DOWN_BEGIN/CT_CHUNK/PLAIN/DOWN_END (with a FIN flag in the
//! DOWN_BEGIN preamble) — the persistent-session broadcast path of
//! DESIGN.md §9. Handshake frames travel under
//! [`CONTROL_ROUND`], the mask-agreement stage under [`MASK_ROUND`], and
//! training round `r` under round id `r`, so one duplex connection serves
//! the whole task without rounds bleeding into each other. A STATS frame in
//! place of a HELLO queries the coordinator's live metrics snapshot
//! (STATS_REPLY carries JSON; the `stats` CLI subcommand) without claiming
//! a session slot.
//!
//! The reader validates magic, version, round, kind and `len` **before**
//! allocating the payload buffer: `len` is capped by a params-derived bound
//! ([`frame_payload_cap`]), so an attacker-controlled length prefix can never
//! drive an allocation beyond one legitimate frame. Truncation (EOF anywhere
//! inside a frame), CRC mismatch, version skew and unknown kinds all return
//! `Err` — the connection's upload is then discarded as a dropped straggler,
//! never a panic or a poisoned round. [`read_frame_into`] reuses one
//! per-connection payload buffer across frames, so steady-state frame reads
//! are allocation-free (gated by `tests/zero_alloc.rs`).
//!
//! **Authenticated mode** (`--wire-auth mac`, DESIGN.md §12): after the
//! CHALLENGE/CHALLENGE_RESP handshake both directions append a 12-byte auth
//! trailer to every frame — `auth_seq u32` (per-session, per-direction,
//! strictly monotone) followed by a truncated SipHash-2-4 tag over
//! `dir ‖ auth_seq ‖ header ‖ payload ‖ crc`. The reader verifies the tag
//! **before** trusting any header field beyond the length (the length must
//! be read to consume the frame), then enforces the monotone sequence: a
//! bad tag counts an `auth_reject`, a stale sequence (a replayed or
//! duplicated frame) counts a `replay_reject`, and in both cases the frame
//! is discarded and the reader continues — framing stays aligned because
//! the rejected frame consumed exactly its declared bytes.

use crate::ckks::serialize::shard_wire_bytes;
use crate::ckks::{CkksParams, CtWire};
use std::io::{Read, Write};

/// Frame magic: "FHTP" (FedML-HE transport protocol).
pub const FRAME_MAGIC: u32 = 0x4648_5450;
/// Wire protocol version; bumped on any layout change.
pub const PROTOCOL_VERSION: u32 = 1;
/// Fixed frame header size: magic(4) version(4) round(8) kind(4) seq(4) len(4).
pub const FRAME_HEADER_BYTES: usize = 28;
/// Fixed frame trailer size: payload CRC-32.
pub const FRAME_TRAILER_BYTES: usize = 4;
/// Authenticated-mode trailer appended after the CRC: auth_seq(4) + tag(8).
pub const AUTH_TRAILER_BYTES: usize = 12;
/// MAC direction byte for client → server frames.
pub const AUTH_DIR_UP: u8 = 1;
/// MAC direction byte for server → client frames.
pub const AUTH_DIR_DOWN: u8 = 2;
/// BEGIN payload: client(8) alpha(8) n_cts(4) n_plain(4) total(8).
pub const BEGIN_PAYLOAD_BYTES: usize = 32;
/// END payload when the client reports its local compute metrics:
/// train_secs(8 f64) encrypt_secs(8 f64) loss(4 f32) pad(4). An empty END
/// is also accepted (metrics default to zero).
pub const END_TIMING_PAYLOAD_BYTES: usize = 24;
/// HELLO payload: client(8) + ciphertext wire mode code(4)
/// ([`CtWire::wire_code`]) — the client announces how it will serialize
/// ciphertext uplinks so a mode mismatch fails at the handshake, not
/// mid-round.
pub const HELLO_PAYLOAD_BYTES: usize = 12;
/// WELCOME payload: next round the server will serve on this session (8) +
/// the server's ciphertext wire mode code(4). A client whose announced mode
/// differs from the server's is never welcomed.
pub const WELCOME_PAYLOAD_BYTES: usize = 12;
/// CHALLENGE payload: the server's 16-byte session nonce.
pub const CHALLENGE_PAYLOAD_BYTES: usize = 16;
/// CHALLENGE_RESP payload: client id echo(8) + SipHash proof tag(8).
pub const CHALLENGE_RESP_PAYLOAD_BYTES: usize = 16;
/// DOWN_BEGIN payload: alpha(8) alpha_mass(8) n_cts(4) n_plain(4) total(8)
/// flags(4).
pub const DOWN_BEGIN_PAYLOAD_BYTES: usize = 36;
/// f32 values per PLAIN frame (256 KiB of payload).
pub const PLAIN_CHUNK_VALUES: usize = 65_536;

/// Round id carried by session-handshake frames (HELLO/WELCOME) — outside
/// the training-round id space.
pub const CONTROL_ROUND: u64 = u64::MAX;
/// Round id of the mask-agreement stage (sensitivity uploads + the MASK
/// broadcast), which precedes training round 0.
pub const MASK_ROUND: u64 = u64::MAX - 1;

/// DOWN_BEGIN flag: the receiving client participates in this round
/// (train + encrypt + upload).
pub const DOWN_FLAG_PARTICIPATE: u32 = 1;
/// DOWN_BEGIN flag: ciphertext/plain frames carrying the previous round's
/// partially-encrypted aggregate follow before DOWN_END.
pub const DOWN_FLAG_HAS_AGG: u32 = 2;
/// DOWN_BEGIN flag: the task is complete after this downlink; the client
/// applies the carried aggregate (if any) and exits its session loop.
pub const DOWN_FLAG_FIN: u32 = 4;

/// What a frame carries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameKind {
    /// Upload preamble: client identity, FedAvg weight, declared shape.
    Begin = 1,
    /// One ciphertext chunk: a full-limb-range shard view
    /// (`ckks::serialize::ciphertext_shard_to_bytes(ct, 0, limbs)`).
    /// Travels uplink (update chunks) and downlink (aggregate chunks).
    CtChunk = 2,
    /// A slice of the compacted plaintext remainder (f32 LE, in order).
    /// Travels uplink and downlink like [`FrameKind::CtChunk`].
    Plain = 3,
    /// Upload complete; the server stamps the arrival here. Payload is
    /// empty or the client's measured timings
    /// ([`END_TIMING_PAYLOAD_BYTES`]).
    End = 4,
    /// Server receipt (u32 LE status, 0 = received).
    Ack = 5,
    /// Session handshake, client → server: claim a persistent client slot
    /// (a reconnect with the same id rebinds the slot — DESIGN.md §9).
    Hello = 6,
    /// Session handshake reply, server → client: slot accepted.
    Welcome = 7,
    /// Downlink broadcast of the agreed encryption mask (run-delta bytes,
    /// `he_agg::mask::MaskLayout` wire format).
    Mask = 8,
    /// Downlink round preamble: this client's normalized FedAvg weight,
    /// the carried aggregate's renormalizer + shape, and the round flags.
    DownBegin = 9,
    /// Downlink round complete (empty payload).
    DownEnd = 10,
    /// Metrics query, client → server, under [`CONTROL_ROUND`] in place of
    /// a HELLO (empty payload). The server answers with
    /// [`FrameKind::StatsReply`] and closes — no session slot is claimed.
    Stats = 11,
    /// Metrics query reply, server → client: the coordinator's
    /// `obs::metrics::snapshot()` as UTF-8 JSON.
    StatsReply = 12,
    /// Authenticated-handshake challenge, server → client (after HELLO,
    /// under [`CONTROL_ROUND`]): a fresh 16-byte session nonce. Sent only
    /// when the coordinator runs `--wire-auth mac`.
    Challenge = 13,
    /// Authenticated-handshake response, client → server: the claimed
    /// client id plus a SipHash proof over (nonce, id) under the derived
    /// session key ([`crate::crypto::mac::handshake_tag`]).
    ChallengeResp = 14,
}

impl FrameKind {
    /// Decode a wire kind id (the inverse of `kind as u32`).
    pub fn from_u32(v: u32) -> anyhow::Result<Self> {
        Ok(match v {
            1 => FrameKind::Begin,
            2 => FrameKind::CtChunk,
            3 => FrameKind::Plain,
            4 => FrameKind::End,
            5 => FrameKind::Ack,
            6 => FrameKind::Hello,
            7 => FrameKind::Welcome,
            8 => FrameKind::Mask,
            9 => FrameKind::DownBegin,
            10 => FrameKind::DownEnd,
            11 => FrameKind::Stats,
            12 => FrameKind::StatsReply,
            13 => FrameKind::Challenge,
            14 => FrameKind::ChallengeResp,
            other => anyhow::bail!("unknown frame kind {other}"),
        })
    }
}

/// One parsed frame.
#[derive(Debug, Clone)]
pub struct Frame {
    pub kind: FrameKind,
    pub seq: u32,
    pub payload: Vec<u8>,
}

impl Frame {
    /// Total bytes this frame occupied on the wire.
    pub fn wire_bytes(&self) -> u64 {
        (FRAME_HEADER_BYTES + self.payload.len() + FRAME_TRAILER_BYTES) as u64
    }
}

/// Largest payload any legitimate frame of a round can carry: the full-limb
/// ciphertext shard view, a PLAIN chunk, or the BEGIN preamble — whichever
/// is biggest. The reader rejects declared lengths above this bound before
/// allocating.
pub fn frame_payload_cap(params: &CkksParams) -> usize {
    shard_wire_bytes(params, 0, params.num_limbs())
        .max(PLAIN_CHUNK_VALUES * 4)
        .max(BEGIN_PAYLOAD_BYTES)
}

/// Upper bound on a MASK downlink payload for a `total`-parameter model.
/// The run-delta wire format (`he_agg::mask::MaskLayout::to_bytes`) is a
/// 12-byte header plus two varints per run; a mask over `total` params has
/// at most `⌈total/2⌉` runs (alternating mask) and each run's two varints
/// cost at most 10 bytes, so `5·total` dominates every legitimate mask —
/// including paper-scale fragmented random masks. The client-side reader
/// trusts the server it dialed more than the server trusts anonymous
/// uploaders, but the cap still bounds any single allocation.
pub fn mask_payload_cap(total: usize) -> usize {
    64 + 5 * total.max(16)
}

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc_table();

/// CRC-32 (IEEE 802.3, reflected).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Outbound frame-authentication state for one direction of one session:
/// the session key, this sender's direction byte, and the monotone auth
/// sequence the receiver checks replays against.
#[derive(Debug)]
pub struct TxAuth {
    key: crate::crypto::mac::MacKey,
    dir: u8,
    seq: u32,
}

impl TxAuth {
    pub fn new(key: crate::crypto::mac::MacKey, dir: u8) -> Self {
        TxAuth { key, dir, seq: 0 }
    }
}

/// Inbound frame-authentication state: the session key, the direction byte
/// the peer must have tagged with, and the highest auth sequence accepted
/// so far (strictly-greater check — the replay window is "never again").
#[derive(Debug)]
pub struct RxAuth {
    key: crate::crypto::mac::MacKey,
    dir: u8,
    last: u32,
}

impl RxAuth {
    pub fn new(key: crate::crypto::mac::MacKey, dir: u8) -> Self {
        RxAuth { key, dir, last: 0 }
    }
}

fn frame_header(round: u64, kind: FrameKind, seq: u32, len: usize) -> [u8; FRAME_HEADER_BYTES] {
    let mut hdr = [0u8; FRAME_HEADER_BYTES];
    hdr[0..4].copy_from_slice(&FRAME_MAGIC.to_le_bytes());
    hdr[4..8].copy_from_slice(&PROTOCOL_VERSION.to_le_bytes());
    hdr[8..16].copy_from_slice(&round.to_le_bytes());
    hdr[16..20].copy_from_slice(&(kind as u32).to_le_bytes());
    hdr[20..24].copy_from_slice(&seq.to_le_bytes());
    hdr[24..28].copy_from_slice(&(len as u32).to_le_bytes());
    hdr
}

/// Write one frame; returns the bytes put on the wire. Legacy
/// (unauthenticated) layout — see [`write_frame_with`] for the MAC path.
pub fn write_frame<W: Write>(
    w: &mut W,
    round: u64,
    kind: FrameKind,
    seq: u32,
    payload: &[u8],
) -> std::io::Result<u64> {
    write_frame_with(w, round, kind, seq, payload, &mut None)
}

/// Write one frame, appending the 12-byte auth trailer when `auth` carries
/// session state (`None` = legacy wire, bit-identical to [`write_frame`]).
pub fn write_frame_with<W: Write>(
    w: &mut W,
    round: u64,
    kind: FrameKind,
    seq: u32,
    payload: &[u8],
    auth: &mut Option<TxAuth>,
) -> std::io::Result<u64> {
    let hdr = frame_header(round, kind, seq, payload.len());
    let crc = crc32(payload);
    w.write_all(&hdr)?;
    w.write_all(payload)?;
    w.write_all(&crc.to_le_bytes())?;
    let mut wire = (FRAME_HEADER_BYTES + payload.len() + FRAME_TRAILER_BYTES) as u64;
    if let Some(tx) = auth {
        tx.seq = tx.seq.checked_add(1).ok_or_else(|| {
            std::io::Error::new(std::io::ErrorKind::Other, "session auth sequence exhausted")
        })?;
        let tag = crate::crypto::mac::frame_tag(&tx.key, tx.dir, tx.seq, &hdr, payload, crc);
        w.write_all(&tx.seq.to_le_bytes())?;
        w.write_all(&tag.to_le_bytes())?;
        wire += AUTH_TRAILER_BYTES as u64;
    }
    crate::obs::metrics::frame_sent(kind as u32, wire);
    Ok(wire)
}

fn read_exact_or(r: &mut impl Read, buf: &mut [u8], what: &str) -> anyhow::Result<()> {
    r.read_exact(buf)
        .map_err(|e| anyhow::anyhow!("truncated {what}: {e}"))
}

/// Cap on consecutive auth/replay-rejected frames the reader will discard
/// before giving up on the connection: bounds the work a flooding peer can
/// extract while letting honest sessions ride out injected faults. Shared
/// with the nonblocking decoder (`transport::machine`), which enforces the
/// same bound across `validate_wire_frame` calls.
pub(crate) const MAX_CONSECUTIVE_AUTH_REJECTS: usize = 4096;

/// Payload length a frame header declares (bytes 24..28). The caller must
/// hand at least [`FRAME_HEADER_BYTES`]; only the length field is read —
/// nothing else in the header is trusted until the frame validates.
pub(crate) fn frame_declared_len(hdr: &[u8]) -> usize {
    u32::from_le_bytes(hdr[24..28].try_into().unwrap()) as usize
}

/// Verdict of [`validate_wire_frame`] over one complete in-memory frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum WireVerdict {
    /// Frame accepted; the payload is
    /// `frame[FRAME_HEADER_BYTES..FRAME_HEADER_BYTES + len]`.
    Accept { round: u64, kind: FrameKind, seq: u32 },
    /// Authenticated frame whose MAC tag failed — counted, discard the
    /// frame and keep the stream (framing stays aligned).
    AuthReject,
    /// Tag verified but the auth sequence was not strictly monotone (a
    /// replay/duplicate) — counted, discard and keep the stream.
    ReplayReject,
}

/// Validate one **complete** wire frame held in memory — the buffer-in
/// twin of [`read_frame_any_round_into_with`], used by the nonblocking
/// session hub where frames are reassembled from partial reads before
/// validation. `frame` must span exactly header ‖ payload ‖ crc
/// (‖ auth trailer when `auth` is armed); the decoder guarantees this by
/// sizing the slice from the header's length field.
///
/// Semantics mirror the blocking reader bit for bit: the MAC is verified
/// before any header field beyond the length is trusted, a bad tag or
/// stale sequence is a counted soft reject (`Ok(AuthReject/ReplayReject)` —
/// the caller discards and continues, bounding the run with
/// [`MAX_CONSECUTIVE_AUTH_REJECTS`]), and malformed framing
/// (magic/version/kind/crc) is a hard `Err` that kills the connection.
pub(crate) fn validate_wire_frame(
    frame: &[u8],
    auth: &mut Option<RxAuth>,
) -> anyhow::Result<WireVerdict> {
    let reject = |msg: String| {
        crate::obs::metrics::frame_reject();
        anyhow::anyhow!(msg)
    };
    anyhow::ensure!(
        frame.len() >= FRAME_HEADER_BYTES + FRAME_TRAILER_BYTES,
        "truncated frame: {} bytes",
        frame.len()
    );
    let hdr: &[u8; FRAME_HEADER_BYTES] = frame[..FRAME_HEADER_BYTES].try_into().unwrap();
    let len = frame_declared_len(hdr);
    let auth_extra = if auth.is_some() { AUTH_TRAILER_BYTES } else { 0 };
    anyhow::ensure!(
        frame.len() == FRAME_HEADER_BYTES + len + FRAME_TRAILER_BYTES + auth_extra,
        "frame slice/declared-length mismatch: {} bytes for payload {len}",
        frame.len()
    );
    let payload = &frame[FRAME_HEADER_BYTES..FRAME_HEADER_BYTES + len];
    let crc_at = FRAME_HEADER_BYTES + len;
    let crc = u32::from_le_bytes(frame[crc_at..crc_at + 4].try_into().unwrap());
    if let Some(rx) = auth.as_mut() {
        let trailer = &frame[crc_at + 4..];
        let auth_seq = u32::from_le_bytes(trailer[0..4].try_into().unwrap());
        let tag = u64::from_le_bytes(trailer[4..12].try_into().unwrap());
        let want = crate::crypto::mac::frame_tag(&rx.key, rx.dir, auth_seq, hdr, payload, crc);
        if tag != want {
            crate::obs::metrics::auth_reject();
            return Ok(WireVerdict::AuthReject);
        }
        if auth_seq <= rx.last {
            crate::obs::metrics::replay_reject();
            return Ok(WireVerdict::ReplayReject);
        }
        rx.last = auth_seq;
    }
    let magic = u32::from_le_bytes(hdr[0..4].try_into().unwrap());
    if magic != FRAME_MAGIC {
        return Err(reject(format!("bad frame magic {magic:#010x}")));
    }
    let version = u32::from_le_bytes(hdr[4..8].try_into().unwrap());
    if version != PROTOCOL_VERSION {
        return Err(reject(format!(
            "protocol version skew: got {version}, expected {PROTOCOL_VERSION}"
        )));
    }
    let round = u64::from_le_bytes(hdr[8..16].try_into().unwrap());
    let kind = FrameKind::from_u32(u32::from_le_bytes(hdr[16..20].try_into().unwrap()))
        .map_err(|e| reject(e.to_string()))?;
    let seq = u32::from_le_bytes(hdr[20..24].try_into().unwrap());
    if crc != crc32(payload) {
        crate::obs::metrics::crc_reject();
        anyhow::bail!("frame crc mismatch");
    }
    crate::obs::metrics::frame_received(kind as u32, frame.len() as u64);
    Ok(WireVerdict::Accept { round, kind, seq })
}

/// Read one frame of **any** round into a caller-pooled buffer, returning
/// `(round, kind, seq)` — the round-flexible core used by the mid-round
/// rejoin replay path, where a reconnecting client may legitimately see a
/// MASK frame ([`MASK_ROUND`]) followed by the current round's downlink.
///
/// In authenticated mode (`auth` is `Some`) the MAC is verified **before**
/// any header validation: a frame that fails the tag or the monotone
/// sequence check is counted (`auth_rejects` / `replay_rejects`),
/// discarded, and the next frame is read — the stream stays aligned
/// because the rejected frame consumed exactly its declared bytes. Only
/// the length field is trusted pre-MAC (it must be, to consume the frame);
/// a corrupted length surfaces as a short read or cap reject, never an
/// unbounded allocation.
pub(crate) fn read_frame_any_round_into_with<R: Read>(
    r: &mut R,
    max_payload: usize,
    payload: &mut Vec<u8>,
    auth: &mut Option<RxAuth>,
) -> anyhow::Result<(u64, FrameKind, u32)> {
    let reject = |msg: String| {
        crate::obs::metrics::frame_reject();
        anyhow::anyhow!(msg)
    };
    let mut rejected = 0usize;
    loop {
        let mut hdr = [0u8; FRAME_HEADER_BYTES];
        read_exact_or(r, &mut hdr, "frame header")?;
        let len = u32::from_le_bytes(hdr[24..28].try_into().unwrap()) as usize;
        if len > max_payload {
            return Err(reject(format!(
                "declared payload length {len} exceeds cap {max_payload}"
            )));
        }
        payload.clear();
        payload.resize(len, 0);
        read_exact_or(r, payload, "frame payload")?;
        let mut crc = [0u8; FRAME_TRAILER_BYTES];
        read_exact_or(r, &mut crc, "frame crc")?;
        let crc = u32::from_le_bytes(crc);
        let mut wire = (FRAME_HEADER_BYTES + len + FRAME_TRAILER_BYTES) as u64;
        if let Some(rx) = auth.as_mut() {
            let mut trailer = [0u8; AUTH_TRAILER_BYTES];
            read_exact_or(r, &mut trailer, "frame auth trailer")?;
            wire += AUTH_TRAILER_BYTES as u64;
            let auth_seq = u32::from_le_bytes(trailer[0..4].try_into().unwrap());
            let tag = u64::from_le_bytes(trailer[4..12].try_into().unwrap());
            let want = crate::crypto::mac::frame_tag(&rx.key, rx.dir, auth_seq, &hdr, payload, crc);
            // MAC first: nothing in the header is trusted until the tag
            // verifies; then the strictly-monotone sequence kills replays
            if tag != want {
                crate::obs::metrics::auth_reject();
                rejected += 1;
                anyhow::ensure!(
                    rejected <= MAX_CONSECUTIVE_AUTH_REJECTS,
                    "too many consecutive auth-rejected frames ({rejected})"
                );
                continue;
            }
            if auth_seq <= rx.last {
                crate::obs::metrics::replay_reject();
                rejected += 1;
                anyhow::ensure!(
                    rejected <= MAX_CONSECUTIVE_AUTH_REJECTS,
                    "too many consecutive replayed frames ({rejected})"
                );
                continue;
            }
            rx.last = auth_seq;
        }
        // validation failures feed the reject counters (DESIGN.md §10) —
        // errors are off the hot path, success records one atomic add
        let magic = u32::from_le_bytes(hdr[0..4].try_into().unwrap());
        if magic != FRAME_MAGIC {
            return Err(reject(format!("bad frame magic {magic:#010x}")));
        }
        let version = u32::from_le_bytes(hdr[4..8].try_into().unwrap());
        if version != PROTOCOL_VERSION {
            return Err(reject(format!(
                "protocol version skew: got {version}, expected {PROTOCOL_VERSION}"
            )));
        }
        let round = u64::from_le_bytes(hdr[8..16].try_into().unwrap());
        let kind = FrameKind::from_u32(u32::from_le_bytes(hdr[16..20].try_into().unwrap()))
            .map_err(|e| reject(e.to_string()))?;
        let seq = u32::from_le_bytes(hdr[20..24].try_into().unwrap());
        if crc != crc32(payload) {
            crate::obs::metrics::crc_reject();
            anyhow::bail!("frame crc mismatch");
        }
        crate::obs::metrics::frame_received(kind as u32, wire);
        return Ok((round, kind, seq));
    }
}

/// Read and validate one frame into a caller-pooled payload buffer —
/// steady-state frame reads make **zero heap allocations** once the buffer
/// has grown to the connection's largest frame (gated by
/// `tests/zero_alloc.rs`). `max_payload` bounds the buffer growth for the
/// declared payload length ([`frame_payload_cap`], or its max with
/// [`mask_payload_cap`] when a MASK broadcast may arrive). Returns
/// `(kind, seq)`; the payload is in `payload[..]` on success.
pub fn read_frame_into<R: Read>(
    r: &mut R,
    expect_round: u64,
    max_payload: usize,
    payload: &mut Vec<u8>,
) -> anyhow::Result<(FrameKind, u32)> {
    read_frame_into_with(r, expect_round, max_payload, payload, &mut None)
}

/// [`read_frame_into`] with optional frame authentication — auth/replay
/// failures are counted, discarded and skipped (see
/// [`read_frame_any_round_into_with`]); a round mismatch on an
/// *authenticated* accepted frame is a hard protocol error.
pub fn read_frame_into_with<R: Read>(
    r: &mut R,
    expect_round: u64,
    max_payload: usize,
    payload: &mut Vec<u8>,
    auth: &mut Option<RxAuth>,
) -> anyhow::Result<(FrameKind, u32)> {
    let (round, kind, seq) = read_frame_any_round_into_with(r, max_payload, payload, auth)?;
    if round != expect_round {
        crate::obs::metrics::frame_reject();
        anyhow::bail!("frame for round {round}, expected {expect_round}");
    }
    Ok((kind, seq))
}

/// Read and validate one frame into a fresh buffer (allocating convenience
/// wrapper over [`read_frame_into`]).
pub fn read_frame<R: Read>(
    r: &mut R,
    expect_round: u64,
    max_payload: usize,
) -> anyhow::Result<Frame> {
    let mut payload = Vec::new();
    let (kind, seq) = read_frame_into(r, expect_round, max_payload, &mut payload)?;
    Ok(Frame { kind, seq, payload })
}

/// Encode a BEGIN payload.
pub fn encode_begin(
    client: u64,
    alpha: f64,
    n_cts: usize,
    n_plain: usize,
    total: usize,
) -> [u8; BEGIN_PAYLOAD_BYTES] {
    let mut p = [0u8; BEGIN_PAYLOAD_BYTES];
    p[0..8].copy_from_slice(&client.to_le_bytes());
    p[8..16].copy_from_slice(&alpha.to_le_bytes());
    p[16..20].copy_from_slice(&(n_cts as u32).to_le_bytes());
    p[20..24].copy_from_slice(&(n_plain as u32).to_le_bytes());
    p[24..32].copy_from_slice(&(total as u64).to_le_bytes());
    p
}

/// Decode a BEGIN payload: `(client, alpha, n_cts, n_plain, total)`.
pub fn decode_begin(p: &[u8]) -> anyhow::Result<(u64, f64, usize, usize, usize)> {
    anyhow::ensure!(
        p.len() == BEGIN_PAYLOAD_BYTES,
        "BEGIN payload must be {BEGIN_PAYLOAD_BYTES} bytes, got {}",
        p.len()
    );
    let client = u64::from_le_bytes(p[0..8].try_into().unwrap());
    let alpha = f64::from_le_bytes(p[8..16].try_into().unwrap());
    let n_cts = u32::from_le_bytes(p[16..20].try_into().unwrap()) as usize;
    let n_plain = u32::from_le_bytes(p[20..24].try_into().unwrap()) as usize;
    let total = u64::from_le_bytes(p[24..32].try_into().unwrap()) as usize;
    anyhow::ensure!(
        alpha.is_finite() && alpha > 0.0 && alpha <= 1.0,
        "FedAvg weight out of range: {alpha}"
    );
    Ok((client, alpha, n_cts, n_plain, total))
}

/// Encode an END payload carrying the client's measured local metrics.
pub fn encode_end_timing(
    train_secs: f64,
    encrypt_secs: f64,
    loss: f32,
) -> [u8; END_TIMING_PAYLOAD_BYTES] {
    let mut p = [0u8; END_TIMING_PAYLOAD_BYTES];
    p[0..8].copy_from_slice(&train_secs.to_le_bytes());
    p[8..16].copy_from_slice(&encrypt_secs.to_le_bytes());
    p[16..20].copy_from_slice(&loss.to_le_bytes());
    p
}

/// Decode an END payload: `(train_secs, encrypt_secs, loss)`. An empty
/// payload (a client that does not report metrics) decodes to zeros; any
/// other length, or non-finite / negative timings, is malformed.
pub fn decode_end_timing(p: &[u8]) -> anyhow::Result<(f64, f64, f32)> {
    if p.is_empty() {
        return Ok((0.0, 0.0, 0.0));
    }
    anyhow::ensure!(
        p.len() == END_TIMING_PAYLOAD_BYTES,
        "END payload must be empty or {END_TIMING_PAYLOAD_BYTES} bytes, got {}",
        p.len()
    );
    let train = f64::from_le_bytes(p[0..8].try_into().unwrap());
    let encrypt = f64::from_le_bytes(p[8..16].try_into().unwrap());
    let loss = f32::from_le_bytes(p[16..20].try_into().unwrap());
    anyhow::ensure!(
        p[20..24] == [0u8; 4],
        "bad END payload padding"
    );
    anyhow::ensure!(
        train.is_finite() && train >= 0.0 && encrypt.is_finite() && encrypt >= 0.0,
        "END timings out of range: train {train}, encrypt {encrypt}"
    );
    anyhow::ensure!(loss.is_finite(), "non-finite END loss {loss}");
    Ok((train, encrypt, loss))
}

/// Encode a HELLO payload: claimed client id + announced ciphertext wire
/// mode.
pub fn encode_hello(client: u64, ct_wire: CtWire) -> [u8; HELLO_PAYLOAD_BYTES] {
    let mut p = [0u8; HELLO_PAYLOAD_BYTES];
    p[0..8].copy_from_slice(&client.to_le_bytes());
    p[8..12].copy_from_slice(&ct_wire.wire_code().to_le_bytes());
    p
}

/// Decode a HELLO payload into `(client, ct_wire)`. A pre-ct-wire 8-byte
/// HELLO (or any unknown mode code) is malformed — the handshake fails
/// loudly instead of silently disagreeing on the uplink format.
pub fn decode_hello(p: &[u8]) -> anyhow::Result<(u64, CtWire)> {
    anyhow::ensure!(
        p.len() == HELLO_PAYLOAD_BYTES,
        "HELLO payload must be {HELLO_PAYLOAD_BYTES} bytes, got {}",
        p.len()
    );
    let client = u64::from_le_bytes(p[0..8].try_into().unwrap());
    let code = u32::from_le_bytes(p[8..12].try_into().unwrap());
    let ct_wire = CtWire::from_wire_code(code)
        .ok_or_else(|| anyhow::anyhow!("unknown ciphertext wire mode code {code}"))?;
    Ok((client, ct_wire))
}

/// Encode a WELCOME payload: the next round the server will serve on this
/// session ([`MASK_ROUND`] while the mask-agreement stage is pending) plus
/// the server's ciphertext wire mode.
pub fn encode_welcome(next_round: u64, ct_wire: CtWire) -> [u8; WELCOME_PAYLOAD_BYTES] {
    let mut p = [0u8; WELCOME_PAYLOAD_BYTES];
    p[0..8].copy_from_slice(&next_round.to_le_bytes());
    p[8..12].copy_from_slice(&ct_wire.wire_code().to_le_bytes());
    p
}

/// Decode a WELCOME payload into `(next_round, ct_wire)`.
pub fn decode_welcome(p: &[u8]) -> anyhow::Result<(u64, CtWire)> {
    anyhow::ensure!(
        p.len() == WELCOME_PAYLOAD_BYTES,
        "WELCOME payload must be {WELCOME_PAYLOAD_BYTES} bytes, got {}",
        p.len()
    );
    let round = u64::from_le_bytes(p[0..8].try_into().unwrap());
    let code = u32::from_le_bytes(p[8..12].try_into().unwrap());
    let ct_wire = CtWire::from_wire_code(code)
        .ok_or_else(|| anyhow::anyhow!("unknown ciphertext wire mode code {code}"))?;
    Ok((round, ct_wire))
}

/// Encode a CHALLENGE payload (the server's fresh session nonce).
pub fn encode_challenge(nonce: &[u8; 16]) -> [u8; CHALLENGE_PAYLOAD_BYTES] {
    *nonce
}

/// Decode a CHALLENGE payload into the session nonce.
pub fn decode_challenge(p: &[u8]) -> anyhow::Result<[u8; 16]> {
    anyhow::ensure!(
        p.len() == CHALLENGE_PAYLOAD_BYTES,
        "CHALLENGE payload must be {CHALLENGE_PAYLOAD_BYTES} bytes, got {}",
        p.len()
    );
    Ok(p.try_into().unwrap())
}

/// Encode a CHALLENGE_RESP payload: client id echo + handshake proof tag.
pub fn encode_challenge_resp(client: u64, tag: u64) -> [u8; CHALLENGE_RESP_PAYLOAD_BYTES] {
    let mut p = [0u8; CHALLENGE_RESP_PAYLOAD_BYTES];
    p[0..8].copy_from_slice(&client.to_le_bytes());
    p[8..16].copy_from_slice(&tag.to_le_bytes());
    p
}

/// Decode a CHALLENGE_RESP payload: `(client, proof_tag)`.
pub fn decode_challenge_resp(p: &[u8]) -> anyhow::Result<(u64, u64)> {
    anyhow::ensure!(
        p.len() == CHALLENGE_RESP_PAYLOAD_BYTES,
        "CHALLENGE_RESP payload must be {CHALLENGE_RESP_PAYLOAD_BYTES} bytes, got {}",
        p.len()
    );
    Ok((
        u64::from_le_bytes(p[0..8].try_into().unwrap()),
        u64::from_le_bytes(p[8..16].try_into().unwrap()),
    ))
}

/// What a round's DOWN_BEGIN preamble declares.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DownBegin {
    /// This client's normalized FedAvg weight for the round (0.0 when it
    /// does not participate).
    pub alpha: f64,
    /// Renormalizer for the carried aggregate (Σ α over the accepted
    /// participants of the previous round; 0.0 when no aggregate follows).
    pub alpha_mass: f64,
    pub n_cts: usize,
    pub n_plain: usize,
    pub total: usize,
    pub participate: bool,
    pub has_agg: bool,
    pub fin: bool,
}

/// Encode a DOWN_BEGIN payload.
pub fn encode_down_begin(d: &DownBegin) -> [u8; DOWN_BEGIN_PAYLOAD_BYTES] {
    let mut p = [0u8; DOWN_BEGIN_PAYLOAD_BYTES];
    p[0..8].copy_from_slice(&d.alpha.to_le_bytes());
    p[8..16].copy_from_slice(&d.alpha_mass.to_le_bytes());
    p[16..20].copy_from_slice(&(d.n_cts as u32).to_le_bytes());
    p[20..24].copy_from_slice(&(d.n_plain as u32).to_le_bytes());
    p[24..32].copy_from_slice(&(d.total as u64).to_le_bytes());
    let mut flags = 0u32;
    if d.participate {
        flags |= DOWN_FLAG_PARTICIPATE;
    }
    if d.has_agg {
        flags |= DOWN_FLAG_HAS_AGG;
    }
    if d.fin {
        flags |= DOWN_FLAG_FIN;
    }
    p[32..36].copy_from_slice(&flags.to_le_bytes());
    p
}

/// Decode and validate a DOWN_BEGIN payload.
pub fn decode_down_begin(p: &[u8]) -> anyhow::Result<DownBegin> {
    anyhow::ensure!(
        p.len() == DOWN_BEGIN_PAYLOAD_BYTES,
        "DOWN_BEGIN payload must be {DOWN_BEGIN_PAYLOAD_BYTES} bytes, got {}",
        p.len()
    );
    let alpha = f64::from_le_bytes(p[0..8].try_into().unwrap());
    let alpha_mass = f64::from_le_bytes(p[8..16].try_into().unwrap());
    let n_cts = u32::from_le_bytes(p[16..20].try_into().unwrap()) as usize;
    let n_plain = u32::from_le_bytes(p[20..24].try_into().unwrap()) as usize;
    let total = u64::from_le_bytes(p[24..32].try_into().unwrap()) as usize;
    let flags = u32::from_le_bytes(p[32..36].try_into().unwrap());
    anyhow::ensure!(
        flags & !(DOWN_FLAG_PARTICIPATE | DOWN_FLAG_HAS_AGG | DOWN_FLAG_FIN) == 0,
        "unknown DOWN_BEGIN flags {flags:#x}"
    );
    let d = DownBegin {
        alpha,
        alpha_mass,
        n_cts,
        n_plain,
        total,
        participate: flags & DOWN_FLAG_PARTICIPATE != 0,
        has_agg: flags & DOWN_FLAG_HAS_AGG != 0,
        fin: flags & DOWN_FLAG_FIN != 0,
    };
    anyhow::ensure!(
        d.alpha.is_finite() && (0.0..=1.0).contains(&d.alpha),
        "downlink FedAvg weight out of range: {}",
        d.alpha
    );
    anyhow::ensure!(
        !d.participate || d.alpha > 0.0,
        "participating round with zero FedAvg weight"
    );
    anyhow::ensure!(
        d.alpha_mass.is_finite() && d.alpha_mass >= 0.0,
        "downlink alpha mass out of range: {}",
        d.alpha_mass
    );
    anyhow::ensure!(
        !d.has_agg || d.alpha_mass > 0.0,
        "aggregate downlink with zero alpha mass"
    );
    Ok(d)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::mac::MacKey;
    use std::io::Cursor;

    fn auth_pair() -> (Option<TxAuth>, Option<RxAuth>) {
        let key = MacKey([0x5au8; 32]);
        (
            Some(TxAuth::new(key.clone(), AUTH_DIR_UP)),
            Some(RxAuth::new(key, AUTH_DIR_UP)),
        )
    }

    #[test]
    fn authenticated_frame_roundtrip_and_trailer_size() {
        let (mut tx, mut rx) = auth_pair();
        let payload = vec![3u8; 200];
        let mut wire = Vec::new();
        let n = write_frame_with(&mut wire, 7, FrameKind::CtChunk, 3, &payload, &mut tx).unwrap();
        assert_eq!(n as usize, wire.len());
        assert_eq!(
            wire.len(),
            FRAME_HEADER_BYTES + payload.len() + FRAME_TRAILER_BYTES + AUTH_TRAILER_BYTES
        );
        let mut buf = Vec::new();
        let (kind, seq) =
            read_frame_into_with(&mut Cursor::new(&wire), 7, 4096, &mut buf, &mut rx).unwrap();
        assert_eq!((kind, seq), (FrameKind::CtChunk, 3));
        assert_eq!(buf, payload);
    }

    #[test]
    fn auth_fuzz_every_single_byte_corruption_is_rejected_and_counted() {
        // Satellite gate: flip every byte of an authenticated frame —
        // header, payload, crc, auth_seq, tag — and every corruption must
        // be rejected (never silently accepted, never a panic). All
        // corruptions outside the 4 length-field bytes are uniform MAC
        // rejects (counted in auth_rejects); a corrupted length surfaces
        // as a short read / cap reject instead.
        let (mut tx, _) = auth_pair();
        let payload: Vec<u8> = (0..96u8).collect();
        let mut wire = Vec::new();
        write_frame_with(&mut wire, 11, FrameKind::Begin, 2, &payload, &mut tx).unwrap();
        let len_field = 24..28usize;
        for i in 0..wire.len() {
            let mut b = wire.clone();
            b[i] ^= 0x80;
            let (_, mut rx) = auth_pair();
            let mut buf = Vec::new();
            let before = crate::obs::metrics::snapshot_auth_rejects();
            let got = read_frame_into_with(&mut Cursor::new(&b), 11, 4096, &mut buf, &mut rx);
            assert!(got.is_err(), "corruption at byte {i} accepted: {got:?}");
            if !len_field.contains(&i) {
                assert!(
                    crate::obs::metrics::snapshot_auth_rejects() > before,
                    "corruption at byte {i} not counted as an auth reject"
                );
            }
        }
        // the pristine frame still verifies (the sweep really was the
        // corruption, not a broken oracle)
        let (_, mut rx) = auth_pair();
        let mut buf = Vec::new();
        assert!(
            read_frame_into_with(&mut Cursor::new(&wire), 11, 4096, &mut buf, &mut rx).is_ok()
        );
    }

    #[test]
    fn replayed_frames_are_discarded_and_counted_not_fatal() {
        // wire = frame1 ‖ frame1 (replay) ‖ frame2: the reader must accept
        // frame1, silently discard the replay (counting it), and hand back
        // frame2 — the honest stream survives the injected duplicate.
        let (mut tx, mut rx) = auth_pair();
        let mut f1 = Vec::new();
        write_frame_with(&mut f1, 9, FrameKind::CtChunk, 0, &[1u8; 32], &mut tx).unwrap();
        let mut f2 = Vec::new();
        write_frame_with(&mut f2, 9, FrameKind::CtChunk, 1, &[2u8; 32], &mut tx).unwrap();
        let mut wire = f1.clone();
        wire.extend_from_slice(&f1);
        wire.extend_from_slice(&f2);
        let mut cur = Cursor::new(&wire);
        let mut buf = Vec::new();
        let (_, seq) = read_frame_into_with(&mut cur, 9, 4096, &mut buf, &mut rx).unwrap();
        assert_eq!(seq, 0);
        let before = crate::obs::metrics::snapshot_replay_rejects();
        let (_, seq) = read_frame_into_with(&mut cur, 9, 4096, &mut buf, &mut rx).unwrap();
        assert_eq!(seq, 1, "replayed frame must be skipped, not delivered");
        assert_eq!(buf, vec![2u8; 32]);
        assert!(crate::obs::metrics::snapshot_replay_rejects() > before);
    }

    #[test]
    fn direction_and_key_confusion_fail_the_mac() {
        // a frame tagged client→server never verifies as server→client
        // (reflection), and a frame under one session key never verifies
        // under another (cross-session replay)
        let key = MacKey([0x5au8; 32]);
        let mut tx = Some(TxAuth::new(key.clone(), AUTH_DIR_UP));
        let mut wire = Vec::new();
        write_frame_with(&mut wire, 3, FrameKind::Ack, 0, &[0u8; 4], &mut tx).unwrap();
        let mut buf = Vec::new();
        let mut reflected = Some(RxAuth::new(key, AUTH_DIR_DOWN));
        assert!(read_frame_into_with(
            &mut Cursor::new(&wire),
            3,
            64,
            &mut buf,
            &mut reflected
        )
        .is_err());
        let mut other = Some(RxAuth::new(MacKey([0xa5u8; 32]), AUTH_DIR_UP));
        assert!(
            read_frame_into_with(&mut Cursor::new(&wire), 3, 64, &mut buf, &mut other).is_err()
        );
    }

    #[test]
    fn challenge_payload_codecs_roundtrip_and_validate() {
        let nonce = [0x42u8; 16];
        assert_eq!(decode_challenge(&encode_challenge(&nonce)).unwrap(), nonce);
        assert!(decode_challenge(&[0u8; 15]).is_err());
        let (c, t) = decode_challenge_resp(&encode_challenge_resp(7, 0xdead_beef_cafe)).unwrap();
        assert_eq!((c, t), (7, 0xdead_beef_cafe));
        assert!(decode_challenge_resp(&[0u8; 17]).is_err());
    }

    #[test]
    fn validate_wire_frame_mirrors_the_blocking_reader() {
        // accept path: the buffer-in validator agrees with the stream reader
        let (mut tx, mut rx) = auth_pair();
        let mut wire = Vec::new();
        write_frame_with(&mut wire, 9, FrameKind::CtChunk, 4, &[7u8; 48], &mut tx).unwrap();
        let verdict = validate_wire_frame(&wire, &mut rx).unwrap();
        assert_eq!(
            verdict,
            WireVerdict::Accept { round: 9, kind: FrameKind::CtChunk, seq: 4 }
        );
        assert_eq!(&wire[FRAME_HEADER_BYTES..FRAME_HEADER_BYTES + 48], &[7u8; 48]);

        // a replayed frame is a soft reject (stream survives)...
        let before = crate::obs::metrics::snapshot_replay_rejects();
        assert_eq!(
            validate_wire_frame(&wire, &mut rx).unwrap(),
            WireVerdict::ReplayReject
        );
        assert!(crate::obs::metrics::snapshot_replay_rejects() > before);

        // ...as is a forged tag (every non-length byte flip)
        let mut forged = wire.clone();
        let last = forged.len() - 1;
        forged[last] ^= 0x80;
        let before = crate::obs::metrics::snapshot_auth_rejects();
        assert_eq!(
            validate_wire_frame(&forged, &mut rx).unwrap(),
            WireVerdict::AuthReject
        );
        assert!(crate::obs::metrics::snapshot_auth_rejects() > before);

        // unauthenticated path: corruption is a hard error, never a panic
        let mut plain = Vec::new();
        write_frame(&mut plain, 5, FrameKind::Plain, 0, &[3u8; 16]).unwrap();
        assert!(matches!(
            validate_wire_frame(&plain, &mut None).unwrap(),
            WireVerdict::Accept { round: 5, kind: FrameKind::Plain, seq: 0 }
        ));
        let mut bad = plain.clone();
        bad[0] ^= 0xFF; // magic
        assert!(validate_wire_frame(&bad, &mut None).is_err());
        let mut bad = plain.clone();
        bad[FRAME_HEADER_BYTES] ^= 1; // payload byte → crc mismatch
        assert!(validate_wire_frame(&bad, &mut None).is_err());
        // truncated / inconsistent slices are hard errors too
        assert!(validate_wire_frame(&plain[..10], &mut None).is_err());
        assert!(validate_wire_frame(&plain[..plain.len() - 1], &mut None).is_err());
    }

    #[test]
    fn crc32_matches_ieee_check_value() {
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn frame_roundtrip() {
        let payload: Vec<u8> = (0..200u16).map(|v| (v % 251) as u8).collect();
        let mut wire = Vec::new();
        let n = write_frame(&mut wire, 7, FrameKind::CtChunk, 3, &payload).unwrap();
        assert_eq!(n as usize, wire.len());
        let f = read_frame(&mut Cursor::new(&wire), 7, 4096).unwrap();
        assert_eq!(f.kind, FrameKind::CtChunk);
        assert_eq!(f.seq, 3);
        assert_eq!(f.payload, payload);
        assert_eq!(f.wire_bytes(), n);
    }

    #[test]
    fn begin_payload_roundtrip_and_validation() {
        let p = encode_begin(42, 0.25, 8, 1000, 9000);
        let (client, alpha, n_cts, n_plain, total) = decode_begin(&p).unwrap();
        assert_eq!(
            (client, alpha, n_cts, n_plain, total),
            (42, 0.25, 8, 1000, 9000)
        );
        // malformed weights are rejected
        for bad in [f64::NAN, f64::INFINITY, -0.5, 0.0, 1.5] {
            let p = encode_begin(1, bad, 1, 1, 1);
            assert!(decode_begin(&p).is_err(), "alpha {bad} accepted");
        }
        assert!(decode_begin(&p[..31]).is_err());
    }

    #[test]
    fn malformed_frames_rejected_not_panicking() {
        let payload = vec![9u8; 64];
        let mut wire = Vec::new();
        write_frame(&mut wire, 5, FrameKind::Plain, 0, &payload).unwrap();

        // truncation at every boundary: header, payload, crc
        for cut in [1, FRAME_HEADER_BYTES - 1, FRAME_HEADER_BYTES + 10, wire.len() - 1] {
            assert!(
                read_frame(&mut Cursor::new(&wire[..cut]), 5, 4096).is_err(),
                "cut at {cut} accepted"
            );
        }
        // bad magic
        let mut b = wire.clone();
        b[0] ^= 0xFF;
        assert!(read_frame(&mut Cursor::new(&b), 5, 4096).is_err());
        // version skew
        let mut b = wire.clone();
        b[4..8].copy_from_slice(&2u32.to_le_bytes());
        assert!(read_frame(&mut Cursor::new(&b), 5, 4096).is_err());
        // wrong round
        assert!(read_frame(&mut Cursor::new(&wire), 6, 4096).is_err());
        // unknown kind
        let mut b = wire.clone();
        b[16..20].copy_from_slice(&99u32.to_le_bytes());
        assert!(read_frame(&mut Cursor::new(&b), 5, 4096).is_err());
        // garbage crc
        let mut b = wire.clone();
        let last = b.len() - 1;
        b[last] ^= 0x55;
        assert!(read_frame(&mut Cursor::new(&b), 5, 4096).is_err());
        // corrupted payload byte → crc mismatch
        let mut b = wire.clone();
        b[FRAME_HEADER_BYTES + 3] ^= 0x01;
        assert!(read_frame(&mut Cursor::new(&b), 5, 4096).is_err());
    }

    #[test]
    fn every_single_byte_corruption_parses_or_errors_never_panics() {
        // the sweep covers every frame kind of the duplex session protocol,
        // including the downlink/session kinds of DESIGN.md §9
        for kind in [
            FrameKind::Begin,
            FrameKind::CtChunk,
            FrameKind::Plain,
            FrameKind::End,
            FrameKind::Ack,
            FrameKind::Hello,
            FrameKind::Welcome,
            FrameKind::Mask,
            FrameKind::DownBegin,
            FrameKind::DownEnd,
            FrameKind::Stats,
            FrameKind::StatsReply,
            FrameKind::Challenge,
            FrameKind::ChallengeResp,
        ] {
            let payload = vec![7u8; 96];
            let mut wire = Vec::new();
            write_frame(&mut wire, 11, kind, 2, &payload).unwrap();
            for i in 0..wire.len() {
                let mut b = wire.clone();
                b[i] ^= 0x80;
                let _ = read_frame(&mut Cursor::new(&b), 11, 4096);
            }
        }
    }

    #[test]
    fn session_payload_codecs_roundtrip_and_validate() {
        // HELLO / WELCOME (with the ct-wire mode announcement)
        assert_eq!(
            decode_hello(&encode_hello(42, CtWire::Seed)).unwrap(),
            (42, CtWire::Seed)
        );
        assert!(decode_hello(&[0u8; 7]).is_err());
        // a pre-ct-wire 8-byte HELLO is malformed, not silently dense
        assert!(decode_hello(&42u64.to_le_bytes()).is_err());
        // unknown mode codes are rejected
        let mut bad = encode_hello(42, CtWire::Dense);
        bad[8..12].copy_from_slice(&9u32.to_le_bytes());
        assert!(decode_hello(&bad).is_err());
        assert_eq!(
            decode_welcome(&encode_welcome(MASK_ROUND, CtWire::Dense)).unwrap(),
            (MASK_ROUND, CtWire::Dense)
        );
        assert!(decode_welcome(&[0u8; 9]).is_err());
        let mut bad = encode_welcome(3, CtWire::Seed);
        bad[8..12].copy_from_slice(&7u32.to_le_bytes());
        assert!(decode_welcome(&bad).is_err());

        // END metrics: empty is zeros, 24 bytes roundtrips, junk is rejected
        assert_eq!(decode_end_timing(&[]).unwrap(), (0.0, 0.0, 0.0));
        let t = encode_end_timing(1.25, 0.5, 0.75);
        assert_eq!(decode_end_timing(&t).unwrap(), (1.25, 0.5, 0.75));
        assert!(decode_end_timing(&t[..8]).is_err());
        assert!(decode_end_timing(&encode_end_timing(f64::NAN, 0.0, 0.0)).is_err());
        assert!(decode_end_timing(&encode_end_timing(-1.0, 0.0, 0.0)).is_err());
        assert!(decode_end_timing(&encode_end_timing(0.0, 0.0, f32::NAN)).is_err());
        let mut bad = encode_end_timing(1.0, 1.0, 1.0);
        bad[23] = 7;
        assert!(decode_end_timing(&bad).is_err());

        // DOWN_BEGIN
        let d = DownBegin {
            alpha: 0.25,
            alpha_mass: 0.75,
            n_cts: 3,
            n_plain: 1000,
            total: 9000,
            participate: true,
            has_agg: true,
            fin: false,
        };
        assert_eq!(decode_down_begin(&encode_down_begin(&d)).unwrap(), d);
        // a non-participating fin downlink with no aggregate is legal
        let fin = DownBegin {
            alpha: 0.0,
            alpha_mass: 0.0,
            n_cts: 0,
            n_plain: 0,
            total: 0,
            participate: false,
            has_agg: false,
            fin: true,
        };
        assert_eq!(decode_down_begin(&encode_down_begin(&fin)).unwrap(), fin);
        // malformed: short, bad weight, participate w/o weight, agg w/o mass
        assert!(decode_down_begin(&encode_down_begin(&d)[..35]).is_err());
        for bad in [
            DownBegin { alpha: f64::NAN, ..d },
            DownBegin { alpha: 1.5, ..d },
            DownBegin { alpha: 0.0, participate: true, ..d },
            DownBegin { alpha_mass: 0.0, has_agg: true, ..d },
            DownBegin { alpha_mass: f64::INFINITY, ..d },
        ] {
            assert!(
                decode_down_begin(&encode_down_begin(&bad)).is_err(),
                "{bad:?} accepted"
            );
        }
        // unknown flag bits are rejected
        let mut p = encode_down_begin(&d);
        p[32..36].copy_from_slice(&0x80u32.to_le_bytes());
        assert!(decode_down_begin(&p).is_err());
    }

    #[test]
    fn pooled_read_reuses_one_buffer_across_frames() {
        let mut wire = Vec::new();
        write_frame(&mut wire, 4, FrameKind::CtChunk, 0, &[1u8; 512]).unwrap();
        write_frame(&mut wire, 4, FrameKind::Plain, 1, &[2u8; 64]).unwrap();
        write_frame(&mut wire, 4, FrameKind::End, 0, &[]).unwrap();
        let mut cur = Cursor::new(&wire);
        let mut buf = Vec::new();
        let (k, _) = read_frame_into(&mut cur, 4, 4096, &mut buf).unwrap();
        assert_eq!(k, FrameKind::CtChunk);
        assert_eq!(buf.len(), 512);
        let cap = buf.capacity();
        let (k, seq) = read_frame_into(&mut cur, 4, 4096, &mut buf).unwrap();
        assert_eq!((k, seq), (FrameKind::Plain, 1));
        assert_eq!(buf.len(), 64);
        assert_eq!(buf.capacity(), cap, "shrinking frame must reuse the buffer");
        assert!(buf.iter().all(|&b| b == 2));
        let (k, _) = read_frame_into(&mut cur, 4, 4096, &mut buf).unwrap();
        assert_eq!(k, FrameKind::End);
        assert!(buf.is_empty());
    }

    #[test]
    fn oversized_declared_length_rejected_before_allocating() {
        // a frame header declaring a u32::MAX payload must be rejected by
        // the cap check, not by attempting a 4 GiB allocation
        let mut hdr = [0u8; FRAME_HEADER_BYTES];
        hdr[0..4].copy_from_slice(&FRAME_MAGIC.to_le_bytes());
        hdr[4..8].copy_from_slice(&PROTOCOL_VERSION.to_le_bytes());
        hdr[8..16].copy_from_slice(&3u64.to_le_bytes());
        hdr[16..20].copy_from_slice(&(FrameKind::CtChunk as u32).to_le_bytes());
        hdr[24..28].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = read_frame(&mut Cursor::new(&hdr[..]), 3, 1 << 20).unwrap_err();
        assert!(err.to_string().contains("exceeds cap"), "{err}");
    }

    #[test]
    fn payload_cap_covers_ct_and_plain_frames() {
        let params = CkksParams::new(256, 3, 30).unwrap();
        let cap = frame_payload_cap(&params);
        assert!(cap >= shard_wire_bytes(&params, 0, params.num_limbs()));
        assert!(cap >= PLAIN_CHUNK_VALUES * 4);
        assert!(cap >= BEGIN_PAYLOAD_BYTES);
    }

    #[test]
    fn mask_cap_covers_worst_case_alternating_mask() {
        // the most fragmented mask possible: every other parameter
        // encrypted — its wire form must fit under the declared cap
        let total = 10_000usize;
        let runs: Vec<crate::he_agg::mask::Run> = (0..total / 2)
            .map(|i| crate::he_agg::mask::Run { lo: 2 * i, hi: 2 * i + 1 })
            .collect();
        let mask = crate::he_agg::EncryptionMask::from_runs(total, runs);
        assert_eq!(mask.encrypted.n_runs(), total / 2);
        assert!(mask.to_bytes().len() <= mask_payload_cap(total));
        // tiny models still get a sane floor
        assert!(mask_payload_cap(1) >= 64);
    }
}
