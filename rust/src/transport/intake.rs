//! Server-side TCP intake: accept concurrent uploads, reassemble
//! [`EncryptedUpdate`]s, and hand them to the streaming aggregation engine
//! as true [`Arrival`]s stamped with wall-clock receive times.
//!
//! Failure containment (DESIGN.md §8 failure matrix): any per-connection
//! error — truncated frame, CRC mismatch, version/round skew, shape
//! mismatch, out-of-range coefficients, mid-upload disconnect — discards
//! only that connection's upload. The client is reported in
//! [`IntakeOutcome::failed`] and folded into the round's straggler
//! accounting; the round itself always completes from the uploads that did
//! land. Nothing on this path panics, and no attacker-controlled length can
//! allocate beyond one legitimate frame ([`super::frame::frame_payload_cap`]).

use super::frame::{
    frame_payload_cap, read_frame_into_with, write_frame_with, FrameKind, RxAuth, TxAuth,
    AUTH_TRAILER_BYTES,
};
use crate::agg_engine::Arrival;
use crate::ckks::{CkksContext, CkksParams, CtWire};
use crate::he_agg::{EncryptedUpdate, EncryptionMask};
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::fd::AsRawFd;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Sentinel client id for a connection that failed before its BEGIN frame
/// identified it.
pub const UNIDENTIFIED_CLIENT: u64 = u64::MAX;

/// Expected shape of every upload in a round, derived by the server from the
/// agreed mask + crypto context. BEGIN declarations must match exactly, so a
/// client can never size a server-side buffer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct UpdateShape {
    pub n_cts: usize,
    pub n_plain: usize,
    pub total: usize,
    /// Ciphertext wire format every CT_CHUNK of the round must use. Not part
    /// of the BEGIN declaration — it is pinned server-side (handshake
    /// negotiation / task config), so a client cannot switch formats
    /// mid-round.
    pub ct_wire: CtWire,
}

impl UpdateShape {
    /// Shape of a selectively-encrypted update under `mask`, on the default
    /// dense ciphertext wire.
    pub fn for_round(ctx: &CkksContext, mask: &EncryptionMask) -> Self {
        Self::for_round_wire(ctx, mask, CtWire::Dense)
    }

    /// [`UpdateShape::for_round`] with an explicit ciphertext wire format.
    pub fn for_round_wire(ctx: &CkksContext, mask: &EncryptionMask, ct_wire: CtWire) -> Self {
        let enc = mask.encrypted_count();
        UpdateShape {
            n_cts: enc.div_ceil(ctx.batch()),
            n_plain: mask.total() - enc,
            total: mask.total(),
            ct_wire,
        }
    }
}

/// Per-round intake knobs.
#[derive(Debug, Clone)]
pub struct IntakeConfig {
    pub round_id: u64,
    /// Connections to wait for (one per expected participant).
    pub expected_uploads: usize,
    /// Quorum for the early-stop hint: once this many uploads completed,
    /// the accept loop waits only `straggler_timeout` longer. The
    /// authoritative accept/drop decision is re-derived at seal by
    /// [`crate::agg_engine::RoundIntake`] over the same stamps.
    pub quorum: Option<usize>,
    pub straggler_timeout: Duration,
    /// Hard wall-clock bound on the whole intake — a hung accept loop fails
    /// fast instead of hanging the round (and CI).
    pub max_wait: Duration,
    /// Per-connection socket read/write timeout.
    pub io_timeout: Duration,
}

impl Default for IntakeConfig {
    fn default() -> Self {
        IntakeConfig {
            round_id: 0,
            expected_uploads: 0,
            quorum: None,
            straggler_timeout: Duration::from_secs(5),
            max_wait: Duration::from_secs(60),
            io_timeout: Duration::from_secs(10),
        }
    }
}

/// What one round's intake produced.
#[derive(Debug, Clone, Default)]
pub struct IntakeOutcome {
    /// Completed uploads, stamped with seconds since the intake opened and
    /// sorted by (stamp, client) — ready for the quorum/straggler policy.
    pub arrivals: Vec<Arrival>,
    /// Clients whose upload failed mid-stream ([`UNIDENTIFIED_CLIENT`] when
    /// the failure predates their BEGIN frame). The caller folds these into
    /// `StreamStats::dropped_stragglers`.
    pub failed: Vec<u64>,
    /// Frame bytes received across all connections, including failed ones.
    pub bytes_received: u64,
    /// Wall-clock duration of the intake (accept-open to last handler done).
    pub elapsed_secs: f64,
    /// Σ client-reported local training seconds over completed uploads
    /// (END-frame metric payloads; zero for clients that do not report).
    pub train_secs: f64,
    /// Σ client-reported encryption seconds over completed uploads.
    pub encrypt_secs: f64,
    /// Σ client-reported training losses over completed uploads.
    pub loss_sum: f64,
}

/// Shared bookkeeping of one round's upload collection — the scaffold that
/// the three collectors (the anonymous [`TcpIntake`], the blocking
/// `session::SessionHub` and the reactor `hub::ReactorHub`) previously
/// each hand-kept: arrival stamping under one lock (stamps monotone in
/// completion order), duplicate-upload discard, failed-client recording,
/// timing/byte sums, the quorum → straggler-cutoff transition, and the
/// final sorted [`IntakeOutcome`]. Callers own their concurrency (worker
/// threads, collector channels, shard events); the ledger owns the
/// round's accounting semantics so all backends settle rounds
/// identically.
pub(crate) struct RoundLedger {
    start: Instant,
    deadline: Instant,
    quorum: Option<usize>,
    straggler_timeout: Duration,
    cutoff: Option<Instant>,
    arrivals: Vec<Arrival>,
    failed: Vec<u64>,
    bytes: u64,
    train_secs: f64,
    encrypt_secs: f64,
    loss_sum: f64,
}

impl RoundLedger {
    /// Open the ledger; the round clock starts now.
    pub fn open(cfg: &IntakeConfig) -> Self {
        let start = Instant::now();
        RoundLedger {
            start,
            deadline: start + cfg.max_wait,
            quorum: cfg.quorum,
            straggler_timeout: cfg.straggler_timeout,
            cutoff: None,
            arrivals: Vec::new(),
            failed: Vec::new(),
            bytes: 0,
            train_secs: 0.0,
            encrypt_secs: 0.0,
            loss_sum: 0.0,
        }
    }

    pub fn start(&self) -> Instant {
        self.start
    }

    /// Hard wall-clock bound on the whole round.
    pub fn deadline(&self) -> Instant {
        self.deadline
    }

    /// The straggler cutoff, armed when the quorum-th upload completed.
    pub fn cutoff(&self) -> Option<Instant> {
        self.cutoff
    }

    /// The earliest of deadline and armed cutoff — when the round stops
    /// accepting new work.
    pub fn closing_time(&self) -> Instant {
        match self.cutoff {
            Some(c) => c.min(self.deadline),
            None => self.deadline,
        }
    }

    pub fn add_bytes(&mut self, n: u64) {
        self.bytes += n;
    }

    pub fn completed_count(&self) -> usize {
        self.arrivals.len()
    }

    pub fn has_completed(&self, client: u64) -> bool {
        self.arrivals.iter().any(|a| a.client == client)
    }

    pub fn has_failed(&self, client: u64) -> bool {
        self.failed.contains(&client)
    }

    /// Record a completed upload: stamp it with seconds since the round
    /// opened, fold in the client-reported metrics, and arm the straggler
    /// cutoff once the quorum is reached. A duplicate completion for an
    /// already-counted client is discarded into `failed` (aggregating it
    /// would double that client's weight) and returns `false`.
    pub fn complete(&mut self, frames: UploadFrames) -> bool {
        let client = frames.client;
        if self.has_completed(client) {
            crate::log_debug!(
                "transport",
                "duplicate upload from client {client} discarded"
            );
            self.failed.push(client);
            return false;
        }
        self.arrivals.push(Arrival {
            client,
            alpha: frames.alpha,
            arrival_secs: self.start.elapsed().as_secs_f64(),
            update: std::sync::Arc::new(frames.update),
        });
        self.train_secs += frames.train_secs;
        self.encrypt_secs += frames.encrypt_secs;
        self.loss_sum += frames.loss as f64;
        if let Some(q) = self.quorum {
            if self.arrivals.len() >= q.max(1) && self.cutoff.is_none() {
                self.cutoff = Some(Instant::now() + self.straggler_timeout);
            }
        }
        true
    }

    /// Record a failed upload attempt for `client`
    /// ([`UNIDENTIFIED_CLIENT`] when the failure predates its BEGIN).
    pub fn fail(&mut self, client: u64) {
        self.failed.push(client);
    }

    /// Seal the round: sort arrivals by (stamp, client) and fold the sums
    /// into the caller-facing outcome.
    pub fn seal(mut self) -> IntakeOutcome {
        self.arrivals.sort_by(|a, b| {
            a.arrival_secs
                .total_cmp(&b.arrival_secs)
                .then(a.client.cmp(&b.client))
        });
        IntakeOutcome {
            elapsed_secs: self.start.elapsed().as_secs_f64(),
            arrivals: self.arrivals,
            failed: self.failed,
            bytes_received: self.bytes,
            train_secs: self.train_secs,
            encrypt_secs: self.encrypt_secs,
            loss_sum: self.loss_sum,
        }
    }
}

/// A bound TCP intake serving one round at a time.
pub struct TcpIntake {
    listener: TcpListener,
    params: std::sync::Arc<CkksParams>,
    shape: UpdateShape,
}

impl TcpIntake {
    /// Bind the intake socket (use port 0 for an ephemeral loopback port).
    pub fn bind(
        addr: &str,
        params: std::sync::Arc<CkksParams>,
        shape: UpdateShape,
    ) -> anyhow::Result<Self> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow::anyhow!("cannot bind transport intake on {addr}: {e}"))?;
        Ok(TcpIntake {
            listener,
            params,
            shape,
        })
    }

    /// The bound address (what clients dial).
    pub fn local_addr(&self) -> anyhow::Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Accept and reassemble one round of uploads. Each connection is served
    /// on its own worker thread; completed updates are stamped (seconds
    /// since the intake opened) under one lock, so stamps are monotone in
    /// completion order. Accepts until `expected_uploads` uploads have
    /// settled (completed, or failed after identifying themselves with a
    /// valid BEGIN — anonymous probes never consume a slot), the quorum
    /// early-stop cutoff passes, or `max_wait` expires — whichever comes
    /// first; uploads still in flight at that point are finished and
    /// included before returning. Duplicate uploads for an already-counted
    /// client id are discarded into `failed`.
    pub fn collect_round(&self, cfg: &IntakeConfig) -> anyhow::Result<IntakeOutcome> {
        self.listener.set_nonblocking(true)?;
        // All round accounting (arrivals, failures, timing sums, the quorum
        // cutoff) lives in the ledger; stamping under its lock keeps stamps
        // monotone in completion order.
        let ledger = RoundLedger::open(cfg);
        let deadline = ledger.deadline();
        let ledger = Mutex::new(ledger);
        let params = &*self.params;
        let shape = self.shape;

        // A participant slot "settles" on a completed upload or an
        // *identified* failure (the connection got through a valid BEGIN for
        // this round). Anonymous probes — port scanners, garbage bytes —
        // are recorded in `failed` but never settle a slot, so they cannot
        // displace a legitimate participant; absent participants are
        // bounded by the quorum cutoff / `max_wait` instead.
        let settled = AtomicUsize::new(0);
        // Live per-connection worker threads. Bounding this (instead of a
        // lifetime spawn count) keeps the accept loop serving after bursts
        // of fast-failing probes: past the cap, new connections wait in the
        // listen backlog instead of each pinning a thread + frame buffer.
        let in_flight = Mutex::new(0usize);
        let slot_freed = Condvar::new();
        let max_in_flight = cfg.expected_uploads.saturating_mul(2).saturating_add(32);

        // Readiness parking: instead of 1 ms sleep-polling the nonblocking
        // listener, the accept loop parks on an epoll set (listener +
        // eventfd) and is woken by a new connection or by a worker settling
        // a slot. The wait is still bounded so the cutoff/deadline checks
        // re-run even when nothing is ready.
        let poller = super::reactor::Poller::new()?;
        let wake = super::reactor::Wakeup::new()?;
        poller.add(self.listener.as_raw_fd(), 0, true, false)?;
        poller.add(wake.as_raw_fd(), 1, true, false)?;
        let mut events = Vec::new();

        std::thread::scope(|s| -> anyhow::Result<()> {
            loop {
                if settled.load(Ordering::Relaxed) >= cfg.expected_uploads {
                    break;
                }
                let closing = ledger.lock().unwrap().closing_time();
                if Instant::now() >= closing {
                    break;
                }
                {
                    let guard = in_flight.lock().unwrap();
                    if *guard >= max_in_flight {
                        let (guard, _timed_out) = slot_freed
                            .wait_timeout(guard, Duration::from_millis(50))
                            .unwrap();
                        drop(guard);
                        continue;
                    }
                }
                match self.listener.accept() {
                    Ok((stream, _peer)) => {
                        *in_flight.lock().unwrap() += 1;
                        let ledger = &ledger;
                        let settled = &settled;
                        let in_flight = &in_flight;
                        let slot_freed = &slot_freed;
                        let wake = &wake;
                        s.spawn(move || {
                            let mut seen_client: Option<u64> = None;
                            let mut received = 0u64;
                            let result = receive_update(
                                stream,
                                params,
                                shape,
                                cfg,
                                deadline,
                                &mut seen_client,
                                &mut received,
                            );
                            let mut led = ledger.lock().unwrap();
                            led.add_bytes(received);
                            match result {
                                Ok(frames) => {
                                    // a completion after an earlier failed
                                    // attempt reuses the slot that failure
                                    // already settled; a duplicate of an
                                    // already-counted upload settles nothing
                                    let failed_before = led.has_failed(frames.client);
                                    if led.complete(frames) && !failed_before {
                                        settled.fetch_add(1, Ordering::Relaxed);
                                    }
                                }
                                Err(e) => {
                                    let id = seen_client.unwrap_or(UNIDENTIFIED_CLIENT);
                                    crate::log_debug!(
                                        "transport",
                                        "upload from client {id} failed: {e}"
                                    );
                                    // a given client id settles at most one
                                    // slot, across completions and failures
                                    // — replaying BEGIN-then-disconnect (or
                                    // failing a retry after a completed
                                    // upload) must not burn the other
                                    // participants' slots
                                    let completed_before = led.has_completed(id);
                                    let first_failure = !led.has_failed(id);
                                    led.fail(id);
                                    if seen_client.is_some()
                                        && first_failure
                                        && !completed_before
                                    {
                                        settled.fetch_add(1, Ordering::Relaxed);
                                    }
                                }
                            }
                            drop(led);
                            *in_flight.lock().unwrap() -= 1;
                            slot_freed.notify_one();
                            wake.wake();
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        let timeout = closing
                            .saturating_duration_since(Instant::now())
                            .min(Duration::from_millis(50));
                        poller.wait(&mut events, Some(timeout))?;
                        if events.iter().any(|ev| ev.token == 1) {
                            crate::obs::metrics::hub_wakeup();
                            wake.drain();
                        }
                    }
                    // a peer that RSTs before we accept (connection churn,
                    // port scans) kills only that connection, not the round
                    Err(e)
                        if matches!(
                            e.kind(),
                            std::io::ErrorKind::ConnectionAborted
                                | std::io::ErrorKind::ConnectionReset
                                | std::io::ErrorKind::Interrupted
                        ) => {}
                    Err(e) => anyhow::bail!("transport accept failed: {e}"),
                }
            }
            Ok(())
        })?;

        Ok(ledger.into_inner().unwrap().seal())
    }
}

/// One reassembled upload (shared between the one-shot intake and the
/// persistent-session collector).
pub(crate) struct UploadFrames {
    pub client: u64,
    pub alpha: f64,
    /// Client-reported local metrics from the END payload (zeros when the
    /// client does not report them).
    pub train_secs: f64,
    pub encrypt_secs: f64,
    pub loss: f32,
    pub update: EncryptedUpdate,
}

/// Reassemble one client's upload off a connection. Any validation failure
/// or disconnect returns `Err`; `seen_client`/`received` report partial
/// progress either way. The ACK is written to `ack_stream` after a valid
/// END.
///
/// `deadline()` is re-evaluated before every frame (the session collector
/// tightens it once a quorum cutoff is known) and the socket read timeout
/// is clamped to the time remaining, so a slowly-trickling connection
/// cannot hold the round open much past the bound by resetting the
/// per-read timer. `expect_client` pins the BEGIN identity (persistent
/// sessions already know whose socket this is) and `expect_alpha` pins the
/// declared FedAvg weight to the one the server assigned for the round —
/// rejecting a skewed weight here keeps the upload out of both the
/// aggregate *and* the round's metric sums; `payload` is the pooled
/// per-connection frame buffer — steady-state frame reads allocate nothing
/// (gated by `tests/zero_alloc.rs`). Under `--wire-auth mac`, `rx` verifies
/// every inbound frame's auth trailer (replayed/forged frames are counted
/// and discarded inside the frame reader) and `tx` tags the ACK.
#[allow(clippy::too_many_arguments)]
pub(crate) fn read_upload<R: std::io::Read, F: Fn() -> Instant>(
    reader: &mut R,
    stream: &TcpStream,
    ack_stream: &TcpStream,
    params: &CkksParams,
    shape: UpdateShape,
    round_id: u64,
    io_timeout: Duration,
    deadline: &F,
    expect_client: Option<u64>,
    expect_alpha: Option<f64>,
    seen_client: &mut Option<u64>,
    received: &mut u64,
    payload: &mut Vec<u8>,
    rx: &mut Option<RxAuth>,
    tx: &mut Option<TxAuth>,
) -> anyhow::Result<UploadFrames> {
    let cap = frame_payload_cap(params);
    let arm_read = |stream: &TcpStream| -> anyhow::Result<()> {
        let remaining = deadline().saturating_duration_since(Instant::now());
        anyhow::ensure!(!remaining.is_zero(), "upload exceeded the intake deadline");
        stream.set_read_timeout(Some(remaining.min(io_timeout)))?;
        Ok(())
    };
    let auth_extra = if rx.is_some() { AUTH_TRAILER_BYTES } else { 0 };
    let frame_bytes = |payload_len: usize| {
        (super::frame::FRAME_HEADER_BYTES
            + payload_len
            + super::frame::FRAME_TRAILER_BYTES
            + auth_extra) as u64
    };

    // BEGIN: identity + declared shape, checked against the round's shape
    // by the shared upload state machine (also driven, frame by decoded
    // frame, by the reactor hub's session machine).
    arm_read(stream)?;
    let (kind, _) = read_frame_into_with(reader, round_id, cap, payload, rx)?;
    *received += frame_bytes(payload.len());
    anyhow::ensure!(
        kind == FrameKind::Begin,
        "upload must start with BEGIN, got {kind:?}"
    );
    let mut asm = super::reassembly::UploadAssembly::begin(
        payload,
        shape,
        expect_client,
        expect_alpha,
        seen_client,
    )?;

    let _span = crate::obs::span_arg("transport", "read_upload", asm.client());
    let timing;
    loop {
        arm_read(stream)?;
        let (kind, seq) = read_frame_into_with(reader, round_id, cap, payload, rx)?;
        *received += frame_bytes(payload.len());
        if let Some(t) = asm.accept(params, kind, seq, payload)? {
            timing = t;
            break;
        }
    }
    let frames = asm.finish(timing)?;
    let mut ack_w = ack_stream;
    write_frame_with(&mut ack_w, round_id, FrameKind::Ack, 0, &0u32.to_le_bytes(), tx)?;
    Ok(frames)
}

/// One-shot connection wrapper over [`read_upload`] (the anonymous uplink
/// path of [`TcpIntake`]): fresh `BufReader` + pooled frame buffer per
/// connection, intake-wide `max_wait` as the deadline.
fn receive_update(
    stream: TcpStream,
    params: &CkksParams,
    shape: UpdateShape,
    cfg: &IntakeConfig,
    deadline: Instant,
    seen_client: &mut Option<u64>,
    received: &mut u64,
) -> anyhow::Result<UploadFrames> {
    stream.set_nonblocking(false)?;
    stream.set_write_timeout(Some(cfg.io_timeout))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    // Per-connection pooled payload buffer: every frame of this upload
    // reuses it (ROADMAP follow-up: no per-frame payload Vec).
    let mut payload = Vec::new();
    read_upload(
        &mut reader,
        &stream,
        &stream,
        params,
        shape,
        cfg.round_id,
        cfg.io_timeout,
        &move || deadline,
        None,
        None,
        seen_client,
        received,
        &mut payload,
        &mut None,
        &mut None,
    )
}
