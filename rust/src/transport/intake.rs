//! Server-side TCP intake: accept concurrent uploads, reassemble
//! [`EncryptedUpdate`]s, and hand them to the streaming aggregation engine
//! as true [`Arrival`]s stamped with wall-clock receive times.
//!
//! Failure containment (DESIGN.md §8 failure matrix): any per-connection
//! error — truncated frame, CRC mismatch, version/round skew, shape
//! mismatch, out-of-range coefficients, mid-upload disconnect — discards
//! only that connection's upload. The client is reported in
//! [`IntakeOutcome::failed`] and folded into the round's straggler
//! accounting; the round itself always completes from the uploads that did
//! land. Nothing on this path panics, and no attacker-controlled length can
//! allocate beyond one legitimate frame ([`super::frame::frame_payload_cap`]).

use super::frame::{
    decode_begin, decode_end_timing, frame_payload_cap, read_frame_into_with, write_frame_with,
    FrameKind, RxAuth, TxAuth, AUTH_TRAILER_BYTES, BEGIN_PAYLOAD_BYTES,
};
use crate::agg_engine::Arrival;
use crate::ckks::{CkksContext, CkksParams};
use crate::he_agg::{EncryptedUpdate, EncryptionMask};
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Sentinel client id for a connection that failed before its BEGIN frame
/// identified it.
pub const UNIDENTIFIED_CLIENT: u64 = u64::MAX;

/// Expected shape of every upload in a round, derived by the server from the
/// agreed mask + crypto context. BEGIN declarations must match exactly, so a
/// client can never size a server-side buffer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct UpdateShape {
    pub n_cts: usize,
    pub n_plain: usize,
    pub total: usize,
}

impl UpdateShape {
    /// Shape of a selectively-encrypted update under `mask`.
    pub fn for_round(ctx: &CkksContext, mask: &EncryptionMask) -> Self {
        let enc = mask.encrypted_count();
        UpdateShape {
            n_cts: enc.div_ceil(ctx.batch()),
            n_plain: mask.total() - enc,
            total: mask.total(),
        }
    }
}

/// Per-round intake knobs.
#[derive(Debug, Clone)]
pub struct IntakeConfig {
    pub round_id: u64,
    /// Connections to wait for (one per expected participant).
    pub expected_uploads: usize,
    /// Quorum for the early-stop hint: once this many uploads completed,
    /// the accept loop waits only `straggler_timeout` longer. The
    /// authoritative accept/drop decision is re-derived at seal by
    /// [`crate::agg_engine::RoundIntake`] over the same stamps.
    pub quorum: Option<usize>,
    pub straggler_timeout: Duration,
    /// Hard wall-clock bound on the whole intake — a hung accept loop fails
    /// fast instead of hanging the round (and CI).
    pub max_wait: Duration,
    /// Per-connection socket read/write timeout.
    pub io_timeout: Duration,
}

impl Default for IntakeConfig {
    fn default() -> Self {
        IntakeConfig {
            round_id: 0,
            expected_uploads: 0,
            quorum: None,
            straggler_timeout: Duration::from_secs(5),
            max_wait: Duration::from_secs(60),
            io_timeout: Duration::from_secs(10),
        }
    }
}

/// What one round's intake produced.
#[derive(Debug, Clone, Default)]
pub struct IntakeOutcome {
    /// Completed uploads, stamped with seconds since the intake opened and
    /// sorted by (stamp, client) — ready for the quorum/straggler policy.
    pub arrivals: Vec<Arrival>,
    /// Clients whose upload failed mid-stream ([`UNIDENTIFIED_CLIENT`] when
    /// the failure predates their BEGIN frame). The caller folds these into
    /// `StreamStats::dropped_stragglers`.
    pub failed: Vec<u64>,
    /// Frame bytes received across all connections, including failed ones.
    pub bytes_received: u64,
    /// Wall-clock duration of the intake (accept-open to last handler done).
    pub elapsed_secs: f64,
    /// Σ client-reported local training seconds over completed uploads
    /// (END-frame metric payloads; zero for clients that do not report).
    pub train_secs: f64,
    /// Σ client-reported encryption seconds over completed uploads.
    pub encrypt_secs: f64,
    /// Σ client-reported training losses over completed uploads.
    pub loss_sum: f64,
}

/// A bound TCP intake serving one round at a time.
pub struct TcpIntake {
    listener: TcpListener,
    params: std::sync::Arc<CkksParams>,
    shape: UpdateShape,
}

impl TcpIntake {
    /// Bind the intake socket (use port 0 for an ephemeral loopback port).
    pub fn bind(
        addr: &str,
        params: std::sync::Arc<CkksParams>,
        shape: UpdateShape,
    ) -> anyhow::Result<Self> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow::anyhow!("cannot bind transport intake on {addr}: {e}"))?;
        Ok(TcpIntake {
            listener,
            params,
            shape,
        })
    }

    /// The bound address (what clients dial).
    pub fn local_addr(&self) -> anyhow::Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Accept and reassemble one round of uploads. Each connection is served
    /// on its own worker thread; completed updates are stamped (seconds
    /// since the intake opened) under one lock, so stamps are monotone in
    /// completion order. Accepts until `expected_uploads` uploads have
    /// settled (completed, or failed after identifying themselves with a
    /// valid BEGIN — anonymous probes never consume a slot), the quorum
    /// early-stop cutoff passes, or `max_wait` expires — whichever comes
    /// first; uploads still in flight at that point are finished and
    /// included before returning. Duplicate uploads for an already-counted
    /// client id are discarded into `failed`.
    pub fn collect_round(&self, cfg: &IntakeConfig) -> anyhow::Result<IntakeOutcome> {
        let start = Instant::now();
        let deadline = start + cfg.max_wait;
        self.listener.set_nonblocking(true)?;
        let completed: Mutex<Vec<Arrival>> = Mutex::new(Vec::new());
        let failed: Mutex<Vec<u64>> = Mutex::new(Vec::new());
        let timing_sums: Mutex<(f64, f64, f64)> = Mutex::new((0.0, 0.0, 0.0));
        let bytes = AtomicU64::new(0);
        // Set when the quorum-th upload completes: accept only until then +
        // straggler_timeout (an upload already in flight still finishes and
        // is judged by the seal-time policy).
        let accept_cutoff: Mutex<Option<Instant>> = Mutex::new(None);
        let params = &*self.params;
        let shape = self.shape;

        // A participant slot "settles" on a completed upload or an
        // *identified* failure (the connection got through a valid BEGIN for
        // this round). Anonymous probes — port scanners, garbage bytes —
        // are recorded in `failed` but never settle a slot, so they cannot
        // displace a legitimate participant; absent participants are
        // bounded by the quorum cutoff / `max_wait` instead.
        let settled = AtomicUsize::new(0);
        // Live per-connection worker threads. Bounding this (instead of a
        // lifetime spawn count) keeps the accept loop serving after bursts
        // of fast-failing probes: past the cap, new connections wait in the
        // listen backlog instead of each pinning a thread + frame buffer.
        let in_flight = AtomicUsize::new(0);
        let max_in_flight = cfg.expected_uploads.saturating_mul(2).saturating_add(32);

        std::thread::scope(|s| -> anyhow::Result<()> {
            loop {
                if settled.load(Ordering::Relaxed) >= cfg.expected_uploads {
                    break;
                }
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                if let Some(cut) = *accept_cutoff.lock().unwrap() {
                    if now >= cut {
                        break;
                    }
                }
                if in_flight.load(Ordering::Relaxed) >= max_in_flight {
                    std::thread::sleep(Duration::from_millis(1));
                    continue;
                }
                match self.listener.accept() {
                    Ok((stream, _peer)) => {
                        in_flight.fetch_add(1, Ordering::Relaxed);
                        let completed = &completed;
                        let failed = &failed;
                        let bytes = &bytes;
                        let timing_sums = &timing_sums;
                        let accept_cutoff = &accept_cutoff;
                        let settled = &settled;
                        let in_flight = &in_flight;
                        let cfg = cfg.clone();
                        s.spawn(move || {
                            let mut seen_client: Option<u64> = None;
                            let mut received = 0u64;
                            let result = receive_update(
                                stream,
                                params,
                                shape,
                                &cfg,
                                deadline,
                                &mut seen_client,
                                &mut received,
                            );
                            bytes.fetch_add(received, Ordering::Relaxed);
                            match result {
                                Ok(UploadFrames {
                                    client,
                                    alpha,
                                    train_secs,
                                    encrypt_secs,
                                    loss,
                                    update,
                                }) => {
                                    let mut done = completed.lock().unwrap();
                                    if done.iter().any(|a| a.client == client) {
                                        // a retry after a lost ACK (or a
                                        // forged id): the first completion
                                        // already counts — aggregating the
                                        // duplicate would double its weight
                                        drop(done);
                                        crate::log_debug!(
                                            "transport",
                                            "duplicate upload from client {client} discarded"
                                        );
                                        failed.lock().unwrap().push(client);
                                    } else {
                                        // stamp inside the lock → stamps
                                        // are monotone in push order
                                        let t = start.elapsed().as_secs_f64();
                                        done.push(Arrival {
                                            client,
                                            alpha,
                                            arrival_secs: t,
                                            update: std::sync::Arc::new(update),
                                        });
                                        let n_done = done.len();
                                        drop(done);
                                        {
                                            let mut t = timing_sums.lock().unwrap();
                                            t.0 += train_secs;
                                            t.1 += encrypt_secs;
                                            t.2 += loss as f64;
                                        }
                                        // a completion after an earlier
                                        // failed attempt reuses the slot
                                        // that failure already settled
                                        let failed_before =
                                            failed.lock().unwrap().contains(&client);
                                        if !failed_before {
                                            settled.fetch_add(1, Ordering::Relaxed);
                                        }
                                        if let Some(q) = cfg.quorum {
                                            if n_done >= q.max(1) {
                                                let mut cut =
                                                    accept_cutoff.lock().unwrap();
                                                if cut.is_none() {
                                                    *cut = Some(
                                                        Instant::now()
                                                            + cfg.straggler_timeout,
                                                    );
                                                }
                                            }
                                        }
                                    }
                                }
                                Err(e) => {
                                    let id = seen_client.unwrap_or(UNIDENTIFIED_CLIENT);
                                    crate::log_debug!(
                                        "transport",
                                        "upload from client {id} failed: {e}"
                                    );
                                    // a given client id settles at most one
                                    // slot, across completions and failures
                                    // — replaying BEGIN-then-disconnect (or
                                    // failing a retry after a completed
                                    // upload) must not burn the other
                                    // participants' slots
                                    let completed_before = completed
                                        .lock()
                                        .unwrap()
                                        .iter()
                                        .any(|a| a.client == id);
                                    let mut f = failed.lock().unwrap();
                                    let first_failure = !f.contains(&id);
                                    f.push(id);
                                    drop(f);
                                    if seen_client.is_some()
                                        && first_failure
                                        && !completed_before
                                    {
                                        settled.fetch_add(1, Ordering::Relaxed);
                                    }
                                }
                            }
                            in_flight.fetch_sub(1, Ordering::Relaxed);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(1));
                    }
                    // a peer that RSTs before we accept (connection churn,
                    // port scans) kills only that connection, not the round
                    Err(e)
                        if matches!(
                            e.kind(),
                            std::io::ErrorKind::ConnectionAborted
                                | std::io::ErrorKind::ConnectionReset
                                | std::io::ErrorKind::Interrupted
                        ) => {}
                    Err(e) => anyhow::bail!("transport accept failed: {e}"),
                }
            }
            Ok(())
        })?;

        let mut arrivals = completed.into_inner().unwrap();
        arrivals.sort_by(|a, b| {
            a.arrival_secs
                .total_cmp(&b.arrival_secs)
                .then(a.client.cmp(&b.client))
        });
        let (train_secs, encrypt_secs, loss_sum) = timing_sums.into_inner().unwrap();
        Ok(IntakeOutcome {
            arrivals,
            failed: failed.into_inner().unwrap(),
            bytes_received: bytes.load(Ordering::Relaxed),
            elapsed_secs: start.elapsed().as_secs_f64(),
            train_secs,
            encrypt_secs,
            loss_sum,
        })
    }
}

/// One reassembled upload (shared between the one-shot intake and the
/// persistent-session collector).
pub(crate) struct UploadFrames {
    pub client: u64,
    pub alpha: f64,
    /// Client-reported local metrics from the END payload (zeros when the
    /// client does not report them).
    pub train_secs: f64,
    pub encrypt_secs: f64,
    pub loss: f32,
    pub update: EncryptedUpdate,
}

/// Reassemble one client's upload off a connection. Any validation failure
/// or disconnect returns `Err`; `seen_client`/`received` report partial
/// progress either way. The ACK is written to `ack_stream` after a valid
/// END.
///
/// `deadline()` is re-evaluated before every frame (the session collector
/// tightens it once a quorum cutoff is known) and the socket read timeout
/// is clamped to the time remaining, so a slowly-trickling connection
/// cannot hold the round open much past the bound by resetting the
/// per-read timer. `expect_client` pins the BEGIN identity (persistent
/// sessions already know whose socket this is) and `expect_alpha` pins the
/// declared FedAvg weight to the one the server assigned for the round —
/// rejecting a skewed weight here keeps the upload out of both the
/// aggregate *and* the round's metric sums; `payload` is the pooled
/// per-connection frame buffer — steady-state frame reads allocate nothing
/// (gated by `tests/zero_alloc.rs`). Under `--wire-auth mac`, `rx` verifies
/// every inbound frame's auth trailer (replayed/forged frames are counted
/// and discarded inside the frame reader) and `tx` tags the ACK.
#[allow(clippy::too_many_arguments)]
pub(crate) fn read_upload<R: std::io::Read, F: Fn() -> Instant>(
    reader: &mut R,
    stream: &TcpStream,
    ack_stream: &TcpStream,
    params: &CkksParams,
    shape: UpdateShape,
    round_id: u64,
    io_timeout: Duration,
    deadline: &F,
    expect_client: Option<u64>,
    expect_alpha: Option<f64>,
    seen_client: &mut Option<u64>,
    received: &mut u64,
    payload: &mut Vec<u8>,
    rx: &mut Option<RxAuth>,
    tx: &mut Option<TxAuth>,
) -> anyhow::Result<UploadFrames> {
    let cap = frame_payload_cap(params);
    let arm_read = |stream: &TcpStream| -> anyhow::Result<()> {
        let remaining = deadline().saturating_duration_since(Instant::now());
        anyhow::ensure!(!remaining.is_zero(), "upload exceeded the intake deadline");
        stream.set_read_timeout(Some(remaining.min(io_timeout)))?;
        Ok(())
    };
    let auth_extra = if rx.is_some() { AUTH_TRAILER_BYTES } else { 0 };
    let frame_bytes = |payload_len: usize| {
        (super::frame::FRAME_HEADER_BYTES
            + payload_len
            + super::frame::FRAME_TRAILER_BYTES
            + auth_extra) as u64
    };

    // BEGIN: identity + declared shape, checked against the round's shape.
    arm_read(stream)?;
    let (kind, _) = read_frame_into_with(reader, round_id, cap, payload, rx)?;
    *received += frame_bytes(payload.len());
    anyhow::ensure!(
        kind == FrameKind::Begin,
        "upload must start with BEGIN, got {kind:?}"
    );
    anyhow::ensure!(
        payload.len() == BEGIN_PAYLOAD_BYTES,
        "BEGIN payload length {}",
        payload.len()
    );
    let (client, alpha, n_cts, n_plain, total) = decode_begin(payload)?;
    // rejected before the connection counts as "identified": the sentinel
    // would corrupt slot settling and straggler accounting downstream
    anyhow::ensure!(
        client != UNIDENTIFIED_CLIENT,
        "client id {client} is reserved"
    );
    if let Some(expected) = expect_client {
        anyhow::ensure!(
            client == expected,
            "session for client {expected} sent BEGIN for client {client}"
        );
    }
    if let Some(expected) = expect_alpha {
        anyhow::ensure!(
            (alpha - expected).abs() <= 1e-9,
            "client {client} declared FedAvg weight {alpha}, round assigned {expected}"
        );
    }
    *seen_client = Some(client);
    anyhow::ensure!(
        n_cts == shape.n_cts && n_plain == shape.n_plain && total == shape.total,
        "upload shape ({n_cts} cts, {n_plain} plain, {total} total) does not match \
         the round shape ({} cts, {} plain, {} total)",
        shape.n_cts,
        shape.n_plain,
        shape.total
    );

    let _span = crate::obs::span_arg("transport", "read_upload", client);
    let mut asm = super::reassembly::ChunkAssembler::new(n_cts, n_plain, total);
    let timing;
    loop {
        arm_read(stream)?;
        let (kind, seq) = read_frame_into_with(reader, round_id, cap, payload, rx)?;
        *received += frame_bytes(payload.len());
        match kind {
            FrameKind::CtChunk => asm.accept_ct(params, seq, payload)?,
            FrameKind::Plain => asm.accept_plain(seq, payload)?,
            FrameKind::End => {
                timing = decode_end_timing(payload)?;
                break;
            }
            FrameKind::Begin => anyhow::bail!("duplicate BEGIN frame"),
            other => anyhow::bail!("unexpected {other:?} frame in an upload"),
        }
    }
    let update = asm.finish()?;
    let mut ack_w = ack_stream;
    write_frame_with(&mut ack_w, round_id, FrameKind::Ack, 0, &0u32.to_le_bytes(), tx)?;
    Ok(UploadFrames {
        client,
        alpha,
        train_secs: timing.0,
        encrypt_secs: timing.1,
        loss: timing.2,
        update,
    })
}

/// One-shot connection wrapper over [`read_upload`] (the anonymous uplink
/// path of [`TcpIntake`]): fresh `BufReader` + pooled frame buffer per
/// connection, intake-wide `max_wait` as the deadline.
fn receive_update(
    stream: TcpStream,
    params: &CkksParams,
    shape: UpdateShape,
    cfg: &IntakeConfig,
    deadline: Instant,
    seen_client: &mut Option<u64>,
    received: &mut u64,
) -> anyhow::Result<UploadFrames> {
    stream.set_nonblocking(false)?;
    stream.set_write_timeout(Some(cfg.io_timeout))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    // Per-connection pooled payload buffer: every frame of this upload
    // reuses it (ROADMAP follow-up: no per-frame payload Vec).
    let mut payload = Vec::new();
    read_upload(
        &mut reader,
        &stream,
        &stream,
        params,
        shape,
        cfg.round_id,
        cfg.io_timeout,
        &move || deadline,
        None,
        None,
        seen_client,
        received,
        &mut payload,
        &mut None,
        &mut None,
    )
}
