//! Minimal readiness reactor over raw `epoll` (DESIGN.md §13).
//!
//! The event-driven session hub (`transport::hub`) needs OS readiness
//! notification without a vendored `mio`: this module hand-rolls the three
//! `epoll` syscalls plus `eventfd` through `extern "C"` declarations (std
//! already links the platform libc, so no new dependency is introduced —
//! the build stays offline). The surface is deliberately tiny and
//! level-triggered:
//!
//! * [`Poller`] — one `epoll` instance; sockets register with a caller
//!   chosen `u64` token and `(readable, writable)` interest, and
//!   [`Poller::wait`] parks the shard thread until readiness or timeout
//!   (no busy-wait, no sleep loop).
//! * [`Wakeup`] — a nonblocking `eventfd` registered like any socket, so
//!   other threads (the hub façade, the accept thread, `shutdown`) can
//!   interrupt a parked [`Poller::wait`] to deliver queued commands.
//!
//! Level-triggered mode keeps the state machines simple: a socket with
//! unread bytes or writable buffer space keeps reporting ready, so a shard
//! that stops mid-frame (frame-buffer pool exhausted, fairness cap) is
//! re-notified on the next `wait` without edge-trigger re-arm bookkeeping.

use std::fs::File;
use std::io::{self, Read, Write};
use std::os::fd::{AsRawFd, FromRawFd, RawFd};
use std::time::Duration;

// Raw syscall bindings (x86_64 Linux ABI). `std` links libc, so these
// resolve at link time without adding a crate.
extern "C" {
    fn epoll_create1(flags: i32) -> i32;
    fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
    fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
    fn eventfd(initval: u32, flags: i32) -> i32;
    fn close(fd: i32) -> i32;
}

const EPOLLIN: u32 = 0x001;
const EPOLLOUT: u32 = 0x004;
const EPOLLERR: u32 = 0x008;
const EPOLLHUP: u32 = 0x010;
const EPOLLRDHUP: u32 = 0x2000;

const EPOLL_CTL_ADD: i32 = 1;
const EPOLL_CTL_DEL: i32 = 2;
const EPOLL_CTL_MOD: i32 = 3;

const EPOLL_CLOEXEC: i32 = 0o2000000;
const EFD_CLOEXEC: i32 = 0o2000000;
const EFD_NONBLOCK: i32 = 0o4000;

/// `struct epoll_event` with the x86_64 layout (packed, 12 bytes).
#[repr(C, packed)]
#[derive(Clone, Copy)]
struct EpollEvent {
    events: u32,
    data: u64,
}

/// One readiness notification out of [`Poller::wait`].
#[derive(Clone, Copy, Debug)]
pub(crate) struct Event {
    /// The token the fd was registered with.
    pub token: u64,
    /// `EPOLLIN`: bytes (or a pending accept) are available.
    pub readable: bool,
    /// `EPOLLOUT`: the socket send buffer has room.
    pub writable: bool,
    /// `EPOLLERR`/`EPOLLHUP`/`EPOLLRDHUP`: the peer closed or the socket
    /// errored — drive the read path to observe the EOF/error.
    pub closed: bool,
}

fn cvt(ret: i32) -> io::Result<i32> {
    if ret < 0 {
        Err(io::Error::last_os_error())
    } else {
        Ok(ret)
    }
}

fn interest_bits(readable: bool, writable: bool) -> u32 {
    let mut ev = EPOLLRDHUP; // always observe peer half-close
    if readable {
        ev |= EPOLLIN;
    }
    if writable {
        ev |= EPOLLOUT;
    }
    ev
}

/// A level-triggered `epoll` instance owning its epoll fd.
pub(crate) struct Poller {
    epfd: RawFd,
}

impl Poller {
    pub fn new() -> io::Result<Self> {
        // Safety: no pointers involved; a negative return is mapped to errno.
        let epfd = cvt(unsafe { epoll_create1(EPOLL_CLOEXEC) })?;
        Ok(Poller { epfd })
    }

    fn ctl(&self, op: i32, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
        let mut ev = EpollEvent { events, data: token };
        // Safety: `ev` outlives the call; the kernel copies it out.
        cvt(unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) })?;
        Ok(())
    }

    /// Register `fd` under `token` with the given interest.
    pub fn add(&self, fd: RawFd, token: u64, readable: bool, writable: bool) -> io::Result<()> {
        self.ctl(EPOLL_CTL_ADD, fd, interest_bits(readable, writable), token)
    }

    /// Change the interest set of an already registered fd.
    pub fn modify(&self, fd: RawFd, token: u64, readable: bool, writable: bool) -> io::Result<()> {
        self.ctl(EPOLL_CTL_MOD, fd, interest_bits(readable, writable), token)
    }

    /// Deregister an fd (must be called before the fd is closed elsewhere,
    /// or the kernel drops it automatically on close).
    pub fn delete(&self, fd: RawFd) -> io::Result<()> {
        self.ctl(EPOLL_CTL_DEL, fd, 0, 0)
    }

    /// Park until readiness or `timeout` (`None` = wait forever), appending
    /// the ready set to `out` (which is cleared first). Returns the number
    /// of events delivered; `0` means the timeout elapsed.
    pub fn wait(&self, out: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<usize> {
        out.clear();
        let timeout_ms: i32 = match timeout {
            None => -1,
            // round up so a 100µs deadline doesn't turn into a spin at 0ms
            Some(d) => d
                .as_millis()
                .saturating_add(u128::from(d.subsec_nanos() % 1_000_000 != 0))
                .min(i32::MAX as u128) as i32,
        };
        let mut buf = [EpollEvent { events: 0, data: 0 }; 256];
        let n = loop {
            // Safety: `buf` is a valid, writable array of `maxevents` entries.
            let ret = unsafe {
                epoll_wait(self.epfd, buf.as_mut_ptr(), buf.len() as i32, timeout_ms)
            };
            match cvt(ret) {
                Ok(n) => break n as usize,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        };
        for ev in &buf[..n] {
            let bits = ev.events;
            out.push(Event {
                token: ev.data,
                readable: bits & EPOLLIN != 0,
                writable: bits & EPOLLOUT != 0,
                closed: bits & (EPOLLERR | EPOLLHUP | EPOLLRDHUP) != 0,
            });
        }
        Ok(n)
    }
}

impl Drop for Poller {
    fn drop(&mut self) {
        // Safety: epfd is owned by this Poller and closed exactly once.
        unsafe {
            close(self.epfd);
        }
    }
}

/// Cross-thread wakeup: a nonblocking `eventfd` whose read side sits in a
/// [`Poller`] under a reserved token. [`Wakeup::wake`] is cheap, wait-free
/// from the caller's perspective, and safe from any thread.
pub(crate) struct Wakeup {
    file: File,
}

impl Wakeup {
    pub fn new() -> io::Result<Self> {
        let fd = cvt(unsafe { eventfd(0, EFD_NONBLOCK | EFD_CLOEXEC) })?;
        // Safety: `fd` is a fresh, owned eventfd; File takes ownership and
        // closes it on drop.
        Ok(Wakeup { file: unsafe { File::from_raw_fd(fd) } })
    }

    pub fn as_raw_fd(&self) -> RawFd {
        self.file.as_raw_fd()
    }

    /// Signal the poller. A full counter (`WouldBlock`) still leaves the fd
    /// readable, so the wakeup is never lost.
    pub fn wake(&self) {
        match (&self.file).write_all(&1u64.to_le_bytes()) {
            Ok(()) => {}
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {}
            Err(_) => {}
        }
    }

    /// Clear the counter after a wakeup so level-triggered polling settles.
    pub fn drain(&self) {
        let mut buf = [0u8; 8];
        loop {
            match (&self.file).read(&mut buf) {
                Ok(_) => break, // one read empties an eventfd counter
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => break,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;
    use std::net::{TcpListener, TcpStream};

    #[test]
    fn wakeup_interrupts_a_parked_wait() {
        let poller = Poller::new().unwrap();
        let wake = Wakeup::new().unwrap();
        poller.add(wake.as_raw_fd(), 7, true, false).unwrap();
        let mut events = Vec::new();
        // nothing pending: a finite wait times out with zero events
        assert_eq!(poller.wait(&mut events, Some(Duration::from_millis(5))).unwrap(), 0);
        wake.wake();
        wake.wake(); // coalesces into one readable notification
        let n = poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        assert_eq!(n, 1);
        assert_eq!(events[0].token, 7);
        assert!(events[0].readable);
        wake.drain();
        assert_eq!(poller.wait(&mut events, Some(Duration::from_millis(5))).unwrap(), 0);
    }

    #[test]
    fn socket_readiness_reports_read_write_and_close() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();

        let poller = Poller::new().unwrap();
        poller.add(server.as_raw_fd(), 42, true, true).unwrap();
        let mut events = Vec::new();

        // a fresh socket is writable but not readable
        poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        let ev = events.iter().find(|e| e.token == 42).unwrap();
        assert!(ev.writable && !ev.readable);

        // narrowing interest to read-only silences the writable report
        poller.modify(server.as_raw_fd(), 42, true, false).unwrap();
        assert_eq!(poller.wait(&mut events, Some(Duration::from_millis(5))).unwrap(), 0);

        client.write_all(b"ping").unwrap();
        poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        assert!(events.iter().any(|e| e.token == 42 && e.readable));

        drop(client); // peer close surfaces as a closed (RDHUP) event
        poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        assert!(events.iter().any(|e| e.token == 42 && e.closed));

        poller.delete(server.as_raw_fd()).unwrap();
        assert_eq!(poller.wait(&mut events, Some(Duration::from_millis(5))).unwrap(), 0);
    }
}
