//! Persistent duplex client sessions: the symmetric transport of
//! DESIGN.md §9.
//!
//! PR-4's transport was uplink-only and one-shot — every upload dialed a
//! fresh connection and the downlink broadcast never touched the wire. Here
//! the client/server boundary is one long-lived duplex connection per
//! client, serving the whole task:
//!
//! * **Handshake** — the client claims its slot with a HELLO frame
//!   ([`super::frame::CONTROL_ROUND`]); the server replies WELCOME with the
//!   next round it will serve. A reconnect with the same client id rebinds
//!   the slot (disconnect-between-rounds → rejoin), replacing any dead
//!   connection; the client's task state (global model, mask, rng streams)
//!   lives in the client process, so nothing needs replaying.
//! * **Downlink** — the server *pushes* real frames: the agreed encryption
//!   mask (MASK, run-delta wire format) and, per round, the
//!   partially-encrypted global aggregate (DOWN_BEGIN + CT_CHUNK/PLAIN +
//!   DOWN_END, ciphertext payloads in the `ckks::serialize` per-shard wire
//!   format). Downlink byte counts and wall-clock times are measured, not
//!   simulated — they are what `FlReport` reports under `--transport tcp`.
//! * **Uplink** — per-round uploads reuse the PR-4 frame sequence
//!   (BEGIN..END) over the persistent socket, reassembled by the same
//!   [`super::intake::read_upload`] validation path with a pooled
//!   per-session frame buffer, stamped on completion, and offered to the
//!   streaming aggregation engine as true `Arrival`s.
//!
//! Failure containment matches the intake: any per-session wire error
//! kills that session only — the round completes from the uploads that
//! landed, the client is reported as failed/straggler, and its slot is
//! free to rejoin. Under `--wire-auth mac` (DESIGN.md §12) the handshake
//! additionally runs a server-nonce challenge/response keyed by the
//! client's MAC key from the task-key file, every post-handshake frame
//! carries a truncated keyed-hash tag plus a monotone auth sequence
//! (replay rejection), and a rejoining session is replayed the current
//! stage's downlink so a mid-round disconnect resumes instead of
//! stalling. With `--wire-auth none` the legacy unauthenticated wire is
//! preserved bit-for-bit.

use super::chaos::{ChaosConfig, ChaosWriter};
use super::client::{connect_with_backoff, FrameSink, UploadReceipt};
use super::frame::{
    decode_challenge, decode_challenge_resp, decode_down_begin, decode_hello, decode_welcome,
    encode_challenge, encode_challenge_resp, encode_down_begin, encode_hello, encode_welcome,
    frame_payload_cap, mask_payload_cap, read_frame_any_round_into_with, read_frame_into,
    write_frame, write_frame_with, DownBegin, FrameKind, RxAuth, TxAuth, AUTH_DIR_DOWN,
    AUTH_DIR_UP, AUTH_TRAILER_BYTES, CHALLENGE_RESP_PAYLOAD_BYTES, CONTROL_ROUND,
    FRAME_HEADER_BYTES, FRAME_TRAILER_BYTES, MASK_ROUND, PLAIN_CHUNK_VALUES,
    WELCOME_PAYLOAD_BYTES,
};
use super::intake::{
    read_upload, IntakeConfig, IntakeOutcome, UpdateShape, UploadFrames, UNIDENTIFIED_CLIENT,
};
use crate::agg_engine::Arrival;
use crate::ckks::serialize::ciphertext_shard_append;
use crate::ckks::{CkksParams, CtWire};
use crate::crypto::mac::{self, MacKey};
use crate::crypto::prng::ChaChaRng;
use crate::he_agg::{EncryptedUpdate, EncryptionMask};
use std::collections::HashMap;
use std::io::{BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::fd::AsRawFd;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// One server-side persistent session.
pub struct PeerSession {
    pub client: u64,
    stream: TcpStream,
    reader: BufReader<TcpStream>,
    /// Pooled frame payload buffer for this session's uplink reads.
    read_buf: Vec<u8>,
    /// Outbound (server→client) frame authenticator; `None` = legacy wire.
    tx: Option<TxAuth>,
    /// Inbound (client→server) frame authenticator.
    rx: Option<RxAuth>,
}

/// What one downlink push put on the wire.
#[derive(Debug, Clone, Default)]
pub struct DownlinkOutcome {
    /// Frame bytes written across all reached sessions.
    pub bytes_sent: u64,
    /// Wall-clock duration of the push (serialize + socket writes).
    pub elapsed_secs: f64,
    /// Clients whose session was missing or died mid-push (their slot is
    /// freed for a rejoin).
    pub failed: Vec<u64>,
}

/// A registered session, shared between the accept thread (rejoin
/// replacement), the broadcast path, and per-round reader threads.
type SharedSession = Arc<Mutex<PeerSession>>;

/// The most recent downlink of each stage, kept so a mid-round rejoin can
/// be replayed what it missed (the aggregate payloads are shared with the
/// broadcast path via `Arc` — caching copies nothing model-sized). Shared
/// with the reactor backend (`super::hub`), which keeps the same replay
/// semantics under its registry lock.
#[derive(Default)]
pub(crate) struct DownlinkCache {
    /// Serialized agreed mask (the MASK broadcast payload).
    pub mask: Option<Vec<u8>>,
    /// The in-flight round's downlink: per-client preambles + the shared
    /// aggregate's pre-encoded frame payloads.
    pub round: Option<RoundSnapshot>,
}

pub(crate) struct RoundSnapshot {
    pub round: u64,
    pub plans: Vec<(u64, DownBegin)>,
    /// Whether the broadcast actually carried aggregate payloads (guards a
    /// replay against a preamble whose chunks were never encoded).
    pub has_payloads: bool,
    pub ct_payloads: Arc<Vec<Vec<u8>>>,
    pub plain_payloads: Arc<Vec<Vec<u8>>>,
}

/// One client's slice of the cached round downlink (Arc-shared payloads —
/// snapshotting copies nothing model-sized).
pub(crate) struct RoundReplay {
    pub round: u64,
    pub down: DownBegin,
    pub has_payloads: bool,
    pub ct_payloads: Arc<Vec<Vec<u8>>>,
    pub plain_payloads: Arc<Vec<Vec<u8>>>,
}

impl DownlinkCache {
    /// Snapshot what a (re)joining `client` must be replayed: the agreed
    /// mask and, when the in-flight round's broadcast addressed it, that
    /// round's preamble + shared aggregate payloads. Callers take this
    /// under their registry/cache lock and write the frames after.
    pub fn replay_for(&self, client: u64) -> (Option<Vec<u8>>, Option<RoundReplay>) {
        let round = self.round.as_ref().and_then(|snap| {
            snap.plans
                .iter()
                .find(|(id, _)| *id == client)
                .map(|(_, down)| RoundReplay {
                    round: snap.round,
                    down: *down,
                    has_payloads: snap.has_payloads,
                    ct_payloads: snap.ct_payloads.clone(),
                    plain_payloads: snap.plain_payloads.clone(),
                })
        });
        (self.mask.clone(), round)
    }
}

/// Write a (re)join's downlink replay — the cached mask, then the cached
/// round downlink when present — shared by the blocking handshake and the
/// reactor shard's registration step.
pub(crate) fn write_replay<W: Write>(
    w: &mut W,
    mask: &Option<Vec<u8>>,
    round: &Option<RoundReplay>,
    auth: &mut Option<TxAuth>,
) -> std::io::Result<u64> {
    let mut sent = 0u64;
    if let Some(mask) = mask {
        sent += write_frame_with(w, MASK_ROUND, FrameKind::Mask, 0, mask, auth)?;
    }
    if let Some(replay) = round {
        let carried = (replay.down.has_agg && replay.has_payloads)
            .then(|| (replay.ct_payloads.as_slice(), replay.plain_payloads.as_slice()));
        sent += write_round_frames(w, replay.round, &replay.down, carried, auth)?;
    }
    Ok(sent)
}

/// Pre-encode a shared aggregate's downlink frame payloads **once** (per-ct
/// shard bytes + packed plain chunks) for fan-out to every session —
/// O(model + N·frames), not O(N·model). Shared by both hub backends.
pub(crate) fn encode_agg_payloads(agg: &EncryptedUpdate) -> (Vec<Vec<u8>>, Vec<Vec<u8>>) {
    let mut ct_payloads: Vec<Vec<u8>> = Vec::with_capacity(agg.cts.len());
    for ct in &agg.cts {
        let mut b = Vec::new();
        ciphertext_shard_append(ct, 0, ct.c0.num_limbs(), &mut b);
        ct_payloads.push(b);
    }
    let mut plain_payloads: Vec<Vec<u8>> =
        Vec::with_capacity(agg.plain.len().div_ceil(PLAIN_CHUNK_VALUES.max(1)));
    for chunk in agg.plain.chunks(PLAIN_CHUNK_VALUES) {
        let mut b = Vec::with_capacity(chunk.len() * 4);
        for &v in chunk {
            b.extend_from_slice(&v.to_le_bytes());
        }
        plain_payloads.push(b);
    }
    (ct_payloads, plain_payloads)
}

struct HubShared {
    listener: TcpListener,
    params: Arc<CkksParams>,
    sessions: Mutex<HashMap<u64, SharedSession>>,
    /// Signaled (with the `sessions` lock) whenever a handshake registers a
    /// session — [`SessionHub::wait_for_clients`] parks here instead of
    /// sleep-polling the registry.
    joined: Condvar,
    /// Interrupts the accept loop's epoll park (shutdown).
    accept_wake: super::reactor::Wakeup,
    /// Advertised in WELCOME: the next wire round this server will serve
    /// ([`MASK_ROUND`] until the mask broadcast happens).
    next_round: AtomicU64,
    stop: AtomicBool,
    /// Bound on concurrently-registered sessions (a flood of HELLOs with
    /// distinct forged ids cannot grow the map without limit).
    max_sessions: usize,
    /// Live handshake threads (half-open connections awaiting HELLO) — a
    /// connected-but-silent peer must never stall other joins/rejoins.
    handshakes: AtomicUsize,
    io_timeout: Duration,
    /// Task MAC root (`--wire-auth mac`): per-client keys derive from it;
    /// `None` = legacy unauthenticated wire.
    auth_root: Option<[u8; 32]>,
    /// The task's ciphertext wire format (`--ct-wire`). Every HELLO must
    /// announce the same mode or the handshake fails — a session can never
    /// negotiate a per-client format.
    ct_wire: CtWire,
    /// Replay state for mid-round rejoins.
    downlink: Mutex<DownlinkCache>,
}

/// The server's session registry: one background accept thread serving
/// HELLO handshakes for the whole task, plus per-round broadcast/collect
/// entry points called by the coordinator's phase machine.
pub struct SessionHub {
    shared: Arc<HubShared>,
    accept: Option<std::thread::JoinHandle<()>>,
}

impl SessionHub {
    /// Bind the listen socket and start the accept thread. `max_sessions`
    /// bounds the registry (use ≥ the expected client count; rejoins
    /// replace their old entry and do not count twice).
    pub fn bind(
        addr: &str,
        params: Arc<CkksParams>,
        max_sessions: usize,
    ) -> anyhow::Result<Self> {
        Self::bind_with_auth(addr, params, max_sessions, None)
    }

    /// [`Self::bind`] with an optional task MAC root (`--wire-auth mac`):
    /// when set, every handshake runs the challenge/response and every
    /// session frame in both directions is authenticated.
    pub fn bind_with_auth(
        addr: &str,
        params: Arc<CkksParams>,
        max_sessions: usize,
        auth_root: Option<[u8; 32]>,
    ) -> anyhow::Result<Self> {
        Self::bind_full(addr, params, max_sessions, auth_root, CtWire::Dense)
    }

    /// [`Self::bind_with_auth`] with the task's ciphertext wire format
    /// (`--ct-wire`): every joining client must announce the same mode in
    /// its HELLO or the handshake fails.
    pub fn bind_full(
        addr: &str,
        params: Arc<CkksParams>,
        max_sessions: usize,
        auth_root: Option<[u8; 32]>,
        ct_wire: CtWire,
    ) -> anyhow::Result<Self> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow::anyhow!("cannot bind session hub on {addr}: {e}"))?;
        listener.set_nonblocking(true)?;
        let shared = Arc::new(HubShared {
            listener,
            params,
            sessions: Mutex::new(HashMap::new()),
            joined: Condvar::new(),
            accept_wake: super::reactor::Wakeup::new()?,
            next_round: AtomicU64::new(MASK_ROUND),
            stop: AtomicBool::new(false),
            max_sessions: max_sessions.max(1),
            handshakes: AtomicUsize::new(0),
            io_timeout: Duration::from_secs(10),
            auth_root,
            ct_wire,
            downlink: Mutex::new(DownlinkCache::default()),
        });
        let accept_shared = shared.clone();
        let accept = std::thread::spawn(move || accept_loop(accept_shared));
        Ok(SessionHub {
            shared,
            accept: Some(accept),
        })
    }

    /// The bound address (what clients dial).
    pub fn local_addr(&self) -> anyhow::Result<SocketAddr> {
        Ok(self.shared.listener.local_addr()?)
    }

    /// Advertise the next wire round (stamped into WELCOME replies so a
    /// rejoining client can sanity-check where the task is).
    pub fn set_next_round(&self, round: u64) {
        self.shared.next_round.store(round, Ordering::Relaxed);
    }

    /// Client ids with a currently-registered session.
    pub fn connected(&self) -> Vec<u64> {
        let mut ids: Vec<u64> = self.shared.sessions.lock().unwrap().keys().copied().collect();
        ids.sort_unstable();
        ids
    }

    fn session(&self, client: u64) -> Option<SharedSession> {
        self.shared.sessions.lock().unwrap().get(&client).cloned()
    }

    /// Drop whatever session currently occupies `client`'s slot (socket
    /// shut down; the slot is free to rejoin).
    pub fn drop_session(&self, client: u64) {
        // take the entry first: holding the map lock while waiting on a
        // session mutex would stall the accept thread behind a slow reader
        let removed = self.shared.sessions.lock().unwrap().remove(&client);
        if let Some(s) = removed {
            // try_lock: if a reader still holds the session it is already
            // failing out on its own timeouts
            if let Ok(sess) = s.try_lock() {
                sess.stream.shutdown(std::net::Shutdown::Both).ok();
            }
        }
    }

    /// Evict `client`'s slot **only if it still holds `observed`** — the
    /// session the caller actually saw fail. Without the identity check, a
    /// reader timing out on a dead connection could remove the fresh
    /// session of a client that had already rejoined mid-round. The
    /// observed (dead) session's socket is shut down either way.
    fn drop_session_if(&self, client: u64, observed: &SharedSession) {
        {
            let mut map = self.shared.sessions.lock().unwrap();
            let same = map
                .get(&client)
                .map(|s| Arc::ptr_eq(s, observed))
                .unwrap_or(false);
            if same {
                map.remove(&client);
            }
        }
        if let Ok(sess) = observed.try_lock() {
            sess.stream.shutdown(std::net::Shutdown::Both).ok();
        }
    }

    /// Block until `n` distinct clients hold sessions (the serve-side
    /// handshake barrier). Errors after `wait` with the shortfall. Parks on
    /// the registry condvar — each registering handshake wakes it — rather
    /// than sleep-polling the session map.
    pub fn wait_for_clients(&self, n: usize, wait: Duration) -> anyhow::Result<Vec<u64>> {
        let deadline = Instant::now() + wait;
        let mut map = self.shared.sessions.lock().unwrap();
        loop {
            if map.len() >= n {
                let mut ids: Vec<u64> = map.keys().copied().collect();
                ids.sort_unstable();
                return Ok(ids);
            }
            let now = Instant::now();
            anyhow::ensure!(
                now < deadline,
                "only {}/{n} clients joined within {:.0?}",
                map.len(),
                wait
            );
            let (guard, _timed_out) = self
                .shared
                .joined
                .wait_timeout(map, deadline - now)
                .unwrap();
            map = guard;
        }
    }

    /// Push the agreed mask to every listed client (MASK frame at
    /// [`MASK_ROUND`]). Sessions that fail mid-push are dropped and
    /// reported in the outcome.
    pub fn broadcast_mask(&self, clients: &[u64], mask_bytes: &[u8]) -> DownlinkOutcome {
        let start = Instant::now();
        // cache before pushing: a session that dies mid-push (or is still
        // mid-rejoin) is replayed the mask at its next handshake
        self.shared.downlink.lock().unwrap().mask = Some(mask_bytes.to_vec());
        let mut out = DownlinkOutcome::default();
        for &client in clients {
            match self.push_to(client, |sess| {
                // buffered: header/payload/crc leave as one segment, not
                // three NODELAY'd writes
                let mut w = BufWriter::new(&sess.stream);
                let n =
                    write_frame_with(&mut w, MASK_ROUND, FrameKind::Mask, 0, mask_bytes, &mut sess.tx)?;
                w.flush()?;
                Ok(n)
            }) {
                Ok(bytes) => out.bytes_sent += bytes,
                Err(e) => {
                    // push_to already evicted the failed session
                    crate::log_debug!("session", "mask downlink to {client} failed: {e}");
                    out.failed.push(client);
                }
            }
        }
        out.elapsed_secs = start.elapsed().as_secs_f64();
        out
    }

    /// Push one round's downlink to every listed client: the per-client
    /// DOWN_BEGIN preamble, the shared aggregate (when `agg` is set and the
    /// preamble's `has_agg` says so), and DOWN_END. The aggregate's chunk
    /// payloads are serialized **once** and fanned out to every session —
    /// O(model + N·frames), not O(N·model). Returns measured bytes and
    /// wall time — the real downlink cost `FlReport` records under tcp.
    pub fn broadcast_round(
        &self,
        round: u64,
        plans: &[(u64, DownBegin)],
        agg: Option<&EncryptedUpdate>,
    ) -> DownlinkOutcome {
        let start = Instant::now();
        // pre-encode the shared aggregate's frame payloads once
        let (ct_payloads, plain_payloads) = match agg {
            Some(agg) => encode_agg_payloads(agg),
            None => (Vec::new(), Vec::new()),
        };
        let ct_payloads = Arc::new(ct_payloads);
        let plain_payloads = Arc::new(plain_payloads);
        // cache before pushing (Arc-shared payloads — no copy): a client
        // whose downlink push fails can rejoin and be replayed this round
        {
            let mut cache = self.shared.downlink.lock().unwrap();
            cache.round = Some(RoundSnapshot {
                round,
                plans: plans.to_vec(),
                has_payloads: agg.is_some(),
                ct_payloads: ct_payloads.clone(),
                plain_payloads: plain_payloads.clone(),
            });
        }
        let mut out = DownlinkOutcome::default();
        for (client, down) in plans {
            let carried = (down.has_agg && agg.is_some())
                .then_some((ct_payloads.as_slice(), plain_payloads.as_slice()));
            match self.push_to(*client, |sess| push_round(sess, round, down, carried)) {
                Ok(bytes) => out.bytes_sent += bytes,
                Err(e) => {
                    // push_to already evicted the failed session
                    crate::log_debug!("session", "round {round} downlink to {client} failed: {e}");
                    out.failed.push(*client);
                }
            }
        }
        out.elapsed_secs = start.elapsed().as_secs_f64();
        out
    }

    /// Run a downlink write against `client`'s current session; on any io
    /// failure the observed session (and only it — identity-checked) is
    /// evicted so the slot can rejoin.
    fn push_to<F>(&self, client: u64, f: F) -> anyhow::Result<u64>
    where
        F: FnOnce(&mut PeerSession) -> std::io::Result<u64>,
    {
        let sess = self
            .session(client)
            .ok_or_else(|| anyhow::anyhow!("no session for client {client}"))?;
        let result = {
            let mut guard = sess.lock().unwrap();
            guard
                .stream
                .set_write_timeout(Some(self.shared.io_timeout))
                .map_err(anyhow::Error::from)
                .and_then(|_| f(&mut guard).map_err(anyhow::Error::from))
        };
        if result.is_err() {
            self.drop_session_if(client, &sess);
        }
        result
    }

    /// Collect one round of uploads from the expected clients' persistent
    /// sessions — the streaming-engine intake fed from sessions instead of
    /// one-shot connections. `expected` pairs each client id with the
    /// FedAvg weight the round assigned it (`None` = don't pin); an upload
    /// declaring a different weight fails its session before touching the
    /// round's arrivals or metric sums. Per-client reader threads
    /// reassemble and stamp completions exactly like [`super::TcpIntake`].
    ///
    /// Sessions are polled, not snapshotted: a client whose session fails
    /// mid-upload (or was absent at collect start — e.g. it disconnected
    /// during the broadcast) may **rejoin and retry** until the straggler
    /// window `cfg.straggler_timeout` (clamped by the quorum cutoff and
    /// `max_wait`) closes; only then does it land in `failed` with its
    /// slot dropped. The rejoin window is what lets a mid-broadcast
    /// disconnect resume via the handshake's downlink replay instead of
    /// failing the round.
    pub fn collect_round(
        &self,
        expected: &[(u64, Option<f64>)],
        shape: UpdateShape,
        cfg: &IntakeConfig,
    ) -> IntakeOutcome {
        let start = Instant::now();
        let deadline = start + cfg.max_wait;
        let mut arrivals: Vec<Arrival> = Vec::new();
        let mut failed: Vec<u64> = Vec::new();
        let mut sums = (0.0f64, 0.0f64, 0.0f64);
        let mut bytes = 0u64;
        // Set when the quorum-th upload completes; readers clamp their
        // per-frame deadline to it, so stragglers fail within one read
        // timeout of the cutoff instead of holding the round to max_wait.
        let cutoff: Mutex<Option<Instant>> = Mutex::new(None);
        let params = &*self.shared.params;

        #[derive(Clone, Copy, PartialEq, Eq)]
        enum Slot {
            /// No live reader: waiting for a (re)joined session.
            Pending,
            /// A reader thread owns the client's current session.
            Reading,
            Done,
            Failed,
        }
        let mut slots = vec![Slot::Pending; expected.len()];
        // the session arc each slot last spawned a reader on — a failed
        // slot retries only when a *different* (rejoined) session appears
        let mut tried: Vec<Option<usize>> = vec![None; expected.len()];

        std::thread::scope(|s| {
            let (res_tx, res_rx) = mpsc::channel::<(usize, anyhow::Result<UploadFrames>, u64)>();
            let mut in_flight = 0usize;
            loop {
                // pending slots fail once the rejoin window closes: the
                // straggler timeout, tightened by the quorum cutoff and
                // the round deadline
                let rejoin_until = {
                    let cut = match *cutoff.lock().unwrap() {
                        Some(c) => c.min(deadline),
                        None => deadline,
                    };
                    (start + cfg.straggler_timeout).min(cut)
                };
                for (i, &(client, expect_alpha)) in expected.iter().enumerate() {
                    if slots[i] != Slot::Pending {
                        continue;
                    }
                    let fresh = self
                        .session(client)
                        .filter(|arc| tried[i] != Some(Arc::as_ptr(arc) as usize));
                    let Some(arc) = fresh else {
                        if Instant::now() >= rejoin_until {
                            slots[i] = Slot::Failed;
                            failed.push(client);
                        }
                        continue;
                    };
                    tried[i] = Some(Arc::as_ptr(&arc) as usize);
                    slots[i] = Slot::Reading;
                    in_flight += 1;
                    let res_tx = res_tx.clone();
                    let cutoff = &cutoff;
                    let hub = &*self;
                    let cfg = cfg.clone();
                    s.spawn(move || {
                        let mut guard = arc.lock().unwrap();
                        let sess = &mut *guard;
                        let mut seen: Option<u64> = None;
                        let mut received = 0u64;
                        let eff_deadline = || match *cutoff.lock().unwrap() {
                            Some(c) => c.min(deadline),
                            None => deadline,
                        };
                        let result = sess
                            .stream
                            .set_write_timeout(Some(cfg.io_timeout))
                            .map_err(anyhow::Error::from)
                            .and_then(|_| {
                                read_upload(
                                    &mut sess.reader,
                                    &sess.stream,
                                    &sess.stream,
                                    params,
                                    shape,
                                    cfg.round_id,
                                    cfg.io_timeout,
                                    &eff_deadline,
                                    Some(client),
                                    expect_alpha,
                                    &mut seen,
                                    &mut received,
                                    &mut sess.read_buf,
                                    &mut sess.rx,
                                    &mut sess.tx,
                                )
                            });
                        if let Err(e) = &result {
                            crate::log_debug!(
                                "session",
                                "round {} upload from client {client} failed: {e}",
                                cfg.round_id
                            );
                            drop(guard);
                            // desynchronized socket (partial frames may be
                            // in flight): kill *this* session and free the
                            // slot — identity-checked so a client that
                            // already rejoined is not evicted
                            hub.drop_session_if(client, &arc);
                        }
                        let _ = res_tx.send((i, result, received));
                    });
                }
                if in_flight == 0
                    && slots.iter().all(|s| matches!(s, Slot::Done | Slot::Failed))
                {
                    break;
                }
                match res_rx.recv_timeout(Duration::from_millis(5)) {
                    Ok((i, result, received)) => {
                        in_flight -= 1;
                        bytes += received;
                        match result {
                            Ok(uf) => {
                                slots[i] = Slot::Done;
                                // stamped in arrival order on this (single)
                                // collector thread → stamps are monotone
                                arrivals.push(Arrival {
                                    client: uf.client,
                                    alpha: uf.alpha,
                                    arrival_secs: start.elapsed().as_secs_f64(),
                                    update: Arc::new(uf.update),
                                });
                                sums.0 += uf.train_secs;
                                sums.1 += uf.encrypt_secs;
                                sums.2 += uf.loss as f64;
                                if let Some(q) = cfg.quorum {
                                    if arrivals.len() >= q.max(1) {
                                        let mut cut = cutoff.lock().unwrap();
                                        if cut.is_none() {
                                            *cut =
                                                Some(Instant::now() + cfg.straggler_timeout);
                                        }
                                    }
                                }
                            }
                            Err(_) => {
                                // the reader evicted its dead session; back
                                // to Pending — a rejoined session (a
                                // different arc) restarts it while the
                                // rejoin window is open
                                slots[i] = Slot::Pending;
                            }
                        }
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
            }
        });

        arrivals.sort_by(|a, b| {
            a.arrival_secs
                .total_cmp(&b.arrival_secs)
                .then(a.client.cmp(&b.client))
        });
        IntakeOutcome {
            arrivals,
            failed,
            bytes_received: bytes,
            elapsed_secs: start.elapsed().as_secs_f64(),
            train_secs: sums.0,
            encrypt_secs: sums.1,
            loss_sum: sums.2,
        }
    }

    /// Stop accepting, close every session, and join the accept thread.
    pub fn shutdown(&mut self) {
        self.shared.stop.store(true, Ordering::Relaxed);
        self.shared.accept_wake.wake();
        let sessions: Vec<SharedSession> = {
            let mut map = self.shared.sessions.lock().unwrap();
            map.drain().map(|(_, s)| s).collect()
        };
        for s in sessions {
            if let Ok(sess) = s.lock() {
                sess.stream.shutdown(std::net::Shutdown::Both).ok();
            }
        }
        if let Some(h) = self.accept.take() {
            h.join().ok();
        }
    }
}

impl Drop for SessionHub {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Bound on concurrent half-open handshakes; connections beyond it are shed
/// (a legitimate client's connect-retry loop will come back).
const MAX_HANDSHAKES: usize = 32;

/// Accept loop: serve HELLO handshakes for the whole task. A HELLO with a
/// known client id *replaces* that client's session (rejoin); an unknown id
/// registers a new slot, subject to the registry bound. Each handshake runs
/// on its own (bounded, detached) thread so a connected-but-silent peer
/// cannot stall other joins or mid-task rejoins behind its read timeout.
fn accept_loop(shared: Arc<HubShared>) {
    // Readiness parking instead of the old 2 ms sleep-poll: the nonblocking
    // listener and the shutdown eventfd share one epoll set, so the thread
    // wakes on the next connection (or shutdown), not on a timer. The wait
    // stays bounded as a belt-and-braces backstop.
    let poller = super::reactor::Poller::new().ok();
    if let Some(p) = &poller {
        p.add(shared.listener.as_raw_fd(), 0, true, false).ok();
        p.add(shared.accept_wake.as_raw_fd(), 1, true, false).ok();
    }
    let mut events = Vec::new();
    while !shared.stop.load(Ordering::Relaxed) {
        match shared.listener.accept() {
            Ok((stream, _peer)) => {
                if shared.handshakes.load(Ordering::Relaxed) >= MAX_HANDSHAKES {
                    drop(stream); // probe burst: shed load, clients retry
                    continue;
                }
                shared.handshakes.fetch_add(1, Ordering::Relaxed);
                let sh = shared.clone();
                std::thread::spawn(move || {
                    if let Err(e) = handshake(&sh, stream) {
                        crate::log_debug!("session", "handshake failed: {e}");
                    }
                    sh.handshakes.fetch_sub(1, Ordering::Relaxed);
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => match &poller {
                Some(p) => {
                    p.wait(&mut events, Some(Duration::from_millis(500))).ok();
                    if events.iter().any(|ev| ev.token == 1) {
                        crate::obs::metrics::hub_wakeup();
                        shared.accept_wake.drain();
                    }
                }
                None => std::thread::sleep(Duration::from_millis(2)),
            },
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::ConnectionAborted
                        | std::io::ErrorKind::ConnectionReset
                        | std::io::ErrorKind::Interrupted
                ) => {}
            Err(_) => {
                // unrecoverable listener error: stop accepting; live
                // sessions keep serving and the coordinator's wait/collect
                // deadlines bound the damage
                break;
            }
        }
    }
}

fn handshake(shared: &HubShared, stream: TcpStream) -> anyhow::Result<()> {
    stream.set_nonblocking(false)?;
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(shared.io_timeout))?;
    stream.set_write_timeout(Some(shared.io_timeout))?;
    // The session's persistent BufReader must read the HELLO: a throwaway
    // reader could buffer (and then discard) bytes the client pipelines
    // right behind its handshake.
    let mut sess = PeerSession {
        client: UNIDENTIFIED_CLIENT,
        reader: BufReader::new(stream.try_clone()?),
        stream,
        read_buf: Vec::new(),
        tx: None,
        rx: None,
    };
    let (kind, _) = read_frame_into(
        &mut sess.reader,
        CONTROL_ROUND,
        WELCOME_PAYLOAD_BYTES.max(super::frame::HELLO_PAYLOAD_BYTES),
        &mut sess.read_buf,
    )?;
    if kind == FrameKind::Stats {
        // live metrics query (the `stats` CLI subcommand): answer with the
        // snapshot and close — no session slot is claimed, so probes can
        // never evict or exhaust client registrations (and no key is
        // required: the snapshot is diagnostic, not task state)
        let snap = crate::obs::metrics::snapshot().to_string();
        let mut w = &sess.stream;
        write_frame(&mut w, CONTROL_ROUND, FrameKind::StatsReply, 0, snap.as_bytes())?;
        return Ok(());
    }
    anyhow::ensure!(kind == FrameKind::Hello, "expected HELLO, got {kind:?}");
    let (client, announced) = decode_hello(&sess.read_buf)?;
    anyhow::ensure!(client != UNIDENTIFIED_CLIENT, "client id {client} is reserved");
    // the ciphertext wire format is a task-level setting, not negotiable
    // per client: a mismatched announcement fails the handshake before any
    // slot is touched, and the round completes from the clients that match
    anyhow::ensure!(
        announced == shared.ct_wire,
        "client {client} announced ciphertext wire mode {}, task runs {}",
        announced.as_str(),
        shared.ct_wire.as_str()
    );
    sess.client = client;
    // --wire-auth mac: challenge/response *before* the slot is touched. The
    // nonce is fresh OS entropy, so a recorded handshake never verifies
    // against a new challenge; a forged HELLO dies here with
    // `auth_rejects` bumped and no session state disturbed — identity
    // claims alone can no longer steal a registered slot.
    if let Some(root) = &shared.auth_root {
        let mut nonce = [0u8; 16];
        ChaChaRng::from_os_entropy()
            .map_err(|e| anyhow::anyhow!("cannot draw a challenge nonce: {e}"))?
            .fill_bytes(&mut nonce);
        {
            let mut w = &sess.stream;
            write_frame(&mut w, CONTROL_ROUND, FrameKind::Challenge, 0, &encode_challenge(&nonce))?;
        }
        let (kind, _) = read_frame_into(
            &mut sess.reader,
            CONTROL_ROUND,
            CHALLENGE_RESP_PAYLOAD_BYTES,
            &mut sess.read_buf,
        )?;
        anyhow::ensure!(
            kind == FrameKind::ChallengeResp,
            "expected CHALLENGE_RESP, got {kind:?} (client not in --wire-auth mac?)"
        );
        let (echoed, tag) = decode_challenge_resp(&sess.read_buf)?;
        let skey = mac::derive_session_key(&mac::derive_client_key(root, client), &nonce);
        if echoed != client || tag != mac::handshake_tag(&skey, &nonce, client) {
            crate::obs::metrics::auth_reject();
            anyhow::bail!("client {client} failed the handshake challenge");
        }
        sess.rx = Some(RxAuth::new(MacKey(skey.0), AUTH_DIR_UP));
        sess.tx = Some(TxAuth::new(skey, AUTH_DIR_DOWN));
    }
    // Snapshot the replay state up front (Arc-shared payloads, no copy) so
    // the downlink lock is never held while writing to a socket.
    let (replay_mask, replay_round) = shared.downlink.lock().unwrap().replay_for(client);
    // Publish-then-welcome, with the session mutex held across both: the
    // registry entry must exist before the client sees WELCOME (so its
    // immediate upload lands in the slot), but a coordinator broadcast
    // that spots the fresh entry must not write MASK/DOWN_BEGIN before —
    // or interleaved with — the WELCOME frame. Holding the mutex while
    // writing WELCOME makes any concurrent `push_to` queue behind it.
    let arc = Arc::new(Mutex::new(sess));
    let mut guard = arc.lock().unwrap();
    let replaced = {
        let mut map = shared.sessions.lock().unwrap();
        anyhow::ensure!(
            map.contains_key(&client) || map.len() < shared.max_sessions,
            "session registry full ({} slots)",
            shared.max_sessions
        );
        map.insert(client, arc.clone())
    };
    shared.joined.notify_all();
    // rejoin: the replaced (dead) session's socket is shut down, outside
    // the map lock so a reader still draining it cannot stall accepts
    if let Some(old) = replaced {
        crate::obs::metrics::rejoin();
        if let Ok(old) = old.try_lock() {
            old.stream.shutdown(std::net::Shutdown::Both).ok();
        }
    }
    let next = shared.next_round.load(Ordering::Relaxed);
    {
        let sess = &mut *guard;
        let mut w = BufWriter::new(&sess.stream);
        write_frame_with(
            &mut w,
            CONTROL_ROUND,
            FrameKind::Welcome,
            0,
            &encode_welcome(next, shared.ct_wire),
            &mut sess.tx,
        )?;
        // Mid-round rejoin replay: still under the session guard (so a
        // concurrent coordinator push queues behind it), re-send the
        // current stage's downlink — the agreed mask and the in-flight
        // round's preamble/aggregate. A fresh pre-broadcast join sees an
        // empty cache and gets only the WELCOME; the client side discards
        // downlinks it has already processed.
        write_replay(&mut w, &replay_mask, &replay_round, &mut sess.tx)?;
        w.flush()?;
    }
    drop(guard);
    Ok(())
}

/// Upper bound on a STATS_REPLY payload (a metrics snapshot is a few KiB of
/// JSON; 1 MiB caps what a malicious "server" can make the querier
/// allocate).
pub const STATS_REPLY_MAX_BYTES: usize = 1 << 20;

/// Query a live coordinator's metrics snapshot over the session protocol:
/// dial `addr`, send a STATS frame in place of a HELLO, parse the JSON
/// STATS_REPLY. The server answers and closes without registering a
/// session, so this is safe against a coordinator mid-round.
pub fn query_stats(addr: &str, timeout: Duration) -> anyhow::Result<crate::util::json::Json> {
    let stream = TcpStream::connect(addr)
        .map_err(|e| anyhow::anyhow!("cannot connect stats query to {addr}: {e}"))?;
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    let mut w = &stream;
    write_frame(&mut w, CONTROL_ROUND, FrameKind::Stats, 0, &[])?;
    let mut reader = BufReader::new(stream);
    let mut buf = Vec::new();
    let (kind, _) = read_frame_into(&mut reader, CONTROL_ROUND, STATS_REPLY_MAX_BYTES, &mut buf)?;
    anyhow::ensure!(kind == FrameKind::StatsReply, "expected STATS_REPLY, got {kind:?}");
    crate::util::json::Json::parse(
        std::str::from_utf8(&buf).map_err(|e| anyhow::anyhow!("non-UTF-8 stats reply: {e}"))?,
    )
}

/// Write one round's downlink frames to a session (preamble, the
/// pre-encoded shared aggregate payloads when carried, DOWN_END); returns
/// the bytes written.
fn push_round(
    sess: &mut PeerSession,
    round: u64,
    down: &DownBegin,
    payloads: Option<(&[Vec<u8>], &[Vec<u8>])>,
) -> std::io::Result<u64> {
    let _span = crate::obs::span_arg("transport", "push_round", round);
    // buffered writer: frame headers/trailers coalesce with their payloads
    // instead of going out as separate NODELAY'd segments
    let mut w = BufWriter::with_capacity(64 * 1024, &sess.stream);
    let sent = write_round_frames(&mut w, round, down, payloads, &mut sess.tx)?;
    w.flush()?;
    Ok(sent)
}

/// The round-downlink frame sequence (preamble, carried payloads, DOWN_END)
/// against an arbitrary writer — shared by the broadcast path, the
/// handshake's mid-round rejoin replay, and the reactor shards' write
/// queues.
pub(crate) fn write_round_frames<W: Write>(
    w: &mut W,
    round: u64,
    down: &DownBegin,
    payloads: Option<(&[Vec<u8>], &[Vec<u8>])>,
    auth: &mut Option<TxAuth>,
) -> std::io::Result<u64> {
    let mut sent =
        write_frame_with(w, round, FrameKind::DownBegin, 0, &encode_down_begin(down), auth)?;
    if let Some((cts, plains)) = payloads {
        for (seq, p) in cts.iter().enumerate() {
            sent += write_frame_with(w, round, FrameKind::CtChunk, seq as u32, p, auth)?;
        }
        for (seq, p) in plains.iter().enumerate() {
            sent += write_frame_with(w, round, FrameKind::Plain, seq as u32, p, auth)?;
        }
    }
    sent += write_frame_with(w, round, FrameKind::DownEnd, 0, &[], auth)?;
    Ok(sent)
}

/// Session-level knobs for the client side.
#[derive(Debug, Clone)]
pub struct SessionOpts {
    /// Per-frame socket timeout once a message has started flowing.
    pub io_timeout: Duration,
    /// How long to wait for the *next* downlink (covers the server's
    /// aggregation + other clients' training between rounds).
    pub round_wait: Duration,
    /// Keep retrying the initial connect for this long (the serve process
    /// may still be binding when a join process starts).
    pub connect_retry: Duration,
    /// Socket write-buffer capacity for uploads.
    pub write_buffer: usize,
    /// This client's MAC key (`--wire-auth mac`): drives the handshake
    /// challenge/response and both directions' frame auth. `None` = legacy
    /// unauthenticated wire.
    pub auth: Option<MacKey>,
    /// Fault-injection schedule interposed on this client's uplink
    /// (tests/adversarial harness only).
    pub chaos: Option<ChaosConfig>,
    /// Dial attempts beyond the first per connect (capped exponential
    /// backoff with jitter); also the session loop's mid-task rejoin
    /// budget. `0` restores fail-fast connects and no rejoins.
    pub connect_retries: u32,
    /// Base backoff delay for connect retries.
    pub retry_base: Duration,
    /// Ciphertext wire format announced in HELLO and used for uplink
    /// CT_CHUNK frames (`--ct-wire`); must match the server's task setting.
    pub ct_wire: CtWire,
}

impl Default for SessionOpts {
    fn default() -> Self {
        SessionOpts {
            io_timeout: Duration::from_secs(10),
            round_wait: Duration::from_secs(300),
            connect_retry: Duration::from_secs(10),
            write_buffer: 256 * 1024,
            auth: None,
            chaos: None,
            connect_retries: 5,
            retry_base: Duration::from_millis(50),
            ct_wire: CtWire::Dense,
        }
    }
}

/// One round's received downlink.
#[derive(Debug, Clone)]
pub struct RoundDownlink {
    pub down: DownBegin,
    /// The previous round's partially-encrypted aggregate (when
    /// `down.has_agg`).
    pub agg: Option<EncryptedUpdate>,
    /// Frame bytes received for this downlink.
    pub bytes: u64,
}

/// The client side of a persistent session (drives `join` processes and the
/// in-process client threads of `--transport tcp`).
pub struct ClientSession {
    sink: FrameSink,
    stream: TcpStream,
    reader: BufReader<TcpStream>,
    read_buf: Vec<u8>,
    params: Arc<CkksParams>,
    opts: SessionOpts,
    /// Inbound (server→client) frame authenticator; `None` = legacy wire.
    rx: Option<RxAuth>,
    pub client: u64,
    pub bytes_down: u64,
}

impl ClientSession {
    /// Dial (with capped exponential backoff inside the connect window),
    /// claim the slot with HELLO — running the challenge/response first
    /// when a MAC key is configured — and wait for WELCOME. Returns the
    /// session and the server's advertised next round.
    pub fn connect(
        addr: &str,
        client: u64,
        params: Arc<CkksParams>,
        opts: SessionOpts,
    ) -> anyhow::Result<(Self, u64)> {
        let deadline = Instant::now() + opts.connect_retry;
        let stream = loop {
            match connect_with_backoff(addr, opts.connect_retries, opts.retry_base, client) {
                Ok(s) => break s,
                Err(e) => {
                    if Instant::now() >= deadline {
                        anyhow::bail!("cannot connect session to {addr}: {e}");
                    }
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        };
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(opts.io_timeout))?;
        // writes use the round-scale bound: an unprompted upload (the
        // client pushes as soon as it is ready) legitimately blocks on a
        // full socket buffer until the server reaches its collect phase —
        // e.g. while other clients are still joining or receiving their
        // downlinks. A dead server closes the socket, which fails the
        // write immediately regardless of the timeout.
        stream.set_write_timeout(Some(opts.round_wait))?;
        let reader = BufReader::new(stream.try_clone()?);
        let sink_stream = stream.try_clone()?;
        let sink = match &opts.chaos {
            Some(cfg) => {
                // the fault injector needs the wire's actual frame length
                // (auth trailer included) to split frames correctly, and
                // must be able to sever the *read* half too so a scripted
                // disconnect kills the whole session, not just the uplink
                let mut ccfg = cfg.clone();
                ccfg.authed = opts.auth.is_some();
                let hook_stream = stream.try_clone()?;
                let w = ChaosWriter::new(sink_stream, ccfg).on_disconnect(Box::new(move || {
                    hook_stream.shutdown(std::net::Shutdown::Both).ok();
                }));
                FrameSink::over_writer(Box::new(w), CONTROL_ROUND, opts.write_buffer)
            }
            None => FrameSink::over(sink_stream, CONTROL_ROUND, opts.write_buffer),
        };
        let mut sess = ClientSession {
            sink,
            stream,
            reader,
            read_buf: Vec::new(),
            params,
            opts,
            rx: None,
            client,
            bytes_down: 0,
        };
        sess.sink.set_ct_wire(sess.opts.ct_wire);
        sess.sink
            .send(FrameKind::Hello, 0, &encode_hello(client, sess.opts.ct_wire))?;
        sess.sink.flush()?;
        if let Some(key) = sess.opts.auth.clone() {
            // server-nonce challenge/response (DESIGN.md §12): both
            // handshake frames ride unauthenticated; the derived session
            // key then arms per-frame auth in both directions, so even
            // WELCOME is tagged.
            let (kind, _) = sess.read_downlink_frame(CONTROL_ROUND, sess.opts.io_timeout)?;
            anyhow::ensure!(
                kind == FrameKind::Challenge,
                "expected CHALLENGE, got {kind:?} (server not in --wire-auth mac?)"
            );
            let nonce = decode_challenge(&sess.read_buf)?;
            let skey = mac::derive_session_key(&key, &nonce);
            let tag = mac::handshake_tag(&skey, &nonce, client);
            sess.sink
                .send(FrameKind::ChallengeResp, 0, &encode_challenge_resp(client, tag))?;
            sess.sink.flush()?;
            sess.sink.set_auth(Some(TxAuth::new(skey.clone(), AUTH_DIR_UP)));
            sess.rx = Some(RxAuth::new(skey, AUTH_DIR_DOWN));
        }
        let (kind, _) = sess.read_downlink_frame(CONTROL_ROUND, sess.opts.io_timeout)?;
        anyhow::ensure!(kind == FrameKind::Welcome, "expected WELCOME, got {kind:?}");
        let (next, server_wire) = decode_welcome(&sess.read_buf)?;
        anyhow::ensure!(
            server_wire == sess.opts.ct_wire,
            "server runs ciphertext wire mode {}, this client is configured for {}",
            server_wire.as_str(),
            sess.opts.ct_wire.as_str()
        );
        Ok((sess, next))
    }

    /// Total frame bytes this session has put on the wire.
    pub fn bytes_up(&self) -> u64 {
        self.sink.total_bytes()
    }

    fn read_downlink_frame(
        &mut self,
        round: u64,
        timeout: Duration,
    ) -> anyhow::Result<(FrameKind, u32)> {
        let cap = frame_payload_cap(&self.params);
        self.read_downlink_frame_with_cap(round, timeout, cap)
    }

    /// Read one frame from the downlink regardless of its wire round,
    /// verifying auth (and rejecting replays) when armed. Returns the
    /// frame's `(round, kind, seq)`.
    fn read_any_frame(
        &mut self,
        timeout: Duration,
        cap: usize,
    ) -> anyhow::Result<(u64, FrameKind, u32)> {
        self.stream.set_read_timeout(Some(timeout))?;
        let (round, kind, seq) =
            read_frame_any_round_into_with(&mut self.reader, cap, &mut self.read_buf, &mut self.rx)?;
        let auth_extra = if self.rx.is_some() { AUTH_TRAILER_BYTES } else { 0 };
        self.bytes_down +=
            (FRAME_HEADER_BYTES + self.read_buf.len() + FRAME_TRAILER_BYTES + auth_extra) as u64;
        Ok((round, kind, seq))
    }

    fn read_downlink_frame_with_cap(
        &mut self,
        round: u64,
        timeout: Duration,
        cap: usize,
    ) -> anyhow::Result<(FrameKind, u32)> {
        let (got, kind, seq) = self.read_any_frame(timeout, cap)?;
        if got != round {
            crate::obs::metrics::frame_reject();
            anyhow::bail!("frame for round {got} while expecting round {round}");
        }
        Ok((kind, seq))
    }

    /// Receive the mask broadcast ([`MASK_ROUND`]) for a `total`-parameter
    /// model (sizes the one frame whose payload scales with the mask's run
    /// count rather than with the crypto context).
    pub fn recv_mask(&mut self, total: usize) -> anyhow::Result<EncryptionMask> {
        let cap = frame_payload_cap(&self.params).max(mask_payload_cap(total));
        let (kind, _) =
            self.read_downlink_frame_with_cap(MASK_ROUND, self.opts.round_wait, cap)?;
        anyhow::ensure!(kind == FrameKind::Mask, "expected MASK, got {kind:?}");
        EncryptionMask::from_bytes(&self.read_buf)
    }

    /// Receive round `round`'s downlink: DOWN_BEGIN, the optional carried
    /// aggregate (validated against `expect_shape` when given), DOWN_END.
    pub fn recv_round(
        &mut self,
        round: u64,
        expect_shape: Option<UpdateShape>,
    ) -> anyhow::Result<RoundDownlink> {
        let _span = crate::obs::span_arg("transport", "recv_round", round);
        let bytes0 = self.bytes_down;
        let (kind, _) = self.read_downlink_frame(round, self.opts.round_wait)?;
        anyhow::ensure!(kind == FrameKind::DownBegin, "expected DOWN_BEGIN, got {kind:?}");
        let down = decode_down_begin(&self.read_buf)?;
        self.finish_round_downlink(round, down, expect_shape, bytes0)
    }

    /// Like [`Self::recv_round`] but accepts whatever wire round the server
    /// is currently serving — the rejoin path, where a reconnected client is
    /// replayed the in-flight round's downlink and may first re-receive the
    /// MASK broadcast (discarded here: the client already holds the agreed
    /// mask). Returns the wire round alongside the downlink so the caller
    /// can fast-forward its own round counter.
    pub fn recv_round_any(
        &mut self,
        expect_shape: Option<UpdateShape>,
        mask_total: usize,
    ) -> anyhow::Result<(u64, RoundDownlink)> {
        let _span = crate::obs::span("transport", "recv_round_any");
        let cap = frame_payload_cap(&self.params).max(mask_payload_cap(mask_total));
        loop {
            let bytes0 = self.bytes_down;
            let (round, kind, _) = self.read_any_frame(self.opts.round_wait, cap)?;
            match kind {
                FrameKind::Mask => continue,
                FrameKind::DownBegin => {
                    let down = decode_down_begin(&self.read_buf)?;
                    let out = self.finish_round_downlink(round, down, expect_shape, bytes0)?;
                    return Ok((round, out));
                }
                other => anyhow::bail!("expected DOWN_BEGIN, got {other:?}"),
            }
        }
    }

    /// Shared tail of a round downlink once DOWN_BEGIN is decoded: shape
    /// validation, chunk reassembly, DOWN_END.
    fn finish_round_downlink(
        &mut self,
        round: u64,
        down: DownBegin,
        expect_shape: Option<UpdateShape>,
        bytes0: u64,
    ) -> anyhow::Result<RoundDownlink> {
        if let (true, Some(shape)) = (down.has_agg, expect_shape) {
            anyhow::ensure!(
                down.n_cts == shape.n_cts
                    && down.n_plain == shape.n_plain
                    && down.total == shape.total,
                "downlink shape ({}, {}, {}) does not match the round shape \
                 ({}, {}, {})",
                down.n_cts,
                down.n_plain,
                down.total,
                shape.n_cts,
                shape.n_plain,
                shape.total
            );
        }
        let mut agg = None;
        if down.has_agg {
            // when no shape is pinned, still bound what a declared preamble
            // can make this side allocate up front
            anyhow::ensure!(
                down.n_cts <= 1 << 20 && down.n_plain <= down.total && down.total <= 1 << 31,
                "implausible downlink shape ({}, {}, {})",
                down.n_cts,
                down.n_plain,
                down.total
            );
            let mut asm =
                super::reassembly::ChunkAssembler::new(down.n_cts, down.n_plain, down.total);
            loop {
                let (kind, seq) = self.read_downlink_frame(round, self.opts.io_timeout)?;
                match kind {
                    FrameKind::CtChunk => asm.accept_ct(&self.params, seq, &self.read_buf)?,
                    FrameKind::Plain => asm.accept_plain(seq, &self.read_buf)?,
                    FrameKind::DownEnd => break,
                    other => anyhow::bail!("unexpected {other:?} frame in a downlink"),
                }
            }
            agg = Some(asm.finish()?);
        } else {
            let (kind, _) = self.read_downlink_frame(round, self.opts.io_timeout)?;
            anyhow::ensure!(kind == FrameKind::DownEnd, "expected DOWN_END, got {kind:?}");
        }
        Ok(RoundDownlink {
            down,
            agg,
            bytes: self.bytes_down - bytes0,
        })
    }

    /// Upload one (already-encrypted) update over the session at wire round
    /// `round`, reporting measured local metrics in the END frame, and wait
    /// for the ACK.
    pub fn upload(
        &mut self,
        round: u64,
        alpha: f64,
        update: &EncryptedUpdate,
        metrics: Option<(f64, f64, f32)>,
    ) -> anyhow::Result<UploadReceipt> {
        self.sink.set_round(round);
        self.sink
            .send_begin(self.client, alpha, update.cts.len(), update.plain.len(), update.total)?;
        for (seq, ct) in update.cts.iter().enumerate() {
            self.sink.send_ct(seq, ct)?;
        }
        self.sink.send_plain(&update.plain)?;
        // the ACK arrives once the server has reassembled the upload; give
        // it the round-scale wait, not the per-frame one
        self.stream.set_read_timeout(Some(self.opts.round_wait))?;
        self.sink
            .end_and_ack(&mut self.reader, &mut self.read_buf, metrics, &mut self.rx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::prng::ChaChaRng;
    use crate::he_agg::SelectiveCodec;

    fn ctx() -> crate::ckks::CkksContext {
        crate::ckks::CkksContext::new(256, 3, 30).unwrap()
    }

    #[test]
    fn handshake_welcome_and_rejoin_replaces_slot() {
        let c = ctx();
        let mut hub = SessionHub::bind("127.0.0.1:0", c.params.clone(), 8).unwrap();
        let addr = hub.local_addr().unwrap().to_string();
        let opts = SessionOpts {
            connect_retry: Duration::from_secs(5),
            ..SessionOpts::default()
        };
        let (s1, next) = ClientSession::connect(&addr, 3, c.params.clone(), opts.clone()).unwrap();
        assert_eq!(next, MASK_ROUND);
        hub.wait_for_clients(1, Duration::from_secs(5)).unwrap();
        assert_eq!(hub.connected(), vec![3]);
        // rejoin with the same id replaces the slot, not a second entry
        hub.set_next_round(2);
        drop(s1);
        let (_s2, next) = ClientSession::connect(&addr, 3, c.params.clone(), opts).unwrap();
        assert_eq!(next, 2);
        hub.wait_for_clients(1, Duration::from_secs(5)).unwrap();
        assert_eq!(hub.connected(), vec![3]);
        hub.shutdown();
    }

    #[test]
    fn registry_bound_rejects_overflow_but_allows_rejoin() {
        let c = ctx();
        let mut hub = SessionHub::bind("127.0.0.1:0", c.params.clone(), 2).unwrap();
        let addr = hub.local_addr().unwrap().to_string();
        let opts = SessionOpts {
            connect_retry: Duration::from_secs(5),
            io_timeout: Duration::from_secs(2),
            ..SessionOpts::default()
        };
        let (_a, _) = ClientSession::connect(&addr, 0, c.params.clone(), opts.clone()).unwrap();
        let (_b, _) = ClientSession::connect(&addr, 1, c.params.clone(), opts.clone()).unwrap();
        hub.wait_for_clients(2, Duration::from_secs(5)).unwrap();
        // a third distinct id is refused (no WELCOME, connection dies)...
        assert!(ClientSession::connect(&addr, 2, c.params.clone(), opts.clone()).is_err());
        // ...but a rejoin of a registered id still works
        let (_a2, _) = ClientSession::connect(&addr, 0, c.params.clone(), opts).unwrap();
        hub.wait_for_clients(2, Duration::from_secs(5)).unwrap();
        assert_eq!(hub.connected(), vec![0, 1]);
        hub.shutdown();
    }

    #[test]
    fn mask_and_round_downlink_reach_the_client() {
        let c = ctx();
        let codec = SelectiveCodec::new(c.clone());
        let mut rng = ChaChaRng::from_seed(21, 0);
        let (pk, _sk) = codec.ctx.keygen(&mut rng);
        let total = 600usize;
        let sens: Vec<f32> = (0..total).map(|i| ((i * 13) % 97) as f32).collect();
        let mask = EncryptionMask::top_p(&sens, 0.3);
        let model: Vec<f32> = (0..total).map(|i| (i as f32 * 0.01).sin()).collect();
        let agg = codec.encrypt_update(&model, &mask, &pk, &mut rng);
        let shape = UpdateShape::for_round(&codec.ctx, &mask);

        let mut hub = SessionHub::bind("127.0.0.1:0", c.params.clone(), 4).unwrap();
        let addr = hub.local_addr().unwrap().to_string();
        let mask_bytes = mask.to_bytes();
        let client_thread = {
            let params = c.params.clone();
            let mask_bytes_len = mask_bytes.len();
            std::thread::spawn(move || {
                let (mut sess, _) = ClientSession::connect(
                    &addr,
                    7,
                    params,
                    SessionOpts {
                        connect_retry: Duration::from_secs(5),
                        round_wait: Duration::from_secs(10),
                        ..SessionOpts::default()
                    },
                )
                .unwrap();
                let got_mask = sess.recv_mask(total).unwrap();
                assert_eq!(got_mask.to_bytes().len(), mask_bytes_len);
                // round 0: no aggregate
                let r0 = sess.recv_round(0, Some(shape)).unwrap();
                assert!(r0.down.participate && !r0.down.has_agg && !r0.down.fin);
                assert!(r0.agg.is_none());
                // round 1: aggregate + fin
                let r1 = sess.recv_round(1, Some(shape)).unwrap();
                assert!(r1.down.fin && r1.down.has_agg);
                assert!((r1.down.alpha_mass - 0.75).abs() < 1e-12);
                assert!(r1.bytes > 0);
                (got_mask, r1.agg.unwrap())
            })
        };
        hub.wait_for_clients(1, Duration::from_secs(5)).unwrap();
        let out = hub.broadcast_mask(&[7], &mask_bytes);
        assert!(out.failed.is_empty());
        assert!(out.bytes_sent > mask_bytes.len() as u64);
        let d0 = DownBegin {
            alpha: 1.0,
            alpha_mass: 0.0,
            n_cts: 0,
            n_plain: 0,
            total: 0,
            participate: true,
            has_agg: false,
            fin: false,
        };
        let out = hub.broadcast_round(0, &[(7, d0)], None);
        assert!(out.failed.is_empty());
        let d1 = DownBegin {
            alpha: 0.0,
            alpha_mass: 0.75,
            n_cts: agg.cts.len(),
            n_plain: agg.plain.len(),
            total: agg.total,
            participate: false,
            has_agg: true,
            fin: true,
        };
        let out = hub.broadcast_round(1, &[(7, d1)], Some(&agg));
        assert!(out.failed.is_empty());
        assert!(out.bytes_sent > 0);

        let (got_mask, got_agg) = client_thread.join().unwrap();
        // the downlink aggregate arrives bitwise-identical
        assert_eq!(got_agg.plain, agg.plain);
        assert_eq!(got_agg.total, agg.total);
        for (a, b) in got_agg.cts.iter().zip(agg.cts.iter()) {
            assert_eq!(a.c0, b.c0);
            assert_eq!(a.c1, b.c1);
        }
        assert_eq!(got_mask.encrypted_count(), mask.encrypted_count());
        hub.shutdown();
    }

    #[test]
    fn session_uploads_feed_collect_round() {
        let c = ctx();
        let codec = SelectiveCodec::new(c.clone());
        let mut rng = ChaChaRng::from_seed(33, 0);
        let (pk, _sk) = codec.ctx.keygen(&mut rng);
        let total = 500usize;
        let mask = EncryptionMask::full(total);
        let shape = UpdateShape::for_round(&codec.ctx, &mask);
        let mut hub = SessionHub::bind("127.0.0.1:0", c.params.clone(), 8).unwrap();
        let addr = hub.local_addr().unwrap().to_string();
        let mut threads = Vec::new();
        for id in 0..3u64 {
            let addr = addr.clone();
            let params = c.params.clone();
            let codec = SelectiveCodec::new(c.clone());
            let pk = pk.clone();
            let mask = mask.clone();
            threads.push(std::thread::spawn(move || {
                let (mut sess, _) = ClientSession::connect(
                    &addr,
                    id,
                    params,
                    SessionOpts {
                        connect_retry: Duration::from_secs(5),
                        ..SessionOpts::default()
                    },
                )
                .unwrap();
                let model: Vec<f32> =
                    (0..total).map(|i| ((i as u64 + id * 31) as f32 * 0.003).cos()).collect();
                let mut rng = ChaChaRng::from_seed(100 + id, 0);
                let upd = codec.encrypt_update(&model, &mask, &pk, &mut rng);
                let receipt = sess
                    .upload(4, 1.0 / 3.0, &upd, Some((0.5, 0.25, 2.0)))
                    .unwrap();
                assert!(receipt.acked);
                assert_eq!(receipt.ct_frames, upd.cts.len());
            }));
        }
        hub.wait_for_clients(3, Duration::from_secs(5)).unwrap();
        let outcome = hub.collect_round(
            &[(0, Some(1.0 / 3.0)), (1, Some(1.0 / 3.0)), (2, None)],
            shape,
            &IntakeConfig {
                round_id: 4,
                expected_uploads: 3,
                quorum: None,
                straggler_timeout: Duration::from_secs(5),
                max_wait: Duration::from_secs(20),
                io_timeout: Duration::from_secs(5),
            },
        );
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(outcome.arrivals.len(), 3);
        assert!(outcome.failed.is_empty());
        assert!(outcome.bytes_received > 0);
        // client-reported metrics are summed
        assert!((outcome.train_secs - 1.5).abs() < 1e-9);
        assert!((outcome.encrypt_secs - 0.75).abs() < 1e-9);
        assert!((outcome.loss_sum - 6.0).abs() < 1e-9);
        // the sessions survive the round (persistence across rounds)
        assert_eq!(hub.connected().len(), 3);
        hub.shutdown();
    }

    #[test]
    fn mac_handshake_and_authed_downlink() {
        let c = ctx();
        let root = [0x5Au8; 32];
        let mut hub =
            SessionHub::bind_with_auth("127.0.0.1:0", c.params.clone(), 8, Some(root)).unwrap();
        let addr = hub.local_addr().unwrap().to_string();
        let opts = SessionOpts {
            connect_retry: Duration::from_secs(5),
            auth: Some(crate::crypto::mac::derive_client_key(&root, 9)),
            ..SessionOpts::default()
        };
        let client_thread = {
            let params = c.params.clone();
            std::thread::spawn(move || {
                let (mut sess, next) =
                    ClientSession::connect(&addr, 9, params, opts).unwrap();
                assert_eq!(next, MASK_ROUND);
                // the mask downlink arrives through the authed path
                let mask = sess.recv_mask(64).unwrap();
                assert_eq!(mask.total(), 64);
            })
        };
        hub.wait_for_clients(1, Duration::from_secs(5)).unwrap();
        let mask_bytes = EncryptionMask::full(64).to_bytes();
        let out = hub.broadcast_mask(&[9], &mask_bytes);
        assert!(out.failed.is_empty());
        client_thread.join().unwrap();
        hub.shutdown();
    }

    #[test]
    fn mac_wrong_key_is_rejected_before_the_slot() {
        let c = ctx();
        let root = [0x11u8; 32];
        let mut hub =
            SessionHub::bind_with_auth("127.0.0.1:0", c.params.clone(), 8, Some(root)).unwrap();
        let addr = hub.local_addr().unwrap().to_string();
        let before = crate::obs::metrics::snapshot_auth_rejects();
        let opts = SessionOpts {
            connect_retry: Duration::from_secs(2),
            io_timeout: Duration::from_secs(2),
            // a forged identity: the key of client 3, claiming client 4
            auth: Some(crate::crypto::mac::derive_client_key(&root, 3)),
            connect_retries: 0,
            ..SessionOpts::default()
        };
        assert!(ClientSession::connect(&addr, 4, c.params.clone(), opts).is_err());
        assert!(crate::obs::metrics::snapshot_auth_rejects() > before);
        // the failed challenge never claimed a session slot
        assert!(hub.connected().is_empty());
        hub.shutdown();
    }

    #[test]
    fn wire_auth_mode_mismatch_fails_loudly() {
        let c = ctx();
        // mac hub, legacy client: the CHALLENGE arrives where WELCOME was
        // expected
        let mut hub =
            SessionHub::bind_with_auth("127.0.0.1:0", c.params.clone(), 8, Some([7u8; 32]))
                .unwrap();
        let addr = hub.local_addr().unwrap().to_string();
        let opts = SessionOpts {
            connect_retry: Duration::from_secs(2),
            io_timeout: Duration::from_secs(2),
            connect_retries: 0,
            ..SessionOpts::default()
        };
        let err = ClientSession::connect(&addr, 1, c.params.clone(), opts.clone())
            .err()
            .expect("legacy client must not pass a mac handshake");
        assert!(err.to_string().contains("WELCOME"), "unexpected error: {err}");
        hub.shutdown();

        // legacy hub, mac client: no CHALLENGE ever arrives
        let mut hub = SessionHub::bind("127.0.0.1:0", c.params.clone(), 8).unwrap();
        let addr = hub.local_addr().unwrap().to_string();
        let opts = SessionOpts {
            auth: Some(crate::crypto::mac::derive_client_key(&[7u8; 32], 1)),
            ..opts
        };
        let err = ClientSession::connect(&addr, 1, c.params.clone(), opts)
            .err()
            .expect("mac client must not pass a legacy handshake");
        assert!(err.to_string().contains("CHALLENGE"), "unexpected error: {err}");
        hub.shutdown();
    }

    #[test]
    fn ct_wire_mode_mismatch_fails_loudly() {
        let c = ctx();
        // dense hub, seed client: the handshake is refused before any slot
        // is claimed
        let mut hub = SessionHub::bind("127.0.0.1:0", c.params.clone(), 8).unwrap();
        let addr = hub.local_addr().unwrap().to_string();
        let opts = SessionOpts {
            connect_retry: Duration::from_secs(2),
            io_timeout: Duration::from_secs(2),
            connect_retries: 0,
            ct_wire: CtWire::Seed,
            ..SessionOpts::default()
        };
        assert!(ClientSession::connect(&addr, 1, c.params.clone(), opts.clone()).is_err());
        assert!(hub.connected().is_empty());
        // the mismatch killed one connection, not the task: a matching
        // client still joins
        let (_ok, _) = ClientSession::connect(
            &addr,
            2,
            c.params.clone(),
            SessionOpts {
                ct_wire: CtWire::Dense,
                ..opts.clone()
            },
        )
        .unwrap();
        hub.wait_for_clients(1, Duration::from_secs(5)).unwrap();
        assert_eq!(hub.connected(), vec![2]);
        hub.shutdown();

        // seed hub, dense client: same refusal in the other direction
        let mut hub =
            SessionHub::bind_full("127.0.0.1:0", c.params.clone(), 8, None, CtWire::Seed)
                .unwrap();
        let addr = hub.local_addr().unwrap().to_string();
        let dense = SessionOpts {
            ct_wire: CtWire::Dense,
            ..opts
        };
        assert!(ClientSession::connect(&addr, 1, c.params.clone(), dense).is_err());
        assert!(hub.connected().is_empty());
        hub.shutdown();
    }

    #[test]
    fn seed_wire_uploads_arrive_lazy_and_expand_bitwise() {
        let c = ctx();
        let codec = SelectiveCodec::new(c.clone());
        let mut rng = ChaChaRng::from_seed(57, 0);
        let (_pk, sk) = codec.ctx.keygen(&mut rng);
        let total = 500usize;
        let mask = EncryptionMask::full(total);
        let shape = UpdateShape::for_round_wire(&codec.ctx, &mask, CtWire::Seed);
        let mut hub =
            SessionHub::bind_full("127.0.0.1:0", c.params.clone(), 8, None, CtWire::Seed)
                .unwrap();
        let addr = hub.local_addr().unwrap().to_string();
        let model: Vec<f32> = (0..total).map(|i| (i as f32 * 0.003).cos()).collect();
        let mut enc_rng = ChaChaRng::from_seed(300, 0);
        let upd = codec.encrypt_update_keyed(
            &model,
            &mask,
            crate::ckks::EncKey::SymSeeded(&sk),
            &mut enc_rng,
        );
        let sent = upd.clone();
        let client_thread = {
            let params = c.params.clone();
            std::thread::spawn(move || {
                let (mut sess, _) = ClientSession::connect(
                    &addr,
                    0,
                    params,
                    SessionOpts {
                        connect_retry: Duration::from_secs(5),
                        ct_wire: CtWire::Seed,
                        ..SessionOpts::default()
                    },
                )
                .unwrap();
                let receipt = sess.upload(4, 1.0, &upd, None).unwrap();
                assert!(receipt.acked);
                receipt.bytes_sent
            })
        };
        hub.wait_for_clients(1, Duration::from_secs(5)).unwrap();
        let outcome = hub.collect_round(
            &[(0, Some(1.0))],
            shape,
            &IntakeConfig {
                round_id: 4,
                expected_uploads: 1,
                quorum: None,
                straggler_timeout: Duration::from_secs(5),
                max_wait: Duration::from_secs(20),
                io_timeout: Duration::from_secs(5),
            },
        );
        let bytes_sent = client_thread.join().unwrap();
        assert_eq!(outcome.arrivals.len(), 1);
        assert!(outcome.failed.is_empty());
        // the compressed upload is roughly half a dense one: seed + c0 vs
        // c0 + c1 (64-byte header/seed overhead per ciphertext)
        let dense_ct_bytes =
            crate::ckks::serialize::shard_wire_bytes(&c.params, 0, c.params.num_limbs())
                * sent.cts.len();
        assert!(
            (bytes_sent as usize) < dense_ct_bytes * 6 / 10,
            "seed-wire upload {bytes_sent} bytes vs dense ct body {dense_ct_bytes}"
        );
        // server-side cts arrive lazy and expand bitwise to what was sent
        let got = &outcome.arrivals[0].update;
        for (g, s) in got.cts.iter().zip(sent.cts.iter()) {
            assert!(g.a_seed.is_some(), "seed wire must deliver lazy cts");
            let mut g = g.clone();
            g.expand_a(&c.params);
            assert_eq!(g.c0, s.c0);
            assert_eq!(g.c1, s.c1);
        }
        hub.shutdown();
    }
}
