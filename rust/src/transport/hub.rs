//! Event-driven session hub: the sharded epoll reactor backend of
//! DESIGN.md §13.
//!
//! [`super::session::SessionHub`] pins one OS thread (plus a stack and a
//! blocking frame buffer) per connected client — robust, but a 5,000-client
//! round costs 5,000 parked threads. [`ReactorHub`] serves the identical
//! wire protocol from a fixed pool: one accept thread plus N intake shards,
//! each owning an epoll set, a scratch read buffer, and the nonblocking
//! [`super::machine::SessionMachine`] state machines of the sessions it
//! adopted. Protocol logic (handshake, `--wire-auth mac`
//! challenge/response, chunk reassembly, auth/replay verification) lives
//! entirely in the machines; the shards only move bytes at readiness
//! boundaries, so partial reads and partial writes — including chaos-split
//! frames — fall out of the same code path as clean ones.
//!
//! Cross-thread coordination is deliberately boring: each shard has a
//! command queue (`Mutex<VecDeque>` + eventfd wakeup), round collection
//! hands completed uploads to the coordinator thread over a condvar-parked
//! event queue, and downlink broadcasts fan out as per-shard write jobs
//! with a completion latch. The registry (client → shard seat) and the
//! downlink replay cache sit behind one `tables` mutex shared with the
//! facade.
//!
//! Backend selection is the coordinator's `--transport-backend
//! {threads,hub}` (default `threads`); both backends produce bitwise-
//! identical final models because aggregation is exact modular arithmetic
//! over the same accepted-participant set — only the scheduling of socket
//! I/O differs. [`TransportHub`] is the enum facade the coordinator drives
//! so round phases stay backend-agnostic.

use super::frame::{
    encode_challenge, encode_welcome, frame_payload_cap, write_frame, write_frame_with, DownBegin,
    FrameKind, TxAuth, CONTROL_ROUND, MASK_ROUND,
};
use super::intake::{IntakeConfig, IntakeOutcome, RoundLedger, UpdateShape, UploadFrames};
use super::machine::{RoundCtx, SessionMachine, Step};
use super::reactor::{Event, Poller, Wakeup};
use super::session::{
    encode_agg_payloads, write_replay, write_round_frames, DownlinkCache, DownlinkOutcome,
    RoundReplay, RoundSnapshot, SessionHub,
};
use crate::ckks::{CkksParams, CtWire};
use crate::crypto::prng::ChaChaRng;
use crate::he_agg::EncryptedUpdate;
use std::collections::{HashMap, HashSet, VecDeque};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::fd::AsRawFd;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Poller token of a shard's command wakeup fd (connection tokens are slot
/// indexes, which can never reach this).
const WAKE_TOKEN: u64 = u64::MAX;

/// How many shard threads to run: `FEDML_HE_HUB_SHARDS` when set (clamped
/// to `1..=MAX_HUB_SHARDS`), else the machine's parallelism clamped to a
/// small default band.
fn shard_count() -> usize {
    if let Ok(v) = std::env::var("FEDML_HE_HUB_SHARDS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.clamp(1, crate::obs::metrics::MAX_HUB_SHARDS);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(2)
        .clamp(2, 8)
}

/// A client's registry seat: which shard owns its connection and the
/// connection's admission generation (a rejoin bumps the generation, so a
/// late kill or broadcast aimed at the dead connection cannot hit the
/// fresh one).
#[derive(Clone, Copy)]
struct Seat {
    shard: usize,
    generation: u64,
}

/// Registry + downlink replay cache, behind one lock shared by the shards
/// (registration, teardown) and the facade (broadcast targeting, waits).
#[derive(Default)]
struct HubTables {
    registry: HashMap<u64, Seat>,
    downlink: DownlinkCache,
}

/// One shard's inbound command lane.
struct ShardLink {
    cmds: Mutex<VecDeque<Cmd>>,
    wake: Wakeup,
}

/// What the facade/accept thread asks a shard to do.
enum Cmd {
    /// Adopt a freshly-accepted connection (nonce pre-drawn so the shard
    /// never blocks on OS entropy).
    Adopt {
        stream: TcpStream,
        nonce: [u8; 16],
        generation: u64,
    },
    /// Enqueue one downlink payload to each listed resident session and
    /// report into `job` as the bytes actually flush.
    Broadcast {
        job: Arc<BroadcastJob>,
        targets: Vec<BroadcastTarget>,
    },
    /// Close the connection currently holding `client` **iff** it is still
    /// the `generation` the sender observed (rejoin replacement, explicit
    /// drops).
    Kill { client: u64, generation: u64 },
    /// Close every connection and exit the shard thread.
    Shutdown,
}

struct BroadcastTarget {
    client: u64,
    generation: u64,
    payload: BroadcastPayload,
}

enum BroadcastPayload {
    /// MASK frame at [`MASK_ROUND`].
    Mask(Arc<Vec<u8>>),
    /// Round downlink preamble + (shared, pre-encoded) aggregate payloads.
    Round {
        round: u64,
        down: DownBegin,
        payloads: Option<(Arc<Vec<Vec<u8>>>, Arc<Vec<Vec<u8>>>)>,
    },
}

/// Completion latch of one broadcast: every target ends as exactly one
/// `complete` (its frames fully flushed to the socket) or one `fail`.
struct BroadcastJob {
    state: Mutex<JobState>,
    done: Condvar,
}

struct JobState {
    pending: usize,
    bytes: u64,
    failed: Vec<u64>,
}

impl BroadcastJob {
    fn new(pending: usize) -> Self {
        BroadcastJob {
            state: Mutex::new(JobState {
                pending,
                bytes: 0,
                failed: Vec::new(),
            }),
            done: Condvar::new(),
        }
    }

    fn complete(&self, bytes: u64) {
        let mut st = self.state.lock().unwrap();
        st.pending -= 1;
        st.bytes += bytes;
        if st.pending == 0 {
            self.done.notify_all();
        }
    }

    fn fail(&self, client: u64) {
        let mut st = self.state.lock().unwrap();
        st.pending -= 1;
        st.failed.push(client);
        if st.pending == 0 {
            self.done.notify_all();
        }
    }

    fn wait(&self) -> (u64, Vec<u64>) {
        let mut st = self.state.lock().unwrap();
        while st.pending > 0 {
            st = self.done.wait(st).unwrap();
        }
        (st.bytes, std::mem::take(&mut st.failed))
    }
}

/// One armed collection round, shared between the facade's collector loop
/// and every shard.
struct RoundSpec {
    round_id: u64,
    shape: UpdateShape,
    /// Expected uploader → server-assigned FedAvg weight.
    expect: HashMap<u64, Option<f64>>,
    /// When the round was armed — an engaged connection's idle clock
    /// starts here, not at its (possibly much earlier) adoption.
    opened: Instant,
    deadline: Instant,
    /// Per-upload inactivity bound for engaged connections.
    io_timeout: Duration,
    /// Mirrored from the ledger once a quorum lands: shards close
    /// stragglers against `min(cutoff, deadline)`.
    cutoff: Mutex<Option<Instant>>,
    /// Clients whose upload already completed this round — their later
    /// frames stay unparsed in kernel/decoder buffers, exactly like the
    /// blocking collector's settled slots.
    done: Mutex<HashSet<u64>>,
    events: Mutex<VecDeque<RoundEvent>>,
    bell: Condvar,
}

impl RoundSpec {
    fn closing(&self) -> Instant {
        let cutoff = *self.cutoff.lock().unwrap();
        cutoff.map_or(self.deadline, |c| c.min(self.deadline))
    }

    fn push_event(&self, ev: RoundEvent) {
        self.events.lock().unwrap().push_back(ev);
        self.bell.notify_all();
    }
}

enum RoundEvent {
    /// A complete, validated upload (already ACKed on its session).
    Upload {
        frames: Box<UploadFrames>,
        wire_bytes: u64,
    },
    /// An engaged session died before completing its upload. Transient —
    /// the client may rejoin and still land; terminal failures are settled
    /// against the ledger only at seal time.
    Failed { client: u64, wire_bytes: u64 },
}

/// Pop the next round event, parking on the bell at most `timeout`.
fn next_event(spec: &RoundSpec, timeout: Duration) -> Option<RoundEvent> {
    let mut q = spec.events.lock().unwrap();
    if let Some(ev) = q.pop_front() {
        return Some(ev);
    }
    let (mut q, _timed_out) = spec.bell.wait_timeout(q, timeout).unwrap();
    q.pop_front()
}

/// State shared by the accept thread, every shard, and the facade.
struct ReactorShared {
    listener: TcpListener,
    params: Arc<CkksParams>,
    auth_root: Option<[u8; 32]>,
    /// Ciphertext wire format this task runs (`--ct-wire`). Task-level:
    /// every session machine gates HELLO announcements against it.
    ct_wire: CtWire,
    /// Handshake/write-stall inactivity bound (engaged uploads use the
    /// armed round's own `io_timeout` instead).
    io_timeout: Duration,
    max_sessions: usize,
    next_round: AtomicU64,
    stop: AtomicBool,
    /// Monotone connection-admission counter (seat generations).
    generations: AtomicU64,
    /// Interrupts the accept thread's epoll park (shutdown).
    accept_wake: Wakeup,
    links: Vec<ShardLink>,
    round: Mutex<Option<Arc<RoundSpec>>>,
    tables: Mutex<HubTables>,
    /// Signaled on every registration — `wait_for_clients` parks here with
    /// the `tables` lock.
    joined: Condvar,
}

fn send_to(shared: &ReactorShared, shard: usize, cmd: Cmd) {
    shared.links[shard].cmds.lock().unwrap().push_back(cmd);
    shared.links[shard].wake.wake();
}

/// A broadcast whose frames have been queued but not yet fully written.
struct FlushMark {
    /// `Conn::out` high-water mark this broadcast's frames end at.
    end: usize,
    /// Frame bytes this broadcast contributed (reported on completion).
    bytes: u64,
    client: u64,
    job: Arc<BroadcastJob>,
}

/// One shard-owned connection.
struct Conn {
    stream: TcpStream,
    /// Slot index == poller token.
    token: u64,
    generation: u64,
    machine: SessionMachine,
    /// Downlink frame authenticator, armed when the handshake proof lands.
    tx: Option<TxAuth>,
    /// Pending outbound bytes (`out[sent..]` still to write).
    out: Vec<u8>,
    sent: usize,
    marks: VecDeque<FlushMark>,
    idle_since: Instant,
    /// A STATS probe: close as soon as the reply drains.
    close_after_flush: bool,
    /// Current epoll interest, to skip redundant `modify` calls.
    want_read: bool,
    want_write: bool,
}

impl Conn {
    fn flush_pending(&self) -> bool {
        self.sent < self.out.len()
    }
}

/// One reactor shard: an epoll set plus the sessions it adopted.
struct Shard {
    idx: usize,
    shared: Arc<ReactorShared>,
    poller: Poller,
    conns: Vec<Option<Conn>>,
    free: Vec<usize>,
    by_client: HashMap<u64, usize>,
    /// Pooled socket read buffer (per shard, not per session).
    scratch: Vec<u8>,
    /// Frame payload cap under the task's params (decoder bound).
    cap: usize,
}

impl Shard {
    fn run(mut self) {
        let mut events: Vec<Event> = Vec::new();
        loop {
            let mut cmds: VecDeque<Cmd> = {
                let mut q = self.shared.links[self.idx].cmds.lock().unwrap();
                q.drain(..).collect()
            };
            while let Some(cmd) = cmds.pop_front() {
                if matches!(cmd, Cmd::Shutdown) {
                    self.close_all("hub shutdown");
                    // fail any broadcasts queued behind the shutdown so
                    // their jobs cannot hang the facade
                    for cmd in cmds {
                        if let Cmd::Broadcast { job, targets } = cmd {
                            for t in targets {
                                job.fail(t.client);
                            }
                        }
                    }
                    return;
                }
                self.handle_cmd(cmd);
            }
            if self
                .poller
                .wait(&mut events, Some(Duration::from_millis(50)))
                .is_err()
            {
                self.close_all("reactor poll failed");
                return;
            }
            for i in 0..events.len() {
                let ev = events[i];
                if ev.token == WAKE_TOKEN {
                    crate::obs::metrics::hub_wakeup();
                    self.shared.links[self.idx].wake.drain();
                } else {
                    self.drive(ev.token as usize, ev.readable || ev.closed, ev.writable);
                }
            }
            self.sweep();
        }
    }

    fn current_spec(&self) -> Option<Arc<RoundSpec>> {
        self.shared.round.lock().unwrap().clone()
    }

    fn handle_cmd(&mut self, cmd: Cmd) {
        match cmd {
            Cmd::Adopt {
                stream,
                nonce,
                generation,
            } => self.adopt(stream, nonce, generation),
            Cmd::Broadcast { job, targets } => self.handle_broadcast(&job, targets),
            Cmd::Kill { client, generation } => {
                let slot = (0..self.conns.len()).find(|&s| {
                    self.conns[s].as_ref().is_some_and(|c| {
                        c.machine.client() == Some(client) && c.generation == generation
                    })
                });
                if let Some(slot) = slot {
                    let conn = self.conns[slot].take().unwrap();
                    self.kill(conn, "replaced by a rejoin");
                }
            }
            Cmd::Shutdown => unreachable!("handled in run()"),
        }
    }

    fn adopt(&mut self, stream: TcpStream, nonce: [u8; 16], generation: u64) {
        let slot = self.free.pop().unwrap_or_else(|| {
            self.conns.push(None);
            self.conns.len() - 1
        });
        let fd = stream.as_raw_fd();
        let machine =
            SessionMachine::new(self.cap, self.shared.auth_root, self.shared.ct_wire, nonce);
        let conn = Conn {
            stream,
            token: slot as u64,
            generation,
            machine,
            tx: None,
            out: Vec::new(),
            sent: 0,
            marks: VecDeque::new(),
            idle_since: Instant::now(),
            close_after_flush: false,
            want_read: true,
            want_write: false,
        };
        if self.poller.add(fd, slot as u64, true, false).is_err() {
            // registration failed: drop the connection (socket closes), keep the slot
            self.free.push(slot);
            return;
        }
        self.conns[slot] = Some(conn);
        crate::obs::metrics::hub_session_opened(self.idx);
    }

    fn handle_broadcast(&mut self, job: &Arc<BroadcastJob>, targets: Vec<BroadcastTarget>) {
        for t in targets {
            let Some(slot) = self.by_client.get(&t.client).copied() else {
                job.fail(t.client);
                continue;
            };
            let Some(mut conn) = self.conns.get_mut(slot).and_then(|c| c.take()) else {
                job.fail(t.client);
                continue;
            };
            if conn.generation != t.generation || conn.machine.client() != Some(t.client) {
                self.conns[slot] = Some(conn);
                job.fail(t.client);
                continue;
            }
            match enqueue_payload(&mut conn, &t.payload) {
                Ok(bytes) => {
                    conn.marks.push_back(FlushMark {
                        end: conn.out.len(),
                        bytes,
                        client: t.client,
                        job: job.clone(),
                    });
                    crate::obs::metrics::hub_write_enqueued(bytes);
                    match self.flush(&mut conn) {
                        Ok(()) => self.conns[slot] = Some(conn),
                        Err(reason) => self.kill(conn, &reason),
                    }
                }
                Err(e) => {
                    job.fail(t.client);
                    self.kill(conn, &format!("downlink enqueue failed: {e}"));
                }
            }
        }
    }

    /// Drive one connection through a readiness edge: take it out of its
    /// slot, run the nonblocking I/O + state machine, and either put it
    /// back or tear it down.
    fn drive(&mut self, slot: usize, readable: bool, writable: bool) {
        let Some(mut conn) = self.conns.get_mut(slot).and_then(|c| c.take()) else {
            return;
        };
        match self.drive_inner(&mut conn, readable, writable) {
            Ok(()) => self.conns[slot] = Some(conn),
            Err(reason) => self.kill(conn, &reason),
        }
    }

    fn drive_inner(&mut self, conn: &mut Conn, readable: bool, writable: bool) -> Result<(), String> {
        let mut eof: Option<String> = None;
        if readable {
            // bounded read burst: fairness across the shard's sessions, and
            // a decoder already holding > 2 frames of bytes stops pulling —
            // the kernel buffer (and ultimately the client's send timeout)
            // carries the backpressure
            for _ in 0..8 {
                if conn.machine.buffered() > self.cap * 2 {
                    break;
                }
                match conn.stream.read(&mut self.scratch) {
                    Ok(0) => {
                        eof = Some("connection closed by peer".into());
                        break;
                    }
                    Ok(n) => {
                        conn.idle_since = Instant::now();
                        conn.machine.push(&self.scratch[..n]);
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(e) => {
                        eof = Some(format!("read failed: {e}"));
                        break;
                    }
                }
            }
        }
        // always advance: buffered bytes may have become actionable even
        // without new socket data (e.g. a round just armed)
        self.advance_machine(conn)?;
        if let Some(reason) = eof {
            return Err(reason);
        }
        if writable || conn.flush_pending() {
            self.flush(conn)?;
        }
        Ok(())
    }

    /// Pump the session state machine until it runs out of actionable
    /// frames, performing each emitted protocol step.
    fn advance_machine(&mut self, conn: &mut Conn) -> Result<(), String> {
        let params = self.shared.params.clone();
        loop {
            if conn.close_after_flush {
                return Ok(());
            }
            let spec = self.current_spec();
            let step = {
                let eligible = match (&spec, conn.machine.client()) {
                    (Some(s), Some(c)) => {
                        s.expect.contains_key(&c) && !s.done.lock().unwrap().contains(&c)
                    }
                    _ => false,
                };
                let ctx = if eligible {
                    let s = spec.as_ref().unwrap();
                    let c = conn.machine.client().unwrap();
                    Some(RoundCtx {
                        round_id: s.round_id,
                        shape: s.shape,
                        expect_alpha: s.expect.get(&c).copied().flatten(),
                        params: &params,
                    })
                } else {
                    None
                };
                match conn.machine.poll(ctx.as_ref()) {
                    Ok(step) => step,
                    Err(e) => return Err(format!("protocol error: {e}")),
                }
            };
            match step {
                None => return Ok(()),
                Some(step) => self.on_step(conn, step, spec.as_deref())?,
            }
        }
    }

    fn on_step(&mut self, conn: &mut Conn, step: Step, spec: Option<&RoundSpec>) -> Result<(), String> {
        match step {
            Step::Stats => {
                let snap = crate::obs::metrics::snapshot().to_string();
                let sent =
                    write_frame(&mut conn.out, CONTROL_ROUND, FrameKind::StatsReply, 0, snap.as_bytes())
                        .map_err(|e| format!("stats reply enqueue failed: {e}"))?;
                crate::obs::metrics::hub_write_enqueued(sent);
                conn.close_after_flush = true;
                Ok(())
            }
            Step::Challenge { nonce } => {
                let sent = write_frame(
                    &mut conn.out,
                    CONTROL_ROUND,
                    FrameKind::Challenge,
                    0,
                    &encode_challenge(&nonce),
                )
                .map_err(|e| format!("challenge enqueue failed: {e}"))?;
                crate::obs::metrics::hub_write_enqueued(sent);
                Ok(())
            }
            Step::Register { client, tx } => self.register(conn, client, tx),
            Step::Upload { frames } => {
                let Some(spec) = spec else {
                    return Err("upload step with no armed round".into());
                };
                // settle the client *before* the collector sees the event,
                // so a pipelined second upload stays unparsed
                spec.done.lock().unwrap().insert(frames.client);
                let wire = conn.machine.take_wire_bytes();
                let sent = write_frame_with(
                    &mut conn.out,
                    spec.round_id,
                    FrameKind::Ack,
                    0,
                    &0u32.to_le_bytes(),
                    &mut conn.tx,
                )
                .map_err(|e| format!("ack enqueue failed: {e}"))?;
                crate::obs::metrics::hub_write_enqueued(sent);
                spec.push_event(RoundEvent::Upload {
                    frames,
                    wire_bytes: wire,
                });
                Ok(())
            }
        }
    }

    /// Claim `client`'s registry seat and enqueue WELCOME plus any
    /// mid-round downlink replay — the nonblocking twin of the blocking
    /// hub's handshake registration, with identical replay semantics.
    fn register(&mut self, conn: &mut Conn, client: u64, tx: Option<TxAuth>) -> Result<(), String> {
        conn.tx = tx;
        let (mask, round, next): (Option<Vec<u8>>, Option<RoundReplay>, u64) = {
            let mut tables = self.shared.tables.lock().unwrap();
            if !tables.registry.contains_key(&client)
                && tables.registry.len() >= self.shared.max_sessions
            {
                return Err(format!(
                    "session registry full ({} slots)",
                    self.shared.max_sessions
                ));
            }
            let prev = tables.registry.insert(
                client,
                Seat {
                    shard: self.idx,
                    generation: conn.generation,
                },
            );
            if let Some(old) = prev {
                crate::obs::metrics::rejoin();
                send_to(&self.shared, old.shard, Cmd::Kill {
                    client,
                    generation: old.generation,
                });
            }
            let (mask, round) = tables.downlink.replay_for(client);
            (mask, round, self.shared.next_round.load(Ordering::Relaxed))
        };
        self.shared.joined.notify_all();
        self.by_client.insert(client, conn.token as usize);
        let mut sent = write_frame_with(
            &mut conn.out,
            CONTROL_ROUND,
            FrameKind::Welcome,
            0,
            &encode_welcome(next, self.shared.ct_wire),
            &mut conn.tx,
        )
        .map_err(|e| format!("welcome enqueue failed: {e}"))?;
        sent += write_replay(&mut conn.out, &mask, &round, &mut conn.tx)
            .map_err(|e| format!("replay enqueue failed: {e}"))?;
        crate::obs::metrics::hub_write_enqueued(sent);
        Ok(())
    }

    /// Nonblocking write of whatever is queued; completes flush marks as
    /// their bytes clear the socket.
    fn flush(&self, conn: &mut Conn) -> Result<(), String> {
        while conn.sent < conn.out.len() {
            match conn.stream.write(&conn.out[conn.sent..]) {
                Ok(0) => return Err("write stalled".into()),
                Ok(n) => {
                    conn.sent += n;
                    conn.idle_since = Instant::now();
                    crate::obs::metrics::hub_write_flushed(n as u64);
                    while conn.marks.front().is_some_and(|m| m.end <= conn.sent) {
                        let mark = conn.marks.pop_front().unwrap();
                        mark.job.complete(mark.bytes);
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(format!("write failed: {e}")),
            }
        }
        if conn.sent == conn.out.len() && !conn.out.is_empty() {
            conn.out.clear();
            conn.sent = 0;
            if conn.close_after_flush {
                // quiet teardown: the stats probe got its reply
                return Err("stats reply delivered".into());
            }
        }
        Ok(())
    }

    /// Deadline enforcement + epoll interest reconciliation, run once per
    /// reactor tick.
    fn sweep(&mut self) {
        let spec = self.current_spec();
        let now = Instant::now();
        let mut stale: Vec<usize> = Vec::new();
        for slot in 0..self.conns.len() {
            let (reason, want_read, want_write, stale_buffer, cur_read, cur_write) = {
                let Some(conn) = self.conns[slot].as_ref() else {
                    continue;
                };
                let client = conn.machine.client();
                let engaged = match (&spec, client) {
                    (Some(s), Some(c)) => {
                        s.expect.contains_key(&c) && !s.done.lock().unwrap().contains(&c)
                    }
                    _ => false,
                };
                let handshaking = client.is_none() && !conn.close_after_flush;
                let write_pending = conn.flush_pending();
                let reason: Option<&'static str> = if engaged {
                    let s = spec.as_ref().unwrap();
                    // an adopted-long-ago connection is idle relative to
                    // the round arming, not its own (ancient) last byte
                    let idle_ref = conn.idle_since.max(s.opened);
                    if now.saturating_duration_since(idle_ref) >= s.io_timeout {
                        Some("upload idle past the io timeout")
                    } else if now >= s.closing() {
                        Some("round closed before the upload completed")
                    } else {
                        None
                    }
                } else if (handshaking || write_pending)
                    && now.saturating_duration_since(conn.idle_since) >= self.shared.io_timeout
                {
                    Some("idle past the io timeout")
                } else if spec.is_none() && conn.machine.mid_upload() {
                    // round torn down with this upload incomplete — the
                    // ledger has already settled it as failed/straggler
                    Some("mid-upload at round teardown")
                } else {
                    None
                };
                let want_read = !conn.close_after_flush && (client.is_none() || engaged);
                (
                    reason,
                    want_read,
                    write_pending,
                    engaged && conn.machine.buffered() > 0,
                    conn.want_read,
                    conn.want_write,
                )
            };
            if let Some(reason) = reason {
                let conn = self.conns[slot].take().unwrap();
                self.kill(conn, reason);
                continue;
            }
            if want_read != cur_read || want_write != cur_write {
                if let Some(conn) = self.conns[slot].as_mut() {
                    if self
                        .poller
                        .modify(conn.stream.as_raw_fd(), slot as u64, want_read, want_write)
                        .is_ok()
                    {
                        conn.want_read = want_read;
                        conn.want_write = want_write;
                    }
                }
            }
            if stale_buffer {
                stale.push(slot);
            }
        }
        // frames buffered before a round armed produce no socket event —
        // pump those machines now that they are eligible
        for slot in stale {
            self.drive(slot, false, false);
        }
    }

    fn kill(&mut self, mut conn: Conn, reason: &str) {
        let slot = conn.token as usize;
        self.poller.delete(conn.stream.as_raw_fd()).ok();
        let abandoned = (conn.out.len() - conn.sent) as u64;
        if abandoned > 0 {
            crate::obs::metrics::hub_write_flushed(abandoned);
        }
        while let Some(mark) = conn.marks.pop_front() {
            mark.job.fail(mark.client);
        }
        if let Some(client) = conn.machine.client() {
            crate::log_debug!("hub", "shard {} closed client {client} session: {reason}", self.idx);
            if self.by_client.get(&client) == Some(&slot) {
                self.by_client.remove(&client);
            }
            {
                let mut tables = self.shared.tables.lock().unwrap();
                if tables.registry.get(&client).map(|s| s.generation) == Some(conn.generation) {
                    tables.registry.remove(&client);
                }
            }
            if let Some(spec) = self.current_spec() {
                if spec.expect.contains_key(&client) && !spec.done.lock().unwrap().contains(&client)
                {
                    spec.push_event(RoundEvent::Failed {
                        client,
                        wire_bytes: conn.machine.take_wire_bytes(),
                    });
                }
            }
        }
        crate::obs::metrics::hub_session_closed(self.idx);
        conn.stream.shutdown(std::net::Shutdown::Both).ok();
        self.free.push(slot);
    }

    fn close_all(&mut self, reason: &str) {
        for slot in 0..self.conns.len() {
            if let Some(conn) = self.conns[slot].take() {
                self.kill(conn, reason);
            }
        }
    }
}

/// Serialize one broadcast payload into the connection's write queue.
fn enqueue_payload(conn: &mut Conn, payload: &BroadcastPayload) -> std::io::Result<u64> {
    match payload {
        BroadcastPayload::Mask(bytes) => {
            write_frame_with(&mut conn.out, MASK_ROUND, FrameKind::Mask, 0, bytes, &mut conn.tx)
        }
        BroadcastPayload::Round {
            round,
            down,
            payloads,
        } => {
            let carried = payloads.as_ref().map(|(c, p)| (c.as_slice(), p.as_slice()));
            write_round_frames(&mut conn.out, *round, down, carried, &mut conn.tx)
        }
    }
}

fn accept_loop(shared: Arc<ReactorShared>) {
    let poller = Poller::new().ok();
    if let Some(p) = &poller {
        p.add(shared.listener.as_raw_fd(), 0, true, false).ok();
        p.add(shared.accept_wake.as_raw_fd(), 1, true, false).ok();
    }
    let mut events: Vec<Event> = Vec::new();
    let mut next_shard = 0usize;
    while !shared.stop.load(Ordering::Relaxed) {
        match shared.listener.accept() {
            Ok((stream, _)) => {
                if stream.set_nonblocking(true).is_err() {
                    continue;
                }
                stream.set_nodelay(true).ok();
                // nonce drawn here so shards never block on OS entropy
                let mut nonce = [0u8; 16];
                if shared.auth_root.is_some() {
                    match ChaChaRng::from_os_entropy() {
                        Ok(mut rng) => rng.fill_bytes(&mut nonce),
                        Err(e) => {
                            crate::log_debug!("hub", "cannot draw a challenge nonce: {e}");
                            continue;
                        }
                    }
                }
                let generation = shared.generations.fetch_add(1, Ordering::Relaxed);
                let shard = next_shard % shared.links.len();
                next_shard = next_shard.wrapping_add(1);
                send_to(&shared, shard, Cmd::Adopt {
                    stream,
                    nonce,
                    generation,
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => match &poller {
                Some(p) => {
                    p.wait(&mut events, Some(Duration::from_millis(500))).ok();
                    if events.iter().any(|ev| ev.token == 1) {
                        crate::obs::metrics::hub_wakeup();
                        shared.accept_wake.drain();
                    }
                }
                None => std::thread::sleep(Duration::from_millis(2)),
            },
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::ConnectionAborted
                        | std::io::ErrorKind::ConnectionReset
                        | std::io::ErrorKind::Interrupted
                ) => {}
            Err(e) => {
                if !shared.stop.load(Ordering::Relaxed) {
                    crate::log_debug!("hub", "accept failed: {e}");
                }
                break;
            }
        }
    }
}

/// The sharded epoll reactor session hub — a drop-in peer of
/// [`SessionHub`] serving the identical wire protocol from a fixed thread
/// pool (select it with `--transport-backend hub`).
pub struct ReactorHub {
    shared: Arc<ReactorShared>,
    accept: Option<std::thread::JoinHandle<()>>,
    shards: Vec<std::thread::JoinHandle<()>>,
}

impl ReactorHub {
    /// Bind the listen socket and start the accept thread + shard pool.
    pub fn bind(addr: &str, params: Arc<CkksParams>, max_sessions: usize) -> anyhow::Result<Self> {
        Self::bind_with_auth(addr, params, max_sessions, None)
    }

    /// [`Self::bind`] with an optional task MAC root (`--wire-auth mac`).
    pub fn bind_with_auth(
        addr: &str,
        params: Arc<CkksParams>,
        max_sessions: usize,
        auth_root: Option<[u8; 32]>,
    ) -> anyhow::Result<Self> {
        Self::bind_full(addr, params, max_sessions, auth_root, CtWire::Dense)
    }

    /// [`Self::bind_with_auth`] with an explicit ciphertext wire mode —
    /// the reactor twin of [`SessionHub::bind_full`].
    pub fn bind_full(
        addr: &str,
        params: Arc<CkksParams>,
        max_sessions: usize,
        auth_root: Option<[u8; 32]>,
        ct_wire: CtWire,
    ) -> anyhow::Result<Self> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow::anyhow!("cannot bind session hub on {addr}: {e}"))?;
        listener.set_nonblocking(true)?;
        let n = shard_count();
        let mut links = Vec::with_capacity(n);
        for _ in 0..n {
            links.push(ShardLink {
                cmds: Mutex::new(VecDeque::new()),
                wake: Wakeup::new()?,
            });
        }
        let shared = Arc::new(ReactorShared {
            listener,
            params,
            auth_root,
            ct_wire,
            io_timeout: Duration::from_secs(10),
            max_sessions: max_sessions.max(1),
            next_round: AtomicU64::new(MASK_ROUND),
            stop: AtomicBool::new(false),
            generations: AtomicU64::new(0),
            accept_wake: Wakeup::new()?,
            links,
            round: Mutex::new(None),
            tables: Mutex::new(HubTables::default()),
            joined: Condvar::new(),
        });
        let cap = frame_payload_cap(&shared.params);
        let mut shards = Vec::with_capacity(n);
        for idx in 0..n {
            let poller = Poller::new()?;
            poller.add(shared.links[idx].wake.as_raw_fd(), WAKE_TOKEN, true, false)?;
            let sh = shared.clone();
            shards.push(
                std::thread::Builder::new()
                    .name(format!("hub-shard-{idx}"))
                    .spawn(move || {
                        Shard {
                            idx,
                            shared: sh,
                            poller,
                            conns: Vec::new(),
                            free: Vec::new(),
                            by_client: HashMap::new(),
                            scratch: vec![0u8; 64 * 1024],
                            cap,
                        }
                        .run()
                    })?,
            );
        }
        let ash = shared.clone();
        let accept = std::thread::Builder::new()
            .name("hub-accept".into())
            .spawn(move || accept_loop(ash))?;
        Ok(ReactorHub {
            shared,
            accept: Some(accept),
            shards,
        })
    }

    /// The bound address (what clients dial).
    pub fn local_addr(&self) -> anyhow::Result<SocketAddr> {
        Ok(self.shared.listener.local_addr()?)
    }

    /// Advertise the next wire round (stamped into WELCOME replies).
    pub fn set_next_round(&self, round: u64) {
        self.shared.next_round.store(round, Ordering::Relaxed);
    }

    /// Client ids with a currently-registered session.
    pub fn connected(&self) -> Vec<u64> {
        let mut ids: Vec<u64> = self
            .shared
            .tables
            .lock()
            .unwrap()
            .registry
            .keys()
            .copied()
            .collect();
        ids.sort_unstable();
        ids
    }

    /// Ask the owning shard to close whatever connection currently holds
    /// `client`'s seat (removal is asynchronous).
    pub fn drop_session(&self, client: u64) {
        let seat = self.shared.tables.lock().unwrap().registry.get(&client).copied();
        if let Some(seat) = seat {
            send_to(&self.shared, seat.shard, Cmd::Kill {
                client,
                generation: seat.generation,
            });
        }
    }

    /// Block until `n` distinct clients hold sessions; errors after `wait`
    /// with the shortfall. Parks on the registration condvar.
    pub fn wait_for_clients(&self, n: usize, wait: Duration) -> anyhow::Result<Vec<u64>> {
        let deadline = Instant::now() + wait;
        let mut tables = self.shared.tables.lock().unwrap();
        loop {
            if tables.registry.len() >= n {
                let mut ids: Vec<u64> = tables.registry.keys().copied().collect();
                ids.sort_unstable();
                return Ok(ids);
            }
            let now = Instant::now();
            anyhow::ensure!(
                now < deadline,
                "only {}/{n} clients joined within {:.0?}",
                tables.registry.len(),
                wait
            );
            let (guard, _timed_out) = self
                .shared
                .joined
                .wait_timeout(tables, deadline - now)
                .unwrap();
            tables = guard;
        }
    }

    fn wake_all(&self) {
        for link in &self.shared.links {
            link.wake.wake();
        }
    }

    fn run_job(&self, per_shard: Vec<Vec<BroadcastTarget>>, total: usize) -> (u64, Vec<u64>) {
        if total == 0 {
            return (0, Vec::new());
        }
        let job = Arc::new(BroadcastJob::new(total));
        for (idx, targets) in per_shard.into_iter().enumerate() {
            if targets.is_empty() {
                continue;
            }
            send_to(&self.shared, idx, Cmd::Broadcast {
                job: job.clone(),
                targets,
            });
        }
        job.wait()
    }

    /// Push the agreed mask to every listed client (MASK frame at
    /// [`MASK_ROUND`]); cached first so a mid-push death can be replayed
    /// at the client's next handshake.
    pub fn broadcast_mask(&self, clients: &[u64], mask_bytes: &[u8]) -> DownlinkOutcome {
        let start = Instant::now();
        let payload = Arc::new(mask_bytes.to_vec());
        let mut per_shard: Vec<Vec<BroadcastTarget>> =
            (0..self.shared.links.len()).map(|_| Vec::new()).collect();
        let mut absent: Vec<u64> = Vec::new();
        let mut total = 0usize;
        {
            let mut tables = self.shared.tables.lock().unwrap();
            tables.downlink.mask = Some(mask_bytes.to_vec());
            for &client in clients {
                match tables.registry.get(&client) {
                    Some(seat) => {
                        per_shard[seat.shard].push(BroadcastTarget {
                            client,
                            generation: seat.generation,
                            payload: BroadcastPayload::Mask(payload.clone()),
                        });
                        total += 1;
                    }
                    None => {
                        crate::log_debug!("hub", "mask downlink to {client} failed: no session");
                        absent.push(client);
                    }
                }
            }
        }
        let (bytes, mut job_failed) = self.run_job(per_shard, total);
        absent.append(&mut job_failed);
        absent.sort_unstable();
        DownlinkOutcome {
            bytes_sent: bytes,
            elapsed_secs: start.elapsed().as_secs_f64(),
            failed: absent,
        }
    }

    /// Push one round's downlink to every planned client — the shared
    /// aggregate's frame payloads are encoded once and Arc-shared across
    /// all shard write queues.
    pub fn broadcast_round(
        &self,
        round: u64,
        plans: &[(u64, DownBegin)],
        agg: Option<&EncryptedUpdate>,
    ) -> DownlinkOutcome {
        let start = Instant::now();
        let (ct_payloads, plain_payloads) = match agg {
            Some(agg) => encode_agg_payloads(agg),
            None => (Vec::new(), Vec::new()),
        };
        let ct_payloads = Arc::new(ct_payloads);
        let plain_payloads = Arc::new(plain_payloads);
        let mut per_shard: Vec<Vec<BroadcastTarget>> =
            (0..self.shared.links.len()).map(|_| Vec::new()).collect();
        let mut absent: Vec<u64> = Vec::new();
        let mut total = 0usize;
        {
            let mut tables = self.shared.tables.lock().unwrap();
            tables.downlink.round = Some(RoundSnapshot {
                round,
                plans: plans.to_vec(),
                has_payloads: agg.is_some(),
                ct_payloads: ct_payloads.clone(),
                plain_payloads: plain_payloads.clone(),
            });
            for &(client, down) in plans {
                match tables.registry.get(&client) {
                    Some(seat) => {
                        let payloads = (down.has_agg && agg.is_some())
                            .then(|| (ct_payloads.clone(), plain_payloads.clone()));
                        per_shard[seat.shard].push(BroadcastTarget {
                            client,
                            generation: seat.generation,
                            payload: BroadcastPayload::Round {
                                round,
                                down,
                                payloads,
                            },
                        });
                        total += 1;
                    }
                    None => {
                        crate::log_debug!(
                            "hub",
                            "round {round} downlink to {client} failed: no session"
                        );
                        absent.push(client);
                    }
                }
            }
        }
        let (bytes, mut job_failed) = self.run_job(per_shard, total);
        absent.append(&mut job_failed);
        absent.sort_unstable();
        DownlinkOutcome {
            bytes_sent: bytes,
            elapsed_secs: start.elapsed().as_secs_f64(),
            failed: absent,
        }
    }

    /// Arm a collection round across the shards and settle it against the
    /// shared [`RoundLedger`] — identical accounting (quorum cutoff,
    /// straggler/rejoin windows, arrival ordering) to the blocking
    /// collector, so both backends report the same rounds.
    pub fn collect_round(
        &self,
        expected: &[(u64, Option<f64>)],
        shape: UpdateShape,
        cfg: &IntakeConfig,
    ) -> IntakeOutcome {
        let mut ledger = RoundLedger::open(cfg);
        let spec = Arc::new(RoundSpec {
            round_id: cfg.round_id,
            shape,
            expect: expected.iter().copied().collect(),
            opened: ledger.start(),
            deadline: ledger.deadline(),
            io_timeout: cfg.io_timeout,
            cutoff: Mutex::new(None),
            done: Mutex::new(HashSet::new()),
            events: Mutex::new(VecDeque::new()),
            bell: Condvar::new(),
        });
        *self.shared.round.lock().unwrap() = Some(spec.clone());
        self.wake_all();
        loop {
            if ledger.completed_count() >= expected.len() {
                break;
            }
            let now = Instant::now();
            let closing = ledger.closing_time();
            if now >= closing {
                break;
            }
            let rejoin_until = (ledger.start() + cfg.straggler_timeout).min(closing);
            if now >= rejoin_until {
                // past the rejoin window: once no pending uploader even
                // holds a session, waiting longer cannot change the round
                let tables = self.shared.tables.lock().unwrap();
                let any_live = expected
                    .iter()
                    .any(|&(c, _)| !ledger.has_completed(c) && tables.registry.contains_key(&c));
                if !any_live {
                    break;
                }
            }
            let timeout = closing
                .saturating_duration_since(now)
                .min(Duration::from_millis(100));
            let Some(ev) = next_event(&spec, timeout) else {
                continue;
            };
            match ev {
                RoundEvent::Upload { frames, wire_bytes } => {
                    ledger.add_bytes(wire_bytes);
                    ledger.complete(*frames);
                    *spec.cutoff.lock().unwrap() = ledger.cutoff();
                }
                RoundEvent::Failed { client, wire_bytes } => {
                    ledger.add_bytes(wire_bytes);
                    crate::log_debug!(
                        "hub",
                        "round {} upload from client {client} failed on the wire",
                        cfg.round_id
                    );
                }
            }
        }
        *self.shared.round.lock().unwrap() = None;
        self.wake_all();
        if ledger.completed_count() < expected.len() {
            // drain the event queue: an upload that completed in the gap
            // between the deadline check and the disarm still counts
            while let Some(ev) = next_event(&spec, Duration::from_millis(60)) {
                match ev {
                    RoundEvent::Upload { frames, wire_bytes } => {
                        ledger.add_bytes(wire_bytes);
                        ledger.complete(*frames);
                        *spec.cutoff.lock().unwrap() = ledger.cutoff();
                    }
                    RoundEvent::Failed { wire_bytes, .. } => ledger.add_bytes(wire_bytes),
                }
            }
        }
        for &(client, _) in expected {
            if !ledger.has_completed(client) {
                ledger.fail(client);
            }
        }
        ledger.seal()
    }

    /// Stop the accept thread and every shard, closing all sessions.
    pub fn shutdown(&mut self) {
        if self.accept.is_none() && self.shards.is_empty() {
            return;
        }
        self.shared.stop.store(true, Ordering::Relaxed);
        self.shared.accept_wake.wake();
        if let Some(a) = self.accept.take() {
            a.join().ok();
        }
        for idx in 0..self.shared.links.len() {
            send_to(&self.shared, idx, Cmd::Shutdown);
        }
        for h in self.shards.drain(..) {
            h.join().ok();
        }
        *self.shared.round.lock().unwrap() = None;
        self.shared.tables.lock().unwrap().registry.clear();
    }
}

impl Drop for ReactorHub {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// The coordinator-facing hub facade: one of the two server-side session
/// backends, selected by `--transport-backend` (env
/// `FEDML_HE_TRANSPORT_BACKEND`). Round phases drive this enum and stay
/// agnostic of which I/O model is underneath.
pub enum TransportHub {
    /// Thread-per-connection blocking backend ([`SessionHub`], default).
    Threads(SessionHub),
    /// Sharded epoll reactor backend ([`ReactorHub`]).
    Reactor(ReactorHub),
}

impl TransportHub {
    /// The selected backend's CLI name (`threads` | `hub`).
    pub fn backend_name(&self) -> &'static str {
        match self {
            TransportHub::Threads(_) => "threads",
            TransportHub::Reactor(_) => "hub",
        }
    }

    pub fn local_addr(&self) -> anyhow::Result<SocketAddr> {
        match self {
            TransportHub::Threads(h) => h.local_addr(),
            TransportHub::Reactor(h) => h.local_addr(),
        }
    }

    pub fn set_next_round(&self, round: u64) {
        match self {
            TransportHub::Threads(h) => h.set_next_round(round),
            TransportHub::Reactor(h) => h.set_next_round(round),
        }
    }

    pub fn connected(&self) -> Vec<u64> {
        match self {
            TransportHub::Threads(h) => h.connected(),
            TransportHub::Reactor(h) => h.connected(),
        }
    }

    pub fn drop_session(&self, client: u64) {
        match self {
            TransportHub::Threads(h) => h.drop_session(client),
            TransportHub::Reactor(h) => h.drop_session(client),
        }
    }

    pub fn wait_for_clients(&self, n: usize, wait: Duration) -> anyhow::Result<Vec<u64>> {
        match self {
            TransportHub::Threads(h) => h.wait_for_clients(n, wait),
            TransportHub::Reactor(h) => h.wait_for_clients(n, wait),
        }
    }

    pub fn broadcast_mask(&self, clients: &[u64], mask_bytes: &[u8]) -> DownlinkOutcome {
        match self {
            TransportHub::Threads(h) => h.broadcast_mask(clients, mask_bytes),
            TransportHub::Reactor(h) => h.broadcast_mask(clients, mask_bytes),
        }
    }

    pub fn broadcast_round(
        &self,
        round: u64,
        plans: &[(u64, DownBegin)],
        agg: Option<&EncryptedUpdate>,
    ) -> DownlinkOutcome {
        match self {
            TransportHub::Threads(h) => h.broadcast_round(round, plans, agg),
            TransportHub::Reactor(h) => h.broadcast_round(round, plans, agg),
        }
    }

    pub fn collect_round(
        &self,
        expected: &[(u64, Option<f64>)],
        shape: UpdateShape,
        cfg: &IntakeConfig,
    ) -> IntakeOutcome {
        match self {
            TransportHub::Threads(h) => h.collect_round(expected, shape, cfg),
            TransportHub::Reactor(h) => h.collect_round(expected, shape, cfg),
        }
    }

    pub fn shutdown(&mut self) {
        match self {
            TransportHub::Threads(h) => h.shutdown(),
            TransportHub::Reactor(h) => h.shutdown(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::frame::{encode_hello, read_frame_into};
    use crate::transport::session::query_stats;
    use std::io::BufReader;

    fn params() -> Arc<CkksParams> {
        Arc::new(CkksParams::new(256, 3, 30).unwrap())
    }

    #[test]
    fn stats_probe_answers_on_reactor_backend() {
        let mut hub = ReactorHub::bind("127.0.0.1:0", params(), 8).unwrap();
        let addr = hub.local_addr().unwrap().to_string();
        let snap = query_stats(&addr, Duration::from_secs(5)).unwrap();
        assert!(snap.to_string().contains("hub_wakeups"));
        hub.shutdown();
    }

    #[test]
    fn handshake_registers_and_mask_broadcast_reaches_client() {
        let mut hub = ReactorHub::bind("127.0.0.1:0", params(), 8).unwrap();
        let addr = hub.local_addr().unwrap().to_string();
        let stream = TcpStream::connect(&addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        stream.set_nodelay(true).ok();
        {
            let mut w = &stream;
            let hello = encode_hello(7, CtWire::Dense);
            write_frame(&mut w, CONTROL_ROUND, FrameKind::Hello, 0, &hello).unwrap();
        }
        let mut reader = BufReader::new(&stream);
        let mut buf = Vec::new();
        let (kind, _) = read_frame_into(&mut reader, CONTROL_ROUND, 1024, &mut buf).unwrap();
        assert_eq!(kind, FrameKind::Welcome);
        let ids = hub.wait_for_clients(1, Duration::from_secs(5)).unwrap();
        assert_eq!(ids, vec![7]);
        assert_eq!(hub.connected(), vec![7]);

        let out = hub.broadcast_mask(&[7], b"mask-bytes");
        assert!(out.failed.is_empty(), "failed: {:?}", out.failed);
        assert!(out.bytes_sent > 0);
        let (kind, _) = read_frame_into(&mut reader, MASK_ROUND, 1024, &mut buf).unwrap();
        assert_eq!(kind, FrameKind::Mask);
        assert_eq!(buf, b"mask-bytes");
        hub.shutdown();
    }
}
