//! Native (pure-Rust) aggregation backend.
//!
//! Serves three roles: the correctness oracle for the XLA artifact, the
//! fallback for shapes the fixed-shape artifact cannot take, and one side of
//! the §Perf L3 comparison.

use super::selective::EncryptedUpdate;
use crate::ckks::{ops, Ciphertext, CkksParams, CkksScratch};

/// Aggregate selectively-encrypted updates: ciphertext parts via the
/// homomorphic weighted sum, plaintext parts via an f64-accumulated
/// weighted sum.
///
/// Both parts are compacted by the run-based mask layout before they arrive
/// here, so the plaintext fold is one contiguous pass — the sequential
/// oracle the run-sharded pipeline (`agg_engine`) must match bitwise.
pub fn aggregate(
    updates: &[EncryptedUpdate],
    alphas: &[f64],
    params: &CkksParams,
) -> EncryptedUpdate {
    assert_eq!(updates.len(), alphas.len());
    assert!(!updates.is_empty());
    let n_cts = updates[0].cts.len();
    let n_plain = updates[0].plain.len();
    assert!(
        updates
            .iter()
            .all(|u| u.cts.len() == n_cts && u.plain.len() == n_plain),
        "heterogeneous update shapes"
    );

    // Encrypted part: per ciphertext index, weighted-sum across clients
    // (borrowed inputs; §Perf: one scratch + one refs buffer reused across
    // every ciphertext index — the whole loop allocates only the outputs).
    let mut scratch = CkksScratch::new(params);
    let mut slice: Vec<&Ciphertext> = Vec::with_capacity(updates.len());
    let cts = (0..n_cts)
        .map(|c| {
            slice.clear();
            slice.extend(updates.iter().map(|u| &u.cts[c]));
            let mut out = Ciphertext::zero(params);
            ops::weighted_sum_refs_into(&slice, alphas, params, &mut scratch, &mut out);
            // Seed-expanded symmetric inputs carry NTT-domain c1; normalize
            // the aggregate back to coefficient domain (INTT is linear mod
            // q, so this matches the sealed streaming pipeline bitwise).
            if out.c1.ntt_form {
                out.c1.from_ntt(params);
            }
            out
        })
        .collect();

    // Plaintext part.
    let mut plain = vec![0.0f64; n_plain];
    for (u, &a) in updates.iter().zip(alphas.iter()) {
        for (acc, &v) in plain.iter_mut().zip(u.plain.iter()) {
            *acc += a * v as f64;
        }
    }

    EncryptedUpdate {
        cts,
        plain: plain.into_iter().map(|v| v as f32).collect(),
        total: updates[0].total,
    }
}

/// Plain (non-HE) FedAvg over flat vectors — the paper's baseline.
pub fn plain_fedavg(models: &[Vec<f32>], alphas: &[f64]) -> Vec<f32> {
    assert_eq!(models.len(), alphas.len());
    let len = models[0].len();
    let mut out = vec![0.0f64; len];
    for (m, &a) in models.iter().zip(alphas.iter()) {
        assert_eq!(m.len(), len);
        for (acc, &v) in out.iter_mut().zip(m.iter()) {
            *acc += a * v as f64;
        }
    }
    out.into_iter().map(|v| v as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckks::CkksContext;
    use crate::crypto::prng::ChaChaRng;
    use crate::he_agg::mask::EncryptionMask;
    use crate::he_agg::selective::SelectiveCodec;

    #[test]
    fn selective_aggregate_matches_plain_fedavg() {
        let ctx = CkksContext::new(512, 4, 45).unwrap();
        let codec = SelectiveCodec::new(ctx);
        let mut rng = ChaChaRng::from_seed(11, 0);
        let (pk, sk) = codec.ctx.keygen(&mut rng);

        let n_clients = 4;
        let alphas = [0.4, 0.3, 0.2, 0.1];
        let models: Vec<Vec<f32>> = (0..n_clients)
            .map(|c| (0..800).map(|i| ((i * (c + 3)) as f32 * 0.01).sin()).collect())
            .collect();
        let sens: Vec<f32> = (0..800).map(|i| ((i * 13) % 797) as f32).collect();
        let mask = EncryptionMask::top_p(&sens, 0.3);

        let updates: Vec<_> = models
            .iter()
            .map(|m| codec.encrypt_update(m, &mask, &pk, &mut rng))
            .collect();
        let agg = aggregate(&updates, &alphas, &codec.ctx.params);
        let got = codec.decrypt_update(&agg, &mask, &sk);
        let expected = plain_fedavg(&models, &alphas);
        for j in 0..800 {
            assert!(
                (got[j] - expected[j]).abs() < 1e-5,
                "j={j}: {} vs {}",
                got[j],
                expected[j]
            );
        }
    }

    #[test]
    fn plain_fedavg_weighted_mean() {
        let models = vec![vec![1.0f32; 4], vec![3.0f32; 4]];
        let got = plain_fedavg(&models, &[0.75, 0.25]);
        assert_eq!(got, vec![1.5f32; 4]);
    }

    #[test]
    #[should_panic(expected = "heterogeneous")]
    fn shape_mismatch_panics() {
        let ctx = CkksContext::new(128, 2, 30).unwrap();
        let codec = SelectiveCodec::new(ctx);
        let mut rng = ChaChaRng::from_seed(12, 0);
        let (pk, _) = codec.ctx.keygen(&mut rng);
        let m1 = vec![1.0f32; 100];
        let m2 = vec![1.0f32; 50];
        let u1 = codec.encrypt_update(&m1, &EncryptionMask::full(100), &pk, &mut rng);
        let u2 = codec.encrypt_update(&m2, &EncryptionMask::full(50), &pk, &mut rng);
        aggregate(&[u1, u2], &[0.5, 0.5], &codec.ctx.params);
    }
}
