//! Run-aware ciphertext packing plans (§Perf).
//!
//! A selective-encryption round packs the masked parameters into CKKS
//! ciphertexts of `batch = n/2` slots each. How the mask's runs are cut
//! into chunks decides the ciphertext count — the dominant term in both
//! the fig14b bandwidth curves and the server's per-round compute:
//!
//! - **Run-aware** ([`PackingPlan::run_aware`]): gather segments are packed
//!   tightly against [`Run`] boundaries in compacted order — a chunk keeps
//!   filling across run edges until all `batch` slots are used, so the
//!   ciphertext count is the information-theoretic floor `⌈k/batch⌉` and
//!   slot utilization approaches 100%. This is the layout
//!   [`super::selective::SelectiveCodec`] encrypts, and it is what keeps
//!   the ciphertext stream (and therefore `ShardPlan`/`agg_engine` sums)
//!   bitwise identical for any worker or shard count: chunk contents are a
//!   pure function of the mask, never of the execution schedule.
//! - **Chunk-aligned** ([`PackingPlan::chunk_aligned`]): the naive grid
//!   layout that cuts the *flat parameter space* at multiples of `batch`
//!   and keeps every window a run touches. Slots between the window edge
//!   and the run edge are padding, so fragmented masks (e.g. BERT-scale
//!   layer-granularity selections) pay for slots they never fill. Kept as
//!   the measured baseline for the packing regression gate and
//!   `perf_hotpath` — not an encryption path.
//!
//! Both constructors are deterministic in the mask alone; the per-chunk
//! segment lists drive the codec's gather directly, which removes the
//! whole-model staging copy the codec used to build before chunking.

use super::mask::Run;

/// How gather segments are assigned to ciphertext chunks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PackingMode {
    /// Tight compacted packing against run boundaries (the codec layout).
    RunAware,
    /// Grid windows of `batch` over the flat parameter space (baseline).
    ChunkAligned,
}

/// A concrete assignment of mask runs to ciphertext chunks: for each chunk,
/// the absolute-index segments whose values it carries, in order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PackingPlan {
    mode: PackingMode,
    batch: usize,
    /// Per-chunk gather segments (absolute parameter indices, in order).
    chunks: Vec<Vec<Run>>,
    /// Masked values carried (Σ segment lengths over all chunks).
    slots_used: usize,
}

impl PackingPlan {
    /// Tight packing: walk the runs in order, splitting only where a chunk
    /// fills all `batch` slots. `n_cts() == ⌈k/batch⌉` for `k` masked
    /// values — no padding except in the final chunk.
    pub fn run_aware(runs: &[Run], batch: usize) -> Self {
        assert!(batch >= 1, "batch must be positive");
        let mut chunks = Vec::new();
        let mut cur: Vec<Run> = Vec::new();
        let mut cur_len = 0usize;
        let mut slots_used = 0usize;
        for r in runs {
            let mut lo = r.lo;
            while lo < r.hi {
                let take = (batch - cur_len).min(r.hi - lo);
                cur.push(Run { lo, hi: lo + take });
                cur_len += take;
                slots_used += take;
                lo += take;
                if cur_len == batch {
                    chunks.push(std::mem::take(&mut cur));
                    cur_len = 0;
                }
            }
        }
        if !cur.is_empty() {
            chunks.push(cur);
        }
        PackingPlan {
            mode: PackingMode::RunAware,
            batch,
            chunks,
            slots_used,
        }
    }

    /// Grid baseline: one chunk per `batch`-aligned window of the flat
    /// parameter space that intersects the mask; run fragments keep their
    /// in-window positions, so unaligned run edges waste slots.
    pub fn chunk_aligned(runs: &[Run], batch: usize) -> Self {
        assert!(batch >= 1, "batch must be positive");
        let mut chunks: Vec<Vec<Run>> = Vec::new();
        let mut cur: Vec<Run> = Vec::new();
        let mut cur_window = usize::MAX;
        let mut slots_used = 0usize;
        for r in runs {
            let mut lo = r.lo;
            while lo < r.hi {
                let window = lo / batch;
                let hi = r.hi.min((window + 1) * batch);
                if window != cur_window {
                    if !cur.is_empty() {
                        chunks.push(std::mem::take(&mut cur));
                    }
                    cur_window = window;
                }
                cur.push(Run { lo, hi });
                slots_used += hi - lo;
                lo = hi;
            }
        }
        if !cur.is_empty() {
            chunks.push(cur);
        }
        PackingPlan {
            mode: PackingMode::ChunkAligned,
            batch,
            chunks,
            slots_used,
        }
    }

    pub fn mode(&self) -> PackingMode {
        self.mode
    }

    /// Slots per ciphertext this plan was cut for.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Ciphertexts the plan produces.
    pub fn n_cts(&self) -> usize {
        self.chunks.len()
    }

    /// Masked values carried across all chunks.
    pub fn slots_used(&self) -> usize {
        self.slots_used
    }

    /// CKKS slots allocated across all chunks (`n_cts · batch`).
    pub fn slots_total(&self) -> usize {
        self.n_cts() * self.batch
    }

    /// Fraction of allocated slots that carry a masked value (1.0 for an
    /// empty plan — nothing allocated, nothing wasted).
    pub fn slot_utilization(&self) -> f64 {
        if self.slots_total() == 0 {
            1.0
        } else {
            self.slots_used as f64 / self.slots_total() as f64
        }
    }

    /// Gather segments of chunk `c` (absolute parameter indices, in order).
    pub fn segments(&self, c: usize) -> &[Run] {
        &self.chunks[c]
    }

    /// Values carried by chunk `c`.
    pub fn chunk_len(&self, c: usize) -> usize {
        self.chunks[c].iter().map(|r| r.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::model_meta;
    use crate::he_agg::mask::EncryptionMask;

    fn runs(spec: &[(usize, usize)]) -> Vec<Run> {
        spec.iter().map(|&(lo, hi)| Run { lo, hi }).collect()
    }

    #[test]
    fn run_aware_hits_ciphertext_floor() {
        // 3 runs of 5 values each over batch 8: 15 values → 2 chunks, the
        // first spanning two run edges without padding.
        let plan = PackingPlan::run_aware(&runs(&[(0, 5), (10, 15), (20, 25)]), 8);
        assert_eq!(plan.n_cts(), 2);
        assert_eq!(plan.slots_used(), 15);
        assert_eq!(plan.chunk_len(0), 8);
        assert_eq!(plan.chunk_len(1), 7);
        assert_eq!(
            plan.segments(0),
            &runs(&[(0, 5), (10, 13)])[..],
            "first chunk packs across the run edge"
        );
        assert_eq!(plan.segments(1), &runs(&[(13, 15), (20, 25)])[..]);
    }

    #[test]
    fn chunk_aligned_pads_at_run_edges() {
        // The same 15 values land in 3 grid windows (0..8, 8..16, 16..24 —
        // and 24..32 for the tail), wasting slots at every unaligned edge.
        let plan = PackingPlan::chunk_aligned(&runs(&[(0, 5), (10, 15), (20, 25)]), 8);
        assert_eq!(plan.slots_used(), 15);
        assert!(plan.n_cts() > 2, "grid layout cannot hit the floor here");
        assert!(plan.slot_utilization() < 0.7);
    }

    #[test]
    fn run_aware_matches_ct_count_formula() {
        for (spec, batch) in [
            (vec![(0usize, 100usize)], 16usize),
            (vec![(3, 20), (40, 41), (50, 90)], 8),
            (vec![(0, 1)], 4096),
            (vec![], 64),
        ] {
            let rs = runs(&spec);
            let k: usize = rs.iter().map(|r| r.len()).sum();
            let plan = PackingPlan::run_aware(&rs, batch);
            assert_eq!(plan.n_cts(), k.div_ceil(batch));
            assert_eq!(plan.slots_used(), k);
            // Segments reproduce the mask exactly, in order.
            let mut flat = Vec::new();
            for c in 0..plan.n_cts() {
                for seg in plan.segments(c) {
                    flat.extend(seg.lo..seg.hi);
                }
            }
            let expect: Vec<usize> = rs.iter().flat_map(|r| r.lo..r.hi).collect();
            assert_eq!(flat, expect);
        }
    }

    #[test]
    fn aligned_mask_is_identical_under_both_modes() {
        // Runs already cut at batch multiples: the grid baseline degenerates
        // to the tight packing (same counts, full utilization).
        let rs = runs(&[(0, 128), (256, 384)]);
        let ra = PackingPlan::run_aware(&rs, 128);
        let ca = PackingPlan::chunk_aligned(&rs, 128);
        assert_eq!(ra.n_cts(), ca.n_cts());
        assert_eq!(ra.slot_utilization(), 1.0);
        assert_eq!(ca.slot_utilization(), 1.0);
    }

    /// The regression gate of ISSUE 7 / ROADMAP item 5 in unit-test form:
    /// on the BERT-scale layer-granularity mask the run-aware plan must
    /// produce strictly fewer ciphertexts than the chunk-aligned baseline.
    #[test]
    fn bert_layer_mask_run_aware_beats_chunk_aligned() {
        let info = model_meta::lookup("bert").expect("bert in registry");
        let total = info.params as usize;
        let spans = info.layer_spans();
        let scores: Vec<f32> = (0..spans.len()).map(|i| ((i * 37) % 101) as f32).collect();
        let mask = EncryptionMask::from_layer_scores(total, &scores, &spans, 0.1);
        let batch = 4096; // the paper's default packing batch (n = 8192)
        let run_aware = PackingPlan::run_aware(mask.runs(), batch);
        let chunk_aligned = PackingPlan::chunk_aligned(mask.runs(), batch);
        assert_eq!(run_aware.n_cts(), mask.encrypted_count().div_ceil(batch));
        assert!(
            run_aware.n_cts() < chunk_aligned.n_cts(),
            "packing regression: run-aware {} vs chunk-aligned {}",
            run_aware.n_cts(),
            chunk_aligned.n_cts()
        );
        assert!(run_aware.slot_utilization() > chunk_aligned.slot_utilization());
        assert!(run_aware.slot_utilization() > 0.999);
    }
}
