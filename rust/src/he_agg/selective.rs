//! Selective encryption codec: flat parameter vector ⇄ (ciphertexts, plain).
//!
//! Implements the client-side transform of Algorithm 1:
//! `[W] = HE.Enc(pk, M ⊙ W) + (1 − M) ⊙ W` — the masked coordinates are
//! compacted in mask order and packed `batch()` values per ciphertext; the
//! remaining coordinates travel as compacted plaintext f32.
//!
//! Gather and scatter operate on the mask's interval runs: every hot-path
//! copy is a contiguous segment (memcpy for the f32 plaintext remainder, a
//! strided-free widening loop for the f64 encrypt staging), never per-index
//! indirection, and no dense boolean view is ever materialized.
//!
//! §Perf (parallel codec): `encrypt_update`/`decrypt_update` fan their chunk
//! ciphertexts across a `std::thread::scope` worker pool. Each worker owns a
//! pooled [`CkksScratch`] (zero steady-state allocation in the per-chunk
//! encrypt), and each chunk encrypts under its **own forked RNG stream**
//! ([`ChaChaRng::fork`], forked from the caller's rng in chunk order), so
//! the produced ciphertexts are bitwise identical for any worker count —
//! client-side cost scales with cores the way the server's `agg_engine`
//! already does.
//!
//! §Perf (run-aware packing + ciphertext arena): the encrypt fan-out is
//! driven by a [`PackingPlan`] cut tightly against the mask's run
//! boundaries — each worker gathers chunk `c`'s segments straight from the
//! model into a batch-sized staging buffer, so the whole-model f64 staging
//! vector the codec used to build (hundreds of MB at BERT scale) is gone.
//! Output ciphertexts come from a caller-supplied [`CtArena`] free list and
//! plaintexts are encoded in place ([`crate::ckks::Encoder::encode_into`]),
//! so a steady-state round allocates nothing per chunk — gated by the
//! counting allocator in `tests/zero_alloc.rs`.

use super::mask::{EncryptionMask, MaskLayout, Run};
use super::packing::PackingPlan;
use crate::ckks::{
    decrypt_into, Ciphertext, CkksContext, CkksParams, CkksScratch, EncKey, EncodeScratch,
    PublicKey, RnsPoly, SecretKey,
};
use crate::crypto::prng::ChaChaRng;
use std::sync::Mutex;

/// One client's (selectively) encrypted model update.
#[derive(Debug, Clone)]
pub struct EncryptedUpdate {
    /// Ciphertexts over the masked coordinates (mask order, batch-packed).
    pub cts: Vec<Ciphertext>,
    /// Compacted plaintext coordinates (complement of the mask, index order).
    pub plain: Vec<f32>,
    /// Total parameter count (for merge validation).
    pub total: usize,
}

impl EncryptedUpdate {
    /// Serialized size in bytes (the communication-cost model: ciphertext
    /// wire format + 4 B per plaintext value).
    pub fn wire_bytes(&self, ctx: &CkksContext) -> usize {
        self.wire_bytes_for(ctx, crate::ckks::CtWire::Dense)
    }

    /// [`Self::wire_bytes`] under an explicit ciphertext wire format: the
    /// seeded wire replaces each dense a-part with a 32-byte seed, so a
    /// `--ct-wire seed` upload costs roughly half the dense bytes.
    pub fn wire_bytes_for(&self, ctx: &CkksContext, ct_wire: crate::ckks::CtWire) -> usize {
        let per_ct = match ct_wire {
            crate::ckks::CtWire::Dense => ctx.params.ciphertext_bytes(),
            crate::ckks::CtWire::Seed => {
                crate::ckks::serialize::seeded_wire_bytes(&ctx.params)
            }
        };
        self.cts.len() * per_ct + 4 * self.plain.len()
    }

    /// Serialized size of limb range [lo, hi) of every ciphertext under the
    /// per-shard wire format (`ckks::serialize::ciphertext_shard_to_bytes`)
    /// — what one aggregation shard receives when the transfer itself is
    /// sharded. The plaintext remainder is accounted separately (it travels
    /// with whichever shard owns its range).
    pub fn limb_shard_wire_bytes(&self, ctx: &CkksContext, lo: usize, hi: usize) -> usize {
        self.cts.len() * crate::ckks::serialize::shard_wire_bytes(&ctx.params, lo, hi)
    }
}

/// A shape-checked free list of ciphertext buffers shared across rounds
/// (§Perf): `take` pops a pooled buffer (or allocates on a cold pool), the
/// consumer calls [`CtArena::recycle`] once the ciphertext has left for the
/// wire, and the next chunk's encrypt reuses it. [`encrypt_into`] fully
/// overwrites both components (proved by the dirty-buffer test in
/// `ckks::encrypt`), so recycled buffers need no zeroing and the ciphertext
/// stream stays bitwise identical to the allocating path.
pub struct CtArena {
    free: Mutex<Vec<Ciphertext>>,
}

impl CtArena {
    pub fn new() -> Self {
        CtArena { free: Mutex::new(Vec::new()) }
    }

    /// Pop a pooled buffer of this parameter set's shape, or allocate one.
    /// Foreign-shaped buffers (an arena outliving a context change) are
    /// dropped rather than handed out.
    pub fn take(&self, params: &CkksParams) -> Ciphertext {
        let mut free = self.free.lock().unwrap();
        while let Some(ct) = free.pop() {
            if ct.c0.n == params.n && ct.c0.num_limbs() == params.num_limbs() {
                return ct;
            }
        }
        drop(free);
        Ciphertext::zero(params)
    }

    /// Return a ciphertext buffer to the pool for the next `take`.
    pub fn recycle(&self, ct: Ciphertext) {
        self.free.lock().unwrap().push(ct);
    }

    /// Buffers currently pooled (waiting for a `take`).
    pub fn len(&self) -> usize {
        self.free.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Default for CtArena {
    fn default() -> Self {
        Self::new()
    }
}

/// Per-worker staging for the plan-driven chunk encrypt: the gathered f64
/// chunk values, pooled encode buffers, the encoded plaintext and the CKKS
/// scratch. One stage lives per worker for a whole call, so the per-chunk
/// path allocates nothing after warm-up.
struct ChunkStage {
    values: Vec<f64>,
    encode: EncodeScratch,
    pt: RnsPoly,
    scratch: CkksScratch,
}

impl ChunkStage {
    fn new(params: &CkksParams) -> Self {
        ChunkStage {
            values: Vec::with_capacity(params.n / 2),
            encode: EncodeScratch::default(),
            pt: RnsPoly::zero(params),
            scratch: CkksScratch::new(params),
        }
    }
}

/// Streaming scatter cursor: walks a run list while compacted (mask-order)
/// value chunks arrive, writing each chunk into as many contiguous segments
/// as it spans.
struct RunCursor<'a> {
    runs: &'a [Run],
    run: usize,
    /// Offset into `runs[run]`.
    off: usize,
    scattered: usize,
}

impl<'a> RunCursor<'a> {
    fn new(runs: &'a [Run]) -> Self {
        RunCursor { runs, run: 0, off: 0, scattered: 0 }
    }

    /// Scatter one chunk of compacted f64 values into `out`. Values beyond
    /// the run list (packing slack in the final ciphertext) are dropped.
    fn scatter(&mut self, values: &[f64], out: &mut [f32]) {
        let mut v = 0usize;
        while v < values.len() && self.run < self.runs.len() {
            let r = self.runs[self.run];
            let take = (r.len() - self.off).min(values.len() - v);
            let base = r.lo + self.off;
            for (d, &s) in out[base..base + take].iter_mut().zip(values[v..v + take].iter()) {
                *d = s as f32;
            }
            v += take;
            self.off += take;
            self.scattered += take;
            if self.off == r.len() {
                self.run += 1;
                self.off = 0;
            }
        }
    }

    fn scattered(&self) -> usize {
        self.scattered
    }
}

/// Scatter the compacted plaintext remainder back into `out` along the
/// complement runs — pure `copy_from_slice` segments.
fn scatter_plain(layout: &MaskLayout, plain: &[f32], out: &mut [f32]) {
    assert_eq!(plain.len(), layout.count(), "plaintext remainder length");
    let mut off = 0usize;
    for r in layout.runs() {
        out[r.lo..r.hi].copy_from_slice(&plain[off..off + r.len()]);
        off += r.len();
    }
}

/// Encoder/decoder bound to a crypto context.
pub struct SelectiveCodec {
    pub ctx: CkksContext,
    /// Worker threads for the per-chunk fan-out (1 = sequential). Chunk
    /// outputs are identical for any value (per-chunk forked RNG streams).
    workers: usize,
}

impl SelectiveCodec {
    /// Codec with one worker per available core.
    pub fn new(ctx: CkksContext) -> Self {
        let workers = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1);
        Self::with_workers(ctx, workers)
    }

    /// Codec with an explicit worker count (1 = the sequential reference
    /// path; results are bitwise identical across worker counts).
    pub fn with_workers(ctx: CkksContext, workers: usize) -> Self {
        SelectiveCodec {
            ctx,
            workers: workers.max(1),
        }
    }

    /// Worker threads used for chunk fan-out.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Ciphertexts needed for `k` encrypted values.
    pub fn ct_count(&self, k: usize) -> usize {
        k.div_ceil(self.ctx.batch())
    }

    /// Encode + encrypt chunk `c` of the packing plan (the per-worker unit
    /// of work): gather the chunk's segments straight from the model, encode
    /// into the stage's pooled plaintext and encrypt into an arena-pooled
    /// ciphertext — allocation-free after warm-up.
    fn encrypt_one_chunk(
        &self,
        model: &[f32],
        plan: &PackingPlan,
        c: usize,
        key: EncKey<'_>,
        rng: &mut ChaChaRng,
        stage: &mut ChunkStage,
        arena: &CtArena,
    ) -> Ciphertext {
        let _span = crate::obs::span_arg("codec", "encrypt_chunk", c as u64);
        stage.values.clear();
        for seg in plan.segments(c) {
            stage.values.extend(model[seg.lo..seg.hi].iter().map(|&v| v as f64));
        }
        self.ctx.encoder.encode_into(&stage.values, &mut stage.encode, &mut stage.pt);
        let mut ct = arena.take(&self.ctx.params);
        key.encrypt_into(
            &self.ctx.params,
            &stage.pt,
            stage.values.len(),
            rng,
            &mut stage.scratch,
            &mut ct,
        );
        ct
    }

    /// Encrypt every chunk of the compacted value vector, handing finished
    /// ciphertexts to `consume` **in chunk order as they complete** — the
    /// transport client pushes chunk `c` onto the wire while chunks `> c`
    /// are still encrypting on the worker pool. Worker `w` owns chunks
    /// `w, w+W, …` with its own pooled scratch and hands results over a
    /// bounded channel, so at most O(workers) finished chunks are ever
    /// buffered ahead of the consumer. One pre-forked RNG per chunk (forked
    /// from the caller's rng in chunk order) makes the ciphertext stream —
    /// and the caller's post-call rng state — a pure function of the
    /// caller's RNG, independent of worker count, scheduling, or consumer
    /// speed: byte-for-byte the stream [`SelectiveCodec::encrypt_update`]
    /// produces.
    ///
    /// Returns the compacted plaintext remainder and the chunk count.
    pub fn encrypt_update_streamed(
        &self,
        params: &[f32],
        mask: &EncryptionMask,
        pk: &PublicKey,
        rng: &mut ChaChaRng,
        consume: impl FnMut(usize, Ciphertext),
    ) -> (Vec<f32>, usize) {
        self.encrypt_update_streamed_with_arena(params, mask, pk, rng, &CtArena::new(), consume)
    }

    /// [`Self::encrypt_update_streamed`] under either ct-wire key mode
    /// ([`EncKey::SymSeeded`] emits seed-expanded symmetric ciphertexts).
    pub fn encrypt_update_streamed_keyed(
        &self,
        params: &[f32],
        mask: &EncryptionMask,
        key: EncKey<'_>,
        rng: &mut ChaChaRng,
        consume: impl FnMut(usize, Ciphertext),
    ) -> (Vec<f32>, usize) {
        self.encrypt_update_streamed_with_arena_keyed(
            params,
            mask,
            key,
            rng,
            &CtArena::new(),
            consume,
        )
    }

    /// [`Self::encrypt_update_streamed`] drawing output ciphertexts from a
    /// caller-owned [`CtArena`]: the consumer recycles each buffer once it
    /// has left for the wire, so a steady-state round allocates no
    /// ciphertext buffers at all. Chunk cuts come from
    /// [`PackingPlan::run_aware`] over the mask's runs — each worker gathers
    /// its chunk's segments straight from `params`, so no whole-model f64
    /// staging vector is ever built. The ciphertext stream is bitwise
    /// identical for any arena state, worker count or consumer speed.
    pub fn encrypt_update_streamed_with_arena(
        &self,
        params: &[f32],
        mask: &EncryptionMask,
        pk: &PublicKey,
        rng: &mut ChaChaRng,
        arena: &CtArena,
        consume: impl FnMut(usize, Ciphertext),
    ) -> (Vec<f32>, usize) {
        self.encrypt_update_streamed_with_arena_keyed(
            params,
            mask,
            EncKey::Public(pk),
            rng,
            arena,
            consume,
        )
    }

    /// [`Self::encrypt_update_streamed_with_arena`] under either ct-wire key
    /// mode. The per-chunk forked RNG streams draw the ciphertext seed and
    /// error from the chunk's own fork, so seeded output — like dense — is
    /// bitwise identical for any worker count, arena state or consumer
    /// speed.
    pub fn encrypt_update_streamed_with_arena_keyed(
        &self,
        params: &[f32],
        mask: &EncryptionMask,
        key: EncKey<'_>,
        rng: &mut ChaChaRng,
        arena: &CtArena,
        mut consume: impl FnMut(usize, Ciphertext),
    ) -> (Vec<f32>, usize) {
        assert_eq!(params.len(), mask.total(), "mask/params length mismatch");
        let plan = PackingPlan::run_aware(mask.runs(), self.ctx.batch());
        crate::obs::metrics::pack_slots(plan.slots_used() as u64, plan.slots_total() as u64);
        // Plaintext part: segment memcpy along the complement runs.
        let plain_layout = mask.plaintext_layout();
        let mut plain: Vec<f32> = Vec::with_capacity(plain_layout.count());
        for r in plain_layout.runs() {
            plain.extend_from_slice(&params[r.lo..r.hi]);
        }
        let n_chunks = plan.n_cts();
        let chunk_rngs: Vec<ChaChaRng> = (0..n_chunks).map(|c| rng.fork(c as u64)).collect();
        let workers = self.workers.min(n_chunks).max(1);
        if workers <= 1 {
            let mut stage = ChunkStage::new(&self.ctx.params);
            for (c, mut r) in chunk_rngs.into_iter().enumerate() {
                let ct = self.encrypt_one_chunk(params, &plan, c, key, &mut r, &mut stage, arena);
                consume(c, ct);
            }
        } else {
            // Stride-distribute the forked rngs: worker w owns chunks
            // w, w+W, … and produces them in ascending order.
            let mut worker_rngs: Vec<Vec<ChaChaRng>> = vec![Vec::new(); workers];
            for (c, r) in chunk_rngs.into_iter().enumerate() {
                worker_rngs[c % workers].push(r);
            }
            let plan = &plan;
            std::thread::scope(|s| {
                let mut rxs = Vec::with_capacity(workers);
                for (w, mut rngs_w) in worker_rngs.into_iter().enumerate() {
                    let (tx, rx) = std::sync::mpsc::sync_channel::<Ciphertext>(2);
                    rxs.push(rx);
                    s.spawn(move || {
                        let mut stage = ChunkStage::new(&self.ctx.params);
                        for (i, chunk_rng) in rngs_w.iter_mut().enumerate() {
                            let c = w + i * workers;
                            let ct = self.encrypt_one_chunk(
                                params,
                                plan,
                                c,
                                key,
                                chunk_rng,
                                &mut stage,
                                arena,
                            );
                            if tx.send(ct).is_err() {
                                break; // consumer side gone
                            }
                        }
                    });
                }
                // In-order drain: chunk c comes from worker c % workers.
                for c in 0..n_chunks {
                    let ct = rxs[c % workers].recv().expect("encrypt worker hung up");
                    consume(c, ct);
                }
            });
        }
        (plain, n_chunks)
    }

    /// Decrypt + decode every ciphertext through a persistent worker pool,
    /// streaming decoded chunks to `consume` **in chunk order**. Worker `w`
    /// owns chunks `w, w+workers, …` (per-worker scratch lives for the whole
    /// call) and hands results over a bounded channel, so transient decoded
    /// plaintext stays O(workers) chunks for any model size. Decryption is
    /// deterministic, so the fan-out needs no RNG plumbing.
    fn decrypt_chunks_streamed(
        &self,
        cts: &[Ciphertext],
        sk: &SecretKey,
        mut consume: impl FnMut(Vec<f64>),
    ) {
        let k = cts.len();
        let workers = self.workers.min(k).max(1);
        if workers <= 1 {
            let mut scratch = CkksScratch::new(&self.ctx.params);
            let mut poly = RnsPoly::zero(&self.ctx.params);
            for (c, ct) in cts.iter().enumerate() {
                let _s = crate::obs::span_arg("codec", "decrypt_chunk", c as u64);
                decrypt_into(&self.ctx.params, sk, ct, &mut scratch, &mut poly);
                consume(self.ctx.encoder.decode(&poly, ct.n_values, ct.scale));
            }
        } else {
            std::thread::scope(|s| {
                let mut rxs = Vec::with_capacity(workers);
                for w in 0..workers {
                    let (tx, rx) = std::sync::mpsc::sync_channel::<Vec<f64>>(4);
                    rxs.push(rx);
                    s.spawn(move || {
                        let mut scratch = CkksScratch::new(&self.ctx.params);
                        let mut poly = RnsPoly::zero(&self.ctx.params);
                        for (i, ct) in cts.iter().skip(w).step_by(workers).enumerate() {
                            let _s = crate::obs::span_arg(
                                "codec",
                                "decrypt_chunk",
                                (w + i * workers) as u64,
                            );
                            decrypt_into(&self.ctx.params, sk, ct, &mut scratch, &mut poly);
                            let values =
                                self.ctx.encoder.decode(&poly, ct.n_values, ct.scale);
                            if tx.send(values).is_err() {
                                break; // consumer side gone
                            }
                        }
                    });
                }
                // In-order drain: chunk c comes from worker c % workers, and
                // each worker produces its chunks in ascending order.
                for c in 0..k {
                    let values = rxs[c % workers].recv().expect("decrypt worker hung up");
                    consume(values);
                }
            });
        }
    }

    /// Apply Algorithm 1's client-side encryption.
    pub fn encrypt_update(
        &self,
        params: &[f32],
        mask: &EncryptionMask,
        pk: &PublicKey,
        rng: &mut ChaChaRng,
    ) -> EncryptedUpdate {
        self.encrypt_update_keyed(params, mask, EncKey::Public(pk), rng)
    }

    /// [`Self::encrypt_update`] under either ct-wire key mode.
    pub fn encrypt_update_keyed(
        &self,
        params: &[f32],
        mask: &EncryptionMask,
        key: EncKey<'_>,
        rng: &mut ChaChaRng,
    ) -> EncryptedUpdate {
        let mut cts: Vec<Ciphertext> = Vec::with_capacity(self.ct_count(mask.encrypted_count()));
        let (plain, n_chunks) =
            self.encrypt_update_streamed_keyed(params, mask, key, rng, |_, ct| cts.push(ct));
        debug_assert_eq!(cts.len(), n_chunks);
        EncryptedUpdate {
            cts,
            plain,
            total: params.len(),
        }
    }

    /// Decrypt + merge an (aggregated) update back into a flat vector.
    pub fn decrypt_update(
        &self,
        update: &EncryptedUpdate,
        mask: &EncryptionMask,
        sk: &SecretKey,
    ) -> Vec<f32> {
        assert_eq!(update.total, mask.total(), "update/mask total mismatch");
        let mut out = vec![0.0f32; mask.total()];
        scatter_plain(&mask.plaintext_layout(), &update.plain, &mut out);
        let mut cursor = RunCursor::new(mask.runs());
        self.decrypt_chunks_streamed(&update.cts, sk, |values| {
            cursor.scatter(&values, &mut out);
        });
        assert_eq!(cursor.scattered(), mask.encrypted_count(), "short decrypt");
        out
    }

    /// Decrypt via threshold partials instead of a single secret key.
    pub fn decrypt_update_threshold(
        &self,
        update: &EncryptedUpdate,
        mask: &EncryptionMask,
        parties: &[&crate::ckks::threshold::ThresholdParty],
        rng: &mut ChaChaRng,
    ) -> Vec<f32> {
        assert_eq!(update.total, mask.total(), "update/mask total mismatch");
        let mut out = vec![0.0f32; mask.total()];
        scatter_plain(&mask.plaintext_layout(), &update.plain, &mut out);
        let mut cursor = RunCursor::new(mask.runs());
        for ct in &update.cts {
            let partials: Vec<_> = parties
                .iter()
                .map(|p| crate::ckks::threshold::partial_decrypt(&self.ctx.params, p, ct, rng))
                .collect();
            let m = crate::ckks::threshold::combine_partials(&self.ctx.params, ct, &partials);
            let values = self.ctx.encoder.decode(&m, ct.n_values, ct.scale);
            cursor.scatter(&values, &mut out);
        }
        assert_eq!(cursor.scattered(), mask.encrypted_count(), "short decrypt");
        out
    }
}

/// Encrypt a full f64 vector (no mask semantics) — used for the sensitivity
/// map aggregation of the mask-agreement stage, where the *entire* map is
/// encrypted.
pub fn encrypt_vector(
    ctx: &CkksContext,
    values: &[f32],
    pk: &PublicKey,
    rng: &mut ChaChaRng,
) -> Vec<Ciphertext> {
    encrypt_vector_keyed(ctx, values, EncKey::Public(pk), rng)
}

/// [`encrypt_vector`] under either ct-wire key mode — in seed mode the
/// sensitivity-map uplink is symmetric too, so every uplink ciphertext
/// travels compressed.
pub fn encrypt_vector_keyed(
    ctx: &CkksContext,
    values: &[f32],
    key: EncKey<'_>,
    rng: &mut ChaChaRng,
) -> Vec<Ciphertext> {
    let batch = ctx.batch();
    values
        .chunks(batch)
        .map(|chunk| {
            let v: Vec<f64> = chunk.iter().map(|&x| x as f64).collect();
            ctx.encrypt_values_keyed(&v, key, rng)
        })
        .collect()
}

/// Decrypt a vector of ciphertexts back to `total` f32 values.
pub fn decrypt_vector(
    ctx: &CkksContext,
    cts: &[Ciphertext],
    sk: &SecretKey,
    total: usize,
) -> Vec<f32> {
    let mut out = Vec::with_capacity(total);
    for ct in cts {
        let vals = ctx.decrypt_values(ct, sk);
        out.extend(vals.into_iter().map(|v| v as f32));
    }
    out.truncate(total);
    out
}


#[cfg(test)]
mod tests {
    use super::*;

    fn small_ctx() -> CkksContext {
        CkksContext::new(512, 4, 45).unwrap()
    }

    #[test]
    fn split_merge_roundtrip() {
        let ctx = small_ctx();
        let codec = SelectiveCodec::new(ctx);
        let mut rng = ChaChaRng::from_seed(1, 0);
        let (pk, sk) = codec.ctx.keygen(&mut rng);
        let params: Vec<f32> = (0..1000).map(|i| (i as f32 * 0.37).sin()).collect();
        let sens: Vec<f32> = (0..1000).map(|i| ((i * 31) % 997) as f32).collect();
        for p in [0.0, 0.1, 0.5, 1.0] {
            let mask = EncryptionMask::top_p(&sens, p);
            let upd = codec.encrypt_update(&params, &mask, &pk, &mut rng);
            assert_eq!(upd.cts.len(), codec.ct_count(mask.encrypted_count()));
            let back = codec.decrypt_update(&upd, &mask, &sk);
            for (a, b) in params.iter().zip(back.iter()) {
                assert!((a - b).abs() < 1e-5, "p={p}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn run_structured_mask_roundtrip() {
        // a layer-style mask (few long runs) exercises the segment paths:
        // multi-run ciphertext chunks and memcpy plaintext scatter
        let ctx = small_ctx();
        let codec = SelectiveCodec::new(ctx);
        let mut rng = ChaChaRng::from_seed(7, 0);
        let (pk, sk) = codec.ctx.keygen(&mut rng);
        let total = 900;
        let params: Vec<f32> = (0..total).map(|i| (i as f32 * 0.11).cos()).collect();
        let mask = EncryptionMask::from_runs(
            total,
            vec![
                Run { lo: 0, hi: 300 },
                Run { lo: 400, hi: 401 },
                Run { lo: 500, hi: 800 },
            ],
        );
        let upd = codec.encrypt_update(&params, &mask, &pk, &mut rng);
        assert_eq!(upd.plain.len(), total - mask.encrypted_count());
        let back = codec.decrypt_update(&upd, &mask, &sk);
        for (a, b) in params.iter().zip(back.iter()) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn parallel_encrypt_matches_sequential_ciphertext_for_ciphertext() {
        // §Perf determinism gate: the worker-pool fan-out must produce the
        // exact ciphertext stream of the sequential path, for any worker
        // count, and leave the caller's RNG in the same state.
        let ctx = small_ctx();
        let (pk, sk) = {
            let mut krng = ChaChaRng::from_seed(41, 0);
            ctx.keygen(&mut krng)
        };
        let total = 2000; // 8 chunks at batch 256
        let params: Vec<f32> = (0..total).map(|i| (i as f32 * 0.017).sin()).collect();
        let sens: Vec<f32> = (0..total).map(|i| ((i * 7) % 611) as f32).collect();
        let mask = EncryptionMask::top_p(&sens, 0.6);
        let seq = SelectiveCodec::with_workers(ctx.clone(), 1);
        let baseline = {
            let mut rng = ChaChaRng::from_seed(42, 0);
            let upd = seq.encrypt_update(&params, &mask, &pk, &mut rng);
            (upd, rng.next_u64())
        };
        for workers in [2usize, 3, 8] {
            let par = SelectiveCodec::with_workers(ctx.clone(), workers);
            let mut rng = ChaChaRng::from_seed(42, 0);
            let upd = par.encrypt_update(&params, &mask, &pk, &mut rng);
            assert_eq!(upd.cts.len(), baseline.0.cts.len());
            for (c, (a, b)) in upd.cts.iter().zip(baseline.0.cts.iter()).enumerate() {
                assert_eq!(a, b, "workers={workers}: chunk {c} differs");
            }
            assert_eq!(upd.plain, baseline.0.plain, "workers={workers}");
            assert_eq!(rng.next_u64(), baseline.1, "workers={workers}: rng drift");
            // parallel decrypt agrees with the sequential decrypt
            let d_seq = seq.decrypt_update(&baseline.0, &mask, &sk);
            let d_par = par.decrypt_update(&upd, &mask, &sk);
            assert_eq!(d_seq, d_par, "workers={workers}");
        }
    }

    #[test]
    fn streamed_encrypt_is_identical_and_in_order() {
        // the wire-streaming entry point must hand out the exact chunk
        // sequence of encrypt_update, in ascending chunk order
        let ctx = small_ctx();
        let (pk, _) = {
            let mut krng = ChaChaRng::from_seed(51, 0);
            ctx.keygen(&mut krng)
        };
        let total = 1500;
        let model: Vec<f32> = (0..total).map(|i| (i as f32 * 0.013).sin()).collect();
        let sens: Vec<f32> = (0..total).map(|i| ((i * 13) % 401) as f32).collect();
        let mask = EncryptionMask::top_p(&sens, 0.7);
        let codec = SelectiveCodec::with_workers(ctx.clone(), 4);
        let baseline = {
            let mut rng = ChaChaRng::from_seed(52, 0);
            codec.encrypt_update(&model, &mask, &pk, &mut rng)
        };
        let mut rng = ChaChaRng::from_seed(52, 0);
        let mut seen: Vec<(usize, Ciphertext)> = Vec::new();
        let (plain, n) =
            codec.encrypt_update_streamed(&model, &mask, &pk, &mut rng, |c, ct| {
                seen.push((c, ct));
            });
        assert_eq!(n, baseline.cts.len());
        assert_eq!(plain, baseline.plain);
        assert_eq!(seen.len(), n);
        for (i, (c, ct)) in seen.iter().enumerate() {
            assert_eq!(*c, i, "chunks must stream in order");
            assert_eq!(ct, &baseline.cts[i], "chunk {i} differs");
        }
    }

    #[test]
    fn arena_encrypt_is_identical_and_reuses_buffers() {
        // Pooled-ciphertext gate: drawing outputs from a dirty arena must
        // not change a single ciphertext bit, and recycling must keep the
        // pool size stable (no fresh buffers) on the next round.
        let ctx = small_ctx();
        let (pk, _) = {
            let mut krng = ChaChaRng::from_seed(61, 0);
            ctx.keygen(&mut krng)
        };
        let total = 1500;
        let model: Vec<f32> = (0..total).map(|i| (i as f32 * 0.019).cos()).collect();
        let sens: Vec<f32> = (0..total).map(|i| ((i * 17) % 509) as f32).collect();
        let mask = EncryptionMask::top_p(&sens, 0.8);
        for workers in [1usize, 3] {
            let codec = SelectiveCodec::with_workers(ctx.clone(), workers);
            let baseline = {
                let mut rng = ChaChaRng::from_seed(62, 0);
                codec.encrypt_update(&model, &mask, &pk, &mut rng)
            };
            let arena = CtArena::new();
            // Poison the pool with garbage-filled buffers of the right
            // shape: every word must be rewritten by the encrypt.
            let mut dirty_rng = ChaChaRng::from_seed(63, 0);
            for _ in 0..2 {
                let mut ct = Ciphertext::zero(&codec.ctx.params);
                ct.c0 = RnsPoly::sample_uniform(&codec.ctx.params, &mut dirty_rng);
                ct.c1 = RnsPoly::sample_uniform(&codec.ctx.params, &mut dirty_rng);
                arena.recycle(ct);
            }
            let mut rng = ChaChaRng::from_seed(62, 0);
            let mut got: Vec<Ciphertext> = Vec::new();
            let (plain, n) = codec
                .encrypt_update_streamed_with_arena(&model, &mask, &pk, &mut rng, &arena, |c, ct| {
                    assert_eq!(c, got.len(), "chunks must stream in order");
                    got.push(ct);
                });
            assert_eq!(n, baseline.cts.len(), "workers={workers}");
            assert_eq!(plain, baseline.plain, "workers={workers}");
            assert_eq!(got, baseline.cts, "workers={workers}: arena stream differs");
            // Recycle the round's outputs: the pool now covers the next
            // round entirely, and `take` keeps draining it.
            let before = arena.len();
            for ct in got {
                arena.recycle(ct);
            }
            assert_eq!(arena.len(), before + n);
            let mut rng = ChaChaRng::from_seed(62, 0);
            let (_, n2) = codec
                .encrypt_update_streamed_with_arena(&model, &mask, &pk, &mut rng, &arena, |i, ct| {
                    assert_eq!(ct, baseline.cts[i], "recycled chunk {i} differs");
                    arena.recycle(ct);
                });
            assert_eq!(n2, n);
            assert!(arena.len() >= n, "recycled buffers must return to the pool");
        }
    }

    #[test]
    fn arena_drops_foreign_shapes() {
        // A buffer from a different parameter set must never be handed out.
        let ctx = small_ctx();
        let other = CkksContext::new(256, 3, 30).unwrap();
        let arena = CtArena::new();
        arena.recycle(Ciphertext::zero(&other.params));
        assert_eq!(arena.len(), 1);
        let ct = arena.take(&ctx.params);
        assert_eq!(ct.c0.n, ctx.params.n);
        assert_eq!(ct.c0.num_limbs(), ctx.params.num_limbs());
        assert!(arena.is_empty(), "foreign-shaped buffer should be dropped");
    }

    #[test]
    fn wire_bytes_scale_with_ratio() {
        let ctx = small_ctx();
        let ct_bytes = ctx.params.ciphertext_bytes();
        let codec = SelectiveCodec::new(ctx);
        let mut rng = ChaChaRng::from_seed(2, 0);
        let (pk, _) = codec.ctx.keygen(&mut rng);
        let params = vec![0.5f32; 2048];
        let sens: Vec<f32> = (0..2048).map(|i| i as f32).collect();
        let full = codec.encrypt_update(&params, &EncryptionMask::top_p(&sens, 1.0), &pk, &mut rng);
        let tenth =
            codec.encrypt_update(&params, &EncryptionMask::top_p(&sens, 0.1), &pk, &mut rng);
        let none = codec.encrypt_update(&params, &EncryptionMask::top_p(&sens, 0.0), &pk, &mut rng);
        assert_eq!(full.wire_bytes(&codec.ctx), 8 * ct_bytes); // 2048/256 slots
        assert_eq!(none.wire_bytes(&codec.ctx), 2048 * 4);
        assert!(tenth.wire_bytes(&codec.ctx) < full.wire_bytes(&codec.ctx) / 4);
    }

    #[test]
    fn limb_shard_bytes_tile_the_ciphertext_bytes() {
        let ctx = small_ctx();
        let codec = SelectiveCodec::new(ctx);
        let mut rng = ChaChaRng::from_seed(9, 0);
        let (pk, _) = codec.ctx.keygen(&mut rng);
        let params = vec![0.25f32; 1024];
        let upd = codec.encrypt_update(&params, &EncryptionMask::full(1024), &pk, &mut rng);
        let l = codec.ctx.params.num_limbs();
        // a 2-way limb partition carries the full ciphertext body; only the
        // per-message headers differ between the two formats
        let split = upd.limb_shard_wire_bytes(&codec.ctx, 0, l / 2)
            + upd.limb_shard_wire_bytes(&codec.ctx, l / 2, l);
        let full_ct_bytes = upd.wire_bytes(&codec.ctx) - 4 * upd.plain.len();
        let header_delta = upd.cts.len()
            * (2 * crate::ckks::serialize::shard_header_bytes()
                - crate::ckks::params::serialize_header_bytes());
        assert_eq!(split, full_ct_bytes + header_delta);
    }

    #[test]
    fn plaintext_part_is_exactly_preserved() {
        let ctx = small_ctx();
        let codec = SelectiveCodec::new(ctx);
        let mut rng = ChaChaRng::from_seed(3, 0);
        let (pk, sk) = codec.ctx.keygen(&mut rng);
        let params: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let sens: Vec<f32> = (0..100).map(|i| (i % 10) as f32).collect();
        let mask = EncryptionMask::top_p(&sens, 0.2);
        let upd = codec.encrypt_update(&params, &mask, &pk, &mut rng);
        let back = codec.decrypt_update(&upd, &mask, &sk);
        // plaintext coordinates are bit-exact
        for r in mask.plaintext_layout().runs() {
            for i in r.lo..r.hi {
                assert_eq!(back[i], params[i]);
            }
        }
    }

    #[test]
    #[should_panic(expected = "update/mask total mismatch")]
    fn decrypt_rejects_total_mismatch() {
        let ctx = small_ctx();
        let codec = SelectiveCodec::new(ctx);
        let mut rng = ChaChaRng::from_seed(13, 0);
        let (pk, sk) = codec.ctx.keygen(&mut rng);
        let params = vec![1.0f32; 100];
        let mask = EncryptionMask::full(100);
        let upd = codec.encrypt_update(&params, &mask, &pk, &mut rng);
        codec.decrypt_update(&upd, &EncryptionMask::full(200), &sk);
    }

    #[test]
    #[should_panic(expected = "update/mask total mismatch")]
    fn threshold_decrypt_rejects_total_mismatch() {
        use crate::ckks::threshold::*;
        let ctx = small_ctx();
        let codec = SelectiveCodec::new(ctx);
        let params_arc = codec.ctx.params.clone();
        let a = common_reference(&params_arc, 7);
        let mut rng = ChaChaRng::from_seed(14, 0);
        let parties: Vec<ThresholdParty> = (0..2)
            .map(|k| party_keygen(&params_arc, k, &a, &mut rng))
            .collect();
        let shares: Vec<&crate::ckks::RnsPoly> =
            parties.iter().map(|p| &p.b_share_ntt).collect();
        let pk = combine_public_key(&params_arc, &a, &shares);
        let params = vec![1.0f32; 100];
        let upd = codec.encrypt_update(&params, &EncryptionMask::full(100), &pk, &mut rng);
        let refs: Vec<&ThresholdParty> = parties.iter().collect();
        codec.decrypt_update_threshold(&upd, &EncryptionMask::full(200), &refs, &mut rng);
    }

    #[test]
    fn vector_helpers_roundtrip() {
        let ctx = small_ctx();
        let mut rng = ChaChaRng::from_seed(4, 0);
        let (pk, sk) = ctx.keygen(&mut rng);
        let values: Vec<f32> = (0..700).map(|i| (i as f32) * 1e-3).collect();
        let cts = encrypt_vector(&ctx, &values, &pk, &mut rng);
        assert_eq!(cts.len(), 3); // 700 / 256
        let back = decrypt_vector(&ctx, &cts, &sk, 700);
        assert_eq!(back.len(), 700);
        for (a, b) in values.iter().zip(back.iter()) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn threshold_decrypt_update_works() {
        use crate::ckks::threshold::*;
        let ctx = small_ctx();
        let codec = SelectiveCodec::new(ctx);
        let params_arc = codec.ctx.params.clone();
        let a = common_reference(&params_arc, 42);
        let mut rng = ChaChaRng::from_seed(5, 0);
        let parties: Vec<ThresholdParty> = (0..2)
            .map(|k| party_keygen(&params_arc, k, &a, &mut rng))
            .collect();
        let shares: Vec<&crate::ckks::RnsPoly> =
            parties.iter().map(|p| &p.b_share_ntt).collect();
        let pk = combine_public_key(&params_arc, &a, &shares);
        let params: Vec<f32> = (0..300).map(|i| (i as f32 * 0.11).cos()).collect();
        let sens = vec![1.0f32; 300];
        let mask = EncryptionMask::top_p(&sens, 0.5);
        let upd = codec.encrypt_update(&params, &mask, &pk, &mut rng);
        let refs: Vec<&ThresholdParty> = parties.iter().collect();
        let back = codec.decrypt_update_threshold(&upd, &mask, &refs, &mut rng);
        for (a, b) in params.iter().zip(back.iter()) {
            assert!((a - b).abs() < 1e-4);
        }
    }
}
