//! Encryption masks: which parameters get homomorphically protected.
//!
//! The paper's Selective Parameter Encryption ranks parameters by the
//! securely-aggregated sensitivity map and encrypts the top-`p` fraction;
//! random selection is the weaker baseline of Fig. 9; the "first and last
//! layers" heuristic is the Empirical Selection Recipe of §4.2.2.

use crate::crypto::prng::ChaChaRng;

/// A binary encryption mask over a flat parameter vector, stored as the
/// sorted list of encrypted indices.
#[derive(Debug, Clone, PartialEq)]
pub struct EncryptionMask {
    pub total: usize,
    /// Sorted indices of encrypted (protected) parameters.
    pub encrypted: Vec<u32>,
}

impl EncryptionMask {
    /// Encrypt everything (the vanilla-HE baseline).
    pub fn full(total: usize) -> Self {
        EncryptionMask {
            total,
            encrypted: (0..total as u32).collect(),
        }
    }

    /// Encrypt nothing (plaintext FedAvg).
    pub fn empty(total: usize) -> Self {
        EncryptionMask {
            total,
            encrypted: Vec::new(),
        }
    }

    /// Top-`p` fraction by sensitivity (the paper's selection strategy).
    pub fn top_p(sensitivity: &[f32], p: f64) -> Self {
        let total = sensitivity.len();
        let k = ((total as f64) * p.clamp(0.0, 1.0)).round() as usize;
        let mut idx: Vec<u32> = (0..total as u32).collect();
        // Partial selection: k largest by sensitivity.
        idx.select_nth_unstable_by(k.min(total.saturating_sub(1)), |&a, &b| {
            sensitivity[b as usize]
                .partial_cmp(&sensitivity[a as usize])
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        let mut encrypted: Vec<u32> = idx[..k].to_vec();
        encrypted.sort_unstable();
        EncryptionMask { total, encrypted }
    }

    /// Uniform-random `p` fraction (Fig. 9's baseline).
    pub fn random(total: usize, p: f64, rng: &mut ChaChaRng) -> Self {
        let k = ((total as f64) * p.clamp(0.0, 1.0)).round() as usize;
        let mut idx: Vec<u32> = (0..total as u32).collect();
        rng.shuffle(&mut idx);
        let mut encrypted: Vec<u32> = idx[..k].to_vec();
        encrypted.sort_unstable();
        EncryptionMask { total, encrypted }
    }

    /// The Empirical Selection Recipe: top-`p` sensitive parameters plus the
    /// first and last layer ranges.
    pub fn recipe(
        sensitivity: &[f32],
        p: f64,
        first_layer: std::ops::Range<usize>,
        last_layer: std::ops::Range<usize>,
    ) -> Self {
        let base = Self::top_p(sensitivity, p);
        let mut set: Vec<bool> = vec![false; sensitivity.len()];
        for &i in &base.encrypted {
            set[i as usize] = true;
        }
        for i in first_layer.chain(last_layer) {
            set[i] = true;
        }
        let encrypted = set
            .iter()
            .enumerate()
            .filter_map(|(i, &b)| b.then_some(i as u32))
            .collect();
        EncryptionMask {
            total: sensitivity.len(),
            encrypted,
        }
    }

    /// Number of encrypted parameters.
    pub fn encrypted_count(&self) -> usize {
        self.encrypted.len()
    }

    /// Actual encrypted ratio.
    pub fn ratio(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.encrypted.len() as f64 / self.total as f64
        }
    }

    /// Dense boolean view (for attack simulation / merging).
    pub fn to_dense(&self) -> Vec<bool> {
        let mut v = vec![false; self.total];
        for &i in &self.encrypted {
            v[i as usize] = true;
        }
        v
    }

    /// Sorted plaintext (unencrypted) indices.
    pub fn plaintext_indices(&self) -> Vec<u32> {
        let dense = self.to_dense();
        (0..self.total as u32)
            .filter(|&i| !dense[i as usize])
            .collect()
    }

    /// Serialize as little-endian u32 list prefixed with total (for the
    /// mask-distribution message of Algorithm 1 round 1).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(8 + 4 * self.encrypted.len());
        out.extend_from_slice(&(self.total as u32).to_le_bytes());
        out.extend_from_slice(&(self.encrypted.len() as u32).to_le_bytes());
        for &i in &self.encrypted {
            out.extend_from_slice(&i.to_le_bytes());
        }
        out
    }

    pub fn from_bytes(bytes: &[u8]) -> anyhow::Result<Self> {
        anyhow::ensure!(bytes.len() >= 8, "truncated mask");
        let total = u32::from_le_bytes(bytes[0..4].try_into().unwrap()) as usize;
        let k = u32::from_le_bytes(bytes[4..8].try_into().unwrap()) as usize;
        anyhow::ensure!(bytes.len() == 8 + 4 * k, "bad mask length");
        let mut encrypted = Vec::with_capacity(k);
        let mut prev: i64 = -1;
        for c in bytes[8..].chunks_exact(4) {
            let i = u32::from_le_bytes(c.try_into().unwrap());
            anyhow::ensure!((i as usize) < total, "mask index out of range");
            anyhow::ensure!(i as i64 > prev, "mask indices must be sorted unique");
            prev = i as i64;
            encrypted.push(i);
        }
        Ok(EncryptionMask { total, encrypted })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top_p_selects_most_sensitive() {
        let s: Vec<f32> = vec![0.1, 5.0, 0.2, 4.0, 0.05, 3.0, 0.3, 2.0, 0.01, 1.0];
        let m = EncryptionMask::top_p(&s, 0.3);
        assert_eq!(m.encrypted, vec![1, 3, 5]); // sensitivities 5,4,3
        assert_eq!(m.encrypted_count(), 3);
        assert!((m.ratio() - 0.3).abs() < 1e-9);
    }

    #[test]
    fn top_p_extremes() {
        let s = vec![1.0f32; 100];
        assert_eq!(EncryptionMask::top_p(&s, 0.0).encrypted_count(), 0);
        assert_eq!(EncryptionMask::top_p(&s, 1.0).encrypted_count(), 100);
        assert_eq!(EncryptionMask::full(100).encrypted_count(), 100);
        assert_eq!(EncryptionMask::empty(100).encrypted_count(), 0);
    }

    #[test]
    fn random_mask_has_right_size_and_spread() {
        let mut rng = ChaChaRng::from_seed(1, 0);
        let m = EncryptionMask::random(10_000, 0.25, &mut rng);
        assert_eq!(m.encrypted_count(), 2500);
        // sorted unique
        for w in m.encrypted.windows(2) {
            assert!(w[0] < w[1]);
        }
        // roughly uniform: mean index near total/2
        let mean: f64 =
            m.encrypted.iter().map(|&i| i as f64).sum::<f64>() / m.encrypted_count() as f64;
        assert!((mean - 5000.0).abs() < 300.0);
    }

    #[test]
    fn recipe_includes_boundary_layers() {
        let s = vec![0.0f32; 100];
        let m = EncryptionMask::recipe(&s, 0.0, 0..10, 90..100);
        assert_eq!(m.encrypted_count(), 20);
        assert!(m.encrypted.contains(&0) && m.encrypted.contains(&99));
    }

    #[test]
    fn plaintext_indices_complement() {
        let s: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let m = EncryptionMask::top_p(&s, 0.4);
        let enc: Vec<u32> = m.encrypted.clone();
        let plain = m.plaintext_indices();
        assert_eq!(enc.len() + plain.len(), 10);
        let mut all: Vec<u32> = enc.into_iter().chain(plain).collect();
        all.sort_unstable();
        assert_eq!(all, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn bytes_roundtrip_and_validation() {
        let s: Vec<f32> = (0..1000).map(|i| ((i * 7919) % 997) as f32).collect();
        let m = EncryptionMask::top_p(&s, 0.1);
        let b = m.to_bytes();
        assert_eq!(EncryptionMask::from_bytes(&b).unwrap(), m);
        // corrupt: unsorted
        let mut bad = b.clone();
        if m.encrypted.len() >= 2 {
            bad[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
            assert!(EncryptionMask::from_bytes(&bad).is_err());
        }
        assert!(EncryptionMask::from_bytes(&b[..b.len() - 2]).is_err());
    }
}
